"""Metamorphic properties of the constrained search.

These hold by the mathematics of L2 + the search's determinism, so any
violation is a bug in the queues / bitset / traversal — not a data issue.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SearchParams,
    constrained_search,
    equal_constraint,
    exact_constrained_search,
    label_set_from_lists,
    recall,
)
from repro.core.types import Corpus
from repro.data.synthetic import make_labeled_corpus, make_queries
from repro.graph.index import build_index

N, D, L = 2000, 12, 6


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=12, sample_size=128)
    q, qlab = make_queries(jax.random.PRNGKey(2), corpus, 12)
    return corpus, graph, q, qlab


PARAMS = SearchParams(mode="prefer", k=8, ef_result=64, n_start=16, max_iters=400)


def test_translation_invariance(world):
    """Shifting corpus AND queries by the same vector preserves results."""
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    res1 = constrained_search(corpus, graph, q, cons, PARAMS)
    shift = jnp.full((D,), 3.7)
    corpus2 = Corpus(vectors=corpus.vectors + shift, labels=corpus.labels)
    res2 = constrained_search(corpus2, graph, q + shift, cons, PARAMS)
    np.testing.assert_array_equal(np.asarray(res1.ids), np.asarray(res2.ids))
    np.testing.assert_allclose(
        np.asarray(res1.dists), np.asarray(res2.dists), rtol=1e-3, atol=1e-4
    )


def test_scale_equivariance(world):
    """Scaling all vectors by c scales squared distances by c^2, ids fixed."""
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    res1 = constrained_search(corpus, graph, q, cons, PARAMS)
    c = 2.5
    corpus2 = Corpus(vectors=corpus.vectors * c, labels=corpus.labels)
    res2 = constrained_search(corpus2, graph, q * c, cons, PARAMS)
    np.testing.assert_array_equal(np.asarray(res1.ids), np.asarray(res2.ids))
    fin = np.isfinite(np.asarray(res1.dists))
    np.testing.assert_allclose(
        np.asarray(res2.dists)[fin], np.asarray(res1.dists)[fin] * c * c,
        rtol=1e-3,
    )


def test_duplicate_queries_get_identical_rows(world):
    """Lock-step batching must keep queries independent."""
    corpus, graph, q, qlab = world
    qq = jnp.concatenate([q[:4], q[:4]], axis=0)
    cons = equal_constraint(jnp.concatenate([qlab[:4], qlab[:4]]), L)
    res = constrained_search(corpus, graph, qq, cons, PARAMS)
    np.testing.assert_array_equal(np.asarray(res.ids[:4]), np.asarray(res.ids[4:]))


def test_constraint_monotonicity_exact(world):
    """Enlarging the allowed set can only improve exact top-k distances."""
    corpus, graph, q, qlab = world
    small = label_set_from_lists([[0]] * q.shape[0], L)
    big = label_set_from_lists([[0, 1, 2]] * q.shape[0], L)
    d_small, _ = exact_constrained_search(corpus, q, small, k=8)
    d_big, _ = exact_constrained_search(corpus, q, big, k=8)
    fin = np.isfinite(np.asarray(d_small))
    assert np.all(np.asarray(d_big)[fin] <= np.asarray(d_small)[fin] + 1e-5)


def test_graph_results_are_subset_of_satisfied_corpus(world):
    """No hallucinated ids: every result exists and satisfies."""
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    res = constrained_search(corpus, graph, q, cons, PARAMS)
    ids = np.asarray(res.ids)
    assert ids.max() < N
    labs = np.asarray(corpus.labels)[np.maximum(ids, 0)]
    assert np.all((labs == np.asarray(qlab)[:, None]) | (ids < 0))


def test_exact_search_self_recall(world):
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    _, ti = exact_constrained_search(corpus, q, cons, k=8)
    assert float(recall(ti, ti)) == 1.0


def test_determinism_across_calls(world):
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    r1 = constrained_search(corpus, graph, q, cons, PARAMS)
    r2 = constrained_search(corpus, graph, q, cons, PARAMS)
    np.testing.assert_array_equal(np.asarray(r1.ids), np.asarray(r2.ids))
    np.testing.assert_array_equal(
        np.asarray(r1.stats.dist_evals), np.asarray(r2.stats.dist_evals)
    )


def test_ef_result_monotonically_nondecreasing_recall(world):
    """Bigger candidate lists never hurt recall (the QPS/recall knob)."""
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    _, ti = exact_constrained_search(corpus, q, cons, k=8)
    prev = 0.0
    for ef in (8, 32, 128):
        params = SearchParams(mode="prefer", k=8, ef_result=ef, n_start=16,
                              max_iters=400)
        r = float(recall(constrained_search(corpus, graph, q, cons, params).ids, ti))
        assert r >= prev - 0.02, (ef, prev, r)  # tiny tie-break slack
        prev = r
