"""Optimizers, train step (grad accum), checkpointing, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ck
from repro.train.compression import dequantize_int8, quantize_int8
from repro.train.optimizer import adafactor, adamw
from repro.train.train_step import make_train_step


def _quadratic_problem():
    w_true = jnp.asarray([1.5, -2.0, 0.5])

    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        mse = jnp.mean((pred - batch["y"]) ** 2)
        return mse, {"loss": mse}

    x = jax.random.normal(jax.random.PRNGKey(0), (64, 3))
    batch = {"x": x, "y": x @ w_true}
    params = {"w": jnp.zeros((3,))}
    return loss_fn, params, batch


@pytest.mark.parametrize("make_opt", [lambda: adamw(1e-1), lambda: adafactor(3e-1, momentum=0.9)])
def test_optimizers_reduce_loss(make_opt):
    loss_fn, params, batch = _quadratic_problem()
    opt = make_opt()
    state = opt.init(params)
    l0 = float(loss_fn(params, batch)[0])
    step = jax.jit(make_train_step(loss_fn, opt))
    for _ in range(60):
        params, state, metrics = step(params, state, batch)
    assert float(metrics["loss"]) < l0 * 0.05


def test_grad_accum_matches_full_batch():
    loss_fn, params, batch = _quadratic_problem()
    opt = adamw(1e-2)
    s1 = opt.init(params)
    s4 = opt.init(params)
    p1, _, _ = jax.jit(make_train_step(loss_fn, opt))(params, s1, batch)
    p4, _, _ = jax.jit(make_train_step(loss_fn, opt, grad_accum=4))(params, s4, batch)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]), rtol=1e-4)


def test_adafactor_state_is_factored():
    opt = adafactor(1e-2)
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    st = opt.init(params)
    assert st["vr"]["w"].shape == (8,)
    assert st["vc"]["w"].shape == (4,)
    assert st["vr"]["b"].shape == (4,)  # non-factored fallback


def test_checkpoint_roundtrip_and_atomicity(tmp_path):
    tree = {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.asarray([1, 2, 3], jnp.int32)},
    }
    d = str(tmp_path / "ckpt")
    ck.save(d, 5, tree)
    ck.save(d, 9, jax.tree.map(lambda x: x + 1, tree))
    assert ck.latest_step(d) == 9
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored = ck.restore(d, 9, like)
    np.testing.assert_allclose(np.asarray(restored["a"]), np.asarray(tree["a"]) + 1)
    # no .tmp dirs leak
    assert not [f for f in os.listdir(d) if f.endswith(".tmp")]
    ck.prune_old(d, keep=1)
    assert ck.latest_step(d) == 9
    assert len(os.listdir(d)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    d = str(tmp_path / "ckpt")
    ck.save(d, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ck.restore(d, 1, {"w": jax.ShapeDtypeStruct((5,), jnp.float32)})


def test_int8_quantization_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 3.0
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s)
    err = jnp.abs(deq - x)
    # max error is half a quantization bucket per row
    bound = s[:, 0] * 0.5 + 1e-6
    assert bool(jnp.all(jnp.max(err, axis=-1) <= bound))


def test_error_feedback_conserves_mass():
    """Across steps, sum(dequantized) + residual == sum(true grads): the EF
    residual is exactly the as-yet-unapplied mass (no silent loss)."""
    rng = jax.random.PRNGKey(0)
    total_true = jnp.zeros((4, 8))
    total_deq = jnp.zeros((4, 8))
    err = jnp.zeros((4, 8))
    for i in range(5):
        g = jax.random.normal(jax.random.fold_in(rng, i), (4, 8)) * (10.0 ** -i)
        total_true = total_true + g
        q, s = quantize_int8(g + err)
        deq = dequantize_int8(q, s)
        err = (g + err) - deq
        total_deq = total_deq + deq
    np.testing.assert_allclose(
        np.asarray(total_deq + err), np.asarray(total_true), rtol=1e-5, atol=1e-6
    )


def test_restart_determinism_of_data_pipeline():
    from repro.data.pipeline import lm_batch

    a = lm_batch(7, 123, 4, 16, 1000)
    b = lm_batch(7, 123, 4, 16, 1000)
    c = lm_batch(7, 124, 4, 16, 1000)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
