"""Fault-tolerance integration: a training run is killed mid-flight and
resumed from its checkpoint; the resumed run must (a) continue from the
checkpointed step, (b) see exactly the batches it would have seen
(deterministic data), and (c) end within tolerance of an uninterrupted run.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from repro.ckpt import checkpoint as ck
from repro.data.pipeline import lm_batch
from repro.distributed.meshinfo import single_device_meshinfo
from repro.models.transformer.model import TransformerConfig, init_params, lm_loss
from repro.train.optimizer import adamw
from repro.train.train_step import make_train_step

MI = single_device_meshinfo()


def _cfg():
    return TransformerConfig(
        name="ft", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=64, vocab_size=128, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, attn_chunk=8, ce_chunk=8, remat="none",
    )


def _run(cfg, steps, start=0, params=None, opt_state=None, ckpt_dir=None,
         ckpt_every=5):
    opt = adamw(1e-3)
    if params is None:
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(lambda p, b: lm_loss(p, cfg, MI, b), opt))
    for step in range(start, steps):
        batch = lm_batch(13, step, 2, 16, cfg.vocab_size)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if ckpt_dir and step and step % ckpt_every == 0:
            ck.save(ckpt_dir, step, {"p": params, "o": opt_state})
    return params, opt_state, float(metrics["loss"])


def test_kill_and_resume_matches_uninterrupted(tmp_path):
    cfg = _cfg()
    # Uninterrupted reference: 12 steps.
    p_ref, _, loss_ref = _run(cfg, 12)

    # "Preempted" run: dies after step 9 (last checkpoint at step 10? no —
    # saved at 5 and 10; simulate death at step 11 before any further save).
    d = str(tmp_path / "ck")
    _run(cfg, 11, ckpt_dir=d, ckpt_every=5)
    last = ck.latest_step(d)
    assert last == 10

    # Resume from step 10 and finish to 12.
    params_abs = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    opt = adamw(1e-3)
    opt_abs = jax.eval_shape(opt.init, params_abs)
    state = ck.restore(d, last, {"p": params_abs, "o": opt_abs})
    p_res, _, loss_res = _run(
        cfg, 12, start=last, params=state["p"], opt_state=state["o"]
    )
    # The checkpoint stores the post-step-10 state, so the resumed run
    # replays steps 10..11; step 10's update is applied twice relative to
    # the reference — a one-step perturbation, so compare within tolerance
    # (the standard at-least-once resume semantics).
    assert abs(loss_res - loss_ref) < 0.15, (loss_res, loss_ref)
    # parameters stay close
    diff = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p_res), jax.tree.leaves(p_ref))
    )
    assert diff < 0.05, diff


def test_driver_subprocess_kill_resume(tmp_path):
    """The real launch driver: run 8 steps, then resume to 16 in a second
    process — the resume banner must appear and training must complete."""
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    d = str(tmp_path / "drv")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "smoke-gqa",
            "--ckpt-dir", d, "--ckpt-every", "4"]
    r1 = subprocess.run(args + ["--steps", "8"], capture_output=True, text=True,
                        env=env, cwd=root, timeout=600)
    assert r1.returncode == 0, r1.stdout + r1.stderr
    r2 = subprocess.run(args + ["--steps", "16"], capture_output=True, text=True,
                        env=env, cwd=root, timeout=600)
    assert r2.returncode == 0, r2.stdout + r2.stderr
    assert "[resume] restoring step 8" in r2.stdout, r2.stdout
    assert "training complete" in r2.stdout
