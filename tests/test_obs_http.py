"""HTTP front-end over the serving runtime (DESIGN.md §12).

A real ThreadingHTTPServer on a loopback socket, over a *VirtualClock*
runtime — the pump thread supplies the passage of time, so these tests
are deterministic about batching semantics while exercising the actual
wire path (JSON framing, status codes, the Prometheus content type, and
graceful shutdown with the injected clock).
"""
import json
import urllib.error
import urllib.request

import jax
import numpy as np
import pytest

from repro.data.synthetic import make_labeled_corpus
from repro.graph.index import build_index
from repro.obs import JsonLogger, parse_exposition, trace_consistent
from repro.obs.http import ServingFrontend
from repro.serving import (
    LocalExecutor,
    ServingRuntime,
    VirtualClock,
    make_tier_ladder,
)

N, D, L = 1500, 16, 5


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    corpus = corpus.replace(
        attrs=jax.random.uniform(jax.random.PRNGKey(50), (N, 2))
    )
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=12,
                        sample_size=128)
    return corpus, graph


@pytest.fixture(scope="module")
def frontend(world):
    corpus, graph = world
    rt = ServingRuntime(
        LocalExecutor(corpus, graph),
        n_labels=L,
        tiers=make_tier_ladder(k_cap=8, base_ef=32, base_iters=64, n_tiers=1),
        ladder=(4,),
        max_wait=0.002,
        clock=VirtualClock(),
    )
    rt.warmup()
    logger = JsonLogger()
    fe = ServingFrontend(rt, logger=logger)
    fe.start()
    yield fe
    if fe._server is not None:  # shutdown test may have closed it already
        fe.close(drain=True)


def _post(fe, path, payload, timeout=30):
    req = urllib.request.Request(
        fe.address + path,
        data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"},
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _get(fe, path, timeout=30):
    try:
        with urllib.request.urlopen(fe.address + path, timeout=timeout) as r:
            return r.status, r.read().decode(), dict(r.headers)
    except urllib.error.HTTPError as e:
        return e.code, e.read().decode(), dict(e.headers)


def test_search_label_and_range_round_trip(frontend):
    st, body = _post(frontend, "/v1/search", {
        "query": [0.1] * D, "k": 4, "family": "label", "labels": [0, 1],
    })
    assert st == 200
    assert body["filled"] >= 1 and len(body["ids"]) == 4
    assert body["trace"] is not None and trace_consistent(body["trace"])
    assert body["batch_id"] >= 0
    st, body = _post(frontend, "/v1/search", {
        "query": [0.1] * D, "k": 4, "family": "range",
        "range": [0.1, 0.9, 0],
    })
    assert st == 200 and body["filled"] >= 1


def test_bad_requests_are_400(frontend):
    st, body = _post(frontend, "/v1/search", {"query": [0.1] * D, "k": 4,
                                              "family": "nope"})
    assert st == 400 and "family" in body["error"]
    st, body = _post(frontend, "/v1/search", {"k": 4, "family": "label",
                                              "labels": [0]})
    assert st == 400  # missing query
    st, body = _post(frontend, "/v1/search", {
        "query": [0.1] * D, "k": 4, "family": "label",  # labels missing
    })
    assert st == 400
    st, body = _post(frontend, "/v1/search", {
        "query": [0.1] * D, "k": 999, "family": "label", "labels": [0],
    })
    assert st == 400  # k over the ladder cap
    st, body = _post(frontend, "/nope", {})
    assert st == 404


def test_metrics_endpoint_parses_and_matches(frontend):
    # At least the two searches from the round-trip test have completed.
    st, text, headers = _get(frontend, "/metrics")
    assert st == 200
    assert headers["Content-Type"].startswith("text/plain")
    fams = parse_exposition(text)
    tel = frontend.runtime.telemetry
    with frontend.lock:
        completed = tel.counters["completed"]
        hist_count = tel.latency_hist.total
    assert fams["repro_serving_events_total"].value(event="completed") == completed
    assert fams["repro_serving_latency_seconds"].hist_count() == hist_count


def test_healthz_and_varz(frontend):
    st, text, _ = _get(frontend, "/healthz")
    assert st == 200
    body = json.loads(text)
    assert body["status"] == "ok"
    assert body["in_flight"] == 0
    st, text, _ = _get(frontend, "/varz")
    assert st == 200
    body = json.loads(text)
    assert {"telemetry", "cache", "controller", "degradation_level",
            "started_requests"} <= set(body)
    assert body["started_requests"] >= 2


def test_backpressure_maps_to_429(world):
    corpus, graph = world
    rt = ServingRuntime(
        LocalExecutor(corpus, graph), n_labels=L,
        tiers=make_tier_ladder(k_cap=8, base_ef=32, base_iters=64, n_tiers=1),
        ladder=(4,), max_wait=0.002, max_pending=0, clock=VirtualClock(),
    )
    fe = ServingFrontend(rt)
    fe.start()
    try:
        st, body = _post(fe, "/v1/search", {
            "query": [0.1] * D, "k": 4, "family": "label", "labels": [0],
        })
        assert st == 429 and "max_pending" in body["error"]
    finally:
        fe.close(drain=False)


def test_graceful_shutdown_drains_and_flushes(frontend, tmp_path):
    log_path = tmp_path / "serve_log.jsonl"
    addr = frontend.address  # capture before close resets the bound port
    report = frontend.close(drain=True, log_path=str(log_path))
    assert report["in_flight"] == 0
    assert report["log_records_flushed"] > 0
    records = [json.loads(x) for x in log_path.read_text().splitlines()]
    assert len(records) == report["log_records_flushed"]
    events = {r["event"] for r in records}
    assert "http_shutdown" in events
    # The injected clock stamped every record with virtual time.
    assert all("ts" in r for r in records)
    # Closed socket: new connections are refused.
    with pytest.raises((urllib.error.URLError, OSError)):
        urllib.request.urlopen(addr + "/healthz", timeout=2)
