"""Per-kernel allclose sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_kernel
from repro.kernels.embedding_bag.ops import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_ref
from repro.kernels.gather_distance.gather_distance import gather_distance_kernel
from repro.kernels.gather_distance.ref import gather_distance_ref
from repro.kernels.l2_matmul.l2_matmul import l2_matmul
from repro.kernels.l2_matmul.ref import l2_matmul_ref
from repro.kernels.pq_adc.pq_adc import pq_adc_kernel
from repro.kernels.pq_adc.ref import pq_adc_ref


def key(i):
    return jax.random.PRNGKey(i)


@pytest.mark.parametrize("m,n,d", [(7, 13, 8), (64, 128, 32), (33, 250, 130), (1, 5, 3)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l2_matmul_shapes(m, n, d, dtype):
    q = jax.random.normal(key(0), (m, d), dtype)
    x = jax.random.normal(key(1), (n, d), dtype)
    out = l2_matmul(q, x, bm=16, bn=32, bk=64, interpret=True)
    ref = l2_matmul_ref(q, x)
    tol = 1e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol * d)


def test_l2_matmul_block_sweep():
    q = jax.random.normal(key(2), (40, 96))
    x = jax.random.normal(key(3), (70, 96))
    ref = l2_matmul_ref(q, x)
    for bm, bn, bk in [(8, 8, 32), (16, 64, 96), (40, 70, 96), (128, 128, 512)]:
        out = l2_matmul(q, x, bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-2)


def test_l2_matmul_nonnegative_identical_rows():
    x = jax.random.normal(key(4), (20, 16))
    out = l2_matmul(x, x, bm=8, bn=8, bk=16, interpret=True)
    assert float(jnp.min(out)) >= 0.0
    np.testing.assert_allclose(jnp.diag(out), 0.0, atol=1e-4)


@pytest.mark.parametrize("b,m,n,d", [(4, 8, 100, 16), (9, 17, 333, 64), (1, 1, 10, 8)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gather_distance_shapes(b, m, n, d, dtype):
    q = jax.random.normal(key(5), (b, d), dtype)
    corpus = jax.random.normal(key(6), (n, d), dtype)
    ids = jax.random.randint(key(7), (b, m), -2, n)
    out = gather_distance_kernel(q, corpus, ids, interpret=True)
    ref = gather_distance_ref(q, corpus, ids)
    assert bool(jnp.all(jnp.isinf(out) == jnp.isinf(ref)))
    fin = jnp.isfinite(ref)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        jnp.where(fin, out, 0.0), jnp.where(fin, ref, 0.0), rtol=tol, atol=tol * d
    )


@pytest.mark.parametrize("b,n,m_sub,n_cent", [(2, 50, 4, 8), (3, 257, 16, 256), (1, 1000, 8, 16)])
def test_pq_adc_shapes(b, n, m_sub, n_cent):
    lut = jax.random.normal(key(8), (b, m_sub, n_cent))
    codes = jax.random.randint(key(9), (n, m_sub), 0, n_cent)
    out = pq_adc_kernel(lut, codes, bn=64, interpret=True)
    ref = pq_adc_ref(lut, codes)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("v,d,b,bag", [(50, 8, 3, 5), (1000, 64, 7, 20), (10, 128, 2, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_shapes(v, d, b, bag, dtype):
    table = jax.random.normal(key(10), (v, d), dtype)
    ids = jax.random.randint(key(11), (b, bag), -3, v)
    out = embedding_bag_kernel(table, ids, interpret=True)
    ref = embedding_bag_ref(table, ids)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol * bag)


def test_embedding_bag_mean_mode():
    table = jax.random.normal(key(12), (20, 4))
    ids = jnp.array([[0, 1, -1, -1], [2, 3, 4, 5]], dtype=jnp.int32)
    out = embedding_bag(table, ids, mode="mean")
    expect0 = (table[0] + table[1]) / 2.0
    expect1 = (table[2] + table[3] + table[4] + table[5]) / 4.0
    np.testing.assert_allclose(out[0], expect0, rtol=1e-5)
    np.testing.assert_allclose(out[1], expect1, rtol=1e-5)


def test_embedding_bag_all_padding_row():
    table = jax.random.normal(key(13), (20, 4))
    ids = jnp.full((2, 3), -1, jnp.int32)
    out = embedding_bag_kernel(table, ids, interpret=True)
    np.testing.assert_allclose(out, 0.0, atol=0)
