"""Property tests for the fixed-capacity sorted-array priority queues."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import queue as q


@st.composite
def batch_ops(draw):
    cap = draw(st.integers(2, 16))
    n_push = draw(st.integers(1, 5))
    pushes = [
        draw(
            st.lists(
                st.floats(2.0**-20, 2.0**20, width=32), min_size=1, max_size=8
            )
        )
        for _ in range(n_push)
    ]
    return cap, pushes


@settings(deadline=None, max_examples=30)
@given(batch_ops())
def test_queue_matches_sorted_reference(ops):
    cap, pushes = ops
    qq = q.queue_init(1, cap)
    ref: list[float] = []
    next_id = 0
    for vals in pushes:
        ids = jnp.arange(next_id, next_id + len(vals), dtype=jnp.int32)[None]
        d = jnp.asarray(vals, jnp.float32)[None]
        qq = q.queue_push(qq, d, ids, jnp.ones_like(d, bool))
        ref.extend(vals)
        ref = sorted(ref)[:cap]
        next_id += len(vals)
    np.testing.assert_allclose(
        np.asarray(qq.dists[0][: len(ref)]), np.asarray(ref, np.float32), rtol=1e-6
    )
    # queue stays ascending with +inf padding
    d = np.asarray(qq.dists[0])
    assert np.all(np.diff(d) >= 0) or np.all(np.isinf(d[np.argsort(d)][len(ref):]))


@settings(deadline=None, max_examples=30)
@given(batch_ops())
def test_queue_pop_returns_min(ops):
    cap, pushes = ops
    qq = q.queue_init(1, cap)
    for i, vals in enumerate(pushes):
        ids = jnp.full((1, len(vals)), i, jnp.int32)
        qq = q.queue_push(
            qq, jnp.asarray(vals, jnp.float32)[None], ids, jnp.ones((1, len(vals)), bool)
        )
    prev = -np.inf
    while bool(q.queue_nonempty(qq)[0]):
        qq, d, _ = q.queue_pop(qq, jnp.ones((1,), bool))
        assert float(d[0]) >= prev  # pops come out ascending
        prev = float(d[0])


def test_pop_on_masked_rows_is_noop():
    qq = q.queue_init(2, 4)
    d = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    ids = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    qq = q.queue_push(qq, d, ids, jnp.ones((2, 2), bool))
    qq2, head_d, _ = q.queue_pop(qq, jnp.asarray([True, False]))
    assert float(qq2.dists[0, 0]) == 2.0  # popped
    assert float(qq2.dists[1, 0]) == 3.0  # untouched


def test_invalid_pushes_are_ignored():
    qq = q.queue_init(1, 4)
    qq = q.queue_push(
        qq,
        jnp.asarray([[5.0, 1.0]]),
        jnp.asarray([[7, 8]], jnp.int32),
        jnp.asarray([[False, True]]),
    )
    assert int(q.queue_size(qq)[0]) == 1
    assert float(qq.dists[0, 0]) == 1.0


def test_topk_threshold_inf_until_full():
    qq = q.queue_init(1, 3)
    assert np.isinf(float(q.topk_threshold(qq, 3)[0]))
    qq = q.queue_push(
        qq,
        jnp.asarray([[1.0, 2.0, 3.0]]),
        jnp.asarray([[1, 2, 3]], jnp.int32),
        jnp.ones((1, 3), bool),
    )
    assert float(q.topk_threshold(qq, 3)[0]) == 3.0
