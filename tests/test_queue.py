"""Property tests for the fixed-capacity sorted-array priority queues."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import queue as q  # noqa: E402


@st.composite
def batch_ops(draw):
    cap = draw(st.integers(2, 16))
    n_push = draw(st.integers(1, 5))
    pushes = [
        draw(
            st.lists(
                st.floats(2.0**-20, 2.0**20, width=32), min_size=1, max_size=8
            )
        )
        for _ in range(n_push)
    ]
    return cap, pushes


@settings(deadline=None, max_examples=30)
@given(batch_ops())
def test_queue_matches_sorted_reference(ops):
    cap, pushes = ops
    qq = q.queue_init(1, cap)
    ref: list[float] = []
    next_id = 0
    for vals in pushes:
        ids = jnp.arange(next_id, next_id + len(vals), dtype=jnp.int32)[None]
        d = jnp.asarray(vals, jnp.float32)[None]
        qq = q.queue_push(qq, d, ids, jnp.ones_like(d, bool))
        ref.extend(vals)
        ref = sorted(ref)[:cap]
        next_id += len(vals)
    np.testing.assert_allclose(
        np.asarray(qq.dists[0][: len(ref)]), np.asarray(ref, np.float32), rtol=1e-6
    )
    # queue stays ascending with +inf padding
    d = np.asarray(qq.dists[0])
    assert np.all(np.diff(d) >= 0) or np.all(np.isinf(d[np.argsort(d)][len(ref):]))


@settings(deadline=None, max_examples=30)
@given(batch_ops())
def test_queue_pop_returns_min(ops):
    cap, pushes = ops
    qq = q.queue_init(1, cap)
    for i, vals in enumerate(pushes):
        ids = jnp.full((1, len(vals)), i, jnp.int32)
        qq = q.queue_push(
            qq, jnp.asarray(vals, jnp.float32)[None], ids, jnp.ones((1, len(vals)), bool)
        )
    prev = -np.inf
    while bool(q.queue_nonempty(qq)[0]):
        qq, d, _ = q.queue_pop(qq, jnp.ones((1,), bool))
        assert float(d[0]) >= prev  # pops come out ascending
        prev = float(d[0])


def test_pop_on_masked_rows_is_noop():
    qq = q.queue_init(2, 4)
    d = jnp.asarray([[1.0, 2.0], [3.0, 4.0]])
    ids = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
    qq = q.queue_push(qq, d, ids, jnp.ones((2, 2), bool))
    qq2, head_d, _ = q.queue_pop(qq, jnp.asarray([True, False]))
    assert float(qq2.dists[0, 0]) == 2.0  # popped
    assert float(qq2.dists[1, 0]) == 3.0  # untouched


def test_invalid_pushes_are_ignored():
    qq = q.queue_init(1, 4)
    qq = q.queue_push(
        qq,
        jnp.asarray([[5.0, 1.0]]),
        jnp.asarray([[7, 8]], jnp.int32),
        jnp.asarray([[False, True]]),
    )
    assert int(q.queue_size(qq)[0]) == 1
    assert float(qq.dists[0, 0]) == 1.0


@st.composite
def merge_cases(draw):
    cap = draw(st.integers(2, 16))
    n_live = draw(st.integers(0, 16))
    live = sorted(
        draw(
            st.lists(
                st.floats(0.0, 2.0**10, width=32), min_size=n_live, max_size=n_live
            )
        )
    )
    m = draw(st.integers(1, 12))
    new = draw(st.lists(st.floats(0.0, 2.0**10, width=32), min_size=m, max_size=m))
    valid = draw(st.lists(st.booleans(), min_size=m, max_size=m))
    # duplicate some values across queue and run to force tie-breaking
    if live and draw(st.booleans()):
        new[0] = live[0]
    return cap, live, new, valid


@settings(deadline=None, max_examples=60)
@given(merge_cases())
def test_merge_sorted_bit_for_bit_equals_push(case):
    """sort_run + queue_merge_sorted == queue_push on ANY batch — including
    ties (queue element first, then original slot order), invalid entries,
    overflow past capacity, and runs longer than the free space."""
    cap, live, new, valid = case
    qq = q.queue_init(1, cap)
    if live:
        qq = q.queue_push(
            qq,
            jnp.asarray(live, jnp.float32)[None],
            jnp.arange(len(live), dtype=jnp.int32)[None],
            jnp.ones((1, len(live)), bool),
        )
    nd = jnp.asarray(new, jnp.float32)[None]
    ni = jnp.arange(100, 100 + len(new), dtype=jnp.int32)[None]
    nv = jnp.asarray(valid)[None]
    run_d, run_i = q.sort_run(nd, ni, nv)
    merged = q.queue_merge_sorted(qq, run_d, run_i)
    pushed = q.queue_push(qq, nd, ni, nv)
    np.testing.assert_array_equal(np.asarray(merged.dists), np.asarray(pushed.dists))
    np.testing.assert_array_equal(np.asarray(merged.ids), np.asarray(pushed.ids))


def test_merge_sorted_empty_run_and_empty_queue():
    qq = q.queue_init(2, 4)
    nd = jnp.full((2, 3), jnp.inf)
    ni = jnp.full((2, 3), -1, jnp.int32)
    merged = q.queue_merge_sorted(qq, nd, ni)
    np.testing.assert_array_equal(np.asarray(merged.dists), np.asarray(qq.dists))
    np.testing.assert_array_equal(np.asarray(merged.ids), np.asarray(qq.ids))


def test_sort_run_stable_under_ties():
    d = jnp.asarray([[2.0, 1.0, 2.0, 0.5, 1.0]])
    i = jnp.asarray([[10, 11, 12, 13, 14]], jnp.int32)
    v = jnp.asarray([[True, True, True, False, True]])
    rd, ri = q.sort_run(d, i, v)
    np.testing.assert_allclose(np.asarray(rd[0]), [1.0, 1.0, 2.0, 2.0, np.inf])
    # equal distances keep original slot order; invalid slots drop to padding
    np.testing.assert_array_equal(np.asarray(ri[0]), [11, 14, 10, 12, -1])


def test_partition_sorted_runs_splits_and_truncates():
    d = jnp.asarray([[3.0, 1.0, 2.0, 1.0, 5.0, 0.5]])
    i = jnp.asarray([[10, 11, 12, 13, 14, 15]], jnp.int32)
    first = jnp.asarray([[True, False, True, False, False, False]])
    second = jnp.asarray([[False, True, False, True, True, False]])
    (fd, fi), (sd, si) = q.partition_sorted_runs(d, i, first, second, 4, 2)
    np.testing.assert_allclose(np.asarray(fd[0]), [2.0, 3.0, np.inf, np.inf])
    np.testing.assert_array_equal(np.asarray(fi[0]), [12, 10, -1, -1])
    # second run truncated to capacity 2: best two of {1.0@11, 1.0@13, 5.0@14}
    np.testing.assert_allclose(np.asarray(sd[0]), [1.0, 1.0])
    np.testing.assert_array_equal(np.asarray(si[0]), [11, 13])


def test_topk_threshold_inf_until_full():
    qq = q.queue_init(1, 3)
    assert np.isinf(float(q.topk_threshold(qq, 3)[0]))
    qq = q.queue_push(
        qq,
        jnp.asarray([[1.0, 2.0, 3.0]]),
        jnp.asarray([[1, 2, 3]], jnp.int32),
        jnp.ones((1, 3), bool),
    )
    assert float(q.topk_threshold(qq, 3)[0]) == 3.0
