"""Metrics registry + Prometheus exposition (DESIGN.md §12).

Two layers: the primitives (families, label sets, render) against the
satellite line-format parser, and the runtime adapters — after a real
replayed workload, the scraped ``/metrics`` text must parse back
*bit-identical* to ``Telemetry``'s in-process state (the PR 9 acceptance
criterion: no double bookkeeping, no drift).
"""
import math
import re

import jax
import pytest

from repro.data.synthetic import make_labeled_corpus
from repro.graph.index import build_index
from repro.obs import (
    Counter,
    ExpositionParseError,
    MetricsRegistry,
    format_value,
    instrument_runtime,
    latency_hist_samples,
    parse_exposition,
)
from repro.serving import (
    LatencyHistogram,
    LocalExecutor,
    ServingRuntime,
    VirtualClock,
    label_words_row,
    make_tier_ladder,
    mixed_workload,
    replay_poisson,
)

N, D, L = 1500, 16, 5
NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


def test_format_value_round_trips():
    for v in (0.0, 17.0, -3.0, 0.1, 1e-6, 59.999999999, 2.5, 1 / 3):
        assert float(format_value(v).replace("+Inf", "inf")) == v
    assert format_value(17.0) == "17"  # integral counters scrape as ints
    assert format_value(float("inf")) == "+Inf"
    assert format_value(float("-inf")) == "-Inf"
    assert format_value(float("nan")) == "NaN"


def test_counter_gauge_basics_and_validation():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "help", labels=("kind",))
    c.labels(kind="a").inc()
    c.labels(kind="a").inc(2)
    c.labels(kind="b").inc()
    with pytest.raises(ValueError):
        c.labels(kind="a").inc(-1)  # counters only go up
    with pytest.raises(ValueError):
        c.labels(wrong="a")  # undeclared label name
    g = reg.gauge("g", "help")
    g.set(5)
    g.dec(2)
    assert g.value == 3
    g.set_function(lambda: 42.0)
    assert g.value == 42.0
    with pytest.raises(ValueError):
        reg.counter("c_total", "dup")  # duplicate registration
    with pytest.raises(ValueError):
        reg.counter("0bad", "bad name")
    with pytest.raises(ValueError):
        Counter("ok", "h", ("__reserved",))
    fams = parse_exposition(reg.render_prometheus())
    assert fams["c_total"].value(kind="a") == 3
    assert fams["c_total"].value(kind="b") == 1
    assert fams["g"].value() == 42.0


def test_histogram_family_render_and_parse():
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "h", buckets=(0.1, 1.0, 10.0))
    for x in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(x)
    fams = parse_exposition(reg.render_prometheus())
    fam = fams["lat_seconds"]
    assert fam.mtype == "histogram"
    assert fam.buckets() == [(0.1, 1), (1.0, 3), (10.0, 4), (math.inf, 5)]
    assert fam.hist_count() == 5
    assert fam.hist_sum() == pytest.approx(56.05)
    with pytest.raises(ValueError):
        reg.histogram("bad", "h", buckets=(1.0, 0.5))  # unsorted edges


def test_label_value_escaping_round_trips():
    reg = MetricsRegistry()
    c = reg.counter("esc_total", "has \\ and \n newline", labels=("v",))
    tricky = 'a"b\\c\nd'
    c.labels(v=tricky).inc()
    fams = parse_exposition(reg.render_prometheus())
    assert fams["esc_total"].label_values("v") == [tricky]
    assert "\n" in fams["esc_total"].help


def test_exposition_line_format_discipline():
    """Every non-comment line: valid name charset, HELP/TYPE seen before
    any sample of that family."""
    reg = MetricsRegistry()
    reg.counter("a_total", "ha").inc()
    reg.gauge("b", "hb", labels=("x",)).labels(x="1").set(2)
    reg.histogram("h_seconds", "hh").observe(0.3)
    text = reg.render_prometheus()
    seen_meta = set()
    for line in text.splitlines():
        if line.startswith("# "):
            _, kind, name = line.split(None, 3)[:3]
            assert kind in ("HELP", "TYPE")
            seen_meta.add(name)
            continue
        name = re.split(r"[{\s]", line, maxsplit=1)[0]
        assert NAME_RE.match(name), line
        base = re.sub(r"_(bucket|sum|count)\Z", "", name)
        assert name in seen_meta or base in seen_meta, line


def test_parser_rejects_malformed_payloads():
    with pytest.raises(ExpositionParseError):
        parse_exposition("x_total{oops} 1\n")
    with pytest.raises(ExpositionParseError):
        parse_exposition("x_total one\n")
    with pytest.raises(ExpositionParseError):
        parse_exposition("# TYPE h histogram\nh_bucket{le=\"1\"} 2\n"
                         "h_bucket{le=\"+Inf\"} 1\nh_sum 1\nh_count 1\n")
    with pytest.raises(ExpositionParseError):
        # non-cumulative then missing +Inf
        parse_exposition("# TYPE h histogram\nh_bucket{le=\"1\"} 1\n"
                         "h_sum 1\nh_count 1\n")
    with pytest.raises(ExpositionParseError):
        parse_exposition("# HELP a one\n# HELP a two\na 1\n")


def test_latency_hist_samples_bit_identical():
    """The adapter's native-histogram view reproduces a LatencyHistogram
    exactly: cumulative counts, _sum, _count, and the quantile rule."""
    hist = LatencyHistogram()
    import numpy as np

    rng = np.random.default_rng(3)
    for x in np.exp(rng.uniform(math.log(1e-5), math.log(50.0), 500)):
        hist.record(float(x))
    hist.record(0.0)  # underflow
    hist.record(100.0)  # overflow
    reg = MetricsRegistry()
    reg.callback("lh_seconds", "histogram", "h",
                 lambda: latency_hist_samples(hist))
    fam = parse_exposition(reg.render_prometheus())["lh_seconds"]
    assert fam.hist_count() == hist.total
    assert fam.hist_sum() == hist.sum  # bit-identical, not approx
    buckets = fam.buckets()
    assert buckets[-1][0] == math.inf
    assert buckets[-1][1] == hist.total
    for p in (1, 50, 90, 99, 100):
        assert fam.quantile(p) == hist.quantile(p), p


# ---------------------------------------------------------------------------
# runtime adapters: scrape == Telemetry, after a real workload
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def served_runtime():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    corpus = corpus.replace(
        attrs=jax.random.uniform(jax.random.PRNGKey(50), (N, 2))
    )
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=12,
                        sample_size=128)
    rt = ServingRuntime(
        LocalExecutor(corpus, graph),
        n_labels=L,
        tiers=make_tier_ladder(k_cap=8, base_ef=32, base_iters=64, n_tiers=2),
        ladder=(4, 16),
        max_wait=0.002,
        clock=VirtualClock(),
    )
    rt.warmup()
    items = mixed_workload(7, corpus, 64, L, k_choices=(4, 8))
    responses, rejected = replay_poisson(rt, items, rate=20000.0, seed=11)
    assert rejected == 0
    return rt, [r for r in responses if r is not None]


def test_scrape_matches_telemetry_exactly(served_runtime):
    rt, served = served_runtime
    fams = parse_exposition(instrument_runtime(rt).render_prometheus())
    tel = rt.telemetry
    events = fams["repro_serving_events_total"]
    for key, v in tel.counters.items():
        assert events.value(event=key) == v, key
    lat = fams["repro_serving_latency_seconds"]
    assert lat.hist_count() == tel.latency_hist.total
    assert lat.hist_sum() == tel.latency_hist.sum
    for p in (50, 99):
        assert lat.quantile(p) == tel.latency_hist.quantile(p)
    # Per-stage histograms (tracing was on) carry the same discipline.
    stages = fams["repro_serving_stage_seconds"]
    for stage, hist in tel.stage_hists.items():
        assert stages.hist_count(stage=stage) == hist.total
        assert stages.hist_sum(stage=stage) == hist.sum
        assert stages.quantile(99, stage=stage) == hist.quantile(99)
    cache = fams["repro_serving_compile_cache_hits_total"]
    assert cache.value() == rt.cache.hits
    assert fams["repro_serving_trace_budget"].value() == rt.trace_budget
    assert fams["repro_serving_in_flight"].value() == 0
    assert fams["repro_serving_queue_depth"].value() == 0
    assert fams["repro_serving_degradation_level"].value() == 0


def test_scrape_is_pull_time_not_snapshot(served_runtime):
    """Two renders straddling new work must disagree — the registry reads
    live state, it does not cache."""
    rt, _ = served_runtime
    reg = instrument_runtime(rt, namespace="pull")
    before = parse_exposition(reg.render_prometheus())
    rt.submit([0.0] * D, 4, "label", label_words_row([0], L))
    rt.drain()
    after = parse_exposition(reg.render_prometheus())

    def completed(fams):
        return fams["pull_serving_events_total"].value(event="completed")

    assert completed(after) == completed(before) + 1
