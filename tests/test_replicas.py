"""Replica-tier tests (DESIGN.md §13): router properties, epoch-consistent
mutation broadcast, the per-replica lock split behind the HTTP front-end,
and graceful drain with zero in-flight loss.

Kept deliberately small/fast: CI replays this file 20x back-to-back to
flush nondeterministic races in the pump/front-end threading.
"""
import json
import threading
import time
import urllib.request
from collections import Counter

import jax
import numpy as np
import pytest

from repro.data.synthetic import make_labeled_corpus
from repro.graph.index import build_index
from repro.obs import JsonLogger, parse_exposition
from repro.obs.http import ServingFrontend
from repro.serving import (
    AdmissionError,
    ConsistentHashRouter,
    LeastLoadedRouter,
    LocalExecutor,
    ReplicaSet,
    ServingRuntime,
    StreamingLocalExecutor,
    VirtualClock,
    label_words_row,
    make_replica_router,
    make_tier_ladder,
)
from repro.streaming import StreamingIndex

N, D, L = 900, 8, 4


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    corpus = corpus.replace(
        attrs=jax.random.uniform(jax.random.PRNGKey(50), (N, 2))
    )
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=8, sample_size=64)
    return corpus, graph


def _runtime(corpus, graph, *, streaming=False, max_pending=256, **kw):
    tiers = make_tier_ladder(k_cap=4, base_ef=16, base_iters=32, n_tiers=1)
    if streaming:
        index = StreamingIndex.from_static(corpus, graph, ef_insert=16)
        executor = StreamingLocalExecutor(index)
    else:
        executor = LocalExecutor(corpus, graph)
    rt = ServingRuntime(
        executor,
        n_labels=L,
        tiers=tiers,
        ladder=(4,),
        families=("label", "range"),
        max_wait=0.002,
        max_pending=max_pending,
        clock=VirtualClock(),
        **kw,
    )
    rt.warmup()
    return rt


def _tier(corpus, graph, n=2, *, streaming=False, router=None, **kw):
    return ReplicaSet(
        [_runtime(corpus, graph, streaming=streaming, **kw) for _ in range(n)],
        router=router,
    )


def _post(addr, route, payload, timeout=30):
    req = urllib.request.Request(
        addr + route,
        data=json.dumps(payload).encode(),
        headers={"Content-Type": "application/json"},
    )
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _get(addr, route, timeout=30):
    with urllib.request.urlopen(addr + route, timeout=timeout) as r:
        body = r.read().decode()
        try:
            return r.status, json.loads(body)
        except json.JSONDecodeError:
            return r.status, body


# --- routers --------------------------------------------------------------

def test_hash_router_deterministic():
    a = ConsistentHashRouter(4)
    b = ConsistentHashRouter(4)
    keys = list(range(500)) + ["req-%d" % i for i in range(100)]
    assert [a.route(k) for k in keys] == [b.route(k) for k in keys]
    # loads are ignored: same verdicts whatever the gauge says
    assert a.route(7, loads=[100, 0, 0, 0]) == a.route(7)
    # every replica owns a nonempty share of a modest keyspace
    owners = Counter(a.route(k) for k in range(1000))
    assert set(owners) == {0, 1, 2, 3}


def test_hash_router_redistribution_bound():
    before = ConsistentHashRouter(4)
    after = ConsistentHashRouter(5)
    keys = range(2000)
    moved = sum(1 for k in keys if before.route(k) != after.route(k))
    # Ideal move fraction is 1/5; the vnode ring keeps it near that, and
    # categorically below a rehash-everything shuffle (which would move
    # ~4/5 of keys).
    assert moved / 2000 <= 0.35


def test_least_loaded_router():
    r = LeastLoadedRouter(3)
    assert r.route(None, [5, 2, 9]) == 1
    # ties break to the lowest index, deterministically
    assert r.route(None, [4, 4, 4]) == 0
    assert r.route(None, [7, 3, 3]) == 1
    with pytest.raises(ValueError):
        r.route(None, [1, 2])


def test_make_replica_router():
    assert isinstance(make_replica_router("hash", 2), ConsistentHashRouter)
    assert isinstance(
        make_replica_router("least-loaded", 2), LeastLoadedRouter
    )
    with pytest.raises(ValueError):
        make_replica_router("round-robin", 2)


# --- tier submit/poll/drain ----------------------------------------------

def test_tier_submit_poll_drain(world):
    corpus, graph = world
    tier = _tier(corpus, graph, n=2, router=LeastLoadedRouter(2))
    vectors = np.asarray(corpus.vectors)
    handles = []
    for i in range(24):
        handles.append(tier.submit(
            vectors[i], 4, "label", label_words_row([i % L], L)
        ))
    assert tier.in_flight == 24
    assert tier.drain() == 24
    assert tier.in_flight == 0
    by_replica = Counter(i for i, _ in handles)
    # least-loaded must spread the stream across both replicas
    assert set(by_replica) == {0, 1}
    for i, rid in handles:
        resp = tier.poll(i, rid)
        assert resp is not None and resp.error is None
        assert resp.trace is not None and resp.trace["replica"] == i


def test_trace_replica_stamp(world):
    corpus, graph = world
    rt = _runtime(corpus, graph)
    rid = rt.submit(
        np.asarray(corpus.vectors)[0], 4, "label", label_words_row([0], L)
    )
    rt.drain()
    resp = rt.poll(rid)
    # standalone runtimes (replica_id=None) keep the PR 9 trace shape
    assert "replica" not in resp.trace


# --- mutation broadcast ---------------------------------------------------

def test_mutation_broadcast_epoch_consistent(world):
    corpus, graph = world
    tier = _tier(corpus, graph, n=2, streaming=True)
    vec = np.asarray(corpus.vectors)[3] + 0.01

    handles = tier.submit_upsert(vec, label=1)
    assert [i for i, _ in handles] == [0, 1]
    tier.step_all(force=True)
    responses = tier.poll_all(handles)
    assert all(r is not None and r.filled == 1 for r in responses)
    slots = {int(np.asarray(r.ids)[0]) for r in responses}
    assert len(slots) == 1, f"replicas assigned different slots: {slots}"
    assert len({r.epoch for r in responses}) == 1
    assert len(set(tier.epochs())) == 1

    # identical post-mutation state: the same query answers identically
    # on every replica
    slot = slots.pop()
    queries = [
        rt.submit(vec, 4, "label", label_words_row([1], L))
        for rt in tier.replicas
    ]
    tier.drain()
    answers = [
        tuple(np.asarray(rt.poll(rid).ids).tolist())
        for rt, rid in zip(tier.replicas, queries)
    ]
    assert answers[0] == answers[1]
    assert slot in answers[0]  # the new vector is its own nearest neighbor

    # delete broadcast: NO replica may keep serving the dead slot
    handles = tier.submit_delete(slot)
    tier.step_all(force=True)
    responses = tier.poll_all(handles)
    assert all(r is not None and r.filled == 1 for r in responses)
    assert len(set(tier.epochs())) == 1
    queries = [
        rt.submit(vec, 4, "label", label_words_row([1], L))
        for rt in tier.replicas
    ]
    tier.drain()
    answers = [
        tuple(np.asarray(rt.poll(rid).ids).tolist())
        for rt, rid in zip(tier.replicas, queries)
    ]
    assert answers[0] == answers[1]
    assert slot not in answers[0]


def test_broadcast_admission_is_atomic(world):
    corpus, graph = world
    tier = _tier(corpus, graph, n=2, streaming=True, max_pending=4)
    vectors = np.asarray(corpus.vectors)
    # fill replica 1 to its admission bound without stepping
    for i in range(4):
        tier.replicas[1].submit(
            vectors[i], 4, "label", label_words_row([0], L)
        )
    with pytest.raises(AdmissionError):
        tier.submit_upsert(vectors[5], label=0)
    # nothing was enqueued anywhere: replica 0 untouched, replica 1 still
    # holds exactly its queries
    assert tier.replicas[0].in_flight == 0
    assert tier.replicas[1].in_flight == 4
    tier.drain()


# --- HTTP front-end over the tier ----------------------------------------

def test_frontend_tier_http_roundtrip(world):
    corpus, graph = world
    logger = JsonLogger()
    tier = _tier(corpus, graph, n=2, streaming=True)
    fe = ServingFrontend(tier, logger=logger)
    addr = fe.start()
    vectors = np.asarray(corpus.vectors)
    try:
        replicas_seen = set()
        for i in range(12):
            status, body = _post(addr, "/v1/search", {
                "query": vectors[i].tolist(), "k": 4,
                "family": "label", "labels": [i % L],
            })
            assert status == 200 and body["error"] is None
            assert body["replica"] in (0, 1)
            assert body["trace"]["replica"] == body["replica"]
            replicas_seen.add(body["replica"])

        status, body = _post(addr, "/v1/upsert", {
            "vector": (vectors[0] + 0.02).tolist(), "label": 2,
        })
        assert status == 200 and body["ok"] and body["slot_consistent"]
        assert {r["replica"] for r in body["replicas"]} == {0, 1}
        assert len({r["epoch"] for r in body["replicas"]}) == 1
        slot = body["slot"]

        status, body = _post(addr, "/v1/delete", {"slot": slot})
        assert status == 200 and body["ok"] and body["slot_consistent"]

        status, health = _get(addr, "/healthz")
        assert status == 200 and health["status"] == "ok"
        assert [r["replica"] for r in health["replicas"]] == [0, 1]

        status, text = _get(addr, "/metrics")
        assert status == 200
        fams = parse_exposition(text)
        events = fams["repro_serving_events_total"]
        assert set(events.label_values("replica")) >= {"0", "1", "all"}
        # replica-label cumulativity: per-replica counters sum to the
        # rollup, for every event key
        for key in events.label_values("event"):
            total = sum(
                events.value(event=key, replica=str(i)) for i in (0, 1)
            )
            assert events.value(event=key, replica="all") == total
        lat = fams["repro_serving_latency_seconds"]
        per_replica = [
            dict(lat.buckets(replica=str(i))) for i in (0, 1)
        ]
        for edge, cum in lat.buckets(replica="all"):
            assert cum == sum(pr[edge] for pr in per_replica)
        assert fams["repro_tier_replicas"].value() == 2.0
        epochs = fams["repro_streaming_epoch"]
        assert (
            epochs.value(replica="0") == epochs.value(replica="1")
        )
    finally:
        report = fe.close(drain=True)
    assert report["in_flight"] == 0
    assert not any(
        t.is_alive() for t in fe._threads if t.name.startswith("obs-http-pump")
    )
    records = logger.sink.records()
    assert {r.get("replica") for r in records if "replica" in r} >= {0, 1}


def test_healthz_and_metrics_responsive_while_replica_locked(world):
    corpus, graph = world
    tier = _tier(corpus, graph, n=2)
    fe = ServingFrontend(tier)
    addr = fe.start()
    try:
        release = threading.Event()

        def hog():
            with tier.locks[1]:
                release.wait(10.0)

        t = threading.Thread(target=hog, daemon=True)
        t.start()
        time.sleep(0.05)  # let the hog take the lock
        t0 = time.monotonic()
        status, health = _get(addr, "/healthz")
        assert status == 200 and health["status"] == "ok"
        status, text = _get(addr, "/metrics")
        assert status == 200
        parse_exposition(text)  # still a valid exposition
        elapsed = time.monotonic() - t0
        # both surfaces answered from timeout-acquire fallbacks instead of
        # waiting out the 10s the lock is held
        assert elapsed < 5.0
        release.set()
        t.join()
    finally:
        fe.close(drain=True)


def test_frontend_graceful_close_zero_loss(world):
    corpus, graph = world
    tier = _tier(corpus, graph, n=2, streaming=True)
    fe = ServingFrontend(tier)
    addr = fe.start()
    vectors = np.asarray(corpus.vectors)
    statuses = []

    def one(i):
        statuses.append(_post(addr, "/v1/search", {
            "query": vectors[i].tolist(), "k": 4,
            "family": "range", "range": [0.1, 0.9, 0],
        })[0])

    threads = [threading.Thread(target=one, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    report = fe.close(drain=True)
    assert statuses == [200] * 8
    assert report["in_flight"] == 0
    # accounting identity over both replicas: everything submitted either
    # completed or was shed — nothing lost in shutdown
    for rt in tier.replicas:
        c = rt.telemetry.counters
        assert c["submitted"] == c["completed"] + c["shed_total"]
    # a closed frontend refuses new work
    status, _ = fe.handle_search({
        "query": vectors[0].tolist(), "k": 4,
        "family": "label", "labels": [0],
    })
    assert status == 503


def test_single_runtime_frontend_unchanged(world):
    # PR 9 contract: a bare runtime behind the frontend still works, with
    # fe.lock coordinating against the (single) pump thread.
    corpus, graph = world
    rt = _runtime(corpus, graph)
    fe = ServingFrontend(rt)
    addr = fe.start()
    vectors = np.asarray(corpus.vectors)
    try:
        status, body = _post(addr, "/v1/search", {
            "query": vectors[0].tolist(), "k": 4,
            "family": "label", "labels": [1],
        })
        assert status == 200 and body["error"] is None
        assert body["replica"] is None
        with fe.lock:
            assert rt.in_flight == 0
        # mutations against a non-streaming executor are a client error
        status, body = _post(addr, "/v1/upsert", {
            "vector": vectors[0].tolist(),
        })
        assert status == 400
        status, health = _get(addr, "/healthz")
        assert status == 200 and "replicas" not in health
    finally:
        fe.close(drain=True)
