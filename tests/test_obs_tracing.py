"""Per-request span tracing + structured logs (DESIGN.md §12).

Everything runs under a VirtualClock, so stage durations are exact
arithmetic over injected timestamps: the breakdown must tile the
end-to-end latency, survive escalations and sheds, feed the per-stage
telemetry histograms, and correlate with the ring-buffered JSON log
records by req_id/batch_id.
"""
import io
import json

import jax
import numpy as np
import pytest

from repro.data.synthetic import make_labeled_corpus
from repro.graph.index import build_index
from repro.obs import (
    STAGES,
    JsonLogger,
    RequestTrace,
    RingBufferSink,
    stage_sum,
    trace_consistent,
)
from repro.serving import (
    LocalExecutor,
    ServingRuntime,
    VirtualClock,
    label_words_row,
    make_tier_ladder,
)

N, D, L = 1500, 16, 5


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    corpus = corpus.replace(
        attrs=jax.random.uniform(jax.random.PRNGKey(50), (N, 2))
    )
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=12,
                        sample_size=128)
    return corpus, graph


def _runtime(world, **kw):
    corpus, graph = world
    kw.setdefault(
        "tiers", make_tier_ladder(k_cap=8, base_ef=32, base_iters=64,
                                  n_tiers=2)
    )
    kw.setdefault("ladder", (4,))
    kw.setdefault("families", ("label",))
    kw.setdefault("max_wait", 0.0)
    kw.setdefault("clock", VirtualClock())
    return ServingRuntime(LocalExecutor(corpus, graph), n_labels=L, **kw)


# ---------------------------------------------------------------------------
# RequestTrace arithmetic (pure, no runtime)
# ---------------------------------------------------------------------------


def test_trace_stages_tile_latency_exactly():
    tr = RequestTrace(req_id=1, arrival_t=10.0)
    tr.on_flush(enqueue_t=10.0, flush_t=10.5)  # 0.5 queue wait
    tr.on_exec(start_t=10.7, end_t=11.0)  # 0.2 batch wait, 0.3 execute
    bd = tr.breakdown(11.1)
    assert bd["queue_wait"] == pytest.approx(0.5)
    assert bd["batch_wait"] == pytest.approx(0.2)
    assert bd["execute"] == pytest.approx(0.3)
    assert bd["overhead"] == pytest.approx(0.1)
    assert bd["total"] == pytest.approx(1.1)
    assert stage_sum(bd) == pytest.approx(bd["total"])
    assert trace_consistent(bd)
    assert bd["passes"] == 1 and bd["outcome"] == "served"
    assert [e for e, _ in bd["events"]] == [
        "admitted", "flushed", "executed", "served",
    ]


def test_trace_accumulates_across_passes():
    tr = RequestTrace(0, 0.0)
    tr.on_flush(0.0, 1.0)
    tr.on_exec(1.0, 2.0)
    tr.mark("escalate:1", 2.0)
    tr.on_flush(2.0, 3.0)  # re-enqueued: second queue wait
    tr.on_exec(3.5, 4.0)
    bd = tr.breakdown(4.0)
    assert bd["queue_wait"] == pytest.approx(2.0)
    assert bd["batch_wait"] == pytest.approx(0.5)
    assert bd["execute"] == pytest.approx(1.5)
    assert bd["passes"] == 2
    assert trace_consistent(bd)


def test_trace_event_log_is_bounded():
    tr = RequestTrace(0, 0.0)
    for i in range(500):
        tr.mark(f"e{i}", float(i))
    bd = tr.breakdown(500.0)
    assert len(bd["events"]) <= 64
    assert bd["events_truncated"] is True


# ---------------------------------------------------------------------------
# runtime integration: every Response carries a consistent trace
# ---------------------------------------------------------------------------


def test_served_responses_carry_consistent_traces(world):
    rt = _runtime(world)
    rt.warmup()
    ids = [
        rt.submit(np.zeros((D,), np.float32), 4, "label",
                  label_words_row([i % L], L))
        for i in range(12)
    ]
    rt.drain()
    for rid in ids:
        resp = rt.poll(rid)
        assert resp is not None and resp.trace is not None
        assert resp.batch_id >= 0
        assert set(STAGES) <= set(resp.trace)
        # VirtualClock timestamps are exact: stage sum == latency.
        assert stage_sum(resp.trace) == pytest.approx(resp.latency, abs=1e-9)
        assert trace_consistent(resp.trace)
        assert resp.trace["outcome"] == "served"
        assert resp.trace["passes"] >= 1
        assert resp.trace["execute"] > 0.0
    # Stage histograms were fed once per completed response.
    tel = rt.telemetry
    assert set(tel.stage_hists) == set(STAGES)
    assert all(h.total == len(ids) for h in tel.stage_hists.values())
    assert "stages" in tel.summary()


def test_escalated_request_accumulates_both_passes(world):
    corpus, graph = world
    from repro.core.types import SearchParams

    starved = SearchParams(mode="prefer", k=8, ef_result=8, ef_sat=8,
                           ef_other=8, n_start=2, max_iters=4)
    big = SearchParams(mode="prefer", k=8, ef_result=128, ef_sat=128,
                       ef_other=128, n_start=32, max_iters=64)
    rt = ServingRuntime(
        LocalExecutor(corpus, graph), n_labels=L, tiers=(starved, big),
        ladder=(4,), families=("range",), max_wait=0.0, clock=VirtualClock(),
    )
    vectors = np.asarray(corpus.vectors)
    attrs = np.asarray(corpus.attrs)
    ids = []
    for i in range(8):
        center = float(attrs[i, 0])
        ids.append(rt.submit(
            vectors[i], 8, "range", (center - 0.04, center + 0.04, 0)
        ))
    rt.drain()
    responses = [rt.poll(rid) for rid in ids]
    escalated = [r for r in responses if r.escalations > 0]
    assert escalated, "starved tier 0 should have under-filled something"
    for r in escalated:
        assert r.trace["passes"] == r.escalations + 1
        assert trace_consistent(r.trace)
        events = [e for e, _ in r.trace["events"]]
        assert any(e.startswith("escalate:") for e in events)


def test_shed_response_trace_outcome(world):
    rt = _runtime(world, slo=None)
    rt.warmup()
    clock = rt.clock
    rid = rt.submit(np.zeros((D,), np.float32), 4, "label",
                    label_words_row([0], L), deadline=clock() + 0.001)
    clock.advance(1.0)  # deadline long gone before the flush
    rt.drain()
    resp = rt.poll(rid)
    assert resp.shed_reason == "expired"
    assert resp.trace is not None
    assert resp.trace["outcome"] == "shed"
    assert resp.trace["execute"] == 0.0  # shed before any dispatch
    assert trace_consistent(resp.trace)


def test_tracing_off_serves_without_traces(world):
    rt = _runtime(world, tracing=False)
    rt.warmup()
    rid = rt.submit(np.zeros((D,), np.float32), 4, "label",
                    label_words_row([0], L))
    rt.drain()
    resp = rt.poll(rid)
    assert resp is not None and resp.trace is None
    assert resp.batch_id >= 0  # batch ids stamp regardless
    assert not rt.telemetry.stage_hists


# ---------------------------------------------------------------------------
# structured logs
# ---------------------------------------------------------------------------


def test_runtime_emits_correlated_log_records(world):
    logger = JsonLogger()
    rt = _runtime(world, logger=logger)
    rt.warmup()
    rid = rt.submit(np.zeros((D,), np.float32), 4, "label",
                    label_words_row([1], L))
    rt.drain()
    resp = rt.poll(rid)
    records = logger.sink.records()
    events = {r["event"] for r in records}
    assert {"admit", "dispatch", "complete"} <= events
    admit = next(r for r in records if r["event"] == "admit")
    assert admit["req_id"] == rid and "ts" in admit
    complete = next(r for r in records if r["event"] == "complete")
    assert complete["req_id"] == rid
    assert complete["batch_id"] == resp.batch_id
    dispatch = next(r for r in records if r["event"] == "dispatch")
    assert dispatch["batch_id"] == resp.batch_id
    assert dispatch["epoch"] is None  # static executor


def test_ring_buffer_sink_bounds_memory():
    sink = RingBufferSink(capacity=4)
    logger = JsonLogger(sink=sink, clock=lambda: 1.5)
    for i in range(10):
        logger.log("e", i=i)
    assert len(sink) == 4
    assert sink.emitted == 10 and sink.dropped == 6
    assert [r["i"] for r in sink.records()] == [6, 7, 8, 9]
    assert all(r["ts"] == 1.5 for r in sink.records())
    out = io.StringIO()
    assert sink.flush(out) == 4
    assert len(sink) == 0
    lines = [json.loads(x) for x in out.getvalue().splitlines()]
    assert [r["i"] for r in lines] == [6, 7, 8, 9]
    with pytest.raises(ValueError):
        RingBufferSink(capacity=0)


def test_logger_stream_tee():
    stream = io.StringIO()
    logger = JsonLogger(stream=stream)
    logger.log("hello", req_id=3)
    rec = json.loads(stream.getvalue())
    assert rec == {"event": "hello", "req_id": 3}
