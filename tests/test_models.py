"""Model-zoo correctness: decode==teacher-forcing, MACE equivariance,
dst-partitioned == simple, recsys numerics, flash-attention VJP."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.meshinfo import single_device_meshinfo
from repro.models.common.modules import chunked_attention
from repro.models.gnn.distributed import dst_partitioned_loss
from repro.models.gnn.mace import MACEConfig, energy_and_forces, init_params as mace_init
from repro.models.gnn.mace import loss as mace_loss
from repro.models.gnn.sampler import sample_subgraph, subgraph_sizes
from repro.models.recsys import models as rs
from repro.models.transformer.model import (
    TransformerConfig,
    decode_step,
    forward_hidden,
    init_cache,
    init_params,
    lm_loss,
)

MI = single_device_meshinfo()


def _tiny_cfg(attn_type="gqa", **kw):
    base = dict(
        name="t", n_layers=2, d_model=32, n_heads=4,
        n_kv_heads=2 if attn_type == "gqa" else 4, head_dim=8, d_ff=64,
        vocab_size=64, attn_type=attn_type, param_dtype=jnp.float32,
        compute_dtype=jnp.float32, attn_chunk=4, ce_chunk=8, remat="none",
    )
    if attn_type == "mla":
        base.update(q_lora_rank=16, kv_lora_rank=8, d_nope=8, d_rope=4, d_v=8)
    base.update(kw)
    return TransformerConfig(**base)


@pytest.mark.parametrize("attn_type", ["gqa", "mla"])
def test_decode_matches_teacher_forcing(attn_type):
    cfg = _tiny_cfg(attn_type)
    p = init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 64)
    h = forward_hidden(p, cfg, MI, toks)
    ref = (h @ p["lm_head"]["w"]).astype(jnp.float32)
    cache = init_cache(cfg, 2, 8)
    outs = []
    for t in range(8):
        lg, cache = decode_step(p, cfg, MI, cache, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref), atol=2e-3)


def test_moe_lm_trains_and_routes():
    cfg = _tiny_cfg(
        "mla", n_layers=3, n_experts=8, n_shared_experts=1, top_k=2,
        d_ff_expert=16, n_dense_layers=1, mtp=True,
    )
    p = init_params(jax.random.PRNGKey(0), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64)}
    loss, metrics = lm_loss(p, cfg, MI, batch)
    assert np.isfinite(float(loss))
    assert "mtp_ce" in metrics
    g = jax.grad(lambda pp: lm_loss(pp, cfg, MI, batch)[0])(p)
    # experts receive gradient (dispatch is differentiable end-to-end)
    gnorm = float(jnp.linalg.norm(g["moe_layers"]["ffn"]["experts"]["w1"]))
    assert gnorm > 0


def test_flash_attention_grads_match_naive():
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (2, 16, 4, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 2, 8))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 2, 8))

    def naive(q, k, v):
        b, sq, h, dh = q.shape
        hkv = k.shape[2]
        qg = q.reshape(b, sq, hkv, h // hkv, dh)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / jnp.sqrt(dh)
        mask = jnp.tril(jnp.ones((sq, sq), bool))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
        return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh)

    f1 = lambda *a: jnp.sum(jnp.cos(chunked_attention(*a, causal=True, chunk=5)))
    f2 = lambda *a: jnp.sum(jnp.cos(naive(*a)))
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_mace_rotation_translation_invariance():
    import scipy.spatial.transform as sst

    cfg = MACEConfig(n_layers=2, d_hidden=12, n_rbf=4, n_species=4)
    p = mace_init(jax.random.PRNGKey(0), cfg)
    r = np.random.default_rng(0)
    N, E = 18, 50
    batch = dict(
        positions=jnp.asarray(r.normal(size=(N, 3)), jnp.float32),
        senders=jnp.asarray(r.integers(0, N, size=E), jnp.int32),
        receivers=jnp.asarray(r.integers(0, N, size=E), jnp.int32),
        species=jnp.asarray(r.integers(0, 4, size=N), jnp.int32),
    )
    e, f = energy_and_forces(p, cfg, batch)
    R = jnp.asarray(sst.Rotation.random(random_state=1).as_matrix(), jnp.float32)
    batch2 = dict(batch, positions=batch["positions"] @ R.T + 5.0)
    e2, f2 = energy_and_forces(p, cfg, batch2)
    assert abs(float(e) - float(e2)) < 1e-3
    np.testing.assert_allclose(np.asarray(f @ R.T), np.asarray(f2), atol=5e-3)


def test_mace_dst_partitioned_equals_simple():
    cfg = MACEConfig(n_layers=2, d_hidden=8, n_rbf=4, n_species=4)
    p = mace_init(jax.random.PRNGKey(0), cfg)
    r = np.random.default_rng(1)
    N, E = 16, 40
    batch = dict(
        positions=jnp.asarray(r.normal(size=(N, 3)), jnp.float32),
        senders=jnp.asarray(r.integers(0, N, size=E), jnp.int32),
        receivers=jnp.asarray(r.integers(0, N, size=E), jnp.int32),
        species=jnp.asarray(r.integers(0, 4, size=N), jnp.int32),
        energy=jnp.asarray([0.7]),
        forces=jnp.zeros((N, 3)),
    )
    l1, _ = mace_loss(p, cfg, batch)
    l2, _ = dst_partitioned_loss(p, cfg, MI, dict(batch, receivers_local=batch["receivers"]))
    assert abs(float(l1) - float(l2)) < 1e-4


def test_sampler_shapes_and_membership():
    indptr = jnp.asarray([0, 3, 5, 6, 6, 9])
    indices = jnp.asarray([1, 2, 4, 0, 3, 1, 0, 2, 4])
    seeds = jnp.asarray([0, 3])
    sub = sample_subgraph(jax.random.PRNGKey(0), indptr, indices, seeds, (3, 2))
    n, e = subgraph_sizes(2, (3, 2))
    assert sub["nodes"].shape == (n,)
    assert sub["senders"].shape == (e,)
    # receivers reference earlier frontier positions only
    assert bool(jnp.all(sub["receivers"] < sub["senders"]))
    # sampled neighbors of node 0 are real neighbors; node 3 (deg 0) self-loops
    n0 = set(np.asarray(sub["nodes"][2:5]).tolist())
    assert n0 <= {1, 2, 4}
    assert int(sub["nodes"][5]) == 3 or int(sub["nodes"][5]) in {}


def test_two_tower_inbatch_softmax_learns():
    cfg = rs.RecsysConfig(
        name="tt", model="two_tower", embed_dim=8, tower_mlp=(16, 4),
        item_vocab=64, user_vocab=64, hist_len=4,
    )
    p = rs.two_tower_init(jax.random.PRNGKey(0), cfg)
    batch = dict(
        user_id=jnp.arange(8, dtype=jnp.int32),
        hist=jax.random.randint(jax.random.PRNGKey(1), (8, 4), -1, 64),
        item_id=jnp.arange(8, dtype=jnp.int32),
    )
    loss_fn = lambda pp: rs.two_tower_loss(pp, cfg, MI, batch)[0]
    l0 = float(loss_fn(p))
    g = jax.grad(loss_fn)(p)
    # L2-normalized towers at 0.02-scale init have steep curvature — tiny step
    p2 = jax.tree.map(lambda a, b: a - 1e-5 * b, p, g)
    assert float(loss_fn(p2)) < l0


def test_deepfm_fm_term_identity():
    """FM trick 0.5((Σv)²−Σv²) equals the pairwise-dot double sum."""
    cfg = rs.RecsysConfig(name="fm", model="deepfm", embed_dim=4, vocab_sizes=(10,) * 5, mlp=(8,))
    p = rs.deepfm_init(jax.random.PRNGKey(0), cfg)
    ids = jax.random.randint(jax.random.PRNGKey(1), (3, 5), 0, 10)
    emb = jnp.stack([p["tables"][f"t{i}"][ids[:, i]] for i in range(5)], axis=1)
    s = jnp.sum(emb, axis=1)
    fm_trick = 0.5 * jnp.sum(s * s - jnp.sum(emb * emb, axis=1), axis=-1)
    pair = sum(
        jnp.sum(emb[:, i] * emb[:, j], -1) for i in range(5) for j in range(i + 1, 5)
    )
    np.testing.assert_allclose(np.asarray(fm_trick), np.asarray(pair), rtol=1e-5)


def test_dlrm_interaction_count():
    cfg = rs.RecsysConfig(
        name="d", model="dlrm", embed_dim=8, vocab_sizes=(20, 20), n_dense=4,
        bot_mlp=(8, 8), top_mlp=(8, 1),
    )
    p = rs.dlrm_init(jax.random.PRNGKey(0), cfg)
    batch = dict(
        dense=jnp.ones((2, 4)), sparse=jnp.zeros((2, 2), jnp.int32),
        label=jnp.ones((2,)),
    )
    out = rs.dlrm_forward(p, cfg, MI, batch)
    assert out.shape == (2,)
    # top MLP input dim = 3 fields choose 2 = 3 interactions + bot output 8
    assert p["top"]["layers"][0]["w"].shape[0] == 3 + 8
