"""Sharded-path spec regressions (PR3) that run on a single host device.

A 1x1 mesh exercises the full shard_map spec machinery — pytree structure
matching between args and in_specs is validated at trace time regardless of
device count — so these catch the historical failure modes cheaply:
``shard_corpus_for_mesh`` silently dropping ``corpus.attrs`` and
``make_distributed_search`` hard-coding the LabelSet constraint spec (both
of which made Range constraints impossible to run distributed). Real
multi-shard semantics live in test_distributed_multidev.py (slow).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.compat import set_mesh
from repro.core import (
    RangeConstraint,
    SearchParams,
    constrained_search,
    equal_constraint,
    exact_constrained_search,
    make_distributed_search,
    pq_train,
    recall,
    shard_corpus_for_mesh,
)
from repro.data.synthetic import make_labeled_corpus, make_queries
from repro.graph.index import build_partitioned_index


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=1500, d=16, n_labels=5)
    attrs = jax.random.uniform(jax.random.PRNGKey(50), (1500, 2))
    corpus = corpus.replace(attrs=attrs)
    corpus_p, graph_p = build_partitioned_index(
        jax.random.PRNGKey(1), corpus, n_shards=1, degree=12,
        sample_size_per_shard=64,
    )
    queries, qlab = make_queries(jax.random.PRNGKey(2), corpus, 8)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return corpus_p, graph_p, queries, qlab, mesh


PARAMS = SearchParams(
    mode="prefer", k=10, ef_result=64, ef_sat=64, ef_other=64,
    n_start=8, max_iters=300,
)


def test_partitioned_index_and_sharding_preserve_attrs(world):
    corpus_p, graph_p, _, _, mesh = world
    assert corpus_p.attrs is not None  # build_partitioned_index carries attrs
    corpus_s, _ = shard_corpus_for_mesh(corpus_p, graph_p, mesh)
    assert corpus_s.attrs is not None  # shard_corpus_for_mesh keeps them
    np.testing.assert_array_equal(
        np.asarray(corpus_s.attrs), np.asarray(corpus_p.attrs)
    )


def test_range_constraint_through_sharded_path(world):
    corpus_p, graph_p, queries, _, mesh = world
    corpus_s, graph_s = shard_corpus_for_mesh(corpus_p, graph_p, mesh)
    b = queries.shape[0]
    cons = RangeConstraint(
        lo=jnp.full((b,), 0.3), hi=jnp.full((b,), 0.9), col=jnp.int32(0)
    )
    search = make_distributed_search(mesh, PARAMS, constraint_type=RangeConstraint)
    with set_mesh(mesh):
        res = search(corpus_s, graph_s, queries, cons)
    ids = np.asarray(res.ids)
    vals = np.asarray(corpus_p.attrs)[np.maximum(ids, 0), 0]
    assert np.all(((vals >= 0.3) & (vals <= 0.9)) | (ids < 0))
    # one shard == the local search: full recall against the exact oracle
    _, ti = exact_constrained_search(corpus_p, queries, cons, k=10)
    assert float(recall(res.ids, ti)) == 1.0


def test_unknown_constraint_type_rejected(world):
    *_, mesh = world
    with pytest.raises(TypeError, match="constraint type"):
        make_distributed_search(mesh, PARAMS, constraint_type=dict)


def test_pq_backend_payload_derived_from_params(world):
    """params.approx — not a separate with_pq flag — decides the backend
    payload specs; fused ADC stays bit-identical through the sharded path."""
    corpus_p, graph_p, queries, qlab, mesh = world
    corpus_s, graph_s = shard_corpus_for_mesh(corpus_p, graph_p, mesh)
    cons = equal_constraint(qlab, 5)
    pq = pq_train(jax.random.PRNGKey(11), corpus_p.vectors, m_sub=4, n_cent=16)
    params_pq = dataclasses.replace(PARAMS, approx="pq")
    with set_mesh(mesh):
        res = make_distributed_search(mesh, params_pq)(
            corpus_s, graph_s, queries, cons, pq
        )
        res_f = make_distributed_search(
            mesh, dataclasses.replace(params_pq, fuse_expand="on")
        )(corpus_s, graph_s, queries, cons, pq)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(res_f.ids))
    np.testing.assert_array_equal(np.asarray(res.dists), np.asarray(res_f.dists))
    # the single-shard distributed result equals the plain local search
    local = constrained_search(
        corpus_p, graph_p, queries, cons, params_pq, pq_index=pq
    )
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(local.ids))


def test_uniform_pq_index_signature(world):
    """The distributed search takes pq_index uniformly (None for exact) so
    callers never branch per backend; mismatched payloads fail loudly."""
    corpus_p, graph_p, queries, qlab, mesh = world
    corpus_s, graph_s = shard_corpus_for_mesh(corpus_p, graph_p, mesh)
    cons = equal_constraint(qlab, 5)
    search = make_distributed_search(mesh, PARAMS)
    with set_mesh(mesh):
        res4 = search(corpus_s, graph_s, queries, cons)
        res5 = search(corpus_s, graph_s, queries, cons, None)  # uniform call
    np.testing.assert_array_equal(np.asarray(res4.ids), np.asarray(res5.ids))
    pq = pq_train(jax.random.PRNGKey(11), corpus_p.vectors, m_sub=4, n_cent=16)
    with pytest.raises(ValueError, match="approx"):
        search(corpus_s, graph_s, queries, cons, pq)  # payload w/o approx=pq
    import dataclasses

    search_pq = make_distributed_search(
        mesh, dataclasses.replace(PARAMS, approx="pq")
    )
    with pytest.raises(ValueError, match="requires"):
        search_pq(corpus_s, graph_s, queries, cons)  # approx=pq w/o payload
