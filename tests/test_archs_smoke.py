"""Per-arch reduced-config smoke tests: every assigned architecture family,
every input-shape kind, one real step on CPU, asserting shapes + no NaNs.

These exercise exactly the code paths the full-size dry-run lowers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.archs.base import get_arch
from repro.distributed.meshinfo import single_device_meshinfo

MI = single_device_meshinfo()

SMOKE_ARCHS = [
    "smoke-gqa",
    "smoke-mla-moe",
    "smoke-mace",
    "smoke-dlrm",
    "smoke-deepfm",
    "smoke-sasrec",
    "smoke-two-tower",
    "smoke-airship",
]


def _concrete(cell):
    """Materialize abstract args. Optimizer-state floats must start at their
    real init values (zeros), not random — random negatives would NaN the
    sqrt in Adam; params/batches get small random values."""

    def fill(path, x):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if jnp.issubdtype(x.dtype, jnp.integer):
            if "token" in key or "sparse" in key or "seq" in key or "id" in key:
                return jnp.ones(x.shape, x.dtype)
            return jnp.zeros(x.shape, x.dtype)
        if x.dtype == jnp.uint32:
            return jnp.ones(x.shape, x.dtype)
        if key.startswith("1/"):  # opt state arg
            return jnp.zeros(x.shape, x.dtype)
        return (
            jax.random.normal(jax.random.PRNGKey(hash(key) % 2**31), x.shape) * 0.05
        ).astype(x.dtype)

    return jax.tree_util.tree_map_with_path(fill, cell.args)


@pytest.mark.parametrize("arch_name", SMOKE_ARCHS)
def test_all_cells_run_and_finite(arch_name):
    arch = get_arch(arch_name)
    for shape in arch.shape_names():
        cell = arch.make_cell(shape, MI)
        args = _concrete(cell)
        out = jax.jit(cell.fn)(*args)
        for leaf in jax.tree.leaves(out):
            if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
                assert not bool(jnp.any(jnp.isnan(leaf))), f"{cell.name} produced NaN"


def test_train_cells_change_params():
    arch = get_arch("smoke-gqa")
    cell = arch.make_cell("train_4k", MI)
    args = _concrete(cell)
    params, opt_state, metrics = jax.jit(cell.fn)(*args)
    delta = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(args[0]))
    )
    assert delta > 0
    assert np.isfinite(float(metrics["loss"]))


def test_assigned_archs_have_all_shapes():
    from repro.configs import ASSIGNED

    total = 0
    for name in ASSIGNED:
        arch = get_arch(name)
        total += len(arch.shape_names())
        assert len(arch.shape_names()) == 4
    assert total == 40  # the assignment's 40 cells


def test_param_counts_match_published_sizes():
    """236B / 671B / 104B / 35B / ~2.5B within tolerance."""
    expect = {
        "deepseek-v2-236b": 236e9,
        "deepseek-v3-671b": 671e9,
        "command-r-plus-104b": 104e9,
        "command-r-35b": 35e9,
        "granite-3-2b": 2.5e9,
    }
    for name, target in expect.items():
        cfg = get_arch(name).cfg
        n = cfg.param_count()
        assert abs(n - target) / target < 0.15, (name, n)
