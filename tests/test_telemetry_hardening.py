"""Telemetry hardening (PR 9 satellites): the goodput-rate window fix and
LatencyHistogram boundary discipline.

The histogram checks are property-style sweeps without a property-testing
dependency: exact bucket edges, one-ulp neighbours of every boundary, and
a seeded log-uniform sample — the inputs a float-rounding regression in
``_bucket_of`` would actually surface on.
"""
import math

import numpy as np
import pytest

from repro.serving import LatencyHistogram, Response, Telemetry


def _resp(i, *, filled=1, arrival=0.0, complete=1.0, k=4):
    return Response(
        req_id=i,
        ids=np.full((k,), -1 if filled == 0 else 0, np.int32),
        dists=np.zeros((k,), np.float32),
        k=k,
        filled=filled,
        tier=0,
        escalations=0,
        fill_history=(filled,),
        arrival_t=arrival,
        complete_t=complete,
    )


# ---------------------------------------------------------------------------
# goodput window regression
# ---------------------------------------------------------------------------


def test_goodput_rate_numerator_is_window_scoped():
    """Regression: the lifetime ``goodput`` counter over the *window's*
    span inflated the rate once ``max_history`` evicted old responses.

    12 goodput responses scroll out of an 8-deep window, leaving 8
    zero-fill (non-goodput) ones: the lifetime counter says 12, but the
    rate over the surviving window must be 0."""
    tel = Telemetry(max_history=8)
    for i in range(12):
        tel.on_complete(_resp(i, filled=1, arrival=i, complete=i + 0.5))
    for i in range(12, 20):
        tel.on_complete(_resp(i, filled=0, arrival=i, complete=i + 0.5))
    assert tel.counters["goodput"] == 12  # lifetime aggregate: unchanged
    assert len(tel.responses) == 8  # deque overflowed as intended
    assert tel.goodput_in_window() == 0
    assert tel.goodput_rate() == 0.0
    assert tel.goodput_rate(window_s=10.0) == 0.0


def test_goodput_rate_mixed_window():
    tel = Telemetry(max_history=4)
    # 6 responses, alternating goodput; window keeps the last 4 (2 good).
    for i in range(6):
        tel.on_complete(
            _resp(i, filled=i % 2, arrival=float(i), complete=float(i) + 0.5)
        )
    assert tel.goodput_in_window() == 2
    # Window span: arrivals 2..5, completions 2.5..5.5 -> 3.5s.
    assert tel.goodput_rate() == pytest.approx(2 / 3.5)
    assert tel.goodput_rate(window_s=2.0) == pytest.approx(1.0)
    assert tel.goodput_rate(window_s=0.0) == 0.0


def test_goodput_excludes_missed_and_shed():
    tel = Telemetry()
    met = _resp(0, filled=2)
    tel.on_complete(met)
    missed = _resp(1, filled=2)
    missed.deadline_missed = True
    tel.on_complete(missed)
    shed = _resp(2, filled=0)
    shed.shed_reason = "expired"
    tel.on_shed(shed)
    assert tel.counters["goodput"] == 1
    assert tel.goodput_in_window() == 1  # sheds never enter the window


# ---------------------------------------------------------------------------
# LatencyHistogram boundary discipline
# ---------------------------------------------------------------------------


def test_bucket_of_exact_edges_stay_in_range():
    """Every exact bucket edge, and both one-ulp neighbours of each, must
    land in a *valid interior* bucket — never the under/overflow buckets —
    for any in-range input."""
    h = LatencyHistogram()
    for b in range(1, h.n_buckets):  # edges strictly inside (lo, hi)
        edge = h.upper_edge(b)
        for x in (math.nextafter(edge, 0.0), edge, math.nextafter(edge, 2 * h.hi)):
            assert h.lo <= x < h.hi  # sanity: still an in-range latency
            got = h._bucket_of(x)
            assert 1 <= got <= h.n_buckets, (b, x, got)


def test_bucket_of_lo_hi_boundaries():
    h = LatencyHistogram()
    assert h._bucket_of(0.0) == 0
    assert h._bucket_of(math.nextafter(h.lo, 0.0)) == 0
    assert h._bucket_of(h.lo) == 1  # lo itself is in-range (clamped vs log dust)
    # One ulp under hi is in-range: must NOT spill into the overflow bucket
    # (log() of it can land exactly on n_buckets without the clamp).
    assert h._bucket_of(math.nextafter(h.hi, 0.0)) == h.n_buckets
    assert h._bucket_of(h.hi) == h.n_buckets + 1
    assert h._bucket_of(float("inf")) == h.n_buckets + 1


def test_bucket_of_monotone_and_consistent_with_edges():
    """Log-uniform sample sweep: bucket index is monotone in the value,
    and each value is <= the upper edge of its own bucket (the invariant
    the quantile rule's conservatism rests on)."""
    h = LatencyHistogram()
    rng = np.random.default_rng(9)
    xs = np.sort(
        np.exp(rng.uniform(math.log(h.lo / 10), math.log(h.hi * 10), 4096))
    )
    buckets = [h._bucket_of(float(x)) for x in xs]
    assert all(b0 <= b1 for b0, b1 in zip(buckets, buckets[1:]))
    for x, b in zip(xs, buckets):
        assert 0 <= b <= h.n_buckets + 1
        assert float(x) <= h.upper_edge(b) or b == h.n_buckets + 1


def test_quantile_monotone_and_conservative():
    h = LatencyHistogram()
    rng = np.random.default_rng(10)
    xs = np.exp(rng.uniform(math.log(2e-6), math.log(30.0), 2000))
    for x in xs:
        h.record(float(x))
    qs = [h.quantile(p) for p in (0, 1, 10, 25, 50, 75, 90, 99, 100)]
    assert all(a <= b for a, b in zip(qs, qs[1:]))
    # Upper-edge rule: every quantile dominates the true order statistic.
    xs_sorted = np.sort(xs)
    for p in (1, 50, 99):
        rank = min(max(math.ceil(len(xs) * p / 100.0), 1), len(xs))
        assert h.quantile(p) >= float(xs_sorted[rank - 1])
    assert h.quantile(100) >= float(xs_sorted[-1])


def test_quantile_empty_and_single():
    h = LatencyHistogram()
    assert math.isnan(h.quantile(50))
    h.record(0.01)
    assert h.quantile(0) == h.quantile(100)
    assert h.quantile(50) >= 0.01  # its own bucket's upper edge
    assert h.summary()["count"] == 1


def test_overflow_and_underflow_recorded():
    h = LatencyHistogram()
    h.record(0.0)  # underflow
    h.record(100.0)  # overflow
    assert h.counts[0] == 1 and h.counts[-1] == 1
    assert h.total == 2
    assert h.quantile(100) == float("inf")
    assert h.summary()["overflow"] == 1
