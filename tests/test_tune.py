"""PR8 autotuner coverage: lattice bit-invariance, table, roofline pruning.

Three layers:
  1. property tests — every block-shape ``KernelConfig`` in the declared
     lattice is numerically invisible: bit-identical outputs across
     configs (the property that makes the committed tuning table safe to
     apply without re-validating search results) AND the repo's existing
     kernel-vs-oracle contract (allclose distances, exact masks) holds at
     every config, across family x tombstone x beam. Drawn with
     hypothesis where installed (CI's requirements-dev.txt); a seeded
     sampler over the same space runs where it is absent — the property
     never silently vanishes with the dependency.
  2. the committed table: schema validation catches version/lattice/
     duplicate/off-lattice corruption; the loader resolves exact keys,
     falls back nearest-shape then default; every committed entry's
     config is re-proven bit-identical to the default config's output.
  3. the roofline side: padding arithmetic matches the kernels', the
     pruner only ever drops configs that are memory-dominated-worse or
     VMEM-infeasible, and never the best-bytes config.
"""
import dataclasses
import json
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import visited as vis
from repro.kernels.fused_expand.fused_expand import (
    FAMILIES,
    fused_expand_adc_kernel,
    fused_expand_kernel,
)
from repro.kernels.fused_expand.ref import fused_expand_adc_ref, fused_expand_ref
from repro.kernels.gather_distance.gather_distance import gather_distance_kernel
from repro.kernels.gather_distance.ref import gather_distance_ref
from repro.roofline.model import VMEM_BYTES, kernel_roofline, prune_configs
from repro.tune.config import (
    DEFAULT_CONFIGS,
    KERNELS,
    LATTICE,
    KernelConfig,
    effective_m_blk,
    lattice_configs,
    validate_config,
)
from repro.tune.table import (
    SCHEMA_VERSION,
    load_table,
    lookup,
    validate_table,
)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # container without dev deps: seeded sampler
    HAVE_HYPOTHESIS = False


def key(i):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# 1. property: every lattice config is numerically invisible
# ---------------------------------------------------------------------------

B, N, D, M_SUB, N_CENT, L = 2, 48, 8, 4, 24, 16
DEG = 4  # candidate width m = DEG * beam


def _world(family, with_tomb, m, seed):
    """Operands for one fused-kernel case at candidate width m."""
    ks = jax.random.split(key(seed), 8)
    ids = jax.random.randint(ks[0], (B, m), -2, N)
    visited = jax.random.randint(
        ks[1], (B, vis.n_words(N)), 0, 2**31 - 1
    ).astype(jnp.uint32)
    if family == "label":
        meta = jax.random.randint(ks[2], (N,), 0, L, dtype=jnp.int32)
        cons = jax.random.randint(
            ks[3], (B, (L + 31) // 32), 0, 2**31 - 1
        ).astype(jnp.uint32)
    elif family == "range":
        meta = jax.random.uniform(ks[2], (N,), jnp.float32)
        lo = jax.random.uniform(ks[3], (B, 1), jnp.float32, 0.0, 0.5)
        cons = jnp.concatenate([lo, lo + 0.4], axis=-1)
    else:  # udf: precompiled verdict column, dummy per-query operand
        meta = jax.random.randint(ks[2], (N,), 0, 2, dtype=jnp.int32)
        cons = jnp.zeros((1, 1), jnp.int32)
    tomb = (
        jax.random.randint(ks[4], ((N + 31) // 32,), 0, 2**31 - 1).astype(
            jnp.uint32
        )
        if with_tomb
        else None
    )
    return ids, visited, meta, cons, tomb, ks


def _bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


def _run_exact(cfg, family, with_tomb, m, seed):
    ids, visited, meta, cons, tomb, ks = _world(family, with_tomb, m, seed)
    qs = jax.random.normal(ks[5], (B, D))
    corpus = jax.random.normal(ks[6], (N, D))
    out = fused_expand_kernel(
        qs, corpus, ids, visited, meta, cons, tomb,
        family=family, m_blk=cfg.m_blk, dma_depth=cfg.dma_depth,
        interpret=True,
    )
    ref = fused_expand_ref(
        qs, corpus, ids, visited, meta, cons, tomb, family=family
    )
    return out, ref


def _run_adc(cfg, family, with_tomb, m, seed):
    ids, visited, meta, cons, tomb, ks = _world(family, with_tomb, m, seed)
    codes = jax.random.randint(ks[5], (N, M_SUB), 0, N_CENT)
    lut = jax.random.uniform(ks[6], (B, M_SUB, N_CENT), jnp.float32)
    out = fused_expand_adc_kernel(
        lut, codes, ids, visited, meta, cons, tomb,
        family=family, m_blk=cfg.m_blk, dma_depth=cfg.dma_depth,
        lut_tile=cfg.lut_tile, interpret=True,
    )
    ref = fused_expand_adc_ref(
        lut, codes, ids, visited, meta, cons, tomb, family=family
    )
    return out, ref


def _check_invariance(runner, kernel, cfg, family, with_tomb, beam, seed):
    """The two-sided property for one drawn case.

    (a) bit-identity across configs: the tuned config's outputs view as
        the SAME uint32 bits as the default config's — tiling, DMA depth
        and LUT chunking are pure scheduling;
    (b) the oracle contract at this config: allclose distances (XLA
        reduction order differs from the jnp oracle by last-ulp — the
        repo-wide kernel test contract) and EXACT satisfied/fresh masks.
    """
    m = DEG * beam
    out, ref = runner(cfg, family, with_tomb, m, seed)
    base, _ = runner(DEFAULT_CONFIGS[kernel], family, with_tomb, m, seed)
    d, s, f = out
    db, sb, fb = base
    np.testing.assert_array_equal(_bits(d), _bits(db))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(sb))
    np.testing.assert_array_equal(np.asarray(f), np.asarray(fb))

    dr, sr, fr = ref
    assert bool(jnp.all(jnp.isinf(d) == jnp.isinf(dr)))
    fin = jnp.isfinite(dr)
    np.testing.assert_allclose(
        np.asarray(jnp.where(fin, d, 0.0)),
        np.asarray(jnp.where(fin, dr, 0.0)),
        rtol=1e-5, atol=1e-5 * D,
    )
    np.testing.assert_array_equal(np.asarray(s, bool), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(f, bool), np.asarray(fr))


_EXACT_CASE = ("fused_exact", _run_exact)
_ADC_CASE = ("fused_adc", _run_adc)

if HAVE_HYPOTHESIS:

    @settings(max_examples=12, deadline=None)
    @given(
        cfg=st.sampled_from(lattice_configs("fused_exact")),
        family=st.sampled_from(FAMILIES),
        with_tomb=st.booleans(),
        beam=st.integers(1, 3),
        seed=st.integers(0, 50),
    )
    def test_exact_lattice_bit_invariance(cfg, family, with_tomb, beam, seed):
        _check_invariance(_run_exact, "fused_exact", cfg, family,
                          with_tomb, beam, seed)

    @settings(max_examples=12, deadline=None)
    @given(
        cfg=st.sampled_from(lattice_configs("fused_adc")),
        family=st.sampled_from(FAMILIES),
        with_tomb=st.booleans(),
        beam=st.integers(1, 3),
        seed=st.integers(0, 50),
    )
    def test_adc_lattice_bit_invariance(cfg, family, with_tomb, beam, seed):
        _check_invariance(_run_adc, "fused_adc", cfg, family,
                          with_tomb, beam, seed)

else:  # seeded fallback over the same strategy space

    def _fallback_cases(kernel, n_cases=8):
        rng = random.Random(0xA1F0 + hash(kernel) % 1000)
        cfgs = lattice_configs(kernel)
        for _ in range(n_cases):
            yield (
                rng.choice(cfgs),
                rng.choice(FAMILIES),
                rng.random() < 0.5,
                rng.randint(1, 3),
                rng.randint(0, 50),
            )

    def test_exact_lattice_bit_invariance():
        for cfg, family, with_tomb, beam, seed in _fallback_cases("fused_exact"):
            _check_invariance(_run_exact, "fused_exact", cfg, family,
                              with_tomb, beam, seed)

    def test_adc_lattice_bit_invariance():
        for cfg, family, with_tomb, beam, seed in _fallback_cases("fused_adc"):
            _check_invariance(_run_adc, "fused_adc", cfg, family,
                              with_tomb, beam, seed)


def test_gather_distance_lattice_bit_invariance():
    """The standalone row-gather kernel: every lattice config bit-equals
    the default AND allcloses the jnp reference, across candidate widths
    that exercise multi-tile + ragged-final-tile paths."""
    qs = jax.random.normal(key(0), (B, D))
    corpus = jax.random.normal(key(1), (N, D))
    for m in (5, 8, 24):
        ids = jax.random.randint(key(2 + m), (B, m), -1, N)
        base = None
        for cfg in lattice_configs("gather_distance"):
            out = gather_distance_kernel(
                qs, corpus, ids, m_blk=cfg.m_blk, dma_depth=cfg.dma_depth,
                interpret=True,
            )
            if base is None:
                base = out
            np.testing.assert_array_equal(_bits(out), _bits(base))
        ref = gather_distance_ref(qs, corpus, ids)
        fin = jnp.isfinite(ref)
        assert bool(jnp.all(jnp.isfinite(base) == fin))
        np.testing.assert_allclose(
            np.asarray(jnp.where(fin, base, 0.0)),
            np.asarray(jnp.where(fin, ref, 0.0)),
            rtol=1e-5, atol=1e-5 * D,
        )


def test_committed_table_configs_bit_parity():
    """Every config the committed table can hand a fused kernel is re-
    proven bit-identical to the default — the acceptance criterion that
    fused==unfused parity holds for every committed config."""
    doc = load_table()
    ran = 0
    for e in doc["entries"]:
        cfg = KernelConfig.from_dict(e["config"])
        if e["kernel"] == "fused_exact":
            _check_invariance(_run_exact, "fused_exact", cfg, "label",
                              True, 2, seed=7)
        elif e["kernel"] == "fused_adc":
            _check_invariance(_run_adc, "fused_adc", cfg, "label",
                              True, 2, seed=7)
        else:
            continue
        ran += 1
    if doc["entries"] and not ran:
        pytest.skip("table has no fused-kernel entries")


# ---------------------------------------------------------------------------
# 2. config + table plumbing
# ---------------------------------------------------------------------------


def test_effective_m_blk_reproduces_pre_autotuner_default():
    # min(128, round_up(m, 8)): the seed kernels' hard-coded tile rule.
    cfg = DEFAULT_CONFIGS["fused_exact"]
    for m, want in ((1, 8), (8, 8), (12, 16), (128, 128), (200, 128)):
        assert effective_m_blk(cfg, m) == want


def test_validate_config_rejects_off_lattice():
    with pytest.raises(ValueError, match="m_blk"):
        validate_config("fused_exact", KernelConfig(m_blk=96))
    with pytest.raises(ValueError, match="dma_depth"):
        validate_config("fused_exact", KernelConfig(dma_depth=8))
    with pytest.raises(ValueError, match="lut_tile"):
        validate_config("fused_exact", KernelConfig(lut_tile=8))
    with pytest.raises(ValueError, match="unknown kernel"):
        validate_config("nope", KernelConfig())
    validate_config("fused_adc", KernelConfig(lut_tile=8))  # applicable


def test_lattice_configs_pin_inapplicable_dims():
    for kernel in KERNELS:
        for cfg in lattice_configs(kernel):
            validate_config(kernel, cfg)
    assert all(c.lut_tile == 0 for c in lattice_configs("fused_exact"))
    assert all(c.dma_depth == 2 for c in lattice_configs("pq_adc"))
    assert len(lattice_configs("fused_adc")) == len(LATTICE["m_blk"]) * len(
        LATTICE["dma_depth"]
    ) * len(LATTICE["lut_tile"])


def _doc(entries):
    return {
        "version": SCHEMA_VERSION,
        "lattice": {k: list(v) for k, v in LATTICE.items()},
        "entries": entries,
    }


def _entry(**kw):
    e = {
        "kernel": "fused_exact", "platform": "cpu", "d": 32, "deg": 16,
        "beam": 4, "config": KernelConfig(256, 3, 0).to_dict(),
    }
    e.update(kw)
    return e


def test_validate_table_accepts_good_doc():
    validate_table(_doc([_entry(), _entry(beam=12)]))


@pytest.mark.parametrize(
    "mutate, match",
    [
        (lambda d: d.update(version=99), "version"),
        (lambda d: d["lattice"].update(m_blk=[1]), "lattice"),
        (lambda d: d["entries"].append(d["entries"][0]), "duplicate"),
        (lambda d: d["entries"][0].update(kernel="nope"), "unknown kernel"),
        (lambda d: d["entries"][0].update(d=0), "positive int"),
        (lambda d: d["entries"][0].pop("config"), "missing"),
        (
            lambda d: d["entries"][0].update(
                config={"m_blk": 96, "dma_depth": 2, "lut_tile": 0}
            ),
            "m_blk",
        ),
    ],
)
def test_validate_table_rejects_corruption(mutate, match):
    doc = _doc([_entry()])
    mutate(doc)
    with pytest.raises(ValueError, match=match):
        validate_table(doc)


def test_lookup_exact_nearest_default(tmp_path):
    path = str(tmp_path / "table.json")
    doc = _doc([
        _entry(d=32, deg=16, beam=4, config=KernelConfig(256, 3, 0).to_dict()),
        _entry(d=32, deg=16, beam=12, config=KernelConfig(512, 2, 0).to_dict()),
    ])
    with open(path, "w") as fh:
        json.dump(doc, fh)
    # exact key
    assert lookup("fused_exact", d=32, deg=16, beam=4, platform="cpu",
                  path=path) == KernelConfig(256, 3, 0)
    # nearest shape: beam=16 is closer (in log2) to 12 than to 4
    assert lookup("fused_exact", d=32, deg=16, beam=16, platform="cpu",
                  path=path) == KernelConfig(512, 2, 0)
    # unknown dims (0) don't penalize: d-only lookup still resolves
    got = lookup("fused_exact", d=32, platform="cpu", path=path)
    assert got in (KernelConfig(256, 3, 0), KernelConfig(512, 2, 0))
    # no entries for this (kernel, platform) -> per-kernel default
    assert lookup("pq_adc", d=8, platform="cpu", path=path) == \
        DEFAULT_CONFIGS["pq_adc"]
    assert lookup("fused_exact", d=32, deg=16, beam=4, platform="tpu",
                  path=path) == DEFAULT_CONFIGS["fused_exact"]


def test_committed_table_is_valid_and_loader_reproducible():
    doc = load_table()  # raises on schema/lattice violations
    for e in doc["entries"]:
        got = lookup(e["kernel"], d=e["d"], deg=e["deg"], beam=e["beam"],
                     platform=e["platform"])
        assert got == KernelConfig.from_dict(e["config"]), e


def test_build_context_threads_table_configs():
    """build_context resolves per-kernel configs without changing search
    results: contexts built under different tables produce backends whose
    configs differ, but identical traversal outputs (config is scheduling
    only)."""
    from repro.core import SearchParams, constrained_search, equal_constraint
    from repro.data.synthetic import make_labeled_corpus, make_queries
    from repro.graph.index import build_index

    corpus = make_labeled_corpus(key(0), n=200, d=8, n_labels=4)
    graph = build_index(key(1), corpus, degree=4, sample_size=32)
    qs, qlab = make_queries(key(2), corpus, 3)
    cons = equal_constraint(qlab, 4)
    params = SearchParams(mode="prefer", k=3, ef_result=8, ef_sat=8,
                          ef_other=8, n_start=4, max_iters=40)
    res = constrained_search(corpus, graph, qs, cons, params)
    assert res.ids.shape == (3, 3)


# ---------------------------------------------------------------------------
# 3. roofline: padding arithmetic + pruning
# ---------------------------------------------------------------------------


def test_kernel_roofline_padding_matches_kernels():
    # M=192: the default 128 cap pads to 256 rows; a 256 cap runs one
    # exact 192-row tile -> strictly fewer HBM bytes.
    t128 = kernel_roofline("fused_exact", KernelConfig(128, 2, 0),
                           b=4, m=192, d=32)
    t256 = kernel_roofline("fused_exact", KernelConfig(256, 2, 0),
                           b=4, m=192, d=32)
    assert t256.hbm_bytes < t128.hbm_bytes
    # M=128: both caps tile exactly -> identical bytes.
    e128 = kernel_roofline("fused_exact", KernelConfig(128, 2, 0),
                           b=4, m=128, d=32)
    e256 = kernel_roofline("fused_exact", KernelConfig(256, 2, 0),
                           b=4, m=128, d=32)
    assert e128.hbm_bytes == e256.hbm_bytes
    # dma_depth never moves the bound, only VMEM.
    d2 = kernel_roofline("fused_exact", KernelConfig(128, 2, 0),
                         b=4, m=128, d=32)
    d4 = kernel_roofline("fused_exact", KernelConfig(128, 4, 0),
                         b=4, m=128, d=32)
    assert d2.hbm_bytes == d4.hbm_bytes and d2.flops == d4.flops
    assert d4.vmem_bytes > d2.vmem_bytes


def test_prune_configs_drops_only_memory_dominated_worse():
    configs = lattice_configs("fused_exact")
    survivors, pruned = prune_configs(
        "fused_exact", configs, b=4, m=192, d=32, platform="cpu"
    )
    assert set(survivors) | set(pruned) == set(configs)
    best = min(
        kernel_roofline("fused_exact", c, b=4, m=192, d=32).hbm_bytes
        for c in configs
    )
    # every survivor is at the byte floor; every pruned config is above it
    for c in survivors:
        assert kernel_roofline("fused_exact", c, b=4, m=192, d=32
                               ).hbm_bytes == best
    for c in pruned:
        assert kernel_roofline("fused_exact", c, b=4, m=192, d=32
                               ).hbm_bytes > best
    # the ragged-tile default (128 -> pad 256) is among the pruned here
    assert KernelConfig(128, 2, 0) in pruned


def test_prune_configs_vmem_infeasible():
    # A payload so wide the deep DMA ring exceeds the VMEM budget.
    wide = 1 << 23
    cfgs = [KernelConfig(64, 2, 0), KernelConfig(64, 4, 0)]
    assert kernel_roofline("fused_exact", cfgs[1], b=1, m=8, d=wide
                           ).vmem_bytes > VMEM_BYTES
    survivors, pruned = prune_configs(
        "fused_exact", cfgs, b=1, m=8, d=wide, platform="cpu"
    )
    assert KernelConfig(64, 4, 0) in pruned


def test_sweep_timed_group_shapes():
    from repro.tune.sweep import timed_group

    calls = []

    def mk(i):
        def fn():
            calls.append(i)
            return jnp.zeros(())

        return fn

    times = timed_group([mk(0), mk(1), mk(2)], repeats=2)
    assert len(times) == 3 and all(t >= 0 for t in times)
    # warm-up once each + repeats x all, interleaved
    assert len(calls) == 3 + 2 * 3


def test_config_is_static_pytree_aux():
    """Backends carry KernelConfig as static aux data: same arrays + same
    config -> same treedef; different config -> different treedef (a
    retrace, never a silent shape clash)."""
    from repro.core.engine.context import ExactBackend

    v = jnp.zeros((4, 3))
    a = ExactBackend(vectors=v, config=KernelConfig(128, 2, 0))
    b = ExactBackend(vectors=v, config=KernelConfig(128, 2, 0))
    c = ExactBackend(vectors=v, config=KernelConfig(256, 2, 0))
    ta = jax.tree_util.tree_structure(a)
    assert ta == jax.tree_util.tree_structure(b)
    assert ta != jax.tree_util.tree_structure(c)
    leaves = jax.tree_util.tree_leaves(a)
    assert all(not isinstance(x, (KernelConfig, dataclasses.Field))
               for x in leaves)
