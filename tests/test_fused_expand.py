"""Fused constrained-expansion coverage (kernels/fused_expand + engine wiring).

Three layers, mirroring the PR's risk surface:
  1. kernels (interpret mode) vs ref.py oracles — padding ids, all-visited
     rows, empty constraint sets, both in-kernel families, M_blk tiling,
     for BOTH distance variants (exact rows and PQ/ADC code rows);
  2. the sorted-merge machinery the fused loop replaces top_k with
     (seeded sweeps — the hypothesis twins in test_queue.py cover CI);
  3. system level: fused and unfused searches are IDENTICAL (ids, dists,
     every stats counter) on random graphs across modes, beams, families,
     and distance backends (exact and PQ), plus the TraversalContext API
     contract (no backend soup left in engine signatures).
"""
import inspect

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RangeConstraint,
    SearchParams,
    constrained_search,
    equal_constraint,
    pq_train,
    unequal_pct_constraint,
)
from repro.core import queue as q
from repro.core import visited as vis
from repro.core.constraints import make_satisfied_fn
from repro.core.engine import mask_first_occurrence, mask_first_occurrence_sorted
from repro.data.synthetic import make_labeled_corpus, make_queries
from repro.graph.index import build_index
from repro.kernels.fused_expand.fused_expand import (
    fused_expand_adc_kernel,
    fused_expand_kernel,
)
from repro.kernels.fused_expand.ref import fused_expand_adc_ref, fused_expand_ref


def key(i):
    return jax.random.PRNGKey(i)


# ---------------------------------------------------------------------------
# 1. kernel (interpret) vs oracle
# ---------------------------------------------------------------------------

B, M, N_CORPUS, D, L = 3, 12, 60, 16, 40


def _label_world(seed=0, all_visited=False, empty_cons=False):
    qs = jax.random.normal(key(seed), (B, D))
    corpus = jax.random.normal(key(seed + 1), (N_CORPUS, D))
    labels = jax.random.randint(key(seed + 2), (N_CORPUS,), 0, L, dtype=jnp.int32)
    ids = jax.random.randint(key(seed + 3), (B, M), -2, N_CORPUS)
    if all_visited:
        visited = jnp.full((B, vis.n_words(N_CORPUS)), 0xFFFFFFFF, jnp.uint32)
    else:
        visited = jax.random.randint(
            key(seed + 4), (B, vis.n_words(N_CORPUS)), 0, 2**31 - 1
        ).astype(jnp.uint32)
    n_words = (L + 31) // 32
    if empty_cons:
        cons = jnp.zeros((B, n_words), jnp.uint32)
    else:
        cons = jax.random.randint(
            key(seed + 5), (B, n_words), 0, 2**31 - 1
        ).astype(jnp.uint32)
    return qs, corpus, labels, ids, visited, cons


def _assert_matches_ref(qs, corpus, meta, ids, visited, cons, family, m_blk=None):
    dk, sk, fk = fused_expand_kernel(
        qs, corpus, ids, visited, meta, cons,
        family=family, m_blk=m_blk, interpret=True,
    )
    dr, sr, fr = fused_expand_ref(
        qs, corpus, ids, visited, meta, cons, family=family
    )
    assert bool(jnp.all(jnp.isinf(dk) == jnp.isinf(dr)))
    fin = jnp.isfinite(dr)
    np.testing.assert_allclose(
        np.asarray(jnp.where(fin, dk, 0.0)),
        np.asarray(jnp.where(fin, dr, 0.0)),
        rtol=1e-5, atol=1e-5 * D,
    )
    np.testing.assert_array_equal(np.asarray(sk, bool), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(fk, bool), np.asarray(fr))


@pytest.mark.parametrize("m_blk", [None, 4, 8])
def test_label_kernel_matches_ref(m_blk):
    qs, corpus, labels, ids, visited, cons = _label_world()
    _assert_matches_ref(qs, corpus, labels, ids, visited, cons, "label", m_blk)


def test_label_kernel_all_padding_row():
    qs, corpus, labels, _, visited, cons = _label_world()
    ids = jnp.full((B, M), -1, jnp.int32)
    _assert_matches_ref(qs, corpus, labels, ids, visited, cons, "label")
    d, s, f = fused_expand_kernel(
        qs, corpus, ids, visited, labels, cons, family="label", interpret=True
    )
    assert bool(jnp.all(jnp.isinf(d)))
    assert not bool(jnp.any(s)) and not bool(jnp.any(f))


def test_label_kernel_all_visited_rows_report_stale():
    qs, corpus, labels, ids, visited, cons = _label_world(all_visited=True)
    _assert_matches_ref(qs, corpus, labels, ids, visited, cons, "label")
    _, _, f = fused_expand_kernel(
        qs, corpus, ids, visited, labels, cons, family="label", interpret=True
    )
    assert not bool(jnp.any(f))


def test_label_kernel_empty_constraint_set():
    qs, corpus, labels, ids, visited, cons = _label_world(empty_cons=True)
    _assert_matches_ref(qs, corpus, labels, ids, visited, cons, "label")
    _, s, _ = fused_expand_kernel(
        qs, corpus, ids, visited, labels, cons, family="label", interpret=True
    )
    assert not bool(jnp.any(s))


def test_label_kernel_blk_not_dividing_m():
    # M=12 with M_blk=8 -> padded grid tile; trailing lanes must be dropped
    qs, corpus, labels, ids, visited, cons = _label_world(seed=7)
    d8, s8, f8 = fused_expand_kernel(
        qs, corpus, ids, visited, labels, cons,
        family="label", m_blk=8, interpret=True,
    )
    assert d8.shape == (B, M)
    d4, s4, f4 = fused_expand_kernel(
        qs, corpus, ids, visited, labels, cons,
        family="label", m_blk=4, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(d8), np.asarray(d4), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(s8), np.asarray(s4))
    np.testing.assert_array_equal(np.asarray(f8), np.asarray(f4))


@pytest.mark.parametrize("empty_window", [False, True])
def test_range_kernel_matches_ref(empty_window):
    qs, corpus, _, ids, visited, _ = _label_world(seed=11)
    attr = jax.random.uniform(key(20), (N_CORPUS,), minval=-1.0, maxval=1.0)
    lo = jnp.full((B,), 0.25) if empty_window else jnp.full((B,), -0.5)
    hi = jnp.full((B,), -0.25) if empty_window else jnp.full((B,), 0.5)
    cons = jnp.stack([lo, hi], axis=-1)
    _assert_matches_ref(qs, corpus, attr, ids, visited, cons, "range")
    if empty_window:
        _, s, _ = fused_expand_kernel(
            qs, corpus, ids, visited, attr, cons, family="range", interpret=True
        )
        assert not bool(jnp.any(s))


# --- ADC variant (PR3): code-row DMAs + in-kernel LUT sums ------------------

M_SUB, N_CENT = 8, 16


def _adc_world(seed=0):
    qs, corpus, labels, ids, visited, cons = _label_world(seed)
    lut = jax.random.uniform(key(seed + 6), (B, M_SUB, N_CENT))
    codes = jax.random.randint(
        key(seed + 7), (N_CORPUS, M_SUB), 0, N_CENT, dtype=jnp.int32
    )
    return lut, codes, labels, ids, visited, cons


def _assert_adc_matches_ref(lut, codes, meta, ids, visited, cons, family,
                            m_blk=None):
    dk, sk, fk = fused_expand_adc_kernel(
        lut, codes, ids, visited, meta, cons,
        family=family, m_blk=m_blk, interpret=True,
    )
    dr, sr, fr = fused_expand_adc_ref(
        lut, codes, ids, visited, meta, cons, family=family
    )
    assert bool(jnp.all(jnp.isinf(dk) == jnp.isinf(dr)))
    fin = jnp.isfinite(dr)
    np.testing.assert_allclose(
        np.asarray(jnp.where(fin, dk, 0.0)),
        np.asarray(jnp.where(fin, dr, 0.0)),
        rtol=1e-5, atol=1e-5 * M_SUB,
    )
    np.testing.assert_array_equal(np.asarray(sk, bool), np.asarray(sr))
    np.testing.assert_array_equal(np.asarray(fk, bool), np.asarray(fr))


@pytest.mark.parametrize("m_blk", [None, 4, 8])
def test_adc_kernel_matches_ref(m_blk):
    lut, codes, labels, ids, visited, cons = _adc_world()
    _assert_adc_matches_ref(lut, codes, labels, ids, visited, cons, "label", m_blk)


def test_adc_kernel_all_padding_row():
    lut, codes, labels, _, visited, cons = _adc_world()
    ids = jnp.full((B, M), -1, jnp.int32)
    d, s, f = fused_expand_adc_kernel(
        lut, codes, ids, visited, labels, cons, family="label", interpret=True
    )
    assert bool(jnp.all(jnp.isinf(d)))
    assert not bool(jnp.any(s)) and not bool(jnp.any(f))


def test_adc_kernel_range_family():
    lut, codes, _, ids, visited, _ = _adc_world(seed=13)
    attr = jax.random.uniform(key(21), (N_CORPUS,), minval=-1.0, maxval=1.0)
    cons = jnp.stack([jnp.full((B,), -0.5), jnp.full((B,), 0.5)], axis=-1)
    _assert_adc_matches_ref(lut, codes, attr, ids, visited, cons, "range")


def test_adc_ref_matches_unfused_pq_backend_bitwise():
    """The ADC oracle IS the unfused PQ computation: distances via the very
    formula PQBackend.distances evaluates — bit-for-bit."""
    from repro.core.engine.context import PQBackend

    lut, codes, labels, ids, visited, cons = _adc_world(seed=5)
    d_ref, _, _ = fused_expand_adc_ref(
        lut, codes, ids, visited, labels, cons, family="label"
    )
    d_eng = PQBackend(codes=codes, lut=lut).distances(None, ids)
    d_eng = jnp.where(ids >= 0, d_eng, jnp.inf)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_eng))


def test_ref_matches_unfused_engine_pieces_bitwise():
    """The oracle IS the unfused computation: distances via the same
    primitive, masks via the same integer ops — bit-for-bit."""
    from repro.common.distances import batched_rowwise_sqdist

    qs, corpus, labels, ids, visited, cons = _label_world(seed=3)
    from repro.core.constraints import LabelSetConstraint
    from repro.core.types import Corpus

    corp = Corpus(vectors=corpus, labels=labels)
    sat_fn = make_satisfied_fn(LabelSetConstraint(words=cons), corp)
    d_ref, s_ref, f_ref = fused_expand_ref(
        qs, corpus, ids, visited, labels, cons, family="label"
    )
    d_eng = batched_rowwise_sqdist(qs, corpus[jnp.maximum(ids, 0)])
    d_eng = jnp.where(ids >= 0, d_eng, jnp.inf)
    np.testing.assert_array_equal(np.asarray(d_ref), np.asarray(d_eng))
    np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(sat_fn(ids)))
    np.testing.assert_array_equal(
        np.asarray(f_ref), np.asarray((ids >= 0) & ~vis.visited_test(visited, ids))
    )


# ---------------------------------------------------------------------------
# 2. sorted-merge + sorted-dedup sweeps (seeded; run without hypothesis)
# ---------------------------------------------------------------------------


def test_merge_sorted_equals_push_seeded_sweep():
    """Fixed shapes (one compile), 50 data draws with heavy distance ties —
    unsorted input goes through sort_run, exactly as the fused loop does."""
    rng = np.random.RandomState(0)
    cap, b, m = 8, 4, 12
    vals = np.asarray([0.5, 1.0, 2.0, 3.5, 7.0, 9.0], np.float32)
    for trial in range(50):
        n_live = rng.randint(0, cap + 1)
        qq = q.queue_init(b, cap)
        if n_live:
            live = np.sort(rng.choice(vals, (b, n_live)), -1)
            qq = q.queue_push(
                qq, jnp.asarray(live),
                jnp.tile(jnp.arange(n_live, dtype=jnp.int32), (b, 1)),
                jnp.ones((b, n_live), bool),
            )
        new = jnp.asarray(rng.choice(vals, (b, m)).astype(np.float32))
        valid = jnp.asarray(rng.rand(b, m) < 0.7)
        ni = jnp.tile(jnp.arange(100, 100 + m, dtype=jnp.int32), (b, 1))
        run_d, run_i = q.sort_run(new, ni, valid)
        merged = q.queue_merge_sorted(qq, run_d, run_i)
        pushed = q.queue_push(qq, new, ni, valid)
        np.testing.assert_array_equal(
            np.asarray(merged.dists), np.asarray(pushed.dists), err_msg=str(trial)
        )
        np.testing.assert_array_equal(
            np.asarray(merged.ids), np.asarray(pushed.ids), err_msg=str(trial)
        )


def test_partition_runs_then_merge_equals_two_pushes_seeded():
    """The fused loop's exact frontier update: one bitonic partition of the
    candidate batch + two windowed merges == two top_k pushes, bit for bit."""
    rng = np.random.RandomState(3)
    b, c, m = 3, 16, 24
    vals = np.asarray([0.25, 0.5, 1.0, 2.0, 3.5], np.float32)
    for trial in range(30):
        mk = lambda s: q.queue_push(
            q.queue_init(b, c),
            jnp.asarray(np.sort(rng.choice(vals, (b, s)), -1)),
            jnp.asarray(rng.randint(0, 1000, (b, s)), jnp.int32),
            jnp.ones((b, s), bool),
        )
        satq, othq = mk(rng.randint(1, c + 1)), mk(rng.randint(1, c + 1))
        d = jnp.asarray(rng.choice(vals, (b, m)).astype(np.float32))
        ids = jnp.asarray(rng.randint(0, 1000, (b, m)), jnp.int32)
        fresh = jnp.asarray(rng.rand(b, m) < 0.7)
        sat_m = jnp.asarray(rng.rand(b, m) < 0.5) & fresh
        run_sat, run_oth = q.partition_sorted_runs(
            d, ids, sat_m, fresh & ~sat_m, c, c
        )
        got_s = q.queue_merge_sorted(satq, *run_sat)
        got_o = q.queue_merge_sorted(othq, *run_oth)
        want_s = q.queue_push(satq, d, ids, sat_m)
        want_o = q.queue_push(othq, d, ids, fresh & ~sat_m)
        for got, want in ((got_s, want_s), (got_o, want_o)):
            np.testing.assert_array_equal(
                np.asarray(got.dists), np.asarray(want.dists), err_msg=str(trial)
            )
            np.testing.assert_array_equal(
                np.asarray(got.ids), np.asarray(want.ids), err_msg=str(trial)
            )


def test_sorted_dedup_equals_pairwise_seeded_sweep():
    rng = np.random.RandomState(1)
    b, m = 4, 24
    for trial in range(50):
        ids = jnp.asarray(rng.randint(-1, 8, (b, m)), jnp.int32)  # heavy dups
        valid = jnp.asarray(rng.rand(b, m) < 0.6)
        got = mask_first_occurrence_sorted(ids, valid)
        # reference: the O(M^2) pairwise rule (M=24 < 128 -> pairwise branch)
        want = mask_first_occurrence(ids, valid)
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want), err_msg=str(trial)
        )


def test_mask_first_occurrence_dispatches_to_sorted_beyond_128():
    rng = np.random.RandomState(2)
    ids = jnp.asarray(rng.randint(0, 40, (2, 160)), jnp.int32)
    valid = jnp.asarray(rng.rand(2, 160) < 0.7)
    got = mask_first_occurrence(ids, valid)  # M=160 -> sorted path
    want = mask_first_occurrence_sorted(ids, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # and the kept set is exactly one valid slot per distinct valid id
    for row_ids, row_keep, row_valid in zip(
        np.asarray(ids), np.asarray(got), np.asarray(valid)
    ):
        kept = row_ids[row_keep]
        assert len(kept) == len(set(kept.tolist()))
        assert set(kept.tolist()) == set(row_ids[row_valid].tolist())


# ---------------------------------------------------------------------------
# 3. system level: fused == unfused searches
# ---------------------------------------------------------------------------

NSYS, DSYS, LSYS = 3000, 16, 8


@pytest.fixture(scope="module")
def sys_world():
    corpus = make_labeled_corpus(key(0), n=NSYS, d=DSYS, n_labels=LSYS)
    attrs = jax.random.uniform(key(50), (NSYS, 2), minval=0.0, maxval=1.0)
    corpus = corpus.replace(attrs=attrs)
    graph = build_index(key(1), corpus, degree=16, sample_size=256)
    queries, qlab = make_queries(key(2), corpus, 16)
    return corpus, graph, queries, qlab


def _search(world, cons, mode, beam, fuse, rng=None, pq_index=None):
    corpus, graph, queries, _ = world
    params = SearchParams(
        mode=mode, k=10, ef_result=64, ef_sat=64, ef_other=64,
        n_start=16, max_iters=600, beam_width=beam, fuse_expand=fuse,
        approx="exact" if pq_index is None else "pq",
    )
    return constrained_search(
        corpus, graph, queries, cons, params, rng=rng, pq_index=pq_index
    )


def _assert_identical(ra, rb):
    np.testing.assert_array_equal(np.asarray(ra.ids), np.asarray(rb.ids))
    np.testing.assert_array_equal(np.asarray(ra.dists), np.asarray(rb.dists))
    for f in ("dist_evals", "hops", "visited", "iters", "beam_expansions"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ra.stats, f)),
            np.asarray(getattr(rb.stats, f)),
            err_msg=f,
        )


@pytest.mark.parametrize("mode", ["vanilla", "prefer"])
@pytest.mark.parametrize("beam", [1, 2, 4])
def test_fused_equals_unfused_label_family(sys_world, mode, beam):
    cons = equal_constraint(sys_world[3], LSYS)
    rng = key(7) if mode == "vanilla" else None
    _assert_identical(
        _search(sys_world, cons, mode, beam, "on", rng),
        _search(sys_world, cons, mode, beam, "off", rng),
    )


@pytest.mark.parametrize("beam", [2, 4])
def test_fused_equals_unfused_unequal_labels(sys_world, beam):
    cons = unequal_pct_constraint(key(3), sys_world[3], LSYS, 25.0)
    _assert_identical(
        _search(sys_world, cons, "prefer", beam, "on"),
        _search(sys_world, cons, "prefer", beam, "off"),
    )


@pytest.mark.parametrize("mode", ["start", "alter"])
def test_fused_equals_unfused_range_family(sys_world, mode):
    b = sys_world[2].shape[0]
    cons = RangeConstraint(
        lo=jnp.full((b,), 0.2), hi=jnp.full((b,), 0.8), col=jnp.int32(1)
    )
    _assert_identical(
        _search(sys_world, cons, mode, 2, "on"),
        _search(sys_world, cons, mode, 2, "off"),
    )


def test_auto_policy_and_path_equivalence(sys_world):
    """auto targets TPU for the in-kernel families only — gated on the
    hardware-validation flag — and resolves to the unfused path on this
    CPU host; either way the results are identical, so the policy is
    purely physical."""
    from repro.core.engine import context as engine_ctx
    from repro.core.engine.context import resolve_auto_fuse

    assert not resolve_auto_fuse(True, "cpu")
    assert not resolve_auto_fuse(False, "tpu")  # no tables -> stay unfused
    # the TPU gate is the validation flag, not the backend check
    assert resolve_auto_fuse(True, "tpu") is engine_ctx.FUSE_AUTO_ON_TPU

    cons = equal_constraint(sys_world[3], LSYS)
    _assert_identical(
        _search(sys_world, cons, "prefer", 2, "auto"),
        _search(sys_world, cons, "prefer", 2, "on"),
    )

    def udf(label, attrs_row):  # same predicate as equal, as a closure
        del attrs_row
        return label >= 0

    _assert_identical(
        _search(sys_world, udf, "prefer", 2, "auto"),
        _search(sys_world, udf, "prefer", 2, "off"),
    )


@pytest.mark.parametrize("beam", [1, 2, 4])
def test_fused_equals_unfused_udf_family(sys_world, beam):
    """UDF constraints fuse via the precompiled predicate column (PR8):
    the kernel consumes the (n,) int32 verdict table as its metadata
    column, so fuse_expand="on" must reproduce the unfused closure path
    bit-for-bit — including a predicate that mixes label and attrs."""

    def udf(label, attrs_row):
        return (label % 2 == 0) | (attrs_row[1] > 0.5)

    _assert_identical(
        _search(sys_world, udf, "prefer", beam, "on"),
        _search(sys_world, udf, "prefer", beam, "off"),
    )


# ---------------------------------------------------------------------------
# 3b. system level: fused ADC == unfused PQ traversal (PR3)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sys_pq(sys_world):
    corpus = sys_world[0]
    return pq_train(key(9), corpus.vectors, m_sub=8, n_cent=32)


@pytest.mark.parametrize("mode", ["vanilla", "prefer"])
@pytest.mark.parametrize("beam", [1, 2, 4])
def test_fused_pq_equals_unfused_pq_label_family(sys_world, sys_pq, mode, beam):
    """`fuse_expand="on"` is now legal for approx="pq": the ADC kernel's
    one-pass code-row gather + LUT sum + constraint + visited must
    reproduce the unfused PQ walk bit-for-bit — ids, exact-reranked
    distances, and every stats counter."""
    cons = equal_constraint(sys_world[3], LSYS)
    rng = key(7) if mode == "vanilla" else None
    _assert_identical(
        _search(sys_world, cons, mode, beam, "on", rng, pq_index=sys_pq),
        _search(sys_world, cons, mode, beam, "off", rng, pq_index=sys_pq),
    )


@pytest.mark.parametrize("mode", ["start", "alter"])
def test_fused_pq_equals_unfused_pq_range_family(sys_world, sys_pq, mode):
    b = sys_world[2].shape[0]
    cons = RangeConstraint(
        lo=jnp.full((b,), 0.2), hi=jnp.full((b,), 0.8), col=jnp.int32(1)
    )
    _assert_identical(
        _search(sys_world, cons, mode, 2, "on", pq_index=sys_pq),
        _search(sys_world, cons, mode, 2, "off", pq_index=sys_pq),
    )


# ---------------------------------------------------------------------------
# 4. TraversalContext API contract
# ---------------------------------------------------------------------------


def test_no_backend_soup_in_engine_signatures():
    """Backend selection flows ONLY through the TraversalContext: no
    use_kernel / pq_codes / lut parameter may reappear in any public
    engine-layer function signature (the PR3 refactor's contract)."""
    from repro.core.engine import context, expand, loop, policy

    banned = {"use_kernel", "pq_codes", "lut"}
    for module in (context, expand, loop, policy):
        for name, fn in vars(module).items():
            if not inspect.isfunction(fn) or name.startswith("_"):
                continue
            params = set(inspect.signature(fn).parameters)
            assert not (params & banned), (
                f"{module.__name__}.{name} leaks backend soup: "
                f"{params & banned}"
            )


def test_golden_beam1_parity_runs_through_context():
    """The golden-file suite (test_engine_beam) exercises the context
    plumbing by construction; spot-check here that constrained_search is
    the context-built path and the backends classify as documented."""
    from repro.core import ExactBackend, L2KernelBackend, PQBackend, build_context
    from repro.core.types import Corpus

    corpus = Corpus(
        vectors=jax.random.normal(key(0), (32, 16)),
        labels=jnp.zeros((32,), jnp.int32),
    )
    qs = jax.random.normal(key(1), (2, 16))
    cons = equal_constraint(jnp.zeros((2,), jnp.int32), 4)

    ctx = build_context(corpus, cons, qs, SearchParams())
    assert isinstance(ctx.backend, ExactBackend)
    assert ctx.backend.fusable and not ctx.backend.approximate

    ctx = build_context(corpus, cons, qs, SearchParams(use_kernel=True))
    assert isinstance(ctx.backend, L2KernelBackend)

    pq = pq_train(key(2), corpus.vectors, m_sub=4, n_cent=8)
    ctx = build_context(
        corpus, cons, qs, SearchParams(approx="pq"), pq_index=pq
    )
    assert isinstance(ctx.backend, PQBackend)
    assert ctx.backend.fusable and ctx.backend.approximate

    with pytest.raises(ValueError, match="pq_index"):
        build_context(corpus, cons, qs, SearchParams(approx="pq"))
