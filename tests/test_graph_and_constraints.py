"""Graph builder invariants + constraint families + alter_ratio estimator."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.common.distances import squared_l2  # noqa: E402
from repro.core import (  # noqa: E402
    Corpus,
    RangeConstraint,
    equal_constraint,
    estimate_alter_ratio,
    label_set_from_lists,
    make_satisfied_fn,
    unequal_pct_constraint,
)
from repro.data.synthetic import make_labeled_corpus  # noqa: E402
from repro.graph.build import build_knn_graph, medoid, nn_descent  # noqa: E402
from repro.graph.index import build_index  # noqa: E402


def _rand_vectors(n=200, d=8, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), (n, d))


def test_exact_knn_graph_matches_bruteforce():
    x = _rand_vectors(120, 6)
    g = build_knn_graph(x, degree=5, block=32)
    d = np.array(squared_l2(x, x))
    np.fill_diagonal(d, np.inf)
    for i in range(0, 120, 17):
        # compare by distance (top_k and argsort may break ties differently)
        expect = np.sort(d[i])[:5]
        got = np.sort(d[i][np.asarray(g[i])])
        np.testing.assert_allclose(got, expect, rtol=1e-5, atol=1e-6)


def test_graph_rows_sorted_self_free_unique():
    x = _rand_vectors(150, 5, seed=1)
    g = np.asarray(build_knn_graph(x, degree=8))
    d = np.asarray(squared_l2(x, x))
    for i, row in enumerate(g):
        live = row[row >= 0]
        assert i not in live
        assert len(live) == len(set(live.tolist()))
        dist = d[i][live]
        assert np.all(np.diff(dist) >= -1e-5)  # ascending by distance


def test_nn_descent_recall_reasonable():
    x = _rand_vectors(400, 8, seed=2)
    exact = np.asarray(build_knn_graph(x, degree=8))
    approx = np.asarray(nn_descent(jax.random.PRNGKey(3), x, degree=8, iters=10))
    hits = total = 0
    for e_row, a_row in zip(exact, approx):
        hits += len(set(e_row.tolist()) & set(a_row[a_row >= 0].tolist()))
        total += len(e_row)
    assert hits / total > 0.6, hits / total


def test_medoid_is_central():
    x = _rand_vectors(300, 4, seed=4)
    m = int(medoid(x))
    dm = float(jnp.sum(squared_l2(x[m : m + 1], x)))
    rand = float(jnp.sum(squared_l2(x[:1], x)))
    assert dm <= rand * 1.1


@settings(deadline=None, max_examples=20)
@given(st.integers(2, 40), st.data())
def test_label_set_constraint_matches_membership(n_labels, data):
    allowed = data.draw(
        st.lists(st.integers(0, n_labels - 1), min_size=1, max_size=n_labels, unique=True)
    )
    cons = label_set_from_lists([allowed], n_labels)
    labels = jnp.arange(n_labels, dtype=jnp.int32)
    corpus = Corpus(
        vectors=jnp.zeros((n_labels, 2)), labels=labels
    )
    sat = make_satisfied_fn(cons, corpus)
    ids = jnp.arange(n_labels, dtype=jnp.int32)[None]
    got = np.asarray(sat(ids))[0]
    expect = np.isin(np.arange(n_labels), allowed)
    np.testing.assert_array_equal(got, expect)


def test_unequal_pct_never_includes_query_label():
    qlab = jnp.arange(10, dtype=jnp.int32) % 7
    cons = unequal_pct_constraint(jax.random.PRNGKey(0), qlab, 7, 40.0)
    corpus = Corpus(vectors=jnp.zeros((7, 2)), labels=jnp.arange(7, dtype=jnp.int32))
    sat = make_satisfied_fn(cons, corpus)
    own = sat(qlab[:, None])  # query's own label id as candidate
    assert not bool(jnp.any(own))


def test_range_constraint():
    corpus = Corpus(
        vectors=jnp.zeros((5, 2)),
        labels=jnp.zeros((5,), jnp.int32),
        attrs=jnp.asarray([[0.0], [1.0], [2.0], [3.0], [4.0]]),
    )
    cons = RangeConstraint(
        lo=jnp.asarray([1.0]), hi=jnp.asarray([3.0]), col=jnp.int32(0)
    )
    sat = make_satisfied_fn(cons, corpus)
    got = np.asarray(sat(jnp.arange(5, dtype=jnp.int32)[None]))[0]
    np.testing.assert_array_equal(got, [False, True, True, True, False])


def test_alter_ratio_clustered_vs_random():
    """§2.4: clustered labels -> ratio near 1; random labels -> ratio ~ p."""
    rng = jax.random.PRNGKey(0)
    clustered = make_labeled_corpus(rng, n=2000, d=16, n_labels=5, pct_random=0.0)
    random_lab = make_labeled_corpus(rng, n=2000, d=16, n_labels=5, pct_random=100.0)
    out = {}
    for name, corpus in [("clustered", clustered), ("random", random_lab)]:
        graph = build_index(jax.random.PRNGKey(1), corpus, degree=8, sample_size=128)
        qlab = corpus.labels[:8]
        cons = equal_constraint(qlab, 5)
        sat = make_satisfied_fn(cons, corpus)
        sample_ids = jnp.broadcast_to(graph.sample_ids[None], (8, 128))
        ratio = estimate_alter_ratio(graph, sat, sat(sample_ids), k=8)
        out[name] = float(jnp.mean(ratio))
    assert out["clustered"] > 0.7
    assert out["random"] < 0.45
    assert 0.0 <= out["random"] and out["clustered"] <= 1.0
