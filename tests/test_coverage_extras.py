"""Additional coverage: data pipelines, roofline report, flash soft-cap,
PQ index quality, NN-descent-built search, launcher batch functions."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.meshinfo import single_device_meshinfo

MI = single_device_meshinfo()


def test_data_pipeline_shapes_match_arch_inputs():
    """Every family's batch generator must produce exactly the tensors the
    arch cells expect (names, shapes, dtypes)."""
    from repro.archs.base import get_arch
    from repro.launch.train import make_batch_fn

    for arch_name in ("smoke-gqa", "smoke-dlrm", "smoke-deepfm",
                      "smoke-sasrec", "smoke-two-tower", "smoke-mace"):
        arch = get_arch(arch_name)
        train_shape = next(
            s for s in arch.shape_names() if arch.shapes[s]["kind"] == "train"
        )
        if arch.family == "gnn" and arch.shapes[train_shape]["mode"] != "simple":
            continue
        cell = arch.make_cell(train_shape, MI)
        batch_abs = cell.args[2]
        batch = make_batch_fn(arch, arch.shapes[train_shape])(7, 0)
        for k, spec in batch_abs.items():
            assert k in batch, (arch_name, k)
            assert tuple(batch[k].shape) == tuple(spec.shape), (arch_name, k)


def test_roofline_report_terms_all_cells():
    """The analytic model must produce finite, positive terms for all 42
    assigned+paper cells without touching artifacts."""
    from repro.configs import ASSIGNED
    from repro.roofline.report import terms_for_cell

    from repro.archs.base import get_arch

    n = 0
    for arch_name in ASSIGNED + ("airship-sift1m",):
        arch = get_arch(arch_name)
        for shape in arch.shape_names():
            t = terms_for_cell(arch_name, shape, 256)
            assert t.flops > 0 and t.hbm_bytes > 0, t.cell
            assert np.isfinite(t.roofline_fraction), t.cell
            assert t.bottleneck in ("compute", "memory", "collective")
            n += 1
    # 40 assigned + 6 airship (incl. the D4 PQ, beam-engine, PR2 fused-
    # pipeline, and PR3 fused-ADC variants)
    assert n == 46


def test_flash_attention_soft_cap_grads():
    from repro.models.common.modules import chunked_attention

    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 4)) * 3
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 4)) * 3
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 4))

    def naive(q, k, v, cap):
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / 2.0
        s = cap * jnp.tanh(s / cap)
        mask = jnp.tril(jnp.ones((8, 8), bool))
        p = jax.nn.softmax(jnp.where(mask[None, None], s, -jnp.inf), axis=-1)
        return jnp.einsum("bhqk,bkhd->bqhd", p, v)

    f1 = lambda *a: jnp.sum(
        jnp.sin(chunked_attention(*a, causal=True, chunk=3, logit_soft_cap=5.0))
    )
    f2 = lambda *a: jnp.sum(jnp.sin(naive(*a, 5.0)))
    o1 = chunked_attention(q, k, v, causal=True, chunk=3, logit_soft_cap=5.0)
    o2 = naive(q, k, v, 5.0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_pq_index_beats_random_ranking():
    """ADC distance ordering must correlate with true distances."""
    from repro.core.pq import adc_scan, adc_table, pq_train
    from repro.common.distances import squared_l2

    x = jax.random.normal(jax.random.PRNGKey(0), (500, 16))
    q = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
    pq = pq_train(jax.random.PRNGKey(2), x, m_sub=4, n_cent=32)
    approx = adc_scan(pq, adc_table(pq, q))  # (4, 500)
    true = squared_l2(q, x)
    # Spearman-ish: top-10 by ADC should heavily overlap true top-50
    for i in range(4):
        a_top = set(np.argsort(np.asarray(approx[i]))[:10].tolist())
        t_top = set(np.argsort(np.asarray(true[i]))[:50].tolist())
        assert len(a_top & t_top) >= 7, (i, len(a_top & t_top))


def test_search_on_nn_descent_index():
    """The searcher is builder-agnostic: an NN-descent index must reach
    useful recall too (slightly below exact-kNN is fine)."""
    from repro.core import (SearchParams, constrained_search,
                            equal_constraint, exact_constrained_search, recall)
    from repro.data.synthetic import make_labeled_corpus, make_queries
    from repro.graph.index import build_index

    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=3000, d=16, n_labels=5)
    graph = build_index(
        jax.random.PRNGKey(1), corpus, degree=12, sample_size=256,
        method="nn_descent", nn_descent_iters=8,
    )
    q, qlab = make_queries(jax.random.PRNGKey(2), corpus, 16)
    cons = equal_constraint(qlab, 5)
    _, ti = exact_constrained_search(corpus, q, cons, k=10)
    params = SearchParams(mode="prefer", k=10, ef_result=128, n_start=16,
                          max_iters=600)
    res = constrained_search(corpus, graph, q, cons, params)
    assert float(recall(res.ids, ti)) > 0.7


def test_partitioned_index_covers_corpus():
    from repro.data.synthetic import make_labeled_corpus
    from repro.graph.index import build_partitioned_index

    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=1000, d=8, n_labels=4)
    corpus_p, graph_p = build_partitioned_index(
        jax.random.PRNGKey(1), corpus, n_shards=4, degree=8,
        sample_size_per_shard=32,
    )
    n_local = corpus_p.n // 4
    # per-shard neighbor ids are local (0..n_local-1)
    nbrs = np.asarray(graph_p.neighbors)
    assert nbrs.max() < n_local
    assert graph_p.sample_ids.shape == (4 * 32,)
    assert graph_p.entry_point.shape == (4,)
    assert np.asarray(graph_p.sample_ids).max() < n_local


def test_visited_count_matches_search_touch():
    """stats.visited == number of distinct vertices whose bit was set."""
    from repro.core import (SearchParams, constrained_search, equal_constraint)
    from repro.data.synthetic import make_labeled_corpus, make_queries
    from repro.graph.index import build_index

    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=2000, d=8, n_labels=4)
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=8, sample_size=64)
    q, qlab = make_queries(jax.random.PRNGKey(2), corpus, 8)
    params = SearchParams(mode="prefer", k=5, ef_result=32, n_start=8, max_iters=200)
    res = constrained_search(corpus, graph, q, equal_constraint(qlab, 4), params)
    v = np.asarray(res.stats.visited)
    assert np.all(v >= 1) and np.all(v <= 2000)
    # touched at least the starts + entry
    assert np.all(v >= np.minimum(8, v))
