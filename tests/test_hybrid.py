"""Hybrid selectivity-adaptive execution (DESIGN.md §9).

Covers the PR-6 acceptance surface:
  * posting-set scan bit-parity with the exact constrained oracle across
    constraint families x backends (exact / PQ) x tombstones, plus the
    empty-posting-set edge case (all-unfilled, never crashes);
  * incremental histogram / posting exactness under streaming churn
    (insert + delete + consolidate), cross-checked against the O(n) scan;
  * the shared estimator module: ``core.selectivity`` delegation, sampled
    UDF fallback, histogram-vs-scan agreement;
  * router lattice dispatch, applicability gates, controller retuning that
    stays within the lattice;
  * overlay lifecycle: results come from the posting set, epoch swaps
    invalidate (a stale overlay is never served), hotness gating;
  * serving integration: Response strategy/selectivity telemetry,
    router-vs-standalone bit-parity, per-strategy counters.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AttributeHistograms,
    LabelSetConstraint,
    PostingLists,
    RangeConstraint,
    RangeIndex,
    RouterConfig,
    SelectivityEstimator,
    StrategyRouter,
    build_overlay,
    equal_constraint,
    exact_constrained_search,
    overlay_search,
    posting_search,
    pq_train,
    scan_selectivity,
    selectivity,
)
from repro.core.posting import pad_posting, posting_bucket
from repro.core.router import GRAPH, OVERLAY, POSTING
from repro.core.types import SearchParams
from repro.data.synthetic import make_labeled_corpus
from repro.graph.index import build_index
from repro.serving import (
    AdaptiveController,
    ServingRuntime,
    StreamingLocalExecutor,
    VirtualClock,
    label_words_row,
    make_serving_router,
    make_tier_ladder,
)
from repro.streaming.slots import StreamingIndex

N, D, L = 1200, 16, 12
K = 8


# Skewed label frequencies so every selectivity bucket is populated:
# 50% ... 0.33% across the 12 labels (the uniform ~8% of the synthetic
# generator would leave the sub-1% buckets empty).
LABEL_COUNTS = (600, 240, 120, 84, 48, 36, 24, 18, 12, 8, 6, 4)
assert sum(LABEL_COUNTS) == N and len(LABEL_COUNTS) == L


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    labels = np.repeat(np.arange(L, dtype=np.int32), LABEL_COUNTS)
    np.random.RandomState(0).shuffle(labels)
    corpus = corpus.replace(
        labels=jnp.asarray(labels),
        attrs=jax.random.uniform(jax.random.PRNGKey(7), (N, 2)),
    )
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=12, sample_size=128)
    queries = jax.random.normal(jax.random.PRNGKey(2), (4, D))
    return corpus, graph, queries


@pytest.fixture(scope="module")
def tombstoned_world(world):
    """The same corpus with ~10% of rows tombstoned."""
    corpus, graph, queries = world
    words = np.zeros(((N + 31) // 32,), np.uint32)
    dead = np.random.RandomState(3).choice(N, size=N // 10, replace=False)
    for s in dead:
        words[s // 32] |= np.uint32(1) << np.uint32(s % 32)
    return corpus.replace(tombstones=jnp.asarray(words)), graph, queries


def _params(**kw):
    base = dict(
        mode="prefer", k=K, ef_result=32, ef_sat=32, ef_other=32,
        n_start=8, max_iters=64,
    )
    base.update(kw)
    return SearchParams(**base)


def _label_constraint(lab, b=4):
    return equal_constraint(jnp.full((b,), lab, jnp.int32), L)


def _posting_ids_for(corpus, constraint):
    """Ground-truth posting set: ids whose metadata can satisfy (ignores
    tombstones — the scan's closure must mask those itself)."""
    if isinstance(constraint, LabelSetConstraint):
        w = np.asarray(constraint.words)[0]
        labels = np.asarray(corpus.labels)
        ok = np.zeros((labels.shape[0],), bool)
        for lab in range(L):
            if (w[lab // 32] >> np.uint32(lab % 32)) & 1:
                ok |= labels == lab
        return np.nonzero(ok)[0].astype(np.int32)
    lo = float(np.asarray(constraint.lo)[0])
    hi = float(np.asarray(constraint.hi)[0])
    col = int(constraint.col)
    vals = np.asarray(corpus.attrs)[:, col]
    return np.nonzero((vals >= lo) & (vals <= hi))[0].astype(np.int32)


# ---------------------------------------------------------------------------
# posting scan: bit-parity with the oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fixture", ["world", "tombstoned_world"])
@pytest.mark.parametrize("family", ["label", "range"])
def test_posting_scan_matches_oracle_exact_backend(request, fixture, family):
    corpus, _, queries = request.getfixturevalue(fixture)
    if family == "label":
        constraint = _label_constraint(1)
    else:
        constraint = RangeConstraint(
            lo=jnp.full((4,), 0.2, jnp.float32),
            hi=jnp.full((4,), 0.35, jnp.float32),
            col=jnp.int32(0),
        )
    ids = _posting_ids_for(corpus, constraint)
    padded = pad_posting(ids, posting_bucket(ids.shape[0]))
    res = posting_search(corpus, queries, constraint, jnp.asarray(padded), _params())
    od, oi = exact_constrained_search(corpus, queries, constraint, K)
    np.testing.assert_array_equal(np.asarray(res.ids), np.asarray(oi))
    np.testing.assert_allclose(
        np.asarray(res.dists), np.asarray(od), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("fixture", ["world", "tombstoned_world"])
def test_posting_scan_pq_backend_reranks_exactly(request, fixture):
    """PQ path: ADC prune + exact re-rank. Distances of returned ids must
    be EXACT (the re-rank contract); id-set recall vs the oracle is high
    but the ordering beyond ties is governed by exact distances."""
    corpus, _, queries = request.getfixturevalue(fixture)
    pq = pq_train(jax.random.PRNGKey(9), corpus.vectors, m_sub=4, n_cent=32)
    constraint = _label_constraint(2)
    ids = _posting_ids_for(corpus, constraint)
    padded = pad_posting(ids, posting_bucket(ids.shape[0]))
    params = _params(approx="pq", ef_result=64)
    res = posting_search(
        corpus, queries, constraint, jnp.asarray(padded), params, pq
    )
    out_ids = np.asarray(res.ids)
    out_d = np.asarray(res.dists)
    vecs = np.asarray(corpus.vectors)
    q = np.asarray(queries)
    for b in range(q.shape[0]):
        got = out_ids[b][out_ids[b] >= 0]
        # every returned id is in the posting set
        assert np.isin(got, ids).all()
        # distances are exact squared-L2 (re-ranked), ascending
        d_true = ((vecs[got] - q[b]) ** 2).sum(-1)
        np.testing.assert_allclose(out_d[b][: got.shape[0]], d_true, rtol=1e-4)
        assert (np.diff(out_d[b][: got.shape[0]]) >= -1e-6).all()


def test_posting_scan_empty_set_returns_unfilled(world):
    corpus, _, queries = world
    empty = jnp.full((256,), -1, jnp.int32)
    res = posting_search(
        corpus, queries, _label_constraint(0), empty, _params()
    )
    assert (np.asarray(res.ids) == -1).all()
    assert np.isinf(np.asarray(res.dists)).all()
    assert (np.asarray(res.filled) == 0).all()


def test_posting_scan_set_smaller_than_k(world):
    """P < k: top_k pads internally; exactly P rows fill."""
    corpus, _, queries = world
    constraint = _label_constraint(3)
    ids = _posting_ids_for(corpus, constraint)[:3]  # truncated posting set
    res = posting_search(
        corpus, queries, constraint, jnp.asarray(ids), _params()
    )
    assert (np.asarray(res.filled) == 3).all()
    got = np.asarray(res.ids)
    assert np.isin(got[got >= 0], ids).all()


# ---------------------------------------------------------------------------
# estimator dedup + histograms
# ---------------------------------------------------------------------------


def test_selectivity_delegates_to_shared_estimator(world):
    corpus, _, _ = world
    c = _label_constraint(1)
    np.testing.assert_array_equal(
        np.asarray(selectivity(c, corpus)),
        np.asarray(scan_selectivity(c, corpus)),
    )


def test_estimator_udf_falls_back_to_sampled(world):
    corpus, graph, _ = world

    def udf(label, attrs):
        return label == 1

    est = SelectivityEstimator(corpus=corpus, sample_ids=graph.sample_ids)
    vals, source = est.estimate_constraint(udf)
    assert source == "sampled"
    truth = float(np.asarray(scan_selectivity(udf, corpus))[0])
    # 128-point sample: generous tolerance, but it must be in the ballpark
    assert abs(float(vals[0]) - truth) < 0.1
    # and histogram-covered families report the histogram source
    hist = AttributeHistograms.from_arrays(
        np.asarray(corpus.labels), np.asarray(corpus.attrs), n_labels=L
    )
    est2 = SelectivityEstimator(histograms=hist)
    vals2, source2 = est2.estimate_constraint(_label_constraint(1))
    assert source2 == "histogram"
    np.testing.assert_allclose(
        vals2, np.asarray(scan_selectivity(_label_constraint(1), corpus)),
        atol=1e-6,
    )


def test_histograms_label_exact_and_range_close(world):
    corpus, _, _ = world
    labels = np.asarray(corpus.labels)
    attrs = np.asarray(corpus.attrs)
    hist = AttributeHistograms.from_arrays(labels, attrs, n_labels=L)
    # label family is exact
    w = label_words_row([4], L)
    assert hist.estimate("label", w) == (labels == 4).mean()
    # range family is exact up to within-bin interpolation
    truth = ((attrs[:, 1] >= 0.1) & (attrs[:, 1] <= 0.4)).mean()
    est = hist.estimate("range", (0.1, 0.4, 1))
    assert abs(est - truth) < 0.05
    # inverted / empty windows estimate zero-ish
    assert hist.estimate("range", (0.9, 0.1, 1)) == 0.0


def test_streaming_histograms_stay_exact_under_churn(world):
    corpus, graph, _ = world
    index = StreamingIndex.from_static(corpus, graph, capacity=N + 256)
    rng = np.random.RandomState(11)
    for i in range(120):
        r = rng.rand()
        if r < 0.5:
            index.insert(
                rng.randn(D).astype(np.float32),
                label=int(rng.randint(L)),
                attrs=rng.rand(2).astype(np.float32),
            )
        else:
            index.delete(int(rng.randint(index.capacity)))
        if i % 40 == 39:
            index.consolidate()
            index.snapshot()  # publication runs the n_live tripwire
    index.check_stats_exact()  # full ground-truth cross-check
    # histogram estimate == live fraction from the device scan
    snap = index.snapshot()
    c = equal_constraint(jnp.asarray([3]), L)
    scan = float(np.asarray(scan_selectivity(c, snap.corpus))[0])
    est = index.histograms.estimate("label", label_words_row([3], L))
    # scan divides by capacity (tombstoned rows fail), histogram by n_live
    assert abs(est * index.pool.n_live / index.capacity - scan) < 1e-6


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------


def _static_router(corpus, graph, config=None, controller=None):
    labels = np.asarray(corpus.labels)
    attrs = np.asarray(corpus.attrs)
    hist = AttributeHistograms.from_arrays(labels, attrs, n_labels=L)
    postings = PostingLists.from_arrays(labels, n_labels=L)
    ri = RangeIndex()
    ri.refresh(attrs, np.ones((labels.shape[0],), bool), 0)
    est = SelectivityEstimator(
        histograms=hist, corpus=corpus, sample_ids=graph.sample_ids
    )
    return StrategyRouter(
        est, n=labels.shape[0], config=config, postings=postings,
        range_index=ri, controller=controller,
    )


def test_router_lattice_dispatch(world):
    corpus, graph, _ = world
    router = _static_router(
        corpus, graph, RouterConfig(overlay_hot_after=10_000)
    )
    # very selective range -> posting
    d = router.route("range", (0.2, 0.205, 0))
    assert d.strategy == POSTING and d.bucket <= 1
    assert d.source == "histogram" and d.est_selectivity < 0.01
    # broad range -> graph (AIRSHIP's home regime)
    d = router.route("range", (0.0, 1.0, 0))
    assert d.strategy == GRAPH and d.bucket == 4
    # the 50% label -> graph regardless of hotness
    d = router.route("label", label_words_row([0], L))
    assert d.strategy == GRAPH
    assert d.label == 0


def test_router_overlay_needs_hotness(world):
    corpus, graph, _ = world
    router = _static_router(corpus, graph, RouterConfig(overlay_hot_after=3))
    labels = np.asarray(corpus.labels)
    rare = int(np.argmin(np.bincount(labels, minlength=L)))
    w = label_words_row([rare], L)
    count = int((labels == rare).sum())
    assert count <= router.config.resolved_posting_cap(N)
    first = [router.route("label", w).strategy for _ in range(2)]
    assert first == [POSTING, POSTING]  # cold label: posting wins the row
    # ... unless posting is inapplicable; here it IS applicable, so overlay
    # only takes over once hot AND preferred by its bucket's lattice row.
    router2 = _static_router(
        corpus, graph,
        RouterConfig(overlay_hot_after=2, posting_cap=1),  # posting gated off
    )
    seq = [router2.route("label", w).strategy for _ in range(4)]
    assert seq[0] == GRAPH  # not hot yet, posting capped out
    assert OVERLAY in seq[1:]  # becomes hot, overlay takes over


def test_router_udf_defaults_to_graph_and_controller_stays_in_lattice(world):
    corpus, graph, _ = world

    class ForcingController:
        def strategy_for(self, key, default):
            return "posting"  # always demand posting

    cfg = RouterConfig()
    router = _static_router(corpus, graph, cfg, ForcingController())
    # no histogram covers a UDF operand -> graph default, no estimate
    d = router.route("udf", object())
    assert d.strategy == GRAPH and d.est_selectivity is None
    assert d.source == "default"
    # controller demands posting, but the >=20% bucket's lattice row is
    # (graph,) — the override must not escape the lattice
    d = router.route("range", (0.0, 1.0, 0))
    assert d.strategy == GRAPH
    # in a bucket whose row allows posting, the override is honoured
    d = router.route("range", (0.2, 0.205, 0))
    assert d.strategy == POSTING


def test_router_epoch_resets_hotness(world):
    corpus, graph, _ = world
    router = _static_router(
        corpus, graph, RouterConfig(overlay_hot_after=2, posting_cap=1)
    )
    labels = np.asarray(corpus.labels)
    rare = int(np.argmin(np.bincount(labels, minlength=L)))
    w = label_words_row([rare], L)
    router.on_epoch(1)
    assert router.route("label", w).strategy == GRAPH  # cold
    assert router.route("label", w).strategy == OVERLAY  # hot now
    router.on_epoch(2)  # epoch swap: hotness resets
    assert router.route("label", w).strategy == GRAPH


def test_controller_strategy_retune_prefers_faster_equal_fill():
    from repro.serving import ControllerConfig

    ctl = AdaptiveController(
        make_tier_ladder(n_tiers=1),
        ControllerConfig(ema_alpha=1.0, min_batches=2),
    )
    key = ("label", 1)
    assert ctl.strategy_for(key, "posting") == "posting"  # no evidence yet
    for _ in range(2):
        ctl.record_strategy(key, "graph", latency=0.010, fill_frac=1.0)
        ctl.record_strategy(key, "posting", latency=0.002, fill_frac=1.0)
    assert ctl.strategy_for(key, "graph") == "posting"  # faster, equal fill
    # a strategy that fills worse never wins on latency alone
    for _ in range(4):
        ctl.record_strategy(key, "posting", latency=0.002, fill_frac=0.4)
        ctl.record_strategy(key, "graph", latency=0.010, fill_frac=1.0)
    assert ctl.strategy_for(key, "posting") == "graph"
    snap = ctl.snapshot()["strategies"]["label@bucket1"]
    assert snap["preferred"] == "graph"
    assert snap["observed"]["posting"]["batches"] == 6


# ---------------------------------------------------------------------------
# overlay lifecycle
# ---------------------------------------------------------------------------


def test_overlay_results_confined_to_posting_set(world):
    corpus, _, queries = world
    labels = np.asarray(corpus.labels)
    lab = 5
    ids = np.nonzero(labels == lab)[0].astype(np.int32)
    ov = build_overlay(lab, ids, np.asarray(corpus.vectors), epoch=7)
    assert ov.epoch == 7 and ov.n_real == ids.shape[0]
    res = overlay_search(ov, queries, _params(ef_result=64, max_iters=128))
    got = np.asarray(res.ids)
    assert np.isin(got[got >= 0], ids).all()
    # a generous budget over a tiny subgraph: near-oracle recall (the walk
    # is approximate — posting scan, not overlay, owns bit-exactness)
    od, oi = exact_constrained_search(corpus, queries, _label_constraint(lab), K)
    oi = np.asarray(oi)
    hits = sum(
        len(set(got[b]) & set(oi[b])) for b in range(oi.shape[0])
    )
    assert hits / oi.size >= 0.9
    # the nearest satisfier is always found
    np.testing.assert_array_equal(got[:, 0], oi[:, 0])


def test_overlay_rejects_singleton_posting_set(world):
    corpus, _, _ = world
    with pytest.raises(ValueError, match=">= 2"):
        build_overlay(0, np.asarray([3], np.int32), np.asarray(corpus.vectors), 0)


def test_serving_never_serves_stale_overlay_under_churn(world):
    """PR-5-style churn interleaved with hot-label queries: every response
    must carry the epoch current at its dispatch, the overlay cache must
    invalidate on each swap, and post-churn results must reflect the
    mutated posting set (a stale overlay would return deleted ids)."""
    corpus, graph, _ = world
    index = StreamingIndex.from_static(corpus, graph, capacity=N + 256)
    executor = StreamingLocalExecutor(index)
    clock = VirtualClock()
    runtime = ServingRuntime(
        executor, n_labels=L, tiers=make_tier_ladder(k_cap=K, n_tiers=1),
        ladder=(4,), families=("label", "range"), max_wait=0.0, clock=clock,
    )
    runtime.router = make_serving_router(
        executor, n_labels=L, config=RouterConfig(overlay_hot_after=1, posting_cap=1),
        controller=runtime.controller,
    )
    labels = np.asarray(corpus.labels)
    rare = int(np.argmin(np.bincount(labels, minlength=L)))
    w = label_words_row([rare], L)
    vectors = np.asarray(corpus.vectors)

    def ask(n=4):
        ids = [runtime.submit(vectors[i], K, "label", w) for i in range(n)]
        runtime.drain()
        return [runtime.poll(r) for r in ids]

    first = ask()
    assert any(r.strategy == "overlay" for r in first)
    epoch0 = executor.epoch
    assert all(r.epoch == epoch0 for r in first if r.strategy == "overlay")

    # churn: delete EVERY current member of the rare label, insert new ones
    rare_ids = index.postings.ids_for_label(rare).tolist()
    for s in rare_ids:
        runtime.submit_delete(int(s))
    rng = np.random.RandomState(5)
    for _ in range(6):
        runtime.submit_upsert(
            rng.randn(D).astype(np.float32), label=rare,
            attrs=rng.rand(2).astype(np.float32),
        )
    runtime.drain()
    assert executor.epoch > epoch0
    inv_before = runtime.overlays.invalidations

    second = ask()
    assert runtime.overlays.invalidations > inv_before  # stale copy rebuilt
    new_set = set(index.postings.ids_for_label(rare).tolist())
    for r in second:
        assert r.epoch == executor.epoch
        returned = set(int(i) for i in r.ids if i >= 0)
        # every returned id is from the POST-churn posting set; any overlap
        # with the deleted pre-churn members would mean a stale overlay
        assert returned <= new_set
        assert not (returned & set(rare_ids))
    index.check_stats_exact()


# ---------------------------------------------------------------------------
# serving integration: parity + telemetry
# ---------------------------------------------------------------------------


def test_routed_responses_match_standalone_strategy_output(world):
    """Acceptance: router-returned ids match the dispatched strategy's
    standalone output bit-for-bit."""
    corpus, graph, queries = world
    index = StreamingIndex.from_static(corpus, graph, capacity=N + 64)
    executor = StreamingLocalExecutor(index)
    tiers = make_tier_ladder(k_cap=K, n_tiers=1)
    runtime = ServingRuntime(
        executor, n_labels=L, tiers=tiers, ladder=(4,),
        families=("label", "range"), max_wait=0.0, clock=VirtualClock(),
    )
    runtime.router = make_serving_router(
        executor, n_labels=L, config=RouterConfig(overlay_hot_after=10_000),
        controller=runtime.controller,
    )
    vectors = np.asarray(queries)
    # a narrow range routes to posting
    operand = (0.2, 0.24, 0)
    ids = [runtime.submit(vectors[i], K, "range", operand) for i in range(4)]
    runtime.drain()
    rs = [runtime.poll(r) for r in ids]
    assert all(r.strategy == "posting" for r in rs)
    snap = executor.snapshot
    constraint = RangeConstraint(
        lo=jnp.full((4,), operand[0], jnp.float32),
        hi=jnp.full((4,), operand[1], jnp.float32),
        col=jnp.int32(0),
    )
    post = runtime.router.range_index.ids_for_range(*operand)
    padded = pad_posting(post, posting_bucket(post.shape[0]))
    standalone = posting_search(
        snap.corpus, queries, constraint, jnp.asarray(padded), tiers[0]
    )
    for i, r in enumerate(rs):
        np.testing.assert_array_equal(
            r.ids, np.asarray(standalone.ids)[i, :K]
        )
    # telemetry satellites: per-strategy counters + summary + controller
    assert runtime.telemetry.counters["routed_posting"] == 4
    summary = runtime.telemetry.summary()
    assert summary["strategies"]["posting"]["n"] == 4
    assert rs[0].est_selectivity is not None
    ctl = runtime.controller.snapshot()
    assert any(k.startswith("range@bucket") for k in ctl["strategies"])
    report = runtime.report()
    assert "overlays" in report
