"""Streaming mutable index: tombstone semantics + consolidation invariants.

The correctness contract of the streaming layer (DESIGN.md §8):

  * a tombstoned id is NEVER returned, by any search mode x constraint
    family x distance backend x fused/unfused combination — deletion masks
    exactly like a failed constraint;
  * every mutation preserves the builder's four adjacency invariants
    (rows distance-ascending, self-free, dup-free, PAD-padded) and
    consolidation restores the slot-pool accounting
    (live + pending + free == capacity, popcount(tombstones) == dead);
  * the serving runtime swaps index epochs atomically at flush boundaries
    (queries in one flush share an epoch; a delete completed before a
    query's arrival is never visible in its results).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    RangeConstraint,
    SearchParams,
    constrained_search,
    equal_constraint,
    pq_train,
)
from repro.data.synthetic import make_labeled_corpus, make_queries
from repro.graph.index import build_index
from repro.streaming import StreamingIndex

N, D, L = 400, 8, 4
PAD = -1


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    corpus = corpus.replace(
        attrs=jax.random.uniform(jax.random.PRNGKey(5), (N, 2))
    )
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=8, sample_size=64)
    q, qlab = make_queries(jax.random.PRNGKey(2), corpus, 6)
    return corpus, graph, q, qlab


@pytest.fixture(scope="module")
def churned(world):
    """One churned index shared by the search-path matrix: delete each
    query's true nearest neighbours (the adversarial case — the walk WILL
    visit them) plus a random slice."""
    corpus, graph, q, qlab = world
    idx = StreamingIndex.from_static(corpus, graph, capacity=N + 64, seed=3)
    params = SearchParams(mode="prefer", k=6, ef_result=32, n_start=16,
                          max_iters=64)
    cons = equal_constraint(qlab, L)
    res = constrained_search(corpus, graph, q, cons, params)
    targets = {int(i) for i in np.asarray(res.ids)[:, :2].ravel() if i >= 0}
    targets |= set(np.random.RandomState(7).choice(N, 40, replace=False).tolist())
    for t in targets:
        assert idx.delete(t)
    idx.pool.check_accounting()
    return idx, targets


def _assert_no_dead(res, dead):
    ids = {int(i) for i in np.asarray(res.ids).ravel() if i >= 0}
    leaked = ids & dead
    assert not leaked, f"tombstoned ids returned: {sorted(leaked)}"


@pytest.mark.parametrize("family", ["label", "range", "udf"])
@pytest.mark.parametrize("backend", ["exact", "kernel", "pq"])
@pytest.mark.parametrize("fuse", ["off", "on"])
def test_no_tombstoned_id_returned(churned, world, family, backend, fuse):
    """The full search matrix: every backend x family x fuse combination
    masks tombstones (deleted ids were each query's true top results, so a
    leak would absolutely surface here)."""
    if family == "udf" and fuse == "on":
        pytest.skip("UDF constraints have no fused path by design")
    corpus, graph, q, qlab = world
    idx, targets = churned
    snap = idx.snapshot()

    if family == "label":
        cons = equal_constraint(qlab, L)
    elif family == "range":
        b = q.shape[0]
        cons = RangeConstraint(
            lo=jnp.zeros((b,), jnp.float32),
            hi=jnp.ones((b,), jnp.float32),
            col=jnp.int32(0),
        )
    else:
        def cons(label, attrs):  # noqa: ANN001 — jnp UDF
            return label >= 0

    pq_index = (
        pq_train(jax.random.PRNGKey(4), snap.corpus.vectors, m_sub=4, n_cent=16)
        if backend == "pq"
        else None
    )
    params = SearchParams(
        mode="prefer", k=6, ef_result=32, n_start=16, max_iters=64,
        use_kernel=backend == "kernel",
        approx="pq" if backend == "pq" else "exact",
        fuse_expand=fuse,
    )
    res = constrained_search(
        snap.corpus, snap.graph, q, cons, params, pq_index=pq_index
    )
    _assert_no_dead(res, targets)


@pytest.mark.parametrize("mode", ["vanilla", "start", "alter", "prefer"])
def test_no_tombstoned_id_any_mode(churned, world, mode):
    corpus, graph, q, qlab = world
    idx, targets = churned
    snap = idx.snapshot()
    params = SearchParams(mode=mode, k=6, ef_result=32, n_start=16, max_iters=64)
    res = constrained_search(
        snap.corpus, snap.graph, q, equal_constraint(qlab, L), params,
        rng=jax.random.PRNGKey(11),
    )
    _assert_no_dead(res, targets)


def test_fused_kernels_honor_tombstones(world):
    """Interpret-mode Pallas kernels == jnp ref with a tombstone bitmap:
    sat is masked for dead candidates, fresh (traversability) is not."""
    from repro.core import visited as vis
    from repro.core.constraints import constraint_tables, tombstone_test
    from repro.kernels.fused_expand.ops import fused_expand, fused_expand_adc
    from repro.kernels.fused_expand.ref import fused_expand_adc_ref, fused_expand_ref

    corpus, graph, q, qlab = world
    rng = np.random.RandomState(0)
    words = np.zeros(((N + 31) // 32,), np.uint32)
    dead = rng.choice(N, 60, replace=False)
    for i in dead:
        words[i // 32] |= np.uint32(1) << np.uint32(i % 32)
    tomb = jnp.asarray(words)
    corpus_t = corpus.replace(tombstones=tomb)

    cons = equal_constraint(qlab, L)
    tables = constraint_tables(cons, corpus_t)
    assert tables.tomb is not None
    ids = jax.random.randint(jax.random.PRNGKey(6), (q.shape[0], 16), -1, N)
    visited = vis.visited_init(q.shape[0], N)

    d_k, s_k, f_k = fused_expand(
        q, corpus.vectors, ids, visited, tables.meta, tables.cons, tables.tomb,
        family="label", force_kernel=True, m_blk=8,
    )
    d_r, s_r, f_r = fused_expand_ref(
        q, corpus.vectors, ids, visited, tables.meta, tables.cons, tables.tomb,
        family="label",
    )
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-6)
    # dead candidates: never satisfied, still traversable when unvisited
    dead_mask = np.asarray(tombstone_test(tomb, ids))
    assert not np.any(np.asarray(s_k) & dead_mask)
    valid = np.asarray(ids) >= 0
    assert np.array_equal(np.asarray(f_k).astype(bool), valid)

    pq_index = pq_train(jax.random.PRNGKey(4), corpus.vectors, m_sub=4, n_cent=16)
    from repro.core.pq import adc_table

    lut = adc_table(pq_index, q)
    d_k, s_k, f_k = fused_expand_adc(
        lut, pq_index.codes, ids, visited, tables.meta, tables.cons,
        tables.tomb, family="label", force_kernel=True, m_blk=8,
    )
    d_r, s_r, f_r = fused_expand_adc_ref(
        lut, pq_index.codes, ids, visited, tables.meta, tables.cons,
        tables.tomb, family="label",
    )
    np.testing.assert_array_equal(np.asarray(s_k), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(f_k), np.asarray(f_r))
    np.testing.assert_allclose(np.asarray(d_k), np.asarray(d_r), rtol=1e-6)
    assert not np.any(np.asarray(s_k) & dead_mask)


def _check_adjacency_invariants(idx):
    nbrs = idx.neighbors
    vecs = idx.pool.vectors
    for u in range(idx.capacity):
        row = nbrs[u]
        live_e = row[row >= 0]
        # dup-free
        assert len(set(live_e.tolist())) == len(live_e), f"dup in row {u}"
        # self-free
        assert u not in live_e, f"self edge in row {u}"
        # PAD only at the tail
        pad_pos = np.nonzero(row < 0)[0]
        if pad_pos.size:
            assert (row[pad_pos[0]:] < 0).all(), f"PAD not tail in row {u}"
        # distance-ascending
        if live_e.size > 1:
            d = np.sum((vecs[live_e] - vecs[u]) ** 2, axis=-1)
            assert (np.diff(d) >= -1e-5).all(), f"row {u} not ascending"


def test_consolidation_invariants_and_accounting(world):
    corpus, graph, q, qlab = world
    idx = StreamingIndex.from_static(corpus, graph, capacity=N + 80, seed=5)
    rng = np.random.RandomState(1)
    base = np.asarray(corpus.vectors)
    inserted = []
    for i in range(30):
        p = rng.randint(N)
        slot = idx.insert(
            base[p] + rng.randn(D).astype(np.float32) * 0.05,
            label=int(np.asarray(corpus.labels)[p]),
            attrs=rng.rand(2).astype(np.float32),
        )
        inserted.append(slot)
    victims = rng.choice(N, 50, replace=False).tolist() + inserted[:5]
    for v in victims:
        assert idx.delete(int(v))
    assert idx.delete(int(victims[0])) is False  # idempotent
    idx.pool.check_accounting()
    assert idx.pool.n_pending == len(victims)

    n_done = idx.consolidate()
    assert n_done == len(victims)
    assert idx.pool.n_pending == 0
    idx.pool.check_accounting()  # live + pending + free == capacity restored
    _check_adjacency_invariants(idx)

    # no edges point at reclaimed (free) slots, and seeds are live
    freed = set(idx.pool.free)
    referenced = set(idx.neighbors[idx.neighbors >= 0].ravel().tolist())
    assert not (referenced & freed)
    assert idx.pool.is_live(idx.entry_point)
    live = set(idx.pool.live_ids().tolist())
    assert set(idx.sample_ids.tolist()) <= live


def test_insert_is_reachable_and_reuses_slots(world):
    corpus, graph, q, qlab = world
    idx = StreamingIndex.from_static(corpus, graph, capacity=N + 16, seed=9)
    rng = np.random.RandomState(2)
    base = np.asarray(corpus.vectors)

    # fill the pool, delete some, consolidate, insert again -> slots reuse
    first = [
        idx.insert(base[i] + 0.01, label=int(np.asarray(corpus.labels)[i]))
        for i in range(10)
    ]
    for s in first[:6]:
        idx.delete(s)
    idx.consolidate()
    freed = set(first[:6])
    again = [
        idx.insert(base[i] - 0.01, label=int(np.asarray(corpus.labels)[i]))
        for i in range(6)
    ]
    assert set(again) <= freed  # LIFO pool hands the reclaimed slots back
    _check_adjacency_invariants(idx)

    # a fresh insert is findable by an equal-label search for itself
    p = rng.randint(N)
    vec = base[p] + rng.randn(D).astype(np.float32) * 0.02
    lab = int(np.asarray(corpus.labels)[p])
    slot = idx.insert(vec, label=lab)
    snap = idx.snapshot()
    params = SearchParams(mode="prefer", k=4, ef_result=32, n_start=16,
                          max_iters=64)
    res = constrained_search(
        snap.corpus, snap.graph, jnp.asarray(vec[None]),
        equal_constraint(jnp.asarray([lab]), L), params,
    )
    assert slot in set(np.asarray(res.ids)[0].tolist())


def test_serving_epoch_swap_and_mutation_flow(world):
    from repro.serving import (
        ServingRuntime,
        StreamingLocalExecutor,
        VirtualClock,
        label_words_row,
        make_tier_ladder,
    )

    corpus, graph, q, qlab = world
    idx = StreamingIndex.from_static(corpus, graph, capacity=N + 64, seed=13)
    executor = StreamingLocalExecutor(idx, consolidate_after=8)
    clock = VirtualClock()
    rt = ServingRuntime(
        executor, n_labels=L,
        tiers=make_tier_ladder(k_cap=6, base_ef=32, base_iters=48,
                               base_n_start=8, growth=4),
        ladder=(4,), max_wait=0.001, clock=clock,
    )
    qv = np.asarray(q)[0]
    operand = label_words_row(list(range(L)), L)  # match-all label mask

    # epoch swap is atomic at the flush boundary: a query and a delete in
    # the same flush -> the query runs AFTER the swap, never mid-mutation
    r1 = rt.submit(qv, 6, "label", operand)
    clock.advance(0.01)
    rt.step(force=True)
    resp1 = rt.poll(r1)
    assert resp1 is not None and resp1.epoch == executor.epoch

    victim = int(resp1.ids[0])
    d1 = rt.submit_delete(victim)
    r2 = rt.submit(qv, 6, "label", operand)
    clock.advance(0.01)
    rt.step(force=True)
    dresp = rt.poll(d1)
    resp2 = rt.poll(r2)
    assert dresp is not None and dresp.filled == 1
    assert resp2 is not None and resp2.epoch > resp1.epoch
    assert victim not in set(resp2.ids.tolist())

    # upsert returns the assigned slot; the new vertex is immediately
    # findable by the next flush's queries
    u1 = rt.submit_upsert(qv, label=int(np.asarray(qlab)[0]))
    clock.advance(0.01)
    rt.step(force=True)
    uresp = rt.poll(u1)
    assert uresp is not None and uresp.filled == 1
    slot = int(uresp.ids[0])
    assert idx.pool.is_live(slot)
    r3 = rt.submit(qv, 6, "label", operand)
    clock.advance(0.01)
    rt.step(force=True)
    resp3 = rt.poll(r3)
    assert slot in set(resp3.ids.tolist())

    # double delete is idempotent (filled == 0), and the trace budget is
    # untouched by mutation traffic
    d2 = rt.submit_delete(victim)
    clock.advance(0.01)
    rt.step(force=True)
    assert rt.poll(d2).filled == 0
    assert rt.cache.trace_count <= rt.trace_budget
    tel = rt.telemetry.counters
    assert tel["upserts_applied"] == 1 and tel["deletes_applied"] == 2
    assert tel["epoch_swaps"] >= 2


def test_mutations_require_streaming_executor(world):
    from repro.serving import LocalExecutor, ServingRuntime, VirtualClock

    corpus, graph, q, qlab = world
    rt = ServingRuntime(
        LocalExecutor(corpus, graph), n_labels=L, ladder=(4,),
        clock=VirtualClock(),
    )
    with pytest.raises(TypeError, match="streaming executor"):
        rt.submit_upsert(np.asarray(q)[0])
    with pytest.raises(TypeError, match="streaming executor"):
        rt.submit_delete(0)
