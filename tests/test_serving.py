"""Serving runtime (DESIGN.md §7): batcher edge cases, compile-cache trace
budget under adversarial streams, under-fill escalation, backpressure, and
the controller's within-ladder retuning.

The trace-budget test asserts against the executor's *actual* jit trace
count (the traced impl body increments a host counter), not just the
cache's bookkeeping — a retrace bug would diverge the two.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.types import SearchParams, SearchResult, SearchStats
from repro.data.synthetic import make_labeled_corpus
from repro.graph.index import build_index
from repro.serving import (
    AdaptiveController,
    AdmissionError,
    CompileCache,
    ControllerConfig,
    DynamicBatcher,
    LocalExecutor,
    Request,
    ServingRuntime,
    TraceBudgetError,
    VirtualClock,
    label_words_row,
)

N, D, L = 1500, 16, 5


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    corpus = corpus.replace(
        attrs=jax.random.uniform(jax.random.PRNGKey(50), (N, 2))
    )
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=12, sample_size=128)
    return corpus, graph


def _req(i, family="label", k=4, deadline=None, operand=None):
    if operand is None:
        operand = (
            label_words_row([i % L], L) if family == "label" else (0.2, 0.8, 0)
        )
    return Request(
        req_id=i, query=np.zeros((D,), np.float32), k=k, family=family,
        operand=operand, deadline=deadline,
    )


def _tiers(k_cap, base_ef, base_iters, n_start=4, growth=4, n_tiers=2):
    out = []
    for t in range(n_tiers):
        g = growth**t
        ef = max(base_ef * g, k_cap)
        out.append(SearchParams(
            mode="prefer", k=k_cap, ef_result=ef, ef_sat=ef, ef_other=ef,
            n_start=n_start * g, max_iters=base_iters * g,
        ))
    return tuple(out)


# ---------------------------------------------------------------------------
# batcher
# ---------------------------------------------------------------------------


def test_batcher_empty_flush_and_timeout():
    b = DynamicBatcher(ladder=(4, 16), max_wait=0.01)
    assert b.flush(0.0) == []  # empty flush on (any) timeout: no-op, no crash
    for i in range(3):
        b.add(_req(i), now=0.0)
    assert b.flush(0.005) == []  # younger than max_wait, below any bucket
    out = b.flush(0.011)
    assert len(out) == 1 and out[0].bucket == 4
    assert out[0].n_real == 3 and out[0].n_padded == 1
    assert b.pending_count() == 0
    assert b.flush(0.012) == []  # drained group leaves no stale timer


def test_batcher_full_bucket_ships_without_timeout():
    b = DynamicBatcher(ladder=(4, 16), max_wait=10.0)
    for i in range(17):
        b.add(_req(i), now=0.0)
    out = b.flush(0.0)  # no timeout elapsed: only the full top bucket ships
    assert [mb.bucket for mb in out] == [16]
    assert out[0].n_padded == 0
    assert b.pending_count() == 1
    out = b.flush(0.0, force=True)
    assert [mb.bucket for mb in out] == [4] and out[0].n_real == 1


def test_batcher_greedy_ladder_packing_pads_only_tail():
    b = DynamicBatcher(ladder=(4, 16), max_wait=0.001)
    for i in range(11):
        b.add(_req(i), now=0.0)
    out = b.flush(1.0)
    assert [mb.bucket for mb in out] == [4, 4, 4]
    assert sum(mb.n_padded for mb in out) == 1  # only the final partial pads


def test_batcher_deadline_forces_early_flush():
    b = DynamicBatcher(ladder=(4,), max_wait=10.0)
    b.add(_req(0, deadline=0.001), now=0.0)
    assert b.flush(0.0005) == []
    out = b.flush(0.002)  # deadline reached long before max_wait
    assert len(out) == 1 and out[0].n_real == 1


def test_batcher_separates_incompatible_groups():
    b = DynamicBatcher(ladder=(4,), max_wait=0.0)
    b.add(_req(0, family="label"), now=0.0)
    b.add(_req(1, family="range", operand=(0.1, 0.9, 0)), now=0.0)
    b.add(_req(2, family="range", operand=(0.1, 0.9, 1)), now=0.0)  # other col
    out = b.flush(0.0)
    # label, range@col0, range@col1 cannot share a traced operand batch
    assert sorted(mb.group for mb in out) == [
        ("label",), ("range", 0), ("range", 1)
    ]


# ---------------------------------------------------------------------------
# compile cache
# ---------------------------------------------------------------------------


def test_compile_cache_counts_and_enforces_budget():
    built = []
    cache = CompileCache(lambda key: built.append(key) or (lambda: key), 2)
    assert cache.get("a")() == "a"
    assert cache.get("a")() == "a"
    assert cache.get("b")() == "b"
    assert (cache.hits, cache.misses, cache.trace_count) == (1, 2, 2)
    with pytest.raises(TraceBudgetError, match="budget"):
        cache.get("c")
    assert built == ["a", "b"]


# ---------------------------------------------------------------------------
# runtime: trace budget under an adversarial stream
# ---------------------------------------------------------------------------


def test_adversarial_interleave_stays_within_trace_budget(world):
    """A stream whose constraint families interleave adversarially (family
    alternating per request, mixed k, ragged counts, multiple rounds) can
    reach every (bucket, family, tier) combination but never exceed the
    ladder product — asserted against actual jit traces."""
    corpus, graph = world
    executor = LocalExecutor(corpus, graph)
    clock = VirtualClock()
    runtime = ServingRuntime(
        executor, n_labels=L, tiers=_tiers(4, 8, 16), ladder=(2, 4),
        families=("label", "range"), max_wait=0.005, clock=clock,
    )
    budget = 2 * 2 * 2  # |ladder| x |families| x |tiers|
    assert runtime.trace_budget == budget
    rng = np.random.RandomState(3)
    vectors = np.asarray(corpus.vectors)
    for rnd in range(4):
        for i in range(5 + rnd):  # ragged per-round counts: odd tails pad
            family = "label" if (i + rnd) % 2 == 0 else "range"
            operand = (
                label_words_row([int(rng.randint(L))], L)
                if family == "label"
                else (0.1, 0.9, 0)
            )
            runtime.submit(
                vectors[rng.randint(N)], int(rng.choice([2, 3, 4])),
                family, operand,
            )
            clock.advance(0.001)
            runtime.step()
        runtime.drain()
    assert runtime.in_flight == 0
    assert runtime.cache.trace_count <= budget
    # the cache's bookkeeping matches jax reality: no hidden retraces
    assert executor.traces == runtime.cache.trace_count
    assert runtime.cache.hits > 0


# ---------------------------------------------------------------------------
# runtime: under-fill escalation
# ---------------------------------------------------------------------------


def test_underfill_escalation_rereuns_at_higher_ef(world):
    """Tier 0 is starved (ef=8, 4 iterations) so selective constraints
    under-fill; escalation must re-run them at the bigger-ef tier and
    return at least as many filled slots — never silently return padding
    while a bigger tier exists."""
    corpus, graph = world
    tiers = _tiers(8, 8, 4, n_start=2, growth=16)  # tier1: ef=128, 64 iters
    runtime = ServingRuntime(
        LocalExecutor(corpus, graph), n_labels=L, tiers=tiers, ladder=(4,),
        families=("range",), max_wait=0.0, clock=VirtualClock(),
    )
    vectors = np.asarray(corpus.vectors)
    attrs = np.asarray(corpus.attrs)
    ids = []
    for i in range(8):
        center = float(attrs[i, 0])
        # ~5% selective window around the query's own attribute value
        ids.append(runtime.submit(
            vectors[i], 8, "range", (center - 0.04, center + 0.04, 0)
        ))
    runtime.drain()
    responses = [runtime.poll(rid) for rid in ids]
    assert all(r is not None for r in responses)
    escalated = [r for r in responses if r.escalations > 0]
    assert escalated, "starved tier 0 should have under-filled something"
    for r in escalated:
        assert r.tier == 1  # final answer came from the bigger-ef tier
        assert len(r.fill_history) == r.escalations + 1
        # the retry returned at least as many filled slots as the first try
        assert r.filled >= r.fill_history[0]
    # escalation materially fixed at least one under-fill
    assert any(r.filled > r.fill_history[0] for r in escalated)


# ---------------------------------------------------------------------------
# runtime: backpressure
# ---------------------------------------------------------------------------


def test_bounded_admission_queue_backpressure(world):
    corpus, graph = world
    runtime = ServingRuntime(
        LocalExecutor(corpus, graph), n_labels=L, tiers=_tiers(4, 8, 16),
        ladder=(4,), families=("label",), max_wait=0.0,
        max_pending=3, clock=VirtualClock(),
    )
    vectors = np.asarray(corpus.vectors)
    ids = [runtime.submit(vectors[i], 4, "label", label_words_row([0], L))
           for i in range(3)]
    with pytest.raises(AdmissionError):
        runtime.submit(vectors[3], 4, "label", label_words_row([0], L))
    assert runtime.telemetry.counters["rejected"] == 1
    runtime.drain()
    assert all(runtime.poll(rid) is not None for rid in ids)
    # capacity freed: admission works again
    runtime.submit(vectors[4], 4, "label", label_words_row([0], L))
    runtime.drain()


# ---------------------------------------------------------------------------
# controller + SearchResult.filled helper
# ---------------------------------------------------------------------------


def test_controller_retunes_only_within_ladder():
    tiers = _tiers(8, 16, 32)
    ctl = AdaptiveController(
        tiers, ControllerConfig(ema_alpha=1.0, min_batches=2)
    )
    assert ctl.tier_for("label") == 0
    for _ in range(2):  # persistent under-fill at the default tier
        ctl.record("label", 0, fill_frac=0.5, mean_iters=32.0)
    assert ctl.tier_for("label") == 1  # promoted
    for _ in range(2):  # full results with lots of iteration headroom
        ctl.record("label", 1, fill_frac=1.0, mean_iters=4.0)
    assert ctl.tier_for("label") == 0  # demoted back
    # escalation never leaves the declared ladder
    req = _req(0)
    req.tier = len(tiers) - 1
    assert ctl.escalate(req) is None


def test_search_result_filled_helper():
    ids = jnp.asarray([[0, 5, -1, -1], [-1, -1, -1, -1], [3, 2, 1, 7]])
    res = SearchResult(
        dists=jnp.zeros((3, 4)), ids=ids,
        stats=SearchStats(
            dist_evals=jnp.zeros((3,), jnp.int32),
            hops=jnp.zeros((3,), jnp.int32),
            visited=jnp.zeros((3,), jnp.int32),
            iters=jnp.int32(0),
        ),
    )
    np.testing.assert_array_equal(np.asarray(res.filled), [2, 0, 4])


# ---------------------------------------------------------------------------
# replay backpressure accounting (PR 7 satellite: these paths predate the
# client retry policy and must stay exact underneath it)
# ---------------------------------------------------------------------------


def test_replay_rejections_stay_aligned_and_counted(world):
    corpus, graph = world
    from repro.serving import mixed_workload, replay_poisson

    runtime = ServingRuntime(
        LocalExecutor(corpus, graph), n_labels=L, tiers=_tiers(4, 8, 16),
        ladder=(4,), families=("label", "range"), max_wait=0.05,
        max_pending=2, clock=VirtualClock(),
    )
    items = mixed_workload(3, corpus, 12, L, k_choices=(4,))
    # rate >> service rate with max_pending=2: most submits must bounce
    responses, rejected = replay_poisson(runtime, items, rate=1e9, seed=1)
    assert rejected > 0
    assert len(responses) == len(items)  # alignment survives rejections
    assert sum(r is None for r in responses) == rejected
    assert runtime.telemetry.counters["rejected"] == rejected
    served = [r for r in responses if r is not None]
    assert runtime.telemetry.counters["completed"] == len(served)
    assert runtime.in_flight == 0


def test_churn_replay_shed_delete_keeps_id_live(world):
    corpus, graph = world
    from repro.serving import StreamingLocalExecutor, WorkItem, replay_churn
    from repro.streaming import StreamingIndex

    index = StreamingIndex.from_static(corpus, graph, capacity=N + 8)
    n_live_before = index.pool.n_live
    runtime = ServingRuntime(
        StreamingLocalExecutor(index, consolidate_after=1000), n_labels=L,
        tiers=_tiers(4, 8, 16), ladder=(4,), families=("label",),
        max_wait=10.0, max_pending=1, clock=VirtualClock(),
    )
    # One query wedges the single admission slot (max_wait holds it
    # batched); both deletes then bounce off backpressure. If the shed
    # delete LEAKED its popped id, the second delete would find the live
    # set empty and be skipped (not rejected) — the counts distinguish it.
    items = [
        WorkItem(np.zeros((D,), np.float32), 4, "label",
                 label_words_row([0], L), "equal"),
        WorkItem(np.zeros((0,), np.float32), 1, "delete", None, "delete"),
        WorkItem(np.zeros((0,), np.float32), 1, "delete", None, "delete"),
    ]
    responses, rejected = replay_churn(
        runtime, items, rate=1e9, seed=1, initial_live=[5]
    )
    assert rejected == 2  # the restored id made the second delete A REAL TRY
    assert responses[1] is None and responses[2] is None
    assert responses[0] is not None  # the wedged query completed at drain
    assert index.pool.n_live == n_live_before  # nothing was deleted
    assert runtime.telemetry.counters["deletes_applied"] == 0
