"""Roofline-model validation.

1. Demonstrates the XLA artifact the analytic model exists to correct:
   cost_analysis counts a while/scan body once, independent of trip count.
2. Cross-checks the analytic LM FLOPs against cost_analysis on a
   single-layer (loop-light) config, where the two must agree.
"""
import jax
import jax.numpy as jnp

from repro.common.compat import cost_analysis_dict
from repro.distributed.meshinfo import single_device_meshinfo
from repro.models.transformer.model import TransformerConfig, forward_hidden, init_params
from repro.roofline.model import (
    RooflineTerms,
    _lm_matmul_params,
    lm_prefill_terms,
)

MI = single_device_meshinfo()


def test_xla_cost_analysis_undercounts_scans():
    def f_scan(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, None, length=10)[0]

    def f_once(x, w):
        return jnp.tanh(x @ w)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    f10 = cost_analysis_dict(jax.jit(f_scan).lower(x, w).compile())["flops"]
    f1 = cost_analysis_dict(jax.jit(f_once).lower(x, w).compile())["flops"]
    # the artifact: 10 iterations counted ~once (tiny loop-counter ops only)
    assert f10 < 1.5 * f1


def test_analytic_lm_flops_matches_measured_single_layer():
    cfg = TransformerConfig(
        name="probe", n_layers=1, d_model=128, n_heads=4, n_kv_heads=4,
        head_dim=32, d_ff=256, vocab_size=512, attn_type="gqa",
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
        attn_chunk=64, ce_chunk=64, remat="none", sequence_parallel=False,
    )
    b, s = 2, 64
    params = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    toks = jax.ShapeDtypeStruct((b, s), jnp.int32)

    def fwd(p, t):
        h = forward_hidden(p, cfg, MI, t)
        return (h[:, -1] @ p["lm_head"]["w"]).astype(jnp.float32)

    measured = cost_analysis_dict(jax.jit(fwd).lower(params, toks).compile())["flops"]
    f, _, _, mf = lm_prefill_terms(cfg, b, s, chips=1)
    # last-position logits only in the probe; analytic assumes full-seq CE.
    # Compare the dominant matmul component instead.
    _, active = _lm_matmul_params(cfg)
    analytic_core = 2.0 * (active - 2 * cfg.d_model * cfg.vocab_padded) * b * s
    assert measured > 0
    ratio = analytic_core / measured
    assert 0.5 < ratio < 1.6, (analytic_core, measured)


def test_roofline_terms_math():
    t = RooflineTerms(
        cell="x", mesh="m", chips=256,
        flops=256 * 197e12,  # exactly 1 second of compute
        hbm_bytes=256 * 819e9 * 0.5,
        coll_bytes=50e9 * 0.25,
        model_flops=256 * 197e12 * 0.8,
    )
    assert abs(t.t_compute - 1.0) < 1e-9
    assert abs(t.t_memory - 0.5) < 1e-9
    assert abs(t.t_collective - 0.25) < 1e-9
    assert t.bottleneck == "compute"
    assert abs(t.roofline_fraction - 0.8) < 1e-9


def test_param_count_consistency_with_analytic():
    """Analytic matmul-param count tracks eval_shape param count."""
    from repro.archs.base import get_arch

    cfg = get_arch("granite-3-2b").cfg
    total, active = _lm_matmul_params(cfg)
    n = cfg.param_count()
    assert total == active  # dense model
    assert abs(total - n) / n < 0.02  # norms are the only non-matmul params
