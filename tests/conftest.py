import os

# Tests run single-device (the dry-run sets 512 host devices itself, in its
# own process). Keep XLA from grabbing a fat thread pool on the 1-core host.
os.environ.setdefault("XLA_FLAGS", "")
