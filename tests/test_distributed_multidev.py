"""Multi-device semantics tests (8 virtual host devices via a subprocess —
device count is locked at first jax init, so these cannot run in-process).

Checks:
  * distributed scatter-search-merge == global exact search agreement
  * elastic checkpoint restore onto a different mesh
  * compressed gradient all-reduce == uncompressed within tolerance
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.core import (SearchParams, equal_constraint, exact_constrained_search,
                            make_distributed_search, recall, shard_corpus_for_mesh)
    from repro.core.types import Corpus
    from repro.common.compat import set_mesh, shard_map
    from repro.data.synthetic import make_labeled_corpus, make_queries
    from repro.graph.index import build_partitioned_index

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=2000, d=16, n_labels=5)
    corpus_p, graph_p = build_partitioned_index(
        jax.random.PRNGKey(1), corpus, n_shards=4, degree=12, sample_size_per_shard=64)
    q, qlab = make_queries(jax.random.PRNGKey(2), corpus, 16)
    cons = equal_constraint(qlab, 5)

    params = SearchParams(mode="prefer", k=10, ef_result=64, ef_sat=64,
                          ef_other=64, n_start=8, max_iters=300)
    search = make_distributed_search(mesh, params)
    corpus_s, graph_s = shard_corpus_for_mesh(corpus_p, graph_p, mesh)
    with set_mesh(mesh):
        res = search(corpus_s, graph_s, q, cons)
    td, ti = exact_constrained_search(corpus_p, q, cons, k=10)
    r = float(recall(res.ids, ti))
    print("DIST_RECALL", r)
    assert r > 0.8, r
    # global ids must be valid and satisfy the constraint
    ids = np.asarray(res.ids)
    labs = np.asarray(corpus_p.labels)[np.maximum(ids, 0)]
    ok = (labs == np.asarray(qlab)[:, None]) | (ids < 0)
    assert ok.all()

    # --- elastic checkpoint: save from 8-dev sharded state, restore on 2x2 ---
    from repro.ckpt import checkpoint as ck
    tree = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                                 NamedSharding(mesh, P("data", "model")))}
    d = "/tmp/elastic_ckpt_test"
    ck.save(d, 3, tree)
    mesh2 = jax.make_mesh((4, 2), ("data", "model"))
    sh2 = {"w": NamedSharding(mesh2, P("model", "data"))}
    like = {"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    restored = ck.restore(d, 3, like, shardings=sh2)
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.arange(64.0).reshape(8, 8))
    print("ELASTIC_OK")

    # --- compressed gradient psum vs exact ---
    from repro.train.compression import compressed_tree_psum_mean
    import functools
    mesh1d = jax.make_mesh((8,), ("dp",))
    g = {"w": jax.random.normal(jax.random.PRNGKey(3), (8, 32))}
    def local(gl):
        red, err = compressed_tree_psum_mean(gl, "dp")
        exact = jax.tree.map(lambda x: jax.lax.pmean(x, "dp"), gl)
        return red, exact
    f = shard_map(local, mesh=mesh1d, in_specs=({"w": P("dp")},),
                  out_specs=({"w": P()}, {"w": P()}))
    red, exact = f(g)
    rel = float(jnp.max(jnp.abs(red["w"] - exact["w"])) /
                (jnp.max(jnp.abs(exact["w"])) + 1e-9))
    print("COMPRESS_RELERR", rel)
    assert rel < 0.02, rel

    # --- PQ distributed search (D4) on 4 corpus shards: the backend payload
    # (codes row-sharded, codebooks replicated) is derived from params.approx;
    # each shard builds its own TraversalContext (PR3) ---
    import dataclasses
    from repro.core import pq_train
    from repro.core.distributed import make_distributed_search as mds
    pq = pq_train(jax.random.PRNGKey(11), corpus_p.vectors, m_sub=4, n_cent=32)
    params_pq = dataclasses.replace(params, approx="pq")
    search_pq = mds(mesh, params_pq)
    with set_mesh(mesh):
        res_pq = search_pq(corpus_s, graph_s, q, cons, pq)
    r_pq = float(recall(res_pq.ids, ti))
    print("DIST_PQ_RECALL", r_pq)
    assert r_pq > 0.7, r_pq
    # fused ADC traversal is bit-identical through the sharded path too
    search_pqf = mds(mesh, dataclasses.replace(params_pq, fuse_expand="on"))
    with set_mesh(mesh):
        res_pqf = search_pqf(corpus_s, graph_s, q, cons, pq)
    np.testing.assert_array_equal(np.asarray(res_pq.ids), np.asarray(res_pqf.ids))
    np.testing.assert_array_equal(np.asarray(res_pq.dists), np.asarray(res_pqf.dists))
    print("DIST_PQ_FUSED_OK")

    # --- Range constraint through the sharded path (PR3 regression: attrs
    # shard with the corpus rows; [lo, hi] shards with the batch) ---
    from repro.core import RangeConstraint
    corpus_a = Corpus(vectors=corpus.vectors, labels=corpus.labels,
                      attrs=jax.random.uniform(jax.random.PRNGKey(20), (2000, 2)))
    corpus_ap, graph_ap = build_partitioned_index(
        jax.random.PRNGKey(1), corpus_a, n_shards=4, degree=12,
        sample_size_per_shard=64)
    assert corpus_ap.attrs is not None  # build_partitioned_index carries attrs
    corpus_as, graph_as = shard_corpus_for_mesh(corpus_ap, graph_ap, mesh)
    assert corpus_as.attrs is not None  # shard_corpus_for_mesh keeps them
    rcons = RangeConstraint(lo=jnp.full((16,), 0.25), hi=jnp.full((16,), 0.85),
                            col=jnp.int32(1))
    search_rng = mds(mesh, params, constraint_type=RangeConstraint)
    with set_mesh(mesh):
        res_rng = search_rng(corpus_as, graph_as, q, rcons)
    ids_r = np.asarray(res_rng.ids)
    vals = np.asarray(corpus_ap.attrs)[np.maximum(ids_r, 0), 1]
    assert (((vals >= 0.25) & (vals <= 0.85)) | (ids_r < 0)).all()
    td_r, ti_r = exact_constrained_search(corpus_ap, q, rcons, k=10)
    r_rng = float(recall(res_rng.ids, ti_r))
    print("DIST_RANGE_RECALL", r_rng)
    assert r_rng > 0.8, r_rng

    # --- two-phase top-k == single-phase on a sharded candidate matrix ---
    from repro.models.recsys import models as rs
    from repro.distributed.meshinfo import MeshInfo
    mi = MeshInfo(mesh=mesh)
    cfg_tt = rs.RecsysConfig(name="tt", model="two_tower", embed_dim=16,
                             tower_mlp=(32, 8), item_vocab=512, user_vocab=256,
                             hist_len=4)
    p_tt = rs.two_tower_init(jax.random.PRNGKey(5), cfg_tt)
    batch_tt = dict(
        user_id=jax.random.randint(jax.random.PRNGKey(6), (8,), 0, 256),
        hist=jax.random.randint(jax.random.PRNGKey(7), (8, 4), -1, 512),
        candidates=jax.random.normal(jax.random.PRNGKey(8), (512, 8)),
    )
    with set_mesh(mesh):
        t1, i1 = jax.jit(lambda p, b: rs.two_tower_score_candidates(
            p, cfg_tt, mi, b, two_phase_topk=False))(p_tt, batch_tt)
        t2, i2 = jax.jit(lambda p, b: rs.two_tower_score_candidates(
            p, cfg_tt, mi, b, two_phase_topk=True))(p_tt, batch_tt)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), rtol=1e-5, atol=1e-6)
    print("TWO_PHASE_TOPK_OK")
    print("ALL_MULTIDEV_OK")
    """
)


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ALL_MULTIDEV_OK" in proc.stdout
