"""Engine-refactor regression suite: queue_pop_n + beam-parallel traversal.

The golden file ``tests/golden/seed_search_outputs.npz`` was produced by the
pre-refactor (seed) ``constrained_search`` on the synthetic corpus — the
engine at ``beam_width=1`` must reproduce it bit-for-bit (ids, dists, and
every stats counter) for all four modes under both constraint families.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SearchParams,
    constrained_search,
    equal_constraint,
    exact_constrained_search,
    recall,
    unequal_pct_constraint,
)
from repro.core import queue as q
from repro.core.engine import mask_first_occurrence
from repro.data.synthetic import make_labeled_corpus, make_queries
from repro.graph.index import build_index

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "seed_search_outputs.npz")

# ---------------------------------------------------------------------------
# queue_pop_n properties
# ---------------------------------------------------------------------------


def _filled_queue(rows):
    """Build a (len(rows), cap) queue from per-row value lists."""
    cap = 8
    qq = q.queue_init(len(rows), cap)
    width = max(len(r) for r in rows)
    d = np.full((len(rows), width), np.inf, np.float32)
    i = np.full((len(rows), width), -1, np.int32)
    v = np.zeros((len(rows), width), bool)
    for r, vals in enumerate(rows):
        d[r, : len(vals)] = vals
        i[r, : len(vals)] = np.arange(100 * r, 100 * r + len(vals))
        v[r, : len(vals)] = True
    return q.queue_push(qq, jnp.asarray(d), jnp.asarray(i), jnp.asarray(v))


def test_pop_n_empty_queue_reports_padding():
    qq = q.queue_init(3, 8)
    new, d, i = q.queue_pop_n(qq, 4, jnp.ones((3,), bool))
    assert d.shape == (3, 4) and i.shape == (3, 4)
    assert np.all(np.isinf(np.asarray(d)))
    assert np.all(np.asarray(i) == -1)
    np.testing.assert_array_equal(np.asarray(new.dists), np.asarray(qq.dists))


def test_pop_n_more_than_live_entries():
    qq = _filled_queue([[3.0, 1.0], [5.0]])
    new, d, i = q.queue_pop_n(qq, 4, jnp.ones((2,), bool))
    np.testing.assert_allclose(np.asarray(d[0]), [1.0, 3.0, np.inf, np.inf])
    np.testing.assert_allclose(np.asarray(d[1]), [5.0, np.inf, np.inf, np.inf])
    assert int(q.queue_size(new)[0]) == 0 and int(q.queue_size(new)[1]) == 0


def test_pop_n_masked_rows_pop_nothing():
    qq = _filled_queue([[1.0, 2.0, 3.0], [4.0, 5.0, 6.0]])
    new, d, i = q.queue_pop_n(qq, 2, jnp.asarray([True, False]))
    # both rows still REPORT their best 2 — callers mask on do_pop
    np.testing.assert_allclose(np.asarray(d), [[1.0, 2.0], [4.0, 5.0]])
    assert float(new.dists[0, 0]) == 3.0  # popped
    np.testing.assert_allclose(np.asarray(new.dists[1, :3]), [4.0, 5.0, 6.0])


def test_pop_n_ascending_and_matches_sequential_pops():
    qq = _filled_queue([[7.0, 2.0, 9.0, 4.0, 1.0], [3.0, 8.0, 0.5, 6.0]])
    live = jnp.ones((2,), bool)
    new_n, d_n, i_n = q.queue_pop_n(qq, 3, live)
    seq = qq
    for j in range(3):
        seq, d1, i1 = q.queue_pop(seq, live)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d_n[:, j]))
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i_n[:, j]))
    np.testing.assert_array_equal(np.asarray(seq.dists), np.asarray(new_n.dists))
    np.testing.assert_array_equal(np.asarray(seq.ids), np.asarray(new_n.ids))
    d = np.asarray(d_n)
    assert np.all(np.diff(d, axis=-1) >= 0)  # beam pops come out ascending


def test_pop_n_at_and_beyond_capacity():
    qq = _filled_queue([[1.0, 2.0, 3.0]])
    for n in (8, 11):  # == capacity, > capacity
        new, d, i = q.queue_pop_n(qq, n, jnp.ones((1,), bool))
        assert d.shape == (1, n)
        np.testing.assert_allclose(np.asarray(d[0, :3]), [1.0, 2.0, 3.0])
        assert np.all(np.isinf(np.asarray(d[0, 3:])))
        assert int(q.queue_size(new)[0]) == 0


def test_short_frontier_mid_beam_does_not_terminate_single_queue():
    """A frontier holding fewer than beam_width live entries (all below the
    threshold) must NOT mark the query done — this iteration's expansion
    refills it, and only a genuine threshold crossing is sticky."""
    from repro.core.engine import pop_frontier_beam

    oth = _filled_queue([[1.0, 2.0]])  # 2 live < beam_width=4, both < thr
    sat = q.queue_init(1, 8)
    zeros = jnp.zeros((1,), jnp.int32)
    done0 = jnp.zeros((1,), bool)
    ratio = jnp.full((1,), 0.5, jnp.float32)
    thr = jnp.full((1,), 5.0, jnp.float32)
    *_, expand, done, _, _ = pop_frontier_beam(
        "vanilla", sat, oth, done0, zeros, zeros, ratio, thr, 4
    )
    np.testing.assert_array_equal(np.asarray(expand[0]), [True, True, False, False])
    assert not bool(done[0])
    # a real crossing IS sticky: thr below the second element
    oth2 = _filled_queue([[1.0, 9.0]])
    *_, expand2, done2, _, _ = pop_frontier_beam(
        "vanilla", sat, oth2, done0, zeros, zeros, ratio, jnp.full((1,), 5.0), 4
    )
    np.testing.assert_array_equal(np.asarray(expand2[0]), [True, False, False, False])
    assert bool(done2[0])


def test_short_frontier_mid_beam_does_not_terminate_two_queue():
    """Same invariant for alter/prefer: exhausting both frontiers at slot 1
    of the beam only skips the remaining slots, while exhaustion observed
    at slot 0 (iteration start) is final."""
    from repro.core.engine import pop_frontier_beam

    oth = _filled_queue([[1.0]])  # single live entry, below thr=inf
    sat = q.queue_init(1, 8)
    zeros = jnp.zeros((1,), jnp.int32)
    done0 = jnp.zeros((1,), bool)
    ratio = jnp.full((1,), 0.5, jnp.float32)
    thr = jnp.full((1,), jnp.inf, jnp.float32)
    *_, expand, done, _, _ = pop_frontier_beam(
        "prefer", sat, oth, done0, zeros, zeros, ratio, thr, 4
    )
    np.testing.assert_array_equal(np.asarray(expand[0]), [True, False, False, False])
    assert not bool(done[0])
    # both empty at iteration START -> done is final (seed semantics)
    *_, _, done_start, _, _ = pop_frontier_beam(
        "prefer", q.queue_init(1, 8), q.queue_init(1, 8), done0, zeros, zeros,
        ratio, thr, 4,
    )
    assert bool(done_start[0])


def test_mask_first_occurrence_keeps_one_copy():
    ids = jnp.asarray([[5, 3, 5, 7, 3, 5]], jnp.int32)
    valid = jnp.asarray([[True, False, True, True, True, True]])
    out = np.asarray(mask_first_occurrence(ids, valid))
    # first VALID copy of each id survives; invalid slots never resurrect
    np.testing.assert_array_equal(out[0], [True, False, False, True, True, False])


# ---------------------------------------------------------------------------
# beam-equivalence vs. the seed implementation (golden outputs)
# ---------------------------------------------------------------------------

N, D, L = 4000, 24, 10


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=16, sample_size=256)
    queries, qlab = make_queries(jax.random.PRNGKey(2), corpus, 24)
    return corpus, graph, queries, qlab


def _run(world, mode, cons, beam_width=1, fuse="auto"):
    corpus, graph, queries, _ = world
    params = SearchParams(
        mode=mode, k=10, ef_result=128, ef_sat=128, ef_other=128,
        n_start=16, max_iters=800, beam_width=beam_width, fuse_expand=fuse,
    )
    rng = jax.random.PRNGKey(7) if mode == "vanilla" else None
    return constrained_search(corpus, graph, queries, cons, params, rng=rng)


def _constraints(qlab):
    return {
        "eq": equal_constraint(qlab, L),
        "uneq": unequal_pct_constraint(jax.random.PRNGKey(3), qlab, L, 20.0),
    }


@pytest.mark.parametrize("fuse", ["on", "off"])
@pytest.mark.parametrize("mode", ["vanilla", "start", "alter", "prefer"])
def test_beam1_matches_seed_bit_for_bit(world, mode, fuse):
    """Both candidate pipelines — fused (kernels/fused_expand + sorted
    merges) and unfused (separate gathers + top_k pushes) — reproduce the
    pre-refactor seed outputs bit-for-bit, stats counters included."""
    golden = np.load(GOLDEN)
    for cname, cons in _constraints(world[3]).items():
        res = _run(world, mode, cons, beam_width=1, fuse=fuse)
        tag = f"{mode}_{cname}"
        np.testing.assert_array_equal(np.asarray(res.ids), golden[f"{tag}_ids"])
        np.testing.assert_array_equal(np.asarray(res.dists), golden[f"{tag}_dists"])
        for field, val in (
            ("dist_evals", res.stats.dist_evals),
            ("hops", res.stats.hops),
            ("visited", res.stats.visited),
            ("iters", res.stats.iters),
        ):
            np.testing.assert_array_equal(
                np.asarray(val), golden[f"{tag}_{field}"], err_msg=f"{tag}.{field}"
            )


def test_beam4_halves_iterations_equal_label_prefer(world):
    """The acceptance bar: >= 2x fewer lock-step iterations at beam_width=4."""
    cons = equal_constraint(world[3], L)
    it1 = int(_run(world, "prefer", cons, beam_width=1).stats.iters)
    it4 = int(_run(world, "prefer", cons, beam_width=4).stats.iters)
    assert it4 * 2 <= it1, (it1, it4)


@pytest.mark.parametrize("beam_width", [2, 4, 8])
def test_beam_results_stay_valid_and_accurate(world, beam_width):
    corpus, graph, queries, qlab = world
    cons = equal_constraint(qlab, L)
    _, ti = exact_constrained_search(corpus, queries, cons, k=10)
    res = _run(world, "prefer", cons, beam_width=beam_width)
    # recall holds up — wider beams over-expand, they don't under-explore
    assert float(recall(res.ids, ti)) > 0.9
    ids = np.asarray(res.ids)
    d = np.asarray(res.dists)
    for row_i, row_d in zip(ids, d):
        live = row_i[row_i >= 0]
        assert len(live) == len(set(live.tolist()))  # beam dedup held
        vals = row_d[np.isfinite(row_d)]
        assert np.all(np.diff(vals) >= -1e-6)
    labs = np.asarray(corpus.labels)[np.maximum(ids, 0)]
    assert np.all((labs == np.asarray(qlab)[:, None]) | (ids < 0))
    # per-slot accounting: slot counts sum to hops, column 0 is the busiest
    be = np.asarray(res.stats.beam_expansions)
    assert be.shape == (queries.shape[0], beam_width)
    np.testing.assert_array_equal(be.sum(-1), np.asarray(res.stats.hops))
    assert np.all(be[:, 0] >= be[:, -1])


def test_beam_works_with_pq_adc_path(world):
    from repro.core import pq_train

    corpus, graph, queries, qlab = world
    cons = equal_constraint(qlab, L)
    pq_index = pq_train(jax.random.PRNGKey(10), corpus.vectors, m_sub=8, n_cent=64)
    params = SearchParams(
        mode="prefer", k=10, ef_result=128, n_start=16, max_iters=800,
        beam_width=4, approx="pq",
    )
    res = constrained_search(corpus, graph, queries, cons, params, pq_index=pq_index)
    d = np.asarray(res.dists)
    for row in d:
        vals = row[np.isfinite(row)]
        assert np.all(np.diff(vals) >= -1e-6)
    assert np.all(np.asarray(res.ids)[np.isfinite(d)] >= 0)
