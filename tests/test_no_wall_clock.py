"""Static guard: the serving layer never reads the wall clock directly.

Every timestamp in ``src/repro/serving/`` must flow through the injected
clock (``ServingRuntime.clock``) or ``types.wall_clock()`` — that is what
makes virtual-time replay deterministic and lets fault injection advance
time. A direct ``time.time()`` / ``time.monotonic()`` /
``time.perf_counter()`` call anywhere else silently couples latencies and
deadline decisions to the host scheduler, which no test would catch until
a flaky CI run did. ``types.py`` is the single allowed importer: it owns
``wall_clock()``.
"""
import ast
import pathlib

SERVING = (
    pathlib.Path(__file__).resolve().parents[1] / "src" / "repro" / "serving"
)
ALLOWED = {"types.py"}  # owns wall_clock(); the one sanctioned time import


def test_serving_layer_has_no_direct_time_imports():
    assert SERVING.is_dir(), SERVING
    offenders = []
    for path in sorted(SERVING.glob("*.py")):
        if path.name in ALLOWED:
            continue
        tree = ast.parse(path.read_text(), filename=str(path))
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "time" or alias.name.startswith("time."):
                        offenders.append(f"{path.name}:{node.lineno} import {alias.name}")
            elif isinstance(node, ast.ImportFrom):
                if node.module == "time":
                    offenders.append(f"{path.name}:{node.lineno} from time import ...")
    assert not offenders, (
        "direct wall-clock access in the serving layer (route timestamps "
        f"through the injected clock / types.wall_clock): {offenders}"
    )


def test_types_wall_clock_is_the_only_time_usage():
    # The sanctioned file uses time for exactly two things: the wall
    # timeline (perf_counter) and per-thread CPU cost accounting
    # (thread_time, for ServingRuntime.busy_seconds).
    tree = ast.parse((SERVING / "types.py").read_text())
    calls = [
        node.attr
        for node in ast.walk(tree)
        if isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "time"
    ]
    assert sorted(calls) == ["perf_counter", "thread_time"], calls
