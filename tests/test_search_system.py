"""End-to-end behaviour tests for the paper's system: the claims of §3.

Built once per module (index construction is the slow part), then each test
checks one experimental claim on the shared fixtures.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    SearchParams,
    constrained_search,
    equal_constraint,
    exact_constrained_search,
    recall,
    selectivity,
    three_stage_pipeline,
    unequal_pct_constraint,
)
from repro.data.synthetic import make_labeled_corpus, make_queries
from repro.graph.index import build_index

N, D, L = 4000, 24, 10


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    graph = build_index(jax.random.PRNGKey(1), corpus, degree=16, sample_size=256)
    q, qlab = make_queries(jax.random.PRNGKey(2), corpus, 24)
    return corpus, graph, q, qlab


def run(world, mode, cons, k=10, ef=128, **kw):
    corpus, graph, q, _ = world
    params = SearchParams(
        mode=mode, k=k, ef_result=ef, ef_sat=128, ef_other=128,
        n_start=16, max_iters=800, **kw,
    )
    return constrained_search(corpus, graph, q, cons, params)


def test_equal_constraint_all_modes_high_recall(world):
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    _, ti = exact_constrained_search(corpus, q, cons, k=10)
    for mode in ("vanilla", "start", "alter", "prefer"):
        r = float(recall(run(world, mode, cons).ids, ti))
        assert r > 0.85, (mode, r)  # paper: all graph methods comparable


def test_unequal_alter_beats_vanilla(world):
    """The paper's core claim: two-frontier search dominates on unequal-X%."""
    corpus, graph, q, qlab = world
    cons = unequal_pct_constraint(jax.random.PRNGKey(3), qlab, L, 20.0)
    _, ti = exact_constrained_search(corpus, q, cons, k=10)
    res_v = run(world, "vanilla", cons)
    res_a = run(world, "prefer", cons)
    r_v = float(recall(res_v.ids, ti))
    r_a = float(recall(res_a.ids, ti))
    assert r_a > r_v + 0.1, (r_v, r_a)
    # and with FEWER distance computations (the QPS proxy)
    assert float(jnp.mean(res_a.stats.dist_evals)) < float(
        jnp.mean(res_v.stats.dist_evals)
    )


def test_results_are_sorted_satisfied_and_valid(world):
    corpus, graph, q, qlab = world
    cons = unequal_pct_constraint(jax.random.PRNGKey(4), qlab, L, 30.0)
    res = run(world, "prefer", cons)
    d = np.asarray(res.dists)
    fin = np.isfinite(d)
    # ascending among finite
    for row, frow in zip(d, fin):
        vals = row[frow]
        assert np.all(np.diff(vals) >= -1e-6)
    # every returned id satisfies the constraint
    from repro.core.constraints import make_satisfied_fn

    sat = make_satisfied_fn(cons, corpus)
    ok = np.asarray(sat(res.ids))
    assert np.all(ok[np.asarray(res.ids) >= 0])


def test_search_never_returns_duplicates(world):
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    res = run(world, "prefer", cons)
    ids = np.asarray(res.ids)
    for row in ids:
        live = row[row >= 0]
        assert len(live) == len(set(live.tolist()))


def test_three_stage_pipeline_underfills(world):
    """Fig. 1 motivation: with selective constraints, retrieving s=2k then
    filtering often yields fewer than k survivors; AIRSHIP fills k."""
    corpus, graph, q, qlab = world
    cons = unequal_pct_constraint(jax.random.PRNGKey(5), qlab, L, 10.0)
    k = 10
    _, _, n_survived = three_stage_pipeline(corpus, graph, q, cons, s=2 * k, k=k)
    res = run(world, "prefer", cons, k=k)
    assert float(jnp.mean(n_survived)) < float(jnp.mean(res.filled))


def test_selectivity_matches_constraint(world):
    corpus, graph, q, qlab = world
    cons = unequal_pct_constraint(jax.random.PRNGKey(6), qlab, L, 20.0)
    sel = selectivity(cons, corpus)
    # 2 of 10 labels allowed -> ~20% of corpus (clustered labels, loose tol)
    assert 0.05 < float(jnp.mean(sel)) < 0.45


def test_assumption1_fallback_linear_scan(world):
    """When p% is tiny, the paper prescribes linear scan — exact search
    must return everything that exists."""
    corpus, graph, q, qlab = world
    # constraint matching a single label: still fine for exact search
    cons = equal_constraint(qlab, L)
    td, ti = exact_constrained_search(corpus, q, cons, k=5)
    assert bool(jnp.all(ti >= 0))
    lab = corpus.labels[jnp.maximum(ti, 0)]
    assert bool(jnp.all(lab == qlab[:, None]))


def test_dist_evals_accounting_positive_and_bounded(world):
    corpus, graph, q, qlab = world
    cons = equal_constraint(qlab, L)
    res = run(world, "prefer", cons)
    de = np.asarray(res.stats.dist_evals)
    assert np.all(de > 0)
    assert np.all(de <= N + 256 + 1)  # can't exceed corpus + sample + entry


def test_pq_fused_traversal_matches_exact_closely(world):
    """Beyond-paper: ADC-driven walk + exact re-rank loses <5 recall points
    while gathering m_sub code bytes instead of d floats per candidate."""
    from repro.core import pq_train

    corpus, graph, q, qlab = world
    cons = unequal_pct_constraint(jax.random.PRNGKey(9), qlab, L, 20.0)
    _, ti = exact_constrained_search(corpus, q, cons, k=10)
    pq = pq_train(jax.random.PRNGKey(10), corpus.vectors, m_sub=8, n_cent=64)
    r = {}
    for approx in ("exact", "pq"):
        params = SearchParams(
            mode="prefer", k=10, ef_result=128, n_start=16, max_iters=800,
            approx=approx,
        )
        res = constrained_search(
            corpus, graph, q, cons, params,
            pq_index=pq if approx == "pq" else None,
        )
        r[approx] = float(recall(res.ids, ti))
        # re-ranked results stay sorted + satisfied
        d = np.asarray(res.dists)
        for row in d:
            vals = row[np.isfinite(row)]
            assert np.all(np.diff(vals) >= -1e-6)
    assert r["pq"] > r["exact"] - 0.05, r
