"""Fault-tolerant serving under SLO (DESIGN.md §10): deadline boundary
semantics, the degradation ladder's hysteresis and recovery, shed paths
(expired + predictive), injected executor faults (errors, latency spikes,
stale epochs) retried-or-failed but never lost, and the client retry
policy's deadline-aware give-up.

The ladder staleness test is a regression test for a real death spiral:
at level 3 everything is shed, so no completions arrive, so the latency
EMA freezes at its burst-era high, so the ladder never recovers — unless
a stale EMA stops counting as an overload signal.
"""
import jax
import numpy as np
import pytest

from repro.data.synthetic import make_labeled_corpus
from repro.graph.index import build_index
from repro.serving import (
    AdmissionError,
    DegradationLadder,
    FaultClock,
    FaultConfig,
    FaultSchedule,
    FaultyExecutor,
    LatencyHistogram,
    LocalExecutor,
    RetryPolicy,
    ServingRuntime,
    SLOConfig,
    StreamingLocalExecutor,
    VirtualClock,
    deadline_due,
    deadline_missed,
    label_words_row,
    mixed_workload,
    poisson_arrivals,
    replay_poisson,
    submit_with_retry,
)
from repro.core.types import SearchParams

N, D, L = 1500, 16, 5


@pytest.fixture(scope="module")
def world():
    corpus = make_labeled_corpus(jax.random.PRNGKey(0), n=N, d=D, n_labels=L)
    corpus = corpus.replace(
        attrs=jax.random.uniform(jax.random.PRNGKey(50), (N, 2))
    )
    graph = build_index(
        jax.random.PRNGKey(1), corpus, degree=12, sample_size=128
    )
    return corpus, graph


def _tiers(k_cap=4, base_ef=8, base_iters=16, n_tiers=1):
    out = []
    for t in range(n_tiers):
        g = 4**t
        ef = max(base_ef * g, k_cap)
        out.append(SearchParams(
            mode="prefer", k=k_cap, ef_result=ef, ef_sat=ef, ef_other=ef,
            n_start=4 * g, max_iters=base_iters * g,
        ))
    return tuple(out)


def _runtime(world, clock=None, **kw):
    corpus, graph = world
    kw.setdefault("n_labels", L)
    kw.setdefault("tiers", _tiers())
    kw.setdefault("ladder", (4,))
    kw.setdefault("families", ("label",))
    kw.setdefault("max_wait", 0.0)
    executor = kw.pop("executor", None) or LocalExecutor(corpus, graph)
    return ServingRuntime(executor, clock=clock or VirtualClock(), **kw)


def _submit(runtime, deadline=None, k=4):
    q = np.zeros((D,), np.float32)
    return runtime.submit(q, k, "label", label_words_row([0], L),
                          deadline=deadline)


# ---------------------------------------------------------------------------
# deadline boundary semantics (the satellite fix: one set of helpers)
# ---------------------------------------------------------------------------


def test_deadline_boundary_semantics():
    # At now == deadline the request is DUE (last chance to ship) but not
    # yet MISSED (completing exactly at the deadline counts).
    assert deadline_due(1.0, 1.0)
    assert not deadline_missed(1.0, 1.0)
    assert deadline_missed(1.0, np.nextafter(1.0, 2.0))
    assert not deadline_due(1.0, 0.999)
    # deadline-free requests are never due-by-deadline and never missed
    assert not deadline_due(None, 1e9)
    assert not deadline_missed(None, 1e9)


# ---------------------------------------------------------------------------
# degradation ladder (pure bookkeeping, no executor)
# ---------------------------------------------------------------------------


def test_ladder_hysteresis_up_and_down():
    cfg = SLOConfig(queue_high=8, queue_low=2, hold_up=2, hold_down=3,
                    max_level=3)
    ladder = DegradationLadder(cfg)
    ladder.observe_load(100)
    assert ladder.level == 0  # one overloaded sample is not enough
    ladder.observe_load(100)
    assert ladder.level == 1  # hold_up reached
    for _ in range(4):
        ladder.observe_load(100)
    assert ladder.level == 3  # climbs one level per hold_up window, capped
    for _ in range(6):
        ladder.observe_load(100)
    assert ladder.level == 3  # max_level is a ceiling, not a wrap
    down_at = []
    for _ in range(60):
        ladder.observe_load(0)
        down_at.append(ladder.level)
    assert ladder.level == 0  # queue EMA decayed below queue_low -> calm
    assert down_at == sorted(down_at, reverse=True)  # monotone recovery
    ups = [t for t in ladder.transitions if t[2] > t[1]]
    downs = [t for t in ladder.transitions if t[2] < t[1]]
    assert len(ups) == 3 and len(downs) == 3


def test_ladder_band_holds_level_and_flapping_is_bounded():
    cfg = SLOConfig(queue_high=8, queue_low=2, hold_up=2, hold_down=2)
    ladder = DegradationLadder(cfg)
    ladder.observe_load(10)
    ladder.observe_load(10)
    assert ladder.level == 1
    for _ in range(20):
        ladder.observe_load(5)  # EMA converges into the [low, high] band
    assert ladder.level == 1 and len(ladder.transitions) == 1
    # A load oscillating across queue_high never holds the overloaded
    # condition for hold_up consecutive samples: the ladder must not move.
    flappy = DegradationLadder(cfg)
    for i in range(50):
        flappy.observe_load(9 if i % 2 == 0 else 1)
    assert flappy.level == 0 and flappy.transitions == []


def test_ladder_stale_latency_cannot_latch_overload():
    # Death-spiral regression: a hot latency EMA with no completions
    # behind it (everything shed) must go stale and release the ladder.
    cfg = SLOConfig(target_latency=0.01, queue_high=50, queue_low=5,
                    hold_up=1, hold_down=2, lat_stale_after=4)
    ladder = DegradationLadder(cfg)
    ladder.observe_latency(1.0)  # 100x the target: overload evidence
    for _ in range(3):
        ladder.observe_load(0)
    assert ladder.level == 3  # latency signal alone drove it up
    for _ in range(20):
        ladder.observe_load(0)  # queue empty, NO new latency samples
    assert ladder.level == 0
    assert ladder.lat_ema > cfg.target_latency  # stale, not decayed


def test_predicted_miss_uses_service_time_not_queue_wait():
    ladder = DegradationLadder(SLOConfig())
    ladder.observe_latency(5.0)  # arrival-to-completion: burst queue wait
    ladder.observe_service(0.001)  # what one dispatch actually costs
    # A deadline 1s out is easily meetable by a 1ms dispatch — the stale
    # queue-wait-contaminated EMA must not shed it.
    assert not ladder.predicted_miss(deadline=1.0, now=0.0)
    assert ladder.predicted_miss(deadline=0.0005, now=0.0)
    # Fallback before any dispatch measurement exists: the latency EMA.
    fallback = DegradationLadder(SLOConfig())
    fallback.observe_latency(5.0)
    assert fallback.predicted_miss(deadline=1.0, now=0.0)
    assert not fallback.predicted_miss(deadline=None, now=0.0)


# ---------------------------------------------------------------------------
# shed paths through the runtime
# ---------------------------------------------------------------------------


def test_expired_request_shed_with_pollable_response(world):
    clock = VirtualClock()
    runtime = _runtime(world, clock=clock)
    rid = _submit(runtime, deadline=clock() + 0.001)
    clock.advance(0.01)  # the deadline passes while the request queues
    runtime.step()
    resp = runtime.poll(rid)
    assert resp is not None and resp.shed_reason == "expired"
    assert resp.filled == 0 and not resp.ok and resp.deadline_missed
    assert runtime.in_flight == 0
    assert runtime.telemetry.counters["shed_expired"] == 1
    assert runtime.telemetry.counters["shed_total"] == 1


def test_shed_disabled_serves_late_but_marked_degraded(world):
    # Pre-PR7 behaviour (shed_expired=False) still upholds the invariant:
    # a completion past its deadline carries the degraded mark.
    clock = VirtualClock()
    runtime = _runtime(world, clock=clock, shed_expired=False)
    rid = _submit(runtime, deadline=clock() + 0.001)
    clock.advance(0.01)
    runtime.drain()
    resp = runtime.poll(rid)
    assert resp is not None and resp.shed_reason is None
    assert resp.deadline_missed and resp.degraded
    assert runtime.telemetry.counters["shed_total"] == 0


def test_predictive_shed_at_level3(world):
    clock = VirtualClock()
    runtime = _runtime(world, clock=clock, slo=SLOConfig())
    ladder = runtime.controller.ladder
    ladder.level = 3
    ladder.observe_service(10.0)  # one dispatch costs 10s in evidence
    rid = _submit(runtime, deadline=clock() + 1.0)  # not expired, hopeless
    runtime.step()
    resp = runtime.poll(rid)
    assert resp is not None and resp.shed_reason == "overload"
    assert resp.degraded  # admitted under a degraded ladder
    assert runtime.telemetry.counters["shed_overload"] == 1
    # Below level 3 the same request is served, not predicted away.
    ladder.level = 2
    rid2 = _submit(runtime, deadline=clock() + 1.0)
    runtime.drain()
    assert runtime.poll(rid2).shed_reason is None


def test_edf_orders_flush_batches_by_deadline(world):
    clock = VirtualClock()
    runtime = _runtime(world, clock=clock, families=("label", "range"),
                       max_wait=10.0)
    # Two incompatible microbatches in one flush; the later-submitted one
    # has the earlier deadline and must execute first.
    rid_late = _submit(runtime, deadline=clock() + 50.0)
    rid_soon = runtime.submit(
        np.zeros((D,), np.float32), 4, "range", (0.0, 1.0, 0),
        deadline=clock() + 1.0,
    )
    runtime.step(force=True)
    order = [r.req_id for r in runtime.telemetry.responses]
    assert order.index(rid_soon) < order.index(rid_late)


# ---------------------------------------------------------------------------
# fault injection: every fault retried to success or surfaced, never lost
# ---------------------------------------------------------------------------


def _faulty_runtime(world, fault_cfg, **kw):
    corpus, graph = world
    base = VirtualClock()
    fclock = FaultClock(base)
    schedule = FaultSchedule(fault_cfg)
    executor = FaultyExecutor(LocalExecutor(corpus, graph), schedule, fclock)
    return _runtime(world, clock=fclock, executor=executor, **kw), schedule, fclock


def test_injected_error_retried_to_success(world):
    runtime, schedule, _ = _faulty_runtime(
        world, FaultConfig(seed=3, error_rate=1.0, max_faults=1)
    )
    rid = _submit(runtime)
    runtime.drain()
    resp = runtime.poll(rid)
    assert resp is not None and resp.ok and resp.filled > 0
    assert resp.faulted  # the retry is accounted on the response
    assert schedule.injected == 1
    assert runtime.telemetry.counters["fault_retries"] == 1
    assert runtime.telemetry.counters["faults_injected"] == 1
    assert runtime.in_flight == 0


def test_fault_budget_exhaustion_surfaces_failed_response(world):
    runtime, schedule, _ = _faulty_runtime(
        world, FaultConfig(seed=3, error_rate=1.0), max_fault_retries=1
    )
    rid = _submit(runtime)
    runtime.drain()  # every dispatch faults; must still terminate
    resp = runtime.poll(rid)
    assert resp is not None and resp.error is not None
    assert not resp.ok and resp.faulted and resp.filled == 0
    assert runtime.in_flight == 0  # failed, never hung
    assert runtime.telemetry.counters["failed"] == 1
    assert runtime.telemetry.counters["fault_retries"] == 1
    assert schedule.injected == 2  # initial dispatch + one retry


def test_latency_spike_marks_response_and_advances_clock(world):
    spike_s = 0.25
    runtime, _, fclock = _faulty_runtime(
        world, FaultConfig(seed=3, spike_rate=1.0, spike_s=spike_s,
                           max_faults=1)
    )
    rid = _submit(runtime)
    runtime.drain()
    resp = runtime.poll(rid)
    assert resp is not None and resp.ok  # spikes delay, they don't fail
    assert resp.faulted and resp.degraded
    assert fclock.injected_s == pytest.approx(spike_s)
    assert resp.latency >= spike_s  # the spike is real in the timeline


def test_warmup_neither_faults_nor_consumes_schedule(world):
    runtime, schedule, _ = _faulty_runtime(
        world, FaultConfig(seed=3, error_rate=1.0, max_faults=1)
    )
    runtime.warmup()  # dummy dispatches against an error_rate=1.0 schedule
    assert schedule.injected == 0
    assert runtime.executor.armed  # re-armed for the measured run
    rid = _submit(runtime)
    runtime.drain()
    assert schedule.injected == 1  # the fault fired on the REAL dispatch
    assert runtime.poll(rid).faulted


def test_stale_epoch_delays_snapshot_publication(world):
    corpus, graph = world
    from repro.streaming import StreamingIndex

    index = StreamingIndex.from_static(corpus, graph, capacity=N + 8)
    schedule = FaultSchedule(FaultConfig(seed=3, stale_epoch_rate=1.0,
                                         max_faults=1))
    executor = FaultyExecutor(
        StreamingLocalExecutor(index, consolidate_after=1000), schedule
    )
    runtime = _runtime(world, executor=executor)
    e0 = executor.epoch
    rid1 = runtime.submit_upsert(np.zeros((D,), np.float32), label=0)
    runtime.drain()
    resp1 = runtime.poll(rid1)
    assert resp1.filled == 1  # the mutation itself applied
    assert resp1.epoch == e0  # ... but publication was delayed (stale)
    assert executor.epoch == e0  # queries keep seeing (and reporting) e0
    rid2 = runtime.submit_upsert(np.zeros((D,), np.float32), label=0)
    runtime.drain()
    assert runtime.poll(rid2).epoch > e0  # next swap catches up
    assert schedule.by_kind["stale_epoch"] == 1
    assert runtime.telemetry.counters["fault_stale_epoch"] == 1


# ---------------------------------------------------------------------------
# client retry policy
# ---------------------------------------------------------------------------


def test_retry_backoff_growth_and_jitter_bounds():
    policy = RetryPolicy(base_backoff=0.01, multiplier=2.0, jitter=0.5)
    rng = np.random.RandomState(0)
    for attempt in range(4):
        nominal = 0.01 * 2.0**attempt
        for _ in range(20):
            b = policy.backoff_for(attempt, rng)
            assert 0.5 * nominal <= b <= 1.5 * nominal
    no_jitter = RetryPolicy(base_backoff=0.01, multiplier=2.0, jitter=0.0)
    assert no_jitter.backoff_for(3, rng) == pytest.approx(0.08)


def test_retry_recovers_from_backpressure(world):
    clock = VirtualClock()
    runtime = _runtime(world, clock=clock, max_pending=2, max_wait=0.05)
    _submit(runtime)
    _submit(runtime)
    with pytest.raises(AdmissionError):
        _submit(runtime)  # full: the no-retry client sheds instantly
    # The retrying client backs off (advancing virtual time, pumping the
    # runtime — which drains the queue) and lands the request.
    rid, retries = submit_with_retry(
        runtime, lambda: _submit(runtime),
        RetryPolicy(max_retries=5, base_backoff=0.1), np.random.RandomState(0),
    )
    assert rid is not None and retries >= 1
    assert runtime.telemetry.counters["retries"] == retries
    runtime.drain()
    assert runtime.poll(rid) is not None


def test_retry_gives_up_before_hopeless_deadline(world):
    clock = VirtualClock()
    runtime = _runtime(world, clock=clock, max_pending=1, max_wait=10.0)
    _submit(runtime)  # wedge the queue (max_wait keeps it batched)
    rid, retries = submit_with_retry(
        runtime, lambda: _submit(runtime),
        RetryPolicy(max_retries=5, base_backoff=0.1, jitter=0.0),
        np.random.RandomState(0),
        deadline=clock() + 0.01,  # sooner than the first backoff lands
    )
    assert rid is None and retries == 0  # gave up without burning budget
    assert runtime.telemetry.counters["retries"] == 0


# ---------------------------------------------------------------------------
# workload plumbing + end-to-end invariants
# ---------------------------------------------------------------------------


def test_poisson_burst_window_compresses_gaps():
    a = poisson_arrivals(np.random.RandomState(7), 300, 100.0)
    b = poisson_arrivals(np.random.RandomState(7), 300, 100.0,
                         burst=(1 / 3, 2 / 3, 5.0))
    ga = np.diff(np.concatenate([[0.0], a]))
    gb = np.diff(np.concatenate([[0.0], b]))
    np.testing.assert_allclose(gb[:100], ga[:100])
    np.testing.assert_allclose(gb[100:200], ga[100:200] / 5.0)
    np.testing.assert_allclose(gb[200:], ga[200:])


def test_latency_histogram_quantiles():
    hist = LatencyHistogram()
    for _ in range(99):
        hist.record(0.001)
    hist.record(1.0)
    assert hist.quantile(50) < 0.002  # upper edge of the 1ms bucket
    assert hist.quantile(99.5) >= 1.0
    s = hist.summary()
    assert s["count"] == 100


def test_replay_under_faults_loses_nothing(world):
    # End-to-end acceptance invariant at test scale: burst + deadline +
    # error/spike faults; every item terminates as a pollable response or
    # a counted rejection, zero late completions go unmarked.
    corpus, graph = world
    items = mixed_workload(5, corpus, 40, L, k_choices=(4,),
                           mix=(0.5, 0.5, 0.0))
    runtime, schedule, fclock = _faulty_runtime(
        world,
        FaultConfig(seed=9, error_rate=0.1, spike_rate=0.1, spike_s=0.02),
        slo=SLOConfig(target_latency=0.05),
        max_wait=0.002, max_pending=16,
    )
    runtime.warmup()
    responses, rejected = replay_poisson(
        runtime, items, rate=400.0, seed=11, deadline_s=0.05,
        retry=RetryPolicy(max_retries=2, base_backoff=0.002),
        burst=(1 / 3, 2 / 3, 10.0),
    )
    served = [r for r in responses if r is not None]
    assert len(served) + rejected == len(items)
    assert runtime.in_flight == 0
    late_unmarked = [
        r for r in served
        if r.deadline_missed
        and r.shed_reason is None and not r.degraded
        and not r.faulted and r.error is None
    ]
    assert late_unmarked == []
    c = runtime.telemetry.counters
    # every submission terminated: completed or shed, nothing lost
    assert c["submitted"] == c["completed"] + c["shed_total"]
    if schedule.by_kind["error"]:
        # every injected error was retried to success or surfaced failed
        assert c["fault_retries"] + c["failed"] >= 1
