"""Property tests for the batched bitset visited-set."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import visited as vis  # noqa: E402


@settings(deadline=None, max_examples=40)
@given(
    st.integers(1, 200),  # n
    st.lists(st.integers(0, 199), min_size=1, max_size=50, unique=True),
)
def test_bitset_matches_python_set(n, ids):
    ids = [i for i in ids if i < n]
    if not ids:
        return
    words = vis.visited_init(1, n)
    arr = jnp.asarray(ids, jnp.int32)[None]
    fresh = ~vis.visited_test(words, arr)
    words = vis.visited_set(words, arr, fresh)
    # everything set is now visited; everything else is not
    all_ids = jnp.arange(n, dtype=jnp.int32)[None]
    got = np.asarray(vis.visited_test(words, all_ids))[0]
    expect = np.zeros(n, bool)
    expect[ids] = True
    np.testing.assert_array_equal(got, expect)
    assert int(vis.visited_count(words)[0]) == len(set(ids))


def test_padding_ids_report_visited():
    words = vis.visited_init(1, 64)
    assert bool(vis.visited_test(words, jnp.asarray([[-1]], jnp.int32))[0, 0])


def test_set_respects_mask_and_duplicate_protection():
    words = vis.visited_init(1, 64)
    ids = jnp.asarray([[3, 9]], jnp.int32)
    words = vis.visited_set(words, ids, jnp.asarray([[True, False]]))
    got = vis.visited_test(words, jnp.asarray([[3, 9]], jnp.int32))
    assert bool(got[0, 0]) and not bool(got[0, 1])
    # re-setting an already-visited id must be masked by the caller contract:
    fresh = ~vis.visited_test(words, ids)
    words2 = vis.visited_set(words, ids, fresh)
    assert int(vis.visited_count(words2)[0]) == 2
