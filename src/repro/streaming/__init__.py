# Streaming mutable index over the proximity graph (DESIGN.md §8):
# slot-pool corpus under static shapes, beam-search-guided insert with
# degree-bounded edge patching, tombstone deletes masked by every search
# path exactly like a failed constraint, and background consolidation that
# splices dead vertices out and returns their slots to the pool.
from repro.streaming.consolidate import consolidate
from repro.streaming.mutate import insert_one, patch_neighbor_row
from repro.streaming.slots import IndexSnapshot, SlotPool, StreamingIndex

__all__ = [
    "IndexSnapshot",
    "SlotPool",
    "StreamingIndex",
    "consolidate",
    "insert_one",
    "patch_neighbor_row",
]
