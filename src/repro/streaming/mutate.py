"""Online insert: beam-search-guided neighbor selection + edge patching.

An insert is two writes under the builder's adjacency contract
(graph/build.py: rows distance-ascending, self-free, dup-free, PAD-padded):

  1. the new slot's OWN row — the ``degree`` closest LIVE vertices found by
     a beam search over the current snapshot (the graph-guided analogue of
     the offline builder's exact kNN row; tombstoned routing nodes and free
     slots are excluded because the search masks the tombstone bitmap);
  2. degree-bounded PATCHES of those neighbors' rows — the new id is merged
     into each selected neighbor's sorted row, evicting its worst edge when
     the row is full (HNSW-style reverse wiring, which is what keeps newly
     inserted regions reachable).

The candidate search reuses the compiled engine: one ``constrained_search``
trace (fixed B=1 / k=degree shapes over the static pool capacity) serves
every insert; the match-all UDF constraint is a module-level function so
its jit key is stable.
"""
from __future__ import annotations

import numpy as np

from repro.core.search import constrained_search
from repro.core.types import SearchParams
from repro.streaming.slots import PAD, StreamingIndex


def _match_all(label, attrs):
    """Match-all UDF: tombstone masking alone decides returnability."""
    del attrs
    return label == label  # noqa: PLR0124 — int self-compare is always True


def _insert_params(index: StreamingIndex) -> SearchParams:
    # vanilla mode with rng=None walks from the fixed entry vertex with
    # unconstrained multi-start — the right shape for neighbor finding
    # (the constraint only filters the RESULT list, and the tombstone wrap
    # keeps dead slots out of it).
    return SearchParams(
        mode="vanilla",
        k=index.degree,
        ef_result=max(index.ef_insert, index.degree),
        ef_other=max(index.ef_insert, 2 * index.degree),
        n_start=min(16, index.ef_insert),
        max_iters=max(64, 4 * index.ef_insert),
    )


def patch_neighbor_row(
    index: StreamingIndex, v: int, new_id: int, d_new: float
) -> None:
    """Merge ``new_id`` (at distance ``d_new`` from ``v``) into v's row.

    Degree-bounded: when the row is full the worst edge is evicted iff the
    new edge is closer. Distances of existing edges are recomputed from the
    pool vectors (rows only store ids), so the ascending invariant is exact.
    """
    row = index.neighbors[v]
    live_e = row[row >= 0]
    if new_id in live_e:  # re-patching the same id is a no-op
        return
    diffs = index.pool.vectors[live_e] - index.pool.vectors[v]
    d_old = np.sum(diffs * diffs, axis=-1)
    ids = np.concatenate([live_e, [new_id]]).astype(np.int32)
    dists = np.concatenate([d_old, [d_new]]).astype(np.float32)
    order = np.argsort(dists, kind="stable")[: index.degree]
    out = np.full((index.degree,), PAD, np.int32)
    out[: order.shape[0]] = ids[order]
    index.neighbors[v] = out


def insert_one(index: StreamingIndex, vector, label=0, attrs=None) -> int:
    """Insert one vector; returns its slot id."""
    vec = np.asarray(vector, np.float32).reshape(index.dim)
    snap = index.snapshot()  # pre-insert epoch guides the neighbor search

    import jax.numpy as jnp

    res = constrained_search(
        snap.corpus,
        snap.graph,
        jnp.asarray(vec[None]),
        _match_all,
        _insert_params(index),
    )
    cand_ids = np.asarray(res.ids[0])
    cand_d = np.asarray(res.dists[0])
    keep = cand_ids >= 0
    cand_ids, cand_d = cand_ids[keep], cand_d[keep]
    # Defensive dedup (keeps ascending order): the searcher's result list
    # is dup-free by construction, but the new row's dup-free invariant
    # must not hinge on that.
    _, uniq = np.unique(cand_ids, return_index=True)
    uniq.sort()
    cand_ids, cand_d = cand_ids[uniq], cand_d[uniq]

    pool = index.pool
    slot = pool.alloc()
    pool.vectors[slot] = vec
    pool.labels[slot] = np.int32(label)
    if pool.attrs is not None:
        pool.attrs[slot] = (
            0.0 if attrs is None else np.asarray(attrs, np.float32)
        )

    # Own row: the search's ascending, dup-free live top-k IS the row.
    row = np.full((index.degree,), PAD, np.int32)
    sel = cand_ids[: index.degree]
    row[: sel.shape[0]] = sel
    index.neighbors[slot] = row

    # Reverse wiring: patch each selected neighbor's degree-bounded row.
    for v, dv in zip(sel, cand_d[: index.degree]):
        patch_neighbor_row(index, int(v), slot, float(dv))

    pool.commit(slot)
    index.on_slot_committed(slot)  # histograms/postings gain the new row
    # Keep AIRSHIP-Start's sample drifting with the live set: occasionally
    # point a random sample slot at the new vertex (uniform reservoir-ish;
    # a fresh slot id cannot already be sampled, so the sample stays
    # duplicate-free).
    if index.sample_ids.shape[0] and slot not in index.sample_ids and (
        index.rng.rand()
        < index.sample_ids.shape[0] / max(pool.n_live, 1)
    ):
        index.sample_ids[index.rng.randint(index.sample_ids.shape[0])] = slot
    index.mark_dirty()
    return slot
