"""Slot-pool corpus: fixed-capacity mutable storage under static shapes.

Compiled searches are fixed-shape, so a mutable index must never change its
array shapes — the pool pre-allocates ``capacity`` slots and mutates rows in
place (host-side numpy; snapshots transfer to device on publish):

  * a slot is LIVE (searchable + returnable), PENDING (deleted via
    tombstone, still wired into the graph as a routing node until
    consolidation), or FREE (on the free list, unreferenced by any edge);
  * the tombstone bitmap marks everything non-returnable (PENDING ∪ FREE) —
    the traversal masks it exactly like a failed constraint
    (core/constraints.py, kernels/fused_expand/);
  * accounting invariant: ``n_live + n_pending + n_free == capacity`` and
    ``popcount(tombstones) == n_pending + n_free`` (property-tested).

``StreamingIndex`` wraps one pool + the adjacency/sample/entry arrays and
publishes immutable epoch-versioned ``IndexSnapshot``s: queries in flight
keep the epoch they were dispatched against; the serving runtime swaps
snapshots only at flush boundaries (serving/runtime.py, DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.types import Corpus, GraphIndex

WORD_BITS = 32
PAD = -1


def _bitmap_words(capacity: int) -> int:
    return (capacity + WORD_BITS - 1) // WORD_BITS


class SlotPool:
    """Fixed-capacity row storage with a LIFO free list + tombstone bitmap."""

    def __init__(
        self,
        vectors: np.ndarray,
        labels: np.ndarray,
        attrs: Optional[np.ndarray],
        capacity: int,
    ):
        n0, d = vectors.shape
        if capacity < n0:
            raise ValueError(f"capacity {capacity} < initial corpus size {n0}")
        self.capacity = int(capacity)
        self.vectors = np.zeros((capacity, d), np.float32)
        self.vectors[:n0] = np.asarray(vectors, np.float32)
        self.labels = np.zeros((capacity,), np.int32)
        self.labels[:n0] = np.asarray(labels, np.int32)
        self.attrs: Optional[np.ndarray] = None
        if attrs is not None:
            attrs = np.asarray(attrs, np.float32)
            self.attrs = np.zeros((capacity, attrs.shape[1]), np.float32)
            self.attrs[:n0] = attrs
        self.tombstones = np.zeros((_bitmap_words(capacity),), np.uint32)
        # Slots [n0, capacity) start FREE: tombstoned (non-returnable) and
        # unreferenced until an insert claims them.
        for s in range(n0, capacity):
            self._set_dead(s)
        self.free: List[int] = list(range(capacity - 1, n0 - 1, -1))  # LIFO
        self.pending: List[int] = []
        self.n_live = n0

    # --- bitmap ----------------------------------------------------------
    def _set_dead(self, slot: int) -> None:
        self.tombstones[slot // WORD_BITS] |= np.uint32(1) << np.uint32(
            slot % WORD_BITS
        )

    def _set_alive(self, slot: int) -> None:
        self.tombstones[slot // WORD_BITS] &= ~(
            np.uint32(1) << np.uint32(slot % WORD_BITS)
        )

    def is_live(self, slot: int) -> bool:
        word = self.tombstones[slot // WORD_BITS]
        return not bool((word >> np.uint32(slot % WORD_BITS)) & np.uint32(1))

    def live_ids(self) -> np.ndarray:
        bits = np.unpackbits(
            self.tombstones.view(np.uint8), bitorder="little"
        )[: self.capacity]
        return np.nonzero(bits == 0)[0].astype(np.int32)

    def live_mask(self) -> np.ndarray:
        """(capacity,) bool — LIVE slots (tombstone bit clear)."""
        bits = np.unpackbits(
            self.tombstones.view(np.uint8), bitorder="little"
        )[: self.capacity]
        return bits == 0

    # --- lifecycle -------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def n_pending(self) -> int:
        return len(self.pending)

    def alloc(self) -> int:
        """Claim a FREE slot (still tombstoned until ``commit``)."""
        if not self.free:
            raise RuntimeError(
                "slot pool exhausted: consolidate pending tombstones or "
                "grow capacity"
            )
        return self.free.pop()

    def commit(self, slot: int) -> None:
        """FREE -> LIVE after the caller wrote the slot's rows + edges."""
        self._set_alive(slot)
        self.n_live += 1

    def release(self, slot: int) -> bool:
        """LIVE -> PENDING (tombstoned; edges stay until consolidation)."""
        if not self.is_live(slot):
            return False
        self._set_dead(slot)
        self.pending.append(slot)
        self.n_live -= 1
        return True

    def reclaim(self, slot: int) -> None:
        """PENDING -> FREE once consolidation has unhooked every in-edge."""
        self.pending.remove(slot)
        self.free.append(slot)

    def stats(self) -> dict:
        """Occupancy gauges for observability (obs/adapters.py exposes
        these as ``repro_streaming_slots{state=...}``); states always
        partition the capacity."""
        return {
            "capacity": self.capacity,
            "live": self.n_live,
            "pending": self.n_pending,
            "free": self.n_free,
        }

    def check_accounting(self) -> None:
        """Raise if the slot-state partition or the bitmap drifted."""
        total = self.n_live + self.n_pending + self.n_free
        if total != self.capacity:
            raise AssertionError(
                f"slot accounting broken: live {self.n_live} + pending "
                f"{self.n_pending} + free {self.n_free} != {self.capacity}"
            )
        dead_bits = int(
            np.unpackbits(self.tombstones.view(np.uint8), bitorder="little")[
                : self.capacity
            ].sum()
        )
        if dead_bits != self.n_pending + self.n_free:
            raise AssertionError(
                f"tombstone popcount {dead_bits} != pending+free "
                f"{self.n_pending + self.n_free}"
            )


@dataclasses.dataclass(frozen=True)
class IndexSnapshot:
    """One immutable epoch of the mutable index (device arrays)."""

    epoch: int
    corpus: Corpus  # tombstones set — every search masks dead slots
    graph: GraphIndex


class StreamingIndex:
    """Mutable proximity-graph index over a slot pool.

    Mutations (``insert``/``delete``/``consolidate``, implemented in
    mutate.py / consolidate.py) edit host arrays in place and mark the
    index dirty; ``snapshot()`` publishes the next epoch on demand. The
    adjacency invariants of graph/build.py (rows distance-ascending,
    self-free, dup-free, PAD-padded) are preserved by every mutation.
    """

    def __init__(
        self,
        pool: SlotPool,
        neighbors: np.ndarray,
        sample_ids: np.ndarray,
        entry_point: int,
        *,
        ef_insert: int = 32,
        seed: int = 0,
    ):
        self.pool = pool
        cap, deg = pool.capacity, neighbors.shape[1]
        self.neighbors = np.full((cap, deg), PAD, np.int32)
        self.neighbors[: neighbors.shape[0]] = np.asarray(neighbors, np.int32)
        self.sample_ids = np.asarray(sample_ids, np.int32).copy()
        self.entry_point = int(entry_point)
        self.ef_insert = int(ef_insert)
        self.rng = np.random.RandomState(seed)
        self.epoch = 0
        self._dirty = True
        self._snap: Optional[IndexSnapshot] = None
        self.consolidations = 0
        # Hybrid-routing stats (DESIGN.md §9): label/range histograms and
        # posting lists maintained INCREMENTALLY by insert/delete (±1 per
        # mutation; consolidation moves PENDING→FREE and never changes live
        # membership) — exact at every snapshot publication, cross-checked
        # there against the pool's n_live. The range index re-sorts lazily
        # per epoch on first range-posting request.
        from repro.core.histogram import AttributeHistograms
        from repro.core.posting import PostingLists, RangeIndex

        live = pool.live_mask()
        self.histograms = AttributeHistograms.from_arrays(
            pool.labels, pool.attrs, live
        )
        self.postings = PostingLists.from_arrays(pool.labels, live)
        self.range_index = RangeIndex()

    @classmethod
    def from_static(
        cls,
        corpus: Corpus,
        graph: GraphIndex,
        *,
        capacity: Optional[int] = None,
        ef_insert: int = 32,
        seed: int = 0,
    ) -> "StreamingIndex":
        """Pool-ify a built (corpus, graph): pad all arrays to ``capacity``
        (default 1.5x the seed size) and start the free list after them."""
        n0 = corpus.n
        cap = int(capacity) if capacity is not None else n0 + max(64, n0 // 2)
        pool = SlotPool(
            np.asarray(corpus.vectors),
            np.asarray(corpus.labels),
            None if corpus.attrs is None else np.asarray(corpus.attrs),
            cap,
        )
        return cls(
            pool,
            np.asarray(graph.neighbors),
            np.asarray(graph.sample_ids),
            int(graph.entry_point),
            ef_insert=ef_insert,
            seed=seed,
        )

    # --- geometry --------------------------------------------------------
    @property
    def dim(self) -> int:
        return self.pool.vectors.shape[1]

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]

    @property
    def capacity(self) -> int:
        return self.pool.capacity

    def mark_dirty(self) -> None:
        self._dirty = True

    # --- hybrid-routing stats maintenance ---------------------------------
    def on_slot_committed(self, slot: int) -> None:
        """FREE->LIVE bookkeeping: histograms + postings gain the slot."""
        label = int(self.pool.labels[slot])
        attrs_row = None if self.pool.attrs is None else self.pool.attrs[slot]
        self.histograms.on_insert(label, attrs_row)
        self.postings.on_insert(label, slot)

    def on_slot_released(self, slot: int) -> None:
        """LIVE->PENDING bookkeeping: histograms + postings drop the slot."""
        label = int(self.pool.labels[slot])
        attrs_row = None if self.pool.attrs is None else self.pool.attrs[slot]
        self.histograms.on_delete(label, attrs_row)
        self.postings.on_delete(label, slot)

    def range_postings(self, lo: float, hi: float, col: int) -> np.ndarray:
        """Sorted LIVE ids with attrs[:, col] in [lo, hi] — the range
        family's posting set (lazy per-epoch re-sort, then binary search)."""
        if self.pool.attrs is None:
            return np.empty((0,), np.int32)
        self.range_index.refresh(
            self.pool.attrs, self.pool.live_mask(), self.epoch
        )
        return self.range_index.ids_for_range(lo, hi, col)

    def check_stats_exact(self) -> None:
        """Raise if the incremental histograms/postings drifted from the
        pool's ground truth (tests; cheap n_live check runs every publish)."""
        live = self.pool.live_mask()
        self.histograms.check_exact(self.pool.labels, live)
        truth_ids = np.nonzero(live)[0]
        truth = {}
        for i in truth_ids:
            truth.setdefault(int(self.pool.labels[i]), set()).add(int(i))
        for lab, ids in truth.items():
            got = set(self.postings.ids_for_label(lab).tolist())
            if got != ids:
                raise AssertionError(f"posting list drifted for label {lab}")
        n_posted = sum(len(s) for s in truth.values())
        total = sum(
            self.postings.count_label(lab)
            for lab in range(len(self.postings._sets))
        )
        if total != n_posted:
            raise AssertionError("phantom postings outside live label space")

    # --- epoch publication ------------------------------------------------
    def snapshot(self) -> IndexSnapshot:
        """Publish (or reuse) the current epoch's immutable device view."""
        if self._snap is None or self._dirty:
            self.epoch += 1
            # "Exact at snapshot publication": the incremental stats must
            # agree with the pool's live count — an O(1) tripwire for the
            # ±1 maintenance (full cross-check: ``check_stats_exact``).
            if self.histograms.n_live != self.pool.n_live:
                raise AssertionError(
                    f"histogram n_live {self.histograms.n_live} drifted from "
                    f"pool n_live {self.pool.n_live} at epoch {self.epoch}"
                )
            corpus = Corpus(
                vectors=jnp.asarray(self.pool.vectors),
                labels=jnp.asarray(self.pool.labels),
                attrs=(
                    None
                    if self.pool.attrs is None
                    else jnp.asarray(self.pool.attrs)
                ),
                tombstones=jnp.asarray(self.pool.tombstones),
            )
            graph = GraphIndex(
                neighbors=jnp.asarray(self.neighbors),
                sample_ids=jnp.asarray(self.sample_ids),
                entry_point=jnp.int32(self.entry_point),
            )
            self._snap = IndexSnapshot(epoch=self.epoch, corpus=corpus, graph=graph)
            self._dirty = False
        return self._snap

    # --- mutations (implementations live in mutate.py / consolidate.py) --
    def insert(self, vector, label=0, attrs=None) -> int:
        from repro.streaming.mutate import insert_one

        return insert_one(self, vector, label, attrs)

    def delete(self, slot: int) -> bool:
        """Tombstone one live slot; its edges stay until consolidation."""
        ok = self.pool.release(int(slot))
        if ok:
            self.on_slot_released(int(slot))
            self.mark_dirty()
        return ok

    def consolidate(self, max_slots: Optional[int] = None) -> int:
        from repro.streaming.consolidate import consolidate

        return consolidate(self, max_slots=max_slots)
