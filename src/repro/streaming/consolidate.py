"""Background consolidation: splice tombstoned vertices out of the graph.

A deleted vertex stays wired in as a routing node (tombstone-as-constraint
keeps it out of every result list) so connectivity never degrades between
consolidations. This pass does the actual surgery, slot by slot:

  * every in-neighbor ``u`` of a target ``t`` drops its ``u -> t`` edge and
    considers ``t``'s out-edges as replacement candidates (the classic
    delete-splice: paths through ``t`` survive as direct edges), re-ranked
    with ``u``'s surviving edges under the degree bound;
  * ``t``'s own row is cleared to PAD and the slot returns to the free
    list — only now, so a recycled slot id can never be dangling-referenced
    by a stale edge;
  * the entry point and AIRSHIP-Start sample are re-pointed at live
    vertices when they died.

All four adjacency invariants (distance-ascending, self-free, dup-free,
PAD-padded) are preserved row by row, and the slot-pool accounting
(live + pending + free == capacity) is restored (property-tested in
tests/test_streaming.py).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.streaming.slots import PAD, StreamingIndex


def _rewrite_row(index: StreamingIndex, u: int, cand: np.ndarray) -> None:
    """Write u's row = the ``degree`` closest of ``cand`` (ascending)."""
    if cand.shape[0] == 0:
        index.neighbors[u] = PAD
        return
    diffs = index.pool.vectors[cand] - index.pool.vectors[u]
    d = np.sum(diffs * diffs, axis=-1)
    order = np.argsort(d, kind="stable")[: index.degree]
    out = np.full((index.degree,), PAD, np.int32)
    out[: order.shape[0]] = cand[order]
    index.neighbors[u] = out


def consolidate(index: StreamingIndex, max_slots: Optional[int] = None) -> int:
    """Splice out up to ``max_slots`` pending tombstones; returns the count."""
    targets = list(
        index.pool.pending
        if max_slots is None
        else index.pool.pending[:max_slots]
    )
    if not targets:
        return 0
    tset = set(targets)
    nbrs = index.neighbors

    # In-neighbor scan: one vectorized membership test over the adjacency.
    hit = np.isin(nbrs, np.asarray(targets, np.int32))
    for u in np.nonzero(hit.any(axis=1))[0]:
        if u in tset:
            continue  # target rows are cleared below
        row = nbrs[u]
        keep = [e for e in row if e >= 0 and e not in tset]
        cand = dict.fromkeys(keep)  # ordered de-dup
        for e in row:
            if e >= 0 and e in tset:
                for w in nbrs[e]:
                    # Splice: t's out-edges stand in for paths through t.
                    if w >= 0 and w not in tset and w != u:
                        cand[w] = None
        _rewrite_row(index, int(u), np.fromiter(cand, np.int32, len(cand)))

    for t in targets:
        nbrs[t] = PAD
        index.pool.reclaim(t)

    # Re-point dead seeds at the live set (the tombstone wrap already keeps
    # them out of results; this keeps SEEDING useful).
    live = index.pool.live_ids()
    if live.shape[0]:
        if not index.pool.is_live(index.entry_point):
            mean = index.pool.vectors[live].mean(axis=0)
            diffs = index.pool.vectors[live] - mean
            index.entry_point = int(live[np.argmin(np.sum(diffs * diffs, -1))])
        dead_sample = ~np.isin(
            index.sample_ids, live, assume_unique=False
        )
        if dead_sample.any():
            # Replacements are drawn from live ids NOT already sampled —
            # the sample must stay duplicate-free (the engine's seeding is
            # dup-guarded, but a degenerate sample still wastes starts).
            pool_ids = np.setdiff1d(live, index.sample_ids)
            n_new = int(dead_sample.sum())
            if pool_ids.shape[0] >= n_new:
                repl = index.rng.choice(pool_ids, size=n_new, replace=False)
            else:
                repl = index.rng.choice(live, size=n_new, replace=True)
            index.sample_ids[dead_sample] = repl.astype(np.int32)

    index.consolidations += 1
    index.mark_dirty()
    index.pool.check_accounting()
    return len(targets)
