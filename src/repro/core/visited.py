"""Exact visited-set as a batched bitset.

The paper's hash-set ``visited`` becomes a ``(B, ceil(n/32))`` uint32 bitmask.
For n = 1M that is 31 KiB per query — trivially VMEM/HBM friendly, exact, and
race-free under the invariant maintained by the search loop:

  * bits are only set for ids that tested *unvisited* in the same step, and
  * within one step each row's id list is duplicate-free (graph adjacency
    rows are unique; padding is masked),

so a scatter-*add* of the fresh bit values equals a scatter-*or* (no carries),
which is what `jnp`'s indexed-add gives us without needing a bitwise-or
scatter primitive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

WORD_BITS = 32


def n_words(n: int) -> int:
    return (n + WORD_BITS - 1) // WORD_BITS


def visited_init(batch: int, n: int) -> Array:
    return jnp.zeros((batch, n_words(n)), dtype=jnp.uint32)


def visited_test(words: Array, ids: Array) -> Array:
    """(B, W) x (B, M) -> (B, M) bool. Padding ids (<0) report as visited."""
    safe = jnp.maximum(ids, 0)
    w = safe // WORD_BITS
    b = (safe % WORD_BITS).astype(jnp.uint32)
    word = jnp.take_along_axis(words, w, axis=-1)
    hit = (word >> b) & jnp.uint32(1)
    return jnp.where(ids >= 0, hit.astype(bool), True)


def visited_set(words: Array, ids: Array, mask: Array) -> Array:
    """Set bits for ``ids`` where ``mask`` holds.

    Caller contract (checked by property tests): every (row, id) pair with
    ``mask`` set must currently be unvisited and appear at most once in
    ``ids[row]``.
    """
    safe = jnp.maximum(ids, 0)
    w = safe // WORD_BITS
    b = (safe % WORD_BITS).astype(jnp.uint32)
    bits = jnp.where(mask & (ids >= 0), jnp.uint32(1) << b, jnp.uint32(0))
    batch_idx = jnp.arange(words.shape[0], dtype=jnp.int32)[:, None]
    return words.at[batch_idx, w].add(bits)


def visited_count(words: Array) -> Array:
    """(B,) number of set bits — i.e. vertices touched per query."""
    x = words
    # SWAR popcount per uint32 word.
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    x = (x * jnp.uint32(0x01010101)) >> 24
    return jnp.sum(x.astype(jnp.int32), axis=-1)
