"""Batched, lock-step constrained proximity-graph search (AIRSHIP core).

Implements the paper's four algorithm variants behind one compiled loop:

  * ``vanilla``  — Alg. 1: single frontier, constraint checked on pop.
  * ``start``    — §2.2: + satisfied starting points from the pre-drawn sample.
  * ``alter``    — §2.3/Alg. 2+3: two frontiers (satisfied / other) selected by
                   ``alter_ratio`` (estimated via Eq. 1 when not given).
  * ``prefer``   — §2.5: + biased selection (override the ratio whenever the
                   best satisfied candidate beats the best unsatisfied one).

TPU adaptation (see DESIGN.md §2): fixed-capacity sorted-array queues, bitset
visited, one `lax.while_loop` over the whole query batch with per-query done
masks, and a fused gather+distance step (Pallas kernel or jnp fallback).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.distances import batched_rowwise_sqdist, squared_l2
from repro.common.pytree import pytree_dataclass
from repro.core import queue as q
from repro.core import visited as vis
from repro.core.alter_ratio import estimate_alter_ratio
from repro.core.constraints import make_satisfied_fn
from repro.core.types import (
    Corpus,
    GraphIndex,
    SearchParams,
    SearchResult,
    SearchStats,
)

Array = jax.Array


@pytree_dataclass
class _State:
    sat: q.BatchedQueue
    oth: q.BatchedQueue
    topk: q.BatchedQueue
    visited: Array  # (B, W) uint32
    cnt_sat: Array  # (B,) int32
    cnt_total: Array  # (B,) int32
    dist_evals: Array  # (B,) int32
    hops: Array  # (B,) int32
    done: Array  # (B,) bool
    iters: Array  # () int32


def _neighbor_distances(
    queries: Array,
    corpus_vectors: Array,
    nbrs: Array,
    use_kernel: bool,
    pq_codes: Optional[Array] = None,
    lut: Optional[Array] = None,
) -> Array:
    """(B, d) x (n, d) x (B, M) ids -> (B, M) squared distances.

    With (pq_codes, lut) set, distances are PQ/ADC approximations: gather
    m_sub code bytes per candidate instead of d floats (32x fewer HBM bytes
    at d=128, m_sub=16) and sum per-subspace LUT entries.
    """
    if lut is not None:
        safe = jnp.maximum(nbrs, 0)
        codes = pq_codes[safe]  # (B, M, m_sub)
        # d[b,m] = sum_s lut[b, s, codes[b,m,s]]
        gathered = jnp.take_along_axis(
            lut[:, None, :, :],  # (B, 1, m_sub, n_cent)
            codes[..., None],  # (B, M, m_sub, 1)
            axis=-1,
        )[..., 0]
        return jnp.sum(gathered, axis=-1)
    if use_kernel:
        from repro.kernels.gather_distance.ops import gather_distance

        return gather_distance(queries, corpus_vectors, nbrs)
    safe = jnp.maximum(nbrs, 0)
    rows = corpus_vectors[safe]  # (B, M, d)
    return batched_rowwise_sqdist(queries, rows)


def _seed_state(
    corpus: Corpus,
    graph: GraphIndex,
    queries: Array,
    satisfied,
    params: SearchParams,
    rng: Optional[Array],
    pq_codes: Optional[Array] = None,
    lut: Optional[Array] = None,
) -> tuple[_State, Array]:
    """Initialize queues/visited per mode; returns (state, alter_ratio (B,))."""
    b = queries.shape[0]
    n = corpus.n
    state = _State(
        sat=q.queue_init(b, params.ef_sat),
        oth=q.queue_init(b, params.ef_other),
        topk=q.queue_init(b, params.result_capacity),
        visited=vis.visited_init(b, n),
        cnt_sat=jnp.zeros((b,), jnp.int32),
        cnt_total=jnp.zeros((b,), jnp.int32),
        dist_evals=jnp.zeros((b,), jnp.int32),
        hops=jnp.zeros((b,), jnp.int32),
        done=jnp.zeros((b,), bool),
        iters=jnp.int32(0),
    )

    # --- global entry vertex (always seeded; exploration anchor + fallback) ---
    if params.mode == "vanilla" and rng is not None:
        entry = jax.random.randint(rng, (b,), 0, n, dtype=jnp.int32)
    else:
        entry = jnp.broadcast_to(graph.entry_point.astype(jnp.int32), (b,))
    d_entry = _neighbor_distances(
        queries, corpus.vectors, entry[:, None], params.use_kernel, pq_codes, lut
    )  # (B, 1)
    state = state.replace(
        oth=q.queue_push(state.oth, d_entry, entry[:, None], jnp.ones((b, 1), bool)),
        visited=vis.visited_set(state.visited, entry[:, None], jnp.ones((b, 1), bool)),
        dist_evals=state.dist_evals + 1,
    )

    ratio = jnp.full((b,), params.alter_ratio or 0.5, jnp.float32)

    sample = graph.sample_ids  # (S,)
    s = sample.shape[0]
    sample_ids_b = jnp.broadcast_to(sample[None, :], (b, s))
    if lut is not None:
        d_sample = _neighbor_distances(
            queries, corpus.vectors, sample_ids_b, False, pq_codes, lut
        )
    else:
        sample_vecs = corpus.vectors[sample]  # (S, d)
        d_sample = squared_l2(queries, sample_vecs)  # (B, S)

    if params.mode == "vanilla":
        # Flat kNN graphs lack HNSW's hierarchy for long-range navigation;
        # the standard fix is multi-start from the build-time sample
        # (UNCONSTRAINED here — the constraint plays no role in vanilla's
        # seeding, matching the paper's baseline semantics).
        n_start = min(params.n_start, s)
        neg_top, top_pos = jax.lax.top_k(-d_sample, n_start)
        start_d = -neg_top
        start_ids = jnp.take_along_axis(sample_ids_b, top_pos, axis=-1)
        fresh = ~vis.visited_test(state.visited, start_ids)
        state = state.replace(
            oth=q.queue_push(state.oth, start_d, start_ids, fresh),
            visited=vis.visited_set(state.visited, start_ids, fresh),
            dist_evals=state.dist_evals + s,
        )
        return state, ratio

    # --- AIRSHIP-Start: filter the pre-drawn sample by the constraint -------
    sample_sat = satisfied(sample_ids_b)  # (B, S)
    d_masked = jnp.where(sample_sat, d_sample, jnp.inf)

    n_start = min(params.n_start, s)
    neg_top, top_pos = jax.lax.top_k(-d_masked, n_start)  # best = smallest dist
    start_d = -neg_top  # (B, n_start)
    start_ids = jnp.take_along_axis(sample_ids_b, top_pos, axis=-1)
    start_valid = jnp.isfinite(start_d)
    # Entry vertex may coincide with a start — only set genuinely fresh bits.
    fresh = start_valid & ~vis.visited_test(state.visited, start_ids)

    target = "oth" if params.mode == "start" else "sat"
    pushed = q.queue_push(getattr(state, target), start_d, start_ids, fresh)
    state = state.replace(
        **{target: pushed},
        visited=vis.visited_set(state.visited, start_ids, fresh),
        dist_evals=state.dist_evals + s,  # the sample scan costs S distances
    )

    if params.mode in ("alter", "prefer") and params.alter_ratio is None:
        ratio = estimate_alter_ratio(
            graph, satisfied, sample_sat, params.alter_ratio_k
        )
    return state, ratio


@partial(jax.jit, static_argnames=("params",))
def constrained_search(
    corpus: Corpus,
    graph: GraphIndex,
    queries: Array,
    constraint,
    params: SearchParams,
    rng: Optional[Array] = None,
    pq_index=None,
) -> SearchResult:
    """Top-k constrained similarity search for a batch of queries.

    queries: (B, d). Returns ascending (B, K) distances/ids; unreachable
    slots hold (+inf, -1).

    With params.approx == "pq", ``pq_index`` (core.pq.PQIndex) drives the
    traversal with ADC distances; the ef_result survivors are re-ranked
    exactly before the final top-k (beyond-paper, EXPERIMENTS.md §Perf D4).
    """
    satisfied = make_satisfied_fn(constraint, corpus)
    if params.approx == "pq":
        if pq_index is None:
            raise ValueError("approx='pq' requires pq_index")
        from repro.core.pq import adc_table

        pq_codes = pq_index.codes
        lut = adc_table(pq_index, queries)
    else:
        pq_codes = lut = None
    state, ratio = _seed_state(
        corpus, graph, queries, satisfied, params, rng, pq_codes, lut
    )
    two_queue = params.mode in ("alter", "prefer")

    def cond(st: _State) -> Array:
        return jnp.any(~st.done) & (st.iters < params.max_iters)

    def body(st: _State) -> _State:
        sat_ne = q.queue_nonempty(st.sat)
        oth_ne = q.queue_nonempty(st.oth)
        # A row with both frontiers exhausted is finished.
        done_now = st.done | ~(sat_ne | oth_ne)

        # --- Alg. 3 (+ §2.5 override): frontier selection -------------------
        if two_queue:
            head_sat_d, _ = q.queue_head(st.sat)
            head_oth_d, _ = q.queue_head(st.oth)
            ratio_rule = st.cnt_sat.astype(jnp.float32) <= ratio * st.cnt_total.astype(
                jnp.float32
            )
            sel_sat = jnp.where(~oth_ne, True, jnp.where(~sat_ne, False, ratio_rule))
            if params.mode == "prefer":
                sel_sat = sel_sat | (sat_ne & (head_sat_d <= head_oth_d))
        else:
            sel_sat = jnp.zeros_like(done_now)

        # --- pop the selected frontier --------------------------------------
        live = ~done_now
        new_sat, sat_d, sat_i = q.queue_pop(st.sat, sel_sat & live)
        new_oth, oth_d, oth_i = q.queue_pop(st.oth, ~sel_sat & live)
        now_d = jnp.where(sel_sat, sat_d, oth_d)
        now_i = jnp.where(sel_sat, sat_i, oth_i)

        cnt_total = st.cnt_total + live.astype(jnp.int32)
        cnt_sat = st.cnt_sat + (sel_sat & live).astype(jnp.int32)

        # --- termination test (Alg. 1/2: break *before* the topk update) ----
        thr = q.topk_threshold(st.topk, params.result_capacity)
        done_next = done_now | (now_d > thr)
        expand = ~done_next

        # --- result update ---------------------------------------------------
        if two_queue:
            # pq_sat only ever holds satisfied vertices.
            upd = expand & sel_sat
        else:
            upd = expand & satisfied(now_i[:, None])[:, 0]
        topk = q.queue_push(st.topk, now_d[:, None], now_i[:, None], upd[:, None])

        # --- expansion --------------------------------------------------------
        safe_now = jnp.maximum(now_i, 0)
        nbrs = graph.neighbors[safe_now]  # (B, deg)
        nb_valid = (nbrs >= 0) & expand[:, None]
        fresh = nb_valid & ~vis.visited_test(st.visited, nbrs)
        d_nb = _neighbor_distances(
            queries, corpus.vectors, nbrs, params.use_kernel, pq_codes, lut
        )
        if two_queue:
            nb_sat = satisfied(nbrs) & fresh
            sat_q = q.queue_push(new_sat, d_nb, nbrs, nb_sat)
            oth_q = q.queue_push(new_oth, d_nb, nbrs, fresh & ~nb_sat)
        else:
            sat_q = new_sat
            oth_q = q.queue_push(new_oth, d_nb, nbrs, fresh)

        return _State(
            sat=sat_q,
            oth=oth_q,
            topk=topk,
            visited=vis.visited_set(st.visited, nbrs, fresh),
            cnt_sat=cnt_sat,
            cnt_total=cnt_total,
            dist_evals=st.dist_evals + jnp.sum(fresh, axis=-1, dtype=jnp.int32),
            hops=st.hops + expand.astype(jnp.int32),
            done=done_next,
            iters=st.iters + 1,
        )

    final = jax.lax.while_loop(cond, body, state)
    stats = SearchStats(
        dist_evals=final.dist_evals,
        hops=final.hops,
        visited=vis.visited_count(final.visited),
        iters=final.iters,
    )
    out_d, out_i = final.topk.dists, final.topk.ids
    if params.approx == "pq":
        # Exact re-rank of the ef_result survivors (ADC ordered the walk;
        # exact distances order the answer).
        exact_d = _neighbor_distances(queries, corpus.vectors, out_i, False)
        exact_d = jnp.where(out_i >= 0, exact_d, jnp.inf)
        order = jnp.argsort(exact_d, axis=-1)
        out_d = jnp.take_along_axis(exact_d, order, axis=-1)
        out_i = jnp.take_along_axis(out_i, order, axis=-1)
        out_i = jnp.where(jnp.isfinite(out_d), out_i, -1)
    # The ef_result-sized candidate list is truncated to the requested top-k.
    return SearchResult(
        dists=out_d[:, : params.k],
        ids=out_i[:, : params.k],
        stats=stats,
    )
