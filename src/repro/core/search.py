"""Batched, lock-step constrained proximity-graph search (AIRSHIP core).

Facade over the beam-parallel traversal engine (``repro.core.engine``,
DESIGN.md §5/§6), which implements the paper's four algorithm variants
behind one compiled loop:

  * ``vanilla``  — Alg. 1: single frontier, constraint checked on pop.
  * ``start``    — §2.2: + satisfied starting points from the pre-drawn sample.
  * ``alter``    — §2.3/Alg. 2+3: two frontiers (satisfied / other) selected by
                   ``alter_ratio`` (estimated via Eq. 1 when not given).
  * ``prefer``   — §2.5: + biased selection (override the ratio whenever the
                   best satisfied candidate beats the best unsatisfied one).

TPU adaptation (see DESIGN.md §2): fixed-capacity sorted-array queues, bitset
visited, one `lax.while_loop` over the whole query batch with per-query done
masks, and a fused gather+distance step fed ``beam_width * deg`` candidates
per iteration.

Every physical choice — which distance backend scores candidates (exact
rows, the Pallas gather kernel, or PQ/ADC codes), the constraint closure
and its raw in-kernel tables, and the fuse decision — is resolved once
into a ``TraversalContext`` (engine/context.py) and threaded through the
engine as one argument; ``SearchParams.use_kernel`` / ``approx`` /
``fuse_expand`` merely select it.

The engine split (context / policy / expand / loop) lives in
``core/engine/``; this module only re-exports the public entry points so
the historical import path ``repro.core.search.constrained_search`` keeps
working.
"""
from __future__ import annotations

from repro.core.engine.context import (
    DistanceBackend,
    ExactBackend,
    L2KernelBackend,
    PQBackend,
    TraversalContext,
    build_context,
)
from repro.core.engine.loop import constrained_search, search_with_context

__all__ = [
    "DistanceBackend",
    "ExactBackend",
    "L2KernelBackend",
    "PQBackend",
    "TraversalContext",
    "build_context",
    "constrained_search",
    "search_with_context",
]
