"""Query-constraint representations.

The paper models the constraint as an arbitrary user-defined predicate
``f(v) -> bool``. On TPU we need the predicate to be (a) vectorizable over
candidate ids and (b) expressible as per-query *data* so that one compiled
search serves every query. Three families cover the paper's experiments and
the common production cases, plus an escape hatch for arbitrary jnp UDFs:

  * ``LabelSetConstraint`` — per-query bitmask over label ids. Covers the
    paper's ``equal`` and ``unequal-X%`` constraint families and any
    category-membership filter (up to a few thousand distinct labels).
  * ``RangeConstraint`` — per-query [lo, hi] window over one numeric
    attribute column.
  * ``udf_satisfied_fn`` — wraps any jnp-traceable predicate over corpus
    attributes (compiled per distinct UDF, like the paper's templated C++).

Every family lowers to a ``SatisfiedFn: (B, M) ids -> (B, M) bool`` closed
over the corpus attribute arrays; the search core only sees that interface.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from typing import Optional

from repro.common.pytree import pytree_dataclass, static_field
from repro.core.types import Corpus, SatisfiedFn

Array = jax.Array

WORD_BITS = 32


@pytree_dataclass
class LabelSetConstraint:
    """Per-query allowed-label set as a bitmask: (B, ceil(L/32)) uint32."""

    words: Array

    @property
    def batch(self) -> int:
        return self.words.shape[0]


@pytree_dataclass
class RangeConstraint:
    """Per-query numeric window over attribute column ``col`` (static)."""

    lo: Array  # (B,)
    hi: Array  # (B,)
    col: Array  # () int32 — attribute column index


def _label_words(n_labels: int) -> int:
    return (n_labels + WORD_BITS - 1) // WORD_BITS


def label_set_from_lists(
    allowed: Sequence[Sequence[int]], n_labels: int
) -> LabelSetConstraint:
    """Host-side builder from explicit python label lists."""
    w = _label_words(n_labels)
    out = np.zeros((len(allowed), w), dtype=np.uint32)
    for i, labels in enumerate(allowed):
        for lab in labels:
            out[i, lab // WORD_BITS] |= np.uint32(1) << np.uint32(lab % WORD_BITS)
    return LabelSetConstraint(words=jnp.asarray(out))


def equal_constraint(query_labels: Array, n_labels: int) -> LabelSetConstraint:
    """Paper §3 'equal': results must share the query's label."""
    b = query_labels.shape[0]
    w = _label_words(n_labels)
    words = jnp.zeros((b, w), dtype=jnp.uint32)
    widx = query_labels // WORD_BITS
    bit = jnp.uint32(1) << (query_labels % WORD_BITS).astype(jnp.uint32)
    return LabelSetConstraint(
        words=words.at[jnp.arange(b), widx].set(bit)
    )


def unequal_pct_constraint(
    rng: Array, query_labels: Array, n_labels: int, pct: float
) -> LabelSetConstraint:
    """Paper §3 'unequal-X%': allow a random X% of labels, all != query label.

    ``pct`` in (0, 100]. At least one label is always allowed.
    """
    b = query_labels.shape[0]
    n_allowed = max(1, int(round(n_labels * pct / 100.0)))
    # Random scores; the query's own label is pushed to the back so the top
    # n_allowed picks are all unequal.
    scores = jax.random.uniform(rng, (b, n_labels))
    scores = scores.at[jnp.arange(b), query_labels].set(jnp.inf)
    picked = jnp.argsort(scores, axis=-1)[:, :n_allowed]  # (B, n_allowed)
    w = _label_words(n_labels)
    words = jnp.zeros((b, w), dtype=jnp.uint32)
    widx = picked // WORD_BITS
    bits = jnp.uint32(1) << (picked % WORD_BITS).astype(jnp.uint32)
    rows = jnp.broadcast_to(jnp.arange(b)[:, None], picked.shape)
    # Distinct labels -> distinct (word,bit); add == or.
    return LabelSetConstraint(words=words.at[rows, widx].add(bits))


def label_satisfied_fn(
    constraint: LabelSetConstraint, corpus: Corpus
) -> SatisfiedFn:
    labels = corpus.labels

    def satisfied(ids: Array) -> Array:  # (B, M) -> (B, M)
        safe = jnp.maximum(ids, 0)
        lab = labels[safe]  # (B, M)
        widx = lab // WORD_BITS
        bit = (lab % WORD_BITS).astype(jnp.uint32)
        word = jnp.take_along_axis(constraint.words, widx, axis=-1)
        ok = ((word >> bit) & jnp.uint32(1)).astype(bool)
        return jnp.where(ids >= 0, ok, False)

    return satisfied


def range_satisfied_fn(constraint: RangeConstraint, corpus: Corpus) -> SatisfiedFn:
    if corpus.attrs is None:
        raise ValueError("corpus has no numeric attributes")
    attrs = corpus.attrs

    def satisfied(ids: Array) -> Array:
        safe = jnp.maximum(ids, 0)
        val = attrs[safe, constraint.col]  # (B, M)
        ok = (val >= constraint.lo[:, None]) & (val <= constraint.hi[:, None])
        return jnp.where(ids >= 0, ok, False)

    return satisfied


def udf_satisfied_fn(
    udf: Callable[[Array, Array], Array], corpus: Corpus
) -> SatisfiedFn:
    """Arbitrary jnp predicate ``udf(labels, attrs_row) -> bool``, vmapped.

    The UDF receives the candidate's label (scalar) and attribute row (m,)
    and must be jnp-traceable. One compiled search per distinct UDF — the
    same cost model as the paper's templated C++ filter.
    """
    labels = corpus.labels
    attrs = (
        corpus.attrs
        if corpus.attrs is not None
        else jnp.zeros((corpus.n, 0), jnp.float32)
    )
    per_item = jax.vmap(jax.vmap(udf))

    def satisfied(ids: Array) -> Array:
        safe = jnp.maximum(ids, 0)
        ok = per_item(labels[safe], attrs[safe])
        return jnp.where(ids >= 0, ok, False)

    return satisfied


@pytree_dataclass
class ConstraintTables:
    """Raw table views of a constraint for in-kernel evaluation.

    The fused-expansion kernel (kernels/fused_expand/) cannot call a
    ``SatisfiedFn`` closure; it needs the underlying arrays: the corpus-side
    metadata column it gathers per candidate (one 4-byte word alongside the
    vector row, instead of a second HBM round trip) and the per-query operand
    it keeps resident in VMEM.

    family: "label" — meta is the (n,) int32 label column, cons the
            (B, Lw) uint32 allowed-label bitmask words;
            "range" — meta is the (n,) f32 attribute column, cons the
            (B, 2) f32 [lo, hi] bounds;
            "udf"   — meta is the (n,) int32 precompiled predicate column
            (the UDF evaluated over every vertex's label/attribute row at
            table-build time — UDFs are query-independent by contract, so
            one evaluation serves the whole batch), cons a (1, 1) dummy
            (there is no per-query operand; the kernels pin its block).
    """

    meta: Array
    cons: Array
    family: str = static_field(default="label")
    # Corpus-wide tombstone bitmap ((ceil(n/32),) uint32) from
    # ``Corpus.tombstones``: the kernels AND the candidate's bit into the
    # satisfied verdict so a deleted slot fails exactly like a failed
    # constraint. None for static (never-mutated) indexes — the kernels
    # then skip the probe entirely.
    tomb: Optional[Array] = None


def udf_predicate_table(
    udf: Callable[[Array, Array], Array], corpus: Corpus
) -> Array:
    """Precompile a UDF into its (n,) int32 verdict column.

    The UDF contract (``udf_satisfied_fn``) is a pure predicate over the
    vertex's label and attribute row — query-independent — so evaluating
    it once over the whole corpus yields a metadata column the fused
    kernels consume exactly like the label/range columns (one 4-byte word
    riding the candidate-row DMA). Unlike a VMEM-resident bitmap this
    scales to any corpus size. O(n) work: ``constraint_tables`` only
    builds it when the caller opts in (``include_udf``), i.e. when the
    fused path is actually reachable.
    """
    labels = corpus.labels
    attrs = (
        corpus.attrs
        if corpus.attrs is not None
        else jnp.zeros((corpus.n, 0), jnp.float32)
    )
    return jax.vmap(udf)(labels, attrs).astype(jnp.int32)


def constraint_tables(
    constraint, corpus: Corpus, include_udf: bool = False
) -> Optional[ConstraintTables]:
    """Raw views for the fused kernel; None for UDF closures unless
    ``include_udf`` (precompiling the predicate table is O(n), so callers
    that never fuse — estimators, routers — keep the historical None)."""
    if isinstance(constraint, LabelSetConstraint):
        return ConstraintTables(
            meta=corpus.labels, cons=constraint.words, family="label",
            tomb=corpus.tombstones,
        )
    if isinstance(constraint, RangeConstraint):
        if corpus.attrs is None:
            raise ValueError("corpus has no numeric attributes")
        return ConstraintTables(
            meta=corpus.attrs[:, constraint.col].astype(jnp.float32),
            cons=jnp.stack(
                [constraint.lo.astype(jnp.float32),
                 constraint.hi.astype(jnp.float32)], axis=-1,
            ),
            family="range",
            tomb=corpus.tombstones,
        )
    if callable(constraint) and include_udf:
        return ConstraintTables(
            meta=udf_predicate_table(constraint, corpus),
            cons=jnp.zeros((1, 1), jnp.int32),  # no per-query operand
            family="udf",
            tomb=corpus.tombstones,
        )
    return None


def tombstone_test(tomb: Array, ids: Array) -> Array:
    """(W,) uint32 x (B, M) ids -> (B, M) bool — is each id tombstoned?

    Padding ids (< 0) report as tombstoned (they are not returnable either
    way). The bitmap is corpus-wide, not per-query, so one word gather
    serves the whole batch.
    """
    safe = jnp.maximum(ids, 0)
    word = tomb[safe // WORD_BITS]
    bit = (safe % WORD_BITS).astype(jnp.uint32)
    dead = ((word >> bit) & jnp.uint32(1)).astype(bool)
    return jnp.where(ids >= 0, dead, True)


def make_satisfied_fn(constraint, corpus: Corpus) -> SatisfiedFn:
    if isinstance(constraint, LabelSetConstraint):
        base = label_satisfied_fn(constraint, corpus)
    elif isinstance(constraint, RangeConstraint):
        base = range_satisfied_fn(constraint, corpus)
    elif callable(constraint):
        base = udf_satisfied_fn(constraint, corpus)
    else:
        raise TypeError(f"unsupported constraint: {type(constraint)}")
    if corpus.tombstones is None:
        return base
    # Streaming mutable index: a tombstoned slot fails EVERY constraint
    # family — deleted vectors stay traversable (frontier pushes key on
    # ``fresh``, not ``satisfied``) but can never re-enter a result list.
    tomb = corpus.tombstones

    def satisfied(ids: Array) -> Array:
        return base(ids) & ~tombstone_test(tomb, ids)

    return satisfied


def selectivity(constraint, corpus: Corpus, chunk: int = 1 << 16) -> Array:
    """(B,) fraction of the corpus satisfying each query's constraint.

    Thin wrapper kept for the historical import path — the implementation
    (and every other selectivity probe: the sampled satisfied-fraction, the
    streaming histograms' host-side estimates) lives in the shared
    estimator module, ``repro.core.estimator`` (lazy import: estimator
    imports this module at load time).
    """
    from repro.core.estimator import scan_selectivity

    return scan_selectivity(constraint, corpus, chunk=chunk)
