"""Frontier-selection policies — which queue feeds the next expansion.

Each of the paper's four algorithm variants reduces to one small pure
function over the two frontier heads and the pop counters, evaluated once
per beam slot (DESIGN.md §5). A policy maps the current traversal view to a
``(B,) bool`` mask ``sel_sat`` — True selects the satisfied frontier, False
the other frontier:

  * ``vanilla`` / ``start`` — single frontier: everything lives in ``oth``,
    so the policy is the constant False.
  * ``alter``  — Alg. 3: keep the satisfied share of pops at ``alter_ratio``
    (``cnt_sat <= ratio * cnt_total``), falling back to whichever queue is
    non-empty.
  * ``prefer`` — §2.5: ``alter`` plus an override whenever the best
    satisfied candidate already beats the best unsatisfied one.

New policies (e.g. learned or per-tenant selection rules) plug in by
registering a function of the same signature — the loop and expansion
layers never branch on the mode themselves.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import queue as q

Array = jax.Array

# (sat_queue, oth_queue, cnt_sat (B,), cnt_total (B,), ratio (B,)) -> (B,) bool
FrontierPolicy = Callable[
    [q.BatchedQueue, q.BatchedQueue, Array, Array, Array], Array
]


def single_queue_policy(
    sat: q.BatchedQueue, oth: q.BatchedQueue, cnt_sat, cnt_total, ratio
) -> Array:
    """vanilla / start: one frontier — always pop ``oth``."""
    return jnp.zeros((oth.batch,), bool)


def ratio_policy(
    sat: q.BatchedQueue, oth: q.BatchedQueue, cnt_sat, cnt_total, ratio
) -> Array:
    """Alg. 3 alternation: hold the satisfied pop share at ``ratio``."""
    sat_ne = q.queue_nonempty(sat)
    oth_ne = q.queue_nonempty(oth)
    rule = cnt_sat.astype(jnp.float32) <= ratio * cnt_total.astype(jnp.float32)
    return jnp.where(~oth_ne, True, jnp.where(~sat_ne, False, rule))


def prefer_policy(
    sat: q.BatchedQueue, oth: q.BatchedQueue, cnt_sat, cnt_total, ratio
) -> Array:
    """§2.5 biased selection: ratio rule + best-satisfied-head override."""
    sel = ratio_policy(sat, oth, cnt_sat, cnt_total, ratio)
    head_sat_d, _ = q.queue_head(sat)
    head_oth_d, _ = q.queue_head(oth)
    return sel | (q.queue_nonempty(sat) & (head_sat_d <= head_oth_d))


POLICIES: Dict[str, FrontierPolicy] = {
    "vanilla": single_queue_policy,
    "start": single_queue_policy,
    "alter": ratio_policy,
    "prefer": prefer_policy,
}


def get_policy(mode: str) -> FrontierPolicy:
    return POLICIES[mode]


def is_two_queue(mode: str) -> bool:
    """Modes that maintain a separate satisfied frontier."""
    return mode in ("alter", "prefer")
