"""TraversalContext: one bundle for everything a traversal scores with.

The AIRSHIP walk is distance-backend-agnostic — each iteration only needs
"score this candidate batch against the query" plus the constraint verdicts
and a fuse decision. Before this module those choices travelled through the
engine as a ``(use_kernel, pq_codes, lut)`` positional soup; now they are
resolved ONCE, in ``build_context``, and the engine layers receive a single
``TraversalContext`` argument (DESIGN.md §6).

Distance backends (each a pytree holding exactly the arrays it scores with):

  * ``ExactBackend``    — gathered corpus rows + ``batched_rowwise_sqdist``
                          (the seed computation, golden-tested bit-for-bit).
  * ``L2KernelBackend`` — the Pallas ``gather_distance`` kernel over the same
                          rows (``SearchParams.use_kernel``).
  * ``PQBackend``       — ADC lookups against a per-query LUT: m_sub code
                          words per candidate instead of d floats, exact
                          re-rank post-loop (``SearchParams.approx == "pq"``).

Every backend exposes

  * ``distances(queries, ids) -> (B, M)`` — score a gathered candidate batch;
  * ``sample_distances(queries, sample_ids) -> (B, S)`` — score the pre-drawn
    build-time sample shared by all queries (exact backends use the pairwise
    matmul expansion here, matching the seed bit-for-bit);
  * ``fused_expand(queries, ids, visited, tables)`` — the one-pass
    gather+distance+constraint+visited kernel of ``kernels/fused_expand``
    (exact rows for the L2 backends, code rows + in-kernel LUT sums for PQ);
  * ``fusable`` / ``approximate`` properties — whether the fused pipeline has
    a kernel for this backend, and whether results need an exact re-rank.

New backends (e.g. learned similarity metrics, NANN-style) plug in by
implementing the same surface; the engine never branches on backend type.
"""
from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.common.distances import batched_rowwise_sqdist, squared_l2
from repro.common.pytree import pytree_dataclass, static_field
from repro.core.constraints import (
    ConstraintTables,
    constraint_tables,
    make_satisfied_fn,
)
from repro.core.types import Corpus, SatisfiedFn, SearchParams
from repro.tune.config import DEFAULT_CONFIGS, KernelConfig
from repro.tune.table import lookup as tune_lookup

Array = jax.Array


# Flip to True once the fused kernels have been validated under compiled
# Mosaic lowering on real hardware (the per-candidate scalar stores and
# narrow metadata/code DMAs have only ever run in interpret mode on this
# container). Until then "auto" never routes a default search through an
# unproven compile path; the fused pipeline is opt-in via fuse_expand="on".
FUSE_AUTO_ON_TPU = False


def resolve_auto_fuse(fusable: bool, backend: str) -> bool:
    """fuse_expand == "auto" policy: where does fusing actually win?

    Both paths return bit-identical results (system-tested); the choice is
    purely physical. On TPU the fused kernel eliminates the separate
    metadata/visited HBM round trips and the windowed sorted merges are
    plain VPU work — that is where auto is meant to fuse, gated on
    ``FUSE_AUTO_ON_TPU`` until hardware validation. On XLA:CPU,
    measurement says fusing loses: the native TopK a ``queue_push``
    lowers to is data-dependent (fast on the inf-padded queues real
    traversals carry) and keeps donated-buffer reuse inside
    ``lax.while_loop``, while the merge's compare-exchange chain forces
    per-iteration copies — standalone the merge wins 2–3.5x, in-loop it
    loses ~2x (EXPERIMENTS.md §Perf PR2). So auto only fuses where the
    memory system, not the op dispatcher, is the bottleneck.
    """
    return fusable and backend == "tpu" and FUSE_AUTO_ON_TPU


class _RowBackend:
    """Shared surface for backends that score full (n, d) corpus rows.

    Subclasses hold ``vectors`` and override only ``distances`` — the
    sample scan and the fused kernel dispatch are identical for every
    exact-L2 flavor (the fused kernel gathers and scores rows itself, so
    it subsumes whatever unfused distance path the subclass picks).
    """

    vectors: Array  # (n, d)
    config: KernelConfig  # static: fused-kernel block shapes (tune table)

    @property
    def fusable(self) -> bool:
        return True

    @property
    def approximate(self) -> bool:
        return False

    def sample_distances(self, queries: Array, sample_ids: Array) -> Array:
        # The sample is shared by every query, so one gather + the pairwise
        # matmul expansion beats a per-query gather (and reproduces the
        # seed's seeding distances bit-for-bit).
        return squared_l2(queries, self.vectors[sample_ids])

    def fused_expand(
        self, queries: Array, ids: Array, visited: Array, tables: ConstraintTables
    ) -> Tuple[Array, Array, Array]:
        from repro.kernels.fused_expand.ops import fused_expand

        return fused_expand(
            queries, self.vectors, ids, visited,
            tables.meta, tables.cons, tables.tomb, family=tables.family,
            config=self.config,
        )


@pytree_dataclass
class ExactBackend(_RowBackend):
    """Exact squared-L2 over gathered corpus rows (the seed computation)."""

    vectors: Array  # (n, d)
    # Static aux data: configs select compiled kernel variants, so they
    # ride the treedef (same shapes + same table -> same trace).
    config: KernelConfig = static_field(default=DEFAULT_CONFIGS["fused_exact"])

    def distances(self, queries: Array, ids: Array) -> Array:
        safe = jnp.maximum(ids, 0)
        return batched_rowwise_sqdist(queries, self.vectors[safe])


@pytree_dataclass
class L2KernelBackend(_RowBackend):
    """Pallas ``gather_distance`` kernel over the same corpus rows.

    Identical mathematics to ``ExactBackend`` — the kernel fuses the row
    gather with the VPU distance reduction (one HBM visit per candidate).
    Selected by ``SearchParams.use_kernel``.
    """

    vectors: Array  # (n, d)
    config: KernelConfig = static_field(default=DEFAULT_CONFIGS["fused_exact"])
    # The unfused per-iteration distances go through gather_distance, a
    # separately-tuned kernel (its own tuning-table key).
    gd_config: KernelConfig = static_field(
        default=DEFAULT_CONFIGS["gather_distance"]
    )

    def distances(self, queries: Array, ids: Array) -> Array:
        from repro.kernels.gather_distance.ops import gather_distance

        return gather_distance(queries, self.vectors, ids, config=self.gd_config)


@pytree_dataclass
class PQBackend:
    """PQ/ADC approximate distances: per-candidate code rows + per-query LUT.

    Gathers m_sub code words per candidate instead of d floats (32x fewer
    HBM bytes at d=128, m_sub=16) and sums per-subspace LUT entries. The
    walk ranks by these; the engine re-ranks the surviving candidate list
    exactly after the loop (``approximate`` property).
    """

    codes: Array  # (n, m_sub) int32
    lut: Array  # (B, m_sub, n_cent) f32 — per-query ADC table
    config: KernelConfig = static_field(default=DEFAULT_CONFIGS["fused_adc"])

    @property
    def fusable(self) -> bool:
        return True

    @property
    def approximate(self) -> bool:
        return True

    def distances(self, queries: Array, ids: Array) -> Array:
        del queries  # the LUT already encodes the query side
        safe = jnp.maximum(ids, 0)
        codes = self.codes[safe]  # (B, M, m_sub)
        # d[b,m] = sum_s lut[b, s, codes[b,m,s]]
        gathered = jnp.take_along_axis(
            self.lut[:, None, :, :],  # (B, 1, m_sub, n_cent)
            codes[..., None],  # (B, M, m_sub, 1)
            axis=-1,
        )[..., 0]
        return jnp.sum(gathered, axis=-1)

    def sample_distances(self, queries: Array, sample_ids: Array) -> Array:
        b = self.lut.shape[0]
        ids_b = jnp.broadcast_to(sample_ids[None, :], (b, sample_ids.shape[0]))
        return self.distances(queries, ids_b)

    def scan_all(self) -> Array:
        """ADC distances to every corpus row: (B, n) — the linear-scan
        baseline's hot loop (core/pq.py), sharing this backend's tables."""
        gathered = jnp.take_along_axis(
            self.lut[:, None, :, :],  # (B, 1, m_sub, n_cent)
            self.codes[None, :, :, None],  # (1, n, m_sub, 1)
            axis=-1,
        )[..., 0]
        return jnp.sum(gathered, axis=-1)

    def fused_expand(
        self, queries: Array, ids: Array, visited: Array, tables: ConstraintTables
    ) -> Tuple[Array, Array, Array]:
        del queries
        from repro.kernels.fused_expand.ops import fused_expand_adc

        return fused_expand_adc(
            self.lut, self.codes, ids, visited,
            tables.meta, tables.cons, tables.tomb, family=tables.family,
            config=self.config,
        )


DistanceBackend = Union[ExactBackend, L2KernelBackend, PQBackend]


@pytree_dataclass
class TraversalContext:
    """Everything the engine scores/filters with, resolved once per search.

    backend  — the distance path (arrays it scores with are pytree children,
               so per-shard contexts shard with their corpus rows);
    tables   — the constraint's raw table views for in-kernel evaluation,
               None for UDF closures (which force the unfused path); carries
               the corpus tombstone bitmap (streaming mutable index) so the
               fused kernels mask deleted slots exactly like a failed
               constraint — the unfused path gets the same mask via the
               tombstone-wrapped ``satisfied`` closure;
    satisfied — the (B, M) ids -> bool constraint closure (static: it is
               trace-time code, never crosses a jit boundary as data);
    fuse     — the resolved fuse decision (static: it selects the compiled
               loop body).
    """

    backend: DistanceBackend
    tables: Optional[ConstraintTables]
    satisfied: SatisfiedFn = static_field()
    fuse: bool = static_field(default=False)


def build_context(
    corpus: Corpus,
    constraint,
    queries: Array,
    params: SearchParams,
    pq_index=None,
    degree: int = 0,
) -> TraversalContext:
    """Resolve (params, constraint, corpus) into one TraversalContext.

    Called once per (local or per-shard) search: selects the distance
    backend from ``params.approx`` / ``params.use_kernel``, builds the
    constraint closure and its raw table views (including the precompiled
    UDF predicate column whenever the fused path is reachable — UDFs are
    no longer ``fusable=False``), resolves the kernel block-shape configs
    from the committed tuning table (``repro.tune``, keyed on payload
    width x ``degree`` x beam x platform; nearest-shape fallback, pure
    host-side python at trace time), and fixes the fuse decision. Raises
    for approx="pq" without a pq_index. ``degree`` is the graph degree
    when the caller has one (0 = unknown: the table lookup then matches
    on the remaining key dims).
    """
    satisfied = make_satisfied_fn(constraint, corpus)
    # The UDF predicate table costs an O(n) sweep, so it is only built
    # when the fused path could consume it; label/range views are free.
    tables = constraint_tables(
        constraint, corpus, include_udf=params.fuse_expand != "off"
    )
    platform = jax.default_backend()
    beam = params.beam_width
    if params.approx == "pq":
        if pq_index is None:
            raise ValueError("approx='pq' requires pq_index")
        from repro.core.pq import adc_table

        backend: DistanceBackend = PQBackend(
            codes=pq_index.codes,
            lut=adc_table(pq_index, queries),
            config=tune_lookup(
                "fused_adc", d=int(pq_index.codes.shape[1]),
                deg=degree, beam=beam, platform=platform,
            ),
        )
    elif params.use_kernel:
        backend = L2KernelBackend(
            vectors=corpus.vectors,
            config=tune_lookup(
                "fused_exact", d=corpus.dim, deg=degree, beam=beam,
                platform=platform,
            ),
            gd_config=tune_lookup(
                "gather_distance", d=corpus.dim, deg=degree, beam=beam,
                platform=platform,
            ),
        )
    else:
        backend = ExactBackend(
            vectors=corpus.vectors,
            config=tune_lookup(
                "fused_exact", d=corpus.dim, deg=degree, beam=beam,
                platform=platform,
            ),
        )

    fusable = tables is not None and backend.fusable
    if params.fuse_expand == "on" and not fusable:
        raise ValueError(
            "fuse_expand='on' requires constraint tables (got a "
            "non-constraint object the kernels cannot evaluate)"
        )
    fuse = params.fuse_expand == "on" or (
        params.fuse_expand == "auto"
        and resolve_auto_fuse(fusable, platform)
    )
    return TraversalContext(
        backend=backend, tables=tables, satisfied=satisfied, fuse=fuse
    )
