"""The compiled traversal loop: state, termination, stats (DESIGN.md §5).

One `lax.while_loop` advances the whole query batch in lock-step. Each
iteration delegates to the sibling layers — ``policy`` decides which
frontier feeds each beam slot, ``expand`` pops the beam and performs the
single flattened gather+distance through the ``TraversalContext``'s
distance backend (``context.py``) — and this module owns everything that
survives between iterations: queue/bitset state, the per-query done masks,
the Alg. 1/2 threshold termination, and the instrumentation counters.

``constrained_search`` is the jitted public entry: it resolves the
(params, constraint, corpus) triple into ONE ``TraversalContext`` via
``build_context`` and hands it to ``search_with_context`` — the
context-level entry the distributed layer calls directly with per-shard
contexts (core/distributed.py).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import pytree_dataclass
from repro.core import queue as q
from repro.core import visited as vis
from repro.core.alter_ratio import estimate_alter_ratio
from repro.core.estimator import sample_satisfied_mask
from repro.core.engine.context import (
    ExactBackend,
    TraversalContext,
    build_context,
)
from repro.core.engine.expand import (
    expand_beam,
    expand_beam_fused,
    mask_first_occurrence,
    pop_frontier_beam,
)
from repro.core.engine.policy import is_two_queue
from repro.core.types import (
    Corpus,
    GraphIndex,
    SearchParams,
    SearchResult,
    SearchStats,
)

Array = jax.Array


@pytree_dataclass
class TraversalState:
    sat: q.BatchedQueue
    oth: q.BatchedQueue
    topk: q.BatchedQueue
    visited: Array  # (B, W) uint32
    cnt_sat: Array  # (B,) int32
    cnt_total: Array  # (B,) int32
    dist_evals: Array  # (B,) int32
    hops: Array  # (B,) int32
    beam_expanded: Array  # (B, beam_width) int32
    done: Array  # (B,) bool
    iters: Array  # () int32


def seed_state(
    corpus: Corpus,
    graph: GraphIndex,
    queries: Array,
    ctx: TraversalContext,
    params: SearchParams,
    rng: Optional[Array],
) -> tuple[TraversalState, Array]:
    """Initialize queues/visited per mode; returns (state, alter_ratio (B,))."""
    b = queries.shape[0]
    n = corpus.n
    state = TraversalState(
        sat=q.queue_init(b, params.ef_sat),
        oth=q.queue_init(b, params.ef_other),
        topk=q.queue_init(b, params.result_capacity),
        visited=vis.visited_init(b, n),
        cnt_sat=jnp.zeros((b,), jnp.int32),
        cnt_total=jnp.zeros((b,), jnp.int32),
        dist_evals=jnp.zeros((b,), jnp.int32),
        hops=jnp.zeros((b,), jnp.int32),
        beam_expanded=jnp.zeros((b, params.beam_width), jnp.int32),
        done=jnp.zeros((b,), bool),
        iters=jnp.int32(0),
    )

    # --- global entry vertex (always seeded; exploration anchor + fallback) ---
    if params.mode == "vanilla" and rng is not None:
        entry = jax.random.randint(rng, (b,), 0, n, dtype=jnp.int32)
    else:
        entry = jnp.broadcast_to(graph.entry_point.astype(jnp.int32), (b,))
    d_entry = ctx.backend.distances(queries, entry[:, None])  # (B, 1)
    state = state.replace(
        oth=q.queue_push(state.oth, d_entry, entry[:, None], jnp.ones((b, 1), bool)),
        visited=vis.visited_set(state.visited, entry[:, None], jnp.ones((b, 1), bool)),
        dist_evals=state.dist_evals + 1,
    )

    ratio = jnp.full((b,), params.alter_ratio or 0.5, jnp.float32)

    sample = graph.sample_ids  # (S,)
    s = sample.shape[0]
    sample_ids_b = jnp.broadcast_to(sample[None, :], (b, s))
    d_sample = ctx.backend.sample_distances(queries, sample)  # (B, S)

    if params.mode == "vanilla":
        # Flat kNN graphs lack HNSW's hierarchy for long-range navigation;
        # the standard fix is multi-start from the build-time sample
        # (UNCONSTRAINED here — the constraint plays no role in vanilla's
        # seeding, matching the paper's baseline semantics).
        n_start = min(params.n_start, s)
        neg_top, top_pos = jax.lax.top_k(-d_sample, n_start)
        start_d = -neg_top
        start_ids = jnp.take_along_axis(sample_ids_b, top_pos, axis=-1)
        fresh = ~vis.visited_test(state.visited, start_ids)
        # The visited scatter-ADD needs dup-free rows; a static build's
        # sample is drawn without replacement but a streaming index's
        # maintained sample may repeat ids — keep only the first copy
        # (exact no-op for dup-free samples, so the golden path is
        # bit-identical).
        fresh = mask_first_occurrence(start_ids, fresh)
        state = state.replace(
            oth=q.queue_push(state.oth, start_d, start_ids, fresh),
            visited=vis.visited_set(state.visited, start_ids, fresh),
            dist_evals=state.dist_evals + s,
        )
        return state, ratio

    # --- AIRSHIP-Start: filter the pre-drawn sample by the constraint -------
    # Shared probe (core/estimator.py): the same mask feeds start-point
    # selection here, Eq.-1 alter_ratio below, and — host-side — the hybrid
    # router's sampled-selectivity fallback.
    sample_sat = sample_satisfied_mask(ctx.satisfied, sample, b)  # (B, S)
    d_masked = jnp.where(sample_sat, d_sample, jnp.inf)

    n_start = min(params.n_start, s)
    neg_top, top_pos = jax.lax.top_k(-d_masked, n_start)  # best = smallest dist
    start_d = -neg_top  # (B, n_start)
    start_ids = jnp.take_along_axis(sample_ids_b, top_pos, axis=-1)
    start_valid = jnp.isfinite(start_d)
    # Entry vertex may coincide with a start — only set genuinely fresh bits.
    fresh = start_valid & ~vis.visited_test(state.visited, start_ids)
    # Dup-free guard for the visited scatter-ADD (see the vanilla branch).
    fresh = mask_first_occurrence(start_ids, fresh)

    target = "oth" if params.mode == "start" else "sat"
    pushed = q.queue_push(getattr(state, target), start_d, start_ids, fresh)
    state = state.replace(
        **{target: pushed},
        visited=vis.visited_set(state.visited, start_ids, fresh),
        dist_evals=state.dist_evals + s,  # the sample scan costs S distances
    )

    if params.mode in ("alter", "prefer") and params.alter_ratio is None:
        ratio = estimate_alter_ratio(
            graph, ctx.satisfied, sample_sat, params.alter_ratio_k
        )
    return state, ratio


def constrained_search(
    corpus: Corpus,
    graph: GraphIndex,
    queries: Array,
    constraint,
    params: SearchParams,
    rng: Optional[Array] = None,
    pq_index=None,
) -> SearchResult:
    """Top-k constrained similarity search for a batch of queries.

    queries: (B, d). Returns ascending (B, K) distances/ids; unreachable
    slots hold (+inf, -1).

    LabelSet/Range constraints are traced data (one compiled search serves
    every query batch); a callable UDF constraint is a static argument —
    one compiled search per distinct UDF, the paper's templated-C++ cost
    model (core/constraints.py).

    With params.approx == "pq", ``pq_index`` (core.pq.PQIndex) drives the
    traversal with ADC distances (``PQBackend``); the ef_result survivors
    are re-ranked exactly before the final top-k (beyond-paper,
    EXPERIMENTS.md §Perf D4).

    With params.beam_width > 1, each iteration expands up to ``beam_width``
    vertices per query through one flattened (B, beam*deg) gather; the
    termination threshold is evaluated against the top-k list as of the
    start of the iteration (beam lock-step semantics, DESIGN.md §5).

    With the fused candidate pipeline active (params.fuse_expand), each
    iteration runs gather + distance + constraint + visited masking as ONE
    pass through the backend's fused kernel (kernels/fused_expand/ — exact
    rows or PQ code rows + in-kernel ADC sums) and updates every queue by
    sorted merge instead of top_k re-selection (EXPERIMENTS.md §Perf PR2).
    """
    impl = _search_static_constraint if callable(constraint) else _search
    return impl(corpus, graph, queries, constraint, params, rng, pq_index)


def search_with_context(
    ctx: TraversalContext,
    corpus: Corpus,
    graph: GraphIndex,
    queries: Array,
    params: SearchParams,
    rng: Optional[Array] = None,
) -> SearchResult:
    """Run the traversal loop against an already-built ``TraversalContext``.

    The context-level entry point: ``constrained_search`` builds the
    context from user-facing knobs and delegates here; the distributed
    layer (core/distributed.py) builds one context per shard — backend
    arrays sharded with the corpus rows — and calls this directly.
    """
    two_queue = is_two_queue(params.mode)
    state, ratio = seed_state(corpus, graph, queries, ctx, params, rng)

    def cond(st: TraversalState) -> Array:
        return jnp.any(~st.done) & (st.iters < params.max_iters)

    def body(st: TraversalState) -> TraversalState:
        # --- Alg. 1/2 termination bound, captured at iteration start --------
        thr = q.topk_threshold(st.topk, params.result_capacity)

        # --- policy + beam pop (engine/policy.py, engine/expand.py) ---------
        sat, oth, now_d, now_i, sel_sat, expand, done, cnt_sat, cnt_total = (
            pop_frontier_beam(
                params.mode, st.sat, st.oth, st.done, st.cnt_sat,
                st.cnt_total, ratio, thr, params.beam_width,
            )
        )

        # --- result update ---------------------------------------------------
        if two_queue:
            # the sat frontier only ever holds satisfied vertices.
            upd = expand & sel_sat
        else:
            upd = expand & ctx.satisfied(now_i)

        # --- one flattened (B, beam*deg) expansion ---------------------------
        if ctx.fuse:
            # Fused pipeline: distances, constraint verdicts, and freshness
            # in one pass; then ONE bitonic partition-sort of the candidate
            # batch feeds every frontier via windowed sorted merges
            # (queue_merge_sorted) — no top_k(C+M) re-selection anywhere in
            # the iteration (EXPERIMENTS.md §Perf PR2).
            nbrs, d_nb, nb_sat_all, fresh = expand_beam_fused(
                graph.neighbors, queries, now_i, expand, st.visited, ctx,
            )
            m = nbrs.shape[-1]
            if two_queue:
                nb_sat = nb_sat_all & fresh
                run_sat, run_oth = q.partition_sorted_runs(
                    d_nb, nbrs, nb_sat, fresh & ~nb_sat,
                    sat.capacity, oth.capacity,
                )
                sat = q.queue_merge_sorted(sat, *run_sat)
                oth = q.queue_merge_sorted(oth, *run_oth)
            else:
                run_d, run_i = q.sort_run(d_nb, nbrs, fresh)
                r = min(m, oth.capacity)
                oth = q.queue_merge_sorted(oth, run_d[:, :r], run_i[:, :r])
            # The beam pops are W <= beam_width elements; two-queue policies
            # interleave the sat/oth heads so the run needs its own (tiny)
            # stable sort before merging into the result list.
            trun_d, trun_i = q.sort_run(now_d, now_i, upd)
            topk = q.queue_merge_sorted(st.topk, trun_d, trun_i)
        else:
            topk = q.queue_push(st.topk, now_d, now_i, upd)
            nbrs, d_nb, fresh = expand_beam(
                graph.neighbors, queries, now_i, expand, st.visited, ctx,
            )
            if two_queue:
                nb_sat = ctx.satisfied(nbrs) & fresh
                sat = q.queue_push(sat, d_nb, nbrs, nb_sat)
                oth = q.queue_push(oth, d_nb, nbrs, fresh & ~nb_sat)
            else:
                oth = q.queue_push(oth, d_nb, nbrs, fresh)

        return TraversalState(
            sat=sat,
            oth=oth,
            topk=topk,
            visited=vis.visited_set(st.visited, nbrs, fresh),
            cnt_sat=cnt_sat,
            cnt_total=cnt_total,
            dist_evals=st.dist_evals + jnp.sum(fresh, axis=-1, dtype=jnp.int32),
            hops=st.hops + jnp.sum(expand, axis=-1, dtype=jnp.int32),
            beam_expanded=st.beam_expanded + expand.astype(jnp.int32),
            done=done,
            iters=st.iters + 1,
        )

    final = jax.lax.while_loop(cond, body, state)
    stats = SearchStats(
        dist_evals=final.dist_evals,
        hops=final.hops,
        visited=vis.visited_count(final.visited),
        iters=final.iters,
        beam_expansions=final.beam_expanded,
    )
    out_d, out_i = final.topk.dists, final.topk.ids
    if ctx.backend.approximate:
        # Exact re-rank of the ef_result survivors (the approximate backend
        # ordered the walk; exact distances order the answer).
        exact_d = ExactBackend(vectors=corpus.vectors).distances(queries, out_i)
        exact_d = jnp.where(out_i >= 0, exact_d, jnp.inf)
        order = jnp.argsort(exact_d, axis=-1)
        out_d = jnp.take_along_axis(exact_d, order, axis=-1)
        out_i = jnp.take_along_axis(out_i, order, axis=-1)
        out_i = jnp.where(jnp.isfinite(out_d), out_i, -1)
    # The ef_result-sized candidate list is truncated to the requested top-k.
    return SearchResult(
        dists=out_d[:, : params.k],
        ids=out_i[:, : params.k],
        stats=stats,
    )


def _constrained_search_impl(
    corpus: Corpus,
    graph: GraphIndex,
    queries: Array,
    constraint,
    params: SearchParams,
    rng: Optional[Array] = None,
    pq_index=None,
) -> SearchResult:
    ctx = build_context(
        corpus, constraint, queries, params, pq_index,
        degree=graph.neighbors.shape[1],
    )
    return search_with_context(ctx, corpus, graph, queries, params, rng)


_search = partial(jax.jit, static_argnames=("params",))(_constrained_search_impl)
_search_static_constraint = partial(
    jax.jit, static_argnames=("params", "constraint")
)(_constrained_search_impl)
