"""Beam expansion: pop ``beam_width`` vertices per query, gather once.

The seed loop popped exactly one vertex per query per lock-step iteration,
so every iteration fed the fused gather+distance path only ``deg``
candidates. Here each iteration pops up to ``beam_width`` vertices per
query (``pop_frontier_beam``) and flattens their adjacency into ONE
``(B, beam*deg)`` candidate gather (``expand_beam``) through whichever
``DistanceBackend`` the ``TraversalContext`` carries — exact rows, the
Pallas ``gather_distance`` kernel, or PQ/ADC lookup (engine/context.py);
``expand_beam_fused`` additionally folds the constraint and visited checks
into the backend's one-pass kernel (kernels/fused_expand/, DESIGN.md §6).
``beam_width=1`` reproduces the seed computation exactly; wider beams trade
per-slot threshold staleness for beam-times fewer lock-step iterations
(DESIGN.md §5).

Correctness note: two vertices popped in the same beam may share an
unvisited neighbor, so the flattened id list can contain duplicates. The
visited bitset uses scatter-ADD (valid only for duplicate-free rows,
core/visited.py) and the frontiers must not hold a vertex twice, so
``mask_first_occurrence`` keeps only the first copy. It is skipped at
``beam_width=1`` where adjacency rows are duplicate-free by construction.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import queue as q
from repro.core import visited as vis
from repro.core.engine.context import TraversalContext
from repro.core.engine.policy import get_policy, is_two_queue

Array = jax.Array


def mask_first_occurrence(ids: Array, valid: Array) -> Array:
    """Clear ``valid`` on all but the first *valid* copy of each id per row.

    ids/valid: (B, M). Below M = 128 the O(M^2) pairwise compare is a cheap
    boolean VPU block next to the candidate gather; beyond that (wide beams x
    high degree) the (B, M, M) mask dominates, so the O(M log M) sort-based
    dedup takes over (property-tested equivalent in tests/test_fused_expand).
    """
    if ids.shape[-1] > 128:
        return mask_first_occurrence_sorted(ids, valid)
    m = ids.shape[-1]
    eq = ids[:, :, None] == ids[:, None, :]  # (B, M, M)
    earlier = jnp.tril(jnp.ones((m, m), bool), k=-1)
    dup = jnp.any(eq & earlier[None] & valid[:, None, :], axis=-1)
    return valid & ~dup


def mask_first_occurrence_sorted(ids: Array, valid: Array) -> Array:
    """Sort-based dedup: keep each id's first valid slot, O(M log M).

    Stable-argsort groups equal ids while preserving original slot order
    inside each group; a segmented prefix count of valid slots then flags
    exactly the group's first valid one. Earlier *invalid* copies never
    suppress later valid ones — same contract as the pairwise version.
    """
    b, m = ids.shape
    order = jnp.argsort(ids, axis=-1)  # stable: ties keep slot order
    sid = jnp.take_along_axis(ids, order, axis=-1)
    sval = jnp.take_along_axis(valid, order, axis=-1)
    seg_start = jnp.concatenate(
        [jnp.ones((b, 1), bool), sid[:, 1:] != sid[:, :-1]], axis=-1
    )
    nval = sval.astype(jnp.int32)
    before = jnp.cumsum(nval, axis=-1) - nval  # valids strictly before, global
    pos = jnp.arange(m, dtype=jnp.int32)[None, :]
    start_pos = jax.lax.cummax(jnp.where(seg_start, pos, 0), axis=1)
    before_group = jnp.take_along_axis(before, start_pos, axis=-1)
    keep_sorted = sval & (before == before_group)  # first valid in its group
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    return jnp.zeros_like(valid).at[rows, order].set(keep_sorted)


def pop_frontier_beam(
    mode: str,
    sat: q.BatchedQueue,
    oth: q.BatchedQueue,
    done: Array,
    cnt_sat: Array,
    cnt_total: Array,
    ratio: Array,
    thr: Array,
    beam_width: int,
) -> Tuple[
    q.BatchedQueue, q.BatchedQueue, Array, Array, Array, Array, Array, Array
]:
    """Pop up to ``beam_width`` vertices per query under the mode's policy.

    Termination is Alg. 1/2's threshold test against ``thr`` — the top-k
    bound captured at the START of the iteration (beam lock-step
    semantics: slots within one beam do not see each other's result-list
    updates). A slot whose pop exceeds ``thr`` marks the query done; later
    slots of a done query neither pop nor expand. Frontier exhaustion is
    only final when observed at iteration start (slot 0): frontiers that
    run short MID-beam merely skip the remaining slots, because this
    iteration's expansion of the earlier slots may refill them.

    Returns (sat, oth, now_d (B, W), now_i (B, W), sel_sat (B, W),
    expand (B, W), done (B,), cnt_sat, cnt_total). Counters count actual
    pops — including the one that trips the threshold, as in the seed.
    """
    if not is_two_queue(mode):
        # Single-frontier fast path: one shifted copy pops the whole beam.
        done_now = done | ~(q.queue_nonempty(sat) | q.queue_nonempty(oth))
        live = ~done_now
        oth, now_d, now_i = q.queue_pop_n(oth, beam_width, live)
        popped = live[:, None] & jnp.isfinite(now_d)
        # Only a genuinely popped vertex can trip Alg. 1/2 termination; a
        # frontier that merely ran short mid-beam (INF padding slots) is
        # refilled by this very iteration's expansion — if it stays empty,
        # next iteration's done_now check finishes the query.
        over = popped & (now_d > thr[:, None])
        # Pops come out ascending: once a slot exceeds thr (or hits queue
        # padding) every later slot does too — cumulative stop.
        stop = jnp.cumsum((over | ~popped).astype(jnp.int32), -1) > 0
        expand = live[:, None] & ~stop
        done = done_now | jnp.any(over, axis=-1)
        cnt_total = cnt_total + jnp.sum(popped, -1, dtype=jnp.int32)
        sel_sat = jnp.zeros_like(expand)
        return sat, oth, now_d, now_i, sel_sat, expand, done, cnt_sat, cnt_total

    # Two-frontier path: the policy re-reads heads and counters after every
    # pop, so slots are peeled one at a time (beam_width is static & small).
    policy = get_policy(mode)
    slots_d, slots_i, slots_sel, slots_expand = [], [], [], []
    for j in range(beam_width):
        empty = ~(q.queue_nonempty(sat) | q.queue_nonempty(oth))
        if j == 0:
            # Empty at iteration START is final — the previous iteration's
            # expansion already ran and pushed nothing (Alg. 1/2).
            done = done | empty
            blocked = done
        else:
            # Empty MID-beam only skips the remaining slots: this
            # iteration's expansion of the earlier slots may refill the
            # frontiers, so the query must survive to the next iteration.
            blocked = done | empty
        sel = policy(sat, oth, cnt_sat, cnt_total, ratio)
        live = ~blocked
        sat, sat_d, sat_i = q.queue_pop(sat, sel & live)
        oth, oth_d, oth_i = q.queue_pop(oth, ~sel & live)
        now_d = jnp.where(sel, sat_d, oth_d)
        now_i = jnp.where(sel, sat_i, oth_i)
        cnt_total = cnt_total + live.astype(jnp.int32)
        cnt_sat = cnt_sat + (sel & live).astype(jnp.int32)
        over = live & (now_d > thr)  # threshold crossings alone are sticky
        done = done | over
        slots_d.append(now_d)
        slots_i.append(now_i)
        slots_sel.append(sel)
        slots_expand.append(live & ~over)
    return (
        sat,
        oth,
        jnp.stack(slots_d, axis=-1),
        jnp.stack(slots_i, axis=-1),
        jnp.stack(slots_sel, axis=-1),
        jnp.stack(slots_expand, axis=-1),
        done,
        cnt_sat,
        cnt_total,
    )


def expand_beam(
    neighbors: Array,
    queries: Array,
    now_i: Array,
    expand: Array,
    visited: Array,
    ctx: TraversalContext,
) -> Tuple[Array, Array, Array]:
    """Flatten the beam's adjacency into one (B, beam*deg) candidate batch.

    now_i/expand: (B, W). Returns (nbrs (B, W*deg) ids, d_nb (B, W*deg)
    distances, fresh (B, W*deg) push mask — valid, unvisited, first
    occurrence). One backend gather+distance call per iteration regardless
    of beam width is the whole point: ``ctx.backend`` sees W*deg candidates.
    """
    b, w = now_i.shape
    deg = neighbors.shape[-1]
    safe = jnp.maximum(now_i, 0)
    nbrs = neighbors[safe].reshape(b, w * deg)
    nb_valid = (nbrs >= 0) & jnp.repeat(expand, deg, axis=-1)
    fresh = nb_valid & ~vis.visited_test(visited, nbrs)
    if w > 1:
        fresh = mask_first_occurrence(nbrs, fresh)
    d_nb = ctx.backend.distances(queries, nbrs)
    return nbrs, d_nb, fresh


def expand_beam_fused(
    neighbors: Array,
    queries: Array,
    now_i: Array,
    expand: Array,
    visited: Array,
    ctx: TraversalContext,
) -> Tuple[Array, Array, Array, Array]:
    """Fused-pipeline twin of ``expand_beam`` (kernels/fused_expand/).

    One backend pass emits distances, constraint verdicts, and visited-
    freshness for the whole (B, beam*deg) candidate batch — the separate
    ``satisfied()`` metadata gather and ``visited_test`` probes of the
    unfused path fold into the same per-candidate HBM visit as the row (or
    PQ code-row) gather. ``ctx.tables`` is the constraint's raw view
    (core.constraints.constraint_tables). Non-expanding slots are
    pre-masked to padding ids so the kernel sees one uniform validity rule.
    Returns (nbrs, d_nb, sat, fresh); ``sat`` covers every valid candidate
    and is masked by ``fresh`` at the push site.
    """
    b, w = now_i.shape
    deg = neighbors.shape[-1]
    safe = jnp.maximum(now_i, 0)
    nbrs = neighbors[safe].reshape(b, w * deg)
    nbrs = jnp.where(jnp.repeat(expand, deg, axis=-1), nbrs, -1)
    d_nb, sat, fresh = ctx.backend.fused_expand(queries, nbrs, visited, ctx.tables)
    if w > 1:
        fresh = mask_first_occurrence(nbrs, fresh)
    return nbrs, d_nb, sat, fresh
