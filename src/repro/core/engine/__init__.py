# Beam-parallel traversal engine (DESIGN.md §5/§6): four separable layers.
#   context.py — TraversalContext: distance backend (Exact/L2Kernel/PQ) +
#                constraint closure/tables + fuse decision, built once
#   policy.py  — frontier selection (vanilla/start/alter/prefer as functions)
#   expand.py  — beam pop + one flattened (B, beam*deg) backend gather
#   loop.py    — compiled lock-step while_loop: state, termination, stats
# `constrained_search` is the single entry point; repro.core.search re-exports
# it so existing callers (pipeline, distributed, archs, examples) are
# untouched. The distributed layer builds per-shard contexts and enters at
# `search_with_context` (core/distributed.py).
from repro.core.engine.context import (
    FUSE_AUTO_ON_TPU,
    DistanceBackend,
    ExactBackend,
    L2KernelBackend,
    PQBackend,
    TraversalContext,
    build_context,
    resolve_auto_fuse,
)
from repro.core.engine.expand import (
    expand_beam,
    expand_beam_fused,
    mask_first_occurrence,
    mask_first_occurrence_sorted,
    pop_frontier_beam,
)
from repro.core.engine.loop import (
    TraversalState,
    constrained_search,
    search_with_context,
    seed_state,
)
from repro.core.engine.policy import (
    POLICIES,
    FrontierPolicy,
    get_policy,
    is_two_queue,
    prefer_policy,
    ratio_policy,
    single_queue_policy,
)

__all__ = [
    "FUSE_AUTO_ON_TPU",
    "POLICIES",
    "DistanceBackend",
    "ExactBackend",
    "FrontierPolicy",
    "L2KernelBackend",
    "PQBackend",
    "TraversalContext",
    "TraversalState",
    "build_context",
    "constrained_search",
    "expand_beam",
    "expand_beam_fused",
    "get_policy",
    "is_two_queue",
    "mask_first_occurrence",
    "mask_first_occurrence_sorted",
    "pop_frontier_beam",
    "prefer_policy",
    "ratio_policy",
    "resolve_auto_fuse",
    "search_with_context",
    "seed_state",
    "single_queue_policy",
]
