# Beam-parallel traversal engine (DESIGN.md §5): three separable layers.
#   policy.py — frontier selection (vanilla/start/alter/prefer as functions)
#   expand.py — beam pop + one flattened (B, beam*deg) gather+distance
#   loop.py   — compiled lock-step while_loop: state, termination, stats
# `constrained_search` is the single entry point; repro.core.search re-exports
# it so existing callers (pipeline, distributed, archs, examples) are
# untouched. Future sharded / async serving PRs plug in at this seam.
from repro.core.engine.expand import (
    expand_beam,
    expand_beam_fused,
    mask_first_occurrence,
    mask_first_occurrence_sorted,
    neighbor_distances,
    pop_frontier_beam,
)
from repro.core.engine.loop import TraversalState, constrained_search, seed_state
from repro.core.engine.policy import (
    POLICIES,
    FrontierPolicy,
    get_policy,
    is_two_queue,
    prefer_policy,
    ratio_policy,
    single_queue_policy,
)

__all__ = [
    "POLICIES",
    "FrontierPolicy",
    "TraversalState",
    "constrained_search",
    "expand_beam",
    "expand_beam_fused",
    "get_policy",
    "is_two_queue",
    "mask_first_occurrence",
    "mask_first_occurrence_sorted",
    "neighbor_distances",
    "pop_frontier_beam",
    "prefer_policy",
    "ratio_policy",
    "seed_state",
    "single_queue_policy",
]
