"""Fixed-capacity, batched priority queues as sorted arrays.

The paper's C++ implementation uses dynamic binary heaps; on TPU we keep each
frontier as a distance-ascending sorted array of static capacity ``C``:

  * empty slots hold ``(+inf, -1)``
  * ``pop``  == take the head, shift everything left by one
  * ``push`` == concatenate, argsort, truncate back to ``C``

All operations carry a leading batch axis ``B`` (one queue per query) so the
whole query batch advances in lock-step (DESIGN.md §2). Sorting ``C + M`` keys
per step is a small sorting network on TPU — for typical ``C`` in [64, 512]
and graph degree ``M`` in [16, 64] this is far cheaper than the
neighbor-distance gathers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import pytree_dataclass

Array = jax.Array

INF = jnp.inf
PAD_ID = -1


@pytree_dataclass
class BatchedQueue:
    """A batch of fixed-capacity min-queues (sorted ascending by distance)."""

    dists: Array  # (B, C) f32, +inf padded, ascending
    ids: Array  # (B, C) i32, -1 padded

    @property
    def capacity(self) -> int:
        return self.dists.shape[-1]

    @property
    def batch(self) -> int:
        return self.dists.shape[0]


def queue_init(batch: int, capacity: int) -> BatchedQueue:
    return BatchedQueue(
        dists=jnp.full((batch, capacity), INF, dtype=jnp.float32),
        ids=jnp.full((batch, capacity), PAD_ID, dtype=jnp.int32),
    )


def queue_head(q: BatchedQueue) -> tuple[Array, Array]:
    """Best (distance, id) per row; (+inf, -1) when empty."""
    return q.dists[:, 0], q.ids[:, 0]


def queue_nonempty(q: BatchedQueue) -> Array:
    """(B,) bool — does each row hold at least one live element."""
    return jnp.isfinite(q.dists[:, 0])


def queue_size(q: BatchedQueue) -> Array:
    """(B,) number of live elements."""
    return jnp.sum(jnp.isfinite(q.dists), axis=-1).astype(jnp.int32)


def queue_pop(q: BatchedQueue, do_pop: Array) -> tuple[BatchedQueue, Array, Array]:
    """Pop the head of each row where ``do_pop`` (B,) bool is set.

    Rows with ``do_pop == False`` are returned unchanged (their reported
    head is still returned — callers mask on ``do_pop``).
    """
    new, head_d, head_i = queue_pop_n(q, 1, do_pop)
    return new, head_d[:, 0], head_i[:, 0]


def queue_pop_n(
    q: BatchedQueue, n: int, do_pop: Array
) -> tuple[BatchedQueue, Array, Array]:
    """Pop the best ``n`` elements of each row where ``do_pop`` (B,) is set.

    Returns (new_queue, (B, n) dists, (B, n) ids), both ascending per row.
    Empty slots report (+inf, -1); when a row holds fewer than ``n`` live
    elements the trailing slots are padding. Rows with ``do_pop == False``
    are returned unchanged (their best ``n`` are still reported — callers
    mask on ``do_pop``). The beam engine (DESIGN.md §5) uses this to pop a
    whole beam in one shifted copy instead of ``n`` sequential pops.
    """
    c = q.capacity
    if n >= c:
        head_d = jnp.pad(q.dists, ((0, 0), (0, n - c)), constant_values=INF)
        head_i = jnp.pad(q.ids, ((0, 0), (0, n - c)), constant_values=PAD_ID)
        shifted_d = jnp.full_like(q.dists, INF)
        shifted_i = jnp.full_like(q.ids, PAD_ID)
    else:
        head_d, head_i = q.dists[:, :n], q.ids[:, :n]
        shifted_d = jnp.concatenate(
            [q.dists[:, n:], jnp.full((q.batch, n), INF, q.dists.dtype)], axis=-1
        )
        shifted_i = jnp.concatenate(
            [q.ids[:, n:], jnp.full((q.batch, n), PAD_ID, q.ids.dtype)], axis=-1
        )
    new = BatchedQueue(
        dists=jnp.where(do_pop[:, None], shifted_d, q.dists),
        ids=jnp.where(do_pop[:, None], shifted_i, q.ids),
    )
    return new, head_d, head_i


def queue_push(
    q: BatchedQueue, new_d: Array, new_i: Array, valid: Array
) -> BatchedQueue:
    """Insert up to M new elements per row; keep the best ``C``.

    new_d: (B, M) f32, new_i: (B, M) i32, valid: (B, M) bool.
    Invalid entries are masked to (+inf, -1) before the merge.
    """
    nd = jnp.where(valid, new_d, INF).astype(q.dists.dtype)
    ni = jnp.where(valid, new_i, PAD_ID).astype(q.ids.dtype)
    all_d = jnp.concatenate([q.dists, nd], axis=-1)  # (B, C+M)
    all_i = jnp.concatenate([q.ids, ni], axis=-1)
    # top_k of the negated keys = the C smallest, already ascending — a
    # partial selection network instead of a full (C+M) sort. Measured
    # 3.3x faster end-to-end search on CPU (EXPERIMENTS.md §Perf D5); on
    # TPU top_k lowers to a cheaper selection than the full bitonic sort.
    neg, pos = jax.lax.top_k(-all_d, q.capacity)
    return BatchedQueue(dists=-neg, ids=jnp.take_along_axis(all_i, pos, axis=-1))


# ---------------------------------------------------------------------------
# Sorted-run machinery for the fused candidate pipeline (EXPERIMENTS.md
# §Perf PR2). Everything below is built from TWO gather-free primitives —
# lexicographic compare-exchange on (key, pos) pairs, and static shifts —
# because on both TPU and XLA:CPU the expensive ops in a queue update are
# comparator sorts and scatters, not elementwise arithmetic. Distances are
# non-negative f32 (squared L2, +inf padding), so their raw bit patterns
# are order-preserving as uint32 ("dist bits"); a per-element position
# makes every (key, pos) pair distinct, which turns the stable-tie-break
# rules of ``top_k`` into an ordinary total order.
# ---------------------------------------------------------------------------

_INF_BITS = jnp.uint32(0x7F800000)  # +inf as its f32 bit pattern


def _dist_bits(d: Array) -> Array:
    """Non-negative f32 (incl. +inf) -> order-preserving uint32 key.

    ``+ 0.0`` canonicalizes a hypothetical -0.0 (bit pattern 0x80000000,
    which would order above +inf) to +0.0 before the bitcast.
    """
    return jax.lax.bitcast_convert_type(
        d.astype(jnp.float32) + 0.0, jnp.uint32
    )


def _bits_dist(u: Array) -> Array:
    return jax.lax.bitcast_convert_type(u, jnp.float32)


def _lexmax(ka, pa, kb, pb):
    b_gt = (kb > ka) | ((kb == ka) & (pb > pa))
    return jnp.where(b_gt, kb, ka), jnp.where(b_gt, pb, pa)


def _lexmin(ka, pa, kb, pb):
    b_lt = (kb < ka) | ((kb == ka) & (pb < pa))
    return jnp.where(b_lt, kb, ka), jnp.where(b_lt, pb, pa)


def bitonic_sort_pairs(key: Array, pos: Array) -> tuple[Array, Array]:
    """Ascending row-sort of (B, n) by (key, pos); n must be a power of two.

    The classic bitonic network: log2(n)*(log2(n)+1)/2 compare-exchange
    stages, each a reshape + elementwise lexicographic min/max — no
    comparator sort, no gathers, TPU-vectorizable as-is. ``pos`` uniqueness
    makes the order total, so the result is deterministic under ties.
    """
    b, n = key.shape
    log_n = int(math.log2(n))
    assert 1 << log_n == n, f"bitonic sort needs a power-of-two width, got {n}"
    for blk_log in range(1, log_n + 1):
        for s_log in range(blk_log - 1, -1, -1):
            s = 1 << s_log
            nb = n // (2 * s)
            # ascending for even blocks of size 2**blk_log, else descending
            asc = ((jnp.arange(nb) * 2 * s) >> blk_log) % 2 == 0
            k4 = key.reshape(b, nb, 2, s)
            p4 = pos.reshape(b, nb, 2, s)
            a = asc[None, :, None]
            lo_k, lo_p = _lexmin(k4[:, :, 0], p4[:, :, 0], k4[:, :, 1], p4[:, :, 1])
            hi_k, hi_p = _lexmax(k4[:, :, 0], p4[:, :, 0], k4[:, :, 1], p4[:, :, 1])
            key = jnp.stack(
                [jnp.where(a, lo_k, hi_k), jnp.where(a, hi_k, lo_k)], axis=2
            ).reshape(b, n)
            pos = jnp.stack(
                [jnp.where(a, lo_p, hi_p), jnp.where(a, hi_p, lo_p)], axis=2
            ).reshape(b, n)
    return key, pos


def sort_run(d: Array, i: Array, valid: Array) -> tuple[Array, Array]:
    """Mask + sort a small batch into an ascending (+inf, -1)-padded run.

    d/i/valid: (B, M). Stable under distance ties (original index order),
    i.e. exactly the candidate order ``queue_push`` would honour — the
    output is a valid ``queue_merge_sorted`` run. Width is padded to the
    next power of two internally.
    """
    b, m = d.shape
    mp = 1 << max(1, math.ceil(math.log2(m))) if m > 1 else 2
    key = jnp.where(valid, _dist_bits(d), jnp.uint32(0xFFFFFFFF))
    pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None, :], (b, m))
    if mp != m:
        key = jnp.pad(key, ((0, 0), (0, mp - m)), constant_values=np.uint32(0xFFFFFFFF))
        pos = jnp.pad(pos, ((0, 0), (0, mp - m)), constant_values=2**30)
    key, pos = bitonic_sort_pairs(key, pos)
    key, pos = key[:, :m], pos[:, :m]
    n_valid = jnp.sum(valid, axis=-1, keepdims=True, dtype=jnp.int32)
    live = jnp.arange(m, dtype=jnp.int32)[None, :] < n_valid
    safe = jnp.minimum(pos, m - 1)
    out_d = jnp.where(live, jnp.take_along_axis(d, safe, axis=-1), INF)
    out_i = jnp.where(live, jnp.take_along_axis(i, safe, axis=-1), PAD_ID)
    return out_d, out_i


def partition_sorted_runs(
    d: Array, i: Array, first: Array, second: Array, cap_first: int, cap_second: int
) -> tuple[tuple[Array, Array], tuple[Array, Array]]:
    """Split a candidate batch into two ascending runs with ONE sort.

    d/i: (B, M); ``first``/``second``: disjoint membership masks (elements
    in neither are dropped). Folds the partition into the top key bit —
    squared distances never use it — so a single bitonic pass yields
    [first-run | second-run | dropped], each segment ascending and
    tie-stable in original index order. Runs are truncated to their
    target queue's capacity (elements beyond rank C can never survive a
    merge) and padded with (+inf, -1).
    """
    b, m = d.shape
    mp = 1 << max(1, math.ceil(math.log2(m))) if m > 1 else 2
    bits = _dist_bits(d)
    key = jnp.where(
        first, bits,
        jnp.where(second, bits + jnp.uint32(0x80000000), jnp.uint32(0xFFFFFFFF)),
    )
    pos = jnp.broadcast_to(jnp.arange(m, dtype=jnp.int32)[None, :], (b, m))
    if mp != m:
        key = jnp.pad(key, ((0, 0), (0, mp - m)), constant_values=np.uint32(0xFFFFFFFF))
        pos = jnp.pad(pos, ((0, 0), (0, mp - m)), constant_values=2**30)
    key, pos = bitonic_sort_pairs(key, pos)
    n_first = jnp.sum(first, axis=-1, keepdims=True, dtype=jnp.int32)
    n_second = jnp.sum(second, axis=-1, keepdims=True, dtype=jnp.int32)

    def extract(offset, count, width):
        ar = jnp.arange(width, dtype=jnp.int32)[None, :]
        seg = jnp.minimum(ar + offset, mp - 1)
        p = jnp.minimum(jnp.take_along_axis(pos, seg, axis=-1), m - 1)
        live = ar < count
        run_d = jnp.where(live, jnp.take_along_axis(d, p, axis=-1), INF)
        run_i = jnp.where(live, jnp.take_along_axis(i, p, axis=-1), PAD_ID)
        return run_d, run_i

    zero = jnp.zeros_like(n_first)
    run1 = extract(zero, n_first, min(m, cap_first))
    run2 = extract(n_first, n_second, min(m, cap_second))
    return run1, run2


def queue_merge_sorted(
    q: BatchedQueue, run_d: Array, run_i: Array
) -> BatchedQueue:
    """Merge an ascending (+inf, -1)-padded run into the queue; keep best C.

    Bit-for-bit equal to ``queue_push(q, run_d, run_i, isfinite(run_d))``
    — including every distance-tie (queue element first, then run order),
    property-tested in tests/test_queue.py — but built as a *windowed
    min-max merge* instead of a ``top_k`` re-selection over C+M keys:
    since both sides are sorted, the (j+1)-th smallest of the union is

        merged[j] = min_{t=0..R} max(queue[j-t], run[t-1])

    (out-of-range terms are ∓inf sentinels). Each ``t`` is a static shift
    plus an elementwise lexicographic min/max on (dist-bits, position)
    pairs — no gathers, no sort — so the cost is O(C·R) vector ops with a
    tiny constant. For the fused engine's run lengths (R = beam·deg ≤ 64)
    this measures 1.3–4.6× faster than the ``top_k(C+M)`` push on CPU and
    maps onto pure VPU work on TPU (EXPERIMENTS.md §Perf PR2); the
    re-selection stays the right tool for unsorted pushes.
    """
    b, c = q.dists.shape
    r = run_d.shape[-1]
    qk = _dist_bits(q.dists)
    qp = jnp.broadcast_to(jnp.arange(c, dtype=jnp.int32)[None, :], (b, c))
    rk = _dist_bits(run_d)
    rp = jnp.broadcast_to(jnp.arange(c, c + r, dtype=jnp.int32)[None, :], (b, r))

    # One left-extension of the queue (−inf sentinels: key 0, pos −1) turns
    # every shifted term queue[j − t] into a static slice.
    ext_k = jnp.concatenate([jnp.zeros((b, r), jnp.uint32), qk], axis=-1)
    ext_p = jnp.concatenate([jnp.full((b, r), -1, jnp.int32), qp], axis=-1)
    cur_k, cur_p = qk, qp  # t = 0: max(queue[j], run[-1] = -inf) = queue[j]
    for t in range(1, r + 1):
        a_k = ext_k[:, r - t : r - t + c]
        a_p = ext_p[:, r - t : r - t + c]
        cand_k, cand_p = _lexmax(a_k, a_p, rk[:, t - 1 : t], rp[:, t - 1 : t])
        cur_k, cur_p = _lexmin(cur_k, cur_p, cand_k, cand_p)

    out_d = _bits_dist(jnp.minimum(cur_k, _INF_BITS))
    all_i = jnp.concatenate([q.ids, run_i], axis=-1)
    gathered = jnp.take_along_axis(all_i, cur_p, axis=-1)
    out_i = jnp.where(jnp.isfinite(out_d), gathered, PAD_ID)
    return BatchedQueue(dists=out_d, ids=out_i)


def queue_worst_finite(q: BatchedQueue) -> Array:
    """(B,) distance of the worst live element; -inf when empty.

    Used for the ``topk`` result list: termination compares the candidate
    against the K-th best so far (+inf while the list is not yet full — the
    caller handles the not-full case via ``queue_size``).
    """
    masked = jnp.where(jnp.isfinite(q.dists), q.dists, -INF)
    return jnp.max(masked, axis=-1)


def topk_threshold(q: BatchedQueue, k: int) -> Array:
    """(B,) value of the k-th slot (== +inf until the list holds k items).

    The result list has capacity exactly ``k`` and stays sorted, so slot
    ``k-1`` is the current worst of the top-k — the paper's
    ``topk.peek_max()`` with the ``|topk| = K`` condition folded in (slot is
    +inf while not full, which disables early termination, as in Alg. 1/2).
    """
    del k  # capacity of the queue *is* k
    return q.dists[:, -1]
