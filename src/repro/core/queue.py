"""Fixed-capacity, batched priority queues as sorted arrays.

The paper's C++ implementation uses dynamic binary heaps; on TPU we keep each
frontier as a distance-ascending sorted array of static capacity ``C``:

  * empty slots hold ``(+inf, -1)``
  * ``pop``  == take the head, shift everything left by one
  * ``push`` == concatenate, argsort, truncate back to ``C``

All operations carry a leading batch axis ``B`` (one queue per query) so the
whole query batch advances in lock-step (DESIGN.md §2). Sorting ``C + M`` keys
per step is a small sorting network on TPU — for typical ``C`` in [64, 512]
and graph degree ``M`` in [16, 64] this is far cheaper than the
neighbor-distance gathers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.pytree import pytree_dataclass

Array = jax.Array

INF = jnp.inf
PAD_ID = -1


@pytree_dataclass
class BatchedQueue:
    """A batch of fixed-capacity min-queues (sorted ascending by distance)."""

    dists: Array  # (B, C) f32, +inf padded, ascending
    ids: Array  # (B, C) i32, -1 padded

    @property
    def capacity(self) -> int:
        return self.dists.shape[-1]

    @property
    def batch(self) -> int:
        return self.dists.shape[0]


def queue_init(batch: int, capacity: int) -> BatchedQueue:
    return BatchedQueue(
        dists=jnp.full((batch, capacity), INF, dtype=jnp.float32),
        ids=jnp.full((batch, capacity), PAD_ID, dtype=jnp.int32),
    )


def queue_head(q: BatchedQueue) -> tuple[Array, Array]:
    """Best (distance, id) per row; (+inf, -1) when empty."""
    return q.dists[:, 0], q.ids[:, 0]


def queue_nonempty(q: BatchedQueue) -> Array:
    """(B,) bool — does each row hold at least one live element."""
    return jnp.isfinite(q.dists[:, 0])


def queue_size(q: BatchedQueue) -> Array:
    """(B,) number of live elements."""
    return jnp.sum(jnp.isfinite(q.dists), axis=-1).astype(jnp.int32)


def queue_pop(q: BatchedQueue, do_pop: Array) -> tuple[BatchedQueue, Array, Array]:
    """Pop the head of each row where ``do_pop`` (B,) bool is set.

    Rows with ``do_pop == False`` are returned unchanged (their reported
    head is still returned — callers mask on ``do_pop``).
    """
    new, head_d, head_i = queue_pop_n(q, 1, do_pop)
    return new, head_d[:, 0], head_i[:, 0]


def queue_pop_n(
    q: BatchedQueue, n: int, do_pop: Array
) -> tuple[BatchedQueue, Array, Array]:
    """Pop the best ``n`` elements of each row where ``do_pop`` (B,) is set.

    Returns (new_queue, (B, n) dists, (B, n) ids), both ascending per row.
    Empty slots report (+inf, -1); when a row holds fewer than ``n`` live
    elements the trailing slots are padding. Rows with ``do_pop == False``
    are returned unchanged (their best ``n`` are still reported — callers
    mask on ``do_pop``). The beam engine (DESIGN.md §5) uses this to pop a
    whole beam in one shifted copy instead of ``n`` sequential pops.
    """
    c = q.capacity
    if n >= c:
        head_d = jnp.pad(q.dists, ((0, 0), (0, n - c)), constant_values=INF)
        head_i = jnp.pad(q.ids, ((0, 0), (0, n - c)), constant_values=PAD_ID)
        shifted_d = jnp.full_like(q.dists, INF)
        shifted_i = jnp.full_like(q.ids, PAD_ID)
    else:
        head_d, head_i = q.dists[:, :n], q.ids[:, :n]
        shifted_d = jnp.concatenate(
            [q.dists[:, n:], jnp.full((q.batch, n), INF, q.dists.dtype)], axis=-1
        )
        shifted_i = jnp.concatenate(
            [q.ids[:, n:], jnp.full((q.batch, n), PAD_ID, q.ids.dtype)], axis=-1
        )
    new = BatchedQueue(
        dists=jnp.where(do_pop[:, None], shifted_d, q.dists),
        ids=jnp.where(do_pop[:, None], shifted_i, q.ids),
    )
    return new, head_d, head_i


def queue_push(
    q: BatchedQueue, new_d: Array, new_i: Array, valid: Array
) -> BatchedQueue:
    """Insert up to M new elements per row; keep the best ``C``.

    new_d: (B, M) f32, new_i: (B, M) i32, valid: (B, M) bool.
    Invalid entries are masked to (+inf, -1) before the merge.
    """
    nd = jnp.where(valid, new_d, INF).astype(q.dists.dtype)
    ni = jnp.where(valid, new_i, PAD_ID).astype(q.ids.dtype)
    all_d = jnp.concatenate([q.dists, nd], axis=-1)  # (B, C+M)
    all_i = jnp.concatenate([q.ids, ni], axis=-1)
    # top_k of the negated keys = the C smallest, already ascending — a
    # partial selection network instead of a full (C+M) sort. Measured
    # 3.3x faster end-to-end search on CPU (EXPERIMENTS.md §Perf D5); on
    # TPU top_k lowers to a cheaper selection than the full bitonic sort.
    neg, pos = jax.lax.top_k(-all_d, q.capacity)
    return BatchedQueue(dists=-neg, ids=jnp.take_along_axis(all_i, pos, axis=-1))


def queue_worst_finite(q: BatchedQueue) -> Array:
    """(B,) distance of the worst live element; -inf when empty.

    Used for the ``topk`` result list: termination compares the candidate
    against the K-th best so far (+inf while the list is not yet full — the
    caller handles the not-full case via ``queue_size``).
    """
    masked = jnp.where(jnp.isfinite(q.dists), q.dists, -INF)
    return jnp.max(masked, axis=-1)


def topk_threshold(q: BatchedQueue, k: int) -> Array:
    """(B,) value of the k-th slot (== +inf until the list holds k items).

    The result list has capacity exactly ``k`` and stays sorted, so slot
    ``k-1`` is the current worst of the top-k — the paper's
    ``topk.peek_max()`` with the ``|topk| = K`` condition folded in (slot is
    +inf while not full, which disables early termination, as in Alg. 1/2).
    """
    del k  # capacity of the queue *is* k
    return q.dists[:, -1]
