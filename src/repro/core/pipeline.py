"""The original three-stage pipeline (paper Fig. 1, upper path).

Stage 1: unconstrained ANN retrieves ``s`` candidates; stage 2 filters them by
the constraint; stage 3 re-ranks the survivors to top-k. This is the baseline
AIRSHIP replaces — implemented here so benchmarks can quantify the defect the
paper identifies (``c < k`` failures and the wasted over-retrieval factor).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.constraints import LabelSetConstraint, make_satisfied_fn
from repro.core.search import constrained_search
from repro.core.types import Corpus, GraphIndex, SearchParams

Array = jax.Array


def _allpass_constraint(batch: int, n_label_words: int = 64) -> LabelSetConstraint:
    """Bitmask accepting every label (stage-1 unconstrained search).

    Covers label ids < 64*32 = 2048 — all experiment protocols here.
    """
    return LabelSetConstraint(
        words=jnp.full((batch, n_label_words), 0xFFFFFFFF, jnp.uint32)
    )


@partial(jax.jit, static_argnames=("s", "k", "ef"))
def three_stage_pipeline(
    corpus: Corpus,
    graph: GraphIndex,
    queries: Array,
    constraint,
    s: int,
    k: int,
    ef: int = 0,
):
    """Returns (dists (B,k), ids (B,k), n_survived (B,)).

    ``n_survived < k`` is exactly the pipeline failure mode the paper
    motivates with: the ANN stage retrieved s candidates but fewer than k
    satisfied the constraint.
    """
    ef = ef or max(2 * s, 64)
    # Stage 1: unconstrained top-s (vanilla search with an all-pass filter).
    params = SearchParams(
        mode="vanilla", k=s, ef_result=max(s, 64), ef_sat=8, ef_other=ef,
        max_iters=4 * ef,
    )
    res = constrained_search(
        corpus, graph, queries, _allpass_constraint(queries.shape[0]), params
    )
    # Stage 2: filter the s candidates.
    satisfied = make_satisfied_fn(constraint, corpus)
    ok = satisfied(res.ids) & (res.ids >= 0)
    n_survived = jnp.sum(ok, axis=-1).astype(jnp.int32)
    # Stage 3: re-rank survivors (they are already distance-sorted) -> top-k.
    d = jnp.where(ok, res.dists, jnp.inf)
    neg, pos = jax.lax.top_k(-d, k)
    ids = jnp.take_along_axis(res.ids, pos, axis=-1)
    ids = jnp.where(jnp.isfinite(-neg), ids, -1)
    return -neg, ids, n_survived
