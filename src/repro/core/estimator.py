"""Selectivity estimation — ONE module for every probe of "what fraction
of the corpus satisfies this constraint" (DESIGN.md §9).

Before this module the repo carried two ad-hoc probes: the chunked O(n)
corpus scan (``core.selectivity``) and the sampled satisfied-fraction that
AIRSHIP-Start / the Eq.-1 alter_ratio estimator compute over the pre-drawn
build sample (engine/loop.py ``seed_state``). The hybrid strategy router
needs a third — a *cheap host-side* estimate per request — so all three now
share this module:

  * ``scan_selectivity``        — the exact chunked scan (moved here from
                                  constraints.py; ``core.selectivity`` is a
                                  thin delegating wrapper).
  * ``sample_satisfied_mask`` / ``sampled_selectivity`` — the (B, S) sample
                                  verdict mask and its mean, shared by the
                                  engine's start-point seeding and by the
                                  router's fallback estimate.
  * ``SelectivityEstimator``    — the router front: prefers the incremental
                                  label/range histograms maintained by the
                                  streaming layer (core/histogram.py, O(1)
                                  per estimate, no device round trip) and
                                  falls back to the sampled estimate when no
                                  histogram covers the constraint (the UDF
                                  case — an arbitrary closure has no table).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import (
    LabelSetConstraint,
    RangeConstraint,
    make_satisfied_fn,
)
from repro.core.types import Corpus, SatisfiedFn

Array = jax.Array


def sample_satisfied_mask(
    satisfied: SatisfiedFn, sample_ids: Array, batch: int
) -> Array:
    """(B, S) constraint verdicts over the pre-drawn build sample.

    The one sample probe shared by AIRSHIP-Start seeding, the Eq.-1
    alter_ratio estimator (both consume the mask itself), and the sampled
    selectivity estimate below (its mean).
    """
    s = sample_ids.shape[0]
    ids_b = jnp.broadcast_to(sample_ids[None, :], (batch, s))
    return satisfied(ids_b)


def sampled_selectivity(
    satisfied: SatisfiedFn, sample_ids: Array, batch: int
) -> Array:
    """(B,) satisfied fraction of the build sample — an unbiased O(S)
    selectivity estimate (the sample is drawn uniformly at build time)."""
    mask = sample_satisfied_mask(satisfied, sample_ids, batch)
    return jnp.mean(mask.astype(jnp.float32), axis=-1)


def scan_selectivity(constraint, corpus: Corpus, chunk: int = 1 << 16) -> Array:
    """(B,) EXACT fraction of the corpus satisfying each query's constraint.

    Linear scan — Assumption-1 fallback logic, benchmarks, and ground truth
    for the estimators above. Chunked over the corpus axis: the one-shot
    (B, n) id grid + bool mask peaked at ~1 GB transient for B=256, n=1M;
    scanning ``chunk``-wide windows holds the working set at B*chunk bytes
    while the satisfied counts accumulate in (B,) int32.
    """
    fn = make_satisfied_fn(constraint, corpus)
    n = corpus.n
    if isinstance(constraint, LabelSetConstraint):
        b = constraint.batch
    elif isinstance(constraint, RangeConstraint):
        b = constraint.lo.shape[0]
    else:
        b = 1
    chunk = min(chunk, n)
    n_chunks = (n + chunk - 1) // chunk
    starts = jnp.arange(n_chunks, dtype=jnp.int32) * chunk

    def body(acc, start):
        ids = start + jnp.arange(chunk, dtype=jnp.int32)
        # Tail chunk: ids past the corpus report unsatisfied (fn masks < 0).
        ids = jnp.where(ids < n, ids, -1)
        ok = fn(jnp.broadcast_to(ids[None, :], (b, chunk)))
        return acc + jnp.sum(ok, axis=-1, dtype=jnp.int32), None

    total, _ = jax.lax.scan(body, jnp.zeros((b,), jnp.int32), starts)
    return total.astype(jnp.float32) / n


class SelectivityEstimator:
    """Host-side estimator front for the strategy router.

    ``histograms`` (core/histogram.py ``AttributeHistograms``) covers the
    label / range families in O(words) per estimate with zero device work;
    ``corpus`` + ``sample_ids`` arm the sampled fallback for constraints no
    histogram covers (UDF closures). Either side may be None — ``estimate``
    reports the source it actually used so routing decisions are debuggable
    (the source rides ``Response`` telemetry).
    """

    def __init__(
        self,
        histograms=None,
        corpus: Optional[Corpus] = None,
        sample_ids: Optional[Array] = None,
    ):
        self.histograms = histograms
        self.corpus = corpus
        self.sample_ids = sample_ids

    # --- host-side operand estimates (serving hot path) -------------------
    def estimate_operand(
        self, family: str, operand
    ) -> Tuple[Optional[float], str]:
        """(estimate, source) for one request's host-side operand.

        family "label": operand is the (Lw,) uint32 allowed-label bitmask
        row; "range": (lo, hi, col). Returns (None, "none") when no
        histogram covers the family — the caller decides the fallback
        (serving routes to the graph default; core callers can afford the
        sampled device probe via ``estimate_constraint``).
        """
        if self.histograms is not None:
            est = self.histograms.estimate(family, operand)
            if est is not None:
                return float(est), "histogram"
        return None, "none"

    # --- traced-constraint estimates (bench / UDF fallback) ---------------
    def estimate_constraint(
        self, constraint, corpus: Optional[Corpus] = None
    ) -> Tuple[np.ndarray, str]:
        """((B,) estimates, source) for a full constraint object.

        Histogram-covered families evaluate per row on the host; anything
        else (UDF) falls back to the sampled satisfied-fraction over the
        pre-drawn build sample — the dedup the router rides on.
        """
        if self.histograms is not None:
            if isinstance(constraint, LabelSetConstraint):
                words = np.asarray(constraint.words)
                out = np.asarray(
                    [self.histograms.estimate("label", w) for w in words],
                    np.float32,
                )
                return out, "histogram"
            if isinstance(constraint, RangeConstraint):
                lo = np.asarray(constraint.lo)
                hi = np.asarray(constraint.hi)
                col = int(constraint.col)
                out = np.asarray(
                    [
                        self.histograms.estimate("range", (lo[i], hi[i], col))
                        for i in range(lo.shape[0])
                    ],
                    np.float32,
                )
                return out, "histogram"
        corpus = corpus if corpus is not None else self.corpus
        if corpus is None or self.sample_ids is None:
            raise ValueError(
                "no histogram covers this constraint and no (corpus, "
                "sample_ids) were provided for the sampled fallback"
            )
        satisfied = make_satisfied_fn(constraint, corpus)
        if isinstance(constraint, LabelSetConstraint):
            b = constraint.batch
        elif isinstance(constraint, RangeConstraint):
            b = int(constraint.lo.shape[0])
        else:
            b = 1
        est = sampled_selectivity(satisfied, jnp.asarray(self.sample_ids), b)
        return np.asarray(est), "sampled"
