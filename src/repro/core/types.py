"""Core value types for the constrained-search system."""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common.pytree import pytree_dataclass, static_field

Array = jax.Array


@pytree_dataclass
class Corpus:
    """Base vectors plus their attributes.

    vectors: (n, d) float
    labels:  (n,)   int32 — the categorical attribute used by the paper's
             equal / unequal-X% constraint families
    attrs:   (n, m) float32 — optional numeric attributes for range UDFs
    tombstones: (ceil(n/32),) uint32 — optional dead-slot bitmap for the
             streaming mutable index (repro.streaming). A set bit marks a
             slot that must never be RETURNED — deleted-but-unconsolidated
             vertices (still traversable as routing nodes) and free pool
             slots alike. None (the static-index default) means every row
             is live; every constraint family masks against this bitmap
             exactly like a failed constraint (core/constraints.py).
    """

    vectors: Array
    labels: Array
    attrs: Optional[Array] = None
    tombstones: Optional[Array] = None

    @property
    def n(self) -> int:
        return self.vectors.shape[0]

    @property
    def dim(self) -> int:
        return self.vectors.shape[1]


@pytree_dataclass
class GraphIndex:
    """Proximity-graph index.

    neighbors: (n, deg) int32 adjacency, rows sorted ascending by distance
               to the owning vertex (required by the alter_ratio estimator,
               Eq. 1), padded with -1.
    sample_ids: (s,) int32 — pre-drawn corpus sample for AIRSHIP-Start.
    entry_point: () int32 — medoid-ish global entry vertex.
    """

    neighbors: Array
    sample_ids: Array
    entry_point: Array

    @property
    def degree(self) -> int:
        return self.neighbors.shape[1]


@pytree_dataclass
class SearchParams:
    """Static search configuration (hashable — part of the jit cache key)."""

    mode: str = static_field(default="prefer")  # vanilla|start|alter|prefer
    k: int = static_field(default=10)
    # Result-list capacity used for the termination test. Alg. 1/2 use
    # exactly k ("|topk| = K and now_dist > topk.peek_max()"); production
    # graph searches sweep an HNSW-style ef >= k for the QPS/recall
    # trade-off. 0 -> max(k, 64).
    ef_result: int = static_field(default=0)
    ef_sat: int = static_field(default=128)
    ef_other: int = static_field(default=128)
    n_start: int = static_field(default=32)
    max_iters: int = static_field(default=512)
    # Beam width: vertices popped per query per lock-step iteration
    # (engine/expand.py). 1 reproduces the paper's one-pop-per-hop loop
    # bit-for-bit; wider beams amortize the fused gather+distance launch
    # over beam*deg candidates at the cost of expanding against a
    # threshold that is one iteration stale (DESIGN.md §5).
    beam_width: int = static_field(default=1)
    # None -> estimate per-query via the Eq.-1 kNN statistic.
    alter_ratio: Optional[float] = static_field(default=None)
    alter_ratio_k: int = static_field(default=16)
    # Selects L2KernelBackend (Pallas gather_distance) over ExactBackend
    # for the unfused distance path; identical mathematics, one HBM visit
    # per candidate. Backend selection flows through the TraversalContext
    # (engine/context.py) — no engine layer reads this directly.
    use_kernel: bool = static_field(default=False)
    # Fused candidate pipeline (kernels/fused_expand/): gather + distance +
    # constraint + visited masking in one pass, frontier updates via sorted
    # merges instead of top_k re-selection (engine/loop.py). "auto" targets
    # TPU only — and only for constraint families with in-kernel evaluation
    # (LabelSet / Range) — gated on the hardware-validation flag
    # FUSE_AUTO_ON_TPU (engine/context.py::resolve_auto_fuse); on other
    # backends native top_k wins in-loop so auto stays unfused
    # (EXPERIMENTS.md §Perf PR2). Every distance backend has a fused
    # kernel (exact rows or PQ code rows + in-kernel ADC sums, §Perf PR3);
    # only UDF constraints force the unfused path. Off-TPU the fused path
    # dispatches to the jnp oracle and returns bit-identical results, so
    # "on"/"off" are safe to force; the TPU kernels reduce in a different
    # FP order (ties may break differently) and stay behind
    # FUSE_AUTO_ON_TPU until validated on hardware.
    fuse_expand: str = static_field(default="auto")  # auto | on | off
    # Beyond-paper: traverse with PQ/ADC approximate distances (PQBackend,
    # 32x fewer HBM bytes per candidate at d=128/m_sub=16), then exact
    # re-rank of the ef_result survivors. Requires passing pq_index to
    # constrained_search.
    approx: str = static_field(default="exact")  # exact | pq

    def __post_init__(self):
        if self.mode not in ("vanilla", "start", "alter", "prefer"):
            raise ValueError(f"unknown search mode: {self.mode}")
        if self.approx not in ("exact", "pq"):
            raise ValueError(f"unknown approx mode: {self.approx}")
        if self.beam_width < 1:
            raise ValueError(f"beam_width must be >= 1, got {self.beam_width}")
        if self.fuse_expand not in ("auto", "on", "off"):
            raise ValueError(f"unknown fuse_expand mode: {self.fuse_expand}")

    @property
    def result_capacity(self) -> int:
        return self.ef_result if self.ef_result > 0 else max(self.k, 64)


@pytree_dataclass
class SearchStats:
    """Per-query instrumentation (hardware-independent cost measures)."""

    dist_evals: Array  # (B,) int32 — distance computations performed
    hops: Array  # (B,) int32 — vertices expanded
    visited: Array  # (B,) int32 — vertices touched
    iters: Array  # ()  int32 — lock-step iterations of the batch
    # (B, beam_width) int32 — per-beam-slot expansion counts: how many
    # iterations each slot actually expanded a vertex. Column 0 equals the
    # single-pop ``hops`` at beam_width=1; trailing columns quantify how
    # well wide beams stay fed (engine/expand.py). Locally
    # sum(beam_expansions, -1) == hops; in the distributed merge the two
    # intentionally diverge — beam_expansions psums across shards (a work
    # measure, like dist_evals) while hops pmaxes (critical-path measure).
    beam_expansions: Optional[Array] = None


@pytree_dataclass
class SearchResult:
    dists: Array  # (B, K) f32 ascending, +inf padded when fewer than K found
    ids: Array  # (B, K) int32, -1 padded
    stats: SearchStats

    @property
    def filled(self) -> Array:
        """(B,) int32 — result slots actually filled (id >= 0).

        The under-fill signal the paper's Fig. 1 is about: ``filled < k``
        means the walk exhausted its budget before finding k satisfying
        vertices. Callers (serve driver, serving controller, benchmarks)
        read this instead of re-deriving ``sum(ids >= 0)``.
        """
        return jnp.sum(self.ids >= 0, axis=-1, dtype=jnp.int32)


SatisfiedFn = Callable[[Array], Array]  # (B, M) ids -> (B, M) bool
