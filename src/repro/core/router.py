"""Per-query strategy router: selectivity-adaptive hybrid execution.

AIRSHIP's in-graph filtering wins mid-selectivity; a posting-set scan wins
when almost nothing satisfies; a label-subgraph overlay wins between them
for hot labels. The router picks per request, from a *cheap* host-side
selectivity estimate (core/estimator.py: incremental histograms, sampled
fallback) — never the O(n) scan. Decisions are constrained to a declared
strategy lattice per selectivity bucket, and the serving layer's
``AdaptiveController`` may retune *within* the lattice from observed
fill/latency EMAs (serving/controller.py); an inapplicable choice always
falls back to the universally-applicable graph walk.

Strategy lattice (DESIGN.md §9): bucket -> preference-ordered candidates.

    sel < 0.1%   : posting > overlay > graph   (scan a handful of ids)
    0.1% – 1%    : posting > overlay > graph   (scan still beats any walk)
    1% – 5%      : overlay > posting > graph   (sets too big to scan; a
                                                hot label's sub-graph walk
                                                touches only satisfiers)
    5% – 20%     : graph > overlay             (full walk finds satisfiers
                                                fast enough; overlay only
                                                if the label is hot)
    >= 20%       : graph                       (AIRSHIP's home regime)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.estimator import SelectivityEstimator

GRAPH, POSTING, OVERLAY = "graph", "posting", "overlay"
STRATEGIES = (GRAPH, POSTING, OVERLAY)


@dataclasses.dataclass(frozen=True)
class RouterConfig:
    """Bucket edges + lattice + applicability gates."""

    # selectivity bucket upper edges; bucket i covers [edges[i-1], edges[i])
    bucket_edges: Tuple[float, ...] = (0.001, 0.01, 0.05, 0.2)
    # preference-ordered strategy candidates per bucket (len(edges)+1 rows)
    lattice: Tuple[Tuple[str, ...], ...] = (
        (POSTING, OVERLAY, GRAPH),
        (POSTING, OVERLAY, GRAPH),
        (OVERLAY, POSTING, GRAPH),
        (GRAPH, OVERLAY),
        (GRAPH,),
    )
    # posting scan applicability: set size cap (None -> max(256, n // 32))
    posting_cap: Optional[int] = None
    # overlay applicability: label must have been routed this many times
    # within the current epoch before paying the sub-index build
    overlay_hot_after: int = 2
    # smallest posting set an overlay build accepts (graph needs >= 2 rows)
    overlay_min_postings: int = 2

    def resolved_posting_cap(self, n: int) -> int:
        if self.posting_cap is not None:
            return int(self.posting_cap)
        return max(256, n // 32)


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    """One request's routing verdict (rides Response telemetry)."""

    strategy: str
    est_selectivity: Optional[float]
    bucket: int
    source: str  # "histogram" | "sampled" | "default"
    label: Optional[int] = None  # single-label operand, when detected


def single_label_of_words(words) -> Optional[int]:
    """The label id if the bitmask operand allows exactly one label."""
    words = np.asarray(words, np.uint32).reshape(-1)
    found = None
    for w, word in enumerate(words):
        word = int(word)
        while word:
            bit = (word & -word).bit_length() - 1
            if found is not None:
                return None  # second bit -> multi-label
            found = w * 32 + bit
            word &= word - 1
    return found


class StrategyRouter:
    """Host-side per-request dispatcher over {graph, posting, overlay}.

    ``postings`` / ``range_index`` (core/posting.py) gate applicability:
    posting needs a materializable set under the cap; overlay needs a
    single hot label with enough postings. ``controller`` (optional,
    serving/controller.py) may override the lattice default *within* the
    bucket's lattice row. With no estimate at all (UDF and no sampled
    fallback armed) every request routes to graph — the universal plan.
    """

    def __init__(
        self,
        estimator: SelectivityEstimator,
        n: int,
        config: Optional[RouterConfig] = None,
        postings=None,
        range_index=None,
        controller=None,
    ):
        self.estimator = estimator
        self.n = int(n)
        self.config = config or RouterConfig()
        self.postings = postings
        self.range_index = range_index
        self.controller = controller
        self._cap = self.config.resolved_posting_cap(self.n)
        self._hot: Dict[int, int] = {}  # label -> routes seen this epoch
        self._hot_epoch = -1
        # plan cache: operand key -> (validity, hot_at_compute, decision).
        # Steady-state traffic repeats operands; recomputing the estimate,
        # the gates and the ranking walk every request costs ~10us where a
        # cached decision costs ~2us — a visible fraction of a sub-100us
        # posting scan. Invalidated by epoch moves and controller retunes
        # (validity tag) and by a label's cold->hot transition (recheck).
        self._plans: Dict[tuple, tuple] = {}

    # --- epoch plumbing ---------------------------------------------------
    def on_epoch(self, epoch: int) -> None:
        """Reset hotness counters when the index epoch moves (the overlay
        cache invalidates itself; hotness re-accumulates per epoch)."""
        if epoch != self._hot_epoch:
            self._hot.clear()
            self._plans.clear()
            self._hot_epoch = epoch

    # --- bucketing --------------------------------------------------------
    def bucket_of(self, est: float) -> int:
        for i, edge in enumerate(self.config.bucket_edges):
            if est < edge:
                return i
        return len(self.config.bucket_edges)

    # --- applicability gates ----------------------------------------------
    def _posting_count(self, family: str, operand) -> Optional[int]:
        if family == "label" and self.postings is not None:
            return self.postings.count_words(operand)
        if family == "range" and self.range_index is not None:
            lo, hi, col = operand
            return self.range_index.count_range(float(lo), float(hi), int(col))
        return None

    def _applicable(
        self,
        strategy: str,
        family: str,
        operand,
        label: Optional[int],
        count: Optional[int] = None,
    ) -> bool:
        if strategy == GRAPH:
            return True
        if strategy == POSTING:
            if count is None:
                count = self._posting_count(family, operand)
            return count is not None and count <= self._cap
        if strategy == OVERLAY:
            if label is None or self.postings is None:
                return False
            count = self.postings.count_label(label)
            if count < self.config.overlay_min_postings:
                return False
            return self._hot.get(label, 0) >= self.config.overlay_hot_after
        return False

    # --- the decision -----------------------------------------------------
    def _validity(self) -> tuple:
        gen = (
            getattr(self.controller, "generation", None)
            if self.controller is not None
            else None
        )
        return (self._hot_epoch, gen)

    def _is_hot(self, label: Optional[int]) -> bool:
        if label is None:
            return False
        return self._hot.get(label, 0) >= self.config.overlay_hot_after

    def route(self, family: str, operand, prefer_cheap: bool = False) -> RouteDecision:
        """Route one request. ``prefer_cheap`` is the serving layer's
        overload override (DESIGN.md §10): the degradation ladder asks for
        the host-side posting/overlay executors ahead of the compiled
        graph walk wherever their applicability gates pass — the lattice's
        quality ordering yields to keeping the burst off the batcher."""
        label = (
            single_label_of_words(operand) if family == "label" else None
        )
        if label is not None:
            self._hot[label] = self._hot.get(label, 0) + 1
        if family == "label":
            plan_key = (family, prefer_cheap, np.asarray(operand, np.uint32).tobytes())
        elif family == "range":
            plan_key = (family, prefer_cheap, tuple(operand))
        else:
            plan_key = None
        validity = self._validity()
        if plan_key is not None:
            hit = self._plans.get(plan_key)
            # hotness accrues per route (bumped above); a cold->hot
            # transition changes overlay applicability, so a cached plan
            # is only reused while the label's hot phase is unchanged
            if (
                hit is not None
                and hit[0] == validity
                and hit[1] == self._is_hot(label)
            ):
                return hit[2]
        decision = self._route_uncached(family, operand, label, prefer_cheap)
        if plan_key is not None:
            if len(self._plans) >= 4096:  # distinct range operands can grow
                self._plans.clear()
            self._plans[plan_key] = (
                validity, self._is_hot(label), decision
            )
        return decision

    def _route_uncached(
        self,
        family: str,
        operand,
        label: Optional[int],
        prefer_cheap: bool = False,
    ) -> RouteDecision:
        est, source = self.estimator.estimate_operand(family, operand)

        if est is None:
            return RouteDecision(GRAPH, None, -1, "default", label)

        bucket = self.bucket_of(est)
        row = self.config.lattice[bucket]
        if prefer_cheap:
            # Overload override: cheapest-executor-first, applicability
            # gates (posting-set cap, overlay hotness) still apply — a
            # huge posting set is NOT cheap and still walks the graph.
            # Observed-performance retuning is skipped: its EMAs rank
            # normal-load latency, not burst survival.
            row = (POSTING, OVERLAY, GRAPH)
            count = self._posting_count(family, operand)
            for cand in row:
                if self._applicable(cand, family, operand, label, count):
                    return RouteDecision(cand, float(est), bucket, source, label)
            return RouteDecision(GRAPH, float(est), bucket, source, label)
        # one posting-count lookup feeds every gate check below
        count = (
            self._posting_count(family, operand)
            if POSTING in row
            else None
        )
        default = GRAPH
        for cand in row:
            if self._applicable(cand, family, operand, label, count):
                default = cand
                break
        chosen = default
        if self.controller is not None:
            key = (family, bucket)
            ranker = getattr(self.controller, "strategy_ranking", None)
            ranking = ranker(key) if ranker is not None else ()
            if not ranking:
                ranking = (self.controller.strategy_for(key, default),)
            # Best *admissible* observed strategy: the first ranked entry
            # inside this bucket's lattice row that passes its gate. When
            # the globally fastest strategy is outside the row, the next
            # one still beats the static lattice default.
            for pref in ranking:
                if pref in row and self._applicable(
                    pref, family, operand, label, count
                ):
                    chosen = pref
                    break
        return RouteDecision(chosen, float(est), bucket, source, label)

    def route_constraint(self, constraint, corpus=None) -> RouteDecision:
        """Route from a full constraint object (bench / UDF path): uses the
        shared estimator's histogram-or-sampled estimate; batch estimates
        collapse to their mean (a micro-batch shares one strategy)."""
        try:
            est_arr, source = self.estimator.estimate_constraint(
                constraint, corpus
            )
        except ValueError:
            return RouteDecision(GRAPH, None, -1, "default", None)
        est = float(np.mean(est_arr))
        bucket = self.bucket_of(est)
        row = self.config.lattice[bucket]
        for cand in row:
            if cand == GRAPH:
                return RouteDecision(GRAPH, est, bucket, source, None)
            # constraint-object routing has no operand gates: posting /
            # overlay need the serving layer's posting structures
        return RouteDecision(GRAPH, est, bucket, source, None)
