"""Exact (brute-force) constrained search — the recall oracle and the
Assumption-1 fallback (paper §2.2: when fewer than p% of vectors satisfy the
constraint, a linear scan + brute-force ranking is the right tool).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.common.distances import squared_l2
from repro.core.constraints import make_satisfied_fn
from repro.core.types import Corpus

Array = jax.Array


@partial(jax.jit, static_argnames=("k", "block"))
def exact_constrained_search(
    corpus: Corpus, queries: Array, constraint, k: int, block: int = 65536
) -> tuple[Array, Array]:
    """Blocked exact constrained top-k. Returns ((B,k) dists, (B,k) ids).

    Streams the corpus in ``block``-row chunks to bound the (B, n) score
    matrix footprint; running top-k is merged per block.
    """
    satisfied = make_satisfied_fn(constraint, corpus)
    b = queries.shape[0]
    n = corpus.n
    n_blocks = (n + block - 1) // block
    pad = n_blocks * block - n

    vecs = jnp.pad(corpus.vectors, ((0, pad), (0, 0)))
    ids_all = jnp.arange(n_blocks * block, dtype=jnp.int32)

    def body(carry, blk):
        best_d, best_i = carry
        rows = jax.lax.dynamic_slice_in_dim(vecs, blk * block, block, axis=0)
        ids = jax.lax.dynamic_slice_in_dim(ids_all, blk * block, block, axis=0)
        d = squared_l2(queries, rows)  # (B, block)
        ids_b = jnp.broadcast_to(ids[None], (b, block))
        ok = satisfied(ids_b) & (ids_b < n)
        d = jnp.where(ok, d, jnp.inf)
        merged_d = jnp.concatenate([best_d, d], axis=-1)
        merged_i = jnp.concatenate([best_i, ids_b], axis=-1)
        neg, pos = jax.lax.top_k(-merged_d, k)
        return (-neg, jnp.take_along_axis(merged_i, pos, axis=-1)), None

    init = (
        jnp.full((b, k), jnp.inf, jnp.float32),
        jnp.full((b, k), -1, jnp.int32),
    )
    (best_d, best_i), _ = jax.lax.scan(body, init, jnp.arange(n_blocks))
    best_i = jnp.where(jnp.isfinite(best_d), best_i, -1)
    return best_d, best_i


def recall(found_ids: Array, true_ids: Array) -> Array:
    """Paper §3 recall: |A ∩ B| / |B| per query, averaged.

    Padding (-1) in ``true_ids`` (fewer than k satisfied vectors exist) is
    excluded from B.
    """
    hits = (found_ids[:, :, None] == true_ids[:, None, :]) & (
        true_ids[:, None, :] >= 0
    )
    inter = jnp.sum(jnp.any(hits, axis=1), axis=-1)
    denom = jnp.maximum(jnp.sum(true_ids >= 0, axis=-1), 1)
    return jnp.mean(inter / denom)
