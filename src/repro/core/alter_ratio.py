"""alter_ratio estimation (paper §2.4, Eq. 1).

The proximity graph approximates a kNN graph and each adjacency row is
distance-sorted at build time, so the first ``k`` edges of a vertex *are* its
approximate k nearest neighbors — Eq. 1 then needs zero distance evaluations
at query time:

    alter_ratio = mean over sampled satisfied vertices v of
                  |{satisfied u : u in top-k edges of v}| / k
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import GraphIndex, SatisfiedFn

Array = jax.Array


def estimate_alter_ratio(
    graph: GraphIndex,
    satisfied: SatisfiedFn,
    sample_sat_mask: Array,
    k: int,
    default: float = 0.5,
) -> Array:
    """Per-query alter_ratio estimate.

    sample_sat_mask: (B, S) bool — which of ``graph.sample_ids`` satisfy each
    query's constraint, produced by the shared sample probe
    (``core.estimator.sample_satisfied_mask``) during start-point selection
    and reused here for free; its row-mean is the sampled selectivity
    estimate the hybrid router falls back to for UDF constraints.

    Returns (B,) float32 in [0, 1]; ``default`` when a query has no satisfied
    sample vertex (Assumption 1 violated within the sample).
    """
    sample = graph.sample_ids  # (S,)
    b = sample_sat_mask.shape[0]
    k = min(k, graph.degree)
    nbrs = graph.neighbors[sample, :k]  # (S, k)
    nbrs_b = jnp.broadcast_to(nbrs[None], (b,) + nbrs.shape)  # (B, S, k)
    nb_sat = satisfied(nbrs_b.reshape(b, -1)).reshape(b, sample.shape[0], k)
    valid = (nbrs_b >= 0)
    # Fraction of satisfied among the (valid) top-k edges of each sample vertex.
    frac = jnp.sum((nb_sat & valid).astype(jnp.float32), axis=-1) / jnp.maximum(
        jnp.sum(valid.astype(jnp.float32), axis=-1), 1.0
    )  # (B, S)
    m = sample_sat_mask.astype(jnp.float32)
    n_sat = jnp.sum(m, axis=-1)  # (B,)
    est = jnp.sum(frac * m, axis=-1) / jnp.maximum(n_sat, 1.0)
    return jnp.where(n_sat > 0, est, jnp.float32(default))
