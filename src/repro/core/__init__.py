# AIRSHIP — constrained approximate similarity search on proximity graph.
# The paper's contribution lives here: batched lock-step graph search with
# two-frontier alternation, start-point selection, biased queue preference,
# and the Eq.-1 alter_ratio estimator; plus the baselines it is evaluated
# against (vanilla filtered search, PQ linear scan, 3-stage pipeline) and the
# scatter-search-merge distributed layout.
from repro.core.alter_ratio import estimate_alter_ratio
from repro.core.constraints import (
    ConstraintTables,
    LabelSetConstraint,
    RangeConstraint,
    constraint_tables,
    equal_constraint,
    label_set_from_lists,
    make_satisfied_fn,
    selectivity,
    unequal_pct_constraint,
)
from repro.core.distributed import make_distributed_search, shard_corpus_for_mesh
from repro.core.estimator import (
    SelectivityEstimator,
    sample_satisfied_mask,
    sampled_selectivity,
    scan_selectivity,
)
from repro.core.exact import exact_constrained_search, recall
from repro.core.histogram import AttributeHistograms
from repro.core.overlay import (
    LabelOverlay,
    OverlayCache,
    build_overlay,
    overlay_search,
)
from repro.core.posting import PostingLists, RangeIndex, posting_search
from repro.core.pipeline import three_stage_pipeline
from repro.core.pq import PQIndex, pq_constrained_search, pq_train
from repro.core.router import (
    RouteDecision,
    RouterConfig,
    StrategyRouter,
    single_label_of_words,
)
from repro.core.search import (
    ExactBackend,
    L2KernelBackend,
    PQBackend,
    TraversalContext,
    build_context,
    constrained_search,
    search_with_context,
)
from repro.core.types import (
    Corpus,
    GraphIndex,
    SearchParams,
    SearchResult,
    SearchStats,
)

__all__ = [
    "AttributeHistograms",
    "ConstraintTables",
    "Corpus",
    "ExactBackend",
    "GraphIndex",
    "L2KernelBackend",
    "LabelOverlay",
    "LabelSetConstraint",
    "OverlayCache",
    "PQBackend",
    "PQIndex",
    "PostingLists",
    "RangeConstraint",
    "RangeIndex",
    "RouteDecision",
    "RouterConfig",
    "SelectivityEstimator",
    "StrategyRouter",
    "SearchParams",
    "SearchResult",
    "SearchStats",
    "TraversalContext",
    "build_context",
    "build_overlay",
    "constrained_search",
    "constraint_tables",
    "equal_constraint",
    "estimate_alter_ratio",
    "exact_constrained_search",
    "label_set_from_lists",
    "make_distributed_search",
    "make_satisfied_fn",
    "overlay_search",
    "posting_search",
    "pq_constrained_search",
    "pq_train",
    "recall",
    "sample_satisfied_mask",
    "sampled_selectivity",
    "scan_selectivity",
    "search_with_context",
    "selectivity",
    "shard_corpus_for_mesh",
    "single_label_of_words",
    "three_stage_pipeline",
    "unequal_pct_constraint",
]
