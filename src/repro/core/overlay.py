"""Label-subgraph overlays: cached sub-indexes over hot posting sets.

The middle of the selectivity range (~1–5%) is where neither extreme wins:
the full-graph walk still wastes most expansions on failing vertices, but
the posting set is already thousands of ids — too many to brute-force
per request. For a *hot* label the fix is a small dedicated proximity
graph over exactly its posting set: built lazily on first use (one
``graph.build_index`` over P rows), cached, and searched with the standard
traversal engine — every vertex satisfies, so the walk never wastes an
expansion.

Lifecycle: an overlay is pinned to the streaming epoch it was built from.
Epoch swaps (snapshot publication after upsert/delete/consolidate)
invalidate it — ``OverlayCache.get`` rebuilds on epoch mismatch, so a
stale overlay is never served (asserted in tests). Sub-corpora pad to a
size ladder with tombstoned pad slots so one compiled search serves every
overlay in a bucket.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.pytree import pytree_dataclass, static_field
from repro.core.constraints import LabelSetConstraint, WORD_BITS
from repro.core.types import Corpus, GraphIndex, SearchParams, SearchResult

Array = jax.Array
PAD = -1

OVERLAY_BUCKETS = (256, 1024, 4096, 16384)


def overlay_bucket(count: int, ladder=OVERLAY_BUCKETS) -> int:
    for b in ladder:
        if count <= b:
            return b
    return int(count)


@pytree_dataclass
class LabelOverlay:
    """One label's cached sub-index (device arrays; static identity)."""

    corpus: Corpus  # (bucket, d) rows; pad slots tombstoned
    graph: GraphIndex  # (bucket, deg) local adjacency; pad rows all-PAD
    ids_dev: Array  # (bucket,) int32 local -> global slot map; PAD pads
    label: int = static_field(default=0)
    epoch: int = static_field(default=0)
    n_real: int = static_field(default=0)


def build_overlay(
    label: int,
    posting_ids: np.ndarray,
    vectors: np.ndarray,
    epoch: int,
    *,
    rng: Optional[Array] = None,
    degree: int = 12,
    sample_size: int = 64,
    bucket: Optional[int] = None,
) -> LabelOverlay:
    """Build one label's overlay from its LIVE posting ids (host arrays).

    Needs P >= 2 (a 1-row graph has no edges — the router never dispatches
    here below that, and the posting scan owns tiny sets anyway). The
    sub-corpus pads to the size-ladder ``bucket`` with zero rows that are
    tombstoned AND labeled -2, so they fail the equal-label constraint two
    independent ways.
    """
    from repro.graph.index import build_index

    posting_ids = np.asarray(posting_ids, np.int32)
    p = int(posting_ids.shape[0])
    if p < 2:
        raise ValueError(f"overlay needs >= 2 postings, got {p}")
    b = int(bucket) if bucket is not None else overlay_bucket(p)
    d = vectors.shape[1]

    rows = np.zeros((b, d), np.float32)
    rows[:p] = np.asarray(vectors, np.float32)[posting_ids]
    labels = np.full((b,), -2, np.int32)
    labels[:p] = int(label)
    # pad slots tombstoned: bits [p, b) set
    words = (b + WORD_BITS - 1) // WORD_BITS
    tomb = np.zeros((words,), np.uint32)
    for s in range(p, b):
        tomb[s // WORD_BITS] |= np.uint32(1) << np.uint32(s % WORD_BITS)

    sub_corpus_real = Corpus(
        vectors=jnp.asarray(rows[:p]), labels=jnp.asarray(labels[:p])
    )
    key = rng if rng is not None else jax.random.PRNGKey(
        (int(label) * 1_000_003 + int(epoch)) & 0x7FFFFFFF
    )
    sub_graph = build_index(
        key,
        sub_corpus_real,
        degree=min(int(degree), p - 1),
        sample_size=min(int(sample_size), p),
    )
    # Adjacency pads to the REQUESTED degree (not the possibly-smaller
    # built one) so every overlay in a size bucket shares one traced shape.
    sub_nbrs = np.asarray(sub_graph.neighbors)
    nbrs = np.full((b, int(degree)), PAD, np.int32)
    nbrs[:p, : sub_nbrs.shape[1]] = sub_nbrs
    # The sample also pads to a fixed length (cycling real ids — the
    # engine's seeding dedups repeats) for the same one-trace-per-bucket
    # reason.
    sample = np.resize(
        np.asarray(sub_graph.sample_ids, np.int32), (int(sample_size),)
    )

    ids_map = np.full((b,), PAD, np.int32)
    ids_map[:p] = posting_ids

    corpus = Corpus(
        vectors=jnp.asarray(rows),
        labels=jnp.asarray(labels),
        tombstones=jnp.asarray(tomb),
    )
    graph = GraphIndex(
        neighbors=jnp.asarray(nbrs),
        sample_ids=jnp.asarray(sample),
        entry_point=sub_graph.entry_point,
    )
    return LabelOverlay(
        corpus=corpus,
        graph=graph,
        ids_dev=jnp.asarray(ids_map),
        label=int(label),
        epoch=int(epoch),
        n_real=p,
    )


def overlay_search(
    overlay: LabelOverlay, queries: Array, params: SearchParams
) -> SearchResult:
    """Traversal over the overlay's sub-graph; global ids out.

    The constraint is the overlay's own equal-label mask — every real
    sub-row satisfies (the walk never wastes an expansion on a failing
    vertex) while pad rows fail via tombstone + label. Local result ids
    map back through ``ids_dev``.
    """
    from repro.core.engine.loop import constrained_search

    bq = queries.shape[0]
    lab = overlay.label
    words = jnp.zeros((bq, lab // WORD_BITS + 1), jnp.uint32)
    words = words.at[:, lab // WORD_BITS].set(
        jnp.uint32(1) << jnp.uint32(lab % WORD_BITS)
    )
    constraint = LabelSetConstraint(words=words)
    res = constrained_search(
        overlay.corpus, overlay.graph, queries, constraint, params
    )
    local = res.ids
    global_ids = jnp.where(
        local >= 0, overlay.ids_dev[jnp.maximum(local, 0)], PAD
    )
    return SearchResult(dists=res.dists, ids=global_ids, stats=res.stats)


class OverlayCache:
    """LRU cache of built overlays, keyed by label, pinned to an epoch.

    ``get`` returns a fresh overlay for (label, epoch): a cached overlay
    from an older epoch is invalidated and rebuilt — the staleness
    guarantee the acceptance criteria assert. ``build_fn(label, epoch,
    bucket)`` supplies the rebuild (the serving layer closes it over the
    current snapshot's postings + vectors).
    """

    def __init__(self, max_overlays: int = 8):
        self.max_overlays = int(max_overlays)
        self._cache: "OrderedDict[int, LabelOverlay]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.builds = 0
        self.invalidations = 0

    def get(
        self,
        label: int,
        epoch: int,
        build_fn: Callable[[int, int], Optional[LabelOverlay]],
    ) -> Optional[LabelOverlay]:
        label = int(label)
        cached = self._cache.get(label)
        if cached is not None:
            if cached.epoch == int(epoch):
                self.hits += 1
                self._cache.move_to_end(label)
                return cached
            # epoch moved under us: never serve stale
            self.invalidations += 1
            del self._cache[label]
        self.misses += 1
        overlay = build_fn(label, int(epoch))
        if overlay is None:
            return None
        assert overlay.epoch == int(epoch), "build_fn returned wrong epoch"
        self.builds += 1
        self._cache[label] = overlay
        self._cache.move_to_end(label)
        while len(self._cache) > self.max_overlays:
            self._cache.popitem(last=False)
        return overlay

    def stats(self) -> dict:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "builds": self.builds,
            "invalidations": self.invalidations,
            "resident": len(self._cache),
        }
