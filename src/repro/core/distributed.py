"""Distributed constrained search: scatter-search-merge over the mesh.

Layout (see DESIGN.md §4):
  * corpus rows + their *local* proximity subgraph are sharded over the
    ``model`` axis (each device owns an independent subgraph whose neighbor
    ids are local),
  * the query batch is sharded over the ``data`` (and optionally ``pod``)
    axes and replicated within each model group,
  * every shard builds its own ``TraversalContext`` — the distance backend's
    arrays (corpus rows, or PQ codes + per-query LUT) shard with the corpus
    rows; the per-query constraint operand shards with the batch — runs the
    full AIRSHIP search on its rows via ``search_with_context``, then the
    global top-k is one `all_gather(K)` + local merge per batch — the only
    collective on the serving path.

This is the standard production layout for distributed graph-ANN (per-shard
indexes + result merge); it keeps the graph walk entirely local so no
pointer-chasing ever crosses the interconnect. Backend sharding is generic:
``params.approx`` decides which backend payload rides along (the PQ code
matrix row-shards exactly like the vectors; codebooks replicate), with no
per-backend special cases in the search body.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map
from repro.core.constraints import LabelSetConstraint, RangeConstraint
from repro.core.engine.context import build_context
from repro.core.engine.loop import search_with_context
from repro.core.types import Corpus, GraphIndex, SearchParams, SearchResult, SearchStats

Array = jax.Array


def merge_topk(dists: Array, ids: Array, k: int) -> tuple[Array, Array]:
    """Merge per-shard results: (B, P, K) -> (B, k) global best."""
    b = dists.shape[0]
    flat_d = dists.reshape(b, -1)
    flat_i = ids.reshape(b, -1)
    neg, pos = jax.lax.top_k(-flat_d, k)
    out_i = jnp.take_along_axis(flat_i, pos, axis=-1)
    return -neg, jnp.where(jnp.isfinite(-neg), out_i, -1)


def constraint_in_spec(constraint_type: type, batch_axes: Sequence[str]):
    """Per-family shard_map in_spec: per-query operands shard with the batch.

    Registry-style so new data-constraint families extend the sharded path
    by adding one entry (UDF closures are static code, not shardable data —
    they cannot cross shard_map as an argument).
    """
    batch_axes = tuple(batch_axes)
    if constraint_type is LabelSetConstraint:
        return LabelSetConstraint(words=P(batch_axes, None))
    if constraint_type is RangeConstraint:
        return RangeConstraint(lo=P(batch_axes), hi=P(batch_axes), col=P())
    raise TypeError(
        f"no sharded in_spec for constraint type {constraint_type!r}; "
        "register it in core.distributed.constraint_in_spec"
    )


def backend_in_specs(params: SearchParams, corpus_axis: str) -> tuple:
    """Extra in_specs for the distance backend's payload, from params.approx.

    Exact / L2-kernel backends score the corpus rows already sharded by the
    corpus spec — no extra payload. PQ adds the code matrix (row-sharded
    like the vectors) + replicated codebooks; the per-query LUT is built
    per shard inside ``build_context``.
    """
    if params.approx == "pq":
        from repro.core.pq import PQIndex

        return (PQIndex(codebooks=P(), codes=P(corpus_axis)),)
    return ()


def make_distributed_search(
    mesh: Mesh,
    params: SearchParams,
    *,
    corpus_axis: str = "model",
    batch_axes: Sequence[str] = ("data",),
    constraint_type: type = LabelSetConstraint,
    with_attrs: Optional[bool] = None,
):
    """Build a jitted distributed search fn for a given mesh.

    The returned fn takes (corpus, graph, queries, constraint, pq_index=None)
    where corpus / graph hold the *global* arrays (sharded row-wise over
    ``corpus_axis``; neighbor ids are shard-local) and queries / constraint
    are batch-sharded. ``constraint_type`` selects the constraint family's
    in_spec (LabelSet by default; Range shards [lo, hi] with the batch and
    needs the attrs column, so ``with_attrs`` defaults to True for it).
    With ``params.approx == "pq"`` the PQ code matrix shards with the
    corpus rows and codebooks replicate — the trailing ``pq_index`` is then
    required; otherwise it must stay None. The signature is uniform across
    backends so callers never branch on the payload (a None rides through
    shard_map as an empty pytree with a None in_spec).
    """
    batch_axes = tuple(batch_axes)
    if with_attrs is None:
        with_attrs = constraint_type is RangeConstraint
    corpus_spec = P(corpus_axis)

    in_specs = (
        Corpus(
            vectors=corpus_spec,
            labels=corpus_spec,
            attrs=corpus_spec if with_attrs else None,
        ),
        GraphIndex(
            neighbors=corpus_spec, sample_ids=corpus_spec, entry_point=corpus_spec
        ),
        P(batch_axes, None),  # queries
        constraint_in_spec(constraint_type, batch_axes),
    )
    # The backend-payload slot is always present (uniform arity): PQ specs
    # when the backend carries codes, a None spec for the None placeholder
    # otherwise.
    backend_specs = backend_in_specs(params, corpus_axis)
    in_specs = in_specs + (backend_specs if backend_specs else (None,))
    out_specs = SearchResult(
        dists=P(batch_axes, None),
        ids=P(batch_axes, None),
        stats=SearchStats(
            dist_evals=P(batch_axes),
            hops=P(batch_axes),
            visited=P(batch_axes),
            iters=P(),
            beam_expansions=P(batch_axes, None),
        ),
    )

    def shard_fn(corpus, graph, queries, constraint, pq_index):
        n_local = corpus.vectors.shape[0]
        shard = jax.lax.axis_index(corpus_axis)
        # Per-shard context: the backend holds this shard's rows (or codes
        # + the local batch's LUT); the constraint closure closes over this
        # shard's metadata columns.
        ctx = build_context(
            corpus, constraint, queries, params, pq_index,
            degree=graph.neighbors.shape[1],
        )
        res = search_with_context(ctx, corpus, graph, queries, params)
        # Local ids -> global ids (row-sharded partition => offset).
        gids = jnp.where(res.ids >= 0, res.ids + shard * n_local, -1)
        # One collective: gather every shard's K best, merge locally.
        all_d = jax.lax.all_gather(res.dists, corpus_axis, axis=1)  # (B, P, K)
        all_i = jax.lax.all_gather(gids, corpus_axis, axis=1)
        out_d, out_i = merge_topk(all_d, all_i, params.k)
        stats = SearchStats(
            dist_evals=jax.lax.psum(res.stats.dist_evals, corpus_axis),
            hops=jax.lax.pmax(res.stats.hops, corpus_axis),
            visited=jax.lax.psum(res.stats.visited, corpus_axis),
            iters=jax.lax.pmax(res.stats.iters, corpus_axis),
            # Per-slot expansions sum across shards (each shard walks its
            # own subgraph with the full beam).
            beam_expansions=jax.lax.psum(res.stats.beam_expansions, corpus_axis),
        )
        return SearchResult(dists=out_d, ids=out_i, stats=stats)

    sharded = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )
    jitted = jax.jit(sharded)
    needs_pq = params.approx == "pq"

    def search(corpus, graph, queries, constraint, pq_index=None):
        if needs_pq and pq_index is None:
            raise ValueError("params.approx='pq' requires a pq_index argument")
        if not needs_pq and pq_index is not None:
            raise ValueError(
                "pq_index passed but params.approx != 'pq'; the exact search "
                "would silently ignore it"
            )
        return jitted(corpus, graph, queries, constraint, pq_index)

    return search


def shard_corpus_for_mesh(
    corpus: Corpus, graph: GraphIndex, mesh: Mesh, corpus_axis: str = "model"
):
    """Device-put global arrays with the row-sharded layout expected above."""
    cspec = NamedSharding(mesh, P(corpus_axis))
    corpus_s = Corpus(
        vectors=jax.device_put(corpus.vectors, cspec),
        labels=jax.device_put(corpus.labels, cspec),
        attrs=(
            jax.device_put(corpus.attrs, cspec)
            if corpus.attrs is not None
            else None
        ),
    )
    graph_s = GraphIndex(
        neighbors=jax.device_put(graph.neighbors, cspec),
        sample_ids=jax.device_put(graph.sample_ids, cspec),
        entry_point=jax.device_put(graph.entry_point, cspec),
    )
    return corpus_s, graph_s
