"""Distributed constrained search: scatter-search-merge over the mesh.

Layout (see DESIGN.md §4):
  * corpus rows + their *local* proximity subgraph are sharded over the
    ``model`` axis (each device owns an independent subgraph whose neighbor
    ids are local),
  * the query batch is sharded over the ``data`` (and optionally ``pod``)
    axes and replicated within each model group,
  * every shard runs the full AIRSHIP search on its rows, then the global
    top-k is one `all_gather(K)` + local merge per batch — the only
    collective on the serving path.

This is the standard production layout for distributed graph-ANN (per-shard
indexes + result merge); it keeps the graph walk entirely local so no
pointer-chasing ever crosses the interconnect.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.common.compat import shard_map
from repro.core.constraints import LabelSetConstraint
from repro.core.search import constrained_search
from repro.core.types import Corpus, GraphIndex, SearchParams, SearchResult, SearchStats

Array = jax.Array


def merge_topk(dists: Array, ids: Array, k: int) -> tuple[Array, Array]:
    """Merge per-shard results: (B, P, K) -> (B, k) global best."""
    b = dists.shape[0]
    flat_d = dists.reshape(b, -1)
    flat_i = ids.reshape(b, -1)
    neg, pos = jax.lax.top_k(-flat_d, k)
    out_i = jnp.take_along_axis(flat_i, pos, axis=-1)
    return -neg, jnp.where(jnp.isfinite(-neg), out_i, -1)


def make_distributed_search(
    mesh: Mesh,
    params: SearchParams,
    *,
    corpus_axis: str = "model",
    batch_axes: Sequence[str] = ("data",),
    with_pq: bool = False,
):
    """Build a jitted distributed search fn for a given mesh.

    The returned fn takes (corpus, graph, queries, constraint[, pq_index])
    where corpus / graph hold the *global* arrays (sharded row-wise over
    ``corpus_axis``; neighbor ids are shard-local) and queries / constraint
    are batch-sharded. With ``with_pq`` (params.approx == "pq"), the PQ code
    matrix shards with the corpus rows and codebooks replicate.
    """
    batch_axes = tuple(batch_axes)
    corpus_spec = P(corpus_axis)
    batch_spec = P(batch_axes)

    in_specs = (
        Corpus(vectors=corpus_spec, labels=corpus_spec, attrs=None),
        GraphIndex(
            neighbors=corpus_spec, sample_ids=corpus_spec, entry_point=corpus_spec
        ),
        P(batch_axes, None),  # queries
        LabelSetConstraint(words=P(batch_axes, None)),
    )
    if with_pq:
        from repro.core.pq import PQIndex

        in_specs = in_specs + (
            PQIndex(codebooks=P(), codes=corpus_spec),
        )
    out_specs = SearchResult(
        dists=P(batch_axes, None),
        ids=P(batch_axes, None),
        stats=SearchStats(
            dist_evals=P(batch_axes),
            hops=P(batch_axes),
            visited=P(batch_axes),
            iters=P(),
            beam_expansions=P(batch_axes, None),
        ),
    )

    def shard_fn(corpus, graph, queries, constraint, *pq):
        n_local = corpus.vectors.shape[0]
        shard = jax.lax.axis_index(corpus_axis)
        res = constrained_search(
            corpus, graph, queries, constraint, params,
            pq_index=pq[0] if pq else None,
        )
        # Local ids -> global ids (row-sharded partition => offset).
        gids = jnp.where(res.ids >= 0, res.ids + shard * n_local, -1)
        # One collective: gather every shard's K best, merge locally.
        all_d = jax.lax.all_gather(res.dists, corpus_axis, axis=1)  # (B, P, K)
        all_i = jax.lax.all_gather(gids, corpus_axis, axis=1)
        out_d, out_i = merge_topk(all_d, all_i, params.k)
        stats = SearchStats(
            dist_evals=jax.lax.psum(res.stats.dist_evals, corpus_axis),
            hops=jax.lax.pmax(res.stats.hops, corpus_axis),
            visited=jax.lax.psum(res.stats.visited, corpus_axis),
            iters=jax.lax.pmax(res.stats.iters, corpus_axis),
            # Per-slot expansions sum across shards (each shard walks its
            # own subgraph with the full beam).
            beam_expansions=jax.lax.psum(res.stats.beam_expansions, corpus_axis),
        )
        return SearchResult(dists=out_d, ids=out_i, stats=stats)

    sharded = shard_map(
        shard_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
    )
    return jax.jit(sharded)


def shard_corpus_for_mesh(
    corpus: Corpus, graph: GraphIndex, mesh: Mesh, corpus_axis: str = "model"
):
    """Device-put global arrays with the row-sharded layout expected above."""
    cspec = NamedSharding(mesh, P(corpus_axis))
    rep = NamedSharding(mesh, P())
    corpus_s = Corpus(
        vectors=jax.device_put(corpus.vectors, cspec),
        labels=jax.device_put(corpus.labels, cspec),
        attrs=None,
    )
    del rep
    graph_s = GraphIndex(
        neighbors=jax.device_put(graph.neighbors, cspec),
        sample_ids=jax.device_put(graph.sample_ids, cspec),
        entry_point=jax.device_put(graph.entry_point, cspec),
    )
    return corpus_s, graph_s
