"""Incremental attribute histograms for O(1) selectivity estimates.

The hybrid strategy router (core/router.py) must not pay the O(n)
``scan_selectivity`` per request — it needs a host-side estimate in
microseconds. These histograms are maintained *incrementally* by the
streaming layer: every ``insert``/``delete`` updates the counts by ±1
(consolidation moves PENDING→FREE slots and therefore never changes live
membership), so the histograms are EXACT for the label family at every
snapshot publication — not a sketch. Range estimates are exact only up to
within-bin interpolation (equi-width bins, edges frozen at construction).

Host-side numpy throughout — estimates never touch the device.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

WORD_BITS = 32
N_RANGE_BINS = 64


class AttributeHistograms:
    """Live-set label counts + per-column equi-width range histograms.

    ``label_counts[l]`` is the number of LIVE corpus rows with label ``l``;
    ``n_live`` the LIVE total. Range histograms bin each attribute column
    into ``n_bins`` equi-width cells between the edges observed at
    construction (out-of-range values clamp into the end bins, keeping
    counts exact even as streaming inserts drift past the initial extent —
    only the *interpolation* inside the end bins degrades).
    """

    def __init__(
        self,
        n_labels: int,
        n_attr_cols: int = 0,
        attr_edges: Optional[np.ndarray] = None,
        n_bins: int = N_RANGE_BINS,
    ):
        self.label_counts = np.zeros((max(int(n_labels), 1),), np.int64)
        self.n_live = 0
        self.n_bins = int(n_bins)
        self.n_attr_cols = int(n_attr_cols)
        if n_attr_cols > 0:
            if attr_edges is None:
                attr_edges = np.stack(
                    [np.zeros(n_attr_cols), np.ones(n_attr_cols)], axis=-1
                )
            self.attr_edges = np.asarray(attr_edges, np.float64)  # (C, 2)
            self.range_counts = np.zeros((n_attr_cols, self.n_bins), np.int64)
        else:
            self.attr_edges = None
            self.range_counts = None

    # --- construction -----------------------------------------------------
    @classmethod
    def from_arrays(
        cls,
        labels: np.ndarray,
        attrs: Optional[np.ndarray],
        live_mask: Optional[np.ndarray] = None,
        n_labels: Optional[int] = None,
        n_bins: int = N_RANGE_BINS,
    ) -> "AttributeHistograms":
        """Exact histograms over the LIVE rows of host arrays.

        ``live_mask`` (n,) bool selects live rows (None = all live);
        edges for the range histograms come from the live attrs' extent.
        """
        labels = np.asarray(labels)
        if live_mask is not None:
            live_mask = np.asarray(live_mask, bool)
            labels_live = labels[live_mask]
        else:
            labels_live = labels
        nl = int(n_labels) if n_labels is not None else (
            int(labels_live.max()) + 1 if labels_live.size else 1
        )
        cols = 0 if attrs is None else int(np.asarray(attrs).shape[1])
        edges = None
        attrs_live = None
        if cols:
            attrs_np = np.asarray(attrs, np.float64)
            attrs_live = attrs_np[live_mask] if live_mask is not None else attrs_np
            if attrs_live.shape[0]:
                lo = attrs_live.min(axis=0)
                hi = attrs_live.max(axis=0)
            else:
                lo, hi = np.zeros(cols), np.ones(cols)
            hi = np.where(hi > lo, hi, lo + 1.0)  # degenerate column guard
            edges = np.stack([lo, hi], axis=-1)
        h = cls(nl, cols, attr_edges=edges, n_bins=n_bins)
        if labels_live.size:
            counts = np.bincount(labels_live.astype(np.int64), minlength=nl)
            h.label_counts[: counts.shape[0]] = counts
        h.n_live = int(labels_live.shape[0])
        if cols and attrs_live is not None and attrs_live.shape[0]:
            for c in range(cols):
                bins = h._bin_of(c, attrs_live[:, c])
                h.range_counts[c] = np.bincount(bins, minlength=h.n_bins)
        return h

    @classmethod
    def from_corpus(cls, corpus, n_labels: Optional[int] = None,
                    n_bins: int = N_RANGE_BINS) -> "AttributeHistograms":
        """Exact histograms from a (possibly tombstoned) device Corpus."""
        labels = np.asarray(corpus.labels)
        attrs = None if corpus.attrs is None else np.asarray(corpus.attrs)
        live = None
        if corpus.tombstones is not None:
            words = np.asarray(corpus.tombstones)
            bits = np.unpackbits(words.view(np.uint8), bitorder="little")
            live = bits[: labels.shape[0]] == 0
        return cls.from_arrays(labels, attrs, live, n_labels, n_bins)

    # --- incremental maintenance (streaming layer) -------------------------
    def _grow_labels(self, label: int) -> None:
        if label >= self.label_counts.shape[0]:
            grown = np.zeros((label + 1,), np.int64)
            grown[: self.label_counts.shape[0]] = self.label_counts
            self.label_counts = grown

    def _bin_of(self, col: int, val) -> np.ndarray:
        lo, hi = self.attr_edges[col]
        x = (np.asarray(val, np.float64) - lo) / (hi - lo)
        b = np.floor(x * self.n_bins).astype(np.int64)
        return np.clip(b, 0, self.n_bins - 1)  # out-of-range → end bins

    def on_insert(self, label: int, attrs_row: Optional[np.ndarray] = None) -> None:
        label = int(label)
        self._grow_labels(label)
        self.label_counts[label] += 1
        self.n_live += 1
        if self.range_counts is not None and attrs_row is not None:
            for c in range(self.n_attr_cols):
                self.range_counts[c, int(self._bin_of(c, attrs_row[c]))] += 1

    def on_delete(self, label: int, attrs_row: Optional[np.ndarray] = None) -> None:
        label = int(label)
        self._grow_labels(label)
        self.label_counts[label] -= 1
        self.n_live -= 1
        if self.range_counts is not None and attrs_row is not None:
            for c in range(self.n_attr_cols):
                self.range_counts[c, int(self._bin_of(c, attrs_row[c]))] -= 1

    # --- estimates ---------------------------------------------------------
    def estimate(self, family: str, operand) -> Optional[float]:
        """Estimated satisfied fraction of the LIVE set, or None when this
        histogram cannot cover the family (UDF, missing attrs).

        family "label": operand is the (Lw,) uint32 allowed-label bitmask
        row (serving wire format) — EXACT: sums the counts of set bits.
        family "range": operand is (lo, hi, col) — exact across fully
        covered bins, linear interpolation in the two partial end bins.
        """
        if self.n_live <= 0:
            return 0.0
        if family == "label":
            words = np.asarray(operand, np.uint32).reshape(-1)
            total = 0
            nl = self.label_counts.shape[0]
            for w, word in enumerate(words):
                word = int(word)
                while word:
                    bit = (word & -word).bit_length() - 1
                    lab = w * WORD_BITS + bit
                    if lab < nl:
                        total += int(self.label_counts[lab])
                    word &= word - 1
            return total / self.n_live
        if family == "range":
            if self.range_counts is None:
                return None
            lo, hi, col = float(operand[0]), float(operand[1]), int(operand[2])
            if col >= self.n_attr_cols or hi < lo:
                return 0.0 if hi < lo else None
            e_lo, e_hi = self.attr_edges[col]
            width = (e_hi - e_lo) / self.n_bins
            # fractional bin coordinates, clamped to the binned extent
            a = np.clip((lo - e_lo) / width, 0.0, self.n_bins)
            b = np.clip((hi - e_lo) / width, 0.0, self.n_bins)
            # b == n_bins (hi at/past the extent) folds into the last bin
            # with a full-coverage weight of 1.
            ia = min(int(np.floor(a)), self.n_bins - 1)
            ib = min(int(np.floor(b)), self.n_bins - 1)
            counts = self.range_counts[col]
            if ia == ib:
                total = float(counts[ia]) * max(b - a, 0.0)
            else:
                total = float(counts[ia]) * (ia + 1 - a)
                total += float(counts[ia + 1: ib].sum())
                total += float(counts[ib]) * (b - ib)
            return min(total / self.n_live, 1.0)
        return None

    # --- exactness check (tests / snapshot publication) --------------------
    def check_exact(self, labels: np.ndarray, live_mask: np.ndarray) -> None:
        """Raise if the incremental label counts drifted from ground truth."""
        labels = np.asarray(labels)
        live_mask = np.asarray(live_mask, bool)
        truth = np.bincount(
            labels[live_mask].astype(np.int64),
            minlength=self.label_counts.shape[0],
        )
        if int(live_mask.sum()) != self.n_live:
            raise AssertionError(
                f"histogram n_live {self.n_live} != ground truth "
                f"{int(live_mask.sum())}"
            )
        mine = self.label_counts
        if truth.shape[0] > mine.shape[0]:
            raise AssertionError("histogram label space narrower than corpus")
        if not np.array_equal(mine[: truth.shape[0]], truth):
            bad = np.nonzero(mine[: truth.shape[0]] != truth)[0][:8]
            raise AssertionError(f"label counts drifted at labels {bad.tolist()}")
        if mine[truth.shape[0]:].any():
            raise AssertionError("phantom counts beyond corpus label space")
