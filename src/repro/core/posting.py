"""Posting lists + brute-force posting-set scan executor (DESIGN.md §9).

At ≤1% selectivity the graph walk burns iterations on mostly-failing
vertices (the regime SIEVE, arXiv:2507.11907, attacks with per-predicate
indexes). There the optimal plan is not a walk at all: gather the
constraint's posting set — the ids that *can* satisfy — and score exactly
those with ONE batched distance call. The scan reuses the traversal's
``DistanceBackend.distances`` surface, so Exact | L2Kernel | PQ all work;
the PQ path prunes with ADC and exactly re-ranks survivors, mirroring the
in-loop engine's contract.

Host side, ``PostingLists`` maintains the per-label id sets (incrementally
updated by the streaming layer alongside the histograms) and ``RangeIndex``
keeps a per-column value-sorted id array (rebuilt lazily per epoch — range
postings are a sorted-slice lookup, not a per-bin set union).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine.context import ExactBackend, build_context
from repro.core.types import Corpus, SearchParams, SearchResult, SearchStats

Array = jax.Array
WORD_BITS = 32
PAD = -1


# ---------------------------------------------------------------------------
# host-side posting maintenance
# ---------------------------------------------------------------------------


class PostingLists:
    """Per-label LIVE id sets with cached sorted-array views.

    Mutations are O(1) set ops; ``ids_for_label`` materializes (and caches)
    the sorted int32 array a scan gathers with — the cache invalidates on
    the first mutation touching that label.
    """

    def __init__(self, n_labels: int):
        self._sets: List[set] = [set() for _ in range(max(int(n_labels), 1))]
        self._cache: Dict[int, np.ndarray] = {}

    @classmethod
    def from_arrays(
        cls,
        labels: np.ndarray,
        live_mask: Optional[np.ndarray] = None,
        n_labels: Optional[int] = None,
    ) -> "PostingLists":
        labels = np.asarray(labels)
        nl = int(n_labels) if n_labels is not None else (
            int(labels.max()) + 1 if labels.size else 1
        )
        p = cls(nl)
        ids = np.arange(labels.shape[0])
        if live_mask is not None:
            ids = ids[np.asarray(live_mask, bool)]
        for i in ids:
            p._sets[int(labels[i])].add(int(i))
        return p

    def _grow(self, label: int) -> None:
        while label >= len(self._sets):
            self._sets.append(set())

    def on_insert(self, label: int, slot: int) -> None:
        label = int(label)
        self._grow(label)
        self._sets[label].add(int(slot))
        self._cache.pop(label, None)

    def on_delete(self, label: int, slot: int) -> None:
        label = int(label)
        self._grow(label)
        self._sets[label].discard(int(slot))
        self._cache.pop(label, None)

    def count_label(self, label: int) -> int:
        label = int(label)
        return len(self._sets[label]) if label < len(self._sets) else 0

    def count_words(self, words: np.ndarray) -> int:
        """Posting-set size for a label-bitmask operand row."""
        return sum(self.count_label(lab) for lab in _labels_of_words(words))

    def ids_for_label(self, label: int) -> np.ndarray:
        label = int(label)
        if label >= len(self._sets):
            return np.empty((0,), np.int32)
        arr = self._cache.get(label)
        if arr is None:
            arr = np.fromiter(self._sets[label], np.int32, len(self._sets[label]))
            arr.sort()
            self._cache[label] = arr
        return arr

    def ids_for_words(self, words: np.ndarray) -> np.ndarray:
        """Sorted union of postings across every set bit of the operand."""
        labs = _labels_of_words(words)
        if not labs:
            return np.empty((0,), np.int32)
        if len(labs) == 1:
            return self.ids_for_label(labs[0])
        parts = [self.ids_for_label(lab) for lab in labs]
        return np.unique(np.concatenate(parts)).astype(np.int32)


def _labels_of_words(words: np.ndarray) -> List[int]:
    labs: List[int] = []
    for w, word in enumerate(np.asarray(words, np.uint32).reshape(-1)):
        word = int(word)
        while word:
            bit = (word & -word).bit_length() - 1
            labs.append(w * WORD_BITS + bit)
            word &= word - 1
    return labs


class RangeIndex:
    """Per-column value-sorted LIVE ids; [lo, hi] posting = one sorted slice.

    Rebuilt lazily: callers bump ``version`` (the streaming layer passes its
    epoch) and the sort re-runs only when the version moved — a range
    posting lookup is then two binary searches.
    """

    def __init__(self):
        self.version = -1
        self._order: Dict[int, np.ndarray] = {}  # col -> ids sorted by value
        self._vals: Dict[int, np.ndarray] = {}  # col -> sorted values

    def refresh(
        self,
        attrs: np.ndarray,
        live_mask: np.ndarray,
        version: int,
    ) -> None:
        if version == self.version:
            return
        attrs = np.asarray(attrs)
        live = np.nonzero(np.asarray(live_mask, bool))[0].astype(np.int32)
        self._order.clear()
        self._vals.clear()
        for c in range(attrs.shape[1]):
            v = attrs[live, c]
            o = np.argsort(v, kind="stable")
            self._order[c] = live[o]
            self._vals[c] = v[o]
        self.version = version

    def ids_for_range(self, lo: float, hi: float, col: int) -> np.ndarray:
        vals = self._vals.get(int(col))
        if vals is None:
            return np.empty((0,), np.int32)
        a = int(np.searchsorted(vals, lo, side="left"))
        b = int(np.searchsorted(vals, hi, side="right"))
        out = self._order[int(col)][a:b]
        return np.sort(out).astype(np.int32)

    def count_range(self, lo: float, hi: float, col: int) -> int:
        vals = self._vals.get(int(col))
        if vals is None:
            return 0
        return int(np.searchsorted(vals, hi, side="right")) - int(
            np.searchsorted(vals, lo, side="left")
        )


def pad_posting(ids: np.ndarray, bucket: int) -> np.ndarray:
    """Pad a posting array to ``bucket`` with PAD (-1) for shape reuse."""
    out = np.full((bucket,), PAD, np.int32)
    out[: ids.shape[0]] = ids
    return out


def posting_bucket(count: int, ladder=(256, 1024, 4096, 16384)) -> int:
    """Smallest ladder bucket holding ``count`` postings (compile reuse:
    one traced scan per bucket size, not per posting-set size)."""
    for b in ladder:
        if count <= b:
            return b
    return ladder[-1] if count <= ladder[-1] else int(count)


# ---------------------------------------------------------------------------
# the scan itself
# ---------------------------------------------------------------------------


def posting_scan_with_context(
    ctx,
    corpus: Corpus,
    queries: Array,
    posting_ids: Array,
    params: SearchParams,
) -> SearchResult:
    """Brute-force top-k over a padded posting set via the context backend.

    posting_ids: (P,) int32, PAD (-1) entries ignored — shared across the
    batch (every query in a micro-batch carries the same operand group).
    The constraint closure still runs over the postings: it masks pads,
    tombstones, and (for multi-label / range operands) any id the posting
    union over-included. Empty posting set (all PAD) returns all-unfilled
    (+inf, -1) rows — never crashes.

    Approximate backends (PQ/ADC) prune to the ef_result capacity then
    re-rank exactly — identical contract to the traversal engine's
    post-loop re-rank, so parity tests compare like for like.
    """
    b = queries.shape[0]
    p = posting_ids.shape[0]
    ids_b = jnp.broadcast_to(posting_ids[None, :], (b, p))
    d = ctx.backend.distances(queries, ids_b)  # (B, P)
    ok = ctx.satisfied(ids_b)  # masks pads, tombstones, constraint
    d = jnp.where(ok, d, jnp.inf)
    ids_live = jnp.where(ok, ids_b, PAD)

    if ctx.backend.approximate:
        # ADC prune to the candidate capacity, then exact re-rank — the
        # same two-stage contract as the engine's post-loop re-rank.
        r = min(params.result_capacity, p)
        neg, pos = jax.lax.top_k(-d, r)
        cand_ids = jnp.take_along_axis(ids_live, pos, axis=-1)
        exact_d = ExactBackend(vectors=corpus.vectors).distances(
            queries, cand_ids
        )
        d = jnp.where(cand_ids >= 0, exact_d, jnp.inf)
        ids_live = cand_ids
        p = r

    k = params.k
    if p < k:  # lax.top_k needs k <= columns
        padw = k - p
        d = jnp.pad(d, ((0, 0), (0, padw)), constant_values=jnp.inf)
        ids_live = jnp.pad(ids_live, ((0, 0), (0, padw)), constant_values=PAD)
    neg_top, pos = jax.lax.top_k(-d, k)
    out_d = -neg_top
    out_i = jnp.take_along_axis(ids_live, pos, axis=-1)
    out_i = jnp.where(jnp.isfinite(out_d), out_i, PAD)

    n_real = jnp.sum(posting_ids >= 0).astype(jnp.int32)
    stats = SearchStats(
        dist_evals=jnp.broadcast_to(n_real, (b,)),
        hops=jnp.zeros((b,), jnp.int32),
        visited=jnp.sum(ok, axis=-1, dtype=jnp.int32),
        iters=jnp.int32(0),
    )
    return SearchResult(dists=out_d, ids=out_i, stats=stats)


@partial(jax.jit, static_argnames=("params",))
def _posting_search(corpus, queries, constraint, posting_ids, params, pq_index):
    ctx = build_context(corpus, constraint, queries, params, pq_index)
    return posting_scan_with_context(ctx, corpus, queries, posting_ids, params)


@partial(jax.jit, static_argnames=("params", "constraint"))
def _posting_search_static(
    corpus, queries, constraint, posting_ids, params, pq_index
):
    ctx = build_context(corpus, constraint, queries, params, pq_index)
    return posting_scan_with_context(ctx, corpus, queries, posting_ids, params)


def posting_search(
    corpus: Corpus,
    queries: Array,
    constraint,
    posting_ids: Array,
    params: SearchParams,
    pq_index=None,
) -> SearchResult:
    """Jitted public entry: posting-set brute-force constrained top-k.

    Same calling convention as ``constrained_search`` plus the (P,) padded
    posting ids. One compiled scan serves every (P-bucket, params) pair;
    UDF constraints are static like the traversal path.
    """
    impl = _posting_search_static if callable(constraint) else _posting_search
    return impl(corpus, queries, constraint, posting_ids, params, pq_index)
