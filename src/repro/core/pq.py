"""Product-quantization baseline (paper §3 'PQ', Jégou et al. 2011).

The paper's PQ baseline does a constrained *linear scan*: every vector's
constraint is checked, and the surviving vectors are ranked by asymmetric
distance (ADC) on the quantized codes. The ADC table scan is the hot loop —
`repro.kernels.pq_adc` provides the Pallas kernel; this module holds codebook
training, encoding, and the LUT builder.

The scoring itself lives in ``repro.core.engine.context.PQBackend`` — the
same (codes, lut) bundle that drives graph traversal when
``SearchParams.approx == "pq"`` also scores this linear scan
(``PQBackend.scan_all``), so both consumers share one ADC formula.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.common.kmeans import kmeans
from repro.common.pytree import pytree_dataclass
from repro.core.constraints import make_satisfied_fn
from repro.core.types import Corpus

Array = jax.Array


@pytree_dataclass
class PQIndex:
    codebooks: Array  # (m_sub, n_cent, d_sub) f32
    codes: Array  # (n, m_sub) int32 (values < n_cent)


def default_m_sub(d: int, preferred: tuple[int, ...] = (16, 8, 4, 2)) -> int:
    """Largest conventional subspace count that divides ``d`` (fallback 1).

    ``pq_train`` requires ``d % m_sub == 0``; every call site that picks an
    m_sub from a dimensionality should go through this so odd dims degrade
    to coarser (still valid) codes instead of crashing.
    """
    for m in preferred:
        if d % m == 0:
            return m
    return 1


def pq_train(
    rng: Array, vectors: Array, m_sub: int = 16, n_cent: int = 256, iters: int = 20
) -> PQIndex:
    n, d = vectors.shape
    if d % m_sub != 0:
        raise ValueError(f"d={d} not divisible by m_sub={m_sub}")
    d_sub = d // m_sub
    sub = vectors.reshape(n, m_sub, d_sub).transpose(1, 0, 2)  # (m_sub, n, d_sub)
    rngs = jax.random.split(rng, m_sub)
    cents, assigns = jax.vmap(lambda r, x: kmeans(r, x, n_cent, iters))(rngs, sub)
    return PQIndex(codebooks=cents, codes=assigns.T.astype(jnp.int32))


def adc_table(index: PQIndex, queries: Array) -> Array:
    """(B, d) -> (B, m_sub, n_cent) LUT of squared subspace distances."""
    b = queries.shape[0]
    m_sub, n_cent, d_sub = index.codebooks.shape
    qs = queries.reshape(b, m_sub, d_sub).astype(jnp.float32)
    diff = qs[:, :, None, :] - index.codebooks[None]  # (B, m_sub, n_cent, d_sub)
    return jnp.sum(diff * diff, axis=-1)


def adc_scan(index: PQIndex, lut: Array, use_kernel: bool = False) -> Array:
    """(B, m_sub, n_cent) LUT -> (B, n) approximate squared distances."""
    if use_kernel:
        from repro.kernels.pq_adc.ops import pq_adc

        return pq_adc(lut, index.codes)
    from repro.core.engine.context import PQBackend

    return PQBackend(codes=index.codes, lut=lut).scan_all()


@partial(jax.jit, static_argnames=("k", "use_kernel"))
def pq_constrained_search(
    corpus: Corpus,
    index: PQIndex,
    queries: Array,
    constraint,
    k: int,
    use_kernel: bool = False,
) -> tuple[Array, Array]:
    """Constrained linear PQ scan: filter all n vectors, rank by ADC."""
    satisfied = make_satisfied_fn(constraint, corpus)
    b = queries.shape[0]
    n = corpus.n
    lut = adc_table(index, queries)
    d = adc_scan(index, lut, use_kernel=use_kernel)  # (B, n)
    ids = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32)[None], (b, n))
    d = jnp.where(satisfied(ids), d, jnp.inf)
    neg, pos = jax.lax.top_k(-d, k)
    found = jnp.where(jnp.isfinite(-neg), pos.astype(jnp.int32), -1)
    return -neg, found
