"""Fault-tolerant checkpointing: atomic, manifest-driven, elastic.

Layout:  <dir>/step_<N>/
             manifest.json       — keypaths, shapes, dtypes, step
             <leaf-id>.npy       — one array per pytree leaf

Writes go to ``step_<N>.tmp`` and are atomically renamed, so a checkpoint
directory is either complete or invisible — a crashed writer never corrupts
resume. ``restore`` puts leaves back with *target* shardings supplied by the
caller, so a run may restart on a different mesh shape (elastic restart):
the stored arrays are logical (unsharded) and resharding happens on load.

For multi-host scale the same layout shards by process (each host writes its
addressable leaves under <leaf-id>.<proc>.npy); this container is
single-process so that path is exercised by the unit tests only logically.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np

Array = jax.Array


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomically write a checkpoint; returns the final directory."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (key, leaf) in enumerate(_leaf_paths(tree)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    ckpt_dir: str,
    step: int,
    like: Any,
    shardings: Optional[Any] = None,
) -> Any:
    """Restore into the structure of ``like``; reshard onto ``shardings``.

    ``shardings`` (optional) is a pytree of NamedSharding matching ``like``
    — pass the *new* mesh's shardings to restart elastically on a different
    topology.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {leaf["key"]: leaf for leaf in manifest["leaves"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (
        jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else None
    )
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        rec = by_key[key]
        arr = np.load(os.path.join(d, rec["file"]))
        if list(arr.shape) != list(leaf.shape):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {leaf.shape}")
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    """Keep the newest ``keep`` checkpoints (bounded disk under failure loops)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))
