# Kernel block-shape autotuner (DESIGN.md §11): an explicit KernelConfig
# lattice per kernel (config.py), a committed tuning table with a
# schema-validated loader + nearest-shape fallback (table.py), and the
# roofline-pruned sweep harness that fills it (sweep.py, driven by
# benchmarks/bench_autotune.py). TraversalContext resolves configs from
# the table at build time; kernels never hard-code block shapes again.
from repro.tune.config import (  # noqa: F401
    DEFAULT_CONFIGS,
    KERNELS,
    LATTICE,
    KernelConfig,
    effective_m_blk,
    lattice_configs,
    validate_config,
)
from repro.tune.table import lookup, load_table  # noqa: F401
