"""Lattice sweep harness: enumerate → roofline-prune → time → pick winners.

One sweep point is a tuning-table key (kernel, d, deg, beam) on the current
platform. For each point the harness enumerates the kernel's applicable
lattice (``lattice_configs``), drops configs the roofline model predicts are
memory-dominated-worse or VMEM-infeasible BEFORE spending wall-clock on them
(``repro.roofline.model.prune_configs``), then times every survivor with the
N-way generalization of bench_hybrid's interleaved paired-min protocol: all
configs alternate inside ONE timing window with a rotating start offset, and
each config reports its min. Config deltas here are a few percent of
sub-millisecond calls — separate windows would let CPU frequency drift dwarf
the quantity being measured, exactly the failure mode the pairwise protocol
was built for.

Off-TPU the kernels are timed in interpret mode (``force_kernel=True``,
matching the CI smoke path): block shapes still move real work there —
m_blk caps the padded candidate count m_pad = round_up(m, tile), so a cap
that divides M exactly beats one that forces a ragged final tile — while
the jnp reference path consumes no config at all and would time every
lattice point identically.

``sweep_kernel`` returns one record per point (per-config timings, pruned
list, winner, achieved roofline_fraction = predicted bound / measured);
``table_doc`` folds winners into the committed table.json schema.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.constraints import equal_constraint
from repro.core.visited import visited_init
from repro.kernels.fused_expand.ops import fused_expand, fused_expand_adc
from repro.kernels.gather_distance.ops import gather_distance
from repro.kernels.pq_adc.ops import pq_adc
from repro.roofline.model import kernel_roofline, prune_configs
from repro.tune.config import DEFAULT_CONFIGS, KernelConfig, lattice_configs
from repro.tune.table import SCHEMA_VERSION
from repro.tune.config import LATTICE

N_CENT = 16  # ADC centroids per subspace in sweep workloads
N_LABELS = 8  # label-family constraint universe (1 bitmask word)


def timed_group(fns: Sequence[Callable[[], object]], repeats: int = 5) -> List[float]:
    """Min seconds per fn, all measured interleaved inside ONE window.

    Generalizes bench_hybrid's ``_timed_pair`` to N contenders: each rep
    runs every fn once, with the starting index rotating per rep so no
    config systematically pays the first-in-window cost. Every fn is run
    once untimed first so all timings are post-compile.
    """
    for fn in fns:
        jax.block_until_ready(fn())
    accs: List[List[float]] = [[] for _ in fns]
    n = len(fns)
    for rep in range(repeats):
        for off in range(n):
            j = (rep + off) % n
            t0 = time.perf_counter()
            jax.block_until_ready(fns[j]())
            accs[j].append(time.perf_counter() - t0)
    return [float(np.min(a)) for a in accs]


def _workload(
    kernel: str,
    config: KernelConfig,
    *,
    d: int,
    m: int,
    b: int,
    n: int,
    force_kernel: bool,
    seed: int = 0,
) -> Callable[[], object]:
    """A zero-arg callable running one kernel invocation at ``config``.

    Operands are synthesized once (outside the timed window) at the
    sweep point's shape: b queries, m candidates each, payload width d
    (vector dim for the row kernels, m_sub for ADC), corpus/codebook of
    n rows. The label-family constraint keeps the fused kernels on their
    full metadata + bitmask path.
    """
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    ids = jax.random.randint(keys[2], (b, m), -1, n)
    if kernel in ("fused_exact", "gather_distance"):
        corpus = jax.random.normal(keys[0], (n, d), jnp.float32)
        queries = jax.random.normal(keys[1], (b, d), jnp.float32)
        if kernel == "gather_distance":
            return lambda: gather_distance(
                queries, corpus, ids, force_kernel=force_kernel, config=config
            )
        meta = jax.random.randint(keys[3], (n,), 0, N_LABELS)
        cons = equal_constraint(
            jax.random.randint(keys[4], (b,), 0, N_LABELS), N_LABELS
        ).words
        visited = visited_init(b, n)
        return lambda: fused_expand(
            queries, corpus, ids, visited, meta, cons,
            family="label", force_kernel=force_kernel, config=config,
        )
    # ADC kernels: d is m_sub; LUT entries are squared distances (>= 0).
    codes = jax.random.randint(keys[0], (n, d), 0, N_CENT)
    lut = jax.random.uniform(keys[1], (b, d, N_CENT), jnp.float32)
    if kernel == "pq_adc":
        return lambda: pq_adc(lut, codes, force_kernel=force_kernel, config=config)
    meta = jax.random.randint(keys[3], (n,), 0, N_LABELS)
    cons = equal_constraint(
        jax.random.randint(keys[4], (b,), 0, N_LABELS), N_LABELS
    ).words
    visited = visited_init(b, n)
    return lambda: fused_expand_adc(
        lut, codes, ids, visited, meta, cons,
        family="label", force_kernel=force_kernel, config=config,
    )


def sweep_kernel(
    kernel: str,
    *,
    d: int,
    deg: int = 1,
    beam: int = 1,
    b: int = 4,
    n: int = 2048,
    repeats: int = 5,
    platform: Optional[str] = None,
    configs: Optional[Sequence[KernelConfig]] = None,
) -> dict:
    """Sweep one (kernel, d, deg, beam) point; return the full record.

    ``m`` (candidates per query) is deg*beam for the per-iteration
    kernels and the corpus row count n for the pq_adc full scan. The
    default config is always timed even when the roofline prunes it —
    the beats-default and roofline_fraction columns need its number.
    """
    platform = platform or jax.default_backend()
    force_kernel = platform != "tpu"
    m = n if kernel == "pq_adc" else max(deg, 1) * max(beam, 1)
    lattice = list(configs if configs is not None else lattice_configs(kernel))
    survivors, pruned = prune_configs(
        kernel, lattice, b=b, m=m, d=d, n_cent=N_CENT, platform=platform
    )
    default = DEFAULT_CONFIGS[kernel]
    if default not in survivors:
        survivors.insert(0, default)
        pruned = [c for c in pruned if c != default]

    fns = [
        _workload(kernel, cfg, d=d, m=m, b=b, n=n, force_kernel=force_kernel)
        for cfg in survivors
    ]
    times = timed_group(fns, repeats=repeats)

    rows = []
    for cfg, t in zip(survivors, times):
        bound = kernel_roofline(kernel, cfg, b=b, m=m, d=d, n_cent=N_CENT)
        rows.append(
            {
                "config": cfg.to_dict(),
                "us": round(t * 1e6, 2),
                "bound_us": round(bound.time_bound(platform) * 1e6, 4),
                "roofline_fraction": round(bound.time_bound(platform) / t, 6),
            }
        )
    win_idx = int(np.argmin(times))
    default_t = times[survivors.index(default)]
    return {
        "kernel": kernel,
        "platform": platform,
        "d": d,
        "deg": deg,
        "beam": beam,
        "b": b,
        "m": m,
        "n": n,
        "interpret": force_kernel,
        "rows": rows,
        "pruned": [c.to_dict() for c in pruned],
        "winner": survivors[win_idx].to_dict(),
        "winner_us": round(times[win_idx] * 1e6, 2),
        "default_us": round(default_t * 1e6, 2),
        "speedup_vs_default": round(default_t / times[win_idx], 4),
        "winner_roofline_fraction": rows[win_idx]["roofline_fraction"],
    }


def table_doc(records: Sequence[dict]) -> dict:
    """Fold sweep records into the committed table.json document."""
    return {
        "version": SCHEMA_VERSION,
        "lattice": {k: list(v) for k, v in LATTICE.items()},
        "entries": [
            {
                "kernel": r["kernel"],
                "platform": r["platform"],
                "d": r["d"],
                "deg": r["deg"],
                "beam": r["beam"],
                "config": r["winner"],
                "winner_us": r["winner_us"],
                "speedup_vs_default": r["speedup_vs_default"],
                "roofline_fraction": r["winner_roofline_fraction"],
            }
            for r in records
        ],
    }
