"""KernelConfig: the explicit block-shape parameter space of every kernel.

Before the autotuner the Pallas kernels ran at fixed, hand-picked shapes —
``m_blk = min(128, round_up(m, 8))``, a hard-coded 2-deep DMA double
buffer, the whole ADC LUT reduced per probe, ``bn = 256`` for the ADC
table scan. ``KernelConfig`` names those degrees of freedom so the sweep
harness (tune/sweep.py) can search them and the committed tuning table
(tune/table.json) can pin winners per (kernel, shape, platform) key.

Semantics — chosen so every config is numerically invisible:

  * ``m_blk`` is a CAP on the (1, m_blk) output-tile width, resolved per
    call as ``min(m_blk, round_up(m, 8))`` (``effective_m_blk``): small
    candidate batches always collapse to one lane-aligned tile, exactly
    like the pre-autotuner default, and distances are computed per
    candidate regardless of tiling — every ``m_blk`` yields identical
    bits (tests/test_tune.py property tests).
  * ``dma_depth`` is the candidate-row DMA pipeline depth (ring-buffer
    slots). 2 is the classic double buffer; 3–4 keep more row copies in
    flight to ride out HBM latency jitter at the cost of VMEM. Scheduling
    only — never touches values.
  * ``lut_tile`` (fused ADC kernel only) chunks the per-probe one-hot
    LUT reduction over ``n_cent`` in ``lut_tile``-column slices; 0 means
    the whole table at once. Each code row selects exactly ONE column per
    subspace, so per-row chunk sums reduce at most one non-zero (exact
    +0.0 padding — LUT entries are squared distances, never -0.0) and
    tiling is bit-invariant by construction (kernels/fused_expand).

The declared lattice is the ONLY space the sweep searches and the only
space ``table.json`` may contain (CI validates membership — see
``repro.tune.table``).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Tuple

# Kernel names are the tuning-table key's first component.
KERNELS = ("fused_exact", "fused_adc", "gather_distance", "pq_adc")

# The declared search lattice (ISSUE 8): m_blk caps 64..512, DMA pipeline
# depth 2..4, ADC LUT tiles {whole, 8, 16} centroid columns.
LATTICE = {
    "m_blk": (64, 128, 256, 512),
    "dma_depth": (2, 3, 4),
    "lut_tile": (0, 8, 16),
}


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One point of the block-shape lattice (hashable: rides jit keys and
    pytree treedefs as static aux data)."""

    m_blk: int = 128
    dma_depth: int = 2
    lut_tile: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        return cls(
            m_blk=int(d["m_blk"]),
            dma_depth=int(d["dma_depth"]),
            lut_tile=int(d["lut_tile"]),
        )


# Per-kernel defaults reproduce the pre-autotuner fixed constants exactly:
# the fused/gather kernels' min(128, round_up(m, 8)) tile + double buffer,
# pq_adc's bn=256 scan block. Used whenever the table has no entry at all
# for a (kernel, platform) — and asserted bit-identical to every other
# lattice point anyway.
DEFAULT_CONFIGS = {
    "fused_exact": KernelConfig(m_blk=128, dma_depth=2, lut_tile=0),
    "fused_adc": KernelConfig(m_blk=128, dma_depth=2, lut_tile=0),
    "gather_distance": KernelConfig(m_blk=128, dma_depth=2, lut_tile=0),
    # pq_adc consumes only m_blk (its HBM scan block ``bn``); depth/tile
    # are pinned at the lattice floor so table entries stay canonical.
    "pq_adc": KernelConfig(m_blk=256, dma_depth=2, lut_tile=0),
}


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def effective_m_blk(config: KernelConfig, m: int) -> int:
    """Resolve the m_blk cap against an actual candidate count."""
    return min(config.m_blk, _round_up(m, 8))


def validate_config(kernel: str, config: KernelConfig) -> None:
    """Raise ValueError unless ``config`` is a declared lattice point for
    ``kernel`` (the CI table-consistency check and the loader both call
    this — nothing outside the searched space ever reaches a kernel)."""
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r} (expected one of {KERNELS})")
    if config.m_blk not in LATTICE["m_blk"]:
        raise ValueError(f"{kernel}: m_blk={config.m_blk} outside {LATTICE['m_blk']}")
    if config.dma_depth not in LATTICE["dma_depth"]:
        raise ValueError(
            f"{kernel}: dma_depth={config.dma_depth} outside {LATTICE['dma_depth']}"
        )
    if config.lut_tile not in LATTICE["lut_tile"]:
        raise ValueError(
            f"{kernel}: lut_tile={config.lut_tile} outside {LATTICE['lut_tile']}"
        )
    if kernel != "fused_adc" and config.lut_tile != 0:
        raise ValueError(f"{kernel}: lut_tile only applies to fused_adc")
    if kernel == "pq_adc" and config.dma_depth != LATTICE["dma_depth"][0]:
        raise ValueError("pq_adc: dma_depth is not a tunable of the ADC scan")


def lattice_configs(kernel: str) -> Tuple[KernelConfig, ...]:
    """Every lattice point that applies to ``kernel`` — the sweep space.

    Dimensions a kernel does not consume are pinned at their canonical
    value (lut_tile=0 outside fused_adc, dma_depth=2 for pq_adc) so the
    sweep never times duplicate configs.
    """
    lut_tiles = LATTICE["lut_tile"] if kernel == "fused_adc" else (0,)
    depths = LATTICE["dma_depth"] if kernel != "pq_adc" else (2,)
    return tuple(
        KernelConfig(m_blk=m, dma_depth=dd, lut_tile=lt)
        for m, dd, lt in itertools.product(LATTICE["m_blk"], depths, lut_tiles)
    )
