"""Committed tuning table: schema-validated loader + nearest-shape fallback.

``table.json`` (next to this module) is written by the sweep harness
(benchmarks/bench_autotune.py, full mode) and read by ``build_context`` at
trace time. Key scheme — one entry per

    (kernel, platform, d, deg, beam)

where ``kernel`` ∈ ``repro.tune.config.KERNELS``, ``platform`` is
``jax.default_backend()`` at sweep time ("cpu" for this container's
interpret-mode numbers, "tpu" once hardware sweeps land), ``d`` is the
per-candidate payload width (vector dim for the row kernels, m_sub for the
ADC kernels) and ``deg``/``beam`` the graph degree and beam width whose
product is the candidate-batch width M.

Fallback rules (DESIGN.md §11), in order:

  1. exact key match → that entry's config;
  2. same (kernel, platform) → the entry at minimum log-shape distance
     sum(|log2(x / x_entry)|) over (d, deg, beam) — block-shape winners
     move slowly in shape space, so the nearest swept neighbour beats the
     blind default (ties: first entry in file order, deterministic);
  3. no (kernel, platform) entries at all → ``DEFAULT_CONFIGS[kernel]``,
     which reproduces the pre-autotuner fixed constants.

Every loaded entry is validated against the declared lattice — a table
edited outside the sweep cannot smuggle an unsearched shape into a kernel.
``python -m repro.tune.table --check`` runs the same validation standalone
(CI's tuning-table consistency step).
"""
from __future__ import annotations

import functools
import json
import math
import os
from typing import Optional

from repro.tune.config import (
    DEFAULT_CONFIGS,
    KERNELS,
    LATTICE,
    KernelConfig,
    validate_config,
)

TABLE_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "table.json")

SCHEMA_VERSION = 1

_ENTRY_REQUIRED = ("kernel", "platform", "d", "deg", "beam", "config")


def validate_table(doc: dict) -> None:
    """Raise ValueError on any schema/lattice violation."""
    if not isinstance(doc, dict):
        raise ValueError("tuning table: top level must be an object")
    if doc.get("version") != SCHEMA_VERSION:
        raise ValueError(
            f"tuning table: version {doc.get('version')!r} != {SCHEMA_VERSION}"
        )
    if doc.get("lattice") != {k: list(v) for k, v in LATTICE.items()}:
        raise ValueError(
            "tuning table: declared lattice differs from repro.tune.config.LATTICE"
        )
    entries = doc.get("entries")
    if not isinstance(entries, list):
        raise ValueError("tuning table: 'entries' must be a list")
    seen = set()
    for idx, e in enumerate(entries):
        if not isinstance(e, dict):
            raise ValueError(f"tuning table entry {idx}: not an object")
        missing = [k for k in _ENTRY_REQUIRED if k not in e]
        if missing:
            raise ValueError(f"tuning table entry {idx}: missing keys {missing}")
        if e["kernel"] not in KERNELS:
            raise ValueError(
                f"tuning table entry {idx}: unknown kernel {e['kernel']!r}"
            )
        for k in ("d", "deg", "beam"):
            if not isinstance(e[k], int) or e[k] <= 0:
                raise ValueError(
                    f"tuning table entry {idx}: {k}={e[k]!r} must be a positive int"
                )
        key = (e["kernel"], e["platform"], e["d"], e["deg"], e["beam"])
        if key in seen:
            raise ValueError(f"tuning table entry {idx}: duplicate key {key}")
        seen.add(key)
        cfg = KernelConfig.from_dict(e["config"])
        validate_config(e["kernel"], cfg)  # in-lattice, kernel-applicable


@functools.lru_cache(maxsize=4)
def load_table(path: Optional[str] = None) -> dict:
    """Load + validate the tuning table; an absent file is an empty table
    (every lookup then resolves to the per-kernel default config)."""
    path = path or TABLE_PATH
    if not os.path.exists(path):
        return {
            "version": SCHEMA_VERSION,
            "lattice": {k: list(v) for k, v in LATTICE.items()},
            "entries": [],
        }
    with open(path) as fh:
        doc = json.load(fh)
    validate_table(doc)
    return doc


def _shape_distance(entry: dict, d: int, deg: int, beam: int) -> float:
    dist = 0.0
    for key, val in (("d", d), ("deg", deg), ("beam", beam)):
        if val is None or val <= 0:
            continue  # caller doesn't know this dim — don't penalize it
        dist += abs(math.log2(val / entry[key]))
    return dist


def lookup(
    kernel: str,
    *,
    d: int,
    deg: int = 0,
    beam: int = 0,
    platform: Optional[str] = None,
    path: Optional[str] = None,
) -> KernelConfig:
    """Resolve one kernel's config for a shape key (see module docstring).

    Pure host-side python over the cached table — safe to call at jit
    trace time (build_context does), never adds traced ops.
    """
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r}")
    if platform is None:
        import jax

        platform = jax.default_backend()
    doc = load_table(path)
    candidates = [
        e
        for e in doc["entries"]
        if e["kernel"] == kernel and e["platform"] == platform
    ]
    if not candidates:
        return DEFAULT_CONFIGS[kernel]
    best = min(candidates, key=lambda e: _shape_distance(e, d, deg, beam))
    return KernelConfig.from_dict(best["config"])


def _main() -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Validate the committed tuning table (CI consistency step)."
    )
    ap.add_argument("--check", action="store_true", help="validate and exit")
    ap.add_argument("--path", default=TABLE_PATH)
    args = ap.parse_args()
    if not os.path.exists(args.path):
        print(f"tuning table: {args.path} not found")
        return 1
    with open(args.path) as fh:
        doc = json.load(fh)
    validate_table(doc)
    # Reproducibility: the loader must resolve every entry's own key back
    # to that entry's config (exact-match precedence over nearest-shape).
    for e in doc["entries"]:
        got = lookup(
            e["kernel"], d=e["d"], deg=e["deg"], beam=e["beam"],
            platform=e["platform"], path=args.path,
        )
        want = KernelConfig.from_dict(e["config"])
        if got != want:
            print(f"tuning table: loader resolves {e} to {got}, not {want}")
            return 1
    print(
        f"tuning table OK: {len(doc['entries'])} entries, "
        f"schema v{doc['version']}, lattice matches declaration"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(_main())
