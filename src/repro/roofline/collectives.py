"""Collective-byte accounting from compiled HLO text.

cost_analysis() reports FLOPs/bytes but not collective traffic; we parse the
HLO and sum the operand bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op. This feeds the third
roofline term (collective_bytes / (chips x link_bw)).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  %all-gather.3 = bf16[16,1024,512]{...} all-gather(...)
_SHAPE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\]"
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Sum output bytes of every collective op in an HLO module dump.

    Uses the op's *result* shape (for all-reduce == payload; for all-gather
    == gathered output; for reduce-scatter == scattered output). A
    conservative, schedule-independent measure of wire traffic per device.
    """
    per_op = defaultdict(int)
    counts = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match op kind as the instruction name after '='
        m = re.search(r"=.*?\b(" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:
            continue  # avoid double counting async pairs
        sm = _SHAPE_RE.search(line)
        if not sm:
            continue
        dtype, dims = sm.group(1), sm.group(2)
        per_op[kind] += _shape_bytes(dtype, dims)
        counts[kind] += 1
    total = sum(per_op.values())
    return {
        "per_op_bytes": dict(per_op),
        "per_op_counts": dict(counts),
        "total_bytes": total,
    }
