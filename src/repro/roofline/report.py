"""Roofline report generator.

Joins the dry-run artifacts (memory analysis, measured collective structure)
with the analytic model (loop-corrected FLOPs/bytes/collectives) and emits
the §Roofline markdown table.
"""
from __future__ import annotations

import glob
import json
import os

from repro.archs.base import get_arch
from repro.roofline import model as rm


def terms_for_cell(arch_name: str, shape: str, chips: int) -> rm.RooflineTerms:
    arch = get_arch(arch_name)
    fam = arch.family
    sh = arch.shapes[shape]
    if fam == "lm":
        cfg = arch.cfg
        b, s = sh["global_batch"], sh["seq_len"]
        if sh["kind"] == "train":
            f, h, c, mf = rm.lm_train_terms(cfg, b, s, chips, arch.grad_accum)
        elif shape.startswith("prefill"):
            f, h, c, mf = rm.lm_prefill_terms(cfg, b, s, chips)
        else:
            f, h, c, mf = rm.lm_decode_terms(cfg, b, s, chips)
    elif fam == "gnn":
        cfg = arch.base_cfg
        from repro.models.gnn.sampler import subgraph_sizes

        mode = sh["mode"]
        if mode == "sampled":
            n, e = subgraph_sizes(sh["batch_nodes"], sh["fanouts"])
        elif mode == "batched":
            n, e = sh["n_nodes"] * sh["batch"], sh["n_edges"] * sh["batch"]
        else:
            n, e = sh["n_nodes"], sh["n_edges"]
        f, h, c, mf = rm.mace_terms(cfg, n, e, chips, mode)
    elif fam == "recsys":
        cfg = arch.cfg
        f, h, c, mf = rm.recsys_terms(
            cfg, sh["batch"], chips, sh["kind"], sh.get("n_candidates", 0)
        )
    else:  # airship
        cfg = arch.cfg
        f, h, c, mf = rm.airship_terms(cfg, sh["batch"], chips)
    return rm.RooflineTerms(
        cell=f"{arch_name}:{shape}",
        mesh=f"{chips}chips",
        chips=chips,
        flops=f,
        hbm_bytes=h,
        coll_bytes=c,
        model_flops=mf,
    )


def load_dryrun(artifact_dir: str):
    recs = {}
    for f in glob.glob(os.path.join(artifact_dir, "*.json")):
        r = json.load(open(f))
        recs[(r["cell"], r["mesh"])] = r
    return recs


def markdown_table(artifact_dir: str = "artifacts/dryrun", chips: int = 256):
    """Per-cell roofline table for the single-pod mesh."""
    recs = load_dryrun(artifact_dir)
    lines = [
        "| cell | t_compute | t_memory | t_collective | bottleneck | "
        "model/HLO flops | roofline fraction | peak GB/chip (measured) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    rows = []
    for (cell, mesh), rec in sorted(recs.items()):
        if mesh != "16x16":
            continue
        arch_name, shape = cell.split(":")
        try:
            t = terms_for_cell(arch_name, shape, chips)
        except Exception as e:  # noqa: BLE001
            lines.append(f"| {cell} | model-error: {e} |")
            continue
        temp = rec["memory"]["temp_bytes"] or 0
        args = rec["memory"]["argument_bytes"] or 0
        rows.append((cell, t, (temp + args) / 1e9))
        lines.append(
            f"| {cell} | {t.t_compute*1e3:.2f} ms | {t.t_memory*1e3:.2f} ms | "
            f"{t.t_collective*1e3:.2f} ms | **{t.bottleneck}** | "
            f"{t.useful_fraction:.2f} | {t.roofline_fraction:.3f} | "
            f"{(temp + args)/1e9:.1f} |"
        )
    return "\n".join(lines), rows


if __name__ == "__main__":
    table, _ = markdown_table()
    print(table)
