"""Analytic roofline model per (arch x shape x mesh).

Why analytic: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE regardless of trip count (verified in tests/test_roofline_model.py), so
any scan-over-layers model under-reports FLOPs/bytes by ~L x. The dry-run
still supplies memory analysis and the *structure* of the collective
schedule; the three roofline terms are computed here from first principles
and cross-checked against cost_analysis on single-layer (loop-free) configs,
where the two must agree.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (per the assignment).
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link


@dataclasses.dataclass
class RooflineTerms:
    cell: str
    mesh: str
    chips: int
    flops: float  # total FLOPs per step, summed over chips
    hbm_bytes: float  # total HBM bytes touched per step, summed over chips
    coll_bytes: float  # per-chip wire bytes per step
    model_flops: float  # 6*N*D (train) / 2*N_active*D (serve) "useful" flops

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        ts = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(ts, key=ts.get)

    @property
    def step_time(self) -> float:
        """No-overlap upper bound (sum) — conservative."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_fraction(self) -> float:
        return self.model_flops / max(self.flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Achievable MFU bound: useful flops / (step_time * peak)."""
        return self.model_flops / (self.step_time * self.chips * PEAK_FLOPS)


# ---------------------------------------------------------------------------
# LM transformers
# ---------------------------------------------------------------------------
def _lm_matmul_params(cfg) -> tuple[float, float]:
    """(total matmul params, active matmul params per token)."""
    d = cfg.d_model
    attn = {}
    if cfg.attn_type == "mla":
        per = cfg.kv_lora_rank * cfg.n_heads * (cfg.d_nope + cfg.d_v)  # wkv_b
        per += d * (cfg.kv_lora_rank + cfg.d_rope)  # wkv_a
        per += cfg.n_heads * cfg.d_v * d  # wo
        if cfg.q_lora_rank:
            per += d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * (
                cfg.d_nope + cfg.d_rope
            )
        else:
            per += d * cfg.n_heads * (cfg.d_nope + cfg.d_rope)
    else:
        per = d * cfg.n_heads * cfg.head_dim * 2 + d * cfg.n_kv_heads * cfg.head_dim * 2
    dense_ffn = 3 * d * cfg.d_ff
    moe_total = 3 * d * cfg.d_ff_expert * cfg.n_experts if cfg.is_moe else 0
    moe_active = 3 * d * cfg.d_ff_expert * cfg.top_k if cfg.is_moe else 0
    shared = 3 * d * cfg.d_ff_expert * cfg.n_shared_experts if cfg.is_moe else 0
    head = 2 * d * cfg.vocab_padded  # embed + lm_head
    total = (
        cfg.n_dense * (per + dense_ffn)
        + cfg.n_moe * (per + moe_total + shared)
        + head
    )
    active = (
        cfg.n_dense * (per + dense_ffn)
        + cfg.n_moe * (per + moe_active + shared)
        + head
    )
    if cfg.mtp:
        total += per + dense_ffn + 2 * d * d
        active += per + dense_ffn + 2 * d * d
    return float(total), float(active)


def _lm_attn_flops_fwd(cfg, batch: int, s_q: int, s_kv: int) -> float:
    """Score+PV matmuls; our flash kernel computes the full rectangle (the
    causal mask is applied, not skipped), so no /2."""
    dh_qk = cfg.d_nope + cfg.d_rope if cfg.attn_type == "mla" else cfg.head_dim
    dh_v = cfg.d_v if cfg.attn_type == "mla" else cfg.head_dim
    return 2.0 * batch * cfg.n_heads * s_q * s_kv * (dh_qk + dh_v) * cfg.n_layers


def lm_train_terms(cfg, batch: int, seq: int, chips: int, grad_accum: int = 1):
    tokens = batch * seq
    total_p, active_p = _lm_matmul_params(cfg)
    # fwd 2, bwd 4, full-remat recompute +2.
    remat_mult = 8.0 if cfg.remat == "full" else 6.0
    mm_flops = remat_mult / 2.0 * 2.0 * active_p * tokens
    # attention: fwd + remat recompute + FA2 bwd (5 matmuls vs 2 fwd).
    attn_fwd = _lm_attn_flops_fwd(cfg, batch, seq, seq)
    attn_flops = attn_fwd * (1.0 + 1.0 + 2.5)
    flops = mm_flops + attn_flops
    model_flops = 6.0 * active_p * tokens

    p_bytes = total_p * 2.0  # bf16
    # params: fwd read + bwd read + grad write + opt read/write (factored
    # stats are negligible; momentum bf16 r/w).
    param_traffic = p_bytes * 5.0
    # activations: residual + block internals, ~12 r/w of (T, D) per layer,
    # x2 for remat recompute; bf16.
    act_traffic = 12.0 * 2.0 * cfg.n_layers * tokens * cfg.d_model * 2.0
    hbm = param_traffic + act_traffic

    # Collectives per chip: TP reduce-scatter+all-gather pairs per layer
    # (SP residual x4), MoE psum, FSDP param all-gather (fwd+bwd) + grad RS.
    tp = 16
    t_local = tokens / max(chips / tp, 1)
    layer_ar = 4.0 * t_local * cfg.d_model * 2.0 * cfg.n_layers * grad_accum
    fsdp = 3.0 * p_bytes / tp  # AG fwd + AG bwd + RS grads, per chip
    coll = layer_ar + fsdp
    return flops, hbm, coll, model_flops


def lm_prefill_terms(cfg, batch: int, seq: int, chips: int):
    tokens = batch * seq
    _, active_p = _lm_matmul_params(cfg)
    flops = 2.0 * active_p * tokens + _lm_attn_flops_fwd(cfg, batch, seq, seq)
    model_flops = 2.0 * active_p * tokens
    total_p, _ = _lm_matmul_params(cfg)
    hbm = total_p * 2.0 + 8.0 * cfg.n_layers * tokens * cfg.d_model * 2.0
    tp = 16
    t_local = tokens / max(chips / tp, 1)
    coll = 4.0 * t_local * cfg.d_model * 2.0 * cfg.n_layers
    return flops, hbm, coll, model_flops


def lm_decode_terms(cfg, batch: int, s_cache: int, chips: int):
    total_p, active_p = _lm_matmul_params(cfg)
    flops = 2.0 * active_p * batch
    if cfg.attn_type == "mla":
        kv_row = cfg.kv_lora_rank + cfg.d_rope  # latent cache, no head dim
        attn = 2.0 * batch * cfg.n_heads * s_cache * (kv_row + cfg.kv_lora_rank)
        cache_bytes = batch * s_cache * kv_row * 2.0 * cfg.n_layers
    else:
        attn = (
            2.0 * batch * cfg.n_heads * s_cache * 2 * cfg.head_dim
        )
        cache_bytes = (
            2.0 * batch * s_cache * cfg.n_kv_heads * cfg.head_dim * 2.0 * cfg.n_layers
        )
    attn *= cfg.n_layers
    flops += attn
    model_flops = 2.0 * active_p * batch + attn
    hbm = total_p * 2.0 + cache_bytes  # weights + whole cache read each step
    # LSE-combine psums (tiny) + TP psum of (B, D) per layer + head gather.
    coll = 4.0 * batch * cfg.d_model * 2.0 * cfg.n_layers / max(chips / 16, 1)
    return flops, hbm, coll, model_flops


# ---------------------------------------------------------------------------
# MACE GNN
# ---------------------------------------------------------------------------
def mace_terms(cfg, n_nodes: int, n_edges: int, chips: int, mode: str):
    k = cfg.d_hidden
    # per edge: radial MLP + messages for 13 lm components; per node: 8K->K
    # update + invariant contractions (~30 K flops) ; x3 for fwd+bwd(energy)
    # and x2 again for the force grad (second backward).
    edge_flops = n_edges * (
        2 * (cfg.n_rbf * cfg.d_radial_mlp + cfg.d_radial_mlp * 3 * k) + 2 * 13 * k
    )
    node_flops = n_nodes * (2 * 8 * k * k + 40 * k)
    fwd = (edge_flops + node_flops) * cfg.n_layers
    flops = fwd * 6.0  # fwd + bwd + force-grad double-backward
    model_flops = fwd * 6.0
    feat = cfg.d_feat if cfg.d_feat else cfg.n_species
    hbm = (
        n_edges * (13 + 3) * k * 4.0 * cfg.n_layers * 3.0
        + n_nodes * (13 * k + feat) * 4.0 * 3.0
    )
    if mode == "dst_partitioned":
        coll = cfg.n_layers * 3.0 * n_nodes * k * 2.0  # all-gather h per layer
    elif mode == "simple":
        coll = 0.0
    else:
        coll = n_nodes * k * 4.0  # psum of A for edge-sharded modes
    return flops, hbm, coll, model_flops


# ---------------------------------------------------------------------------
# RecSys
# ---------------------------------------------------------------------------
def _mlp_params(dims) -> float:
    return float(sum(a * b for a, b in zip(dims[:-1], dims[1:])))


def recsys_terms(cfg, batch: int, chips: int, kind: str, n_candidates: int = 0):
    d = cfg.embed_dim
    if cfg.model == "dlrm":
        n_f = len(cfg.vocab_sizes) + 1
        mlp_p = _mlp_params((cfg.n_dense,) + cfg.bot_mlp) + _mlp_params(
            (n_f * (n_f - 1) // 2 + cfg.bot_mlp[-1],) + cfg.top_mlp
        )
        inter_flops = 2.0 * batch * n_f * n_f * d
        lookup_rows = batch * len(cfg.vocab_sizes)
    elif cfg.model == "deepfm":
        n_f = len(cfg.vocab_sizes)
        mlp_p = _mlp_params((n_f * d,) + cfg.mlp + (1,))
        inter_flops = 2.0 * batch * n_f * d
        lookup_rows = batch * n_f * 2
    elif cfg.model == "sasrec":
        mlp_p = 8.0 * d * d * cfg.n_blocks
        inter_flops = (
            4.0 * batch * cfg.seq_len**2 * d * cfg.n_blocks
            + 2.0 * batch * cfg.seq_len * d  # scoring
        )
        lookup_rows = batch * cfg.seq_len * 3
    else:  # two_tower
        mlp_p = _mlp_params((2 * d,) + cfg.tower_mlp) + _mlp_params(
            (d,) + cfg.tower_mlp
        )
        inter_flops = 2.0 * batch * batch * cfg.tower_mlp[-1]  # in-batch logits
        lookup_rows = batch * (2 + cfg.hist_len)

    mm = 2.0 * mlp_p * batch
    mult = 6.0 if kind == "train" else 2.0
    flops = mm / 2.0 * mult + inter_flops * (3.0 if kind == "train" else 1.0)
    if n_candidates:
        flops += 2.0 * batch * n_candidates * cfg.tower_mlp[-1] if cfg.model == "two_tower" \
            else 2.0 * batch * n_candidates * d
    model_flops = flops
    emb_traffic = lookup_rows * d * 4.0 * (2.0 if kind == "train" else 1.0)
    hbm = emb_traffic + mlp_p * 4.0 * (3.0 if kind == "train" else 1.0)
    if n_candidates:
        hbm += n_candidates * cfg.tower_mlp[-1] * 4.0 if cfg.model == "two_tower" \
            else n_candidates * d * 4.0
    # sharded-table lookups: psum of gathered rows across the model axis
    coll = lookup_rows / max(chips / 16, 1) * d * 4.0
    return flops, hbm, coll, model_flops


# ---------------------------------------------------------------------------
# Per-config kernel roofline (PR8 autotuner)
# ---------------------------------------------------------------------------
# The block-shape autotuner (repro.tune) prunes lattice configs the model
# predicts are memory-dominated-worse before spending wall-clock on them,
# and the regression gate anchors measured kernel time against the same
# bound. Two platforms: "tpu" uses the chip constants above; anything else
# is treated as a host (CPU jnp/interpret) with the sustained-DRAM numbers
# below — deliberately round figures, because the gate compares *fractions
# of the bound across runs on the same platform*, where the constant
# cancels, not absolute MFU claims.
HOST_BW = 20e9  # B/s sustained single-socket DRAM stream
HOST_FLOPS = 100e9  # f32 FLOP/s, one core + modest SIMD (pytest/CI class)
VMEM_BYTES = 64 * 1024 * 1024  # per-core VMEM budget we allow a config


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@dataclasses.dataclass(frozen=True)
class KernelRoofline:
    """Predicted cost of ONE tuned-kernel invocation at a fixed config.

    flops/hbm_bytes follow from shape + padding (m_blk caps the tile, so
    the padded candidate count m_pad = round_up(m, effective tile) is the
    config-sensitive term); vmem_bytes is the peak resident working set
    (DMA ring + per-query operands + output tile). dma_depth never moves
    the bound — it is pure scheduling — so depth variants of one m_blk
    tie here and are separated only by measurement.
    """

    flops: float
    hbm_bytes: float
    vmem_bytes: float

    def t_compute(self, platform: str = "tpu") -> float:
        return self.flops / (PEAK_FLOPS if platform == "tpu" else HOST_FLOPS)

    def t_memory(self, platform: str = "tpu") -> float:
        return self.hbm_bytes / (HBM_BW if platform == "tpu" else HOST_BW)

    def time_bound(self, platform: str = "tpu") -> float:
        return max(self.t_compute(platform), self.t_memory(platform))

    def memory_bound(self, platform: str = "tpu") -> bool:
        return self.t_memory(platform) >= self.t_compute(platform)


def kernel_roofline(
    kernel: str,
    config,
    *,
    b: int,
    m: int,
    d: int,
    n_cent: int = 16,
) -> KernelRoofline:
    """Roofline terms for one tuned kernel at (batch b, candidates m).

    ``d`` is the payload width: the vector dim for fused_exact /
    gather_distance, the subquantizer count m_sub for fused_adc / pq_adc
    (for pq_adc, ``m`` is the corpus row count the scan covers). Mirrors
    the kernels' own padding arithmetic: effective tile =
    min(m_blk, round_up(m, 8)), m_pad = round_up(m, tile) — the term that
    makes one m_blk beat another at fixed work.
    """
    eff = min(config.m_blk, _round_up(max(m, 1), 8))
    m_pad = _round_up(max(m, 1), eff)
    row = 4.0 * d  # f32 vector row / int32 code row
    if kernel in ("fused_exact", "fused_adc"):
        meta = 4.0  # constraint metadata word riding the row DMA
        out = 12.0  # dist f32 + satisfied/fresh words
    elif kernel == "gather_distance":
        meta, out = 0.0, 4.0
    elif kernel == "pq_adc":
        meta, out = 0.0, 4.0
    else:
        raise ValueError(f"unknown kernel {kernel!r}")

    hbm = b * m_pad * (row + meta) + b * m_pad * out
    if kernel in ("fused_exact", "gather_distance"):
        # query row in + 3 flops/element (sub, square, accumulate)
        hbm += b * row
        flops = 3.0 * b * m_pad * d
    else:
        # ADC: per candidate row, each of d code words scans its n_cent
        # LUT chunk (compare + select + add); LUT streamed in once per
        # query. lut_tile re-shapes the scan, never its flop count.
        hbm += b * d * n_cent * 4.0
        flops = 3.0 * b * m_pad * d * n_cent

    lut_res = d * n_cent * 4.0 if kernel in ("fused_adc", "pq_adc") else 0.0
    chunk = getattr(config, "lut_tile", 0) or n_cent
    vmem = (
        config.dma_depth * (row + 4.0)  # row ring + meta ring
        + row  # query / per-query operand block
        + eff * out  # output tile
        + lut_res
        + min(chunk, n_cent) * d * 4.0  # active LUT slice of the scan
    )
    return KernelRoofline(flops=float(flops), hbm_bytes=float(hbm), vmem_bytes=float(vmem))


def prune_configs(
    kernel: str,
    configs,
    *,
    b: int,
    m: int,
    d: int,
    n_cent: int = 16,
    platform: str = "tpu",
):
    """Split a config lattice into (survivors, pruned) before timing.

    A config is pruned when (a) its working set exceeds VMEM_BYTES, or
    (b) the model says the kernel is memory-bound at this shape AND the
    config reads strictly more HBM bytes than the best config — timing
    it cannot change the winner, only burn sweep budget. Compute-bound
    shapes keep every feasible config: byte count no longer predicts
    rank there.
    """
    terms = {
        cfg: kernel_roofline(kernel, cfg, b=b, m=m, d=d, n_cent=n_cent)
        for cfg in configs
    }
    feasible = {c: t for c, t in terms.items() if t.vmem_bytes <= VMEM_BYTES}
    survivors, pruned = [], []
    best_bytes = min((t.hbm_bytes for t in feasible.values()), default=0.0)
    for cfg in configs:
        t = terms[cfg]
        if cfg not in feasible:
            pruned.append(cfg)
        elif t.memory_bound(platform) and t.hbm_bytes > best_bytes:
            pruned.append(cfg)
        else:
            survivors.append(cfg)
    return survivors, pruned


# ---------------------------------------------------------------------------
# AIRSHIP constrained search (serve)
# ---------------------------------------------------------------------------
def airship_terms(cfg, batch: int, chips: int, est_iters: float = 200.0):
    tp = 16
    d = cfg.dim
    # Per query per iteration: gather degree rows + distances; queue merge
    # sort ~ (ef+deg) log; across tp shards each runs the full search on its
    # shard (scatter-search-merge executes tp searches per query).
    per_iter_flops = 3.0 * cfg.degree * d  # sub+sq+add
    flops = batch * tp * est_iters * per_iter_flops + batch * tp * (
        cfg.sample_per_shard * 3.0 * d
    )
    model_flops = flops
    hbm = batch * tp * est_iters * cfg.degree * d * 4.0  # the gathers
    k = cfg.params.k
    coll = batch / max(chips / tp, 1) * tp * k * 8.0  # final all-gather merge
    return flops, hbm, coll, model_flops
