"""GNN-family Arch (MACE): the four assigned graph regimes.

  * full_graph_sm  — cora-scale full-batch (replicated; trivial memory)
  * minibatch_lg   — reddit-scale sampled training: real fanout sampler
                     feeds fixed-shape subgraphs (see models/gnn/sampler.py)
  * ogb_products   — 2.4M x 62M full-batch via the dst-partitioned layout
  * molecule       — batched small graphs (128 molecules, segment readout)

Non-molecular graphs carry no 3-D coordinates; positions are synthesized
(DESIGN.md §5) and `d_feat` enters through the species/feature projection.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.archs.base import Arch, CellSpec
from repro.distributed.meshinfo import MeshInfo
from repro.models.gnn import mace as gm
from repro.models.gnn.distributed import dst_partitioned_loss
from repro.models.gnn.sampler import subgraph_sizes
from repro.train.optimizer import adamw
from repro.train.train_step import make_train_step


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


GNN_SHAPES: Dict[str, dict] = {
    "full_graph_sm": dict(
        kind="train", n_nodes=2708, n_edges=10556, d_feat=1433, mode="simple"
    ),
    "minibatch_lg": dict(
        kind="train", batch_nodes=1024, fanouts=(15, 10), d_feat=602, mode="sampled"
    ),
    "ogb_products": dict(
        kind="train", n_nodes=2_449_029, n_edges=61_859_140, d_feat=100,
        mode="dst_partitioned",
    ),
    "molecule": dict(
        kind="train", n_nodes=30, n_edges=64, batch=128, mode="batched"
    ),
}


class GNNArch(Arch):
    family = "gnn"

    def __init__(self, cfg: gm.MACEConfig, shapes: Dict[str, dict] | None = None):
        self.name = cfg.name
        self.base_cfg = cfg
        self.shapes = shapes or GNN_SHAPES

    def shape_names(self):
        return list(self.shapes)

    def _cfg_for(self, sh: dict) -> gm.MACEConfig:
        import dataclasses

        d_feat = sh.get("d_feat", 0)
        compute = jnp.bfloat16 if sh["mode"] == "dst_partitioned" else jnp.float32
        return dataclasses.replace(
            self.base_cfg, d_feat=d_feat, compute_dtype=compute
        )

    def _batch_abs(self, sh: dict, mi: MeshInfo):
        mode = sh["mode"]
        n_all = mi.mesh.size
        if mode == "sampled":
            n, e = subgraph_sizes(sh["batch_nodes"], sh["fanouts"])
        elif mode == "batched":
            n = sh["n_nodes"] * sh["batch"]
            e = sh["n_edges"] * sh["batch"]
        else:
            n, e = sh["n_nodes"], sh["n_edges"]
        if mode == "dst_partitioned":
            n = _round_up(n, n_all)
            e = _round_up(e, n_all)
        f32, i32 = jnp.float32, jnp.int32
        batch = {
            "positions": jax.ShapeDtypeStruct((n, 3), f32),
            "senders": jax.ShapeDtypeStruct((e,), i32),
            "energy": jax.ShapeDtypeStruct((sh.get("batch", 1),), f32),
            "forces": jax.ShapeDtypeStruct((n, 3), f32),
        }
        d_feat = sh.get("d_feat", 0)
        if d_feat:
            batch["node_feat"] = jax.ShapeDtypeStruct((n, d_feat), f32)
        else:
            batch["species"] = jax.ShapeDtypeStruct((n,), i32)
        if mode == "dst_partitioned":
            batch["receivers_local"] = jax.ShapeDtypeStruct((e,), i32)
        else:
            batch["receivers"] = jax.ShapeDtypeStruct((e,), i32)
        if mode == "batched":
            batch["node_graph"] = jax.ShapeDtypeStruct((n,), i32)
        return batch

    def _batch_specs(self, sh: dict, batch_abs: dict, mi: MeshInfo):
        mode = sh["mode"]
        all_axes = mi.dp_axes + (mi.tp_axis,)
        specs = {}
        for k, v in batch_abs.items():
            if mode == "dst_partitioned" and k in ("senders", "receivers_local"):
                specs[k] = P(all_axes)
            else:
                specs[k] = P(*([None] * len(v.shape)))
        return specs

    def make_cell(self, shape: str, mi: MeshInfo) -> CellSpec:
        sh = self.shapes[shape]
        cfg = self._cfg_for(sh)
        params_abs = jax.eval_shape(lambda: gm.init_params(jax.random.PRNGKey(0), cfg))
        pspecs = gm.param_specs(cfg, mi)
        opt = adamw(lr=1e-3)
        opt_abs = jax.eval_shape(opt.init, params_abs)
        opt_specs = opt.state_specs(pspecs, params_abs)

        mode = sh["mode"]
        if mode == "dst_partitioned":
            loss_fn = lambda p, batch: dst_partitioned_loss(p, cfg, mi, batch)
        else:
            loss_fn = lambda p, batch: gm.loss(p, cfg, batch)
        if mode == "batched":
            def loss_fn(p, batch, _cfg=cfg):
                b2 = dict(batch, n_graphs=sh["batch"])
                return gm.loss(p, _cfg, b2)

        step = make_train_step(loss_fn, opt, clip_norm=1.0)
        batch_abs = self._batch_abs(sh, mi)
        batch_specs = self._batch_specs(sh, batch_abs, mi)
        return CellSpec(
            name=f"{self.name}:{shape}",
            kind="train",
            fn=step,
            args=(params_abs, opt_abs, batch_abs),
            in_specs=(pspecs, opt_specs, batch_specs),
            donate_argnums=(0, 1),
        )
