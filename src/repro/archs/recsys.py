"""RecSys-family Arch: train_batch / serve_p99 / serve_bulk / retrieval_cand.

retrieval_cand (batch=1 x 1M candidates): for the two-tower arch this is a
user-tower forward + sharded candidate matmul + top-k — the brute-force path
AIRSHIP's constrained graph search replaces (the integration is exercised in
examples/constrained_serving.py). For the ranking archs (dlrm/deepfm/sasrec)
it is bulk scoring of 1M candidate feature rows for one request context.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.archs.base import Arch, CellSpec
from repro.distributed.meshinfo import MeshInfo
from repro.models.recsys import models as rs
from repro.train.optimizer import adamw
from repro.train.train_step import make_train_step

RECSYS_SHAPES: Dict[str, dict] = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512),
    "serve_bulk": dict(kind="serve", batch=262144),
    "retrieval_cand": dict(kind="serve", batch=1, n_candidates=1_000_000),
}

_INIT = {
    "dlrm": rs.dlrm_init,
    "deepfm": rs.deepfm_init,
    "sasrec": rs.sasrec_init,
    "two_tower": rs.two_tower_init,
}
_SPECS = {
    "dlrm": rs.dlrm_specs,
    "deepfm": rs.deepfm_specs,
    "sasrec": rs.sasrec_specs,
    "two_tower": rs.two_tower_specs,
}
_LOSS = {
    "dlrm": rs.dlrm_loss,
    "deepfm": rs.deepfm_loss,
    "sasrec": rs.sasrec_loss,
    "two_tower": rs.two_tower_loss,
}


class RecsysArch(Arch):
    family = "recsys"

    def __init__(self, cfg: rs.RecsysConfig, shapes: Dict[str, dict] | None = None):
        self.name = cfg.name
        self.cfg = cfg
        self.shapes = shapes or RECSYS_SHAPES

    def shape_names(self):
        return list(self.shapes)

    def _batch_abs(self, batch: int, *, serve: bool = False, candidates: int = 0):
        cfg = self.cfg
        i32, f32 = jnp.int32, jnp.float32
        m = cfg.model
        if m == "dlrm":
            out = {
                "dense": jax.ShapeDtypeStruct((batch, cfg.n_dense), f32),
                "sparse": jax.ShapeDtypeStruct((batch, len(cfg.vocab_sizes)), i32),
            }
            if not serve:
                out["label"] = jax.ShapeDtypeStruct((batch,), f32)
            return out
        if m == "deepfm":
            out = {"sparse": jax.ShapeDtypeStruct((batch, len(cfg.vocab_sizes)), i32)}
            if not serve:
                out["label"] = jax.ShapeDtypeStruct((batch,), f32)
            return out
        if m == "sasrec":
            out = {"seq": jax.ShapeDtypeStruct((batch, cfg.seq_len), i32)}
            if serve:
                out["candidates"] = jax.ShapeDtypeStruct(
                    (batch, candidates or 100), i32
                )
            else:
                out["pos"] = jax.ShapeDtypeStruct((batch, cfg.seq_len), i32)
                out["neg"] = jax.ShapeDtypeStruct((batch, cfg.seq_len), i32)
            return out
        # two_tower
        out = {
            "user_id": jax.ShapeDtypeStruct((batch,), i32),
            "hist": jax.ShapeDtypeStruct((batch, cfg.hist_len), i32),
        }
        if candidates:
            out["candidates"] = jax.ShapeDtypeStruct(
                (candidates, cfg.tower_mlp[-1]), f32
            )
        else:
            out["item_id"] = jax.ShapeDtypeStruct((batch,), i32)
        return out

    def _batch_specs(self, batch_abs, mi: MeshInfo):
        specs = {}
        for k, v in batch_abs.items():
            if k == "candidates" and v.ndim == 2 and v.dtype == jnp.float32:
                # candidate embedding matrix: shard rows over model axis
                specs[k] = P(mi.axes_if_divisible(v.shape[0], (mi.tp_axis,)), None)
            else:
                lead = mi.axes_if_divisible(v.shape[0], mi.dp_axes)
                specs[k] = P(*((lead,) + (None,) * (len(v.shape) - 1)))
        return specs

    def make_cell(self, shape: str, mi: MeshInfo) -> CellSpec:
        cfg = self.cfg
        sh = self.shapes[shape]
        b = sh["batch"]
        params_abs = jax.eval_shape(
            lambda: _INIT[cfg.model](jax.random.PRNGKey(0), cfg)
        )
        pspecs = _SPECS[cfg.model](cfg, mi)
        name = f"{self.name}:{shape}"

        if sh["kind"] == "train":
            opt = adamw(lr=1e-3)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_specs = opt.state_specs(pspecs, params_abs)
            loss_fn = lambda p, batch: _LOSS[cfg.model](p, cfg, mi, batch)
            step = make_train_step(loss_fn, opt)
            batch_abs = self._batch_abs(b)
            return CellSpec(
                name=name,
                kind="train",
                fn=step,
                args=(params_abs, opt_abs, batch_abs),
                in_specs=(pspecs, opt_specs, self._batch_specs(batch_abs, mi)),
                donate_argnums=(0, 1),
            )

        n_cand = sh.get("n_candidates", 0)
        if cfg.model == "two_tower":
            if n_cand:
                fn = lambda p, batch: rs.two_tower_score_candidates(p, cfg, mi, batch)
                batch_abs = self._batch_abs(b, serve=True, candidates=n_cand)
            else:
                def fn(p, batch):
                    u = rs.two_tower_user(p, cfg, mi, batch)
                    v = rs.two_tower_item(p, cfg, mi, batch["item_id"])
                    return jnp.sum(u * v, axis=-1)

                batch_abs = self._batch_abs(b)
        elif cfg.model == "sasrec":
            fn = lambda p, batch: rs.sasrec_serve(p, cfg, mi, batch)
            batch_abs = self._batch_abs(b, serve=True, candidates=n_cand or 100)
        else:
            fwd = rs.dlrm_forward if cfg.model == "dlrm" else rs.deepfm_forward
            bb = n_cand if n_cand else b
            fn = lambda p, batch: jax.nn.sigmoid(fwd(p, cfg, mi, batch))
            batch_abs = self._batch_abs(bb, serve=True)
        return CellSpec(
            name=name,
            kind="serve",
            fn=fn,
            args=(params_abs, batch_abs),
            in_specs=(pspecs, self._batch_specs(batch_abs, mi)),
        )
