"""Arch/Cell abstraction: every assigned architecture exposes, per input
shape, a CellSpec — a jittable step function plus abstract inputs and their
PartitionSpecs. The dry-run lowers+compiles CellSpecs; smoke tests run
reduced configs through the same code path on CPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import jax

from repro.distributed.meshinfo import MeshInfo


@dataclasses.dataclass
class CellSpec:
    name: str  # "<arch>:<shape>"
    kind: str  # train | serve
    fn: Callable  # positional-args jittable
    args: Tuple[Any, ...]  # pytree of jax.ShapeDtypeStruct per positional arg
    in_specs: Tuple[Any, ...]  # matching pytree of PartitionSpec
    donate_argnums: Tuple[int, ...] = ()
    note: str = ""


class Arch:
    """Family base; subclasses implement make_cell + shape_names."""

    name: str = ""
    family: str = ""

    def shape_names(self) -> list[str]:
        raise NotImplementedError

    def make_cell(self, shape: str, mi: MeshInfo) -> CellSpec:
        raise NotImplementedError


def abstract(tree):
    """Map a pytree of arrays/ShapeDtypeStructs to ShapeDtypeStructs."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )


_REGISTRY: Dict[str, Callable[[], Arch]] = {}


def register(name: str, factory: Callable[[], Arch]) -> None:
    _REGISTRY[name] = factory


def get_arch(name: str) -> Arch:
    if name not in _REGISTRY:
        import repro.configs  # noqa: F401 — populate registry

    return _REGISTRY[name]()


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
