"""LM-family Arch: train_4k / prefill_32k / decode_32k / long_500k cells.

long_500k note (DESIGN.md §5): all five assigned LM archs are pure
full-attention, so quadratic *prefill* at 524k is skipped per the
assignment; the cell lowers ``serve_step`` (one-token decode over a 524k
KV cache), which is linear in S and runs with sequence-sharded KV.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.archs.base import Arch, CellSpec
from repro.distributed.meshinfo import MeshInfo
from repro.models.transformer import model as tm
from repro.train.optimizer import adafactor, adamw
from repro.train.train_step import make_train_step

LM_SHAPES: Dict[str, dict] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="serve", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="serve", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="serve", seq_len=524288, global_batch=1),
}


class LMArch(Arch):
    family = "lm"

    def __init__(
        self,
        cfg: tm.TransformerConfig,
        optimizer: str = "adafactor",
        shapes: Dict[str, dict] | None = None,
        grad_accum: int = 1,
    ):
        self.name = cfg.name
        self.cfg = cfg
        self.optimizer_name = optimizer
        self.shapes = shapes or LM_SHAPES
        self.grad_accum = grad_accum

    def shape_names(self):
        return list(self.shapes)

    def _optimizer(self):
        if self.optimizer_name == "adafactor":
            return adafactor(lr=1e-3, momentum=0.9)
        return adamw(lr=3e-4, weight_decay=0.1)

    def _abstract_params(self):
        return jax.eval_shape(lambda: tm.init_params(jax.random.PRNGKey(0), self.cfg))

    def make_cell(self, shape: str, mi: MeshInfo) -> CellSpec:
        cfg = self.cfg
        sh = self.shapes[shape]
        b, s = sh["global_batch"], sh["seq_len"]
        params_abs = self._abstract_params()
        pspecs = tm.param_specs(cfg, mi)
        name = f"{self.name}:{shape}"

        if sh["kind"] == "train":
            opt = self._optimizer()
            opt_abs = jax.eval_shape(opt.init, params_abs)
            opt_specs = opt.state_specs(pspecs, params_abs)
            loss_fn = lambda p, batch: tm.lm_loss(p, cfg, mi, batch)
            step = make_train_step(
                loss_fn, opt, clip_norm=1.0, grad_accum=self.grad_accum
            )
            batch_abs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
            batch_specs = {"tokens": P(mi.dp_axes, None)}
            return CellSpec(
                name=name,
                kind="train",
                fn=step,
                args=(params_abs, opt_abs, batch_abs),
                in_specs=(pspecs, opt_specs, batch_specs),
                donate_argnums=(0, 1),
            )

        if shape.startswith("prefill"):
            fn = lambda p, tokens: tm.prefill_logits(p, cfg, mi, tokens)
            toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
            return CellSpec(
                name=name,
                kind="serve",
                fn=fn,
                args=(params_abs, toks),
                in_specs=(pspecs, P(mi.dp_axes, None)),
            )

        # decode cells: one new token against an S-token KV cache.
        cache_abs = tm.cache_shape(cfg, b, s)
        cache_specs = tm.cache_specs(cfg, mi, b, s)
        fn = lambda p, cache, tokens: tm.decode_step(p, cfg, mi, cache, tokens)
        toks = jax.ShapeDtypeStruct((b,), jnp.int32)
        note = (
            "long-context decode: linear in S; quadratic 500k prefill skipped "
            "(pure full-attention arch)"
            if shape == "long_500k"
            else ""
        )
        return CellSpec(
            name=name,
            kind="serve",
            fn=fn,
            args=(params_abs, cache_abs, toks),
            in_specs=(
                pspecs,
                cache_specs,
                P(mi.axes_if_divisible(b, mi.dp_axes)),
            ),
            donate_argnums=(1,),
            note=note,
        )
