"""AIRSHIP serve Arch — the paper's own workload as a dry-runnable cell.

Corpus + per-shard subgraphs are row-sharded over ``model`` (scatter-search-
merge, core/distributed.py); query batches shard over the data axes. The
serve step is the full constrained graph search (mode=prefer) + one
all-gather top-k merge.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.archs.base import Arch, CellSpec
from repro.core.constraints import LabelSetConstraint
from repro.core.distributed import make_distributed_search
from repro.core.types import Corpus, GraphIndex, SearchParams
from repro.distributed.meshinfo import MeshInfo


@dataclasses.dataclass(frozen=True)
class AirshipServeConfig:
    name: str = "airship-sift1m"
    n: int = 1_000_000
    dim: int = 128
    degree: int = 32
    n_labels: int = 10
    sample_per_shard: int = 128
    params: SearchParams = SearchParams(
        mode="prefer", k=10, ef_result=128, ef_sat=128, ef_other=128,
        n_start=32, max_iters=512,
    )


AIRSHIP_SHAPES: Dict[str, dict] = {
    "serve_256": dict(kind="serve", batch=256),
    "serve_bulk_8k": dict(kind="serve", batch=8192),
    # Beyond-paper D4: ADC traversal + exact re-rank (32x fewer HBM bytes
    # per candidate); m_sub=16 codes shard with the corpus rows.
    "serve_256_pq": dict(kind="serve", batch=256, pq=True),
    # Beam-parallel engine (DESIGN.md §5): 4 pops/query/iteration feed the
    # fused gather 4*deg candidates — ~4x fewer lock-step iterations.
    "serve_256_beam4": dict(kind="serve", batch=256, beam=4),
    # PR2 fused candidate pipeline forced on: one kernels/fused_expand pass
    # per iteration + sorted-merge frontier updates (EXPERIMENTS.md §Perf
    # PR2). "auto" would enable it on TPU anyway; the explicit shape keeps
    # the fused path dry-runnable and cost-model-visible on any backend.
    "serve_256_fused": dict(kind="serve", batch=256, fuse="on"),
    # PR3 fused ADC traversal: PQBackend through the fused pipeline — code
    # rows (m_sub words/candidate) stream through the same double-buffered
    # DMA as exact rows, LUT sums in-kernel (EXPERIMENTS.md §Perf PR3).
    "serve_256_pq_fused": dict(kind="serve", batch=256, pq=True, fuse="on"),
}


class AirshipArch(Arch):
    family = "airship"

    def __init__(self, cfg: AirshipServeConfig, shapes=None):
        self.name = cfg.name
        self.cfg = cfg
        self.shapes = shapes or AIRSHIP_SHAPES

    def shape_names(self):
        return list(self.shapes)

    def make_cell(self, shape: str, mi: MeshInfo) -> CellSpec:
        import dataclasses

        cfg = self.cfg
        sh = self.shapes[shape]
        b = sh["batch"]
        use_pq = sh.get("pq", False)
        n_shards = mi.tp_size
        n = ((cfg.n + n_shards - 1) // n_shards) * n_shards
        f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
        n_words = (cfg.n_labels + 31) // 32

        corpus_abs = Corpus(
            vectors=jax.ShapeDtypeStruct((n, cfg.dim), f32),
            labels=jax.ShapeDtypeStruct((n,), i32),
            attrs=None,
        )
        graph_abs = GraphIndex(
            neighbors=jax.ShapeDtypeStruct((n, cfg.degree), i32),
            sample_ids=jax.ShapeDtypeStruct((n_shards * cfg.sample_per_shard,), i32),
            entry_point=jax.ShapeDtypeStruct((n_shards,), i32),
        )
        queries_abs = jax.ShapeDtypeStruct((b, cfg.dim), f32)
        cons_abs = LabelSetConstraint(
            words=jax.ShapeDtypeStruct((b, n_words), u32)
        )

        params = cfg.params
        if use_pq:
            params = dataclasses.replace(params, approx="pq")
        if sh.get("beam", 0) > 1:
            params = dataclasses.replace(params, beam_width=sh["beam"])
        if sh.get("fuse"):
            params = dataclasses.replace(params, fuse_expand=sh["fuse"])
        search = make_distributed_search(
            mi.mesh, params, batch_axes=mi.dp_axes
        )
        cspec = P(mi.tp_axis)
        bspec = mi.axes_if_divisible(b, mi.dp_axes)
        args = (corpus_abs, graph_abs, queries_abs, cons_abs)
        in_specs = (
            Corpus(vectors=cspec, labels=cspec, attrs=None),
            GraphIndex(neighbors=cspec, sample_ids=cspec, entry_point=cspec),
            P(bspec, None),
            LabelSetConstraint(words=P(bspec, None)),
        )
        if use_pq:
            from repro.core.pq import PQIndex, default_m_sub

            m_sub = default_m_sub(cfg.dim)
            pq_abs = PQIndex(
                codebooks=jax.ShapeDtypeStruct((m_sub, 256, cfg.dim // m_sub), f32),
                codes=jax.ShapeDtypeStruct((n, m_sub), i32),
            )
            args = args + (pq_abs,)
            in_specs = in_specs + (PQIndex(codebooks=P(), codes=cspec),)
        return CellSpec(
            name=f"{self.name}:{shape}",
            kind="serve",
            fn=search,
            args=args,
            in_specs=in_specs,
            note="paper workload: constrained ANN serve (scatter-search-merge)"
            + (" + PQ traversal (D4)" if use_pq else ""),
        )
