"""Index assembly: graph + pre-drawn sample + entry point, and the
row-partitioned layout used by the distributed scatter-search-merge path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Corpus, GraphIndex
from repro.graph.build import add_reverse_edges, build_knn_graph, medoid, nn_descent

Array = jax.Array


def build_index(
    rng: Array,
    corpus: Corpus,
    degree: int = 16,
    sample_size: int = 256,
    *,
    method: str = "exact",
    reverse_edges: bool = True,
    nn_descent_iters: int = 8,
) -> GraphIndex:
    """Build a searchable index over the corpus (single shard).

    ``method``: "exact" (blocked brute-force kNN) or "nn_descent".
    The pre-drawn sample (AIRSHIP-Start, §2.2) is taken uniformly at build
    time, exactly as the paper prescribes — no query knowledge involved.
    """
    r_graph, r_sample = jax.random.split(rng)
    if method == "exact":
        nbrs = build_knn_graph(corpus.vectors, degree)
    elif method == "nn_descent":
        nbrs = nn_descent(r_graph, corpus.vectors, degree, iters=nn_descent_iters)
    else:
        raise ValueError(f"unknown build method: {method}")
    if reverse_edges:
        nbrs = add_reverse_edges(nbrs, corpus.vectors, degree)
    sample_size = min(sample_size, corpus.n)
    sample = jax.random.choice(
        r_sample, corpus.n, (sample_size,), replace=False
    ).astype(jnp.int32)
    return GraphIndex(
        neighbors=nbrs,
        sample_ids=sample,
        entry_point=medoid(corpus.vectors),
    )


def build_partitioned_index(
    rng: Array,
    corpus: Corpus,
    n_shards: int,
    degree: int = 16,
    sample_size_per_shard: int = 128,
    **kwargs,
) -> tuple[Corpus, GraphIndex]:
    """Row-partition the corpus into ``n_shards`` independent subgraphs.

    Returns global arrays laid out so that row-sharding over the mesh's
    corpus axis hands each device exactly its subgraph: shard ``s`` owns rows
    [s*n_local, (s+1)*n_local); neighbor/sample/entry ids are *local*.
    The corpus is padded (repeating row 0) to a multiple of ``n_shards``.
    """
    n = corpus.n
    n_local = (n + n_shards - 1) // n_shards
    pad = n_local * n_shards - n
    vecs = jnp.concatenate([corpus.vectors, corpus.vectors[:max(pad, 0)]], axis=0) \
        if pad else corpus.vectors
    labs = jnp.concatenate([corpus.labels, corpus.labels[:max(pad, 0)]], axis=0) \
        if pad else corpus.labels
    attrs = corpus.attrs
    if attrs is not None and pad:
        attrs = jnp.concatenate([attrs, attrs[:pad]], axis=0)

    all_nbrs, all_samples, all_entries = [], [], []
    for s in range(n_shards):
        r = jax.random.fold_in(rng, s)
        lo = s * n_local
        sub = Corpus(vectors=vecs[lo : lo + n_local], labels=labs[lo : lo + n_local])
        idx = build_index(
            r, sub, degree=degree, sample_size=sample_size_per_shard, **kwargs
        )
        all_nbrs.append(np.asarray(idx.neighbors))
        all_samples.append(np.asarray(idx.sample_ids))
        all_entries.append(np.asarray(idx.entry_point)[None])

    graph = GraphIndex(
        neighbors=jnp.asarray(np.concatenate(all_nbrs, axis=0)),
        sample_ids=jnp.asarray(np.concatenate(all_samples, axis=0)),
        entry_point=jnp.asarray(np.concatenate(all_entries, axis=0)),
    )
    return Corpus(vectors=vecs, labels=labs, attrs=attrs), graph
