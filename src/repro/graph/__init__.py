from repro.graph.build import (
    add_reverse_edges,
    build_knn_graph,
    medoid,
    nn_descent,
)
from repro.graph.index import build_index, build_partitioned_index

__all__ = [
    "add_reverse_edges",
    "build_index",
    "build_knn_graph",
    "build_partitioned_index",
    "medoid",
    "nn_descent",
]
