"""Proximity-graph construction.

Two builders, one contract:

  * ``build_knn_graph`` — blocked *exact* kNN graph (quadratic; the default
    for n up to a few hundred thousand on this host, and the oracle for the
    approximate builder),
  * ``nn_descent`` — iterative neighbor-of-neighbor refinement for large n
    (near-linear per round; Dong et al., WWW'11), used above the exact
    builder's practical range.

Both emit the invariants the searcher and the Eq.-1 estimator rely on:
adjacency rows are distance-ascending, self-free, duplicate-free, and padded
with -1. ``add_reverse_edges`` optionally symmetrizes (HNSW-style) under the
same degree bound, which materially improves reachability for clustered data.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.distances import squared_l2

Array = jax.Array

PAD = -1


def _dedup_sorted_by_dist(ids: Array, dists: Array, degree: int) -> tuple[Array, Array]:
    """Per-row: drop duplicate ids / invalid, keep the ``degree`` closest.

    ids: (n, C) int32 (PAD for invalid), dists: (n, C) f32.
    """
    invalid = ids < 0
    d = jnp.where(invalid, jnp.inf, dists)
    # Sort by id to find duplicates, keep the first (smallest distance wins
    # later anyway because duplicates share the same distance).
    id_order = jnp.argsort(jnp.where(invalid, jnp.iinfo(jnp.int32).max, ids), axis=-1)
    ids_s = jnp.take_along_axis(ids, id_order, axis=-1)
    d_s = jnp.take_along_axis(d, id_order, axis=-1)
    dup = jnp.concatenate(
        [jnp.zeros_like(ids_s[:, :1], bool), ids_s[:, 1:] == ids_s[:, :-1]], axis=-1
    )
    d_s = jnp.where(dup, jnp.inf, d_s)
    # Now sort by distance and trim.
    order = jnp.argsort(d_s, axis=-1)
    ids_f = jnp.take_along_axis(ids_s, order, axis=-1)[:, :degree]
    d_f = jnp.take_along_axis(d_s, order, axis=-1)[:, :degree]
    ids_f = jnp.where(jnp.isfinite(d_f), ids_f, PAD)
    return ids_f, d_f


@partial(jax.jit, static_argnames=("degree", "block"))
def build_knn_graph(vectors: Array, degree: int, block: int = 4096) -> Array:
    """Exact kNN adjacency (n, degree), distance-ascending, self excluded."""
    n, _ = vectors.shape
    n_blocks = (n + block - 1) // block
    pad = n_blocks * block - n
    padded = jnp.pad(vectors, ((0, pad), (0, 0)))

    def row_block(blk):
        rows = jax.lax.dynamic_slice_in_dim(padded, blk * block, block, axis=0)
        d = squared_l2(rows, vectors)  # (block, n)
        rid = blk * block + jnp.arange(block)
        cid = jnp.arange(n)
        d = jnp.where(cid[None, :] == rid[:, None], jnp.inf, d)  # no self
        d = jnp.where(rid[:, None] < n, d, jnp.inf)  # padding rows
        neg, idx = jax.lax.top_k(-d, degree)
        dist = -neg
        idx = jnp.where(jnp.isfinite(dist), idx, PAD)
        return idx.astype(jnp.int32), dist

    idx, dist = jax.lax.map(row_block, jnp.arange(n_blocks))
    del dist
    return idx.reshape(-1, degree)[:n]


@partial(jax.jit, static_argnames=("degree", "iters", "n_extra"))
def nn_descent(
    rng: Array, vectors: Array, degree: int, iters: int = 8, n_extra: int = 2
) -> Array:
    """NN-descent approximate kNN graph.

    Each round considers, per vertex: current neighbors, a sample of
    neighbors-of-neighbors (``n_extra`` per neighbor), and fresh random
    vertices; keeps the ``degree`` closest.
    """
    n, _ = vectors.shape

    def dist_rows(ids: Array) -> Array:  # (n, C) -> (n, C)
        rows = vectors[jnp.maximum(ids, 0)]
        diff = rows - vectors[:, None, :]
        d = jnp.sum(diff * diff, axis=-1)
        self_or_pad = (ids == jnp.arange(n)[:, None]) | (ids < 0)
        return jnp.where(self_or_pad, jnp.inf, d)

    k0 = jax.random.randint(rng, (n, degree), 0, n, dtype=jnp.int32)
    nbrs, _ = _dedup_sorted_by_dist(k0, dist_rows(k0), degree)

    def round_fn(carry, r):
        nbrs = carry
        rng_r = jax.random.fold_in(rng, r)
        safe = jnp.maximum(nbrs, 0)
        # neighbor-of-neighbor sample: for each neighbor take n_extra of its edges
        cols = jax.random.randint(rng_r, (n, degree, n_extra), 0, degree)
        nn2 = jnp.take_along_axis(
            nbrs[safe], cols, axis=-1
        ).reshape(n, degree * n_extra)
        rand = jax.random.randint(
            jax.random.fold_in(rng_r, 1), (n, degree), 0, n, dtype=jnp.int32
        )
        cand = jnp.concatenate([nbrs, nn2, rand], axis=-1)
        new, _ = _dedup_sorted_by_dist(cand, dist_rows(cand), degree)
        return new, None

    nbrs, _ = jax.lax.scan(round_fn, nbrs, jnp.arange(iters))
    return nbrs


def add_reverse_edges(neighbors: Array, vectors: Array, degree: int) -> Array:
    """Symmetrize under the degree bound (host-side; build-time only)."""
    nbrs = np.asarray(neighbors)
    n, deg = nbrs.shape
    rev_lists: list[list[int]] = [[] for _ in range(n)]
    for u in range(n):
        for v in nbrs[u]:
            if v >= 0:
                rev_lists[v].append(u)
    max_rev = max(1, max(len(r) for r in rev_lists))
    rev = np.full((n, max_rev), PAD, dtype=np.int32)
    for u, lst in enumerate(rev_lists):
        rev[u, : len(lst)] = lst
    cand = jnp.concatenate([jnp.asarray(nbrs), jnp.asarray(rev)], axis=-1)
    rows = jnp.asarray(vectors)[jnp.maximum(cand, 0)]
    d = jnp.sum((rows - jnp.asarray(vectors)[:, None, :]) ** 2, axis=-1)
    d = jnp.where((cand < 0) | (cand == jnp.arange(n)[:, None]), jnp.inf, d)
    out, _ = _dedup_sorted_by_dist(cand, d, degree)
    return out


def medoid(vectors: Array) -> Array:
    """Approximate medoid: the vector closest to the corpus mean."""
    mean = jnp.mean(vectors.astype(jnp.float32), axis=0, keepdims=True)
    d = squared_l2(mean, vectors)[0]
    return jnp.argmin(d).astype(jnp.int32)
