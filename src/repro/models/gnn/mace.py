"""MACE — higher-order E(3)-equivariant message passing (arXiv:2206.07697),
in a Cartesian basis.

For l_max = 2 the real-spherical-harmonic irreps have exact Cartesian
equivalents: l=0 ↔ scalar, l=1 ↔ vector, l=2 ↔ traceless symmetric matrix.
We build the ACE A-basis per node as Cartesian moments of the neighbor
density and form the B-basis by contracting A-tensors up to correlation
order 3 with learned channel mixings — every Clebsch-Gordan coupling for
l ≤ 2 is one of the classic Cartesian contractions (dot, trace, T·v, vᵀTv,
tr(T³)), so equivariance is exact by construction (verified by property
tests under random rotations). Deviation from the reference torch/e3nn MACE:
messages are weighted by *scalar* sender features only (the dominant MACE
path); we note this in DESIGN.md §6.

Graph substrate: message passing is `jax.ops.segment_sum` over an edge list
(senders/receivers, -1 padded) — JAX has no sparse message-passing engine,
so this module IS the engine. Edge arrays shard over the data axes; node
accumulators are combined with one psum per layer (see gnn train_step).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.meshinfo import MeshInfo
from repro.models.common.modules import dense_init, mlp_apply, mlp_init, mlp_specs

Array = jax.Array
Params = dict


@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128  # channels K
    l_max: int = 2
    correlation_order: int = 3
    n_rbf: int = 8
    r_cut: float = 2.5
    d_feat: int = 0  # input node feature dim; 0 -> species embedding
    n_species: int = 32
    d_radial_mlp: int = 64
    d_readout: int = 16
    # Rematerialize each interaction layer in the backward pass: the ACE
    # A-basis is (N, K, 13) floats and the force objective double-backwards
    # through it — recompute beats storing it (29.9 -> 23.3 GB/chip on
    # minibatch_lg; EXPERIMENTS.md §Perf).
    remat_layers: bool = True
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    @property
    def n_invariants(self) -> int:
        # order-1: A0 | order-2: A1.A1, tr(A2A2), A0^2 |
        # order-3: A1.A2.A1, tr(A2^3), A0^3, A0*(A1.A1)
        return 8


def bessel_rbf(dist: Array, n_rbf: int, r_cut: float) -> Array:
    """Bessel radial basis with smooth polynomial cutoff envelope."""
    d = jnp.maximum(dist, 1e-9)[..., None]
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / r_cut) * jnp.sin(n * jnp.pi * d / r_cut) / d
    x = jnp.clip(dist / r_cut, 0.0, 1.0)
    env = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5  # C2-smooth cutoff
    return basis * env[..., None]


def init_params(rng: Array, cfg: MACEConfig) -> Params:
    ks = jax.random.split(rng, 4 + cfg.n_layers)
    k_feat = cfg.d_feat if cfg.d_feat else cfg.n_species
    p: Params = {
        "embed": dense_init(ks[0], k_feat, cfg.d_hidden, cfg.param_dtype),
        "readout": mlp_init(
            ks[1], [cfg.d_hidden, cfg.d_readout, 1], cfg.param_dtype
        ),
    }
    layers = []
    for i in range(cfg.n_layers):
        r = ks[4 + i]
        rs = jax.random.split(r, 4)
        layers.append(
            {
                # radial MLP -> per-l channel weights (3K outputs: l=0,1,2)
                "radial": mlp_init(
                    rs[0],
                    [cfg.n_rbf, cfg.d_radial_mlp, 3 * cfg.d_hidden],
                    cfg.param_dtype,
                ),
                # channel mixings applied to A before taking products
                "mix_a": dense_init(rs[1], cfg.d_hidden, cfg.d_hidden, cfg.param_dtype),
                # B-basis -> update
                "update": dense_init(
                    rs[2],
                    cfg.n_invariants * cfg.d_hidden,
                    cfg.d_hidden,
                    cfg.param_dtype,
                ),
            }
        )
    p["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return p


def param_specs(cfg: MACEConfig, mi: MeshInfo) -> Params:
    fs, tp = mi.fsdp_axis, mi.tp_axis
    layer = {
        "radial": mlp_specs(
            {"layers": [{"w": 0, "b": 0}, {"w": 0, "b": 0}]}, P(None, None)
        ),
        "mix_a": {"w": P(None, None)},
        "update": {"w": P(None, None)},
    }
    return {
        "embed": {"w": P(None, None)},
        "readout": mlp_specs({"layers": [{"w": 0, "b": 0}, {"w": 0, "b": 0}]}, P(None, None)),
        "layers": jax.tree.map(
            lambda s: P(*((None,) + tuple(s))),
            layer,
            is_leaf=lambda x: isinstance(x, P),
        ),
    }


def _node_features(params, cfg, batch) -> Array:
    if cfg.d_feat:
        return batch["node_feat"].astype(cfg.compute_dtype) @ params["embed"][
            "w"
        ].astype(cfg.compute_dtype)
    onehot = jax.nn.one_hot(batch["species"], cfg.n_species, dtype=cfg.compute_dtype)
    return onehot @ params["embed"]["w"].astype(cfg.compute_dtype)


def _layer(
    lp: Params,
    cfg: MACEConfig,
    h: Array,  # (N, K)
    positions: Array,  # (N, 3)
    senders: Array,  # (E,) — -1 padded
    receivers: Array,  # (E,)
    n_nodes: int,
    edge_psum_axes=None,
) -> Array:
    k = cfg.d_hidden
    valid = (senders >= 0) & (receivers >= 0)
    s = jnp.maximum(senders, 0)
    r = jnp.maximum(receivers, 0)
    rvec = positions[r] - positions[s]  # (E, 3)
    dist = jnp.linalg.norm(rvec + 1e-12, axis=-1)
    rhat = rvec / jnp.maximum(dist, 1e-9)[..., None]

    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.r_cut)  # (E, n_rbf)
    rad = mlp_apply(lp["radial"], rbf.astype(h.dtype), act=jax.nn.silu)  # (E, 3K)
    rad = rad * valid[:, None].astype(rad.dtype)
    r0, r1, r2 = rad[:, :k], rad[:, k : 2 * k], rad[:, 2 * k :]

    hs = h[s] @ lp["mix_a"]["w"].astype(h.dtype)  # (E, K) mixed sender scalars
    # Cartesian "spherical harmonics": y1 = rhat, y2 = rhat⊗rhat − I/3.
    eye = jnp.eye(3, dtype=h.dtype) / 3.0
    y2 = rhat[:, :, None] * rhat[:, None, :] - eye  # (E, 3, 3)

    m0 = r0 * hs  # (E, K)
    m1 = (r1 * hs)[:, :, None] * rhat[:, None, :]  # (E, K, 3)
    m2 = (r2 * hs)[:, :, None, None] * y2[:, None]  # (E, K, 3, 3)

    seg = lambda m: jax.ops.segment_sum(m, r, num_segments=n_nodes)
    a0, a1, a2 = seg(m0), seg(m1), seg(m2)  # ACE A-basis
    if edge_psum_axes:
        a0 = jax.lax.psum(a0, edge_psum_axes)
        a1 = jax.lax.psum(a1, edge_psum_axes)
        a2 = jax.lax.psum(a2, edge_psum_axes)

    # B-basis: invariant contractions up to correlation order 3.
    i_a0 = a0
    i_11 = jnp.einsum("nki,nki->nk", a1, a1)
    i_22 = jnp.einsum("nkij,nkij->nk", a2, a2)
    i_00 = a0 * a0
    i_121 = jnp.einsum("nki,nkij,nkj->nk", a1, a2, a1)
    i_222 = jnp.einsum("nkij,nkjl,nkli->nk", a2, a2, a2)
    i_000 = a0 * a0 * a0
    i_011 = a0 * i_11
    feats = jnp.concatenate(
        [i_a0, i_11, i_22, i_00, i_121, i_222, i_000, i_011], axis=-1
    )  # (N, 8K)
    return h + feats @ lp["update"]["w"].astype(h.dtype)


def energy(
    params: Params,
    cfg: MACEConfig,
    batch: dict,
    *,
    edge_psum_axes=None,
) -> Array:
    """Total energy per graph: (G,) for batched graphs, else scalar sum.

    batch: positions (N,3), senders/receivers (E,), species or node_feat,
    optional node_graph (N,) segment ids + n_graphs.
    """
    h = _node_features(params, cfg, batch)
    n_nodes = batch["positions"].shape[0]
    layer_fn = (
        jax.checkpoint(
            _layer,
            policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=(1, 6, 7),
        )
        if cfg.remat_layers
        else _layer
    )

    def body(h, lp):
        return (
            layer_fn(
                lp,
                cfg,
                h,
                batch["positions"].astype(cfg.compute_dtype),
                batch["senders"],
                batch["receivers"],
                n_nodes,
                edge_psum_axes,
            ),
            None,
        )

    h, _ = jax.lax.scan(body, h, params["layers"])
    node_e = mlp_apply(params["readout"], h, act=jax.nn.silu)[..., 0]  # (N,)
    if "node_graph" in batch:
        return jax.ops.segment_sum(
            node_e, batch["node_graph"], num_segments=batch["n_graphs"]
        )
    return jnp.sum(node_e, keepdims=True)


def energy_and_forces(params, cfg, batch, **kw):
    def e_total(pos):
        return jnp.sum(energy(params, cfg, dict(batch, positions=pos), **kw))

    e, neg_f = jax.value_and_grad(e_total)(batch["positions"])
    return e, -neg_f


def loss(params: Params, cfg: MACEConfig, batch: dict, **kw) -> tuple[Array, dict]:
    """Energy + force MSE (standard MACE objective)."""
    e, f = energy_and_forces(params, cfg, batch, **kw)
    e_target = jnp.sum(batch.get("energy", jnp.zeros(())))
    f_target = batch.get("forces", jnp.zeros_like(f))
    e_loss = (e - e_target) ** 2 / jnp.maximum(batch["positions"].shape[0], 1)
    f_loss = jnp.mean(jnp.sum((f - f_target) ** 2, axis=-1))
    total = e_loss + f_loss
    return total, {"loss": total, "e_loss": e_loss, "f_loss": f_loss}
