"""Destination-partitioned distributed MACE for full-graph training at
ogb-products scale (2.4M nodes, 62M edges).

Memory problem: the ACE A-basis is (N, K, 13) floats — ~16 GB at N=2.4M,
K=128 — far over a v5e's HBM if replicated. Layout that fixes it:

  * edges are partitioned by *destination* shard (data pipeline contract:
    every edge lives on the shard that owns its receiver; receiver ids are
    shard-local),
  * node state h is sharded by the same node blocks; each layer all-gathers
    only h (N x K, ~1 GB bf16) to read sender features, and accumulates the
    13x larger A-basis strictly locally — no psum of A ever happens,
  * readout reduces locally + one scalar psum.

Per-layer collective volume = one all-gather of (N, K) over the flattened
mesh; everything edge- and A-sized stays shard-local.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.meshinfo import MeshInfo
from repro.models.common.modules import mlp_apply
from repro.models.gnn.mace import MACEConfig, bessel_rbf

Array = jax.Array
Params = dict


def _flat_shard_index(mi: MeshInfo):
    idx = jnp.int32(0)
    for a in mi.dp_axes + (mi.tp_axis,):
        idx = idx * mi.mesh.shape[a] + jax.lax.axis_index(a)
    return idx


def _all_axes(mi: MeshInfo):
    return mi.dp_axes + (mi.tp_axis,)


def dst_partitioned_energy(
    params: Params, cfg: MACEConfig, mi: MeshInfo, batch: dict
) -> Array:
    """Total energy with the dst-partitioned layout. Returns scalar."""

    axes = _all_axes(mi)

    def local_fn(positions, feat, senders, receivers_local):
        # positions/feat replicated (N, .); edges local.
        n = positions.shape[0]
        n_shards = 1
        for a in axes:
            n_shards *= mi.mesh.shape[a]
        n_local = n // n_shards
        shard = _flat_shard_index(mi)
        lo = shard * n_local

        if cfg.d_feat:
            feat_local = jax.lax.dynamic_slice_in_dim(feat, lo, n_local, axis=0)
            h_local = feat_local.astype(cfg.compute_dtype) @ params["embed"][
                "w"
            ].astype(cfg.compute_dtype)
        else:
            sp_local = jax.lax.dynamic_slice_in_dim(feat, lo, n_local, axis=0)
            h_local = jax.nn.one_hot(
                sp_local, cfg.n_species, dtype=cfg.compute_dtype
            ) @ params["embed"]["w"].astype(cfg.compute_dtype)

        valid = (senders >= 0) & (receivers_local >= 0)
        s = jnp.maximum(senders, 0)
        r = jnp.maximum(receivers_local, 0)
        pos_local = jax.lax.dynamic_slice_in_dim(positions, lo, n_local, axis=0)
        rvec = pos_local[r] - positions[s]  # (E_l, 3)
        dist = jnp.linalg.norm(rvec + 1e-12, axis=-1)
        rhat = rvec / jnp.maximum(dist, 1e-9)[..., None]
        rbf = bessel_rbf(dist, cfg.n_rbf, cfg.r_cut).astype(cfg.compute_dtype)
        eye = jnp.eye(3, dtype=cfg.compute_dtype) / 3.0
        y2 = rhat[:, :, None] * rhat[:, None, :] - eye

        def layer(h_local, lp):
            # The only inter-shard traffic: gather global sender features.
            h_global = jax.lax.all_gather(h_local, axes, tiled=True)  # (N, K)
            rad = mlp_apply(lp["radial"], rbf, act=jax.nn.silu)
            rad = rad * valid[:, None].astype(rad.dtype)
            r0, r1, r2 = rad[:, : cfg.d_hidden], rad[:, cfg.d_hidden : 2 * cfg.d_hidden], rad[:, 2 * cfg.d_hidden :]
            hs = h_global[s] @ lp["mix_a"]["w"].astype(h_local.dtype)
            m0 = r0 * hs
            m1 = (r1 * hs)[:, :, None] * rhat.astype(hs.dtype)[:, None, :]
            m2 = (r2 * hs)[:, :, None, None] * y2.astype(hs.dtype)[:, None]
            seg = lambda m: jax.ops.segment_sum(m, r, num_segments=n_local)
            a0, a1, a2 = seg(m0), seg(m1), seg(m2)
            i_a0 = a0
            i_11 = jnp.einsum("nki,nki->nk", a1, a1)
            i_22 = jnp.einsum("nkij,nkij->nk", a2, a2)
            i_00 = a0 * a0
            i_121 = jnp.einsum("nki,nkij,nkj->nk", a1, a2, a1)
            i_222 = jnp.einsum("nkij,nkjl,nkli->nk", a2, a2, a2)
            i_000 = a0 * a0 * a0
            i_011 = a0 * i_11
            feats = jnp.concatenate(
                [i_a0, i_11, i_22, i_00, i_121, i_222, i_000, i_011], axis=-1
            )
            return h_local + feats @ lp["update"]["w"].astype(h_local.dtype), None

        h_local, _ = jax.lax.scan(layer, h_local, params["layers"])
        node_e = mlp_apply(params["readout"], h_local, act=jax.nn.silu)[..., 0]
        return jax.lax.psum(jnp.sum(node_e), axes)

    feat_key = "node_feat" if cfg.d_feat else "species"
    edge_spec = P(axes)
    fn = shard_map(
        local_fn,
        mesh=mi.mesh,
        in_specs=(P(), P(), edge_spec, edge_spec),
        out_specs=P(),
    )
    return fn(
        batch["positions"].astype(cfg.compute_dtype),
        batch[feat_key],
        batch["senders"],
        batch["receivers_local"],
    )


def dst_partitioned_loss(params, cfg, mi, batch):
    """Energy + force objective under the dst-partitioned layout."""

    def e_total(pos):
        return dst_partitioned_energy(params, cfg, mi, dict(batch, positions=pos))

    e, neg_f = jax.value_and_grad(e_total)(batch["positions"])
    f = -neg_f
    e_target = jnp.sum(batch.get("energy", jnp.zeros(())))
    f_target = batch.get("forces", jnp.zeros_like(f))
    n = batch["positions"].shape[0]
    e_loss = (e - e_target) ** 2 / n
    f_loss = jnp.mean(jnp.sum((f - f_target) ** 2, axis=-1))
    total = e_loss + f_loss
    return total, {"loss": total, "e_loss": e_loss, "f_loss": f_loss}
