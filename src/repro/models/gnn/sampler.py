"""Uniform-fanout neighbor sampler over CSR adjacency (GraphSAGE-style).

Produces fixed-shape sampled subgraphs for `minibatch_lg`: for seed nodes
(B,), layer-wise uniform sampling with fanouts (f1, f2, ...) yields a padded
edge list + the node set, ready for the MACE/GNN train step. Sampling with
replacement when degree < fanout (standard GraphSAGE behaviour); isolated
nodes emit self-loops.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array


@partial(jax.jit, static_argnames=("fanout",))
def sample_neighbors(
    rng: Array, indptr: Array, indices: Array, nodes: Array, fanout: int
) -> Array:
    """For each node (M,), draw ``fanout`` neighbors uniformly w/ replacement.

    Returns (M, fanout) int32 neighbor ids (self-loop when degree == 0).
    """
    start = indptr[nodes]  # (M,)
    deg = indptr[nodes + 1] - start
    draw = jax.random.randint(rng, (nodes.shape[0], fanout), 0, 1 << 30)
    offs = draw % jnp.maximum(deg, 1)[:, None]
    nbr = indices[start[:, None] + offs]
    return jnp.where(deg[:, None] > 0, nbr, nodes[:, None]).astype(jnp.int32)


def sample_subgraph(
    rng: Array,
    indptr: Array,
    indices: Array,
    seeds: Array,
    fanouts: Sequence[int],
):
    """Layered fanout sampling.

    Returns dict with:
      nodes    (N_sub,)  — frontier-concatenated node ids (seeds first)
      senders  (E_sub,)  — LOCAL indices into ``nodes``
      receivers(E_sub,)  — LOCAL indices into ``nodes``
    Shapes are static given (len(seeds), fanouts).
    """
    frontiers = [seeds.astype(jnp.int32)]
    senders_l, receivers_l = [], []
    offset = 0
    next_offset = seeds.shape[0]
    for li, f in enumerate(fanouts):
        r = jax.random.fold_in(rng, li)
        cur = frontiers[-1]
        nbr = sample_neighbors(r, indptr, indices, cur, f)  # (M, f)
        m = cur.shape[0]
        # Local ids: receivers are the current frontier, senders the new one.
        recv_local = jnp.repeat(jnp.arange(m, dtype=jnp.int32) + offset, f)
        send_local = jnp.arange(m * f, dtype=jnp.int32) + next_offset
        senders_l.append(send_local)
        receivers_l.append(recv_local)
        frontiers.append(nbr.reshape(-1))
        offset = next_offset
        next_offset += m * f
    nodes = jnp.concatenate(frontiers)
    return {
        "nodes": nodes,
        "senders": jnp.concatenate(senders_l),
        "receivers": jnp.concatenate(receivers_l),
    }


def subgraph_sizes(n_seeds: int, fanouts: Sequence[int]) -> tuple[int, int]:
    """(n_nodes, n_edges) of the padded sampled subgraph."""
    n_nodes, n_edges, m = n_seeds, 0, n_seeds
    for f in fanouts:
        n_edges += m * f
        m = m * f
        n_nodes += m
    return n_nodes, n_edges
