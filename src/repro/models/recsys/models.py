"""RecSys model family: DLRM (MLPerf), DeepFM, SASRec, two-tower retrieval.

The embedding lookup is the hot path; JAX has no EmbeddingBag or sparse
gather-reduce, so the bag/lookup substrate here is `jnp.take` +
`jax.ops.segment_sum` (with the fused Pallas kernel in
repro/kernels/embedding_bag as the TPU path). Large tables are row-sharded
over the ``model`` axis (vocab padded to a multiple of the axis size);
lookups over sharded tables lower to GSPMD's masked-gather + psum.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.common.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.meshinfo import MeshInfo
from repro.models.common.modules import (
    chunked_attention,
    dense_init,
    layernorm_apply,
    layernorm_init,
    mlp_apply,
    mlp_init,
)

Array = jax.Array
Params = dict


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    model: str  # dlrm | deepfm | sasrec | two_tower
    embed_dim: int
    # categorical fields
    vocab_sizes: Tuple[int, ...] = ()
    n_dense: int = 0
    # mlps
    bot_mlp: Tuple[int, ...] = ()
    top_mlp: Tuple[int, ...] = ()
    mlp: Tuple[int, ...] = ()
    # sasrec
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 1
    item_vocab: int = 0
    # two-tower
    tower_mlp: Tuple[int, ...] = ()
    user_vocab: int = 0
    hist_len: int = 0
    table_shard_axis: str = "model"
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32

    def padded_vocab(self, v: int, tp: int) -> int:
        return ((v + tp - 1) // tp) * tp


# ---------------------------------------------------------------------------
# embedding tables
# ---------------------------------------------------------------------------
def _tables_init(rng, cfg, vocabs: Sequence[int], dim: int, tp_pad: int = 256):
    tables = {}
    for i, v in enumerate(vocabs):
        vp = cfg.padded_vocab(v, tp_pad)
        r = jax.random.fold_in(rng, i)
        tables[f"t{i}"] = (
            jax.random.normal(r, (vp, dim), cfg.param_dtype)
            / math.sqrt(dim)
        )
    return tables


def _tables_specs(cfg, vocabs, mi: MeshInfo):
    # Rows over model (the big dim), embedding cols FSDP'd over data when
    # divisible — fully-sharded tables keep optimizer state in-budget.
    tp, fs = mi.tp_axis, mi.fsdp_axis
    col = mi.axes_if_divisible(cfg.embed_dim, fs)
    return {f"t{i}": P(tp, col) for i in range(len(vocabs))}


def _lookup(tables: Params, ids: Array) -> Array:
    """ids (B, F) -> (B, F, D): one gather per field table."""
    outs = [tables[f"t{i}"][ids[:, i]] for i in range(ids.shape[1])]
    return jnp.stack(outs, axis=1)


def embedding_bag_sum(table: Array, ids: Array) -> Array:
    """(V, D) x (B, L) -1-padded -> (B, D). The take+mask+sum substrate."""
    rows = table[jnp.maximum(ids, 0)]
    mask = (ids >= 0).astype(rows.dtype)[..., None]
    return jnp.sum(rows * mask, axis=1)


# ===========================================================================
# DLRM (MLPerf config)
# ===========================================================================
def dlrm_init(rng, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(rng, 3)
    d = cfg.embed_dim
    n_f = len(cfg.vocab_sizes) + 1  # + dense projection
    n_inter = n_f * (n_f - 1) // 2
    return {
        "tables": _tables_init(ks[0], cfg, cfg.vocab_sizes, d),
        "bot": mlp_init(ks[1], (cfg.n_dense,) + cfg.bot_mlp, cfg.param_dtype),
        "top": mlp_init(
            ks[2], (n_inter + cfg.bot_mlp[-1],) + cfg.top_mlp, cfg.param_dtype
        ),
    }


def dlrm_specs(cfg, mi: MeshInfo) -> Params:
    return {
        "tables": _tables_specs(cfg, cfg.vocab_sizes, mi),
        "bot": mlp_specs_like(cfg.bot_mlp, P(None, None)),
        "top": mlp_specs_like(cfg.top_mlp, P(None, None)),
    }


def mlp_specs_like(dims, spec):
    return {"layers": [{"w": spec, "b": P(None)} for _ in range(len(dims))]}


def dlrm_forward(p: Params, cfg, mi: MeshInfo, batch: dict) -> Array:
    dense = batch["dense"].astype(cfg.compute_dtype)  # (B, 13)
    sparse = batch["sparse"]  # (B, 26)
    x0 = mlp_apply(p["bot"], dense, final_act=True)  # (B, D)
    emb = _lookup(p["tables"], sparse).astype(cfg.compute_dtype)  # (B, 26, D)
    z = jnp.concatenate([x0[:, None], emb], axis=1)  # (B, 27, D)
    z = mi.constrain(z, mi.axes_if_divisible(z.shape[0], mi.dp_axes), None, None)
    inter = jnp.einsum("bfd,bgd->bfg", z, z)  # (B, 27, 27) dot interaction
    n_f = z.shape[1]
    iu, ju = jnp.tril_indices(n_f, k=-1)
    flat = inter[:, iu, ju]  # (B, 351)
    top_in = jnp.concatenate([x0, flat], axis=-1)
    return mlp_apply(p["top"], top_in)[..., 0]  # (B,) logit


def dlrm_loss(p, cfg, mi, batch):
    logit = dlrm_forward(p, cfg, mi, batch)
    label = batch["label"].astype(jnp.float32)
    loss = jnp.mean(_bce(logit.astype(jnp.float32), label))
    return loss, {"loss": loss}


def _bce(logit, label):
    return jnp.maximum(logit, 0) - logit * label + jnp.log1p(jnp.exp(-jnp.abs(logit)))


# ===========================================================================
# DeepFM
# ===========================================================================
def deepfm_init(rng, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(rng, 4)
    d = cfg.embed_dim
    n_f = len(cfg.vocab_sizes)
    return {
        "tables": _tables_init(ks[0], cfg, cfg.vocab_sizes, d),
        "linear": _tables_init(ks[1], cfg, cfg.vocab_sizes, 1),
        "deep": mlp_init(ks[2], (n_f * d,) + cfg.mlp + (1,), cfg.param_dtype),
        "bias": jnp.zeros((), cfg.param_dtype),
    }


def deepfm_specs(cfg, mi: MeshInfo) -> Params:
    return {
        "tables": _tables_specs(cfg, cfg.vocab_sizes, mi),
        "linear": _tables_specs(cfg, cfg.vocab_sizes, mi),
        "deep": mlp_specs_like(cfg.mlp + (1,), P(None, None)),
        "bias": P(),
    }


def deepfm_forward(p, cfg, mi: MeshInfo, batch):
    sparse = batch["sparse"]  # (B, 39)
    emb = _lookup(p["tables"], sparse).astype(cfg.compute_dtype)  # (B, 39, D)
    lin = _lookup(p["linear"], sparse)[..., 0].astype(cfg.compute_dtype)  # (B, 39)
    # FM second-order: 0.5 * ((sum v)^2 - sum v^2)
    s = jnp.sum(emb, axis=1)
    s2 = jnp.sum(emb * emb, axis=1)
    fm = 0.5 * jnp.sum(s * s - s2, axis=-1)  # (B,)
    deep = mlp_apply(p["deep"], emb.reshape(emb.shape[0], -1))[..., 0]
    return fm + jnp.sum(lin, axis=-1) + deep + p["bias"].astype(jnp.float32)


def deepfm_loss(p, cfg, mi, batch):
    logit = deepfm_forward(p, cfg, mi, batch)
    loss = jnp.mean(_bce(logit.astype(jnp.float32), batch["label"].astype(jnp.float32)))
    return loss, {"loss": loss}


# ===========================================================================
# SASRec
# ===========================================================================
def sasrec_init(rng, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(rng, 3 + cfg.n_blocks)
    d = cfg.embed_dim
    vp = cfg.padded_vocab(cfg.item_vocab, 256)
    blocks = []
    for i in range(cfg.n_blocks):
        r = jax.random.split(ks[3 + i], 6)
        blocks.append(
            {
                "ln1": layernorm_init(d, cfg.param_dtype),
                "wq": dense_init(r[0], d, d, cfg.param_dtype),
                "wk": dense_init(r[1], d, d, cfg.param_dtype),
                "wv": dense_init(r[2], d, d, cfg.param_dtype),
                "wo": dense_init(r[3], d, d, cfg.param_dtype),
                "ln2": layernorm_init(d, cfg.param_dtype),
                "ff1": dense_init(r[4], d, d, cfg.param_dtype),
                "ff2": dense_init(r[5], d, d, cfg.param_dtype),
            }
        )
    return {
        "items": jax.random.normal(ks[0], (vp, d), cfg.param_dtype) * 0.02,
        "pos": jax.random.normal(ks[1], (cfg.seq_len, d), cfg.param_dtype) * 0.02,
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "final_ln": layernorm_init(d, cfg.param_dtype),
    }


def sasrec_specs(cfg, mi: MeshInfo) -> Params:
    tp = mi.tp_axis
    col = mi.axes_if_divisible(cfg.embed_dim, mi.fsdp_axis)
    blk = {
        "ln1": {"scale": P(None, None), "bias": P(None, None)},
        "ln2": {"scale": P(None, None), "bias": P(None, None)},
        **{k: {"w": P(None, None, None)} for k in ("wq", "wk", "wv", "wo", "ff1", "ff2")},
    }
    return {
        "items": P(tp, col),
        "pos": P(None, None),
        "blocks": blk,
        "final_ln": {"scale": P(None), "bias": P(None)},
    }


def sasrec_hidden(p, cfg, mi: MeshInfo, seq: Array) -> Array:
    """seq (B, S) item ids (0 = padding) -> (B, S, D)."""
    b, s = seq.shape
    h = p["items"][seq].astype(cfg.compute_dtype) + p["pos"][None, :s].astype(
        cfg.compute_dtype
    )
    nheads = cfg.n_heads
    d = cfg.embed_dim

    def block(h, bp):
        x = layernorm_apply(bp["ln1"], h)
        q = (x @ bp["wq"]["w"].astype(x.dtype)).reshape(b, s, nheads, d // nheads)
        k = (x @ bp["wk"]["w"].astype(x.dtype)).reshape(b, s, nheads, d // nheads)
        v = (x @ bp["wv"]["w"].astype(x.dtype)).reshape(b, s, nheads, d // nheads)
        a = chunked_attention(q, k, v, causal=True, chunk=min(64, s))
        h = h + a.reshape(b, s, d) @ bp["wo"]["w"].astype(x.dtype)
        x = layernorm_apply(bp["ln2"], h)
        ff = jax.nn.relu(x @ bp["ff1"]["w"].astype(x.dtype)) @ bp["ff2"]["w"].astype(
            x.dtype
        )
        return h + ff, None

    h, _ = jax.lax.scan(block, h, p["blocks"])
    return layernorm_apply(p["final_ln"], h)


def sasrec_loss(p, cfg, mi, batch):
    """BCE over (positive next item, sampled negative) pairs — SASRec §3."""
    h = sasrec_hidden(p, cfg, mi, batch["seq"])  # (B, S, D)
    pos_e = p["items"][batch["pos"]].astype(h.dtype)  # (B, S, D)
    neg_e = p["items"][batch["neg"]].astype(h.dtype)
    pos_s = jnp.sum(h * pos_e, axis=-1).astype(jnp.float32)
    neg_s = jnp.sum(h * neg_e, axis=-1).astype(jnp.float32)
    mask = (batch["pos"] > 0).astype(jnp.float32)
    loss = jnp.sum(
        (_bce(pos_s, jnp.ones_like(pos_s)) + _bce(neg_s, jnp.zeros_like(neg_s))) * mask
    ) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss}


def sasrec_serve(p, cfg, mi, batch):
    """Score last position against candidate items (B, C) -> (B, C)."""
    h = sasrec_hidden(p, cfg, mi, batch["seq"])[:, -1]  # (B, D)
    cand = p["items"][batch["candidates"]].astype(h.dtype)  # (B, C, D)
    return jnp.einsum("bd,bcd->bc", h, cand)


# ===========================================================================
# Two-tower retrieval
# ===========================================================================
def two_tower_init(rng, cfg: RecsysConfig) -> Params:
    ks = jax.random.split(rng, 5)
    d = cfg.embed_dim
    up = cfg.padded_vocab(cfg.user_vocab, 256)
    ip = cfg.padded_vocab(cfg.item_vocab, 256)
    return {
        "user_emb": jax.random.normal(ks[0], (up, d), cfg.param_dtype) * 0.02,
        "item_emb": jax.random.normal(ks[1], (ip, d), cfg.param_dtype) * 0.02,
        # user tower input: user emb + history bag
        "user_tower": mlp_init(ks[2], (2 * d,) + cfg.tower_mlp, cfg.param_dtype),
        "item_tower": mlp_init(ks[3], (d,) + cfg.tower_mlp, cfg.param_dtype),
        "log_tau": jnp.zeros((), jnp.float32),
    }


def two_tower_specs(cfg, mi: MeshInfo) -> Params:
    tp = mi.tp_axis
    col = mi.axes_if_divisible(cfg.embed_dim, mi.fsdp_axis)
    return {
        "user_emb": P(tp, col),
        "item_emb": P(tp, col),
        "user_tower": mlp_specs_like(cfg.tower_mlp, P(None, None)),
        "item_tower": mlp_specs_like(cfg.tower_mlp, P(None, None)),
        "log_tau": P(),
    }


def two_tower_user(p, cfg, mi, batch) -> Array:
    ue = p["user_emb"][batch["user_id"]].astype(cfg.compute_dtype)  # (B, D)
    hist = embedding_bag_sum(p["item_emb"], batch["hist"]).astype(
        cfg.compute_dtype
    )  # (B, D)
    x = jnp.concatenate([ue, hist], axis=-1)
    u = mlp_apply(p["user_tower"], x, act=jax.nn.relu)
    return u / (jnp.linalg.norm(u, axis=-1, keepdims=True) + 1e-8)


def two_tower_item(p, cfg, mi, item_ids: Array) -> Array:
    ie = p["item_emb"][item_ids].astype(cfg.compute_dtype)
    v = mlp_apply(p["item_tower"], ie, act=jax.nn.relu)
    return v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-8)


def two_tower_loss(p, cfg, mi, batch, *, neg_chunk: int = 4096):
    """In-batch sampled softmax (RecSys'19 two-tower retrieval objective).

    The (B, B) logit matrix at the assigned train batch (65536) is 17 GB in
    f32 (34 GB with its gradient) — §Perf iteration C. The logsumexp is
    streamed over negative chunks instead (online-softmax recurrence, body
    rematerialized), so peak logit memory is (B, neg_chunk) and the
    backward recomputes each chunk.
    """
    u = two_tower_user(p, cfg, mi, batch)  # (B, D)
    v = two_tower_item(p, cfg, mi, batch["item_id"])  # (B, D)
    tau = jnp.maximum(jnp.exp(p["log_tau"]), 1e-3)
    b = u.shape[0]
    diag = jnp.sum(u * v, axis=-1).astype(jnp.float32) / tau
    if b <= neg_chunk:
        logits = (u @ v.T).astype(jnp.float32) / tau
        lse = jax.nn.logsumexp(logits, axis=-1)
    else:
        assert b % neg_chunk == 0
        n_chunks = b // neg_chunk
        u32 = u.astype(jnp.float32)
        vc_all = v.astype(jnp.float32).reshape(n_chunks, neg_chunk, -1)

        @jax.checkpoint
        def step(carry, vc):
            m, lsum = carry
            logits = (u32 @ vc.T) / tau  # (B, chunk)
            m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
            lsum = lsum * jnp.exp(m - m_new) + jnp.sum(
                jnp.exp(logits - m_new[:, None]), axis=-1
            )
            return (m_new, lsum), None

        init = (jnp.full((b,), -jnp.inf, jnp.float32), jnp.zeros((b,), jnp.float32))
        (m, lsum), _ = jax.lax.scan(step, init, vc_all)
        lse = m + jnp.log(jnp.maximum(lsum, 1e-30))
    loss = jnp.mean(lse - diag)
    return loss, {"loss": loss}


def two_tower_score_candidates(
    p, cfg, mi: MeshInfo, batch, *, two_phase_topk: bool = True
) -> Array:
    """retrieval_cand: score users against a candidate matrix (C, D).

    Candidates (precomputed item-tower outputs) shard over the model axis;
    the score is one sharded matmul + top-k merge — the brute-force baseline
    AIRSHIP's constrained graph search replaces (see core/ + examples).

    ``two_phase_topk`` (beyond-paper §Perf iteration): each shard takes its
    local top-k and only (P x k) score/id pairs cross the wire, instead of
    letting GSPMD all-gather the full (B, C) score matrix for the global
    top-k — measured ~250x collective-byte reduction at C=1M, k=100.
    """
    u = two_tower_user(p, cfg, mi, batch)  # (B, D)
    cand = batch["candidates"].astype(u.dtype)  # (C, D)
    c = cand.shape[0]
    k = min(100, c)
    if two_phase_topk and mi.tp_size > 1 and c % mi.tp_size == 0:
        tp = mi.tp_axis
        bspec = mi.axes_if_divisible(u.shape[0], mi.dp_axes)

        def local(u_l, cand_l):
            shard = jax.lax.axis_index(tp)
            scores = u_l @ cand_l.T  # (B_l, C_local)
            top, idx = jax.lax.top_k(scores, k)
            idx = idx + shard * cand_l.shape[0]
            all_top = jax.lax.all_gather(top, tp, axis=1)  # (B_l, P, k)
            all_idx = jax.lax.all_gather(idx, tp, axis=1)
            t2, pos = jax.lax.top_k(all_top.reshape(top.shape[0], -1), k)
            i2 = jnp.take_along_axis(
                all_idx.reshape(idx.shape[0], -1), pos, axis=-1
            )
            return t2, i2

        return shard_map(
            local,
            mesh=mi.mesh,
            in_specs=(P(bspec, None), P(tp, None)),
            out_specs=(P(bspec, None), P(bspec, None)),
        )(u, cand)
    cand = mi.constrain(cand, mi.tp_axis, None)
    scores = u @ cand.T  # (B, C)
    scores = mi.constrain(
        scores, mi.axes_if_divisible(u.shape[0], mi.dp_axes), mi.tp_axis
    )
    top, idx = jax.lax.top_k(scores, k)
    return top, idx
