"""Decoder-only transformer LM: dense (GQA) and MoE (MLA, DeepSeek-style),
with scan-over-layers, sequence parallelism, chunked-softmax CE loss, a
sequence-sharded KV-cache decode path, and optional multi-token prediction
(DeepSeek-V3 MTP).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.common.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.meshinfo import MeshInfo
from repro.models.common.modules import (
    dense_init,
    rmsnorm_apply,
    rmsnorm_init,
)
from repro.models.transformer import attention as attn
from repro.models.transformer import moe as moe_mod

Array = jax.Array
Params = dict


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    attn_type: str = "gqa"  # gqa | mla
    rope_theta: float = 10_000.0
    # MLA
    q_lora_rank: int = 0
    kv_lora_rank: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    n_dense_layers: int = -1  # -1 -> all dense (no MoE)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.0
    # MTP (DeepSeek-V3)
    mtp: bool = False
    mtp_coef: float = 0.3
    # numerics / memory
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    attn_chunk: int = 512
    ce_chunk: int = 1024
    remat: str = "full"  # full | dots | none
    sequence_parallel: bool = True

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a TP-friendly multiple (Megatron-style
        padding; logical ids stay < vocab_size)."""
        return ((self.vocab_size + 511) // 512) * 512

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def n_dense(self) -> int:
        if not self.is_moe:
            return self.n_layers
        return max(self.n_dense_layers, 0)

    @property
    def n_moe(self) -> int:
        return self.n_layers - self.n_dense

    def param_count(self) -> int:
        """Total parameter count (for 6ND model-flops accounting)."""
        import math

        shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), self))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k routed only)."""
        total = self.param_count()
        if not self.is_moe:
            return total
        e, fe, d = self.n_experts, self.d_ff_expert, self.d_model
        routed = self.n_moe * 3 * d * fe * e
        active_routed = self.n_moe * 3 * d * fe * self.top_k
        return total - routed + active_routed


# ---------------------------------------------------------------------------
# init + specs
# ---------------------------------------------------------------------------
def _attn_init(rng, cfg):
    return attn.mla_init(rng, cfg) if cfg.attn_type == "mla" else attn.gqa_init(rng, cfg)


def _attn_specs(cfg, mi):
    return attn.mla_specs(cfg, mi) if cfg.attn_type == "mla" else attn.gqa_specs(cfg, mi)


def _dense_ffn_init(rng, cfg):
    ks = jax.random.split(rng, 3)
    return {
        "w1": dense_init(ks[0], cfg.d_model, cfg.d_ff, cfg.param_dtype),
        "w3": dense_init(ks[1], cfg.d_model, cfg.d_ff, cfg.param_dtype),
        "w2": dense_init(ks[2], cfg.d_ff, cfg.d_model, cfg.param_dtype),
    }


def _dense_ffn_specs(cfg, mi):
    fs, tp = mi.fsdp_axis, mi.tp_axis
    return {"w1": {"w": P(fs, tp)}, "w3": {"w": P(fs, tp)}, "w2": {"w": P(tp, fs)}}


def _layer_init(rng, cfg, kind: str):
    ks = jax.random.split(rng, 2)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "ln2": rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "attn": _attn_init(ks[0], cfg),
    }
    p["ffn"] = (
        moe_mod.moe_init(ks[1], cfg) if kind == "moe" else _dense_ffn_init(ks[1], cfg)
    )
    return p


def _layer_specs(cfg, mi, kind: str):
    return {
        "ln1": {"scale": P(None)},
        "ln2": {"scale": P(None)},
        "attn": _attn_specs(cfg, mi),
        "ffn": moe_mod.moe_specs(cfg, mi) if kind == "moe" else _dense_ffn_specs(cfg, mi),
    }


def _stack(layers):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *layers)


def init_params(rng: Array, cfg: TransformerConfig) -> Params:
    ks = jax.random.split(rng, 6)
    p: Params = {
        "embed": {
            "table": jax.random.normal(
                ks[0], (cfg.vocab_padded, cfg.d_model), cfg.param_dtype
            )
            * 0.02
        },
        "final_norm": rmsnorm_init(cfg.d_model, cfg.param_dtype),
        "lm_head": dense_init(ks[1], cfg.d_model, cfg.vocab_padded, cfg.param_dtype),
    }
    if cfg.n_dense:
        p["dense_layers"] = _stack(
            [
                _layer_init(jax.random.fold_in(ks[2], i), cfg, "dense")
                for i in range(cfg.n_dense)
            ]
        )
    if cfg.n_moe:
        p["moe_layers"] = _stack(
            [
                _layer_init(jax.random.fold_in(ks[3], i), cfg, "moe")
                for i in range(cfg.n_moe)
            ]
        )
    if cfg.mtp:
        p["mtp"] = {
            "proj": dense_init(ks[4], 2 * cfg.d_model, cfg.d_model, cfg.param_dtype),
            "layer": _layer_init(ks[5], cfg, "dense"),
            "norm": rmsnorm_init(cfg.d_model, cfg.param_dtype),
        }
    return p


def _prefix_none(tree):
    return jax.tree.map(
        lambda s: P(*((None,) + tuple(s))),
        tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def param_specs(cfg: TransformerConfig, mi: MeshInfo) -> Params:
    fs, tp = mi.fsdp_axis, mi.tp_axis
    p: Params = {
        "embed": {"table": P(tp, fs)},
        "final_norm": {"scale": P(None)},
        "lm_head": {"w": P(fs, tp)},
    }
    if cfg.n_dense:
        p["dense_layers"] = _prefix_none(_layer_specs(cfg, mi, "dense"))
    if cfg.n_moe:
        p["moe_layers"] = _prefix_none(_layer_specs(cfg, mi, "moe"))
    if cfg.mtp:
        p["mtp"] = {
            "proj": {"w": P(fs, tp)},
            "layer": _layer_specs(cfg, mi, "dense"),
            "norm": {"scale": P(None)},
        }
    return p


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------
def _residual_constraint(cfg, mi: MeshInfo, x: Array) -> Array:
    # Megatron-style sequence parallelism: the residual stream is sharded
    # over (dp, seq=model); blocks internally reshard to head/ff layouts.
    seq = mi.tp_axis if cfg.sequence_parallel else None
    return mi.constrain(x, mi.dp_axes, seq, None)


def _layer_apply(cfg, mi: MeshInfo, kind: str, lp: Params, x: Array, positions):
    h = rmsnorm_apply(lp["ln1"], x, cfg.norm_eps)
    if cfg.attn_type == "mla":
        a = attn.mla_train(lp["attn"], cfg, mi, h, positions)
    else:
        a = attn.gqa_train(lp["attn"], cfg, mi, h, positions)
    x = _residual_constraint(cfg, mi, x + a)
    h = rmsnorm_apply(lp["ln2"], x, cfg.norm_eps)
    if kind == "moe":
        f = moe_mod.moe_ffn(lp["ffn"], cfg, mi, h)
    else:
        ff = lp["ffn"]
        hh = jax.nn.silu(h @ ff["w1"]["w"].astype(h.dtype)) * (
            h @ ff["w3"]["w"].astype(h.dtype)
        )
        hh = mi.constrain(hh, mi.dp_axes, None, mi.tp_axis)
        f = hh @ ff["w2"]["w"].astype(h.dtype)
    return _residual_constraint(cfg, mi, x + f)


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


def forward_hidden(
    params: Params, cfg: TransformerConfig, mi: MeshInfo, tokens: Array
) -> Array:
    """tokens (B, S) -> hidden states (B, S, D)."""
    _, s = tokens.shape
    x = params["embed"]["table"][tokens].astype(cfg.compute_dtype)
    x = _residual_constraint(cfg, mi, x)
    positions = jnp.arange(s)

    def scan_stack(x, stacked, kind):
        body = _remat_wrap(
            cfg, lambda x, lp: (_layer_apply(cfg, mi, kind, lp, x, positions), None)
        )
        x, _ = jax.lax.scan(body, x, stacked)
        return x

    if cfg.n_dense:
        x = scan_stack(x, params["dense_layers"], "dense")
    if cfg.n_moe:
        x = scan_stack(x, params["moe_layers"], "moe")
    return rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)


def _chunked_ce(
    cfg, mi: MeshInfo, h: Array, lm_head: Array, labels: Array, weights: Array
) -> Array:
    """Cross-entropy without materializing full (B, S, V) logits."""
    b, s, d = h.shape
    chunk = min(cfg.ce_chunk, s)
    n_chunks = (s + chunk - 1) // chunk
    assert s % chunk == 0, (s, chunk)

    def step(carry, idx):
        tot, wsum = carry
        hc = jax.lax.dynamic_slice_in_dim(h, idx * chunk, chunk, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        wc = jax.lax.dynamic_slice_in_dim(weights, idx * chunk, chunk, axis=1)
        logits = (hc @ lm_head.astype(hc.dtype)).astype(jnp.float32)
        logits = mi.constrain(logits, mi.dp_axes, None, mi.tp_axis)
        lse = jax.nn.logsumexp(logits, axis=-1)
        # Label-logit extraction via masked-max: stays vocab-sharded under
        # GSPMD (take_along_axis would all-gather the (B, c, V) logits).
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        ll = jnp.max(
            jnp.where(vocab_iota == lc[..., None], logits, -jnp.inf), axis=-1
        )
        tot = tot + jnp.sum((lse - ll) * wc)
        return (tot, wsum + jnp.sum(wc)), None

    body = _remat_wrap(cfg, step)
    (tot, wsum), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), jnp.arange(n_chunks)
    )
    return tot / jnp.maximum(wsum, 1.0)


def lm_loss(
    params: Params, cfg: TransformerConfig, mi: MeshInfo, batch: dict
) -> tuple[Array, dict]:
    """batch: tokens (B, S) int32. Next-token CE (+ optional MTP, aux)."""
    tokens = batch["tokens"]
    h = forward_hidden(params, cfg, mi, tokens)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    weights = jnp.concatenate(
        [
            jnp.ones_like(tokens[:, 1:], jnp.float32),
            jnp.zeros_like(tokens[:, :1], jnp.float32),
        ],
        axis=1,
    )
    loss = _chunked_ce(cfg, mi, h, params["lm_head"]["w"], labels, weights)
    metrics = {"ce": loss}
    if cfg.mtp:
        # Predict token t+2 from [h_t ; emb(token_{t+1})] through one extra
        # block (simplified DeepSeek-V3 MTP with a single depth-1 module).
        emb_next = params["embed"]["table"][labels].astype(cfg.compute_dtype)
        mixed = jnp.concatenate([h.astype(cfg.compute_dtype), emb_next], axis=-1)
        h2 = mixed @ params["mtp"]["proj"]["w"].astype(mixed.dtype)
        h2 = _layer_apply(
            cfg, mi, "dense", params["mtp"]["layer"], h2, jnp.arange(tokens.shape[1])
        )
        h2 = rmsnorm_apply(params["mtp"]["norm"], h2, cfg.norm_eps)
        labels2 = jnp.concatenate([tokens[:, 2:], tokens[:, :2]], axis=1)
        w2 = jnp.concatenate(
            [
                jnp.ones_like(tokens[:, 2:], jnp.float32),
                jnp.zeros_like(tokens[:, :2], jnp.float32),
            ],
            axis=1,
        )
        mtp_loss = _chunked_ce(cfg, mi, h2, params["lm_head"]["w"], labels2, w2)
        metrics["mtp_ce"] = mtp_loss
        loss = loss + cfg.mtp_coef * mtp_loss
    if cfg.is_moe and cfg.router_aux_coef > 0:
        # Aux loss on the last MoE layer's router (cheap proxy).
        aux = moe_mod.router_aux_loss(
            jax.tree.map(lambda x: x[-1], params["moe_layers"])["ffn"], cfg, h
        )
        metrics["router_aux"] = aux
        loss = loss + cfg.router_aux_coef * aux
    metrics["loss"] = loss
    return loss, metrics


# ---------------------------------------------------------------------------
# decode (serve) path
# ---------------------------------------------------------------------------
def cache_shape(cfg: TransformerConfig, batch: int, s_max: int):
    """Abstract KV-cache shapes (per layer stacked over L)."""
    if cfg.attn_type == "mla":
        entry = (batch, s_max, cfg.kv_lora_rank + cfg.d_rope)
        return {
            "c": jax.ShapeDtypeStruct((cfg.n_layers,) + entry, cfg.compute_dtype),
            "pos": jax.ShapeDtypeStruct((), jnp.int32),
        }
    kv = (batch, s_max, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jax.ShapeDtypeStruct((cfg.n_layers,) + kv, cfg.compute_dtype),
        "v": jax.ShapeDtypeStruct((cfg.n_layers,) + kv, cfg.compute_dtype),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def cache_specs(cfg: TransformerConfig, mi: MeshInfo, batch: int, s_max: int):
    """Cache sharding: batch over dp (when divisible), sequence over model."""
    bspec = mi.axes_if_divisible(batch, mi.dp_axes)
    sspec = mi.axes_if_divisible(s_max, (mi.tp_axis,))
    if cfg.attn_type == "mla":
        return {"c": P(None, bspec, sspec, None), "pos": P()}
    kv = P(None, bspec, sspec, None, None)
    return {"k": kv, "v": kv, "pos": P()}


def init_cache(cfg: TransformerConfig, batch: int, s_max: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_shape(cfg, batch, s_max)
    )


def _stacked_layer_params(params, cfg):
    """Concatenate dense + moe stacks into one per-layer scan structure.

    Dense and MoE layers differ structurally, so we scan them separately but
    must interleave caches correctly; layer order = dense first, then moe.
    """
    return params.get("dense_layers"), params.get("moe_layers")


def decode_step(
    params: Params,
    cfg: TransformerConfig,
    mi: MeshInfo,
    cache: dict,
    tokens: Array,  # (B,) int32 — current step's token ids
) -> tuple[Array, dict]:
    """One greedy decode step against a sequence-sharded KV cache.

    Returns (logits (B, V), updated cache).
    """
    b = tokens.shape[0]
    pos = cache["pos"]
    x = params["embed"]["table"][tokens].astype(cfg.compute_dtype)  # (B, D)
    x = mi.constrain(x, mi.axes_if_divisible(b, mi.dp_axes), None)
    seq_axis = mi.tp_axis if mi.tp_size > 1 else None

    is_mla = cfg.attn_type == "mla"
    cache_arrays = (cache["c"],) if is_mla else (cache["k"], cache["v"])

    def one_layer(x, lp, layer_cache, kind):
        h = rmsnorm_apply(lp["ln1"], x[:, None, :], cfg.norm_eps)[:, 0]
        if is_mla:
            (c_l,) = layer_cache
            a, c_l = _mla_decode_sharded(lp["attn"], cfg, mi, h, c_l, pos, seq_axis)
            new_cache = (c_l,)
        else:
            k_l, v_l = layer_cache
            a, k_l, v_l = _gqa_decode_sharded(
                lp["attn"], cfg, mi, h, k_l, v_l, pos, seq_axis
            )
            new_cache = (k_l, v_l)
        x = x + a
        h = rmsnorm_apply(lp["ln2"], x[:, None, :], cfg.norm_eps)
        if kind == "moe":
            f = moe_mod.moe_ffn(lp["ffn"], cfg, mi, h)[:, 0]
        else:
            ff = lp["ffn"]
            hh = jax.nn.silu(h[:, 0] @ ff["w1"]["w"].astype(x.dtype)) * (
                h[:, 0] @ ff["w3"]["w"].astype(x.dtype)
            )
            f = hh @ ff["w2"]["w"].astype(x.dtype)
        return x + f, new_cache

    dense_p, moe_p = _stacked_layer_params(params, cfg)
    nd = cfg.n_dense
    new_caches = []
    for kind, stacked, lo, hi in (
        ("dense", dense_p, 0, nd),
        ("moe", moe_p, nd, cfg.n_layers),
    ):
        if stacked is None or hi <= lo:
            continue
        span = hi - lo
        layer_cache = tuple(
            jax.lax.dynamic_slice_in_dim(c, lo, span, axis=0) for c in cache_arrays
        )

        def body(x, inputs, kind=kind):
            lp, lc = inputs
            x, new_lc = one_layer(x, lp, lc, kind)
            return x, new_lc

        x, updated = jax.lax.scan(body, x, (stacked, layer_cache))
        new_caches.append((lo, updated))

    # Reassemble full cache arrays.
    out_arrays = list(cache_arrays)
    for lo, updated in new_caches:
        for i in range(len(out_arrays)):
            out_arrays[i] = jax.lax.dynamic_update_slice_in_dim(
                out_arrays[i], updated[i], lo, axis=0
            )

    h = rmsnorm_apply(params["final_norm"], x[:, None, :], cfg.norm_eps)[:, 0]
    logits = (h @ params["lm_head"]["w"].astype(h.dtype)).astype(jnp.float32)
    logits = mi.constrain(logits, mi.axes_if_divisible(b, mi.dp_axes), mi.tp_axis)
    new_cache = dict(
        zip(("c",) if is_mla else ("k", "v"), out_arrays), pos=pos + 1
    )
    return logits, new_cache


def _gqa_decode_sharded(ap, cfg, mi, h, k_cache, v_cache, pos, seq_axis):
    b = h.shape[0]
    hds, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (h @ ap["wq"]["w"].astype(h.dtype)).reshape(b, hds, dh)
    k_new = (h @ ap["wk"]["w"].astype(h.dtype)).reshape(b, hkv, dh)
    v_new = (h @ ap["wv"]["w"].astype(h.dtype)).reshape(b, hkv, dh)
    posv = jnp.asarray(pos)
    q = _rope_one(q, posv, cfg.rope_theta)
    k_new = _rope_one(k_new, posv, cfg.rope_theta)

    if seq_axis is None:
        out, k_c, v_c = attn.gqa_decode_attend(
            q, k_cache, v_cache, k_new, v_new, pos,
            seq_axis=None, shard_idx=jnp.int32(0),
        )
    else:
        bspec = mi.axes_if_divisible(b, mi.dp_axes)

        def inner(q, kc, vc, kn, vn):
            return attn.gqa_decode_attend(
                q, kc, vc, kn, vn, pos,
                seq_axis=seq_axis, shard_idx=jax.lax.axis_index(seq_axis),
            )

        out, k_c, v_c = shard_map(
            inner,
            mesh=mi.mesh,
            in_specs=(
                P(bspec, None, None),
                P(bspec, seq_axis, None, None),
                P(bspec, seq_axis, None, None),
                P(bspec, None, None),
                P(bspec, None, None),
            ),
            out_specs=(
                P(bspec, None, None),
                P(bspec, seq_axis, None, None),
                P(bspec, seq_axis, None, None),
            ),
        )(q, k_cache, v_cache, k_new, v_new)
    proj = out.reshape(b, hds * dh).astype(h.dtype) @ ap["wo"]["w"].astype(h.dtype)
    return proj, k_c, v_c


def _mla_decode_sharded(ap, cfg, mi, h, c_cache, pos, seq_axis):
    b = h.shape[0]
    if seq_axis is None:
        out, c_c = attn.mla_decode_attend(
            ap, cfg, h, c_cache, pos, seq_axis=None, shard_idx=jnp.int32(0)
        )
        return out, c_c
    bspec = mi.axes_if_divisible(b, mi.dp_axes)

    def inner(h_, cc):
        return attn.mla_decode_attend(
            ap, cfg, h_, cc, pos,
            seq_axis=seq_axis, shard_idx=jax.lax.axis_index(seq_axis),
        )

    out, c_c = shard_map(
        inner,
        mesh=mi.mesh,
        in_specs=(P(bspec, None), P(bspec, seq_axis, None)),
        out_specs=(P(bspec, None), P(bspec, seq_axis, None)),
    )(h, c_cache)
    return out, c_c


def _rope_one(x: Array, pos: Array, theta: float) -> Array:
    """RoPE for a single position: x (B, H, d) at scalar position."""
    from repro.models.common.modules import rope_frequencies

    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)
    angles = pos.astype(jnp.float32) * freqs  # (d/2,)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def prefill_logits(
    params: Params, cfg: TransformerConfig, mi: MeshInfo, tokens: Array
) -> Array:
    """Full-sequence prefill returning last-position logits (B, V)."""
    h = forward_hidden(params, cfg, mi, tokens)
    last = h[:, -1]
    logits = (last @ params["lm_head"]["w"].astype(last.dtype)).astype(jnp.float32)
    return mi.constrain(logits, mi.dp_axes, mi.tp_axis)
