"""DeepSeek-style MoE FFN with expert parallelism over the ``model`` axis.

Dispatch scheme (dropless-ish, fixed shapes — see DESIGN.md):
  * the router runs globally (tiny GEMM);
  * tokens are replicated within each data-parallel group (they already are,
    between TP blocks), experts are sharded over ``model``;
  * each shard ranks the tokens routed to *its* experts by router weight and
    keeps the best C per expert (capacity = cf * T * top_k / E), gathers
    them, runs the local expert GEMMs as one batched einsum, scatters back
    weighted by the (renormalized) gate, and a single psum over ``model``
    sums expert contributions — the same collective volume as a dense TP
    FFN's all-reduce, with no all-to-all.

Shared experts (DeepSeek: always-on) are a dense SwiGLU with TP sharding.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.common.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.meshinfo import MeshInfo
from repro.models.common.modules import dense_init

Array = jax.Array
Params = dict


def moe_init(rng, cfg) -> Params:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(rng, 7)
    scale = 1.0 / jnp.sqrt(d)
    p: Params = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "experts": {
            "w1": jax.random.uniform(ks[1], (e, d, fe), cfg.param_dtype, -scale, scale),
            "w3": jax.random.uniform(ks[2], (e, d, fe), cfg.param_dtype, -scale, scale),
            "w2": jax.random.uniform(ks[3], (e, fe, d), cfg.param_dtype, -scale, scale),
        },
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        p["shared"] = {
            "w1": dense_init(ks[4], d, fs, cfg.param_dtype),
            "w3": dense_init(ks[5], d, fs, cfg.param_dtype),
            "w2": dense_init(ks[6], fs, d, cfg.param_dtype),
        }
    return p


def moe_specs(cfg, mi: MeshInfo) -> Params:
    fs, tp = mi.fsdp_axis, mi.tp_axis
    p = {
        "router": {"w": P(None, None)},
        "experts": {
            "w1": P(tp, fs, None),
            "w3": P(tp, fs, None),
            "w2": P(tp, None, fs),
        },
    }
    if cfg.n_shared_experts:
        p["shared"] = {
            "w1": {"w": P(fs, tp)},
            "w3": {"w": P(fs, tp)},
            "w2": {"w": P(tp, fs)},
        }
    return p


def _swiglu(x: Array, w1: Array, w3: Array, w2: Array) -> Array:
    h = jax.nn.silu(x @ w1.astype(x.dtype)) * (x @ w3.astype(x.dtype))
    return h @ w2.astype(x.dtype)


def _moe_local(
    x: Array,  # (Bl, S, D) tokens of this DP group (replicated over model)
    probs: Array,  # (Bl, S, E) router probabilities (full expert axis)
    w1: Array,  # (E_local, D, Fe)
    w3: Array,
    w2: Array,
    *,
    cfg,
    tp_axis: Optional[str],
):
    bl, s, d = x.shape
    e = probs.shape[-1]
    e_local = w1.shape[0]
    t = bl * s
    top_k = cfg.top_k
    n_shards = e // e_local
    # capacity per *local* expert; total kept tokens = cf * T * top_k.
    cap = max(1, int(cfg.capacity_factor * t * top_k / e))

    xf = x.reshape(t, d)
    pf = probs.reshape(t, e)
    # Token-choice top-k threshold (k-th largest prob per token).
    thresh = jax.lax.top_k(pf, top_k)[0][:, -1]  # (T,)
    shard = jax.lax.axis_index(tp_axis) if tp_axis else 0
    local_p = jax.lax.dynamic_slice_in_dim(pf, shard * e_local, e_local, axis=1)
    gate = jnp.where(local_p >= thresh[:, None], local_p, 0.0)  # (T, E_local)
    # Renormalize selected gates to sum 1 over the chosen experts (DeepSeek).
    local_sum = jnp.sum(gate, axis=-1)
    denom = (
        jax.lax.psum(local_sum, tp_axis) if tp_axis else local_sum
    )
    gate = gate / jnp.maximum(denom[:, None], 1e-9)

    # Per-expert top-C tokens by gate weight (capacity-drop dispatch).
    scores = gate.T  # (E_local, T)
    top_w, top_idx = jax.lax.top_k(scores, min(cap, t))  # (E_local, C)
    valid = top_w > 0.0
    xg = xf[top_idx]  # (E_local, C, D)
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", xg, w1.astype(xg.dtype))
    ) * jnp.einsum("ecd,edf->ecf", xg, w3.astype(xg.dtype))
    y = jnp.einsum("ecf,efd->ecd", h, w2.astype(xg.dtype))
    y = y * (top_w * valid)[..., None].astype(y.dtype)
    out = jnp.zeros((t, d), y.dtype).at[top_idx.reshape(-1)].add(
        y.reshape(-1, d), mode="drop"
    )
    if tp_axis:
        out = jax.lax.psum(out, tp_axis)
    return out.reshape(bl, s, d)


def moe_ffn(p: Params, cfg, mi: MeshInfo, x: Array) -> Array:
    """(B, S, D) -> (B, S, D). Router global; experts via shard_map EP."""
    probs = jax.nn.softmax(
        (x.astype(jnp.float32) @ p["router"]["w"]), axis=-1
    )  # (B, S, E)

    # B=1 decode cannot shard the token batch over the data axes.
    dp = mi.axes_if_divisible(x.shape[0], mi.dp_axes)
    tp = mi.tp_axis
    e = cfg.n_experts
    if mi.tp_size > 1 and e % mi.tp_size == 0:
        local = shard_map(
            lambda xs, ps, w1, w3, w2: _moe_local(
                xs, ps, w1, w3, w2, cfg=cfg, tp_axis=tp
            ),
            mesh=mi.mesh,
            in_specs=(
                P(dp, None, None),
                P(dp, None, None),
                P(tp, None, None),
                P(tp, None, None),
                P(tp, None, None),
            ),
            out_specs=P(dp, None, None),
        )
        out = local(
            x,
            probs.astype(x.dtype),
            p["experts"]["w1"],
            p["experts"]["w3"],
            p["experts"]["w2"],
        )
    else:
        out = _moe_local(
            x,
            probs.astype(x.dtype),
            p["experts"]["w1"],
            p["experts"]["w3"],
            p["experts"]["w2"],
            cfg=cfg,
            tp_axis=None,
        )
    if cfg.n_shared_experts:
        sh = p["shared"]
        shared = _swiglu(x, sh["w1"]["w"], sh["w3"]["w"], sh["w2"]["w"])
        out = out + shared
    return out


def router_aux_loss(p: Params, cfg, x: Array) -> Array:
    """Switch-style load-balancing loss (optional; DeepSeek-V3 is
    aux-loss-free via bias updates — we expose the standard aux loss as a
    config knob instead and note the deviation)."""
    probs = jax.nn.softmax(x.astype(jnp.float32) @ p["router"]["w"], axis=-1)
    e = probs.shape[-1]
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return e * jnp.sum(frac_tokens * frac_probs)
