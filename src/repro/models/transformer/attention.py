"""Attention blocks: GQA and MLA (DeepSeek multi-head latent attention),
each with a training/prefill path (chunked flash) and a decode path over a
sequence-sharded KV cache with log-sum-exp combination across shards.

Decode sharding: decode cells include B=1 (long_500k), so the cache cannot
always shard over batch; instead the *sequence* axis of the cache shards
over the ``model`` mesh axis and each shard computes a partial softmax
(m, l, o); the exact global softmax is reconstructed with one pmax + two
psums — flash-decoding's split-KV scheme mapped onto the TPU mesh.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.meshinfo import MeshInfo
from repro.models.common.modules import (
    apply_rope,
    chunked_attention,
    dense_init,
    rmsnorm_apply,
    rmsnorm_init,
)

Array = jax.Array
Params = dict


# ===========================================================================
# GQA
# ===========================================================================
def gqa_init(rng, cfg) -> Params:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(rng, 4)
    return {
        "wq": dense_init(ks[0], d, h * dh, cfg.param_dtype),
        "wk": dense_init(ks[1], d, hkv * dh, cfg.param_dtype),
        "wv": dense_init(ks[2], d, hkv * dh, cfg.param_dtype),
        "wo": dense_init(ks[3], h * dh, d, cfg.param_dtype),
    }


def gqa_specs(cfg, mi: MeshInfo) -> Params:
    fs, tp = mi.fsdp_axis, mi.tp_axis
    return {
        "wq": {"w": P(fs, tp)},
        "wk": {"w": P(fs, tp)},
        "wv": {"w": P(fs, tp)},
        "wo": {"w": P(tp, fs)},
    }


def gqa_qkv(p: Params, cfg, mi: MeshInfo, x: Array, positions: Array):
    """x (B,S,D) -> q (B,S,H,dh), k,v (B,S,Hkv,dh), RoPE applied."""
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]["w"].astype(x.dtype)).reshape(b, s, h, dh)
    k = (x @ p["wk"]["w"].astype(x.dtype)).reshape(b, s, hkv, dh)
    v = (x @ p["wv"]["w"].astype(x.dtype)).reshape(b, s, hkv, dh)
    q = mi.constrain(q, mi.dp_axes, None, mi.tp_axis, None)
    k = mi.constrain(k, mi.dp_axes, None, mi.tp_axis, None)
    v = mi.constrain(v, mi.dp_axes, None, mi.tp_axis, None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_train(p: Params, cfg, mi: MeshInfo, x: Array, positions: Array) -> Array:
    b, s, _ = x.shape
    q, k, v = gqa_qkv(p, cfg, mi, x, positions)
    out = chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, mi=mi)
    out = mi.constrain(out, mi.dp_axes, None, mi.tp_axis, None)
    return out.reshape(b, s, cfg.n_heads * cfg.head_dim) @ p["wo"]["w"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Split-KV decode with LSE combine (shared by GQA and MLA)
# ---------------------------------------------------------------------------
def _lse_combine(m: Array, lsum: Array, o: Array, axis: Optional[str]):
    """Combine per-shard partial softmax (m,lsum,o) exactly across ``axis``."""
    if axis is None:
        safe_l = jnp.maximum(lsum, 1e-30)
        return o / safe_l[..., None]
    m_g = jax.lax.pmax(m, axis)
    m_safe = jnp.where(jnp.isfinite(m_g), m_g, 0.0)
    corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
    l_g = jax.lax.psum(lsum * corr, axis)
    o_g = jax.lax.psum(o * corr[..., None], axis)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


DECODE_CHUNK = 8192  # per-shard cache chunk: bounds the f32 score slice


def _chunked_partial_softmax(score_fn, value_fn, s_local: int, kv_base, pos,
                             init_o_shape):
    """Online softmax over cache chunks; returns partial (m, l, o).

    score_fn(start, size) -> (..., size) f32 scores for that cache slice;
    value_fn(p, start, size) -> (..., d) the p-weighted value contraction.
    Keeps the score slice at (..., chunk) instead of (..., S_local) — at
    524k context the full slice is GBs (EXPERIMENTS.md §Perf F).
    """
    chunk = min(DECODE_CHUNK, s_local)
    n_chunks = (s_local + chunk - 1) // chunk
    assert s_local % chunk == 0, (s_local, chunk)

    def step(carry, idx):
        m, lsum, o = carry
        start = idx * chunk
        s = score_fn(start, chunk)  # (..., chunk), -inf masked
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(jnp.isfinite(s), jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = lsum * corr + jnp.sum(p, axis=-1)
        o_new = o * corr[..., None] + value_fn(p, start, chunk)
        return (m_new, l_new, o_new), None

    init = (
        jnp.full(init_o_shape[:-1], -jnp.inf, jnp.float32),
        jnp.zeros(init_o_shape[:-1], jnp.float32),
        jnp.zeros(init_o_shape, jnp.float32),
    )
    (m, lsum, o), _ = jax.lax.scan(step, init, jnp.arange(n_chunks))
    return m, lsum, o


def gqa_decode_attend(
    q: Array,  # (B, H, dh) — current token's queries, all heads
    k_cache: Array,  # (B, S_local, Hkv, dh) — this shard's cache slice
    v_cache: Array,
    k_new: Array,  # (B, Hkv, dh)
    v_new: Array,
    pos: Array,  # () int32 — global position being written
    *,
    seq_axis: Optional[str],
    shard_idx: Array,
) -> tuple[Array, Array, Array]:
    """One decode step on a sequence-sharded cache. Returns (out, k_c, v_c)."""
    b, s_local, hkv, dh = k_cache.shape
    h = q.shape[1]
    g = h // hkv
    local_pos = pos - shard_idx * s_local
    in_range = (local_pos >= 0) & (local_pos < s_local)
    lp = jnp.clip(local_pos, 0, s_local - 1)
    k_upd = jax.lax.dynamic_update_slice(k_cache, k_new[:, None], (0, lp, 0, 0))
    v_upd = jax.lax.dynamic_update_slice(v_cache, v_new[:, None], (0, lp, 0, 0))
    k_cache = jnp.where(in_range, k_upd, k_cache)
    v_cache = jnp.where(in_range, v_upd, v_cache)

    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    scale = 1.0 / math.sqrt(dh)

    # Keep the cache in bf16 end-to-end and accumulate in f32 via
    # preferred_element_type: upcasting cache slices lets XLA hoist one
    # full-stack f32 conversion out of the layer scan (+8.6 GB/chip
    # measured on command-r-plus long_500k; EXPERIMENTS.md §Perf F).
    def score_fn(start, size):
        kc = jax.lax.dynamic_slice_in_dim(k_cache, start, size, axis=1)
        s = jnp.einsum(
            "bhgd,bshd->bhgs", qg.astype(kc.dtype), kc,
            preferred_element_type=jnp.float32,
        ) * scale
        kv_pos = shard_idx * s_local + start + jnp.arange(size)
        return jnp.where((kv_pos <= pos)[None, None, None], s, -jnp.inf)

    def value_fn(p, start, size):
        vc = jax.lax.dynamic_slice_in_dim(v_cache, start, size, axis=1)
        return jnp.einsum(
            "bhgs,bshd->bhgd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32,
        )

    m, lsum, o = _chunked_partial_softmax(
        score_fn, value_fn, s_local, None, pos, (b, hkv, g, dh)
    )
    out = _lse_combine(m, lsum, o, seq_axis)  # (B, Hkv, G, dh)
    return out.reshape(b, h, dh), k_cache, v_cache


# ===========================================================================
# MLA (DeepSeek-V2/V3 multi-head latent attention)
# ===========================================================================
def mla_init(rng, cfg) -> Params:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = cfg.d_nope, cfg.d_rope, cfg.d_v, cfg.kv_lora_rank
    ks = jax.random.split(rng, 6)
    p: Params = {
        "wkv_a": dense_init(ks[0], d, r + dr, cfg.param_dtype),
        "kv_norm": rmsnorm_init(r, cfg.param_dtype),
        "wkv_b": dense_init(ks[1], r, h * (dn + dv), cfg.param_dtype),
        "wo": dense_init(ks[2], h * dv, d, cfg.param_dtype),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = dense_init(ks[3], d, cfg.q_lora_rank, cfg.param_dtype)
        p["q_norm"] = rmsnorm_init(cfg.q_lora_rank, cfg.param_dtype)
        p["wq_b"] = dense_init(ks[4], cfg.q_lora_rank, h * (dn + dr), cfg.param_dtype)
    else:
        p["wq"] = dense_init(ks[5], d, h * (dn + dr), cfg.param_dtype)
    return p


def mla_specs(cfg, mi: MeshInfo) -> Params:
    fs, tp = mi.fsdp_axis, mi.tp_axis
    p = {
        "wkv_a": {"w": P(fs, tp)},
        "kv_norm": {"scale": P(None)},
        "wkv_b": {"w": P(fs, tp)},
        "wo": {"w": P(tp, fs)},
    }
    if cfg.q_lora_rank:
        p["wq_a"] = {"w": P(fs, tp)}
        p["q_norm"] = {"scale": P(None)}
        p["wq_b"] = {"w": P(fs, tp)}
    else:
        p["wq"] = {"w": P(fs, tp)}
    return p


def _mla_q(p: Params, cfg, x: Array):
    """(B,S,D) -> q_nope (B,S,H,dn), q_rope (B,S,H,dr) (RoPE not yet applied)."""
    b, s, _ = x.shape
    h, dn, dr = cfg.n_heads, cfg.d_nope, cfg.d_rope
    if cfg.q_lora_rank:
        cq = x @ p["wq_a"]["w"].astype(x.dtype)
        cq = rmsnorm_apply(p["q_norm"], cq, cfg.norm_eps)
        q = cq @ p["wq_b"]["w"].astype(x.dtype)
    else:
        q = x @ p["wq"]["w"].astype(x.dtype)
    q = q.reshape(b, s, h, dn + dr)
    return q[..., :dn], q[..., dn:]


def _mla_kv_latent(p: Params, cfg, x: Array):
    """(B,S,D) -> c_kv (B,S,r) normalized latent, k_rope (B,S,dr) (no RoPE yet)."""
    r = cfg.kv_lora_rank
    kv = x @ p["wkv_a"]["w"].astype(x.dtype)
    c_kv, k_rope = kv[..., :r], kv[..., r:]
    return rmsnorm_apply(p["kv_norm"], c_kv, cfg.norm_eps), k_rope


def mla_train(p: Params, cfg, mi: MeshInfo, x: Array, positions: Array) -> Array:
    """Expanded (non-absorbed) MLA for training/prefill."""
    b, s, _ = x.shape
    h, dn, dr, dv = cfg.n_heads, cfg.d_nope, cfg.d_rope, cfg.d_v
    q_nope, q_rope = _mla_q(p, cfg, x)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c_kv, k_rope = _mla_kv_latent(p, cfg, x)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)  # (B,S,1,dr)
    kv = (c_kv @ p["wkv_b"]["w"].astype(x.dtype)).reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
    q = mi.constrain(q, mi.dp_axes, None, mi.tp_axis, None)
    k = mi.constrain(k, mi.dp_axes, None, mi.tp_axis, None)
    v = mi.constrain(v, mi.dp_axes, None, mi.tp_axis, None)
    out = chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, mi=mi)
    out = mi.constrain(out, mi.dp_axes, None, mi.tp_axis, None)
    return out.reshape(b, s, h * dv) @ p["wo"]["w"].astype(x.dtype)


def mla_decode_attend(
    p: Params,
    cfg,
    x_tok: Array,  # (B, D) — current token's hidden state
    c_cache: Array,  # (B, S_local, r + dr) — latent cache slice (this shard)
    pos: Array,
    *,
    seq_axis: Optional[str],
    shard_idx: Array,
) -> tuple[Array, Array]:
    """Absorbed-matrix MLA decode on a sequence-sharded latent cache.

    The cache stores only [c_kv ; k_rope] (r + dr per token, no head axis) —
    MLA's signature memory saving. W_uk is absorbed into the query and W_uv
    is applied after attention, so per-step FLOPs are H*(r+dr) per cache row.
    """
    b, s_local, _ = c_cache.shape
    h, dn, dr, dv, r = cfg.n_heads, cfg.d_nope, cfg.d_rope, cfg.d_v, cfg.kv_lora_rank
    x = x_tok[:, None, :]  # (B, 1, D)
    q_nope, q_rope = _mla_q(p, cfg, x)  # (B,1,H,dn), (B,1,H,dr)
    q_rope = apply_rope(q_rope, pos[None], cfg.rope_theta)
    c_new, k_rope_new = _mla_kv_latent(p, cfg, x)  # (B,1,r), (B,1,dr)
    k_rope_new = apply_rope(k_rope_new[..., None, :], pos[None], cfg.rope_theta)[
        ..., 0, :
    ]
    entry = jnp.concatenate([c_new, k_rope_new], axis=-1)[:, 0]  # (B, r+dr)

    local_pos = pos - shard_idx * s_local
    in_range = (local_pos >= 0) & (local_pos < s_local)
    lp = jnp.clip(local_pos, 0, s_local - 1)
    upd = jax.lax.dynamic_update_slice(c_cache, entry[:, None], (0, lp, 0))
    c_cache = jnp.where(in_range, upd, c_cache)

    # Absorb W_uk: q_eff[h] = W_uk[h]^T q_nope[h]  -> (B, H, r)
    wkv_b = p["wkv_b"]["w"].astype(jnp.float32).reshape(r, h, dn + dv)
    w_uk = wkv_b[..., :dn]  # (r, H, dn)
    w_uv = wkv_b[..., dn:]  # (r, H, dv)
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32), w_uk)
    scale = 1.0 / math.sqrt(dn + dr)
    cache_dtype = c_cache.dtype
    q_eff_c = q_eff.astype(cache_dtype)
    q_rope_c = q_rope[:, 0].astype(cache_dtype)

    def score_fn(start, size):
        cc = jax.lax.dynamic_slice_in_dim(c_cache, start, size, axis=1)
        s_lat = jnp.einsum(
            "bhr,bsr->bhs", q_eff_c, cc[..., :r],
            preferred_element_type=jnp.float32,
        )
        s_rope = jnp.einsum(
            "bhd,bsd->bhs", q_rope_c, cc[..., r:],
            preferred_element_type=jnp.float32,
        )
        s_all = (s_lat + s_rope) * scale
        kv_pos = shard_idx * s_local + start + jnp.arange(size)
        return jnp.where((kv_pos <= pos)[None, None], s_all, -jnp.inf)

    def value_fn(pr, start, size):
        cc = jax.lax.dynamic_slice_in_dim(c_cache, start, size, axis=1)
        return jnp.einsum(
            "bhs,bsr->bhr", pr.astype(cache_dtype), cc[..., :r],
            preferred_element_type=jnp.float32,
        )

    m, lsum, o_lat = _chunked_partial_softmax(
        score_fn, value_fn, s_local, None, pos, (b, h, r)
    )
    o_lat = _lse_combine(m, lsum, o_lat, seq_axis)
    out = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv)  # (B, H, dv)
    out = out.reshape(b, h * dv).astype(x_tok.dtype)
    return out @ p["wo"]["w"].astype(x_tok.dtype), c_cache
