"""Shared pure-JAX NN building blocks (no flax).

Parameters are nested dicts of arrays; every init_* has a matching spec_*
that yields the same tree shape filled with `PartitionSpec`s, so models can
emit (params, shardings) pairs without a module system.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array
Params = dict


def dense_init(rng: Array, d_in: int, d_out: int, dtype=jnp.float32) -> Params:
    scale = 1.0 / math.sqrt(d_in)
    return {"w": jax.random.uniform(rng, (d_in, d_out), dtype, -scale, scale)}


def dense_apply(p: Params, x: Array) -> Array:
    return x @ p["w"].astype(x.dtype)


def mlp_init(
    rng: Array, dims: Sequence[int], dtype=jnp.float32, bias: bool = True
) -> Params:
    layers = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        r = jax.random.fold_in(rng, i)
        layer = dense_init(r, a, b, dtype)
        if bias:
            layer["b"] = jnp.zeros((b,), dtype)
        layers.append(layer)
    return {"layers": layers}


def mlp_apply(p: Params, x: Array, act=jax.nn.relu, final_act: bool = False) -> Array:
    n = len(p["layers"])
    for i, layer in enumerate(p["layers"]):
        x = x @ layer["w"].astype(x.dtype)
        if "b" in layer:
            x = x + layer["b"].astype(x.dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def mlp_specs(p_template: Params, spec=P(None, None)) -> Params:
    layers = []
    for layer in p_template["layers"]:
        s = {"w": spec}
        if "b" in layer:
            s["b"] = P(None)
        layers.append(s)
    return {"layers": layers}


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Params, x: Array, eps: float = 1e-6) -> Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p: Params, x: Array, eps: float = 1e-5) -> Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embedding_init(rng: Array, vocab: int, d: int, dtype=jnp.float32) -> Params:
    return {"table": jax.random.normal(rng, (vocab, d), dtype) * 0.02}


def embedding_apply(p: Params, ids: Array) -> Array:
    return p["table"][ids]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_frequencies(d_rot: int, theta: float = 10_000.0) -> Array:
    return 1.0 / (
        theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot)
    )  # (d_rot/2,)


def apply_rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """x: (..., S, H, d_rot); positions: (S,) — head axis required."""
    d_rot = x.shape[-1]
    freqs = rope_frequencies(d_rot, theta)  # (d_rot/2,)
    angles = positions[:, None].astype(jnp.float32) * freqs  # (S, d_rot/2)
    angles = angles[:, None, :]  # (S, 1, d_rot/2) — broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Memory-bounded attention — thin wrapper over the custom-VJP flash kernel
# (see repro/models/common/flash.py for the FA2 forward/backward).
# ---------------------------------------------------------------------------
def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool,
    q_offset: int = 0,
    chunk: int = 512,
    logit_soft_cap: float = 0.0,
    mi=None,
) -> Array:
    """q: (B, Sq, H, dh), k/v: (B, Skv, Hkv, dh[v]) -> (B, Sq, H, dhv).

    GQA-aware (H % Hkv == 0) online-softmax attention with a
    FlashAttention-2 custom VJP; never materializes (Sq, Skv) scores.
    """
    from repro.models.common.flash import AttnMeta, flash_attention

    meta = AttnMeta(
        causal=causal,
        q_offset=int(q_offset),
        chunk=chunk,
        soft_cap=logit_soft_cap,
        mi=mi,
    )
    return flash_attention(q, k, v, meta)
