"""FlashAttention-2-style chunked attention with a custom VJP.

Forward: online-softmax scan over KV chunks (never materializes the
(Sq, Skv) score matrix); saves only (q, k, v, out, lse).
Backward: recomputes p per chunk from the saved lse and accumulates
dq / dk / dv — the FA2 recompute schedule. Without the custom VJP,
jax.grad of the forward scan stacks every chunk's f32 scores+mask
(+13 GB/device measured on DeepSeek-V3 train_4k; EXPERIMENTS.md §Perf).

Sharding: GSPMD does not reliably propagate head sharding into the scan's
f32 carries, so the (b, hkv, g, sq[, d]) intermediates are constrained
explicitly — KV-head sharding when Hkv divides the model axis, group
sharding when G does, else query-sequence (context-parallel) sharding.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AttnMeta:
    causal: bool
    q_offset: int
    chunk: int
    soft_cap: float
    mi: Any  # MeshInfo (hashable) or None


def _constrainer(meta: AttnMeta, hkv: int, g: int):
    mi = meta.mi
    if mi is None or mi.tp_size <= 1:
        return lambda x: x
    tp, dp = mi.tp_axis, mi.dp_axes
    if hkv % mi.tp_size == 0:
        c_spec = (dp, tp, None, None)
    elif g % mi.tp_size == 0:
        c_spec = (dp, None, tp, None)
    else:
        # Neither Hkv nor G divides the model axis (e.g. 8-KV-head GQA on a
        # 16-way mesh). GSPMD derives a mixed (hkv x g) sub-axis sharding
        # that PartitionSpec cannot express; forcing query-sequence sharding
        # here fought that propagation and triggered involuntary full
        # rematerialization (+20 GB temp, +41 GB collectives per layer on
        # command-r-plus — EXPERIMENTS.md §Perf B1). Leave it to GSPMD.
        return lambda x: x

    def _c(x):
        return mi.constrain(x, *(c_spec + (None,) * (x.ndim - 4)))

    return _c


def _fwd_core(q: Array, k: Array, v: Array, meta: AttnMeta):
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    dhv = v.shape[-1]
    g = h // hkv
    _c = _constrainer(meta, hkv, g)
    qg = q.reshape(b, sq, hkv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    chunk = min(meta.chunk, skv)
    n_chunks = (skv + chunk - 1) // chunk
    pad = n_chunks * chunk - skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q_pos = meta.q_offset + jnp.arange(sq)

    def step(carry, idx):
        m, lsum, acc = carry
        kc = jax.lax.dynamic_slice_in_dim(kp, idx * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, idx * chunk, chunk, axis=1)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), kc.astype(jnp.float32)
        ) * scale
        s = _c(s)
        if meta.soft_cap > 0:
            s = meta.soft_cap * jnp.tanh(s / meta.soft_cap)
        kv_pos = idx * chunk + jnp.arange(chunk)
        valid = kv_pos[None, :] < skv
        if meta.causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        s = jnp.where(valid[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(valid[None, None, None], p, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = lsum * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (
        _c(jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32)),
        _c(jnp.zeros((b, hkv, g, sq), jnp.float32)),
        _c(jnp.zeros((b, hkv, g, sq, dhv), jnp.float32)),
    )
    (m, lsum, acc), _ = jax.lax.scan(step, init, jnp.arange(n_chunks))
    out5 = acc / jnp.maximum(lsum, 1e-30)[..., None]  # (b, hkv, g, sq, dhv)
    lse = jnp.where(
        (lsum > 0) & jnp.isfinite(m), m + jnp.log(jnp.maximum(lsum, 1e-30)), -jnp.inf
    )
    out = out5.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dhv).astype(q.dtype)
    return out, lse  # lse: (b, hkv, g, sq)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention(q: Array, k: Array, v: Array, meta: AttnMeta) -> Array:
    return _fwd_core(q, k, v, meta)[0]


def _fa_fwd(q, k, v, meta):
    out, lse = _fwd_core(q, k, v, meta)
    return out, (q, k, v, out, lse)


def _fa_bwd(meta: AttnMeta, res, dout):
    q, k, v, out, lse = res
    b, sq, h, dh = q.shape
    _, skv, hkv, _ = k.shape
    dhv = v.shape[-1]
    g = h // hkv
    _c = _constrainer(meta, hkv, g)
    scale = 1.0 / math.sqrt(dh)
    chunk = min(meta.chunk, skv)
    n_chunks = (skv + chunk - 1) // chunk
    pad = n_chunks * chunk - skv
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    q_pos = meta.q_offset + jnp.arange(sq)

    qg = q.reshape(b, sq, hkv, g, dh).astype(jnp.float32)
    og = out.reshape(b, sq, hkv, g, dhv).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    dog = dout.reshape(b, sq, hkv, g, dhv).transpose(0, 2, 3, 1, 4).astype(jnp.float32)
    dmass = jnp.sum(dog * og, axis=-1)  # (b, hkv, g, sq) — FA2's D term
    lse_safe = jnp.where(jnp.isfinite(lse), lse, 0.0)
    lse_finite = jnp.isfinite(lse)

    def step(dq, idx):
        kc = jax.lax.dynamic_slice_in_dim(kp, idx * chunk, chunk, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(vp, idx * chunk, chunk, axis=1)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc.astype(jnp.float32)) * scale
        s = _c(s)
        if meta.soft_cap > 0:
            t = jnp.tanh(s / meta.soft_cap)
            s_eff = meta.soft_cap * t
            dtanh = 1.0 - t * t
        else:
            s_eff = s
            dtanh = None
        kv_pos = idx * chunk + jnp.arange(chunk)
        valid = kv_pos[None, :] < skv
        if meta.causal:
            valid = valid & (kv_pos[None, :] <= q_pos[:, None])
        p = jnp.where(
            valid[None, None, None] & lse_finite[..., None],
            jnp.exp(s_eff - lse_safe[..., None]),
            0.0,
        )
        p = _c(p)
        dv_c = jnp.einsum("bhgqk,bhgqd->bkhd", p, dog)
        dp = jnp.einsum("bhgqd,bkhd->bhgqk", dog, vc.astype(jnp.float32))
        ds = p * (dp - dmass[..., None])
        if dtanh is not None:
            ds = ds * dtanh
        ds = _c(ds)
        dq_new = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kc.astype(jnp.float32)) * scale
        dk_c = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg) * scale
        return dq_new, (dk_c, dv_c)

    dq0 = jnp.zeros((b, sq, hkv, g, dh), jnp.float32)
    dq, (dk_chunks, dv_chunks) = jax.lax.scan(step, dq0, jnp.arange(n_chunks))
    dq = dq.reshape(b, sq, h, dh).astype(q.dtype)
    dk = (
        dk_chunks.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, hkv, dh)
    )[:, :skv].astype(k.dtype)
    dv = (
        dv_chunks.transpose(1, 0, 2, 3, 4).reshape(b, n_chunks * chunk, hkv, dhv)
    )[:, :skv].astype(v.dtype)
    return dq, dk, dv


flash_attention.defvjp(_fa_fwd, _fa_bwd)
