"""Architecture registry: ``--arch <id>`` resolves here.

10 assigned architectures + the paper's own AIRSHIP serve workload.
"""
from repro.archs.base import register
from repro.configs import lm_configs as lm
from repro.configs import other_configs as oc

ASSIGNED = (
    "deepseek-v2-236b",
    "deepseek-v3-671b",
    "command-r-plus-104b",
    "granite-3-2b",
    "command-r-35b",
    "mace",
    "two-tower-retrieval",
    "deepfm",
    "sasrec",
    "dlrm-mlperf",
)

register("deepseek-v2-236b", lm.deepseek_v2_236b)
register("deepseek-v3-671b", lm.deepseek_v3_671b)
register("command-r-plus-104b", lm.command_r_plus_104b)
register("command-r-35b", lm.command_r_35b)
register("granite-3-2b", lm.granite_3_2b)
register("mace", oc.mace)
register("dlrm-mlperf", oc.dlrm_mlperf)
register("deepfm", oc.deepfm)
register("sasrec", oc.sasrec)
register("two-tower-retrieval", oc.two_tower_retrieval)
register("airship-sift1m", oc.airship_sift1m)

# Reduced smoke variants (same family code paths, CPU-sized).
register("smoke-gqa", lambda: lm.smoke_lm("gqa"))
register("smoke-mla-moe", lambda: lm.smoke_lm("mla", moe=True, mtp=True))
register("smoke-mace", oc.smoke_mace)
register("smoke-dlrm", lambda: oc.smoke_recsys("dlrm"))
register("smoke-deepfm", lambda: oc.smoke_recsys("deepfm"))
register("smoke-sasrec", lambda: oc.smoke_recsys("sasrec"))
register("smoke-two-tower", lambda: oc.smoke_recsys("two_tower"))
register("smoke-airship", oc.smoke_airship)
