"""MACE, the four recsys archs, and the paper's own AIRSHIP serve config."""
from __future__ import annotations


from repro.archs.airship import AirshipArch, AirshipServeConfig
from repro.archs.gnn import GNNArch
from repro.archs.recsys import RecsysArch
from repro.core.types import SearchParams
from repro.models.gnn.mace import MACEConfig
from repro.models.recsys.models import RecsysConfig

# MLPerf DLRM (Criteo 1TB) categorical vocab sizes — 26 fields.
CRITEO_VOCABS = (
    39884406, 39043, 17289, 7420, 20263, 3, 7120, 1543, 63, 38532951,
    2953546, 403346, 10, 2208, 11938, 155, 4, 976, 14, 39979771,
    25641295, 39664984, 585935, 12972, 108, 36,
)

# DeepFM on Criteo-style features: 13 bucketized numeric (vocab 1024) +
# 26 categorical hashed to <=1M rows (hash-trick, standard DeepFM practice).
DEEPFM_VOCABS = tuple([1024] * 13 + [min(v, 1_000_000) for v in CRITEO_VOCABS])


def mace() -> GNNArch:
    # [arXiv:2206.07697]: 2 interaction layers, 128 channels, l_max=2,
    # correlation order 3, 8 Bessel RBFs, E(3)-equivariant.
    return GNNArch(
        MACEConfig(
            name="mace",
            n_layers=2,
            d_hidden=128,
            l_max=2,
            correlation_order=3,
            n_rbf=8,
        )
    )


def smoke_mace() -> GNNArch:
    shapes = {
        "full_graph_sm": dict(kind="train", n_nodes=64, n_edges=256, d_feat=16, mode="simple"),
        "minibatch_lg": dict(kind="train", batch_nodes=8, fanouts=(3, 2), d_feat=16, mode="sampled"),
        "ogb_products": dict(kind="train", n_nodes=128, n_edges=512, d_feat=8, mode="dst_partitioned"),
        "molecule": dict(kind="train", n_nodes=6, n_edges=12, batch=4, mode="batched"),
    }
    return GNNArch(
        MACEConfig(name="smoke-mace", n_layers=2, d_hidden=8, n_rbf=4), shapes=shapes
    )


def dlrm_mlperf() -> RecsysArch:
    # [arXiv:1906.00091] MLPerf config: 13 dense, 26 sparse, dim 128,
    # bottom 512-256-128, top 1024-1024-512-256-1, dot interaction.
    return RecsysArch(
        RecsysConfig(
            name="dlrm-mlperf",
            model="dlrm",
            embed_dim=128,
            vocab_sizes=CRITEO_VOCABS,
            n_dense=13,
            bot_mlp=(512, 256, 128),
            top_mlp=(1024, 1024, 512, 256, 1),
        )
    )


def deepfm() -> RecsysArch:
    # [arXiv:1703.04247]: 39 fields, dim 10, MLP 400-400-400, FM interaction.
    return RecsysArch(
        RecsysConfig(
            name="deepfm",
            model="deepfm",
            embed_dim=10,
            vocab_sizes=DEEPFM_VOCABS,
            mlp=(400, 400, 400),
        )
    )


def sasrec() -> RecsysArch:
    # [arXiv:1808.09781]: dim 50, 2 blocks, 1 head, seq 50. Item vocab set
    # to 1M (industrial scale; vocab is not pinned by the paper config) so
    # retrieval_cand (1M candidates) is well-defined.
    return RecsysArch(
        RecsysConfig(
            name="sasrec",
            model="sasrec",
            embed_dim=50,
            seq_len=50,
            n_blocks=2,
            n_heads=1,
            item_vocab=1_000_000,
        )
    )


def two_tower_retrieval() -> RecsysArch:
    # [RecSys'19 YouTube]: dim 256, towers 1024-512-256, dot interaction.
    return RecsysArch(
        RecsysConfig(
            name="two-tower-retrieval",
            model="two_tower",
            embed_dim=256,
            tower_mlp=(1024, 512, 256),
            item_vocab=50_000_000,
            user_vocab=50_000_000,
            hist_len=50,
        )
    )


def smoke_recsys(model: str) -> RecsysArch:
    shapes = {
        "train_batch": dict(kind="train", batch=16),
        "serve_p99": dict(kind="serve", batch=8),
        "serve_bulk": dict(kind="serve", batch=32),
        "retrieval_cand": dict(kind="serve", batch=1, n_candidates=256),
    }
    cfgs = {
        "dlrm": RecsysConfig(
            name="smoke-dlrm", model="dlrm", embed_dim=8,
            vocab_sizes=(100, 50, 30), n_dense=4, bot_mlp=(16, 8), top_mlp=(16, 1),
        ),
        "deepfm": RecsysConfig(
            name="smoke-deepfm", model="deepfm", embed_dim=5,
            vocab_sizes=(40,) * 6, mlp=(16, 16),
        ),
        "sasrec": RecsysConfig(
            name="smoke-sasrec", model="sasrec", embed_dim=16,
            seq_len=10, n_blocks=2, n_heads=1, item_vocab=200,
        ),
        "two_tower": RecsysConfig(
            name="smoke-two-tower", model="two_tower", embed_dim=16,
            tower_mlp=(32, 8), item_vocab=500, user_vocab=300, hist_len=5,
        ),
    }
    return RecsysArch(cfgs[model], shapes=shapes)


def airship_sift1m() -> AirshipArch:
    # The paper's evaluation scale: 1M 128-d vectors, 10 labels (SIFT1M +
    # k-means labeling protocol, §3 'Data').
    return AirshipArch(AirshipServeConfig())


def smoke_airship() -> AirshipArch:
    cfg = AirshipServeConfig(
        name="smoke-airship", n=2048, dim=16, degree=8, sample_per_shard=32,
        params=SearchParams(
            mode="prefer", k=5, ef_result=32, ef_sat=32, ef_other=32,
            n_start=8, max_iters=64,
        ),
    )
    return AirshipArch(cfg, shapes={"serve_256": dict(kind="serve", batch=16)})
