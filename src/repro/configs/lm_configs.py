"""The five assigned LM-transformer architectures, exact public configs.

Sources (per assignment): DeepSeek-V2 [arXiv:2405.04434], DeepSeek-V3
[arXiv:2412.19437], Command-R / Command-R+ [hf:CohereForAI], Granite-3.0-2B
[hf:ibm-granite]. d_ff for the MoE archs is the routed-expert FFN width;
the leading dense layers use the models' published dense widths.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.archs.lm import LMArch
from repro.models.transformer.model import TransformerConfig


def deepseek_v2_236b() -> LMArch:
    # 60L, d=5120, 128H MLA (kv_lora=512, q_lora=1536), 160 routed experts
    # top-6 + 2 shared, expert d_ff=1536, first layer dense (d_ff=12288).
    return LMArch(
        TransformerConfig(
            name="deepseek-v2-236b",
            n_layers=60,
            d_model=5120,
            n_heads=128,
            n_kv_heads=128,
            head_dim=192,  # d_nope + d_rope (q/k); v heads are d_v=128
            d_ff=12288,
            vocab_size=102400,
            attn_type="mla",
            q_lora_rank=1536,
            kv_lora_rank=512,
            d_nope=128,
            d_rope=64,
            d_v=128,
            n_experts=160,
            n_shared_experts=2,
            top_k=6,
            d_ff_expert=1536,
            n_dense_layers=1,
        ),
        optimizer="adafactor",
        # ga=2: +7.2 GB temp vs ga=4 but half the SP collective volume —
        # the better roofline point; still fits the 512-chip mesh
        # (EXPERIMENTS.md §Perf A8).
        grad_accum=2,
    )


def deepseek_v3_671b() -> LMArch:
    # 61L, d=7168, 128H MLA, 256 routed top-8 + 1 shared, expert d_ff=2048,
    # first 3 layers dense (d_ff=18432), MTP.
    return LMArch(
        TransformerConfig(
            name="deepseek-v3-671b",
            n_layers=61,
            d_model=7168,
            n_heads=128,
            n_kv_heads=128,
            head_dim=192,
            d_ff=18432,
            vocab_size=129280,
            attn_type="mla",
            q_lora_rank=1536,
            kv_lora_rank=512,
            d_nope=128,
            d_rope=64,
            d_v=128,
            n_experts=256,
            n_shared_experts=1,
            top_k=8,
            d_ff_expert=2048,
            n_dense_layers=3,
            mtp=True,
        ),
        optimizer="adafactor",
        grad_accum=4,
    )


def command_r_plus_104b() -> LMArch:
    return LMArch(
        TransformerConfig(
            name="command-r-plus-104b",
            n_layers=64,
            d_model=12288,
            n_heads=96,
            n_kv_heads=8,
            head_dim=128,
            d_ff=33792,
            vocab_size=256000,
            attn_type="gqa",
        ),
        optimizer="adafactor",
    )


def command_r_35b() -> LMArch:
    return LMArch(
        TransformerConfig(
            name="command-r-35b",
            n_layers=40,
            d_model=8192,
            n_heads=64,
            n_kv_heads=8,
            head_dim=128,
            d_ff=22528,
            vocab_size=256000,
            attn_type="gqa",
        ),
        optimizer="adafactor",
    )


def granite_3_2b() -> LMArch:
    return LMArch(
        TransformerConfig(
            name="granite-3-2b",
            n_layers=40,
            d_model=2048,
            n_heads=32,
            n_kv_heads=8,
            head_dim=64,
            d_ff=8192,
            vocab_size=49155,
            attn_type="gqa",
        ),
        optimizer="adamw",
    )


def smoke_lm(attn_type: str = "gqa", moe: bool = False, mtp: bool = False) -> LMArch:
    """Reduced same-family config for CPU smoke tests."""
    kwargs = dict(
        name=f"smoke-{attn_type}{'-moe' if moe else ''}",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2 if attn_type == "gqa" else 4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        attn_type=attn_type,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
        attn_chunk=16,
        ce_chunk=16,
        remat="none",
        mtp=mtp,
    )
    if attn_type == "mla":
        kwargs.update(q_lora_rank=32, kv_lora_rank=16, d_nope=16, d_rope=8, d_v=16)
    if moe:
        kwargs.update(
            n_experts=8, n_shared_experts=1, top_k=2, d_ff_expert=32, n_dense_layers=1
        )
    shapes = {
        "train_4k": dict(kind="train", seq_len=32, global_batch=4),
        "prefill_32k": dict(kind="serve", seq_len=64, global_batch=2),
        "decode_32k": dict(kind="serve", seq_len=64, global_batch=4),
        "long_500k": dict(kind="serve", seq_len=128, global_batch=1),
    }
    return LMArch(TransformerConfig(**kwargs), optimizer="adamw", shapes=shapes)
