"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: (16, 16) = 256 chips ("data",
"model"); multi-pod: (2, 16, 16) = 512 chips ("pod", "data", "model").
"""
from __future__ import annotations

import jax

from repro.distributed.meshinfo import MeshInfo


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_production_meshinfo(*, multi_pod: bool = False) -> MeshInfo:
    return MeshInfo(mesh=make_production_mesh(multi_pod=multi_pod))


def make_test_mesh(n_devices: int | None = None, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    devs = jax.devices()
    n = n_devices or len(devs)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"), devices=devs[: data * model])
