"""Production training driver: ``--arch <id>`` + mesh + fault tolerance.

Fault model (1000+-node posture):
  * checkpoint every N steps, atomic (manifest + rename), keep-K pruning;
  * resume = restore latest + deterministic data skip (batches are pure
    functions of (seed, step) — no data-state checkpoint needed);
  * elastic restart: restore accepts a different mesh's shardings, so a
    run that loses a pod resumes on the shrunken mesh (see
    tests/test_distributed_multidev.py for the reshard path);
  * straggler mitigation: synchronous steps with a deadline — a step
    exceeding --step-deadline-x the trailing median is logged and, past
    --max-straggles, the driver checkpoints and exits nonzero so the
    scheduler can replace the slow host (standard preemption contract);
  * NaN guard: skip-and-log update on non-finite loss (keeps params).

Reduced CPU run:
    PYTHONPATH=src python -m repro.launch.train --arch smoke-gqa --steps 20
"""
from __future__ import annotations

import argparse
import statistics
import time

import jax
import jax.numpy as jnp

from repro.archs.base import get_arch
from repro.ckpt import checkpoint as ck
from repro.data import pipeline as dp
from repro.distributed.meshinfo import MeshInfo, single_device_meshinfo


def make_batch_fn(arch, shape_cfg):
    fam = arch.family
    if fam == "lm":
        cfg = arch.cfg
        b, s = shape_cfg["global_batch"], shape_cfg["seq_len"]
        return lambda seed, step: dp.lm_batch(seed, step, b, s, cfg.vocab_size)
    if fam == "recsys":
        cfg = arch.cfg
        b = shape_cfg["batch"]
        if cfg.model == "dlrm":
            return lambda seed, step: dp.dlrm_batch(
                seed, step, b, cfg.n_dense, cfg.vocab_sizes
            )
        if cfg.model == "deepfm":
            return lambda seed, step: dp.deepfm_batch(seed, step, b, cfg.vocab_sizes)
        if cfg.model == "sasrec":
            return lambda seed, step: dp.sasrec_batch(
                seed, step, b, cfg.seq_len, cfg.item_vocab
            )
        return lambda seed, step: dp.two_tower_batch(
            seed, step, b, cfg.user_vocab, cfg.item_vocab, cfg.hist_len
        )
    if fam == "gnn":
        cfg = arch.base_cfg
        sh = shape_cfg
        if sh["mode"] == "sampled":
            from repro.models.gnn.sampler import subgraph_sizes

            n, e = subgraph_sizes(sh["batch_nodes"], sh["fanouts"])
        else:
            n, e = sh["n_nodes"], sh["n_edges"]
        return lambda seed, step: dp.gnn_batch(
            seed, step, n, e, d_feat=sh.get("d_feat", 0)
        )
    raise ValueError(f"no batch fn for family {fam}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None, help="train shape name")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--step-deadline-x", type=float, default=3.0)
    ap.add_argument("--max-straggles", type=int, default=5)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    shape = args.shape or next(
        s for s in arch.shape_names() if arch.shapes[s]["kind"] == "train"
    )
    mi = single_device_meshinfo() if jax.device_count() == 1 else MeshInfo(
        mesh=jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    )
    cell = arch.make_cell(shape, mi)
    batch_fn = make_batch_fn(arch, arch.shapes[shape])

    # init or resume
    start = ck.latest_step(args.ckpt_dir)
    if start is not None:
        print(f"[resume] restoring step {start}")
        state = ck.restore(
            args.ckpt_dir, start, {"params": cell.args[0], "opt": cell.args[1]}
        )
        params, opt_state = state["params"], state["opt"]
    else:
        start = 0
        fam_init = {
            "lm": lambda: __import__(
                "repro.models.transformer.model", fromlist=["init_params"]
            ).init_params(jax.random.PRNGKey(args.seed), arch.cfg),
            "gnn": lambda: __import__(
                "repro.models.gnn.mace", fromlist=["init_params"]
            ).init_params(jax.random.PRNGKey(args.seed), arch.base_cfg),
        }
        if arch.family in fam_init:
            params = fam_init[arch.family]()
        else:
            from repro.archs.recsys import _INIT

            params = _INIT[arch.cfg.model](jax.random.PRNGKey(args.seed), arch.cfg)
        opt_state = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), cell.args[1]
        )

    step_fn = jax.jit(cell.fn, donate_argnums=cell.donate_argnums)
    times: list[float] = []
    straggles = 0
    for step in range(start, args.steps):
        t0 = time.time()
        batch = dp.shard_batch(batch_fn(args.seed, step), mi)
        params2, opt2, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        if not jnp.isfinite(loss):
            print(f"[nan-guard] step {step}: non-finite loss, skipping update")
        else:
            params, opt_state = params2, opt2
        if times and dt > args.step_deadline_x * statistics.median(times):
            straggles += 1
            print(f"[straggler] step {step} took {dt:.2f}s "
                  f"(median {statistics.median(times):.2f}s) "
                  f"[{straggles}/{args.max_straggles}]")
            if straggles >= args.max_straggles:
                ck.save(args.ckpt_dir, step, {"params": params, "opt": opt_state})
                raise SystemExit(17)  # scheduler contract: replace me
        times = (times + [dt])[-20:]
        if step % 10 == 0:
            print(f"step {step:5d} loss={loss:.4f} ({dt*1e3:.0f} ms)")
        if step and step % args.ckpt_every == 0:
            ck.save(args.ckpt_dir, step, {"params": params, "opt": opt_state})
            ck.prune_old(args.ckpt_dir, keep=args.keep)
    ck.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt_state})
    print("training complete")


if __name__ == "__main__":
    main()
