import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
cell on the production meshes, with ShapeDtypeStruct stand-ins (no device
allocation), and record memory/cost/collective analysis for §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from repro.common.compat import cost_analysis_dict, set_mesh  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.archs.base import get_arch  # noqa: E402
from repro.distributed.meshinfo import MeshInfo  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline.collectives import collective_bytes_from_hlo  # noqa: E402


def dryrun_cell(arch_name: str, shape: str, *, multi_pod: bool, out_dir: str | None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mi = MeshInfo(mesh=mesh)
    arch = get_arch(arch_name)
    cell = arch.make_cell(shape, mi)

    in_shardings = jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        cell.in_specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    t0 = time.time()
    with set_mesh(mesh):
        lowered = jax.jit(
            cell.fn,
            in_shardings=in_shardings,
            donate_argnums=cell.donate_argnums,
        ).lower(*cell.args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes_from_hlo(hlo)

    record = {
        "cell": cell.name,
        "kind": cell.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "note": cell.note,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {
            "flops": cost.get("flops") if cost else None,
            "bytes_accessed": cost.get("bytes accessed") if cost else None,
            "transcendentals": cost.get("transcendentals") if cost else None,
        },
        "collectives": coll,
    }
    print(f"=== {cell.name} @ {record['mesh']} ===")
    print("memory_analysis:", mem)
    print(
        "cost_analysis: flops={flops} bytes={bytes_accessed}".format(**record["cost"])
    )
    print("collective_bytes:", json.dumps(coll["per_op_bytes"], indent=None))
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{cell.name.replace(':', '_')}_{record['mesh'].replace('x', '-')}"
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(record, f, indent=2)
    return record


ALL_CELLS = None  # filled lazily from the registry


def all_cells():
    from repro.configs import ASSIGNED

    cells = []
    for a in ASSIGNED + ("airship-sift1m",):
        arch = get_arch(a)
        for s in arch.shape_names():
            cells.append((a, s))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    targets = all_cells() if args.all else [(args.arch, args.shape)]
    failures = []
    for arch_name, shape in targets:
        for mp in meshes:
            try:
                dryrun_cell(arch_name, shape, multi_pod=mp, out_dir=args.out)
            except Exception as e:  # noqa: BLE001 — report, keep sweeping
                failures.append((arch_name, shape, mp, repr(e)))
                print(f"FAILED {arch_name}:{shape} multi_pod={mp}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nALL DRY-RUN CELLS PASSED")


if __name__ == "__main__":
    main()
