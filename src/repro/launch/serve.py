"""Constrained-search serving driver (the paper's workload).

Builds (or loads) a partitioned index, then serves batched constrained
queries with the distributed scatter-search-merge path.

Reduced CPU run:
    PYTHONPATH=src python -m repro.launch.serve --n 20000 --batches 5
"""
from __future__ import annotations

import argparse
import time

import jax
from repro.common.compat import set_mesh
import jax.numpy as jnp

from repro.core import (
    SearchParams,
    equal_constraint,
    make_distributed_search,
    shard_corpus_for_mesh,
    unequal_pct_constraint,
)
from repro.data.synthetic import make_labeled_corpus, make_queries
from repro.distributed.meshinfo import MeshInfo
from repro.graph.index import build_partitioned_index


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--labels", type=int, default=10)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--batches", type=int, default=10)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--constraint", default="unequal-20")
    ap.add_argument(
        "--approx", default="exact", choices=("exact", "pq"),
        help="distance backend for the walk: exact rows or PQ/ADC codes "
        "(trains a PQ index on the corpus; exact re-rank post-loop)",
    )
    ap.add_argument(
        "--fuse", default="auto", choices=("auto", "on", "off"),
        help="fused candidate pipeline (kernels/fused_expand; 'on' forces "
        "the one-pass gather+distance+constraint+visited kernel for either "
        "backend)",
    )
    args = ap.parse_args()

    n_dev = jax.device_count()
    model = min(4, n_dev)
    data = n_dev // model
    mesh = jax.make_mesh((data, model), ("data", "model"))
    mi = MeshInfo(mesh=mesh)
    print(f"mesh: {dict(mesh.shape)}")

    corpus = make_labeled_corpus(
        jax.random.PRNGKey(0), n=args.n, d=args.d, n_labels=args.labels
    )
    print("building partitioned index...")
    corpus_p, graph_p = build_partitioned_index(
        jax.random.PRNGKey(1), corpus, n_shards=model, degree=16,
        sample_size_per_shard=128,
    )
    corpus_s, graph_s = shard_corpus_for_mesh(corpus_p, graph_p, mesh)

    params = SearchParams(mode="prefer", k=args.k, ef_result=128, n_start=32,
                          max_iters=800, approx=args.approx,
                          fuse_expand=args.fuse)
    pq_index = None
    if args.approx == "pq":
        from repro.core import pq_train
        from repro.core.pq import default_m_sub

        m_sub = default_m_sub(args.d)
        print(f"training PQ codebooks (m_sub={m_sub})...")
        pq_index = pq_train(jax.random.PRNGKey(4), corpus_p.vectors,
                            m_sub=m_sub, n_cent=256)
    search = make_distributed_search(mesh, params)

    total_q = 0
    t_start = time.perf_counter()
    with set_mesh(mesh):
        for b in range(args.batches):
            q, qlab = make_queries(jax.random.fold_in(jax.random.PRNGKey(2), b),
                                   corpus, args.batch)
            if args.constraint == "equal":
                cons = equal_constraint(qlab, args.labels)
            else:
                pct = float(args.constraint.split("-")[1])
                cons = unequal_pct_constraint(
                    jax.random.fold_in(jax.random.PRNGKey(3), b), qlab,
                    args.labels, pct,
                )
            res = (
                search(corpus_s, graph_s, q, cons, pq_index)
                if pq_index is not None
                else search(corpus_s, graph_s, q, cons)
            )
            jax.block_until_ready(res.dists)
            total_q += args.batch
            filled = float(jnp.mean(jnp.sum(res.ids >= 0, axis=-1)))
            print(f"batch {b}: filled {filled:.1f}/{args.k}, "
                  f"mean dist-evals {float(jnp.mean(res.stats.dist_evals)):.0f}")
    dt = time.perf_counter() - t_start
    print(f"served {total_q} queries in {dt:.2f}s = {total_q/dt:.0f} QPS "
          f"(single-core host; see EXPERIMENTS.md §Roofline for TPU projection)")


if __name__ == "__main__":
    main()
