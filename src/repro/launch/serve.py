"""Online serving driver: Poisson-arrival mixed constrained workload.

Thin front over the serving runtime (repro.serving, DESIGN.md §7): builds
an index, then streams individual constrained queries — each with its own
k, constraint family/operand (equal / unequal-X% label sets and numeric
ranges in one stream), and Poisson arrival time — through the dynamic
batcher, shape-bucketed compile cache, and adaptive escalation controller,
and prints the telemetry summary (QPS, latency percentiles, fill, cache
hit rate).

Reduced CPU run:
    PYTHONPATH=src python -m repro.launch.serve --n 20000 --requests 256

Distributed path (scatter-search-merge over the mesh) and PQ/ADC traversal:
    PYTHONPATH=src python -m repro.launch.serve --distributed --approx pq

HTTP front-end (DESIGN.md §12) — wall-clock runtime behind a real socket:
    PYTHONPATH=src python -m repro.launch.serve --serve-http 8080 \
        --log-json serve_log.jsonl
    curl -s localhost:8080/metrics | head

Multi-replica tier (DESIGN.md §13) — N shared-nothing runtimes behind one
front-end, hash- or load-routed, with /metrics labeled per replica:
    PYTHONPATH=src python -m repro.launch.serve --serve-http 8080 \
        --replicas 4 --router hash
"""
from __future__ import annotations

import argparse
import json
import threading

import jax

from repro.data.synthetic import make_labeled_corpus
from repro.graph.index import build_index, build_partitioned_index
from repro.serving import (
    LocalExecutor,
    ServingRuntime,
    VirtualClock,
    make_tier_ladder,
    mixed_workload,
    replay_poisson,
)


def build_runtime(args, corpus, clock, prebuilt_graph=None, replica_id=None):
    """Executor + runtime for either the local or the distributed path.

    ``prebuilt_graph`` shares one (read-only) static graph build across
    replicas; each replica still gets its OWN executor, compile cache and
    (for churn) its own mutable ``StreamingIndex`` slot pool —
    shared-nothing everywhere state can change."""

    def train_pq(vectors):
        # Codes are row-aligned with the corpus the executor serves, so the
        # distributed path trains on the PARTITIONED (padded) corpus.
        from repro.core import pq_train
        from repro.core.pq import default_m_sub

        m_sub = default_m_sub(args.d)
        print(f"training PQ codebooks (m_sub={m_sub})...")
        return pq_train(jax.random.PRNGKey(4), vectors, m_sub=m_sub, n_cent=256)

    if args.churn > 0:
        if args.distributed or args.approx == "pq":
            raise SystemExit(
                "--churn serves through the streaming local executor "
                "(exact backend); drop --distributed/--approx pq"
            )
        from repro.serving import StreamingLocalExecutor
        from repro.streaming import StreamingIndex

        print("building streaming index (slot pool)...")
        graph = prebuilt_graph if prebuilt_graph is not None else build_index(
            jax.random.PRNGKey(1), corpus, degree=16, sample_size=512
        )
        index = StreamingIndex.from_static(
            corpus, graph, ef_insert=args.base_ef
        )
        executor = StreamingLocalExecutor(
            index, consolidate_after=args.consolidate_after
        )
    elif args.distributed:
        from repro.core import shard_corpus_for_mesh
        from repro.serving import DistributedExecutor

        n_dev = jax.device_count()
        model = min(4, n_dev)
        data = n_dev // model
        mesh = jax.make_mesh((data, model), ("data", "model"))
        print(f"mesh: {dict(mesh.shape)}")
        print("building partitioned index...")
        corpus_p, graph_p = build_partitioned_index(
            jax.random.PRNGKey(1), corpus, n_shards=model, degree=16,
            sample_size_per_shard=128,
        )
        corpus_s, graph_s = shard_corpus_for_mesh(corpus_p, graph_p, mesh)
        pq_index = train_pq(corpus_p.vectors) if args.approx == "pq" else None
        executor = DistributedExecutor(mesh, corpus_s, graph_s, pq_index)
    else:
        if prebuilt_graph is not None:
            graph = prebuilt_graph
        else:
            print("building index...")
            graph = build_index(
                jax.random.PRNGKey(1), corpus, degree=16, sample_size=512
            )
        pq_index = train_pq(corpus.vectors) if args.approx == "pq" else None
        executor = LocalExecutor(corpus, graph, pq_index)

    tiers = make_tier_ladder(
        k_cap=args.k_cap,
        base_ef=args.base_ef,
        base_iters=args.base_iters,
        n_tiers=2,
    )
    if args.approx == "pq" or args.fuse != "auto":
        import dataclasses

        tiers = tuple(
            dataclasses.replace(t, approx=args.approx, fuse_expand=args.fuse)
            for t in tiers
        )
    if args.inject_faults > 0:
        from repro.serving import (
            FaultClock,
            FaultConfig,
            FaultSchedule,
            FaultyExecutor,
        )

        fault_clock = FaultClock(clock)
        schedule = FaultSchedule(FaultConfig(
            seed=21,
            error_rate=args.inject_faults,
            spike_rate=args.inject_faults,
            spike_s=(args.deadline_ms / 2000.0) if args.deadline_ms > 0
            else 0.05,
            stale_epoch_rate=args.inject_faults if args.churn > 0 else 0.0,
        ))
        executor = FaultyExecutor(executor, schedule, fault_clock)
        clock = fault_clock

    slo_cfg = None
    if args.slo:
        from repro.serving import SLOConfig

        slo_cfg = SLOConfig(
            target_latency=(args.deadline_ms / 1000.0)
            if args.deadline_ms > 0 else 0.05,
            queue_high=max(8, args.max_pending // 4),
            queue_low=max(4, args.max_pending // 16),
        )

    ladder = tuple(int(b) for b in args.ladder.split(","))
    runtime = ServingRuntime(
        executor,
        n_labels=args.labels,
        tiers=tiers,
        ladder=ladder,
        families=("label", "range"),
        max_wait=args.max_wait,
        max_pending=args.max_pending,
        clock=clock,
        slo=slo_cfg,
        shed_expired=args.slo,
        replica_id=replica_id,
    )
    if args.hybrid:
        if args.distributed:
            raise SystemExit(
                "--hybrid needs host-side posting lists; the distributed "
                "executor is graph-only for now (drop --distributed)"
            )
        from repro.serving import make_serving_router

        runtime.router = make_serving_router(
            executor, n_labels=args.labels, controller=runtime.controller
        )
    return runtime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--labels", type=int, default=10)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--rate", type=float, default=2000.0,
                    help="Poisson arrival rate (requests/s of virtual time)")
    ap.add_argument("--k-cap", type=int, default=16)
    ap.add_argument("--ladder", default="8,32,128",
                    help="comma batch-bucket ladder")
    ap.add_argument("--base-ef", type=int, default=64)
    ap.add_argument("--base-iters", type=int, default=128,
                    help="tier-0 max_iters (escalation tier gets 4x)")
    ap.add_argument("--max-wait", type=float, default=0.005,
                    help="batcher flush timeout (s)")
    ap.add_argument("--max-pending", type=int, default=1024,
                    help="admission-queue bound (backpressure)")
    ap.add_argument("--distributed", action="store_true",
                    help="serve through the scatter-search-merge mesh path")
    ap.add_argument("--churn", type=float, default=0.0,
                    help="fraction of the stream that is upsert/delete "
                    "traffic against the streaming mutable index (0 = "
                    "static index; try 0.3 to replay the churn workload)")
    ap.add_argument("--consolidate-after", type=int, default=64,
                    help="pending tombstones that trigger a background "
                    "consolidation pass at the next flush boundary")
    ap.add_argument(
        "--approx", default="exact", choices=("exact", "pq"),
        help="distance backend for the walk: exact rows or PQ/ADC codes "
        "(trains a PQ index on the corpus; exact re-rank post-loop)",
    )
    ap.add_argument(
        "--hybrid", action="store_true",
        help="selectivity-adaptive execution (DESIGN.md §9): a per-query "
        "strategy router estimates constraint selectivity from incremental "
        "histograms and dispatches each request to the graph walk, a "
        "brute-force posting-set scan, or a cached label-subgraph overlay",
    )
    ap.add_argument(
        "--slo", action="store_true",
        help="fault-tolerant serving under SLO (DESIGN.md §10): expired "
        "requests are shed at flush time instead of served late, and a "
        "hysteretic degradation ladder caps tiers / prefers cheap "
        "strategies / predictively sheds as overload deepens",
    )
    ap.add_argument(
        "--deadline-ms", type=float, default=0.0,
        help="per-query deadline in virtual-time milliseconds (0 = no "
        "deadline); with --slo, expired requests are shed with a pollable "
        "shed_reason instead of completing late",
    )
    ap.add_argument(
        "--inject-faults", type=float, default=0.0,
        help="seeded fault-injection rate (per compiled dispatch: this "
        "probability each of an executor error and a latency spike; with "
        "--churn also a stale-epoch rate per refresh). Exercises the "
        "retry-within-budget and failed-Response recovery paths",
    )
    ap.add_argument(
        "--fuse", default="auto", choices=("auto", "on", "off"),
        help="fused candidate pipeline (kernels/fused_expand; 'on' forces "
        "the one-pass gather+distance+constraint+visited kernel for either "
        "backend, applied to every serving tier)",
    )
    ap.add_argument(
        "--serve-http", type=int, default=None, metavar="PORT",
        help="instead of replaying a synthetic stream, serve over HTTP "
        "(DESIGN.md §12): POST /v1/search /v1/upsert /v1/delete, GET "
        "/metrics (Prometheus text), /healthz, /varz. Runs on the wall "
        "clock; Ctrl-C drains in-flight work and exits. Port 0 picks a "
        "free port",
    )
    ap.add_argument(
        "--replicas", type=int, default=1, metavar="N",
        help="shared-nothing runtime replicas behind the HTTP front-end "
        "(DESIGN.md §13): each gets its own compile cache, controller, "
        "batcher, pump thread, and (with --churn) slot pool; mutations "
        "broadcast to all at one enqueue boundary. Needs --serve-http",
    )
    ap.add_argument(
        "--router", default="hash", choices=("hash", "least-loaded"),
        help="replica router: consistent-hash by request key (compile-"
        "cache affinity, deterministic) or least-loaded by pending depth",
    )
    ap.add_argument(
        "--log-json", default=None, metavar="PATH",
        help="structured JSON request logs (admit/dispatch/complete/shed "
        "records with req_id/batch_id/epoch) buffered in a bounded ring "
        "and flushed to PATH at shutdown",
    )
    args = ap.parse_args()
    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.replicas > 1 and args.serve_http is None:
        raise SystemExit("--replicas N needs --serve-http (the replica "
                         "tier lives behind the HTTP front-end)")
    if args.replicas > 1 and args.distributed:
        raise SystemExit("--replicas replicates the local executor; the "
                         "mesh path is single-tier (drop --distributed)")

    corpus = make_labeled_corpus(
        jax.random.PRNGKey(0), n=args.n, d=args.d, n_labels=args.labels
    )
    corpus = corpus.replace(
        attrs=jax.random.uniform(jax.random.PRNGKey(5), (args.n, 2))
    )

    # HTTP mode serves real clients, so it runs on the wall clock; replay
    # mode keeps the deterministic virtual timeline.
    if args.serve_http is not None:
        from repro.serving import wall_clock

        clock = wall_clock
    else:
        clock = VirtualClock()
    if args.replicas > 1:
        from repro.serving import ReplicaSet, make_replica_router

        print(f"building index (shared across {args.replicas} replicas)...")
        shared_graph = build_index(
            jax.random.PRNGKey(1), corpus, degree=16, sample_size=512
        )
        runtime = ReplicaSet(
            [
                build_runtime(
                    args, corpus, clock,
                    prebuilt_graph=shared_graph, replica_id=i,
                )
                for i in range(args.replicas)
            ],
            router=make_replica_router(args.router, args.replicas),
        )
        trace_budget = runtime.replicas[0].trace_budget
    else:
        runtime = build_runtime(args, corpus, clock)
        trace_budget = runtime.trace_budget
    logger = None
    if args.log_json is not None:
        from repro.obs import JsonLogger

        # Single-runtime path keeps the runtime's own clock (build_runtime
        # may have wrapped it in a FaultClock); tier children bind their
        # replica's clock in attach_logger.
        logger = JsonLogger(
            clock=clock if args.replicas > 1 else runtime.clock
        )
        if args.replicas > 1:
            runtime.attach_logger(logger)
        else:
            runtime.logger = logger
    print(f"warming compile cache ({trace_budget} bucket shapes"
          + (f" x {args.replicas} replicas" if args.replicas > 1 else "")
          + ")...")
    compiled = runtime.warmup()

    if args.serve_http is not None:
        import signal

        from repro.obs.http import ServingFrontend

        frontend = ServingFrontend(runtime, logger=logger, port=args.serve_http)
        addr = frontend.start()
        print(f"compiled {compiled} closures; serving on {addr}")
        print(f"replicas: {frontend.n_replicas} (router "
              f"{runtime.router.name if args.replicas > 1 else 'n/a'})")
        print("routes: POST /v1/search /v1/upsert /v1/delete | "
              "GET /metrics /healthz /varz "
              "(SIGINT/SIGTERM drains and exits)")
        # Explicit handlers: a supervisor (or a non-interactive shell that
        # spawned us with SIGINT ignored) sends SIGTERM — both signals must
        # take the same graceful drain-and-flush path as a TTY Ctrl-C.
        stop = threading.Event()
        for sig in (signal.SIGINT, signal.SIGTERM):
            signal.signal(sig, lambda *_: stop.set())
        stop.wait()
        print("draining...")
        report = frontend.close(drain=True, log_path=args.log_json)
        print(json.dumps({"shutdown": report}, indent=2))
        return

    print(f"compiled {compiled} closures; serving {args.requests} requests "
          f"at Poisson rate {args.rate}/s...")

    k_choices = tuple(sorted({min(4, args.k_cap), min(8, args.k_cap),
                              args.k_cap}))
    deadline_s = args.deadline_ms / 1000.0 if args.deadline_ms > 0 else None
    retry = None
    if args.slo:
        from repro.serving import RetryPolicy

        retry = RetryPolicy()  # backpressure rejections retry with backoff
    if args.churn > 0:
        from repro.serving import churn_workload, replay_churn

        items = churn_workload(
            7, corpus, args.requests, args.labels,
            mutation_frac=args.churn, k_choices=k_choices,
        )
        responses, rejected = replay_churn(
            runtime, items, rate=args.rate, seed=11,
            deadline_s=deadline_s, retry=retry,
        )
    else:
        items = mixed_workload(
            7, corpus, args.requests, args.labels, k_choices=k_choices,
        )
        responses, rejected = replay_poisson(
            runtime, items, rate=args.rate, seed=11,
            deadline_s=deadline_s, retry=retry,
        )

    report = runtime.report()
    print(json.dumps(report, indent=2, default=str))
    served = [r for r in responses if r is not None]
    mean_fill = (
        sum(r.fill_frac for r in served) / len(served) if served else 0.0
    )
    print(
        f"served {len(served)}/{len(items)} requests "
        f"({rejected} rejected by backpressure) | "
        f"qps {report['telemetry'].get('qps', 0)} | mean fill {mean_fill:.3f} "
        f"| cache hit rate {report['cache']['hit_rate']} "
        f"(single-core host; see EXPERIMENTS.md §Roofline for TPU projection)"
    )
    if args.slo or args.inject_faults > 0:
        counters = report["telemetry"]  # summary() flattens the counters
        goodput = sum(
            1 for r in served
            if r.ok and not r.deadline_missed and r.filled > 0
        )
        print(
            f"slo: goodput {goodput} | shed {counters.get('shed_total', 0)} "
            f"(expired {counters.get('shed_expired', 0)}, overload "
            f"{counters.get('shed_overload', 0)}) | "
            f"failed {counters.get('failed', 0)} | "
            f"fault retries {counters.get('fault_retries', 0)} | "
            f"degradation level {runtime.controller.degradation_level}"
        )
    if logger is not None:
        n = logger.flush_to_path(args.log_json)
        print(f"flushed {n} structured log records to {args.log_json}")


if __name__ == "__main__":
    main()
