# Online serving runtime over the constrained-search engine (DESIGN.md §7):
# dynamic batcher (bucket-ladder shapes), shape-bucketed compile cache with a
# hard trace budget, adaptive tier controller with under-fill escalation, and
# the submit/poll runtime front with backpressure + telemetry.
from repro.serving.batcher import BATCH_LADDER, DynamicBatcher, MicroBatch, bucket_for
from repro.serving.cache import CompileCache, TraceBudgetError
from repro.serving.controller import (
    AdaptiveController,
    ControllerConfig,
    make_tier_ladder,
)
from repro.serving.runtime import (
    DistributedExecutor,
    EpochRangeView,
    LocalExecutor,
    ServingRuntime,
    StreamingLocalExecutor,
    assemble_constraint,
    assemble_queries,
    make_serving_router,
)
from repro.serving.telemetry import Telemetry, percentile
from repro.serving.types import (
    MUTATION_FAMILIES,
    AdmissionError,
    DeleteRequest,
    Request,
    Response,
    UpsertRequest,
    VirtualClock,
    wall_clock,
)
from repro.serving.workload import (
    WorkItem,
    churn_workload,
    label_words_row,
    mixed_workload,
    replay_churn,
    replay_poisson,
)

__all__ = [
    "AdaptiveController",
    "AdmissionError",
    "BATCH_LADDER",
    "CompileCache",
    "ControllerConfig",
    "DeleteRequest",
    "DistributedExecutor",
    "DynamicBatcher",
    "EpochRangeView",
    "LocalExecutor",
    "MUTATION_FAMILIES",
    "MicroBatch",
    "Request",
    "Response",
    "ServingRuntime",
    "StreamingLocalExecutor",
    "Telemetry",
    "TraceBudgetError",
    "UpsertRequest",
    "VirtualClock",
    "WorkItem",
    "assemble_constraint",
    "assemble_queries",
    "bucket_for",
    "churn_workload",
    "label_words_row",
    "make_serving_router",
    "make_tier_ladder",
    "mixed_workload",
    "percentile",
    "replay_churn",
    "replay_poisson",
    "wall_clock",
]
