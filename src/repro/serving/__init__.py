# Online serving runtime over the constrained-search engine (DESIGN.md §7):
# dynamic batcher (bucket-ladder shapes), shape-bucketed compile cache with a
# hard trace budget, adaptive tier controller with under-fill escalation, and
# the submit/poll runtime front with backpressure + telemetry. PR 7 layers
# fault tolerance on top (DESIGN.md §10): deadline enforcement + load
# shedding, the SLO degradation ladder, client retry policy, and seeded
# fault injection.
from repro.serving.batcher import BATCH_LADDER, DynamicBatcher, MicroBatch, bucket_for
from repro.serving.cache import CompileCache, TraceBudgetError
from repro.serving.controller import (
    AdaptiveController,
    ControllerConfig,
    make_tier_ladder,
)
from repro.serving.faults import (
    ExecutorFault,
    FaultClock,
    FaultConfig,
    FaultSchedule,
    FaultyExecutor,
    InjectedFault,
)
from repro.serving.replicas import (
    ROUTER_KINDS,
    ConsistentHashRouter,
    LeastLoadedRouter,
    ReplicaSet,
    make_replica_router,
)
from repro.serving.retry import RetryPolicy, submit_with_retry
from repro.serving.slo import DegradationLadder, SLOConfig
from repro.serving.runtime import (
    DistributedExecutor,
    EpochRangeView,
    LocalExecutor,
    ServingRuntime,
    StreamingLocalExecutor,
    assemble_constraint,
    assemble_queries,
    make_serving_router,
)
from repro.serving.telemetry import LatencyHistogram, Telemetry, percentile
from repro.serving.types import (
    MUTATION_FAMILIES,
    AdmissionError,
    DeleteRequest,
    Request,
    Response,
    UpsertRequest,
    VirtualClock,
    deadline_due,
    deadline_missed,
    wall_clock,
)
from repro.serving.workload import (
    WorkItem,
    churn_workload,
    label_words_row,
    mixed_workload,
    poisson_arrivals,
    replay_churn,
    replay_poisson,
)

__all__ = [
    "AdaptiveController",
    "AdmissionError",
    "BATCH_LADDER",
    "CompileCache",
    "ConsistentHashRouter",
    "ControllerConfig",
    "DegradationLadder",
    "DeleteRequest",
    "DistributedExecutor",
    "DynamicBatcher",
    "EpochRangeView",
    "ExecutorFault",
    "FaultClock",
    "FaultConfig",
    "FaultSchedule",
    "FaultyExecutor",
    "InjectedFault",
    "LatencyHistogram",
    "LeastLoadedRouter",
    "LocalExecutor",
    "MUTATION_FAMILIES",
    "MicroBatch",
    "ROUTER_KINDS",
    "ReplicaSet",
    "Request",
    "Response",
    "RetryPolicy",
    "SLOConfig",
    "ServingRuntime",
    "StreamingLocalExecutor",
    "Telemetry",
    "TraceBudgetError",
    "UpsertRequest",
    "VirtualClock",
    "WorkItem",
    "assemble_constraint",
    "assemble_queries",
    "bucket_for",
    "churn_workload",
    "deadline_due",
    "deadline_missed",
    "label_words_row",
    "make_replica_router",
    "make_serving_router",
    "make_tier_ladder",
    "mixed_workload",
    "percentile",
    "poisson_arrivals",
    "replay_churn",
    "replay_poisson",
    "submit_with_retry",
    "wall_clock",
]
