"""Online serving runtime: submit/poll front over batcher + cache + controller.

``ServingRuntime`` is the event loop gluing the subsystem together
(DESIGN.md §7): ``submit`` admits one constrained query (its own k,
constraint operand, deadline) under a bounded admission queue
(backpressure — ``AdmissionError`` when full), ``step`` flushes due
microbatches through the shape-bucketed compile cache and routes
under-filled results back through the controller's escalation tiers, and
``poll``/``drain`` hand completed ``Response`` records back to the caller.

The runtime is single-threaded and clock-injectable: drivers decide when
``step`` runs (serve loop, bench replay, tests with a fake clock). Search
execution is pluggable via an *executor* that builds one compiled closure
per (bucket, family, tier) key:

  * ``LocalExecutor`` — single-process ``build_context`` +
    ``search_with_context`` over an in-memory index; counts actual jit
    traces, so tests can assert the trace budget against reality.
  * ``DistributedExecutor`` — ``make_distributed_search`` over a sharded
    corpus/graph (the scatter-search-merge path), uniform ``pq_index``
    payload.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.compat import set_mesh
from repro.core import build_context, make_distributed_search, search_with_context
from repro.core.constraints import WORD_BITS, LabelSetConstraint, RangeConstraint
from repro.core.estimator import SelectivityEstimator
from repro.core.histogram import AttributeHistograms
from repro.core.overlay import OverlayCache, build_overlay, overlay_search
from repro.core.posting import (
    PostingLists,
    RangeIndex,
    pad_posting,
    posting_bucket,
    posting_search,
)
from repro.core.router import RouterConfig, StrategyRouter
from repro.core.types import Corpus, GraphIndex, SearchParams, SearchResult
from repro.obs.logs import JsonLogger
from repro.obs.tracing import RequestTrace
from repro.serving.batcher import BATCH_LADDER, DynamicBatcher, MicroBatch
from repro.serving.cache import CompileCache
from repro.serving.controller import AdaptiveController, make_tier_ladder
from repro.serving.faults import ExecutorFault
from repro.serving.slo import SLOConfig
from repro.serving.telemetry import Telemetry
from repro.serving.types import (
    MUTATION_FAMILIES,
    AdmissionError,
    DeleteRequest,
    Request,
    Response,
    UpsertRequest,
    cpu_clock,
    deadline_missed,
    wall_clock,
)

Array = jax.Array


# ---------------------------------------------------------------------------
# microbatch -> traced arrays
# ---------------------------------------------------------------------------


def assemble_queries(mb: MicroBatch, dim: int) -> Array:
    rows = [np.asarray(r.query, dtype=np.float32).reshape(dim) for r in mb.requests]
    rows.extend([rows[-1]] * mb.n_padded)  # pad = repeat last real lane
    return jnp.asarray(np.stack(rows), dtype=jnp.float32)


def assemble_constraint(mb: MicroBatch):
    if mb.family == "label":
        words = [np.asarray(r.operand, dtype=np.uint32) for r in mb.requests]
        words.extend([words[-1]] * mb.n_padded)
        return LabelSetConstraint(words=jnp.asarray(np.stack(words), jnp.uint32))
    if mb.family == "range":
        lo = [float(r.operand[0]) for r in mb.requests]
        hi = [float(r.operand[1]) for r in mb.requests]
        lo.extend([lo[-1]] * mb.n_padded)
        hi.extend([hi[-1]] * mb.n_padded)
        return RangeConstraint(
            lo=jnp.asarray(lo, jnp.float32),
            hi=jnp.asarray(hi, jnp.float32),
            col=jnp.int32(mb.group[1]),
        )
    raise ValueError(f"unknown constraint family: {mb.family}")


# ---------------------------------------------------------------------------
# executors
# ---------------------------------------------------------------------------


class LocalExecutor:
    """Compiled fixed-shape closures over one in-memory (corpus, graph).

    ``traces`` counts *actual* jit traces (the impl body runs only while
    tracing), so the serving tests assert the bucket-ladder trace budget
    against jax's real behaviour, not just the cache's bookkeeping.
    """

    def __init__(self, corpus: Corpus, graph: GraphIndex, pq_index=None):
        self.corpus = corpus
        self.graph = graph
        self.pq_index = pq_index
        self.traces = 0

    @property
    def dim(self) -> int:
        return self.corpus.dim

    def build(
        self, bucket: int, family: str, params: SearchParams
    ) -> Callable[..., SearchResult]:
        del bucket, family  # fixed by the traced shapes themselves

        def impl(corpus, graph, queries, constraint, pq_index):
            self.traces += 1  # trace-time side effect: runs once per trace
            ctx = build_context(
                corpus, constraint, queries, params, pq_index,
                degree=graph.neighbors.shape[1],
            )
            return search_with_context(ctx, corpus, graph, queries, params)

        jitted = jax.jit(impl)

        def fn(queries: Array, constraint) -> SearchResult:
            return jitted(self.corpus, self.graph, queries, constraint, self.pq_index)

        return fn


class StreamingLocalExecutor:
    """Epoch-versioned closures over a mutable ``StreamingIndex``.

    The slot pool keeps every array shape static at the pool capacity, so
    ONE compiled closure per (bucket, family, tier) serves every epoch —
    mutations swap the snapshot the closure reads, never its shapes. The
    swap is explicit (``refresh``): the runtime calls it once per flush
    boundary after applying that flush's mutation microbatches, so every
    query batch of a flush runs against one epoch and queries already
    dispatched keep the epoch they started with.
    """

    def __init__(self, index, *, consolidate_after: int = 64):
        self.index = index
        # Background consolidation policy: splice tombstones out once this
        # many deletes are pending (0 disables auto-consolidation).
        self.consolidate_after = int(consolidate_after)
        self.traces = 0
        self.snapshot = index.snapshot()

    @property
    def dim(self) -> int:
        return self.index.dim

    @property
    def epoch(self) -> int:
        return self.snapshot.epoch

    def refresh(self) -> int:
        """Publish the latest epoch (running consolidation when due) and
        atomically swap the snapshot future dispatches will read."""
        if (
            self.consolidate_after
            and self.index.pool.n_pending >= self.consolidate_after
        ):
            self.index.consolidate()
        self.snapshot = self.index.snapshot()
        return self.snapshot.epoch

    def apply_mutations(self, requests: Sequence[Request]) -> list:
        """Host-side mutation application; returns one (ok, slot) per
        request. The new epoch is NOT published here — ``refresh`` does
        that once per flush boundary. A request that cannot apply (e.g.
        pool exhaustion after an emergency consolidation) reports
        ``ok=False`` instead of raising: an exception mid-batch would
        strand the batch's requests in the runtime's in-flight count.
        """
        out = []
        for req in requests:
            if isinstance(req, UpsertRequest):
                label, attrs = req.operand
                try:
                    if self.index.pool.n_free == 0 and self.index.pool.n_pending:
                        # Emergency reclaim: trade one early consolidation
                        # for not shedding the insert.
                        self.index.consolidate()
                    slot = self.index.insert(req.query, label=label, attrs=attrs)
                    out.append((True, slot))
                except RuntimeError:  # pool exhausted, nothing reclaimable
                    out.append((False, -1))
            elif isinstance(req, DeleteRequest):
                slot = int(req.operand)
                out.append((self.index.delete(slot), slot))
            else:
                raise TypeError(f"not a mutation request: {type(req)}")
        return out

    def build(
        self, bucket: int, family: str, params: SearchParams
    ) -> Callable[..., SearchResult]:
        del bucket, family  # fixed by the traced shapes themselves

        def impl(corpus, graph, queries, constraint):
            self.traces += 1  # trace-time side effect: runs once per trace
            ctx = build_context(
                corpus, constraint, queries, params, None,
                degree=graph.neighbors.shape[1],
            )
            return search_with_context(ctx, corpus, graph, queries, params)

        jitted = jax.jit(impl)

        def fn(queries: Array, constraint) -> SearchResult:
            snap = self.snapshot  # the epoch pinned at dispatch time
            return jitted(snap.corpus, snap.graph, queries, constraint)

        return fn


class DistributedExecutor:
    """Scatter-search-merge closures over a mesh-sharded index.

    One ``make_distributed_search`` per (family, tier) x bucket shape; the
    uniform trailing ``pq_index`` payload (None for exact) means no
    per-backend call branching here either.
    """

    def __init__(self, mesh, corpus_s: Corpus, graph_s: GraphIndex, pq_index=None):
        self.mesh = mesh
        self.corpus_s = corpus_s
        self.graph_s = graph_s
        self.pq_index = pq_index

    @property
    def dim(self) -> int:
        return self.corpus_s.dim

    def build(
        self, bucket: int, family: str, params: SearchParams
    ) -> Callable[..., SearchResult]:
        del bucket
        ctype = LabelSetConstraint if family == "label" else RangeConstraint
        search = make_distributed_search(self.mesh, params, constraint_type=ctype)

        def fn(queries: Array, constraint) -> SearchResult:
            with set_mesh(self.mesh):
                return search(
                    self.corpus_s, self.graph_s, queries, constraint, self.pq_index
                )

        return fn


# ---------------------------------------------------------------------------
# hybrid routing plumbing (DESIGN.md §9)
# ---------------------------------------------------------------------------


class EpochRangeView:
    """Range-posting view over a streaming index that re-sorts lazily at
    each epoch — the router's applicability gate and the posting scan both
    read through this, so neither ever sees a stale sort order."""

    def __init__(self, index):
        self._index = index

    def _fresh(self):
        idx = self._index
        if idx.pool.attrs is not None:
            idx.range_index.refresh(
                idx.pool.attrs, idx.pool.live_mask(), idx.epoch
            )
        return idx.range_index

    def count_range(self, lo, hi, col) -> int:
        return self._fresh().count_range(lo, hi, col)

    def ids_for_range(self, lo, hi, col) -> np.ndarray:
        return self._fresh().ids_for_range(lo, hi, col)


def make_serving_router(
    executor,
    n_labels: int,
    config: Optional[RouterConfig] = None,
    controller: Optional[AdaptiveController] = None,
) -> StrategyRouter:
    """Wire a ``StrategyRouter`` to an executor's index state.

    Streaming executors share the index's incrementally-maintained
    histograms/postings (exact at every epoch); static ``LocalExecutor``s
    get one-shot structures built from the corpus. The distributed executor
    is graph-only for now (posting gathers against a sharded corpus need
    per-shard postings — ROADMAP).
    """
    if hasattr(executor, "apply_mutations"):  # streaming
        index = executor.index
        estimator = SelectivityEstimator(histograms=index.histograms)
        return StrategyRouter(
            estimator,
            n=index.capacity,
            config=config,
            postings=index.postings,
            range_index=EpochRangeView(index),
            controller=controller,
        )
    if not hasattr(executor, "corpus"):
        raise TypeError(
            f"hybrid routing needs a local or streaming executor; "
            f"have {type(executor).__name__}"
        )
    corpus = executor.corpus
    labels = np.asarray(corpus.labels)
    attrs = None if corpus.attrs is None else np.asarray(corpus.attrs)
    hist = AttributeHistograms.from_arrays(labels, attrs, n_labels=n_labels)
    postings = PostingLists.from_arrays(labels, n_labels=n_labels)
    range_index = RangeIndex()
    if attrs is not None:
        range_index.refresh(attrs, np.ones((labels.shape[0],), bool), 0)
    graph = getattr(executor, "graph", None)
    estimator = SelectivityEstimator(
        histograms=hist,
        corpus=corpus,
        sample_ids=None if graph is None else graph.sample_ids,
    )
    return StrategyRouter(
        estimator,
        n=int(labels.shape[0]),
        config=config,
        postings=postings,
        range_index=range_index,
        controller=controller,
    )


# ---------------------------------------------------------------------------
# the runtime
# ---------------------------------------------------------------------------


class ServingRuntime:
    def __init__(
        self,
        executor,
        *,
        n_labels: int,
        tiers: Optional[Tuple[SearchParams, ...]] = None,
        ladder: Tuple[int, ...] = BATCH_LADDER,
        families: Sequence[str] = ("label", "range"),
        max_wait: float = 0.002,
        max_pending: int = 1024,
        controller: Optional[AdaptiveController] = None,
        clock: Optional[Callable[[], float]] = None,
        router: Optional[StrategyRouter] = None,
        max_overlays: int = 8,
        slo: Optional[SLOConfig] = None,
        shed_expired: bool = True,
        max_fault_retries: int = 2,
        tracing: bool = True,
        logger: Optional[JsonLogger] = None,
        replica_id: Optional[int] = None,
    ):
        self.executor = executor
        self.n_labels = int(n_labels)
        # Which replica of a ReplicaSet this runtime is (None standalone);
        # stamped into every trace so a tier's spans are attributable.
        self.replica_id = replica_id
        tiers = tuple(tiers) if tiers is not None else make_tier_ladder()
        self.controller = controller or AdaptiveController(tiers, slo=slo)
        if slo is not None and self.controller.ladder is None:
            # A caller-supplied controller gains the ladder the runtime
            # was asked for (the ladder lives on the controller so
            # tier_for/escalate consult it without extra plumbing).
            from repro.serving.slo import DegradationLadder

            self.controller.ladder = DegradationLadder(slo)
        # Fault-tolerance policy (DESIGN.md §10): shed already-expired
        # requests at flush time instead of burning a search they cannot
        # use (shed_expired=False reproduces the pre-PR7 burn for A/B
        # benchmarking), and re-queue ExecutorFault-hit requests at most
        # this many times before surfacing a failed Response.
        self.shed_expired = bool(shed_expired)
        self.max_fault_retries = int(max_fault_retries)
        self.families = tuple(families)
        self.ladder = tuple(ladder)
        self.max_pending = int(max_pending)
        self.clock = clock or wall_clock
        self.batcher = DynamicBatcher(ladder=self.ladder, max_wait=max_wait)
        self.telemetry = Telemetry()
        # The declared trace budget: an arbitrary stream can reach at most
        # every (bucket, family, tier) combination.
        self.trace_budget = (
            len(self.ladder) * len(self.families) * len(self.controller.tiers)
        )
        self.cache = CompileCache(self._build_for_key, max_entries=self.trace_budget)
        # Completed-but-unpolled responses are bounded too: callers that
        # never poll must not grow the server (oldest evicted + counted).
        self._responses: Dict[int, Response] = {}
        self._max_unpolled = 4 * self.max_pending
        self._in_flight = 0
        self._next_id = 0
        # Cumulative dispatch CPU seconds charged to this runtime — one
        # charge per microbatch (queries and mutations), measured on the
        # dispatching thread's CPU clock, unlike the execute stage
        # histogram which charges wall batch duration to every member
        # request. This is the replica's true busy time — the cost it
        # would pay on its own core — and the scrape-side denominator
        # for tier scaling (see types.cpu_clock).
        self.busy_seconds = 0.0
        # Hybrid execution (opt-in; DESIGN.md §9): a router stamps each
        # request's strategy at admission and the pump dispatches posting /
        # overlay microbatches outside the graph compile cache (their jit
        # keys are shape-laddered independently). router=None reproduces
        # pre-hybrid behaviour exactly.
        self.router = router
        if router is not None and router.controller is None:
            router.controller = self.controller
        self.overlays = OverlayCache(max_overlays=max_overlays)
        # Observability (DESIGN.md §12): every admitted request carries a
        # clock-injected span recorder (tracing=False serves without the
        # per-request dict churn), structured events go to the optional
        # JSON logger (req_id/batch_id/epoch correlated), and microbatches
        # get monotonic dispatch ids for log<->Response correlation.
        self.tracing = bool(tracing)
        self.logger = logger
        if logger is not None and logger.clock is None:
            logger.clock = self.clock
        self._next_batch_id = 0

    def _log(self, event: str, **fields) -> None:
        if self.logger is not None:
            self.logger.log(event, **fields)

    # --- compile-cache plumbing ------------------------------------------
    def _build_for_key(self, key):
        bucket, family, tier = key
        return self.executor.build(bucket, family, self.controller.params_for(tier))

    def warmup(self) -> int:
        """Pre-trace every (bucket, family, tier) closure with dummy data,
        then zero the hit/miss counters — so steady-state serving reports
        pure-hit cache behaviour and no request pays a compile. Returns the
        number of closures compiled."""
        dim = self.executor.dim
        n_words = (self.n_labels + WORD_BITS - 1) // WORD_BITS
        # A fault-injecting executor is disarmed for the dummy dispatches:
        # warmup must neither fault nor consume the seeded schedule's draws.
        was_armed = getattr(self.executor, "armed", None)
        if was_armed is not None:
            self.executor.armed = False
        for family in self.families:
            for tier in range(len(self.controller.tiers)):
                for bucket in self.ladder:
                    fn = self.cache.get((bucket, family, tier))
                    queries = jnp.zeros((bucket, dim), jnp.float32)
                    if family == "label":
                        cons = LabelSetConstraint(
                            words=jnp.full((bucket, n_words), 0xFFFFFFFF, jnp.uint32)
                        )
                    else:
                        cons = RangeConstraint(
                            lo=jnp.full((bucket,), -1e30, jnp.float32),
                            hi=jnp.full((bucket,), 1e30, jnp.float32),
                            col=jnp.int32(0),
                        )
                    jax.block_until_ready(fn(queries, cons).dists)
        if was_armed is not None:
            self.executor.armed = was_armed
        compiled = self.cache.trace_count
        self.cache.reset_counters()
        return compiled

    # --- request front ----------------------------------------------------
    @property
    def in_flight(self) -> int:
        return self._in_flight

    def submit(
        self,
        query: np.ndarray,
        k: int,
        family: str,
        operand,
        deadline: Optional[float] = None,
    ) -> int:
        """Admit one constrained query; returns its request id.

        Raises ``AdmissionError`` when ``max_pending`` requests are already
        in flight — the bounded admission queue is the backpressure surface
        (callers shed or retry; the runtime never buffers unboundedly).
        """
        if family not in self.families:
            raise ValueError(f"family {family!r} not served (have {self.families})")
        if k > self.controller.k_cap:
            raise ValueError(f"k={k} exceeds the ladder's k cap {self.controller.k_cap}")
        ladder = self.controller.ladder
        degraded = ladder is not None and ladder.level > 0
        req = Request(
            req_id=self._next_id,
            query=np.asarray(query, dtype=np.float32),
            k=int(k),
            family=family,
            operand=operand,
            deadline=deadline,
            arrival_t=self.clock(),
            # tier_for consults the degradation ladder: base tier under
            # overload, the family default otherwise.
            tier=self.controller.tier_for(family),
            degraded=degraded,
        )
        if self.router is not None:
            prefer_cheap = ladder is not None and ladder.prefer_cheap
            decision = self.router.route(
                family, operand, prefer_cheap=prefer_cheap
            )
            req.strategy = decision.strategy
            req.est_selectivity = decision.est_selectivity
            req.sel_bucket = decision.bucket
            req.sel_source = decision.source
            req.overlay_label = decision.label
            self.telemetry.on_route(decision.strategy)
        return self._admit(req)

    def _admit(self, req: Request) -> int:
        if self._in_flight >= self.max_pending:
            self.telemetry.on_reject()
            raise AdmissionError(
                f"{self._in_flight} requests in flight >= max_pending="
                f"{self.max_pending}"
            )
        self._next_id += 1
        self._in_flight += 1
        self.telemetry.on_submit()
        if self.tracing:
            req.trace = RequestTrace(
                req.req_id, req.arrival_t, replica=self.replica_id
            )
            req.trace.mark(f"route:{req.strategy}", req.arrival_t)
        self._log(
            "admit",
            req_id=req.req_id,
            family=req.family,
            strategy=req.strategy,
            tier=req.tier,
            k=req.k,
        )
        self.batcher.add(req, req.arrival_t)
        return req.req_id

    def submit_upsert(
        self, vector: np.ndarray, label: int = 0, attrs=None
    ) -> int:
        """Admit one insert for the streaming index; returns its request id.

        The response's ``ids[0]`` is the assigned slot id. Requires a
        streaming executor (one exposing ``apply_mutations``). Predictable
        failures are rejected HERE (bad shape) or reported as a failed
        response (pool exhaustion) — they must never escape mid-flush and
        corrupt the runtime's in-flight accounting.
        """
        self._require_streaming()
        vec = np.asarray(vector, dtype=np.float32)
        if vec.size != self.executor.dim:
            raise ValueError(
                f"upsert vector has {vec.size} elements, index dim is "
                f"{self.executor.dim}"
            )
        return self._admit(
            UpsertRequest(
                req_id=self._next_id,
                query=vec.reshape(self.executor.dim),
                k=1,
                family="upsert",
                operand=(int(label), attrs),
                arrival_t=self.clock(),
            )
        )

    def submit_delete(self, slot: int) -> int:
        """Admit one tombstone delete; the response's ``filled`` is 1 iff
        the slot was live (idempotent otherwise)."""
        self._require_streaming()
        slot = int(slot)
        if not 0 <= slot < self.executor.index.capacity:
            raise ValueError(
                f"slot {slot} outside the pool [0, "
                f"{self.executor.index.capacity})"
            )
        return self._admit(
            DeleteRequest(
                req_id=self._next_id,
                query=np.zeros((0,), np.float32),
                k=1,
                family="delete",
                operand=slot,
                arrival_t=self.clock(),
            )
        )

    def _require_streaming(self) -> None:
        if not hasattr(self.executor, "apply_mutations"):
            raise TypeError(
                "mutations need a streaming executor "
                "(StreamingLocalExecutor over a StreamingIndex); "
                f"have {type(self.executor).__name__}"
            )

    def poll(self, req_id: int) -> Optional[Response]:
        """Completed response for ``req_id`` (popped), or None if pending."""
        return self._responses.pop(req_id, None)

    # --- the pump ---------------------------------------------------------
    def step(self, force: bool = False) -> int:
        """Flush and execute every microbatch due now; returns completions.

        Flush-boundary epoch semantics (streaming executors): the flush's
        mutation microbatches are applied FIRST, then the executor swaps in
        the new index epoch exactly once, then every query microbatch of
        the flush runs against that one snapshot. Queries already executing
        hold the snapshot they were dispatched with; nothing observes a
        half-applied flush.

        Fault-tolerance order of operations (DESIGN.md §10): the load
        sample feeds the degradation ladder BEFORE this flush executes
        (the level must reflect the queue the flush is about to face),
        query microbatches run earliest-deadline-first, and each batch is
        stripped of already-expired (and, at ladder level 3, provably
        unmeetable) requests before any compute is spent on it.
        """
        self.controller.observe_load(self.batcher.pending_count())
        done = 0
        t_flush = self.clock()
        batches = self.batcher.flush(t_flush, force=force)
        for mb in batches:
            mb.batch_id = self._next_batch_id
            self._next_batch_id += 1
            for r in mb.requests:
                if r.trace is not None:
                    # Span accounting at the flush boundary: everything
                    # since (re-)enqueue was batcher queue wait.
                    r.trace.on_flush(r.enqueue_t, t_flush)
        mutations = [mb for mb in batches if mb.family in MUTATION_FAMILIES]
        queries = [mb for mb in batches if mb.family not in MUTATION_FAMILIES]
        applied: list = []
        for mb in mutations:
            applied.extend(self._execute_mutation(mb))
        if mutations:
            epoch = self.executor.refresh()  # the atomic epoch swap
            self.telemetry.on_epoch_swap()
            self._log("epoch_swap", epoch=epoch)
            self._drain_executor_faults()  # a stale-epoch injection counts
            if self.router is not None:
                # Overlay hotness re-accumulates per epoch; the overlay
                # cache itself invalidates on epoch mismatch at get().
                self.router.on_epoch(epoch)
            for resp in applied:
                # The first epoch this mutation is visible in — queries
                # with Response.epoch >= this one see its effect.
                resp.epoch = epoch
        done += len(applied)
        # Earliest-deadline-first across the flush's query batches: when
        # the flush holds more work than the deadline budget, the batches
        # that can still win execute before the ones that already lost.
        queries.sort(key=self._batch_deadline)
        for mb in queries:
            done += self._shed_due(mb)
            if mb.requests:
                done += self._execute(mb)
        return done

    @staticmethod
    def _batch_deadline(mb: MicroBatch) -> float:
        return min(
            (r.deadline for r in mb.requests if r.deadline is not None),
            default=float("inf"),
        )

    def _shed_due(self, mb: MicroBatch) -> int:
        """Drop this batch's hopeless requests before dispatch: expired
        ones always (``shed_expired``), predicted-unmeetable ones at
        ladder level 3. Returns the number shed; ``mb.requests`` keeps
        only the live ones (the bucket stays — padding just grows)."""
        if not self.shed_expired:
            return 0
        now = self.clock()
        ladder = self.controller.ladder
        predict = ladder is not None and ladder.shed_predicted
        live: List[Request] = []
        shed = 0
        for req in mb.requests:
            if deadline_missed(req.deadline, now):
                self._shed(req, "expired", now, batch_id=mb.batch_id)
                shed += 1
            elif predict and ladder.predicted_miss(req.deadline, now):
                self._shed(req, "overload", now, batch_id=mb.batch_id)
                shed += 1
            else:
                live.append(req)
        mb.requests = live
        return shed

    def _shed(
        self, req: Request, reason: str, now: float, batch_id: int = -1
    ) -> None:
        """Terminal shed: a pollable empty Response with ``shed_reason``
        set — the request is accounted, never silently dropped, and never
        burns a search."""
        self._bound_unpolled()
        resp = Response(
            req_id=req.req_id,
            ids=np.full((req.k,), -1, np.int32),
            dists=np.full((req.k,), np.inf, np.float32),
            k=req.k,
            filled=0,
            tier=req.tier,
            escalations=req.escalations,
            fill_history=req.fill_history + (0,),
            arrival_t=req.arrival_t,
            complete_t=now,
            deadline_missed=deadline_missed(req.deadline, now),
            epoch=getattr(self.executor, "epoch", None),
            strategy=req.strategy,
            est_selectivity=req.est_selectivity,
            shed_reason=reason,
            degraded=req.degraded,
            trace=(
                req.trace.breakdown(now, outcome="shed")
                if req.trace is not None
                else None
            ),
            batch_id=batch_id,
        )
        self._responses[req.req_id] = resp
        self._in_flight -= 1
        self.telemetry.on_shed(resp)
        self._log(
            "shed", req_id=req.req_id, reason=reason, batch_id=batch_id
        )

    def _drain_executor_faults(self) -> List[str]:
        """Collect fault kinds the (possibly fault-injecting) executor
        observed since the last drain; counts them into telemetry."""
        pop = getattr(self.executor, "pop_faults", None)
        kinds = pop() if pop is not None else []
        for kind in kinds:
            self.telemetry.on_fault(kind)
        return kinds

    def _bound_unpolled(self) -> None:
        while len(self._responses) >= self._max_unpolled:
            self._responses.pop(next(iter(self._responses)))
            self.telemetry.counters["responses_evicted"] += 1

    def drain(self) -> int:
        """Run until nothing is in flight (escalations included)."""
        done = 0
        while self._in_flight:
            done += self.step(force=True)
        return done

    def _execute_mutation(self, mb: MicroBatch) -> list:
        """Apply one upsert/delete microbatch on the host; returns the
        created responses (``step`` stamps their visibility epoch after
        the flush's swap).

        Mutations never touch the compile cache (no padded lanes are
        materialized — ``bucket`` is irrelevant to a host loop); their
        measured wall time still advances a virtual-time replay so churn
        costs land in the same timeline as query execution.
        """
        t_start = self.clock()
        t0 = wall_clock()
        c0 = cpu_clock()
        results = self.executor.apply_mutations(mb.requests)
        dt = wall_clock() - t0
        self.busy_seconds += cpu_clock() - c0
        if hasattr(self.clock, "advance"):
            self.clock.advance(dt)
        now = self.clock()
        self.telemetry.on_mutation(mb.family, len(mb.requests))
        self._log(
            "dispatch",
            batch_id=mb.batch_id,
            family=mb.family,
            n_real=mb.n_real,
        )
        responses = []
        for req, (ok, slot) in zip(mb.requests, results):
            self._bound_unpolled()
            if req.trace is not None:
                req.trace.on_exec(t_start, now)
            resp = Response(
                req_id=req.req_id,
                ids=np.asarray([slot], np.int32),
                dists=np.zeros((1,), np.float32),
                k=1,
                filled=int(ok),
                tier=req.tier,
                escalations=0,
                fill_history=(int(ok),),
                arrival_t=req.arrival_t,
                complete_t=now,
                deadline_missed=deadline_missed(req.deadline, now),
                trace=(
                    req.trace.breakdown(now) if req.trace is not None else None
                ),
                batch_id=mb.batch_id,
            )
            self._responses[req.req_id] = resp
            responses.append(resp)
            self._in_flight -= 1
        return responses

    # --- hybrid strategy executors (DESIGN.md §9) -------------------------
    def _current_corpus(self) -> Corpus:
        if hasattr(self.executor, "apply_mutations"):
            return self.executor.snapshot.corpus
        return self.executor.corpus

    def _host_vectors(self) -> np.ndarray:
        if hasattr(self.executor, "apply_mutations"):
            return self.executor.index.pool.vectors
        return np.asarray(self.executor.corpus.vectors)

    def _run_posting(self, mb: MicroBatch, queries, constraint):
        """Brute-force scan over the batch's shared posting set. The scan
        is exact over that set (the constraint closure re-verifies every
        id), so its results never escalate — an under-fill means fewer
        than k satisfying rows exist."""
        req = mb.requests[0]
        if req.family == "label":
            ids = self.router.postings.ids_for_words(
                np.asarray(req.operand, np.uint32)
            )
        else:
            lo, hi, col = req.operand
            ids = self.router.range_index.ids_for_range(
                float(lo), float(hi), int(col)
            )
        padded = pad_posting(ids, posting_bucket(int(ids.shape[0])))
        params = self.controller.params_for(mb.tier)
        pq = (
            getattr(self.executor, "pq_index", None)
            if params.approx == "pq"
            else None
        )
        return posting_search(
            self._current_corpus(), queries, constraint,
            jnp.asarray(padded), params, pq,
        )

    def _run_overlay(self, mb: MicroBatch, queries):
        """Traversal over the hot label's cached sub-index; None when no
        overlay can be built (caller falls back to the graph plan)."""
        label = int(mb.group[-1])
        epoch = getattr(self.executor, "epoch", 0)
        overlay = self.overlays.get(label, epoch, self._overlay_build_fn)
        if overlay is None:
            return None
        # The acceptance invariant: churn must never serve a stale overlay.
        assert overlay.epoch == epoch, (
            f"overlay epoch {overlay.epoch} != index epoch {epoch}"
        )
        return overlay_search(
            overlay, queries, self.controller.params_for(mb.tier)
        )

    def _overlay_build_fn(self, label: int, epoch: int):
        ids = self.router.postings.ids_for_label(label)
        if ids.shape[0] < 2:  # a sub-graph needs at least one edge
            return None
        return build_overlay(label, ids, self._host_vectors(), epoch)

    def _execute(self, mb: MicroBatch) -> int:
        # The whole request-processing path is the service time: operand
        # assembly + host->device transfer + search + result readback. A
        # virtual-time replay charges all of it to the timeline — this is
        # exactly the per-request overhead the batch=1 baseline cannot
        # amortize.
        t_start = self.clock()
        t0 = wall_clock()
        c0 = cpu_clock()
        try:
            queries = assemble_queries(mb, self.executor.dim)
            constraint = assemble_constraint(mb)
            strategy = mb.strategy
            res = None
            if strategy == "posting":
                res = self._run_posting(mb, queries, constraint)
            elif strategy == "overlay":
                res = self._run_overlay(mb, queries)
            if res is None:
                # graph strategy, or a routed strategy that turned out
                # inapplicable at dispatch time (e.g. the label's posting
                # set shrank below the overlay minimum under churn): the
                # full traversal is the universal fallback.
                strategy = "graph"
                fn = self.cache.get((mb.bucket, mb.family, mb.tier))
                res = fn(queries, constraint)
            jax.block_until_ready(res.dists)
        except ExecutorFault as fault:
            # The recovery contract: a faulted dispatch costs its wall
            # time, its requests are retried through the batcher within
            # their budget, and budget-exhausted ones surface as FAILED
            # responses — a fault never hangs or loses a request.
            dt = wall_clock() - t0
            self.busy_seconds += cpu_clock() - c0
            if hasattr(self.clock, "advance"):
                self.clock.advance(dt)
            return self._recover_faulted(mb, fault, t_start)
        ids = np.asarray(res.ids)
        dists = np.asarray(res.dists)
        dt = wall_clock() - t0
        self.busy_seconds += cpu_clock() - c0
        if hasattr(self.clock, "advance"):
            # Virtual-time replay: execution cost advances the timeline.
            self.clock.advance(dt)
        now = self.clock()
        # Execution-only duration (injected spikes excluded — they advance
        # the virtual clock, not the measured wall interval): the ladder's
        # predictive-shedding estimate of what one more dispatch costs.
        self.controller.observe_service(dt)
        # An injected latency spike completed the batch but late: mark its
        # responses faulted (+degraded) so a spike-caused deadline miss is
        # accountable, never a silent late completion.
        spiked = "spike" in self._drain_executor_faults()
        self.telemetry.on_dispatch(mb.bucket, mb.n_real)
        self._log(
            "dispatch",
            batch_id=mb.batch_id,
            family=mb.family,
            strategy=strategy,
            tier=mb.tier,
            bucket=mb.bucket,
            n_real=mb.n_real,
            epoch=getattr(self.executor, "epoch", None),
            exec_s=round(dt, 9),
        )

        mean_iters = float(res.stats.iters)
        # ids rows are -1-padded at the tail (ascending dists), so the fill
        # within a request's k-prefix is min(total filled, k).
        filled_rows = np.minimum(np.asarray(res.filled),
                                 [r.k for r in mb.requests] + [0] * mb.n_padded)
        fill_fracs = []
        done = 0
        for i, req in enumerate(mb.requests):
            row_ids = ids[i, : req.k]
            filled = int(filled_rows[i])
            req.fill_history = req.fill_history + (filled,)
            fill_fracs.append(filled / max(req.k, 1))
            if req.trace is not None:
                req.trace.on_exec(t_start, now)
            # Posting-scan results are exact over the posting set: an
            # under-fill means fewer than k rows satisfy at all, and no
            # bigger-ef tier can conjure more — never escalate those.
            if filled < req.k and strategy != "posting":
                next_tier = self.controller.escalate(req)
                if next_tier is not None:
                    # Under-fill escalation: re-run at a bigger-ef tier
                    # instead of returning padded slots (the online
                    # analogue of the paper's "hope s is large enough").
                    req.tier = next_tier
                    req.escalations += 1
                    self.telemetry.on_escalate()
                    if req.trace is not None:
                        req.trace.mark(f"escalate:{next_tier}", now)
                    self._log(
                        "escalate",
                        req_id=req.req_id,
                        batch_id=mb.batch_id,
                        tier=next_tier,
                    )
                    self.batcher.add(req, now)
                    continue
                elif (
                    self.controller.ladder is not None
                    and self.controller.ladder.cap_escalations
                    and req.tier < self.controller.max_tier
                ):
                    # The ladder (not the ladder top) suppressed the
                    # retry: this partial answer is a degraded one.
                    req.degraded = True
            self._bound_unpolled()
            ladder = self.controller.ladder
            self._responses[req.req_id] = Response(
                req_id=req.req_id,
                ids=row_ids.copy(),
                dists=dists[i, : req.k].copy(),
                k=req.k,
                filled=filled,
                tier=req.tier,
                escalations=req.escalations,
                fill_history=req.fill_history,
                arrival_t=req.arrival_t,
                complete_t=now,
                deadline_missed=deadline_missed(req.deadline, now),
                epoch=getattr(self.executor, "epoch", None),
                strategy=strategy,
                est_selectivity=req.est_selectivity,
                # Degraded if the ladder shaped it at any point of its
                # life — admission, dispatch, or completion — a spike hit
                # its batch, or it crossed its deadline DURING execution
                # (it passed the flush-time shed check, then the dispatch
                # outlasted its budget: late = SLO-degraded): every late
                # completion carries a mark explaining it, never a silent
                # miss.
                degraded=(
                    req.degraded
                    or spiked
                    or (ladder is not None and ladder.level > 0)
                    or deadline_missed(req.deadline, now)
                ),
                faulted=spiked or req.fault_retries > 0,
                trace=(
                    req.trace.breakdown(now) if req.trace is not None else None
                ),
                batch_id=mb.batch_id,
            )
            self._in_flight -= 1
            self.telemetry.on_complete(self._responses[req.req_id])
            self.controller.observe_latency(now - req.arrival_t)
            self._log(
                "complete",
                req_id=req.req_id,
                batch_id=mb.batch_id,
                filled=filled,
                latency_s=round(now - req.arrival_t, 9),
            )
            done += 1
        if not fill_fracs:
            return done
        mean_fill = sum(fill_fracs) / len(fill_fracs)
        if strategy == "graph":
            # Tier retuning reads traversal fill/iteration EMAs — posting
            # scans (iters == 0 by construction) must not train them.
            self.controller.record(mb.family, mb.tier, mean_fill, mean_iters)
        if self.router is not None and mb.requests[0].sel_bucket >= 0:
            # Strategy retuning per (family, selectivity bucket): observed
            # per-request latency + fill for whatever executor actually ran.
            self.controller.record_strategy(
                (mb.family, mb.requests[0].sel_bucket),
                strategy,
                dt / max(mb.n_real, 1),
                mean_fill,
            )
        return done

    def _recover_faulted(
        self, mb: MicroBatch, fault: ExecutorFault, t_start: float
    ) -> int:
        """Fault recovery (DESIGN.md §10): every request of a faulted
        dispatch is either re-queued through the batcher (within its
        ``max_fault_retries`` budget) or completed as a FAILED pollable
        Response carrying the fault message — never hung in ``in_flight``,
        never silently lost. Returns the number completed-as-failed."""
        self._drain_executor_faults()  # count the injection behind this raise
        now = self.clock()
        done = 0
        for req in mb.requests:
            if req.trace is not None:
                # The faulted dispatch still burned execute time.
                req.trace.on_exec(t_start, now)
            if req.fault_retries < self.max_fault_retries:
                req.fault_retries += 1
                self.telemetry.on_fault_retry()
                if req.trace is not None:
                    req.trace.mark("fault_retry", now)
                self._log(
                    "fault_retry", req_id=req.req_id, batch_id=mb.batch_id
                )
                self.batcher.add(req, now)
                continue
            self._bound_unpolled()
            resp = Response(
                req_id=req.req_id,
                ids=np.full((req.k,), -1, np.int32),
                dists=np.full((req.k,), np.inf, np.float32),
                k=req.k,
                filled=0,
                tier=req.tier,
                escalations=req.escalations,
                fill_history=req.fill_history + (0,),
                arrival_t=req.arrival_t,
                complete_t=now,
                deadline_missed=deadline_missed(req.deadline, now),
                epoch=getattr(self.executor, "epoch", None),
                strategy=req.strategy,
                est_selectivity=req.est_selectivity,
                degraded=req.degraded,
                faulted=True,
                error=str(fault),
                trace=(
                    req.trace.breakdown(now, outcome="failed")
                    if req.trace is not None
                    else None
                ),
                batch_id=mb.batch_id,
            )
            self._responses[req.req_id] = resp
            self._in_flight -= 1
            self.telemetry.on_complete(resp)
            self._log(
                "failed",
                req_id=req.req_id,
                batch_id=mb.batch_id,
                error=str(fault),
            )
            done += 1
        return done

    # --- reporting --------------------------------------------------------
    def report(self) -> dict:
        out = {
            "telemetry": self.telemetry.summary(),
            "cache": self.cache.stats(),
            "trace_budget": self.trace_budget,
            "controller": self.controller.snapshot(),
            "pending": self.batcher.pending_count(),
        }
        if self.router is not None:
            out["overlays"] = self.overlays.stats()
        if hasattr(self.executor, "apply_mutations"):
            idx = self.executor.index
            out["index"] = {
                "epoch": self.executor.epoch,
                "capacity": idx.capacity,
                "n_live": idx.pool.n_live,
                "n_pending": idx.pool.n_pending,
                "n_free": idx.pool.n_free,
                "consolidations": idx.consolidations,
            }
        return out
