"""Value types for the online serving runtime (DESIGN.md §7).

A *request* is one constrained query with its own ``k``, constraint family
and operand, and optional deadline — the heterogeneous unit the dynamic
batcher groups into bucket-shaped microbatches. A *response* is the
completed answer plus the telemetry the adaptive controller feeds on.

Requests are host-side mutable records (they move between batcher tiers as
the controller escalates them); everything that crosses into jitted code is
assembled per microbatch by the batcher from their operands.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Tuple

import numpy as np

from repro.obs.tracing import RequestTrace

FAMILIES = ("label", "range")
# Mutation "families" ride the SAME batcher as queries (their own groups,
# so they never share a microbatch with a search) but execute on the host
# against the streaming index — they never touch the compile cache, so the
# trace budget stays a pure query-shape quantity.
MUTATION_FAMILIES = ("upsert", "delete")


class AdmissionError(RuntimeError):
    """Raised by ``ServingRuntime.submit`` when the admission queue is full
    (backpressure: the caller must retry later or shed the request)."""


# --- deadline discipline (DESIGN.md §10) -----------------------------------
# A deadline is the LAST instant at which completion still counts: a
# response landing exactly at ``deadline`` is met. Everything that compares
# a deadline goes through these two helpers, so the batcher's "time to
# ship", the runtime's shed decision, and the completion verdict cannot
# drift apart (they once did: batcher flushed on ``deadline <= now`` while
# the runtime reported misses on ``now > deadline`` — consistent only by
# accident of both being exclusive at the boundary).


def deadline_due(deadline: Optional[float], now: float) -> bool:
    """The batcher must ship now: the deadline instant has arrived. At
    ``now == deadline`` the request is due AND still meetable — this is
    its last chance, not a miss."""
    return deadline is not None and now >= deadline


def deadline_missed(deadline: Optional[float], now: float) -> bool:
    """Completion (or shed-evaluation) strictly after the deadline is a
    miss; completing exactly at the deadline is met. Also the shed test:
    a request is expired-at-flush iff its deadline is already missed."""
    return deadline is not None and now > deadline


@dataclasses.dataclass
class Request:
    """One in-flight constrained query.

    operand: family == "label" -> (Lw,) uint32 allowed-label bitmask words;
             family == "range" -> (lo, hi, col) with col static per group.
    """

    req_id: int
    query: np.ndarray  # (d,) float32
    k: int
    family: str  # "label" | "range"
    operand: object
    deadline: Optional[float] = None  # absolute clock time, None = no deadline
    arrival_t: float = 0.0
    enqueue_t: float = 0.0  # last time it entered the batcher (escalations reset it)
    tier: int = 0
    escalations: int = 0
    fill_history: Tuple[int, ...] = ()  # filled count at each completed dispatch
    # Hybrid-routing verdict (DESIGN.md §9), stamped by the strategy router
    # at admission; defaults reproduce pre-hybrid behaviour exactly.
    strategy: str = "graph"  # "graph" | "posting" | "overlay"
    est_selectivity: Optional[float] = None
    sel_bucket: int = -1
    sel_source: str = "default"  # "histogram" | "sampled" | "default"
    overlay_label: Optional[int] = None  # single hot label, overlay routes
    # Fault-tolerance state (DESIGN.md §10): set while the degradation
    # ladder shapes this request (base tier forced / escalation capped /
    # cheap strategy preferred), and the executor-fault retry budget spent.
    degraded: bool = False
    fault_retries: int = 0
    # Observability (DESIGN.md §12): the span recorder riding this request
    # (None when the runtime serves with tracing off).
    trace: Optional[RequestTrace] = None

    def group(self) -> tuple:
        """Batcher compatibility key: requests in one microbatch must share
        it. The range column is per-batch traced data with a single value
        (RangeConstraint.col), so it joins the group; label operands are
        fully per-query.

        Graph-strategy keys are EXACTLY the pre-hybrid keys — the hybrid
        router only ever appends to the tuple for its own strategies, so
        existing traces, tests, and telemetry keyed on graph groups are
        untouched. Posting microbatches additionally share their operand
        (the scan gathers ONE posting set for the whole batch); overlay
        microbatches share their hot label (one sub-index per batch).
        """
        base = (
            (self.family, int(self.operand[2]))
            if self.family == "range"
            else (self.family,)
        )
        if self.strategy == "posting":
            return base + ("posting", self._operand_key())
        if self.strategy == "overlay":
            return base + ("overlay", int(self.overlay_label))
        return base

    def _operand_key(self) -> tuple:
        """Hashable identity of the operand (posting-group sharing)."""
        if self.family == "range":
            return (float(self.operand[0]), float(self.operand[1]))
        return (np.asarray(self.operand, np.uint32).tobytes(),)


@dataclasses.dataclass
class UpsertRequest(Request):
    """Insert one vector into the streaming index.

    ``query`` carries the new vector; ``operand`` is ``(label, attrs_row)``
    (attrs_row None when the corpus has no numeric attributes). The
    response's ``ids[0]`` is the assigned slot id.
    """

    def group(self) -> tuple:
        return ("upsert",)


@dataclasses.dataclass
class DeleteRequest(Request):
    """Tombstone one slot id (``operand``) in the streaming index.

    The response's ``filled`` is 1 when the slot was live and is now
    tombstoned, 0 when it was already dead (idempotent delete).
    """

    def group(self) -> tuple:
        return ("delete",)


@dataclasses.dataclass
class Response:
    req_id: int
    ids: np.ndarray  # (k,) int32, -1 padded
    dists: np.ndarray  # (k,) float32, +inf padded
    k: int
    filled: int  # slots with id >= 0 among the first k
    tier: int  # tier that produced the final answer
    escalations: int
    fill_history: Tuple[int, ...]  # filled at each dispatch incl. final
    arrival_t: float = 0.0
    complete_t: float = 0.0
    deadline_missed: bool = False
    # Index epoch the answer was computed against (streaming executors
    # only; None for static indexes). Queries in one flush share an epoch —
    # the snapshot swap is atomic at flush boundaries (DESIGN.md §8).
    epoch: Optional[int] = None
    # Hybrid-routing telemetry (DESIGN.md §9): the executor strategy that
    # produced this answer and the router's selectivity estimate for it.
    strategy: str = "graph"
    est_selectivity: Optional[float] = None
    # Fault-tolerance outcome (DESIGN.md §10). A response is exactly one
    # of: served (shed_reason None, error None), shed (shed_reason
    # "expired" — deadline already missed at flush — or "overload" — the
    # level-3 ladder predicted an unmeetable deadline), or failed (error
    # set: an executor fault exhausted its retry budget). ``degraded``
    # marks answers shaped by the ladder or hit by an injected latency
    # spike — the mark that makes a late completion accountable.
    shed_reason: Optional[str] = None
    degraded: bool = False
    faulted: bool = False  # an injected fault touched this dispatch
    error: Optional[str] = None
    # Observability (DESIGN.md §12): the span recorder's stage breakdown
    # (queue_wait | batch_wait | execute | overhead, summing to the
    # end-to-end latency) and the microbatch that produced the final
    # answer — None/-1 when the runtime serves with tracing off.
    trace: Optional[dict] = None
    batch_id: int = -1

    @property
    def ok(self) -> bool:
        """Served (possibly degraded/partial) — not shed, not failed."""
        return self.shed_reason is None and self.error is None

    @property
    def latency(self) -> float:
        return self.complete_t - self.arrival_t

    @property
    def fill_frac(self) -> float:
        return self.filled / max(self.k, 1)


class VirtualClock:
    """Injectable clock for deterministic tests and discrete-event replay.

    ``ServingRuntime`` timestamps via ``clock()``; drivers that simulate
    Poisson arrivals advance virtual time explicitly (arrival gaps) and the
    runtime adds each microbatch's *measured* execution wall time via
    ``advance`` — so latencies are arrival-to-completion in a consistent
    timeline even when the host replays the stream faster than real time.
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        self.now += float(dt)
        return self.now

    def advance_to(self, t: float) -> float:
        self.now = max(self.now, float(t))
        return self.now


def wall_clock() -> float:
    return time.perf_counter()


def cpu_clock() -> float:
    """CPU seconds consumed by the calling thread.

    Dispatch *cost accounting* (ServingRuntime.busy_seconds) uses this
    instead of wall intervals: in a single-process multi-replica harness
    the GIL deschedules a dispatching pump while other replicas' threads
    run, and a wall interval would charge that contention to the replica
    — precisely what shared-nothing placement on separate cores removes.
    Timeline advancement (deadlines, virtual clocks) stays on
    ``wall_clock``.
    """
    return time.thread_time()
