"""Shape-bucketed compile cache with a hard trace budget.

Every distinct (bucket batch size, constraint family, params tier) needs
its own trace of the search loop — XLA compiles fixed shapes and
``SearchParams`` is a static jit key. The registry memoizes those compiled
closures, counts hits/misses, and *refuses* to grow past the budget the
bucket ladder implies: an arbitrary request stream can force at most
|ladder| x |families| x |tiers| traces, and exceeding that is a bug in the
batcher/controller (e.g. a tier escaping the declared ladder), not a
workload property — so it raises instead of silently compiling.
"""
from __future__ import annotations

from typing import Callable, Dict, Hashable


class TraceBudgetError(AssertionError):
    """A bucket key outside the declared ladder reached the compile cache."""


class CompileCache:
    def __init__(self, build_fn: Callable[[Hashable], Callable], max_entries: int):
        self._build = build_fn
        self._fns: Dict[Hashable, Callable] = {}
        self.max_entries = int(max_entries)
        self.hits = 0
        self.misses = 0

    @property
    def trace_count(self) -> int:
        return len(self._fns)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def get(self, key: Hashable) -> Callable:
        fn = self._fns.get(key)
        if fn is not None:
            self.hits += 1
            return fn
        if len(self._fns) >= self.max_entries:
            raise TraceBudgetError(
                f"bucket key {key!r} would be compiled closure "
                f"#{len(self._fns) + 1}, over the declared budget of "
                f"{self.max_entries} (= |ladder| x |families| x |tiers|); "
                f"known keys: {sorted(map(repr, self._fns))}"
            )
        self.misses += 1
        fn = self._build(key)
        self._fns[key] = fn
        return fn

    def reset_counters(self) -> None:
        """Zero hit/miss counters (compiled closures stay warm) — used to
        report steady-state hit rates after an explicit warmup pass."""
        self.hits = 0
        self.misses = 0

    def stats(self) -> dict:
        return {
            "trace_count": self.trace_count,
            "max_entries": self.max_entries,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(self.hit_rate, 4),
        }
