"""Adaptive search controller: tier ladder + under-fill escalation.

The paper's offline fix for pipeline under-fill is "hope s is large
enough"; online, over-provisioning every query with a huge ``ef`` wastes
the common case. The controller keeps a small declared ladder of
``SearchParams`` *tiers* — same mode/k, growing ``ef_result`` /
``max_iters`` / ``n_start`` — and works at two timescales:

  * per request: a query that comes back with ``filled < k`` is escalated
    to the next tier and re-dispatched (through the batcher, so retries
    batch too) instead of returning padded slots;
  * per family: an EMA of fill fraction and loop-iteration headroom picks
    the *default* tier new requests start at — a family whose base tier
    keeps under-filling is promoted (first-dispatch fill, fewer retries), a
    family that fills easily with iteration headroom is demoted back.

Both knobs only ever select *within* the declared ladder, which is what
keeps the compile-cache trace budget a static quantity (cache.py).

With an ``SLOConfig`` the controller additionally runs the degradation
ladder (slo.py, DESIGN.md §10): queue-depth + observed-latency EMAs feed
a hysteretic overload level, and the two request-policy entry points that
already live here — ``tier_for`` (admission tier) and ``escalate``
(retry-tier re-runs) — consult it, so overload protection needs no new
wiring in the runtime's hot path.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.types import SearchParams
from repro.serving.slo import DegradationLadder, SLOConfig
from repro.serving.types import Request


def make_tier_ladder(
    k_cap: int = 16,
    mode: str = "prefer",
    n_tiers: int = 2,
    base_ef: int = 64,
    base_iters: int = 128,
    base_n_start: int = 16,
    growth: int = 4,
) -> Tuple[SearchParams, ...]:
    """Geometric tier ladder. Tier 0 is lean (sized for the common case);
    each next tier multiplies the search budget by ``growth``. ``k`` is the
    static cap every compiled closure serves — per-request ``k <= k_cap``
    takes a prefix of the result list."""
    tiers = []
    for t in range(n_tiers):
        g = growth**t
        ef = max(base_ef * g, k_cap)
        tiers.append(
            SearchParams(
                mode=mode,
                k=k_cap,
                ef_result=ef,
                ef_sat=ef,
                ef_other=ef,
                n_start=base_n_start * g,
                max_iters=base_iters * g,
            )
        )
    return tuple(tiers)


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    ema_alpha: float = 0.25  # weight of the newest batch in the EMAs
    promote_below: float = 0.9  # default-tier fill EMA below this -> promote
    demote_above: float = 0.995  # fill EMA above this AND headroom -> demote
    # Demotion additionally requires the iteration EMA to fit comfortably in
    # the *lower* tier's budget (otherwise demoting would just re-underfill).
    demote_iter_headroom: float = 0.5
    min_batches: int = 4  # batches observed at a tier before retuning


@dataclasses.dataclass
class _FamilyState:
    default_tier: int = 0
    fill_ema: Optional[float] = None  # fill fraction at the default tier
    iter_ema: Optional[float] = None  # loop iterations at the default tier
    batches_at_tier: int = 0


@dataclasses.dataclass
class _StrategyStats:
    """Per-strategy EMAs within one (family, selectivity-bucket) key."""

    lat_ema: Optional[float] = None  # per-request latency at this strategy
    fill_ema: Optional[float] = None
    batches: int = 0


@dataclasses.dataclass
class _StrategyState:
    """Observed-performance state for one (family, sel-bucket) routing key.

    ``preferred`` is None until enough evidence accumulates — the router
    then uses its own lattice default. Retuning only ever *selects among*
    strategies the router's lattice row allows (the router re-checks
    membership + applicability before honouring the preference), so the
    controller cannot route outside the declared lattice.
    """

    preferred: Optional[str] = None
    stats: Dict[str, _StrategyStats] = dataclasses.field(default_factory=dict)
    # best-first ordering, recomputed at record time so the router's
    # per-request hot path reads a cached tuple instead of sorting
    ranking: Tuple[str, ...] = ()


class AdaptiveController:
    def __init__(
        self,
        tiers: Tuple[SearchParams, ...],
        config: ControllerConfig = ControllerConfig(),
        slo: Optional[SLOConfig] = None,
    ):
        if not tiers:
            raise ValueError("need at least one SearchParams tier")
        k_cap = tiers[0].k
        if any(t.k != k_cap for t in tiers):
            raise ValueError("all tiers must share the same k cap")
        self.tiers = tuple(tiers)
        self.config = config
        self._families: Dict[str, _FamilyState] = {}
        self._strategies: Dict[tuple, _StrategyState] = {}
        # bumped on every record_strategy; the router's plan cache keys
        # decision validity on it so retuning invalidates cached plans
        self.generation = 0
        # Degradation ladder (DESIGN.md §10): None = no overload policy,
        # bit-identical pre-PR7 behaviour.
        self.ladder = DegradationLadder(slo) if slo is not None else None

    @property
    def max_tier(self) -> int:
        return len(self.tiers) - 1

    @property
    def k_cap(self) -> int:
        return self.tiers[0].k

    def params_for(self, tier: int) -> SearchParams:
        return self.tiers[tier]

    # --- overload policy (DESIGN.md §10) ----------------------------------
    @property
    def degradation_level(self) -> int:
        return 0 if self.ladder is None else self.ladder.level

    def observe_load(self, queue_depth: int) -> int:
        """One runtime-step load sample into the ladder (no-op without an
        SLO config); returns the current degradation level."""
        if self.ladder is None:
            return 0
        return self.ladder.observe_load(queue_depth)

    def observe_latency(self, latency: float) -> None:
        """One completed response's latency into the ladder's EMA."""
        if self.ladder is not None:
            self.ladder.observe_latency(latency)

    def observe_service(self, duration: float) -> None:
        """One dispatch's measured execution duration into the ladder's
        service-time EMA (the predictive-shedding estimate)."""
        if self.ladder is not None:
            self.ladder.observe_service(duration)

    def tier_for(self, family: str) -> int:
        """Default tier for a newly admitted request of this family. While
        the ladder is degraded, every admission starts at the base tier —
        the family default is an *up*-tuning the overload cannot afford."""
        if self.ladder is not None and self.ladder.force_base_tier:
            return 0
        return self._families.setdefault(family, _FamilyState()).default_tier

    def escalate(self, req: Request) -> Optional[int]:
        """Next tier for an under-filled request, or None when maxed out —
        or when the degradation ladder has capped retry-tier escalations
        (a retry re-runs the query at a multiple of the budget; under
        overload that multiple is exactly what must not be spent)."""
        if self.ladder is not None and self.ladder.cap_escalations:
            return None
        return req.tier + 1 if req.tier < self.max_tier else None

    def record(
        self, family: str, tier: int, fill_frac: float, mean_iters: float
    ) -> None:
        """Fold one completed microbatch's telemetry into the family policy.

        Only the family's current default tier trains the EMAs — escalated
        retries measure the retry tier, not where new requests should start.
        """
        st = self._families.setdefault(family, _FamilyState())
        if tier != st.default_tier:
            return
        a = self.config.ema_alpha
        st.fill_ema = (
            fill_frac
            if st.fill_ema is None
            else (1 - a) * st.fill_ema + a * fill_frac
        )
        st.iter_ema = (
            mean_iters
            if st.iter_ema is None
            else (1 - a) * st.iter_ema + a * mean_iters
        )
        st.batches_at_tier += 1
        if st.batches_at_tier < self.config.min_batches:
            return
        if st.fill_ema < self.config.promote_below and st.default_tier < self.max_tier:
            st.default_tier += 1
            st.fill_ema = st.iter_ema = None
            st.batches_at_tier = 0
        elif st.default_tier > 0 and st.fill_ema >= self.config.demote_above:
            lower_budget = self.tiers[st.default_tier - 1].max_iters
            if st.iter_ema <= self.config.demote_iter_headroom * lower_budget:
                st.default_tier -= 1
                st.fill_ema = st.iter_ema = None
                st.batches_at_tier = 0

    # --- hybrid strategy retuning (DESIGN.md §9) --------------------------
    def strategy_for(self, key: tuple, default: str) -> str:
        """Preferred executor strategy for a (family, sel-bucket) routing
        key, or the router's lattice ``default`` before evidence exists.
        The router re-validates the preference against its lattice row and
        applicability gates — this is a hint, never an override beyond the
        declared lattice."""
        st = self._strategies.get(key)
        if st is None or st.preferred is None:
            return default
        return st.preferred

    def strategy_ranking(self, key: tuple) -> tuple:
        """All observed strategies for the key, best-first: adequately
        filling ones (within 1% of the best fill EMA) by ascending latency,
        then under-filling ones by ascending latency. Empty before any
        strategy has ``min_batches`` observations. The router walks this
        ranking so that when the globally fastest strategy is outside the
        bucket's lattice row (or inapplicable), the *next-best observed*
        strategy still wins over the static lattice default. Cached at
        record time — this sits on the per-request routing hot path."""
        st = self._strategies.get(key)
        return () if st is None else st.ranking

    def record_strategy(
        self, key: tuple, strategy: str, latency: float, fill_frac: float
    ) -> None:
        """Fold one completed microbatch's per-request latency + fill into
        the (family, sel-bucket) strategy EMAs, and retune the preference:
        the lowest-latency strategy among those that fill essentially as
        well as the best observed (within 1%), once every candidate has
        ``min_batches`` observations."""
        st = self._strategies.setdefault(key, _StrategyState())
        self.generation += 1
        s = st.stats.setdefault(strategy, _StrategyStats())
        a = self.config.ema_alpha
        s.lat_ema = (
            latency if s.lat_ema is None else (1 - a) * s.lat_ema + a * latency
        )
        s.fill_ema = (
            fill_frac
            if s.fill_ema is None
            else (1 - a) * s.fill_ema + a * fill_frac
        )
        s.batches += 1
        ready = {
            name: stats
            for name, stats in st.stats.items()
            if stats.batches >= self.config.min_batches
        }
        if not ready:
            return
        best_fill = max(stats.fill_ema for stats in ready.values())
        adequate = sorted(
            (name for name, s in ready.items() if s.fill_ema >= best_fill - 0.01),
            key=lambda name: ready[name].lat_ema,
        )
        lagging = sorted(
            (name for name, s in ready.items() if s.fill_ema < best_fill - 0.01),
            key=lambda name: ready[name].lat_ema,
        )
        st.ranking = tuple(adequate) + tuple(lagging)
        st.preferred = st.ranking[0]

    def snapshot(self) -> dict:
        out: dict = {
            fam: {
                "default_tier": st.default_tier,
                "fill_ema": None if st.fill_ema is None else round(st.fill_ema, 4),
                "iter_ema": None if st.iter_ema is None else round(st.iter_ema, 1),
            }
            for fam, st in self._families.items()
        }
        if self._strategies:
            out["strategies"] = {
                f"{key[0]}@bucket{key[1]}": {
                    "preferred": st.preferred,
                    "observed": {
                        name: {
                            "lat_ema": (
                                None
                                if s.lat_ema is None
                                else round(s.lat_ema, 6)
                            ),
                            "fill_ema": (
                                None
                                if s.fill_ema is None
                                else round(s.fill_ema, 4)
                            ),
                            "batches": s.batches,
                        }
                        for name, s in st.stats.items()
                    },
                }
                for key, st in self._strategies.items()
            }
        if self.ladder is not None:
            out["slo"] = self.ladder.snapshot()
        return out
