"""Mixed-workload generation + Poisson replay for the serving runtime.

Real filtered-search traffic mixes constraint selectivities wildly (SIEVE's
workload study); this module synthesizes that: one stream interleaving
equal-label, unequal-X%, and numeric-range constraints with mixed per-query
``k`` and Poisson arrivals. Shared by the serve driver
(launch/serve.py) and the serving benchmark (benchmarks/bench_serving.py)
so both measure the same stream shape.

Replay runs in virtual time (``VirtualClock``): arrival gaps advance the
clock explicitly and the runtime adds each microbatch's measured execution
wall time, so latency percentiles are consistent arrival-to-completion
quantities even though the host replays the stream as fast as it can.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.constraints import WORD_BITS
from repro.core.types import Corpus
from repro.serving.retry import RetryPolicy, submit_with_retry
from repro.serving.runtime import ServingRuntime
from repro.serving.types import AdmissionError, Response, VirtualClock


@dataclasses.dataclass
class WorkItem:
    query: np.ndarray  # (d,) float32
    k: int
    family: str
    operand: object
    kind: str  # workload slice tag ("equal" | "unequal" | "range")


def label_words_row(labels: Sequence[int], n_labels: int) -> np.ndarray:
    """(Lw,) uint32 allowed-label bitmask row for one request."""
    row = np.zeros(((n_labels + WORD_BITS - 1) // WORD_BITS,), np.uint32)
    for lab in labels:
        row[lab // WORD_BITS] |= np.uint32(1) << np.uint32(lab % WORD_BITS)
    return row


def mixed_workload(
    seed: int,
    corpus: Corpus,
    n_requests: int,
    n_labels: int,
    *,
    k_choices: Tuple[int, ...] = (4, 8, 16),
    mix: Tuple[float, float, float] = (0.4, 0.4, 0.2),  # equal/unequal/range
    unequal_pct: float = 20.0,
    range_col: int = 0,
    range_width: Tuple[float, float] = (0.05, 0.3),
    jitter: float = 0.05,
) -> List[WorkItem]:
    """One heterogeneous stream: queries drawn near corpus points (the
    paper's protocol), each with its own k and constraint.

    Range windows are centered on the query point's own attribute value
    with width >= ``range_width[0]`` so every request is satisfiable by
    >= k corpus items in expectation (attrs ~ U[0, 1]).
    """
    rng = np.random.RandomState(seed)
    vectors = np.asarray(corpus.vectors)
    labels = np.asarray(corpus.labels)
    attrs = None if corpus.attrs is None else np.asarray(corpus.attrs)
    n, d = vectors.shape
    if mix[2] > 0 and attrs is None:
        raise ValueError("range slice requested but corpus has no attrs")

    items: List[WorkItem] = []
    kinds = rng.choice(3, size=n_requests, p=np.asarray(mix) / np.sum(mix))
    picks = rng.randint(0, n, size=n_requests)
    for kind_id, pick in zip(kinds, picks):
        q = vectors[pick] + rng.randn(d).astype(np.float32) * jitter
        k = int(rng.choice(k_choices))
        qlab = int(labels[pick])
        if kind_id == 0:
            items.append(WorkItem(q, k, "label", label_words_row([qlab], n_labels), "equal"))
        elif kind_id == 1:
            n_allowed = max(1, int(round(n_labels * unequal_pct / 100.0)))
            others = [lab for lab in range(n_labels) if lab != qlab]
            allowed = rng.choice(others, size=min(n_allowed, len(others)), replace=False)
            items.append(
                WorkItem(q, k, "label", label_words_row(list(allowed), n_labels), "unequal")
            )
        else:
            center = float(attrs[pick, range_col])
            width = float(rng.uniform(*range_width))
            lo, hi = center - width / 2, center + width / 2
            items.append(WorkItem(q, k, "range", (lo, hi, range_col), "range"))
    return items


def churn_workload(
    seed: int,
    corpus: Corpus,
    n_requests: int,
    n_labels: int,
    *,
    mutation_frac: float = 0.3,
    delete_frac: float = 0.5,
    k_choices: Tuple[int, ...] = (4, 8, 16),
    mix: Tuple[float, float, float] = (0.4, 0.4, 0.2),
    unequal_pct: float = 20.0,
    range_col: int = 0,
    range_width: Tuple[float, float] = (0.05, 0.3),
    jitter: float = 0.05,
) -> List[WorkItem]:
    """One Poisson-replayable stream mixing QUERIES with index mutations.

    ``mutation_frac`` of the stream is upsert/delete traffic (split by
    ``delete_frac``); the rest is the usual constrained-query mix. Upsert
    items carry the new vector + ``(label, attrs_row)`` operand; delete
    items carry no target — ``replay_churn`` picks a live id at submit time
    (the generator cannot know slot assignments that only exist once the
    runtime has processed earlier upserts).
    """
    rng = np.random.RandomState(seed)
    queries = mixed_workload(
        seed + 1, corpus, n_requests, n_labels,
        k_choices=k_choices, mix=mix, unequal_pct=unequal_pct,
        range_col=range_col, range_width=range_width, jitter=jitter,
    )
    vectors = np.asarray(corpus.vectors)
    labels = np.asarray(corpus.labels)
    attrs = None if corpus.attrs is None else np.asarray(corpus.attrs)
    n, d = vectors.shape

    items: List[WorkItem] = []
    for q in queries:
        if rng.rand() >= mutation_frac:
            items.append(q)
            continue
        if rng.rand() < delete_frac:
            items.append(
                WorkItem(np.zeros((0,), np.float32), 1, "delete", None, "delete")
            )
        else:
            pick = rng.randint(0, n)
            vec = vectors[pick] + rng.randn(d).astype(np.float32) * jitter
            arow = None if attrs is None else attrs[pick].copy()
            items.append(
                WorkItem(vec, 1, "upsert", (int(labels[pick]), arow), "upsert")
            )
    return items


def _is_virtual(clock) -> bool:
    """A ``VirtualClock`` or any wrapper exposing its advance surface
    (``FaultClock`` wraps one to own injected spike time)."""
    return isinstance(clock, VirtualClock) or (
        hasattr(clock, "advance") and hasattr(clock, "advance_to")
    )


def poisson_arrivals(
    rng: np.random.RandomState,
    n: int,
    rate: float,
    burst: Optional[Tuple[float, float, float]] = None,
) -> np.ndarray:
    """Cumulative Poisson arrival times for ``n`` items at ``rate`` qps.

    ``burst=(start_frac, end_frac, mult)`` multiplies the arrival rate by
    ``mult`` for the items whose *index* falls in that fraction of the
    stream — the overload window the SLO harness injects (a 5x burst in
    the middle third: ``(1/3, 2/3, 5.0)``).
    """
    gaps = rng.exponential(1.0 / rate, size=n)
    if burst is not None:
        lo_f, hi_f, mult = burst
        i0, i1 = int(lo_f * n), int(hi_f * n)
        gaps[i0:i1] /= float(mult)
    return np.cumsum(gaps)


def replay_churn(
    runtime: ServingRuntime,
    items: Sequence[WorkItem],
    rate: float,
    seed: int = 0,
    initial_live: Optional[Sequence[int]] = None,
    *,
    deadline_s: Optional[float] = None,
    retry: Optional[RetryPolicy] = None,
    burst: Optional[Tuple[float, float, float]] = None,
) -> Tuple[List[Optional[Response]], int]:
    """Drive a churn stream (queries + upserts/deletes) with Poisson arrivals.

    Like ``replay_poisson`` but routes mutation items through
    ``submit_upsert``/``submit_delete`` and tracks the live-id set as
    upsert responses surface slot assignments, so deletes always target an
    id that was live at submit time. Returns (responses aligned with items
    — None for rejected or skipped [no live id to delete] items, rejection
    count).

    ``deadline_s`` stamps each QUERY with an absolute deadline that many
    seconds after its submission instant (mutations stay deadline-free:
    an upsert shed for lateness would silently lose data). ``retry`` runs
    every submission under the client retry policy (retry.py); ``burst``
    is forwarded to ``poisson_arrivals``.
    """
    clock = runtime.clock
    if not _is_virtual(clock):
        raise TypeError("replay_churn needs a runtime built on a VirtualClock")
    rng = np.random.RandomState(seed)
    live: List[int] = list(
        initial_live
        if initial_live is not None
        else range(runtime.executor.index.pool.n_live)
    )
    arrivals = poisson_arrivals(rng, len(items), rate, burst)
    req_ids: List[Optional[int]] = []
    open_upserts: dict = {}

    def harvest_upserts() -> None:
        # Learn slot assignments as upsert responses complete, so later
        # deletes can target freshly inserted items too.
        for rid in list(open_upserts):
            resp = runtime.poll(rid)
            if resp is not None:
                open_upserts.pop(rid)
                _responses[rid] = resp
                if resp.filled:
                    live.append(int(resp.ids[0]))

    _responses: dict = {}
    rejected = 0
    for item, t_arr in zip(items, arrivals):
        clock.advance_to(t_arr)
        runtime.step()
        harvest_upserts()
        target: Optional[int] = None
        if item.family == "upsert":
            submit = lambda it=item: runtime.submit_upsert(it.query, *it.operand)
            deadline = None
        elif item.family == "delete":
            if not live:
                req_ids.append(None)
                continue
            target = live.pop(rng.randint(len(live)))
            submit = lambda t=target: runtime.submit_delete(t)
            deadline = None
        else:
            deadline = (
                None if deadline_s is None else runtime.clock() + deadline_s
            )
            submit = lambda it=item, dl=deadline: runtime.submit(
                it.query, it.k, it.family, it.operand, deadline=dl
            )
        try:
            if retry is not None:
                rid, _ = submit_with_retry(
                    runtime, submit, retry, rng, deadline=deadline
                )
                if rid is None:
                    raise AdmissionError("retry budget exhausted")
            else:
                rid = submit()
            if item.family == "upsert":
                open_upserts[rid] = True
            req_ids.append(rid)
        except AdmissionError:
            if target is not None:
                live.append(target)  # the delete was shed, the id stays live
            req_ids.append(None)
            rejected += 1
        runtime.step()
        harvest_upserts()
    while runtime.in_flight:
        clock.advance(runtime.batcher.max_wait)
        runtime.step()
        harvest_upserts()
    out: List[Optional[Response]] = []
    for rid in req_ids:
        if rid is None:
            out.append(None)
        elif rid in _responses:
            out.append(_responses[rid])
        else:
            out.append(runtime.poll(rid))
    return out, rejected


def replay_poisson(
    runtime: ServingRuntime,
    items: Sequence[WorkItem],
    rate: float,
    seed: int = 0,
    *,
    deadline_s: Optional[float] = None,
    retry: Optional[RetryPolicy] = None,
    burst: Optional[Tuple[float, float, float]] = None,
) -> Tuple[List[Optional[Response]], int]:
    """Drive ``items`` through the runtime with Poisson(rate) arrivals.

    Requires the runtime's clock to be a ``VirtualClock``. Returns
    (responses aligned with items — None for rejected requests, rejection
    count).

    ``deadline_s`` stamps each request with an absolute deadline that many
    seconds after its submission instant; ``retry`` runs submissions under
    the client retry policy (retry.py — backpressure becomes jittered
    backoff instead of an instant client-side shed); ``burst`` injects an
    overload window (``poisson_arrivals``).
    """
    clock = runtime.clock
    if not _is_virtual(clock):
        raise TypeError("replay_poisson needs a runtime built on a VirtualClock")
    rng = np.random.RandomState(seed)
    arrivals = poisson_arrivals(rng, len(items), rate, burst)
    req_ids: List[Optional[int]] = []
    rejected = 0
    for item, t_arr in zip(items, arrivals):
        clock.advance_to(t_arr)
        runtime.step()  # flush anything that came due while idle
        deadline = None if deadline_s is None else runtime.clock() + deadline_s
        submit = lambda it=item, dl=deadline: runtime.submit(
            it.query, it.k, it.family, it.operand, deadline=dl
        )
        try:
            if retry is not None:
                rid, _ = submit_with_retry(
                    runtime, submit, retry, rng, deadline=deadline
                )
                if rid is None:
                    rejected += 1
                req_ids.append(rid)
            else:
                req_ids.append(submit())
        except AdmissionError:
            req_ids.append(None)
            rejected += 1
        runtime.step()  # full buckets ship immediately
    while runtime.in_flight:
        clock.advance(runtime.batcher.max_wait)
        runtime.step()
    return [None if rid is None else runtime.poll(rid) for rid in req_ids], rejected
