"""Mixed-workload generation + Poisson replay for the serving runtime.

Real filtered-search traffic mixes constraint selectivities wildly (SIEVE's
workload study); this module synthesizes that: one stream interleaving
equal-label, unequal-X%, and numeric-range constraints with mixed per-query
``k`` and Poisson arrivals. Shared by the serve driver
(launch/serve.py) and the serving benchmark (benchmarks/bench_serving.py)
so both measure the same stream shape.

Replay runs in virtual time (``VirtualClock``): arrival gaps advance the
clock explicitly and the runtime adds each microbatch's measured execution
wall time, so latency percentiles are consistent arrival-to-completion
quantities even though the host replays the stream as fast as it can.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.constraints import WORD_BITS
from repro.core.types import Corpus
from repro.serving.runtime import ServingRuntime
from repro.serving.types import AdmissionError, Response, VirtualClock


@dataclasses.dataclass
class WorkItem:
    query: np.ndarray  # (d,) float32
    k: int
    family: str
    operand: object
    kind: str  # workload slice tag ("equal" | "unequal" | "range")


def label_words_row(labels: Sequence[int], n_labels: int) -> np.ndarray:
    """(Lw,) uint32 allowed-label bitmask row for one request."""
    row = np.zeros(((n_labels + WORD_BITS - 1) // WORD_BITS,), np.uint32)
    for lab in labels:
        row[lab // WORD_BITS] |= np.uint32(1) << np.uint32(lab % WORD_BITS)
    return row


def mixed_workload(
    seed: int,
    corpus: Corpus,
    n_requests: int,
    n_labels: int,
    *,
    k_choices: Tuple[int, ...] = (4, 8, 16),
    mix: Tuple[float, float, float] = (0.4, 0.4, 0.2),  # equal/unequal/range
    unequal_pct: float = 20.0,
    range_col: int = 0,
    range_width: Tuple[float, float] = (0.05, 0.3),
    jitter: float = 0.05,
) -> List[WorkItem]:
    """One heterogeneous stream: queries drawn near corpus points (the
    paper's protocol), each with its own k and constraint.

    Range windows are centered on the query point's own attribute value
    with width >= ``range_width[0]`` so every request is satisfiable by
    >= k corpus items in expectation (attrs ~ U[0, 1]).
    """
    rng = np.random.RandomState(seed)
    vectors = np.asarray(corpus.vectors)
    labels = np.asarray(corpus.labels)
    attrs = None if corpus.attrs is None else np.asarray(corpus.attrs)
    n, d = vectors.shape
    if mix[2] > 0 and attrs is None:
        raise ValueError("range slice requested but corpus has no attrs")

    items: List[WorkItem] = []
    kinds = rng.choice(3, size=n_requests, p=np.asarray(mix) / np.sum(mix))
    picks = rng.randint(0, n, size=n_requests)
    for kind_id, pick in zip(kinds, picks):
        q = vectors[pick] + rng.randn(d).astype(np.float32) * jitter
        k = int(rng.choice(k_choices))
        qlab = int(labels[pick])
        if kind_id == 0:
            items.append(WorkItem(q, k, "label", label_words_row([qlab], n_labels), "equal"))
        elif kind_id == 1:
            n_allowed = max(1, int(round(n_labels * unequal_pct / 100.0)))
            others = [lab for lab in range(n_labels) if lab != qlab]
            allowed = rng.choice(others, size=min(n_allowed, len(others)), replace=False)
            items.append(
                WorkItem(q, k, "label", label_words_row(list(allowed), n_labels), "unequal")
            )
        else:
            center = float(attrs[pick, range_col])
            width = float(rng.uniform(*range_width))
            lo, hi = center - width / 2, center + width / 2
            items.append(WorkItem(q, k, "range", (lo, hi, range_col), "range"))
    return items


def churn_workload(
    seed: int,
    corpus: Corpus,
    n_requests: int,
    n_labels: int,
    *,
    mutation_frac: float = 0.3,
    delete_frac: float = 0.5,
    k_choices: Tuple[int, ...] = (4, 8, 16),
    mix: Tuple[float, float, float] = (0.4, 0.4, 0.2),
    unequal_pct: float = 20.0,
    range_col: int = 0,
    range_width: Tuple[float, float] = (0.05, 0.3),
    jitter: float = 0.05,
) -> List[WorkItem]:
    """One Poisson-replayable stream mixing QUERIES with index mutations.

    ``mutation_frac`` of the stream is upsert/delete traffic (split by
    ``delete_frac``); the rest is the usual constrained-query mix. Upsert
    items carry the new vector + ``(label, attrs_row)`` operand; delete
    items carry no target — ``replay_churn`` picks a live id at submit time
    (the generator cannot know slot assignments that only exist once the
    runtime has processed earlier upserts).
    """
    rng = np.random.RandomState(seed)
    queries = mixed_workload(
        seed + 1, corpus, n_requests, n_labels,
        k_choices=k_choices, mix=mix, unequal_pct=unequal_pct,
        range_col=range_col, range_width=range_width, jitter=jitter,
    )
    vectors = np.asarray(corpus.vectors)
    labels = np.asarray(corpus.labels)
    attrs = None if corpus.attrs is None else np.asarray(corpus.attrs)
    n, d = vectors.shape

    items: List[WorkItem] = []
    for q in queries:
        if rng.rand() >= mutation_frac:
            items.append(q)
            continue
        if rng.rand() < delete_frac:
            items.append(
                WorkItem(np.zeros((0,), np.float32), 1, "delete", None, "delete")
            )
        else:
            pick = rng.randint(0, n)
            vec = vectors[pick] + rng.randn(d).astype(np.float32) * jitter
            arow = None if attrs is None else attrs[pick].copy()
            items.append(
                WorkItem(vec, 1, "upsert", (int(labels[pick]), arow), "upsert")
            )
    return items


def replay_churn(
    runtime: ServingRuntime,
    items: Sequence[WorkItem],
    rate: float,
    seed: int = 0,
    initial_live: Optional[Sequence[int]] = None,
) -> Tuple[List[Optional[Response]], int]:
    """Drive a churn stream (queries + upserts/deletes) with Poisson arrivals.

    Like ``replay_poisson`` but routes mutation items through
    ``submit_upsert``/``submit_delete`` and tracks the live-id set as
    upsert responses surface slot assignments, so deletes always target an
    id that was live at submit time. Returns (responses aligned with items
    — None for rejected or skipped [no live id to delete] items, rejection
    count).
    """
    clock = runtime.clock
    if not isinstance(clock, VirtualClock):
        raise TypeError("replay_churn needs a runtime built on a VirtualClock")
    rng = np.random.RandomState(seed)
    live: List[int] = list(
        initial_live
        if initial_live is not None
        else range(runtime.executor.index.pool.n_live)
    )
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(items)))
    req_ids: List[Optional[int]] = []
    open_upserts: dict = {}

    def harvest_upserts() -> None:
        # Learn slot assignments as upsert responses complete, so later
        # deletes can target freshly inserted items too.
        for rid in list(open_upserts):
            resp = runtime.poll(rid)
            if resp is not None:
                open_upserts.pop(rid)
                _responses[rid] = resp
                if resp.filled:
                    live.append(int(resp.ids[0]))

    _responses: dict = {}
    rejected = 0
    for item, t_arr in zip(items, arrivals):
        clock.advance_to(t_arr)
        runtime.step()
        harvest_upserts()
        target: Optional[int] = None
        try:
            if item.family == "upsert":
                rid = runtime.submit_upsert(item.query, *item.operand)
                open_upserts[rid] = True
            elif item.family == "delete":
                if not live:
                    req_ids.append(None)
                    continue
                target = live.pop(rng.randint(len(live)))
                rid = runtime.submit_delete(target)
            else:
                rid = runtime.submit(item.query, item.k, item.family, item.operand)
            req_ids.append(rid)
        except AdmissionError:
            if target is not None:
                live.append(target)  # the delete was shed, the id stays live
            req_ids.append(None)
            rejected += 1
        runtime.step()
        harvest_upserts()
    while runtime.in_flight:
        clock.advance(runtime.batcher.max_wait)
        runtime.step()
        harvest_upserts()
    out: List[Optional[Response]] = []
    for rid in req_ids:
        if rid is None:
            out.append(None)
        elif rid in _responses:
            out.append(_responses[rid])
        else:
            out.append(runtime.poll(rid))
    return out, rejected


def replay_poisson(
    runtime: ServingRuntime,
    items: Sequence[WorkItem],
    rate: float,
    seed: int = 0,
) -> Tuple[List[Optional[Response]], int]:
    """Drive ``items`` through the runtime with Poisson(rate) arrivals.

    Requires the runtime's clock to be a ``VirtualClock``. Returns
    (responses aligned with items — None for rejected requests, rejection
    count).
    """
    clock = runtime.clock
    if not isinstance(clock, VirtualClock):
        raise TypeError("replay_poisson needs a runtime built on a VirtualClock")
    rng = np.random.RandomState(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=len(items)))
    req_ids: List[Optional[int]] = []
    rejected = 0
    for item, t_arr in zip(items, arrivals):
        clock.advance_to(t_arr)
        runtime.step()  # flush anything that came due while idle
        try:
            req_ids.append(runtime.submit(item.query, item.k, item.family, item.operand))
        except AdmissionError:
            req_ids.append(None)
            rejected += 1
        runtime.step()  # full buckets ship immediately
    while runtime.in_flight:
        clock.advance(runtime.batcher.max_wait)
        runtime.step()
    return [None if rid is None else runtime.poll(rid) for rid in req_ids], rejected
