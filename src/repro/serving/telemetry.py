"""Per-request and aggregate serving metrics.

Everything the acceptance criteria and the adaptive controller read comes
through here: arrival-to-completion latency percentiles, fill rate split by
final tier (the escalation tier's worst-case fill is the "never return
padding" check), QPS over the completed window, dispatch/padding overhead,
and admission-rejection counts. Compile-cache hit rates live on the cache
itself (cache.py); the bench merges both into BENCH_PR4.json.
"""
from __future__ import annotations

from collections import Counter, deque
from typing import Deque, Dict, List, Sequence

import numpy as np

from repro.serving.types import Response


def percentile(xs: Sequence[float], p: float) -> float:
    """np.percentile with an empty-input nan guard, p in [0, 100]."""
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=np.float64), p))


class Telemetry:
    """Counters are unbounded aggregates; per-response records are kept in
    a bounded window (``max_history`` newest) so a long-lived server's
    memory stays flat — ``summary()`` percentiles describe that window."""

    def __init__(self, max_history: int = 65_536) -> None:
        self.responses: Deque[Response] = deque(maxlen=max_history)
        self.counters: Counter = Counter()

    # --- event hooks (runtime calls these) --------------------------------
    def on_submit(self) -> None:
        self.counters["submitted"] += 1

    def on_reject(self) -> None:
        self.counters["rejected"] += 1

    def on_dispatch(self, bucket: int, n_real: int) -> None:
        self.counters["batches"] += 1
        self.counters["dispatched_slots"] += bucket
        self.counters["dispatched_real"] += n_real
        self.counters["padded_slots"] += bucket - n_real

    def on_escalate(self) -> None:
        self.counters["escalations"] += 1

    def on_route(self, strategy: str) -> None:
        """Hybrid router verdicts: per-strategy admission counts."""
        self.counters[f"routed_{strategy}"] += 1

    def on_mutation(self, family: str, n: int) -> None:
        """Streaming mutations are counted, not mixed into the query
        latency/fill percentiles (they complete on the host, not through
        the compiled search path)."""
        self.counters[f"{family}s_applied"] += n

    def on_epoch_swap(self) -> None:
        self.counters["epoch_swaps"] += 1

    def on_complete(self, resp: Response) -> None:
        self.counters["completed"] += 1
        if resp.deadline_missed:
            self.counters["deadline_missed"] += 1
        self.responses.append(resp)

    # --- aggregates -------------------------------------------------------
    def summary(self) -> dict:
        rs = self.responses
        out: Dict[str, object] = dict(self.counters)
        if not rs:
            return out
        lat = [r.latency for r in rs]
        fills = [r.fill_frac for r in rs]
        makespan = max(r.complete_t for r in rs) - min(r.arrival_t for r in rs)
        out.update(
            qps=round(len(rs) / makespan, 1) if makespan > 0 else float("inf"),
            latency_p50=round(percentile(lat, 50), 6),
            latency_p99=round(percentile(lat, 99), 6),
            mean_fill_frac=round(sum(fills) / len(fills), 4),
            # worst-case fill at 99% coverage: 99% of requests fill at least
            # this fraction of their k
            p99_fill_frac=round(percentile(fills, 1), 4),
            underfilled=sum(1 for r in rs if r.filled < r.k),
        )
        # Fill split by final tier: the escalation tiers must not return
        # padding (the online analogue of the paper's under-fill fix).
        by_tier: Dict[int, List[Response]] = {}
        for r in rs:
            by_tier.setdefault(r.tier, []).append(r)
        out["tiers"] = {
            str(tier): {
                "n": len(group),
                "mean_fill_frac": round(
                    sum(g.fill_frac for g in group) / len(group), 4
                ),
                "p99_fill_frac": round(
                    percentile([g.fill_frac for g in group], 1), 4
                ),
            }
            for tier, group in sorted(by_tier.items())
        }
        # Fill/latency split by executor strategy (hybrid routing): the
        # crossover evidence the adaptive controller retunes on.
        by_strategy: Dict[str, List[Response]] = {}
        for r in rs:
            by_strategy.setdefault(r.strategy, []).append(r)
        out["strategies"] = {
            strat: {
                "n": len(group),
                "latency_p50": round(
                    percentile([g.latency for g in group], 50), 6
                ),
                "mean_fill_frac": round(
                    sum(g.fill_frac for g in group) / len(group), 4
                ),
            }
            for strat, group in sorted(by_strategy.items())
        }
        return out
