"""Per-request and aggregate serving metrics.

Everything the acceptance criteria and the adaptive controller read comes
through here: arrival-to-completion latency percentiles, fill rate split by
final tier (the escalation tier's worst-case fill is the "never return
padding" check), QPS over the completed window, dispatch/padding overhead,
and admission-rejection counts. Compile-cache hit rates live on the cache
itself (cache.py); the bench merges both into BENCH_PR4.json.

PR 7 adds the fault-tolerance ledger (DESIGN.md §10): every terminal
outcome is a counter — ``shed_expired`` / ``shed_overload`` (dropped at
flush time), ``degraded`` (served under the ladder), ``failed`` (executor
fault exhausted its retries), ``faults_injected`` + per-kind splits,
client ``retries`` and executor ``fault_retries``, and ``goodput`` (served
in-deadline with at least one filled slot — the number the SLO harness
optimizes). Latencies additionally land in a bucketed log-scale histogram
so p99 is readable from telemetry directly instead of recomputed from the
bounded response window.
"""
from __future__ import annotations

import math
from collections import Counter, deque
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.types import Response


def percentile(xs: Sequence[float], p: float) -> float:
    """np.percentile with an empty-input nan guard, p in [0, 100]."""
    if not xs:
        return float("nan")
    return float(np.percentile(np.asarray(xs, dtype=np.float64), p))


class LatencyHistogram:
    """Fixed log-spaced latency buckets: O(1) record, bounded memory, and
    quantiles that never look at individual samples — so a long-lived
    server's p99 covers its whole lifetime, not just the response window.

    Quantiles report the *upper edge* of the bucket holding the target
    rank (the conservative, Prometheus-style answer: the true quantile is
    at most this). Resolution is the bucket ratio (~12% per step at the
    default 96 buckets across 1µs..60s) — plenty against a 2x SLO bound.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 60.0, n_buckets: int = 96):
        self.lo = float(lo)
        self.hi = float(hi)
        self.n_buckets = int(n_buckets)
        self._log_lo = math.log(self.lo)
        self._log_ratio = (math.log(self.hi) - self._log_lo) / self.n_buckets
        # + 2: underflow bucket [0, lo) and overflow bucket [hi, inf)
        self.counts = np.zeros((self.n_buckets + 2,), np.int64)
        self.total = 0
        self.sum = 0.0

    def _bucket_of(self, x: float) -> int:
        if x < self.lo:
            return 0
        if x >= self.hi:
            return self.n_buckets + 1
        # Clamp against float rounding at the edges: log() of a value one
        # ulp under ``hi`` can land exactly on n_buckets (indexing into
        # the overflow bucket for an in-range value), and log() of ``lo``
        # itself can come out one ulp below _log_lo (indexing bucket 0).
        b = 1 + int((math.log(x) - self._log_lo) / self._log_ratio)
        return min(max(b, 1), self.n_buckets)

    def upper_edge(self, bucket: int) -> float:
        if bucket <= 0:
            return self.lo
        if bucket > self.n_buckets:
            return float("inf")
        return math.exp(self._log_lo + bucket * self._log_ratio)

    def record(self, latency: float) -> None:
        self.counts[self._bucket_of(float(latency))] += 1
        self.total += 1
        self.sum += float(latency)

    def quantile(self, p: float) -> float:
        """Upper bucket edge at percentile ``p`` in [0, 100]; nan when
        empty."""
        if self.total == 0:
            return float("nan")
        rank = math.ceil(self.total * (p / 100.0))
        rank = min(max(rank, 1), self.total)
        cum = 0
        for b, c in enumerate(self.counts):
            cum += int(c)
            if cum >= rank:
                return self.upper_edge(b)
        return float("inf")  # unreachable

    def summary(self) -> dict:
        return {
            "count": int(self.total),
            "mean": round(self.sum / self.total, 6) if self.total else None,
            "p50": round(self.quantile(50), 6) if self.total else None,
            "p99": round(self.quantile(99), 6) if self.total else None,
            "overflow": int(self.counts[-1]),
        }


class Telemetry:
    """Counters are unbounded aggregates; per-response records are kept in
    a bounded window (``max_history`` newest) so a long-lived server's
    memory stays flat — ``summary()`` percentiles describe that window
    (the latency histogram covers the full lifetime)."""

    def __init__(self, max_history: int = 65_536) -> None:
        self.responses: Deque[Response] = deque(maxlen=max_history)
        self.counters: Counter = Counter()
        self.latency_hist = LatencyHistogram()
        # Per-stage latency histograms fed from Response.trace breakdowns
        # (queue_wait | batch_wait | execute | overhead) when the runtime
        # runs with tracing on — where a p99 outlier spent its time.
        self.stage_hists: Dict[str, LatencyHistogram] = {}

    # --- event hooks (runtime calls these) --------------------------------
    def on_submit(self) -> None:
        self.counters["submitted"] += 1

    def on_reject(self) -> None:
        self.counters["rejected"] += 1

    def on_dispatch(self, bucket: int, n_real: int) -> None:
        self.counters["batches"] += 1
        self.counters["dispatched_slots"] += bucket
        self.counters["dispatched_real"] += n_real
        self.counters["padded_slots"] += bucket - n_real

    def on_escalate(self) -> None:
        self.counters["escalations"] += 1

    def on_route(self, strategy: str) -> None:
        """Hybrid router verdicts: per-strategy admission counts."""
        self.counters[f"routed_{strategy}"] += 1

    def on_mutation(self, family: str, n: int) -> None:
        """Streaming mutations are counted, not mixed into the query
        latency/fill percentiles (they complete on the host, not through
        the compiled search path)."""
        self.counters[f"{family}s_applied"] += n

    def on_epoch_swap(self) -> None:
        self.counters["epoch_swaps"] += 1

    def on_shed(self, resp: Response) -> None:
        """A request dropped at flush time (``shed_reason`` "expired" |
        "overload"). Shed responses are pollable and counted, but stay out
        of the latency/fill window — a shed costs microseconds and would
        flatter every percentile it joined."""
        self.counters[f"shed_{resp.shed_reason}"] += 1
        self.counters["shed_total"] += 1
        if resp.deadline_missed:
            self.counters["deadline_missed"] += 1

    def on_fault(self, kind: str) -> None:
        """One injected (or real) executor fault observed by the runtime."""
        self.counters["faults_injected"] += 1
        self.counters[f"fault_{kind}"] += 1

    def on_fault_retry(self) -> None:
        """A faulted request re-queued within its executor-retry budget."""
        self.counters["fault_retries"] += 1

    def on_complete(self, resp: Response) -> None:
        self.counters["completed"] += 1
        if resp.deadline_missed:
            self.counters["deadline_missed"] += 1
        if resp.degraded:
            self.counters["degraded"] += 1
        if resp.error is not None:
            self.counters["failed"] += 1
        else:
            self.latency_hist.record(resp.latency)
            # Fill accounting as plain counters so "equal fill" is
            # measurable from a /metrics scrape alone (the replica-tier
            # bench reads filled_slots / requested_slots, never telemetry).
            self.counters["filled_slots"] += int(resp.filled)
            self.counters["requested_slots"] += int(resp.k)
            if resp.trace is not None:
                for stage in ("queue_wait", "batch_wait", "execute", "overhead"):
                    hist = self.stage_hists.get(stage)
                    if hist is None:
                        hist = self.stage_hists[stage] = LatencyHistogram()
                    hist.record(float(resp.trace[stage]))
        if self._is_goodput(resp):
            # Goodput: answers that arrived in time with something in
            # them — the quantity overload policy is allowed to optimize
            # (a fast shed and a late fill both score zero).
            self.counters["goodput"] += 1
        self.responses.append(resp)

    # --- aggregates -------------------------------------------------------
    @staticmethod
    def _is_goodput(resp: Response) -> bool:
        return resp.ok and not resp.deadline_missed and resp.filled > 0

    def goodput_in_window(self) -> int:
        """Goodput responses still inside the bounded response window."""
        return sum(1 for r in self.responses if self._is_goodput(r))

    def goodput_rate(self, window_s: Optional[float] = None) -> float:
        """Goodput per second of served time (completion-window span).

        Both numerator and denominator are WINDOW-scoped: the lifetime
        ``goodput`` counter over the bounded window's span would inflate
        the rate as soon as ``max_history`` evicts old responses (the
        counter keeps every served request forever; the span only covers
        the newest ``max_history``)."""
        if window_s is None:
            rs = self.responses
            if not rs:
                return 0.0
            window_s = max(r.complete_t for r in rs) - min(
                r.arrival_t for r in rs
            )
        return self.goodput_in_window() / window_s if window_s > 0 else 0.0

    def summary(self) -> dict:
        rs = self.responses
        out: Dict[str, object] = dict(self.counters)
        out["latency_hist"] = self.latency_hist.summary()
        if self.stage_hists:
            out["stages"] = {
                stage: hist.summary()
                for stage, hist in sorted(self.stage_hists.items())
            }
        if not rs:
            return out
        lat = [r.latency for r in rs]
        fills = [r.fill_frac for r in rs]
        makespan = max(r.complete_t for r in rs) - min(r.arrival_t for r in rs)
        out.update(
            qps=round(len(rs) / makespan, 1) if makespan > 0 else float("inf"),
            goodput_qps=(
                round(self.goodput_rate(makespan), 1)
                if makespan > 0
                else float("inf")
            ),
            latency_p50=round(percentile(lat, 50), 6),
            latency_p99=round(percentile(lat, 99), 6),
            mean_fill_frac=round(sum(fills) / len(fills), 4),
            # worst-case fill at 99% coverage: 99% of requests fill at least
            # this fraction of their k
            p99_fill_frac=round(percentile(fills, 1), 4),
            underfilled=sum(1 for r in rs if r.filled < r.k),
        )
        # Fill split by final tier: the escalation tiers must not return
        # padding (the online analogue of the paper's under-fill fix).
        by_tier: Dict[int, List[Response]] = {}
        for r in rs:
            by_tier.setdefault(r.tier, []).append(r)
        out["tiers"] = {
            str(tier): {
                "n": len(group),
                "mean_fill_frac": round(
                    sum(g.fill_frac for g in group) / len(group), 4
                ),
                "p99_fill_frac": round(
                    percentile([g.fill_frac for g in group], 1), 4
                ),
            }
            for tier, group in sorted(by_tier.items())
        }
        # Fill/latency split by executor strategy (hybrid routing): the
        # crossover evidence the adaptive controller retunes on.
        by_strategy: Dict[str, List[Response]] = {}
        for r in rs:
            by_strategy.setdefault(r.strategy, []).append(r)
        out["strategies"] = {
            strat: {
                "n": len(group),
                "latency_p50": round(
                    percentile([g.latency for g in group], 50), 6
                ),
                "mean_fill_frac": round(
                    sum(g.fill_frac for g in group) / len(group), 4
                ),
            }
            for strat, group in sorted(by_strategy.items())
        }
        return out


# The ops-facing registry surface is the real thing now: repro.obs
# (``MetricsRegistry`` + ``instrument_runtime``) exposes every counter and
# histogram here — plus cache/batcher/ladder/slot-pool gauges — in
# Prometheus text format behind ``GET /metrics`` (DESIGN.md §12). The old
# ``TelemetryRegistry = Telemetry`` alias is gone; adapt via
# ``repro.obs.instrument_runtime(runtime)``.
