"""SLO degradation ladder: overload detection with hysteresis (DESIGN.md §10).

Under a traffic burst the runtime cannot serve every request at full
quality *and* on time; the ladder decides which to give up, stepwise:

    level 0  normal        — full tier ladder, escalations allowed
    level 1  capped        — new requests start at tier 0, under-fill
                             escalations to the retry tier are suppressed
                             (the single biggest compute saving: a retry
                             re-runs the query at 4x the budget)
    level 2  cheap-first   — additionally, the PR 6 strategy router is
                             asked to prefer the host-side posting /
                             overlay executors wherever they are
                             applicable, keeping bursts off the compiled
                             graph path entirely
    level 3  shedding      — additionally, requests whose deadline is
                             provably unmeetable (sooner than the observed
                             service-latency EMA) are shed at flush time
                             with ``shed_reason="overload"`` instead of
                             burning a search they cannot use

The detector folds two signals into EMAs: the batcher's queue depth
(observed once per ``step``) and completed-response latency (observed per
response). A level moves only after the overloaded/calm condition holds
for ``hold_up``/``hold_down`` consecutive load observations — hysteresis,
so one slow batch does not flap the ladder and the ladder recovers after
the burst instead of latching degraded forever.

Everything here is pure bookkeeping: no clock access (latency samples
arrive from outside), no jax, so the ladder is trivially deterministic
under virtual-time replay and fault injection.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    """Overload thresholds + hysteresis for the degradation ladder."""

    # Observed arrival-to-completion latency above this is an SLO breach
    # signal (seconds; compare your workload's deadline).
    target_latency: float = 0.05
    # Queue depth (batcher pending + in flight) EMA >= high -> overloaded;
    # <= low (with latency also healthy) -> calm. low < high = hysteresis
    # band: between the two, the ladder holds its current level.
    queue_high: int = 64
    queue_low: int = 8
    ema_alpha: float = 0.25
    # Consecutive overloaded/calm load observations before a level moves.
    hold_up: int = 2
    hold_down: int = 4
    max_level: int = 3
    # Latency recovery margin: calm additionally needs the latency EMA
    # under margin * target (recovering at exactly the target would flap).
    recover_margin: float = 0.8
    # Load observations without a single completion before the latency EMA
    # stops counting as an overload signal. Without this the ladder can
    # death-spiral: level 3 sheds everything -> zero completions -> the EMA
    # freezes at its burst-era high -> level 3 latches forever. A stale EMA
    # means "we have no current latency evidence", not "still slow".
    lat_stale_after: int = 8


class DegradationLadder:
    """Hysteretic overload detector + the level the runtime acts on."""

    def __init__(self, config: Optional[SLOConfig] = None):
        self.config = config or SLOConfig()
        self.level = 0
        self.queue_ema: Optional[float] = None
        self.lat_ema: Optional[float] = None
        # Execution-only dispatch duration EMA: what one more dispatch
        # would cost *now*, free of the queue-wait that inflates lat_ema
        # during a burst — the honest basis for predictive shedding.
        self.service_ema: Optional[float] = None
        self._lat_obs_at = 0
        self._up_held = 0
        self._down_held = 0
        self.observations = 0
        # (observation index, old level, new level) — bounded; a ladder
        # that transitions thousands of times is flapping, which the
        # hysteresis test asserts against.
        self.transitions: List[Tuple[int, int, int]] = []

    # --- signal intake ----------------------------------------------------
    def observe_latency(self, latency: float) -> None:
        """Fold one completed response's latency into the EMA. Does NOT
        move the level — transitions happen at load observations only, so
        the hold counters count runtime steps, not responses."""
        a = self.config.ema_alpha
        self.lat_ema = (
            float(latency)
            if self.lat_ema is None
            else (1 - a) * self.lat_ema + a * float(latency)
        )
        self._lat_obs_at = self.observations

    def observe_service(self, duration: float) -> None:
        """Fold one dispatch's measured *execution* duration (no queue
        wait) into the service-time EMA used by ``predicted_miss``."""
        a = self.config.ema_alpha
        self.service_ema = (
            float(duration)
            if self.service_ema is None
            else (1 - a) * self.service_ema + a * float(duration)
        )

    def observe_load(self, queue_depth: int) -> int:
        """Fold one queue-depth sample, then step the level (with
        hysteresis) and return it. Called once per runtime ``step``."""
        a = self.config.ema_alpha
        self.queue_ema = (
            float(queue_depth)
            if self.queue_ema is None
            else (1 - a) * self.queue_ema + a * float(queue_depth)
        )
        self.observations += 1
        cfg = self.config
        # A latency EMA with no completion behind it for lat_stale_after
        # steps is evidence of *shedding*, not of slowness: it must not
        # keep the ladder pinned up (see SLOConfig.lat_stale_after).
        lat_stale = self.observations - self._lat_obs_at > cfg.lat_stale_after
        lat_known = self.lat_ema is not None and not lat_stale
        lat_hot = lat_known and self.lat_ema > cfg.target_latency
        lat_calm = (
            not lat_known
            or self.lat_ema <= cfg.recover_margin * cfg.target_latency
        )
        overloaded = self.queue_ema >= cfg.queue_high or lat_hot
        calm = self.queue_ema <= cfg.queue_low and lat_calm

        if overloaded:
            self._up_held += 1
            self._down_held = 0
            if self._up_held >= cfg.hold_up and self.level < cfg.max_level:
                self._move(self.level + 1)
                self._up_held = 0
        elif calm:
            self._down_held += 1
            self._up_held = 0
            if self._down_held >= cfg.hold_down and self.level > 0:
                self._move(self.level - 1)
                self._down_held = 0
        else:  # hysteresis band: hold the level, reset both counters
            self._up_held = 0
            self._down_held = 0
        return self.level

    def _move(self, new_level: int) -> None:
        self.transitions.append((self.observations, self.level, new_level))
        self.level = new_level

    # --- what the runtime acts on ----------------------------------------
    @property
    def force_base_tier(self) -> bool:
        """New requests start at tier 0 regardless of the family default."""
        return self.level >= 1

    @property
    def cap_escalations(self) -> bool:
        """Suppress under-fill escalations to the retry tier."""
        return self.level >= 1

    @property
    def prefer_cheap(self) -> bool:
        """Ask the strategy router to prefer posting/overlay executors."""
        return self.level >= 2

    @property
    def shed_predicted(self) -> bool:
        """Shed flush-time requests whose deadline the latency EMA says
        cannot be met (``shed_reason="overload"``)."""
        return self.level >= 3

    def predicted_miss(self, deadline: Optional[float], now: float) -> bool:
        """True when ``deadline`` is sooner than one more dispatch can
        possibly finish (only consulted at level 3). Uses the execution-only
        service EMA: the arrival-to-completion EMA would double-count the
        burst's queue wait, which a flush-time request no longer pays —
        predicting with it sheds requests that would in fact make it."""
        if deadline is None:
            return False
        est = self.service_ema if self.service_ema is not None else self.lat_ema
        if est is None:
            return False
        return now + est > deadline

    def snapshot(self) -> dict:
        return {
            "level": self.level,
            "queue_ema": None if self.queue_ema is None else round(self.queue_ema, 2),
            "lat_ema": None if self.lat_ema is None else round(self.lat_ema, 6),
            "service_ema": (
                None if self.service_ema is None else round(self.service_ema, 6)
            ),
            "observations": self.observations,
            "transitions": len(self.transitions),
        }
