"""Shared-nothing replica tier over N ``ServingRuntime``s (DESIGN.md §13).

Each replica owns its whole serving stack — compile cache, batcher,
controller, telemetry, and (streaming) slot pool — so replicas never share
mutable state and never contend on one lock. The tier adds exactly three
things on top:

  * a pluggable ``ReplicaRouter`` deciding which replica serves each query
    (``ConsistentHashRouter`` by request key for compile-cache affinity;
    ``LeastLoadedRouter`` by the pending-depth gauge as the alternative);
  * per-replica ``RLock``s — the submit/step/drain critical section is per
    replica, so one slow replica (or its shutdown drain) can never stall
    the others or the front-end's read-only surfaces;
  * epoch-consistent mutation broadcast: upserts/deletes are enqueued into
    EVERY replica's batcher under all replica locks at once, so no replica
    can flush the mutation before the others have it. Each replica then
    applies it at its own next flush boundary with the PR 5 atomic
    snapshot swap — replicas built from the same seed state and fed the
    same broadcast order assign identical slot ids and converge to the
    same epoch at quiesce.

The tier deliberately quacks enough like a single runtime for the HTTP
front-end (``repro.obs.http``) to serve either: it exposes ``replicas``,
``locks``, ``submit``/``poll`` (routed), broadcast mutations, ``drain``,
``in_flight`` and ``report``.
"""
from __future__ import annotations

import bisect
import hashlib
import threading
from typing import Callable, List, Optional, Sequence, Tuple

from repro.serving.types import AdmissionError


def _hash64(key) -> int:
    """Stable 64-bit hash (process-independent — ``hash()`` is salted)."""
    digest = hashlib.blake2b(str(key).encode(), digest_size=8).digest()
    return int.from_bytes(digest, "big")


class ConsistentHashRouter:
    """Hash-ring routing by request key.

    Each replica owns ``vnodes`` points on a 64-bit ring; a key routes to
    the first point clockwise of its hash. Two properties the tests pin:
    the mapping is deterministic across processes (blake2b, not the salted
    builtin), and resizing N -> N+1 moves only the keys landing on the new
    replica's arcs — expected fraction 1/(N+1), never a full reshuffle
    (the compile-cache-affinity argument for hash routing).
    """

    name = "hash"

    def __init__(self, n_replicas: int, vnodes: int = 64):
        if n_replicas <= 0:
            raise ValueError(f"need at least one replica: {n_replicas}")
        self.n_replicas = int(n_replicas)
        self.vnodes = int(vnodes)
        points: List[Tuple[int, int]] = []
        for i in range(self.n_replicas):
            for v in range(self.vnodes):
                points.append((_hash64(f"replica-{i}/vnode-{v}"), i))
        points.sort()
        self._points = [p for p, _ in points]
        self._owners = [i for _, i in points]

    def route(self, key, loads: Optional[Sequence[int]] = None) -> int:
        del loads  # hash routing ignores load
        idx = bisect.bisect_right(self._points, _hash64(key))
        if idx == len(self._points):
            idx = 0  # wrap past the last ring point
        return self._owners[idx]


class LeastLoadedRouter:
    """Route to the replica with the smallest pending depth (the
    ``queue_depth`` gauge); ties break to the lowest replica index so the
    verdict is deterministic."""

    name = "least-loaded"

    def __init__(self, n_replicas: int):
        if n_replicas <= 0:
            raise ValueError(f"need at least one replica: {n_replicas}")
        self.n_replicas = int(n_replicas)

    def route(self, key, loads: Sequence[int]) -> int:
        del key
        if len(loads) != self.n_replicas:
            raise ValueError(
                f"{len(loads)} loads for {self.n_replicas} replicas"
            )
        return min(range(self.n_replicas), key=lambda i: (loads[i], i))


ROUTER_KINDS = ("hash", "least-loaded")


def make_replica_router(kind: str, n_replicas: int):
    if kind == "hash":
        return ConsistentHashRouter(n_replicas)
    if kind == "least-loaded":
        return LeastLoadedRouter(n_replicas)
    raise ValueError(f"unknown router {kind!r} (have {ROUTER_KINDS})")


class ReplicaSet:
    """N shared-nothing runtimes + router + per-replica locks."""

    def __init__(self, replicas: Sequence, router=None, logger=None):
        if not replicas:
            raise ValueError("a replica tier needs at least one runtime")
        self.replicas = list(replicas)
        self.locks = [threading.RLock() for _ in self.replicas]
        self.router = router or ConsistentHashRouter(len(self.replicas))
        for i, rt in enumerate(self.replicas):
            rt.replica_id = i
        # One tier-wide monotonic key: the hash router's request key and
        # the submitted counter the tier-level metrics expose.
        self._submitted = 0
        self._state_lock = threading.Lock()
        if logger is not None:
            self.attach_logger(logger)

    # --- shape -----------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    @property
    def n_labels(self) -> int:
        return self.replicas[0].n_labels

    @property
    def submitted(self) -> int:
        return self._submitted

    @property
    def in_flight(self) -> int:
        return sum(rt.in_flight for rt in self.replicas)

    def pending(self) -> int:
        return sum(rt.batcher.pending_count() for rt in self.replicas)

    def loads(self) -> List[int]:
        """Pending-depth gauge per replica (what LeastLoadedRouter reads)."""
        return [rt.batcher.pending_count() for rt in self.replicas]

    def epochs(self) -> List[Optional[int]]:
        return [getattr(rt.executor, "epoch", None) for rt in self.replicas]

    def attach_logger(self, logger) -> None:
        """Give each replica a child logger bound to its replica id (one
        shared ring sink, per-replica clocks)."""
        for i, rt in enumerate(self.replicas):
            if rt.logger is None:
                child = logger.bind(replica=i)
                child.clock = rt.clock
                rt.logger = child

    def warmup(self) -> int:
        return sum(rt.warmup() for rt in self.replicas)

    # --- queries ---------------------------------------------------------
    def submit(
        self,
        query,
        k: int,
        family: str,
        operand,
        deadline_s: Optional[float] = None,
    ) -> Tuple[int, int]:
        """Route one query; returns ``(replica, local req_id)`` — replicas
        number their own requests, so the pair is the tier-global handle.
        ``deadline_s`` is relative: the absolute deadline is computed
        against the ROUTED replica's clock (each replica owns its own
        timeline)."""
        with self._state_lock:
            key = self._submitted
            self._submitted += 1
        i = self.router.route(key, self.loads())
        rt = self.replicas[i]
        with self.locks[i]:
            deadline = rt.clock() + deadline_s if deadline_s is not None else None
            req_id = rt.submit(query, k, family, operand, deadline=deadline)
        return i, req_id

    def poll(self, replica: int, req_id: int):
        with self.locks[replica]:
            return self.replicas[replica].poll(req_id)

    # --- mutation broadcast ----------------------------------------------
    def _broadcast(self, fn: Callable) -> Tuple[Tuple[int, int], ...]:
        """Enqueue one mutation into every replica under ALL replica locks
        (acquired in index order — every broadcaster uses the same order,
        so no deadlock). Holding all locks means no replica can reach its
        next flush boundary before every replica has the mutation: each
        one's atomic snapshot swap then publishes it at its own next
        flush, and replicas fed the same broadcast order stay identical."""
        acquired = []
        try:
            for lk in self.locks:
                lk.acquire()
                acquired.append(lk)
            # All-or-nothing admission: a partial broadcast (one replica
            # full, the rest enqueued) would diverge the replicas forever,
            # so capacity is checked everywhere before anything enqueues.
            for i, rt in enumerate(self.replicas):
                if rt.in_flight >= rt.max_pending:
                    raise AdmissionError(
                        f"replica {i} at max_pending={rt.max_pending}; "
                        "broadcast refused"
                    )
            with self._state_lock:
                self._submitted += 1
            return tuple(
                (i, fn(rt)) for i, rt in enumerate(self.replicas)
            )
        finally:
            for lk in reversed(acquired):
                lk.release()

    def submit_upsert(
        self, vector, label: int = 0, attrs=None
    ) -> Tuple[Tuple[int, int], ...]:
        """Broadcast one insert; returns ``((replica, req_id), ...)`` for
        every replica."""
        return self._broadcast(
            lambda rt: rt.submit_upsert(vector, label=label, attrs=attrs)
        )

    def submit_delete(self, slot: int) -> Tuple[Tuple[int, int], ...]:
        """Broadcast one tombstone delete of ``slot`` (slot ids agree
        across replicas by the identical-history construction)."""
        return self._broadcast(lambda rt: rt.submit_delete(slot))

    def poll_all(self, handles: Sequence[Tuple[int, int]]) -> list:
        """Poll a broadcast's handles; None entries are still pending."""
        return [self.poll(i, rid) for i, rid in handles]

    # --- pump / shutdown --------------------------------------------------
    def step_all(self, force: bool = False) -> int:
        done = 0
        for i, rt in enumerate(self.replicas):
            with self.locks[i]:
                done += rt.step(force=force)
        return done

    def drain(self) -> int:
        """Drain every replica concurrently (each under its own lock) —
        total completions returned; zero in-flight loss by the runtime's
        own drain contract."""
        drained = [0] * len(self.replicas)

        def _one(i: int) -> None:
            with self.locks[i]:
                drained[i] = self.replicas[i].drain()

        threads = [
            threading.Thread(target=_one, args=(i,), name=f"replica-drain-{i}")
            for i in range(len(self.replicas))
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return sum(drained)

    # --- reporting --------------------------------------------------------
    def report(self) -> dict:
        return {
            "replicas": [rt.report() for rt in self.replicas],
            "n_replicas": self.n_replicas,
            "router": self.router.name,
            "submitted": self._submitted,
            "in_flight": self.in_flight,
            "pending": self.pending(),
            "epochs": self.epochs(),
        }
