"""Dynamic batcher: group compatible requests, pad to a bucket ladder.

Compiled search closures are fixed-shape, so per-request dispatch would
either retrace per batch size (unbounded compiles) or serialize everything
at batch=1 (no vectorization). The batcher quantizes instead: requests are
grouped by ``(family[, range col], tier)`` and shipped as microbatches
padded to a small ladder of batch sizes (default {8, 32, 128}) — so the
compile-cache key space is |ladder| x |families| x |tiers| no matter what
the stream looks like (DESIGN.md §7).

Flush policy per group:
  * whenever a group holds >= max(ladder) requests, full top-size buckets
    ship immediately (no timeout needed to reach peak throughput);
  * a group whose oldest enqueued request has waited ``max_wait`` — or
    whose earliest deadline has arrived — drains completely, greedily
    packing the largest ladder sizes that fill with real requests and
    padding only the final partial bucket up to the smallest size that
    admits it (padding waste < min(ladder) requests per flush);
  * ``force=True`` drains everything (used by ``ServingRuntime.drain``).

Padding repeats the last real request's query + operand so padded lanes
cost one realistic traversal each and are discarded on the way out.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Tuple

from repro.serving.types import Request, deadline_due

BATCH_LADDER = (8, 32, 128)


@dataclasses.dataclass
class MicroBatch:
    group: tuple  # (family[, col])
    tier: int
    bucket: int  # padded batch size (a ladder entry)
    requests: List[Request]  # len <= bucket, all sharing (group, tier)
    # Monotonic dispatch id stamped by the runtime at flush time — the
    # correlation key between structured log records and Response.batch_id.
    batch_id: int = -1

    @property
    def family(self) -> str:
        return self.group[0]

    @property
    def strategy(self) -> str:
        """Executor strategy shared by the batch ("graph" unless the hybrid
        router stamped something else; group keys separate strategies)."""
        return self.requests[0].strategy if self.requests else "graph"

    @property
    def n_real(self) -> int:
        return len(self.requests)

    @property
    def n_padded(self) -> int:
        return self.bucket - len(self.requests)


def bucket_for(n: int, ladder: Tuple[int, ...]) -> int:
    """Smallest ladder size admitting n requests (n <= max(ladder))."""
    for b in ladder:
        if n <= b:
            return b
    raise ValueError(f"batch {n} exceeds ladder {ladder}")


class DynamicBatcher:
    def __init__(self, ladder: Tuple[int, ...] = BATCH_LADDER, max_wait: float = 0.002):
        if not ladder or list(ladder) != sorted(set(ladder)):
            raise ValueError(f"ladder must be sorted unique sizes: {ladder}")
        self.ladder = tuple(int(b) for b in ladder)
        self.max_wait = float(max_wait)
        self._pending: Dict[tuple, Deque[Request]] = {}

    def add(self, req: Request, now: float) -> None:
        req.enqueue_t = now
        key = (req.group(), req.tier)
        self._pending.setdefault(key, deque()).append(req)

    def pending_count(self) -> int:
        return sum(len(q) for q in self._pending.values())

    def occupancy(self) -> Dict[tuple, int]:
        """Pending requests per (group, tier) key — the bucket-occupancy
        gauge the metrics registry exposes (obs/adapters.py)."""
        return {key: len(q) for key, q in self._pending.items() if q}

    def _due(self, reqs: Deque[Request], now: float) -> bool:
        oldest = min(r.enqueue_t for r in reqs)
        if now - oldest >= self.max_wait:
            return True
        # Shared boundary semantics (types.deadline_due): at now ==
        # deadline the request ships — its last meetable instant.
        return any(deadline_due(r.deadline, now) for r in reqs)

    def _drain_group(self, reqs: Deque[Request]) -> List[Tuple[int, List[Request]]]:
        """Greedy ladder packing: largest fully-real buckets first, pad only
        the final partial one."""
        out: List[Tuple[int, List[Request]]] = []
        while reqs:
            n = len(reqs)
            full = [b for b in self.ladder if b <= n]
            take = max(full) if full else n
            chunk = [reqs.popleft() for _ in range(take)]
            out.append((bucket_for(take, self.ladder), chunk))
        return out

    def flush(self, now: float, force: bool = False) -> List[MicroBatch]:
        """Collect every microbatch due at ``now``; empty list when nothing
        is due (including the empty-batcher case)."""
        out: List[MicroBatch] = []
        top = self.ladder[-1]
        for key, reqs in list(self._pending.items()):
            group, tier = key
            # Full top-size buckets ship unconditionally.
            while len(reqs) >= top:
                chunk = [reqs.popleft() for _ in range(top)]
                out.append(MicroBatch(group=group, tier=tier, bucket=top, requests=chunk))
            if reqs and (force or self._due(reqs, now)):
                for bucket, chunk in self._drain_group(reqs):
                    out.append(
                        MicroBatch(group=group, tier=tier, bucket=bucket, requests=chunk)
                    )
            if not reqs:
                del self._pending[key]
        return out
