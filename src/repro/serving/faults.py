"""Seeded, deterministic fault injection for the serving runtime.

Every recovery path in DESIGN.md §10 is driven by *injected* faults in
tests and in ``benchmarks/bench_slo.py`` — not hoped-for in production:

  * executor exceptions — a compiled-search dispatch raises
    ``InjectedFault`` (an ``ExecutorFault``); the runtime retries the
    microbatch's requests through the batcher up to a per-request budget,
    then surfaces a *failed* ``Response`` (``error`` set) — never a hung
    or silently lost request;
  * latency spikes — a dispatch takes ``spike_s`` longer than measured;
    under virtual-time replay the spike advances the injected clock, so
    deadline misses caused by the spike are real in the timeline and the
    affected responses are marked ``faulted`` (and ``degraded``, so the
    "no unmarked late completion" invariant stays checkable);
  * stale-epoch snapshots — a streaming ``refresh()`` applies its
    mutations but *delays publishing* the new snapshot by one flush
    boundary: queries keep serving (and honestly reporting) the old
    epoch until the next swap catches up.

``FaultSchedule`` draws the fault sequence from one seeded RNG, so a
given (seed, rates) pair replays the identical fault pattern every run.
``FaultyExecutor`` wraps any executor (Local / StreamingLocal /
Distributed) and delegates everything it does not intercept, so the
runtime cannot tell it apart from the real thing — which is the point.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


class ExecutorFault(RuntimeError):
    """An executor-level failure the runtime is expected to survive
    (retry within budget, then surface as a failed ``Response``).
    Real executors should wrap infrastructure errors in this type to opt
    into the recovery path; anything else propagates as a bug."""


class InjectedFault(ExecutorFault):
    """An ``ExecutorFault`` raised by ``FaultyExecutor`` on schedule."""


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Rates are per *event*: error/spike per compiled-search dispatch,
    stale per ``refresh()`` (epoch swap). All draws come from one seeded
    RNG in event order, so the schedule is deterministic."""

    seed: int = 0
    error_rate: float = 0.0
    spike_rate: float = 0.0
    spike_s: float = 0.05
    stale_epoch_rate: float = 0.0
    max_faults: Optional[int] = None  # stop injecting after this many


class FaultSchedule:
    def __init__(self, config: FaultConfig):
        self.config = config
        self._rng = np.random.RandomState(config.seed)
        self.injected = 0
        self.by_kind: dict = {"error": 0, "spike": 0, "stale_epoch": 0}

    def _budget_left(self) -> bool:
        mx = self.config.max_faults
        return mx is None or self.injected < mx

    def _count(self, kind: str) -> str:
        self.injected += 1
        self.by_kind[kind] += 1
        return kind

    def draw_dispatch(self) -> Optional[str]:
        """Fault verdict for one compiled-search dispatch:
        "error" | "spike" | None."""
        r = float(self._rng.rand())
        if not self._budget_left():
            return None
        if r < self.config.error_rate:
            return self._count("error")
        if r < self.config.error_rate + self.config.spike_rate:
            return self._count("spike")
        return None

    def draw_refresh(self) -> bool:
        """True when this epoch swap should publish stale (delayed)."""
        r = float(self._rng.rand())
        if not self._budget_left():
            return False
        if r < self.config.stale_epoch_rate:
            self._count("stale_epoch")
            return True
        return False


class FaultClock:
    """Clock wrapper that owns spike time: reads delegate to the base
    clock, ``spike(dt)`` advances it (virtual clocks only) and accounts
    the injected seconds — so a test can assert exactly how much latency
    the schedule added to the timeline."""

    def __init__(self, base):
        self.base = base
        self.injected_s = 0.0

    def __call__(self) -> float:
        return self.base()

    def advance(self, dt: float) -> float:
        return self.base.advance(dt)

    def advance_to(self, t: float) -> float:
        return self.base.advance_to(t)

    def spike(self, dt: float) -> None:
        self.injected_s += float(dt)
        if hasattr(self.base, "advance"):
            self.base.advance(dt)
        # wall-clock base: the spike is accounted but cannot move real
        # time — dispatch-duration measurement will still include any
        # real slowness; injection is a virtual-time tool.


class FaultyExecutor:
    """Wraps an executor; injects the schedule's faults at its seams.

    Intercepts ``build`` (compiled-search dispatches: errors + spikes)
    and ``refresh`` (streaming epoch swaps: stale publication). Every
    other attribute — ``dim``, ``corpus``, ``index``, ``apply_mutations``,
    ``epoch``, ``traces`` — delegates to the wrapped executor, so
    capability probes (``hasattr``) see exactly the inner executor's
    surface. Host-side posting/overlay dispatches bypass ``build`` and
    are therefore not faultable (they share the runtime's process; an
    executor fault seam there would be injecting into ourselves).

    ``pop_faults()`` hands the runtime the kinds injected since the last
    pop, so telemetry counts and per-response ``faulted`` marks come from
    the injector's ground truth, not a parallel guess.
    """

    def __init__(self, inner, schedule: FaultSchedule, clock: Optional[FaultClock] = None):
        self.inner = inner
        self.schedule = schedule
        self.clock = clock
        # ``armed=False`` passes everything through clean: warmup's dummy
        # dispatches must neither fault nor consume schedule draws (the
        # measured run's fault pattern stays a pure (seed, rates) function).
        self.armed = True
        self._pending_kinds: List[str] = []

    def __getattr__(self, name):
        return getattr(self.inner, name)

    def pop_faults(self) -> List[str]:
        kinds, self._pending_kinds = self._pending_kinds, []
        return kinds

    def build(self, bucket: int, family: str, params):
        fn = self.inner.build(bucket, family, params)

        def faulty(queries, constraint):
            kind = self.schedule.draw_dispatch() if self.armed else None
            if kind == "error":
                self._pending_kinds.append(kind)
                raise InjectedFault(
                    f"injected executor fault #{self.schedule.injected} "
                    f"(bucket={bucket}, family={family})"
                )
            if kind == "spike":
                self._pending_kinds.append(kind)
                if self.clock is not None:
                    self.clock.spike(self.schedule.config.spike_s)
            return fn(queries, constraint)

        return faulty

    def refresh(self) -> int:
        if self.armed and self.schedule.draw_refresh():
            self._pending_kinds.append("stale_epoch")
            # Mutations (and any due consolidation) still apply; only the
            # snapshot publication is delayed one flush boundary — the
            # inner executor keeps serving, and honestly reporting, the
            # old epoch until the next refresh.
            stale = self.inner.snapshot
            self.inner.refresh()
            self.inner.snapshot = stale
            return stale.epoch
        return self.inner.refresh()
