"""Client-side retry policy: jittered exponential backoff on backpressure.

``AdmissionError`` is the runtime telling the caller "not now"; a client
that retries immediately just hammers the full queue, and one that never
retries converts transient overload into permanent sheds. The policy in
between: back off exponentially with jitter (decorrelates competing
clients), respect a per-request retry budget, and give up *early* when
the next attempt could not land before the request's deadline anyway —
deadline-aware give-up, so retry traffic never becomes a second source
of already-expired work.

Backoff waits go through the runtime's injected clock: a ``VirtualClock``
is advanced explicitly (deterministic replay), a wall clock is waited out
by pumping ``runtime.step()`` — which is what a real single-threaded
client would do anyway, and keeps this module free of direct wall-clock
calls (tests/test_no_wall_clock.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np

from repro.serving.types import AdmissionError


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_retries: int = 3
    base_backoff: float = 0.002  # seconds before the first retry
    multiplier: float = 2.0  # exponential growth per attempt
    jitter: float = 0.5  # +/- fraction of the backoff, uniform

    def backoff_for(self, attempt: int, rng: np.random.RandomState) -> float:
        base = self.base_backoff * self.multiplier**attempt
        if self.jitter:
            base *= 1.0 + self.jitter * float(2.0 * rng.rand() - 1.0)
        return max(base, 0.0)


def submit_with_retry(
    runtime,
    submit_fn: Callable[[], int],
    policy: RetryPolicy,
    rng: np.random.RandomState,
    deadline: Optional[float] = None,
) -> Tuple[Optional[int], int]:
    """Run ``submit_fn`` (a zero-arg closure over ``runtime.submit``/
    ``submit_upsert``/``submit_delete``) under the retry policy.

    Returns ``(req_id, retries_used)`` — ``req_id`` None when the budget
    ran out or the deadline made another attempt pointless (the caller
    sheds client-side; its accounting stays exact either way). Retries are
    counted into ``runtime.telemetry.counters["retries"]``.
    """
    attempt = 0
    while True:
        try:
            return submit_fn(), attempt
        except AdmissionError:
            if attempt >= policy.max_retries:
                return None, attempt
            backoff = policy.backoff_for(attempt, rng)
            now = runtime.clock()
            if deadline is not None and now + backoff > deadline:
                # Even if the retry were admitted instantly it would
                # already be expired-at-flush — give up now.
                return None, attempt
            attempt += 1
            runtime.telemetry.counters["retries"] += 1
            if hasattr(runtime.clock, "advance"):
                runtime.clock.advance(backoff)
                runtime.step()
            else:
                # Wall clock: pump the runtime until the backoff elapses
                # (each step drains work, which is what frees capacity).
                t_until = now + backoff
                while runtime.clock() < t_until:
                    runtime.step()
