"""Train-step builder: value_and_grad -> optimizer -> apply, with optional
gradient accumulation (microbatching) and gradient clipping.

Distribution is carried by shardings on params / optimizer state / batch
(GSPMD inserts the reductions); the builder only wires pure functions.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optimizer import Optimizer, apply_updates

Array = jax.Array
LossFn = Callable[[Any, dict], tuple[Array, dict]]  # (params, batch) -> (loss, metrics)


def global_norm(tree) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


def make_train_step(
    loss_fn: LossFn,
    optimizer: Optimizer,
    *,
    grad_accum: int = 1,
    clip_norm: float = 0.0,
):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With grad_accum > 1, the batch's leading axis is split into
    ``grad_accum`` microbatches scanned sequentially (activation memory /
    pipeline-bubble trade).
    """

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch
        )
        return loss, metrics, grads

    def step(params, opt_state, batch):
        if grad_accum > 1:
            micro_batches = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum) + x.shape[1:]),
                batch,
            )
            mb0 = jax.tree.map(lambda x: x[0], micro_batches)
            metrics_shape = jax.eval_shape(lambda: grads_of(params, mb0)[1])

            def micro(carry, mb):
                acc, msum = carry
                _, metrics, g = grads_of(params, mb)
                acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), acc, g)
                msum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), msum, metrics
                )
                return (acc, msum), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mzeros = jax.tree.map(
                lambda s: jnp.zeros(s.shape, jnp.float32), metrics_shape
            )
            (gsum, msum), _ = jax.lax.scan(micro, (zeros, mzeros), micro_batches)
            grads = jax.tree.map(lambda g: g / grad_accum, gsum)
            metrics = jax.tree.map(lambda m: m / grad_accum, msum)
        else:
            _, metrics, grads = grads_of(params, batch)

        if clip_norm > 0:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return step
