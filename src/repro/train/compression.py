"""Int8 gradient compression with error feedback, for slow (cross-pod)
gradient reductions.

``compressed_psum_mean(g, axis)`` quantizes each tensor to int8 with a
per-row (last-dim-block) scale, all-reduces the int32-widened payload, and
dequantizes; 4x fewer bytes than f32 / 2x fewer than bf16 on the wire. The
quantization residual is returned so callers can carry it as error feedback
(added back to the next step's gradient), which keeps SGD convergence
unbiased in expectation (1-bit Adam / EF-SGD lineage).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def quantize_int8(x: Array) -> tuple[Array, Array]:
    """Per-row symmetric int8 quantization. x (..., D) -> (q int8, scale)."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(
    x: Array, axis: str, err: Optional[Array] = None
) -> tuple[Array, Array]:
    """Mean all-reduce of ``x`` over mesh axis ``axis`` in int8.

    Returns (reduced mean, new error-feedback residual). Must be called
    inside shard_map (needs a named axis).
    """
    if err is not None:
        x = x + err.astype(x.dtype)
    q, scale = quantize_int8(x)
    deq = dequantize_int8(q, scale)
    new_err = (x.astype(jnp.float32) - deq).astype(x.dtype)
    # Widen before the wire-reduce; scales reduce alongside.
    total = jax.lax.psum(deq, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return (total / n).astype(x.dtype), new_err


def compressed_tree_psum_mean(tree, axis: str, err_tree=None):
    """Apply compressed_psum_mean leaf-wise over a gradient pytree."""
    if err_tree is None:
        err_tree = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    out = jax.tree.map(
        lambda g, e: compressed_psum_mean(g, axis, e), tree, err_tree
    )
    is_tup = lambda x: isinstance(x, tuple)
    reduced = jax.tree.map(lambda o: o[0], out, is_leaf=is_tup)
    new_err = jax.tree.map(lambda o: o[1], out, is_leaf=is_tup)
    return reduced, new_err
