"""Optimizers in pure JAX: AdamW and Adafactor.

Adafactor (factored second moment, optional bf16 momentum) is the default
for the largest configs: DeepSeek-V3 @ 671B with full f32 Adam state would
need ~8 TB of optimizer memory — factored stats bring the per-chip budget
inside a v5e's 16 GB at 256 chips (see EXPERIMENTS.md §Dry-run).

Optimizer state lives in a pytree mirroring the params; ``state_specs``
derives its PartitionSpecs from the param specs so ZeRO-style sharding
follows the parameters automatically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)
    state_specs: Callable[[Any, Any], Any]  # (param_specs, param_shapes)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw(
    lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    state_dtype=jnp.float32,
) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, state_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        count = state["count"] + 1
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(state_dtype)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * g32 * g32
            mhat = m / c1
            vhat = v / c2
            step = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step = step + weight_decay * p.astype(state_dtype)
            return (-lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": new_m, "v": new_v, "count": count}

    def state_specs(param_specs, param_shapes):
        del param_shapes
        return {"m": param_specs, "v": param_specs, "count": P()}

    return Optimizer(init=init, update=update, state_specs=state_specs)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored over the last two dims
# ---------------------------------------------------------------------------
def adafactor(
    lr: float = 1e-2,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    momentum: Optional[float] = None,
    momentum_dtype=jnp.bfloat16,
) -> Optimizer:
    def _factored(p) -> bool:
        return p.ndim >= 2

    def init(params):
        def vr(p):
            return (
                jnp.zeros(p.shape[:-1], jnp.float32)
                if _factored(p)
                else jnp.zeros(p.shape, jnp.float32)
            )

        def vc(p):
            return (
                jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                if _factored(p)
                else jnp.zeros((1,), jnp.float32)
            )

        state = {
            "vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "count": jnp.zeros((), jnp.int32),
        }
        if momentum is not None:
            state["m"] = jax.tree.map(
                lambda p: jnp.zeros(p.shape, momentum_dtype), params
            )
        return state

    def update(grads, state, params):
        count = state["count"] + 1
        beta2 = 1.0 - count.astype(jnp.float32) ** (-decay)

        def upd(g, vr, vc, p, m=None):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + eps
            if _factored(p):
                vr = beta2 * vr + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * vc + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                vhat = (
                    vr[..., None] / denom[..., None]
                ) * vc[..., None, :]
                step = g32 / jnp.sqrt(vhat + eps)
            else:
                vr = beta2 * vr + (1 - beta2) * g2
                step = g32 / jnp.sqrt(vr + eps)
            # Update clipping (RMS-based), per Adafactor.
            rms = jnp.sqrt(jnp.mean(step * step) + 1e-30)
            step = step / jnp.maximum(1.0, rms / clip_threshold)
            if m is not None:
                m_new = momentum * m.astype(jnp.float32) + step
                step = m_new
                m = m_new.astype(momentum_dtype)
            out = (-lr * step).astype(p.dtype)
            return out, vr, vc, m

        if momentum is not None:
            res = jax.tree.map(upd, grads, state["vr"], state["vc"], params, state["m"])
        else:
            res = jax.tree.map(
                lambda g, vr, vc, p: upd(g, vr, vc, p),
                grads, state["vr"], state["vc"], params,
            )
        is_tup = lambda x: isinstance(x, tuple)
        updates = jax.tree.map(lambda o: o[0], res, is_leaf=is_tup)
        new_state = {
            "vr": jax.tree.map(lambda o: o[1], res, is_leaf=is_tup),
            "vc": jax.tree.map(lambda o: o[2], res, is_leaf=is_tup),
            "count": count,
        }
        if momentum is not None:
            new_state["m"] = jax.tree.map(lambda o: o[3], res, is_leaf=is_tup)
        return updates, new_state

    def state_specs(param_specs, param_shapes):
        def vr_spec(spec, shape):
            s = tuple(spec) if spec else ()
            s = s + (None,) * (len(shape.shape) - len(s))
            return P(*s[:-1]) if len(shape.shape) >= 2 else P(*s)

        def vc_spec(spec, shape):
            s = tuple(spec) if spec else ()
            s = s + (None,) * (len(shape.shape) - len(s))
            if len(shape.shape) >= 2:
                return P(*(s[:-2] + (s[-1],)))
            return P(None)

        is_spec = lambda x: isinstance(x, P)
        specs = {
            "vr": jax.tree.map(vr_spec, param_specs, param_shapes, is_leaf=is_spec),
            "vc": jax.tree.map(vc_spec, param_specs, param_shapes, is_leaf=is_spec),
            "count": P(),
        }
        if momentum is not None:
            specs["m"] = param_specs
        return specs

    return Optimizer(init=init, update=update, state_specs=state_specs)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)
