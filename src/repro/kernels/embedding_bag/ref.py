"""Pure-jnp oracle for embedding_bag (the take + mask + sum formulation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def embedding_bag_ref(table: Array, ids: Array) -> Array:
    rows = table[jnp.maximum(ids, 0)].astype(jnp.float32)  # (B, L, D)
    mask = (ids >= 0).astype(jnp.float32)[..., None]
    return jnp.sum(rows * mask, axis=1)
