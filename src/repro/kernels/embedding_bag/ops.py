"""Public EmbeddingBag wrapper: sum / mean modes, kernel or jnp path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch_kernel
from repro.kernels.embedding_bag.embedding_bag import embedding_bag_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref

Array = jax.Array


def embedding_bag(
    table: Array,
    ids: Array,
    *,
    mode: str = "sum",
    force_kernel: bool = False,
) -> Array:
    fn, _ = dispatch_kernel(
        embedding_bag_kernel, embedding_bag_ref, force_kernel=force_kernel
    )
    out = fn(table, ids)
    if mode == "mean":
        counts = jnp.maximum(jnp.sum((ids >= 0), axis=-1, keepdims=True), 1)
        out = out / counts
    elif mode != "sum":
        raise ValueError(f"unknown mode {mode}")
    return out
