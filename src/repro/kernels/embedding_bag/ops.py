"""Public EmbeddingBag wrapper: sum / mean modes, kernel or jnp path."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.embedding_bag import embedding_bag_kernel
from repro.kernels.embedding_bag.ref import embedding_bag_ref

Array = jax.Array


def embedding_bag(
    table: Array,
    ids: Array,
    *,
    mode: str = "sum",
    force_kernel: bool = False,
) -> Array:
    backend = jax.default_backend()
    if backend == "tpu":
        out = embedding_bag_kernel(table, ids)
    elif force_kernel:
        out = embedding_bag_kernel(table, ids, interpret=True)
    else:
        out = embedding_bag_ref(table, ids)
    if mode == "mean":
        counts = jnp.maximum(jnp.sum((ids >= 0), axis=-1, keepdims=True), 1)
        out = out / counts
    elif mode != "sum":
        raise ValueError(f"unknown mode {mode}")
    return out
