"""EmbeddingBag (sum-mode) kernel — the recsys hot path.

JAX has no native EmbeddingBag; the framework's reference path is
``jnp.take`` + ``jax.ops.segment_sum``. This kernel fuses the two: for a bag
matrix IDS (B, L) over a table (V, D) it accumulates sum_l table[IDS[b, l]]
directly in a VMEM accumulator tile, one DMA'd table row per grid step,
scalar-prefetched ids driving the row index_map (same gather idiom as
``gather_distance``). Padding ids (< 0) contribute zero.

Out-block revisiting across the innermost grid axis keeps the accumulator
resident in VMEM for the whole bag — the (B, L, D) gathered intermediate the
jnp path materializes never exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(ids_ref, row_ref, out_ref, *, bag: int):
    b = pl.program_id(0)
    lane = pl.program_id(1)

    @pl.when(lane == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    valid = (ids_ref[b, lane] >= 0).astype(jnp.float32)
    out_ref[...] += valid * row_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag_kernel(
    table: Array, ids: Array, *, interpret: bool = False
) -> Array:
    """(V, D) table, (B, L) int32 ids -> (B, D) f32 bag sums."""
    _, dim = table.shape
    b, bag = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, bag),
        in_specs=[
            # Padding ids (< 0) are clamped in the index_map; the kernel
            # zero-weights them using the *unclamped* prefetched table.
            pl.BlockSpec(
                (1, dim), lambda i, j, ids_pref: (jnp.maximum(ids_pref[i, j], 0), 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda i, j, ids_pref: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, bag=bag),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, dim), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
