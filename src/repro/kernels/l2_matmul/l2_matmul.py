"""Tiled pairwise squared-L2 distance kernel (MXU path).

Computes D[i, j] = ||q_i - x_j||^2 for Q (M, d) and X (N, d) via the matmul
expansion  |q|^2 - 2 q·x + |x|^2  so the -2·QXᵀ term rides the MXU. Grid is
(M/bm, N/bn, d/bk) with k innermost; the partial row/col norms of each k
slice are added in the same pass, so a single f32 accumulator tile in VMEM
holds the finished distance block after the last k step.

Block defaults (128, 128, 512) are sized for v5e: working set per program =
bm·bk + bn·bk + bm·bn floats = (128·512)*2 + 128² ≈ 0.6 MB « 16 MB VMEM,
MXU dims all multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(q_ref, x_ref, out_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    q = q_ref[...].astype(jnp.float32)  # (bm, bk)
    x = x_ref[...].astype(jnp.float32)  # (bn, bk)
    qx = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bm, bn)
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (bm, 1)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True).T  # (1, bn)
    out_ref[...] += q2 - 2.0 * qx + x2

    @pl.when(k == n_k - 1)
    def _clamp():
        out_ref[...] = jnp.maximum(out_ref[...], 0.0)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret")
)
def l2_matmul(
    q: Array,
    x: Array,
    *,
    bm: int = 128,
    bn: int = 128,
    bk: int = 512,
    interpret: bool = False,
) -> Array:
    """Pairwise squared L2: (M, d) x (N, d) -> (M, N) f32."""
    m, d = q.shape
    n, d2 = x.shape
    assert d == d2, (d, d2)
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, d)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-d) % bk
    qp = jnp.pad(q, ((0, pm), (0, pk)))
    xp = jnp.pad(x, ((0, pn), (0, pk)))
    n_k = (d + pk) // bk
    grid = ((m + pm) // bm, (n + pn) // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.float32),
        interpret=interpret,
    )(qp, xp)
    return out[:m, :n]
