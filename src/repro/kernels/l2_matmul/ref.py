"""Pure-jnp oracle for the l2_matmul kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def l2_matmul_ref(q: Array, x: Array) -> Array:
    """Naive elementwise pairwise squared L2 (no matmul trick)."""
    diff = q.astype(jnp.float32)[:, None, :] - x.astype(jnp.float32)[None, :, :]
    return jnp.sum(diff * diff, axis=-1)
