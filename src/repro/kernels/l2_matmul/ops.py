"""Public wrapper for the pairwise-distance kernel.

Selects the Pallas kernel on TPU, interpret-mode Pallas when forced, and the
jnp matmul expansion otherwise (CPU default — interpret mode is for tests).
"""
from __future__ import annotations

import jax

from repro.common.distances import squared_l2
from repro.kernels import dispatch_kernel
from repro.kernels.l2_matmul.l2_matmul import l2_matmul

Array = jax.Array


def pairwise_sqdist(q: Array, x: Array, *, force_kernel: bool = False) -> Array:
    fn, _ = dispatch_kernel(l2_matmul, squared_l2, force_kernel=force_kernel)
    return fn(q, x)
