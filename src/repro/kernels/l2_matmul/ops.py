"""Public wrapper for the pairwise-distance kernel.

Selects the Pallas kernel on TPU, interpret-mode Pallas when forced, and the
jnp matmul expansion otherwise (CPU default — interpret mode is for tests).
"""
from __future__ import annotations

import jax

from repro.common.distances import squared_l2
from repro.kernels.l2_matmul.l2_matmul import l2_matmul

Array = jax.Array


def pairwise_sqdist(q: Array, x: Array, *, force_kernel: bool = False) -> Array:
    backend = jax.default_backend()
    if backend == "tpu":
        return l2_matmul(q, x)
    if force_kernel:
        return l2_matmul(q, x, interpret=True)
    return squared_l2(q, x)
