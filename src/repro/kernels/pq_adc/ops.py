"""Public wrapper for the ADC scan."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import dispatch_kernel
from repro.kernels.pq_adc.pq_adc import pq_adc_kernel
from repro.kernels.pq_adc.ref import pq_adc_ref
from repro.tune.config import KernelConfig
from repro.tune.table import lookup as tune_lookup

Array = jax.Array


def pq_adc(
    lut: Array,
    codes: Array,
    *,
    force_kernel: bool = False,
    config: Optional[KernelConfig] = None,
) -> Array:
    # The scan consumes only m_blk (its HBM code-block height ``bn``);
    # dma_depth/lut_tile are pinned in the lattice for this kernel. With
    # no explicit config the tuning table resolves one from the code
    # width (deg/beam don't shape a full-corpus scan: keyed at 1).
    cfg = config if config is not None else tune_lookup(
        "pq_adc", d=int(codes.shape[1]), deg=1, beam=1
    )
    fn, _ = dispatch_kernel(
        functools.partial(pq_adc_kernel, bn=cfg.m_blk),
        pq_adc_ref,
        force_kernel=force_kernel,
    )
    return fn(lut, codes)
