"""Public wrapper for the ADC scan."""
from __future__ import annotations

import jax

from repro.kernels.pq_adc.pq_adc import pq_adc_kernel
from repro.kernels.pq_adc.ref import pq_adc_ref

Array = jax.Array


def pq_adc(lut: Array, codes: Array, *, force_kernel: bool = False) -> Array:
    backend = jax.default_backend()
    if backend == "tpu":
        return pq_adc_kernel(lut, codes)
    if force_kernel:
        return pq_adc_kernel(lut, codes, interpret=True)
    return pq_adc_ref(lut, codes)
