"""Pure-jnp oracle for pq_adc."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def pq_adc_ref(lut: Array, codes: Array) -> Array:
    """(B, m_sub, n_cent) x (N, m_sub) -> (B, N)."""
    # lut[b, s, codes[v, s]] summed over s.
    per_sub = lut[:, jnp.arange(codes.shape[1])[None, :], codes]  # (B, N, m_sub)
    return jnp.sum(per_sub, axis=-1)
