"""PQ asymmetric-distance (ADC) table-scan kernel.

Given per-query LUTs (B, m_sub, n_cent) of subspace distances and the code
matrix (N, m_sub), computes ADC[b, v] = sum_s LUT[b, s, codes[v, s]].

TPU mapping: VMEM-gather is awkward on the VPU, so the lookup is recast as a
one-hot × LUT matmul that rides the MXU: each (bn,)-row code slice becomes a
(bn, m_sub·n_cent) one-hot block contracted with the flattened LUT row. The
one-hot block lives only in VMEM (bn=256, m_sub=16, n_cent=256 → 4 MB f32)
and the scan streams code blocks from HBM — memory-bound at ~m_sub bytes per
corpus vector, the same arithmetic the paper's CPU baseline does per scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array


def _kernel(lut_ref, codes_ref, out_ref, *, n_cent: int):
    lut = lut_ref[...].astype(jnp.float32)  # (1, m_sub, n_cent)
    codes = codes_ref[...]  # (bn, m_sub) int32
    bn, m_sub = codes.shape
    # one-hot over centroids, flattened over (m_sub, n_cent) -> MXU matvec.
    iota = jax.lax.broadcasted_iota(jnp.int32, (bn, m_sub, n_cent), 2)
    onehot = (iota == codes[:, :, None]).astype(jnp.float32)
    flat = onehot.reshape(bn, m_sub * n_cent)
    out_ref[...] = jax.lax.dot_general(
        flat,
        lut.reshape(1, m_sub * n_cent),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).T  # (1, bn)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def pq_adc_kernel(
    lut: Array, codes: Array, *, bn: int = 256, interpret: bool = False
) -> Array:
    """(B, m_sub, n_cent) x (N, m_sub) -> (B, N) f32 ADC distances."""
    b, m_sub, n_cent = lut.shape
    n, m2 = codes.shape
    assert m_sub == m2
    bn = min(bn, n)
    pad = (-n) % bn
    cp = jnp.pad(codes, ((0, pad), (0, 0)))
    grid = (b, (n + pad) // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, n_cent=n_cent),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, m_sub, n_cent), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((bn, m_sub), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n + pad), jnp.float32),
        interpret=interpret,
    )(lut, cp.astype(jnp.int32))
    return out[:, :n]
