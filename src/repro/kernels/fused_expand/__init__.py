from repro.kernels.fused_expand.ops import fused_expand, fused_expand_adc

__all__ = ["fused_expand", "fused_expand_adc"]
