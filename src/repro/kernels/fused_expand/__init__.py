from repro.kernels.fused_expand.ops import fused_expand

__all__ = ["fused_expand"]
