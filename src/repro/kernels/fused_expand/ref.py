"""Pure-jnp oracles for the fused expansion kernels.

Distances go through the exact primitives the unfused engine paths use —
``batched_rowwise_sqdist`` for the L2 kernel, the take-along-axis LUT sum of
``PQBackend.distances`` for the ADC kernel — so the fused CPU paths stay
bit-for-bit equal to the seed computation (the golden-file guarantee in
tests/test_engine_beam.py and the fused==unfused system tests). The
visited-probe and constraint checks are integer/compare ops and therefore
exact by construction; they mirror ``core.visited.visited_test`` and the
``core.constraints`` satisfied fns without importing them (kernels stay leaf
modules).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.distances import batched_rowwise_sqdist

Array = jax.Array

WORD_BITS = 32


def _fresh_and_sat(
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    family: str,
    tomb: Array | None = None,
) -> tuple[Array, Array]:
    """Shared mask logic: (valid & unvisited, valid & constraint-ok).

    ``tomb`` is the optional corpus-wide tombstone bitmap ((W,) uint32,
    streaming mutable index): a set bit fails ``satisfied`` exactly like a
    failed constraint while leaving ``fresh`` (traversability) untouched.
    """
    safe = jnp.maximum(ids, 0)
    valid = ids >= 0

    vword = jnp.take_along_axis(visited, safe // WORD_BITS, axis=-1)
    vbit = (safe % WORD_BITS).astype(jnp.uint32)
    unvisited = ((vword >> vbit) & jnp.uint32(1)) == jnp.uint32(0)
    fresh = valid & unvisited

    meta_col = meta.reshape(-1)
    if family == "label":
        lab = meta_col[safe]  # (B, M) int32
        cword = jnp.take_along_axis(cons, lab // WORD_BITS, axis=-1)
        cbit = (lab % WORD_BITS).astype(jnp.uint32)
        ok = ((cword >> cbit) & jnp.uint32(1)) == jnp.uint32(1)
    elif family == "range":
        val = meta_col.astype(jnp.float32)[safe]  # (B, M)
        ok = (val >= cons[:, 0:1]) & (val <= cons[:, 1:2])
    elif family == "udf":
        # Precompiled predicate table: meta is the (n,) int32 verdict
        # column (the UDF evaluated over every vertex at table-build
        # time); cons is an unused dummy.
        ok = meta_col[safe] != jnp.int32(0)
    else:
        raise ValueError(f"unsupported in-kernel constraint family: {family}")
    if tomb is not None:
        tword = tomb.reshape(-1)[safe // WORD_BITS]
        alive = ((tword >> vbit) & jnp.uint32(1)) == jnp.uint32(0)
        ok = ok & alive
    return fresh, valid & ok


def fused_expand_ref(
    queries: Array,
    corpus: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    tomb: Array | None = None,
    *,
    family: str,
) -> tuple[Array, Array, Array]:
    """Same contract as fused_expand_kernel, with bool masks."""
    safe = jnp.maximum(ids, 0)
    valid = ids >= 0

    rows = corpus[safe]  # (B, M, d)
    dists = batched_rowwise_sqdist(queries, rows)
    dists = jnp.where(valid, dists, jnp.inf)

    fresh, sat = _fresh_and_sat(ids, visited, meta, cons, family, tomb)
    return dists, sat, fresh


def fused_expand_adc_ref(
    lut: Array,
    codes: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    tomb: Array | None = None,
    *,
    family: str,
) -> tuple[Array, Array, Array]:
    """Same contract as fused_expand_adc_kernel, with bool masks.

    The distance is the unfused ADC formula verbatim (``PQBackend.
    distances``): gather each candidate's (m_sub,) code row, sum the
    per-subspace LUT entries — identical computation graph, identical bits.
    """
    safe = jnp.maximum(ids, 0)
    valid = ids >= 0

    crows = codes[safe]  # (B, M, m_sub)
    gathered = jnp.take_along_axis(
        lut[:, None, :, :],  # (B, 1, m_sub, n_cent)
        crows[..., None],  # (B, M, m_sub, 1)
        axis=-1,
    )[..., 0]
    dists = jnp.sum(gathered, axis=-1)
    dists = jnp.where(valid, dists, jnp.inf)

    fresh, sat = _fresh_and_sat(ids, visited, meta, cons, family, tomb)
    return dists, sat, fresh
