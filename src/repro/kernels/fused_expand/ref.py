"""Pure-jnp oracle for fused_expand.

Distances go through ``batched_rowwise_sqdist`` — the exact primitive the
unfused engine path uses — so the fused CPU path stays bit-for-bit equal to
the seed computation (the golden-file guarantee in tests/test_engine_beam.py).
The visited-probe and constraint checks are integer/compare ops and therefore
exact by construction; they mirror ``core.visited.visited_test`` and the
``core.constraints`` satisfied fns without importing them (kernels stay leaf
modules).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.distances import batched_rowwise_sqdist

Array = jax.Array

WORD_BITS = 32


def fused_expand_ref(
    queries: Array,
    corpus: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    *,
    family: str,
) -> tuple[Array, Array, Array]:
    """Same contract as fused_expand_kernel, with bool masks."""
    safe = jnp.maximum(ids, 0)
    valid = ids >= 0

    rows = corpus[safe]  # (B, M, d)
    dists = batched_rowwise_sqdist(queries, rows)
    dists = jnp.where(valid, dists, jnp.inf)

    vword = jnp.take_along_axis(visited, safe // WORD_BITS, axis=-1)
    vbit = (safe % WORD_BITS).astype(jnp.uint32)
    unvisited = ((vword >> vbit) & jnp.uint32(1)) == jnp.uint32(0)
    fresh = valid & unvisited

    meta_col = meta.reshape(-1)
    if family == "label":
        lab = meta_col[safe]  # (B, M) int32
        cword = jnp.take_along_axis(cons, lab // WORD_BITS, axis=-1)
        cbit = (lab % WORD_BITS).astype(jnp.uint32)
        ok = ((cword >> cbit) & jnp.uint32(1)) == jnp.uint32(1)
    elif family == "range":
        val = meta_col.astype(jnp.float32)[safe]  # (B, M)
        ok = (val >= cons[:, 0:1]) & (val <= cons[:, 1:2])
    else:
        raise ValueError(f"unsupported in-kernel constraint family: {family}")
    sat = valid & ok
    return dists, sat, fresh
