"""Fused constrained-expansion kernels — the whole candidate pipeline in one pass.

For a batch of queries Q (B, d) and a flattened (B, M = beam*deg) candidate
id batch, ONE ``pallas_call`` performs what the unfused engine spreads over
three independent HBM round trips per iteration (EXPERIMENTS.md §Perf PR2):

  * corpus-row gather + squared-L2 distance   (was: gather_distance / jnp)
  * constraint evaluation against the corpus label / attribute tables
    (was: a second per-candidate metadata gather in ``satisfied()``)
  * visited-bitset probe + padding masking    (was: ``visited_test``)

emitting ``(dists, satisfied, fresh)`` without ever materializing the
(B, M, d) gathered tensor or re-gathering per-candidate metadata.

TPU mapping: the id matrix is *scalar-prefetched* (SMEM) and drives manual
pipelined row DMAs — unlike ``gather_distance``'s historical layout, the
grid here is ``(B, M / M_blk)`` with lane-aligned ``(1, M_blk)`` output
tiles: each grid step streams ``M_blk`` corpus rows (plus their 4-byte
metadata words) through a ``dma_depth``-slot VMEM ring buffer, overlapping
up to ``dma_depth - 1`` upcoming row copies with the current row's VPU
distance reduction. The per-query operands (query row, constraint words /
bounds, visited-bitset words) ride along as (1, ·) VMEM blocks revisited
across the inner grid axis.

Block shapes are no longer fixed: ``m_blk`` (an output-tile-width CAP,
resolved as ``min(m_blk, round_up(m, 8))``), ``dma_depth`` (2..4) and the
ADC kernel's ``lut_tile`` come from ``repro.tune.KernelConfig`` via the
ops.py wrappers — the autotuner (DESIGN.md §11) sweeps that lattice and
every point is bit-identical by construction: tiling/pipelining only
reorders DMAs, never the per-candidate arithmetic.

Two distance variants share the layout (PR3):

  * ``fused_expand_kernel``     — exact squared L2 over (1, d) corpus rows.
  * ``fused_expand_adc_kernel`` — PQ/ADC: the DMA streams (1, m_sub) *code*
    rows (m_sub words instead of d floats — 32x fewer HBM bytes at d=128,
    m_sub=16) and the distance is a per-subspace LUT gather + sum against
    the query's (m_sub, n_cent) ADC table, VMEM-resident per query. The
    gather is a one-hot compare-select-reduce (``broadcasted_iota`` against
    the code row) — plain VPU work, no dynamic VMEM indexing — evaluated in
    ``lut_tile``-column slices when tiled. Each code row selects exactly one
    column per subspace, so per-row slice sums reduce at most one non-zero
    against exact +0.0 padding (LUT entries are squared distances, never
    -0.0): every ``lut_tile`` produces identical bits.

Constraint families (static ``family`` switch, one compiled kernel each):

  * ``"label"`` — LabelSet bitmask: meta table is the (n, 1) int32 label
    column, per-query operand is the (B, Lw) uint32 allowed-label words.
  * ``"range"`` — numeric window: meta table is the (n, 1) f32 attribute
    column, per-query operand is the (B, 2) f32 [lo, hi] bounds.
  * ``"udf"``   — precompiled predicate table: meta is the (n, 1) int32
    verdict column (the UDF evaluated over every vertex at table-build
    time — core/constraints.py), non-zero means satisfied. There is no
    per-query operand; the cons block is a (1, 1) dummy pinned to block
    (0, 0). This removed the last ``fusable=False`` constraint family.

Padding ids (< 0) are redirected to row 0 and reported as (+inf, 0, 0);
``satisfied``/``fresh`` are int32 masks (cast to bool by ops.py) since TPU
output tiles are happier as 32-bit lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

WORD_BITS = 32

FAMILIES = ("label", "range", "udf")


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _resolve_m_blk(m_blk: int | None, m: int) -> int:
    """m_blk is a cap on the lane-aligned output-tile width: small candidate
    batches collapse to one tile (the pre-autotuner default behaviour)."""
    return min(m_blk if m_blk is not None else 128, _round_up(m, 8))


def _unvisited(vis_ref, cid):
    """Probe one word of the per-query visited bitset (VMEM-resident)."""
    sid = jnp.maximum(cid, 0)
    vword = vis_ref[0, sid // WORD_BITS]
    vbit = (sid % WORD_BITS).astype(jnp.uint32)
    return ((vword >> vbit) & jnp.uint32(1)) == jnp.uint32(0)


def _constraint_ok(family, meta_val, cons_ref):
    """Evaluate the candidate's metadata word against the per-query operand."""
    if family == "label":
        lab = meta_val  # int32 label
        cword = cons_ref[0, lab // WORD_BITS]
        cbit = (lab % WORD_BITS).astype(jnp.uint32)
        return ((cword >> cbit) & jnp.uint32(1)) == jnp.uint32(1)
    if family == "udf":
        # Precompiled predicate table: the metadata word IS the verdict.
        return meta_val != jnp.int32(0)
    # "range"
    return (meta_val >= cons_ref[0, 0]) & (meta_val <= cons_ref[0, 1])


def _alive(tomb_ref, cid):
    """Probe the corpus-wide tombstone bitmap (VMEM-resident, shared by
    every query): True when the candidate has NOT been deleted/freed."""
    sid = jnp.maximum(cid, 0)
    tword = tomb_ref[0, sid // WORD_BITS]
    tbit = (sid % WORD_BITS).astype(jnp.uint32)
    return ((tword >> tbit) & jnp.uint32(1)) == jnp.uint32(0)


def _cons_spec(family: str, cons: Array):
    """Per-query operand block — except "udf", whose (1, 1) dummy is pinned
    to block (0, 0) (the predicate travels in the metadata column)."""
    if family == "udf":
        return pl.BlockSpec((1, cons.shape[1]), lambda i, j, ids_p: (0, 0))
    return pl.BlockSpec((1, cons.shape[1]), lambda i, j, ids_p: (i, 0))


def _make_kernel(family: str, m_blk: int, with_tomb: bool, dma_depth: int):
    def kernel(
        ids_ref,  # (B, M) int32, scalar-prefetched (SMEM)
        q_ref,  # (1, d) query row (VMEM)
        cons_ref,  # (1, Lw) uint32 words | (1, 2) f32 bounds (VMEM)
        vis_ref,  # (1, W) uint32 visited words (VMEM)
        *rest,  # [tomb_ref (1, Wt) u32,] corpus/meta HBM, outs, scratch
    ):
        if with_tomb:
            tomb_ref, *rest = rest
        else:
            tomb_ref = None
        (
            corpus_hbm,  # (n, d) full corpus (ANY/HBM)
            meta_hbm,  # (n, 1) label/attr/predicate column (ANY/HBM)
            dist_ref,  # (1, M_blk) f32 out
            sat_ref,  # (1, M_blk) int32 out
            fresh_ref,  # (1, M_blk) int32 out
            row_buf,  # (dma_depth, 1, d) VMEM scratch — corpus-row ring
            meta_buf,  # (dma_depth, 1, 1) VMEM scratch — metadata-word ring
            row_sem,  # (dma_depth,) DMA semaphores
            meta_sem,  # (dma_depth,) DMA semaphores
        ) = rest
        i = pl.program_id(0)
        jb = pl.program_id(1)
        base = jb * m_blk

        def row_dma(t, slot):
            cid = jnp.maximum(ids_ref[i, base + t], 0)
            return pltpu.make_async_copy(
                corpus_hbm.at[pl.ds(cid, 1), :], row_buf.at[slot], row_sem.at[slot]
            )

        def meta_dma(t, slot):
            cid = jnp.maximum(ids_ref[i, base + t], 0)
            return pltpu.make_async_copy(
                meta_hbm.at[pl.ds(cid, 1), :], meta_buf.at[slot], meta_sem.at[slot]
            )

        # Warm up the pipeline: the first dma_depth-1 candidates' rows +
        # metadata in flight (the classic double buffer at depth 2).
        for t0 in range(min(dma_depth - 1, m_blk)):
            row_dma(t0, t0 % dma_depth).start()
            meta_dma(t0, t0 % dma_depth).start()
        q = q_ref[...].astype(jnp.float32)  # (1, d)

        def body(t, carry):
            slot = t % dma_depth

            # Keep dma_depth-1 copies in flight: start candidate
            # t + dma_depth - 1's DMAs before waiting on candidate t.
            @pl.when(t + dma_depth - 1 < m_blk)
            def _():
                nxt = t + dma_depth - 1
                row_dma(nxt, nxt % dma_depth).start()
                meta_dma(nxt, nxt % dma_depth).start()

            row_dma(t, slot).wait()
            meta_dma(t, slot).wait()

            cid = ids_ref[i, base + t]
            valid = cid >= 0

            # --- distance: VPU reduction over the freshly landed row -------
            row = row_buf[slot, 0].astype(jnp.float32)  # (d,)
            diff = q[0] - row
            d2 = jnp.sum(diff * diff)

            # --- visited probe + constraint on the metadata word -----------
            unvisited = _unvisited(vis_ref, cid)
            ok = _constraint_ok(family, meta_buf[slot, 0, 0], cons_ref)
            if with_tomb:
                # Tombstone-as-constraint (streaming mutable index): a
                # deleted slot fails `sat` but stays `fresh`-traversable.
                ok = ok & _alive(tomb_ref, cid)

            dist_ref[0, t] = jnp.where(valid, d2, jnp.inf)
            sat_ref[0, t] = (valid & ok).astype(jnp.int32)
            fresh_ref[0, t] = (valid & unvisited).astype(jnp.int32)
            return carry

        jax.lax.fori_loop(0, m_blk, body, None)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("family", "m_blk", "dma_depth", "interpret")
)
def fused_expand_kernel(
    queries: Array,
    corpus: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    tomb: Array | None = None,
    *,
    family: str,
    m_blk: int | None = None,
    dma_depth: int = 2,
    interpret: bool = False,
) -> tuple[Array, Array, Array]:
    """(B, d), (n, d), (B, M) i32, (B, W) u32, (n,|n,1) meta, (B, ·) cons
    [, (Wt,) u32 tombstones]
    -> ((B, M) f32 dists, (B, M) i32 satisfied, (B, M) i32 fresh)."""
    if family not in FAMILIES:
        raise ValueError(f"unsupported in-kernel constraint family: {family}")
    b, d = queries.shape
    _, m = ids.shape
    m_blk = _resolve_m_blk(m_blk, m)
    m_pad = _round_up(m, m_blk)
    ids = ids.astype(jnp.int32)
    if m_pad != m:
        ids = jnp.pad(ids, ((0, 0), (0, m_pad - m)), constant_values=-1)
    meta2d = meta.reshape(-1, 1)
    if family == "range":
        meta2d = meta2d.astype(jnp.float32)

    with_tomb = tomb is not None
    # The tombstone bitmap is corpus-wide: ONE (1, Wt) VMEM block revisited
    # by every grid step (index map pins it to block (0, 0)), unlike the
    # per-query operands that follow the batch axis.
    tomb_specs = (
        [pl.BlockSpec((1, tomb.shape[0]), lambda i, j, ids_p: (0, 0))]
        if with_tomb
        else []
    )
    tomb_args = (tomb.reshape(1, -1),) if with_tomb else ()
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, m_pad // m_blk),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, ids_p: (i, 0)),
            _cons_spec(family, cons),
            pl.BlockSpec((1, visited.shape[1]), lambda i, j, ids_p: (i, 0)),
            *tomb_specs,
            pl.BlockSpec(memory_space=pltpu.ANY),  # corpus stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # metadata column in HBM
        ],
        out_specs=[
            pl.BlockSpec((1, m_blk), lambda i, j, ids_p: (i, j)),
            pl.BlockSpec((1, m_blk), lambda i, j, ids_p: (i, j)),
            pl.BlockSpec((1, m_blk), lambda i, j, ids_p: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((dma_depth, 1, d), corpus.dtype),
            pltpu.VMEM((dma_depth, 1, 1), meta2d.dtype),
            pltpu.SemaphoreType.DMA((dma_depth,)),
            pltpu.SemaphoreType.DMA((dma_depth,)),
        ],
    )
    dists, sat, fresh = pl.pallas_call(
        _make_kernel(family, m_blk, with_tomb, dma_depth),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, m_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, m_pad), jnp.int32),
        ],
        interpret=interpret,
    )(ids, queries, cons, visited, *tomb_args, corpus, meta2d)
    return dists[:, :m], sat[:, :m], fresh[:, :m]


def _make_adc_kernel(
    family: str,
    m_blk: int,
    m_sub: int,
    n_cent: int,
    with_tomb: bool,
    dma_depth: int,
    lut_tile: int,
):
    # lut_tile == 0 (or >= n_cent) means one whole-table slice; either way
    # the reduction below is per-row-exact, so every tile width is
    # bit-identical (see module docstring).
    chunk = lut_tile if 0 < lut_tile < n_cent else n_cent

    def kernel(
        ids_ref,  # (B, M) int32, scalar-prefetched (SMEM)
        lut_ref,  # (1, m_sub, n_cent) f32 ADC table for this query (VMEM)
        cons_ref,  # (1, Lw) uint32 words | (1, 2) f32 bounds (VMEM)
        vis_ref,  # (1, W) uint32 visited words (VMEM)
        *rest,  # [tomb_ref (1, Wt) u32,] codes/meta HBM, outs, scratch
    ):
        if with_tomb:
            tomb_ref, *rest = rest
        else:
            tomb_ref = None
        (
            codes_hbm,  # (n, m_sub) int32 full code matrix (ANY/HBM)
            meta_hbm,  # (n, 1) label/attr/predicate column (ANY/HBM)
            dist_ref,  # (1, M_blk) f32 out
            sat_ref,  # (1, M_blk) int32 out
            fresh_ref,  # (1, M_blk) int32 out
            code_buf,  # (dma_depth, 1, m_sub) VMEM scratch — code-row ring
            meta_buf,  # (dma_depth, 1, 1) VMEM scratch — metadata-word ring
            code_sem,  # (dma_depth,) DMA semaphores
            meta_sem,  # (dma_depth,) DMA semaphores
        ) = rest
        i = pl.program_id(0)
        jb = pl.program_id(1)
        base = jb * m_blk

        def code_dma(t, slot):
            cid = jnp.maximum(ids_ref[i, base + t], 0)
            return pltpu.make_async_copy(
                codes_hbm.at[pl.ds(cid, 1), :], code_buf.at[slot], code_sem.at[slot]
            )

        def meta_dma(t, slot):
            cid = jnp.maximum(ids_ref[i, base + t], 0)
            return pltpu.make_async_copy(
                meta_hbm.at[pl.ds(cid, 1), :], meta_buf.at[slot], meta_sem.at[slot]
            )

        # Warm up the pipeline: the first dma_depth-1 candidates' code rows
        # + metadata in flight.
        for t0 in range(min(dma_depth - 1, m_blk)):
            code_dma(t0, t0 % dma_depth).start()
            meta_dma(t0, t0 % dma_depth).start()
        lut = lut_ref[0]  # (m_sub, n_cent) — the query's ADC table, VMEM
        # One-hot centroid selector: dynamic-gather-free LUT lookup (TPU
        # needs >= 2D iota; compare-select-reduce is plain VPU work).
        cent = jax.lax.broadcasted_iota(jnp.int32, (m_sub, n_cent), 1)

        def body(t, carry):
            slot = t % dma_depth

            # Keep dma_depth-1 copies in flight: start candidate
            # t + dma_depth - 1's DMAs before waiting on candidate t.
            @pl.when(t + dma_depth - 1 < m_blk)
            def _():
                nxt = t + dma_depth - 1
                code_dma(nxt, nxt % dma_depth).start()
                meta_dma(nxt, nxt % dma_depth).start()

            code_dma(t, slot).wait()
            meta_dma(t, slot).wait()

            cid = ids_ref[i, base + t]
            valid = cid >= 0

            # --- ADC distance: per-subspace LUT entry sum ------------------
            # Sliced over `chunk` centroid columns; each row slice selects
            # at most one non-zero, so vals[s] is EXACTLY lut[s, crow[s]]
            # (+0.0 folds are exact) and the final (m_sub,) reduction is
            # identical for every tile width.
            crow = code_buf[slot, 0]  # (m_sub,) int32 centroid ids
            vals = jnp.zeros((m_sub,), jnp.float32)
            for c0 in range(0, n_cent, chunk):
                c1 = min(c0 + chunk, n_cent)
                sel = cent[:, c0:c1] == crow[:, None]
                vals = vals + jnp.sum(
                    jnp.where(sel, lut[:, c0:c1], 0.0), axis=1
                )
            d2 = jnp.sum(vals)

            # --- visited probe + constraint on the metadata word -----------
            unvisited = _unvisited(vis_ref, cid)
            ok = _constraint_ok(family, meta_buf[slot, 0, 0], cons_ref)
            if with_tomb:
                # Tombstone-as-constraint (streaming mutable index): a
                # deleted slot fails `sat` but stays `fresh`-traversable.
                ok = ok & _alive(tomb_ref, cid)

            dist_ref[0, t] = jnp.where(valid, d2, jnp.inf)
            sat_ref[0, t] = (valid & ok).astype(jnp.int32)
            fresh_ref[0, t] = (valid & unvisited).astype(jnp.int32)
            return carry

        jax.lax.fori_loop(0, m_blk, body, None)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("family", "m_blk", "dma_depth", "lut_tile", "interpret"),
)
def fused_expand_adc_kernel(
    lut: Array,
    codes: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    tomb: Array | None = None,
    *,
    family: str,
    m_blk: int | None = None,
    dma_depth: int = 2,
    lut_tile: int = 0,
    interpret: bool = False,
) -> tuple[Array, Array, Array]:
    """(B, m_sub, n_cent) f32 LUT, (n, m_sub) i32 codes, (B, M) i32 ids,
    (B, W) u32 visited, (n,|n,1) meta, (B, ·) cons [, (Wt,) u32 tombstones]
    -> ((B, M) f32 ADC dists, (B, M) i32 satisfied, (B, M) i32 fresh)."""
    if family not in FAMILIES:
        raise ValueError(f"unsupported in-kernel constraint family: {family}")
    b, m_sub, n_cent = lut.shape
    _, m = ids.shape
    m_blk = _resolve_m_blk(m_blk, m)
    m_pad = _round_up(m, m_blk)
    ids = ids.astype(jnp.int32)
    if m_pad != m:
        ids = jnp.pad(ids, ((0, 0), (0, m_pad - m)), constant_values=-1)
    meta2d = meta.reshape(-1, 1)
    if family == "range":
        meta2d = meta2d.astype(jnp.float32)
    codes = codes.astype(jnp.int32)
    lut = lut.astype(jnp.float32)

    with_tomb = tomb is not None
    tomb_specs = (
        [pl.BlockSpec((1, tomb.shape[0]), lambda i, j, ids_p: (0, 0))]
        if with_tomb
        else []
    )
    tomb_args = (tomb.reshape(1, -1),) if with_tomb else ()
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, m_pad // m_blk),
        in_specs=[
            pl.BlockSpec((1, m_sub, n_cent), lambda i, j, ids_p: (i, 0, 0)),
            _cons_spec(family, cons),
            pl.BlockSpec((1, visited.shape[1]), lambda i, j, ids_p: (i, 0)),
            *tomb_specs,
            pl.BlockSpec(memory_space=pltpu.ANY),  # code matrix stays in HBM
            pl.BlockSpec(memory_space=pltpu.ANY),  # metadata column in HBM
        ],
        out_specs=[
            pl.BlockSpec((1, m_blk), lambda i, j, ids_p: (i, j)),
            pl.BlockSpec((1, m_blk), lambda i, j, ids_p: (i, j)),
            pl.BlockSpec((1, m_blk), lambda i, j, ids_p: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((dma_depth, 1, m_sub), jnp.int32),
            pltpu.VMEM((dma_depth, 1, 1), meta2d.dtype),
            pltpu.SemaphoreType.DMA((dma_depth,)),
            pltpu.SemaphoreType.DMA((dma_depth,)),
        ],
    )
    dists, sat, fresh = pl.pallas_call(
        _make_adc_kernel(
            family, m_blk, m_sub, n_cent, with_tomb, dma_depth, lut_tile
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, m_pad), jnp.float32),
            jax.ShapeDtypeStruct((b, m_pad), jnp.int32),
            jax.ShapeDtypeStruct((b, m_pad), jnp.int32),
        ],
        interpret=interpret,
    )(ids, lut, cons, visited, *tomb_args, codes, meta2d)
    return dists[:, :m], sat[:, :m], fresh[:, :m]
