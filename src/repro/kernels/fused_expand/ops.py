"""Public wrappers: Pallas on TPU, jnp oracle elsewhere (interpret for tests).

``fused_expand`` scores with exact squared L2 over corpus rows;
``fused_expand_adc`` scores with PQ/ADC lookups over code rows — same
constraint + visited treatment, selected by the engine's ``DistanceBackend``
(core/engine/context.py). Platform dispatch goes through the shared
``repro.kernels.dispatch_kernel`` helper.

Block shapes come from an optional ``repro.tune.KernelConfig`` (the
autotuner's resolved table entry, threaded in by ``build_context``); the
legacy ``m_blk`` keyword still wins when given explicitly (tests pin tiny
tiles with it). All configs are bit-identical — see fused_expand.py.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import dispatch_kernel
from repro.kernels.fused_expand.fused_expand import (
    fused_expand_adc_kernel,
    fused_expand_kernel,
)
from repro.kernels.fused_expand.ref import fused_expand_adc_ref, fused_expand_ref
from repro.tune.config import DEFAULT_CONFIGS, KernelConfig

Array = jax.Array


def _blocking(
    config: Optional[KernelConfig], m_blk: Optional[int], kernel: str
) -> tuple[Optional[int], int, int]:
    """(m_blk cap, dma_depth, lut_tile) — explicit m_blk keyword wins."""
    cfg = config if config is not None else DEFAULT_CONFIGS[kernel]
    return (m_blk if m_blk is not None else cfg.m_blk,
            cfg.dma_depth, cfg.lut_tile)


def fused_expand(
    queries: Array,
    corpus: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    tomb: Array | None = None,
    *,
    family: str,
    force_kernel: bool = False,
    m_blk: int | None = None,
    config: Optional[KernelConfig] = None,
) -> tuple[Array, Array, Array]:
    """One pass over a (B, M) candidate batch -> (dists, satisfied, fresh).

    meta is the corpus-side metadata column ((n,) labels for family="label",
    (n,) f32 attribute values for family="range", (n,) int32 precompiled
    predicate verdicts for family="udf"); cons the per-query operand
    ((B, Lw) uint32 words / (B, 2) f32 bounds / a (1, 1) dummy for "udf") —
    see ``repro.core.constraints.constraint_tables`` for the raw-view
    builder. ``tomb`` is the optional corpus-wide tombstone bitmap
    ((Wt,) uint32, streaming mutable index): a set bit clears ``satisfied``
    in-kernel, exactly like a failed constraint.
    """
    cap, depth, _ = _blocking(config, m_blk, "fused_exact")
    fn, used_kernel = dispatch_kernel(
        functools.partial(
            fused_expand_kernel, family=family, m_blk=cap, dma_depth=depth
        ),
        functools.partial(fused_expand_ref, family=family),
        force_kernel=force_kernel,
    )
    d, s, f = fn(queries, corpus, ids, visited, meta, cons, tomb)
    if used_kernel:
        s, f = s.astype(bool), f.astype(bool)
    return d, s, f


def fused_expand_adc(
    lut: Array,
    codes: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    tomb: Array | None = None,
    *,
    family: str,
    force_kernel: bool = False,
    m_blk: int | None = None,
    config: Optional[KernelConfig] = None,
) -> tuple[Array, Array, Array]:
    """ADC twin of ``fused_expand``: one pass -> (dists, satisfied, fresh).

    lut is the query batch's (B, m_sub, n_cent) ADC table
    (``repro.core.pq.adc_table``), codes the (n, m_sub) int32 code matrix;
    distances are PQ approximations summed in-kernel from the VMEM-resident
    LUT (in ``config.lut_tile``-column slices when tiled) while the
    candidate's code row (m_sub words instead of d floats) streams through
    the same ``config.dma_depth``-slot DMA ring as the exact kernel's
    corpus rows.
    """
    cap, depth, lut_tile = _blocking(config, m_blk, "fused_adc")
    fn, used_kernel = dispatch_kernel(
        functools.partial(
            fused_expand_adc_kernel,
            family=family, m_blk=cap, dma_depth=depth, lut_tile=lut_tile,
        ),
        functools.partial(fused_expand_adc_ref, family=family),
        force_kernel=force_kernel,
    )
    d, s, f = fn(lut, codes, ids, visited, meta, cons, tomb)
    if used_kernel:
        s, f = s.astype(bool), f.astype(bool)
    return d, s, f
