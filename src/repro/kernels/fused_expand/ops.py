"""Public wrappers: Pallas on TPU, jnp oracle elsewhere (interpret for tests).

``fused_expand`` scores with exact squared L2 over corpus rows;
``fused_expand_adc`` scores with PQ/ADC lookups over code rows — same
constraint + visited treatment, selected by the engine's ``DistanceBackend``
(core/engine/context.py).
"""
from __future__ import annotations

import jax

from repro.kernels.fused_expand.fused_expand import (
    fused_expand_adc_kernel,
    fused_expand_kernel,
)
from repro.kernels.fused_expand.ref import fused_expand_adc_ref, fused_expand_ref

Array = jax.Array


def fused_expand(
    queries: Array,
    corpus: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    tomb: Array | None = None,
    *,
    family: str,
    force_kernel: bool = False,
    m_blk: int | None = None,
) -> tuple[Array, Array, Array]:
    """One pass over a (B, M) candidate batch -> (dists, satisfied, fresh).

    meta is the corpus-side metadata column ((n,) labels for family="label",
    (n,) f32 attribute values for family="range"); cons the per-query operand
    ((B, Lw) uint32 words / (B, 2) f32 bounds) — see
    ``repro.core.constraints.constraint_tables`` for the raw-view builder.
    ``tomb`` is the optional corpus-wide tombstone bitmap ((Wt,) uint32,
    streaming mutable index): a set bit clears ``satisfied`` in-kernel,
    exactly like a failed constraint.
    """
    if jax.default_backend() == "tpu":
        d, s, f = fused_expand_kernel(
            queries, corpus, ids, visited, meta, cons, tomb,
            family=family, m_blk=m_blk,
        )
    elif force_kernel:
        d, s, f = fused_expand_kernel(
            queries, corpus, ids, visited, meta, cons, tomb,
            family=family, m_blk=m_blk, interpret=True,
        )
    else:
        return fused_expand_ref(
            queries, corpus, ids, visited, meta, cons, tomb, family=family
        )
    return d, s.astype(bool), f.astype(bool)


def fused_expand_adc(
    lut: Array,
    codes: Array,
    ids: Array,
    visited: Array,
    meta: Array,
    cons: Array,
    tomb: Array | None = None,
    *,
    family: str,
    force_kernel: bool = False,
    m_blk: int | None = None,
) -> tuple[Array, Array, Array]:
    """ADC twin of ``fused_expand``: one pass -> (dists, satisfied, fresh).

    lut is the query batch's (B, m_sub, n_cent) ADC table
    (``repro.core.pq.adc_table``), codes the (n, m_sub) int32 code matrix;
    distances are PQ approximations summed in-kernel from the VMEM-resident
    LUT while the candidate's code row (m_sub words instead of d floats)
    streams through the same double-buffered DMA as the exact kernel's
    corpus rows.
    """
    if jax.default_backend() == "tpu":
        d, s, f = fused_expand_adc_kernel(
            lut, codes, ids, visited, meta, cons, tomb,
            family=family, m_blk=m_blk,
        )
    elif force_kernel:
        d, s, f = fused_expand_adc_kernel(
            lut, codes, ids, visited, meta, cons, tomb,
            family=family, m_blk=m_blk, interpret=True,
        )
    else:
        return fused_expand_adc_ref(
            lut, codes, ids, visited, meta, cons, tomb, family=family
        )
    return d, s.astype(bool), f.astype(bool)
