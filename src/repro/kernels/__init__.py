# Pallas TPU kernels for the compute hot-spots of the constrained-search
# system. Each subpackage ships <name>.py (pl.pallas_call + BlockSpec),
# ops.py (jit'd public wrapper with a pure-jnp fallback) and ref.py (the
# oracle the tests assert against). On this CPU container the kernels run
# in interpret mode; BlockSpecs target TPU v5e VMEM/MXU geometry.
