"""Pallas TPU kernels for the compute hot-spots of the constrained-search
system. Each subpackage ships <name>.py (pl.pallas_call + BlockSpec),
ops.py (jit'd public wrapper with a pure-jnp fallback) and ref.py (the
oracle the tests assert against). On this CPU container the kernels run
in interpret mode; BlockSpecs target TPU v5e VMEM/MXU geometry.

Every ops.py wrapper routes through ``dispatch_kernel`` below — the one
copy of the "Pallas on TPU, jnp oracle elsewhere, interpret-mode Pallas
for tests/CI smoke" platform policy that used to be duplicated across the
five wrappers.
"""
from __future__ import annotations

import functools
from typing import Callable, Tuple

import jax


def dispatch_kernel(
    kernel_fn: Callable,
    ref_fn: Callable,
    *,
    force_kernel: bool = False,
) -> Tuple[Callable, bool]:
    """Select the execution path for one kernel call.

    Returns ``(fn, used_kernel)``: the compiled Pallas kernel on TPU, the
    interpret-mode kernel when ``force_kernel`` (tests and CI smoke runs
    exercise the real kernel body on CPU), the pure-jnp oracle otherwise.
    ``used_kernel`` lets wrappers post-process kernel-only output quirks
    (e.g. the fused kernels' int32 masks -> bool).

    ``kernel_fn`` must accept ``interpret=``; both callables must share
    the remaining signature.
    """
    if jax.default_backend() == "tpu":
        return functools.partial(kernel_fn, interpret=False), True
    if force_kernel:
        return functools.partial(kernel_fn, interpret=True), True
    return ref_fn, False
