"""Public wrapper: Pallas on TPU, jnp gather elsewhere (interpret for tests)."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from repro.kernels import dispatch_kernel
from repro.kernels.gather_distance.gather_distance import gather_distance_kernel
from repro.kernels.gather_distance.ref import gather_distance_ref
from repro.tune.config import DEFAULT_CONFIGS, KernelConfig

Array = jax.Array


def gather_distance(
    queries: Array,
    corpus: Array,
    ids: Array,
    *,
    force_kernel: bool = False,
    config: Optional[KernelConfig] = None,
) -> Array:
    cfg = config if config is not None else DEFAULT_CONFIGS["gather_distance"]
    fn, _ = dispatch_kernel(
        functools.partial(
            gather_distance_kernel, m_blk=cfg.m_blk, dma_depth=cfg.dma_depth
        ),
        gather_distance_ref,
        force_kernel=force_kernel,
    )
    return fn(queries, corpus, ids)
