"""Public wrapper: Pallas on TPU, jnp gather elsewhere (interpret for tests)."""
from __future__ import annotations

import jax

from repro.kernels.gather_distance.gather_distance import gather_distance_kernel
from repro.kernels.gather_distance.ref import gather_distance_ref

Array = jax.Array


def gather_distance(
    queries: Array, corpus: Array, ids: Array, *, force_kernel: bool = False
) -> Array:
    backend = jax.default_backend()
    if backend == "tpu":
        return gather_distance_kernel(queries, corpus, ids)
    if force_kernel:
        return gather_distance_kernel(queries, corpus, ids, interpret=True)
    return gather_distance_ref(queries, corpus, ids)
