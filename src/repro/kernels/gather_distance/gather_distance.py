"""Fused gather + distance kernel — the inner step of the graph search.

For a batch of queries Q (B, d) and per-query neighbor id lists IDS (B, M),
computes D[b, m] = ||Q[b] - corpus[IDS[b, m]]||^2 without materializing the
(B, M, d) gathered tensor in HBM.

TPU mapping: the id matrix is *scalar-prefetched* (SMEM) and drives manual
pipelined row DMAs over a ``(B, M / m_blk)`` grid with lane-aligned
``(1, m_blk)`` output tiles — the same layout as the fused-expansion
kernels (kernels/fused_expand), minus their metadata word and constraint /
visited probes. Each grid step streams ``m_blk`` corpus rows through a
``dma_depth``-slot VMEM ring buffer, overlapping upcoming row copies with
the current row's VPU distance reduction. (The original one-row-per-grid-
step layout — (B, M) grid, (1, 1) output blocks, BlockSpec-index-map
gather — left the block shape unsearchable; this form exposes the same
``m_blk``/``dma_depth`` lattice the autotuner sweeps, DESIGN.md §11.)
This kernel is HBM-bandwidth-bound by construction — see EXPERIMENTS.md
§Roofline.

Padding ids (< 0) are redirected to row 0 and reported as +inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _make_kernel(m_blk: int, dma_depth: int):
    def kernel(
        ids_ref,  # (B, M) int32, scalar-prefetched (SMEM)
        q_ref,  # (1, d) query row (VMEM)
        corpus_hbm,  # (n, d) full corpus (ANY/HBM)
        out_ref,  # (1, m_blk) f32 out
        row_buf,  # (dma_depth, 1, d) VMEM scratch — corpus-row ring
        row_sem,  # (dma_depth,) DMA semaphores
    ):
        i = pl.program_id(0)
        jb = pl.program_id(1)
        base = jb * m_blk

        def row_dma(t, slot):
            cid = jnp.maximum(ids_ref[i, base + t], 0)
            return pltpu.make_async_copy(
                corpus_hbm.at[pl.ds(cid, 1), :], row_buf.at[slot], row_sem.at[slot]
            )

        for t0 in range(min(dma_depth - 1, m_blk)):
            row_dma(t0, t0 % dma_depth).start()
        q = q_ref[...].astype(jnp.float32)  # (1, d)

        def body(t, carry):
            slot = t % dma_depth

            @pl.when(t + dma_depth - 1 < m_blk)
            def _():
                nxt = t + dma_depth - 1
                row_dma(nxt, nxt % dma_depth).start()

            row_dma(t, slot).wait()
            row = row_buf[slot, 0].astype(jnp.float32)  # (d,)
            diff = q[0] - row
            d2 = jnp.sum(diff * diff)
            pad = ids_ref[i, base + t] < 0
            out_ref[0, t] = jnp.where(pad, jnp.inf, d2)
            return carry

        jax.lax.fori_loop(0, m_blk, body, None)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("m_blk", "dma_depth", "interpret")
)
def gather_distance_kernel(
    queries: Array,
    corpus: Array,
    ids: Array,
    *,
    m_blk: int | None = None,
    dma_depth: int = 2,
    interpret: bool = False,
) -> Array:
    """(B, d), (n, d), (B, M) int32 -> (B, M) f32 squared distances."""
    b, d = queries.shape
    _, m = ids.shape
    # m_blk is a cap on the lane-aligned output-tile width: small neighbor
    # lists collapse to one tile (see repro.tune.config.effective_m_blk).
    m_blk = min(m_blk if m_blk is not None else 128, _round_up(m, 8))
    m_pad = _round_up(m, m_blk)
    ids = ids.astype(jnp.int32)
    if m_pad != m:
        ids = jnp.pad(ids, ((0, 0), (0, m_pad - m)), constant_values=-1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, m_pad // m_blk),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, ids_pref: (i, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),  # corpus stays in HBM
        ],
        out_specs=pl.BlockSpec((1, m_blk), lambda i, j, ids_pref: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((dma_depth, 1, d), corpus.dtype),
            pltpu.SemaphoreType.DMA((dma_depth,)),
        ],
    )
    out = pl.pallas_call(
        _make_kernel(m_blk, dma_depth),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, m_pad), jnp.float32),
        interpret=interpret,
    )(ids, queries, corpus)
    return out[:, :m]
