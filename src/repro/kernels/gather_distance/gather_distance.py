"""Fused gather + distance kernel — the inner step of the graph search.

For a batch of queries Q (B, d) and per-query neighbor id lists IDS (B, M),
computes D[b, m] = ||Q[b] - corpus[IDS[b, m]]||^2 without materializing the
(B, M, d) gathered tensor in HBM.

TPU mapping: the id matrix is *scalar-prefetched* (SMEM) and drives the
corpus BlockSpec index_map, so each grid step DMAs exactly one corpus row
(1, d) from HBM into VMEM; Pallas double-buffers these row copies across the
(B, M) grid, which is the canonical TPU gather pattern. The query row rides
along at block (1, d) and the distance is a VPU reduction. This kernel is
HBM-bandwidth-bound by construction — see EXPERIMENTS.md §Roofline.

Padding ids (< 0) are redirected to row 0 and reported as +inf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array


def _kernel(ids_ref, q_ref, row_ref, out_ref):
    b = pl.program_id(0)
    m = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32)  # (1, d)
    row = row_ref[...].astype(jnp.float32)  # (1, d)
    diff = q - row
    d = jnp.sum(diff * diff)
    pad = ids_ref[b, m] < 0
    out_ref[0, 0] = jnp.where(pad, jnp.inf, d)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_distance_kernel(
    queries: Array, corpus: Array, ids: Array, *, interpret: bool = False
) -> Array:
    """(B, d), (n, d), (B, M) int32 -> (B, M) f32 squared distances."""
    b, d = queries.shape
    _, m = ids.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, m),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, j, ids_pref: (i, 0)),
            # The gather: block row chosen by the prefetched id table
            # (padding ids clamped here; masked to +inf in the kernel).
            pl.BlockSpec(
                (1, d), lambda i, j, ids_pref: (jnp.maximum(ids_pref[i, j], 0), 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j, ids_pref: (i, j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, m), jnp.float32),
        interpret=interpret,
    )(ids.astype(jnp.int32), queries, corpus)
