"""Pure-jnp oracle for gather_distance."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def gather_distance_ref(queries: Array, corpus: Array, ids: Array) -> Array:
    rows = corpus[jnp.maximum(ids, 0)].astype(jnp.float32)  # (B, M, d)
    diff = rows - queries.astype(jnp.float32)[:, None, :]
    d = jnp.sum(diff * diff, axis=-1)
    return jnp.where(ids >= 0, d, jnp.inf)
