"""Tiny pytree-dataclass helper (flax.struct-like, no external deps).

Usage::

    @pytree_dataclass
    class State:
        x: jax.Array
        n: int = static_field(default=0)   # static (aux) field

Static fields become part of the pytree aux data (hashable, compared for
equality when jitting); array fields are children.
"""
from __future__ import annotations

import dataclasses
from typing import Any, TypeVar

import jax

_T = TypeVar("_T")

_STATIC_MARK = "__repro_static__"


def static_field(**kwargs: Any) -> Any:
    """A dataclass field treated as static (pytree aux data)."""
    metadata = dict(kwargs.pop("metadata", {}) or {})
    metadata[_STATIC_MARK] = True
    return dataclasses.field(metadata=metadata, **kwargs)


def pytree_dataclass(cls: type[_T]) -> type[_T]:
    """Decorator: frozen dataclass registered as a JAX pytree."""
    cls = dataclasses.dataclass(frozen=True)(cls)
    fields = dataclasses.fields(cls)
    child_names = tuple(
        f.name for f in fields if not f.metadata.get(_STATIC_MARK, False)
    )
    static_names = tuple(
        f.name for f in fields if f.metadata.get(_STATIC_MARK, False)
    )

    def flatten(obj):
        children = tuple(getattr(obj, n) for n in child_names)
        aux = tuple(getattr(obj, n) for n in static_names)
        return children, aux

    def flatten_with_keys(obj):
        children = tuple(
            (jax.tree_util.GetAttrKey(n), getattr(obj, n)) for n in child_names
        )
        aux = tuple(getattr(obj, n) for n in static_names)
        return children, aux

    def unflatten(aux, children):
        kwargs = dict(zip(child_names, children))
        kwargs.update(dict(zip(static_names, aux)))
        return cls(**kwargs)

    jax.tree_util.register_pytree_with_keys(cls, flatten_with_keys, unflatten, flatten)

    def replace(self: _T, **updates: Any) -> _T:
        return dataclasses.replace(self, **updates)

    cls.replace = replace  # type: ignore[attr-defined]
    return cls
