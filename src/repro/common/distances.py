"""Distance primitives shared across the system.

All distances are *squared* Euclidean unless noted — monotone in L2, cheaper,
and what proximity-graph searches actually rank by. Inner-product and cosine
variants are provided for the MIPS-style retrieval paths (two-tower).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def squared_l2(a: Array, b: Array) -> Array:
    """Pairwise squared L2 between rows of ``a`` (A, d) and ``b`` (B, d).

    Uses the matmul expansion ``|a|^2 - 2 a.b + |b|^2`` so the MXU does the
    heavy lifting; accumulates in f32.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    a2 = jnp.sum(a * a, axis=-1, keepdims=True)  # (A, 1)
    b2 = jnp.sum(b * b, axis=-1, keepdims=True).T  # (1, B)
    ab = a @ b.T  # (A, B)
    d = a2 - 2.0 * ab + b2
    return jnp.maximum(d, 0.0)


def squared_l2_one_to_many(q: Array, x: Array) -> Array:
    """Squared L2 between a single query (d,) and rows of ``x`` (N, d)."""
    diff = x.astype(jnp.float32) - q.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=-1)


def batched_rowwise_sqdist(q: Array, rows: Array) -> Array:
    """(B, d) queries vs (B, M, d) gathered rows -> (B, M) squared distances."""
    diff = rows.astype(jnp.float32) - q.astype(jnp.float32)[:, None, :]
    return jnp.sum(diff * diff, axis=-1)


def neg_inner_product(a: Array, b: Array) -> Array:
    """Negative inner product (so that smaller == more similar), (A,d)x(B,d)."""
    return -(a.astype(jnp.float32) @ b.astype(jnp.float32).T)


def cosine_distance(a: Array, b: Array) -> Array:
    an = a / (jnp.linalg.norm(a, axis=-1, keepdims=True) + 1e-12)
    bn = b / (jnp.linalg.norm(b, axis=-1, keepdims=True) + 1e-12)
    return 1.0 - an @ bn.T
