"""Blocked Lloyd's k-means in pure JAX (used by PQ codebook training and by
the synthetic-label pipeline that reproduces the paper's SIFT labeling)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.common.distances import squared_l2

Array = jax.Array


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans(rng: Array, x: Array, k: int, iters: int = 25) -> tuple[Array, Array]:
    """Returns (centroids (k, d), assignment (n,) int32)."""
    n = x.shape[0]
    init_idx = jax.random.choice(rng, n, (k,), replace=False)
    cent = x[init_idx].astype(jnp.float32)

    def step(cent, _):
        d = squared_l2(x, cent)  # (n, k)
        assign = jnp.argmin(d, axis=-1)
        one_hot = jax.nn.one_hot(assign, k, dtype=jnp.float32)  # (n, k)
        counts = jnp.sum(one_hot, axis=0)  # (k,)
        sums = one_hot.T @ x.astype(jnp.float32)  # (k, d)
        new = sums / jnp.maximum(counts[:, None], 1.0)
        # Keep empty clusters where they were.
        new = jnp.where(counts[:, None] > 0, new, cent)
        return new, None

    cent, _ = jax.lax.scan(step, cent, None, length=iters)
    assign = jnp.argmin(squared_l2(x, cent), axis=-1).astype(jnp.int32)
    return cent, assign
