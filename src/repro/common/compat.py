"""Version-compatibility shims for the jax API surface this repo uses.

The repo targets current jax (top-level ``jax.shard_map`` with
``check_vma``; dict-returning ``cost_analysis``) but must also run on the
0.4.x CPU wheels pinned in requirements-dev.txt, where ``shard_map`` still
lives under ``jax.experimental`` (with ``check_rep``) and
``Compiled.cost_analysis()`` returns a one-element list of dicts. Every
call site goes through these wrappers instead of branching locally.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional

import jax


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across jax versions (drop-in for the modern call)."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_vma,
            )
        except TypeError:  # top-level API predating the check_vma rename
            return jax.shard_map(
                fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma,
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def set_mesh(mesh):
    """``jax.set_mesh`` across versions (context manager).

    On jax without an ambient-mesh API (0.4.x), this is a no-op context:
    there, shardings always propagate from explicitly placed arguments and
    the mesh is bound per shard_map call, so nothing needs activating.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return contextlib.nullcontext(mesh)


def cost_analysis_dict(compiled) -> Optional[dict[str, Any]]:
    """``Compiled.cost_analysis()`` as a flat dict (or None when absent).

    jax 0.4.x returns ``[{...}]`` (one entry per computation); newer jax
    returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost
