"""Structured JSON logging behind a bounded ring-buffer sink.

Every record is one flat dict — ``ts`` (from the *injected* clock),
``event``, and whatever correlation fields the call site attaches
(``req_id`` / ``batch_id`` / ``epoch`` are the ones the serving runtime
stamps) — so a p99 outlier's whole life is greppable by request id across
admission, flush, dispatch, and completion records.

The ring buffer keeps the server's memory flat no matter how chatty the
stream is (oldest records evicted and counted); ``flush()`` writes the
buffered records as JSON lines, and an optional live ``stream`` tees every
record out as it happens (``launch/serve.py --log-json``).
"""
from __future__ import annotations

import json
from collections import deque
from typing import Callable, Deque, List, Optional, TextIO


class RingBufferSink:
    """Bounded in-memory record buffer: O(1) emit, oldest-out eviction."""

    def __init__(self, capacity: int = 4096):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive: {capacity}")
        self.capacity = int(capacity)
        self._records: Deque[dict] = deque(maxlen=self.capacity)
        self.emitted = 0  # lifetime count, evictions included

    def emit(self, record: dict) -> None:
        self._records.append(record)
        self.emitted += 1

    def __len__(self) -> int:
        return len(self._records)

    @property
    def dropped(self) -> int:
        return self.emitted - len(self._records)

    def records(self) -> List[dict]:
        return list(self._records)

    def clear(self) -> None:
        self._records.clear()

    def flush(self, fh: TextIO) -> int:
        """Write the buffered records as JSON lines (oldest first) and
        clear the buffer; returns the number written."""
        n = 0
        for rec in self._records:
            fh.write(json.dumps(rec, default=str) + "\n")
            n += 1
        fh.flush()
        self._records.clear()
        return n


class JsonLogger:
    """Structured logger over a ring sink, timestamped by an injected
    clock (the serving runtime passes its own, so virtual-time replays
    produce virtual-time logs)."""

    def __init__(
        self,
        sink: Optional[RingBufferSink] = None,
        clock: Optional[Callable[[], float]] = None,
        stream: Optional[TextIO] = None,
        fields: Optional[dict] = None,
    ):
        self.sink = sink if sink is not None else RingBufferSink()
        self.clock = clock
        self.stream = stream
        self.fields = dict(fields) if fields else {}

    def bind(self, **fields) -> "JsonLogger":
        """A child logger sharing this sink/stream with extra fields
        stamped on every record (e.g. ``logger.bind(replica=2)``) — how
        the replica tier tags one shared ring by replica id."""
        merged = {**self.fields, **fields}
        return JsonLogger(
            sink=self.sink, clock=self.clock, stream=self.stream,
            fields=merged,
        )

    def log(self, event: str, **fields) -> dict:
        record = {"event": str(event)}
        if self.clock is not None:
            record["ts"] = round(float(self.clock()), 9)
        if self.fields:
            record.update(self.fields)
        record.update(fields)
        self.sink.emit(record)
        if self.stream is not None:
            self.stream.write(json.dumps(record, default=str) + "\n")
        return record

    def flush_to(self, fh: TextIO) -> int:
        return self.sink.flush(fh)

    def flush_to_path(self, path: str) -> int:
        with open(path, "a") as fh:
            return self.sink.flush(fh)
