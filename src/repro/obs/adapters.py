"""Registry adapters over the serving runtime's existing state.

``instrument_runtime`` builds ONE ``MetricsRegistry`` whose families read
the live objects the runtime already maintains — telemetry counters, the
log-bucketed ``LatencyHistogram`` (exposed as a *native* Prometheus
histogram: its exact bucket edges as ``le`` labels, ``_sum``/``_count``
from the same fields ``summary()`` reports), per-stage trace histograms,
compile-cache hits/misses, batcher queue depth and per-group occupancy,
the degradation-ladder level, streaming epoch/slot-pool gauges, and
per-strategy router verdicts. Everything is pull-time (``CallbackFamily``):
the scrape reads the same counters the benches read, so ``GET /metrics``
is bit-identical to ``Telemetry.summary()`` by construction, not by
double bookkeeping.

``instrument_tier`` lifts the same surface over a ``ReplicaSet``
(DESIGN.md §13): every family keeps its PR 9 name but each sample gains a
``replica="i"`` label, and the tier appends a rollup sample per label set
under ``replica="all"`` — the elementwise sum, so per-replica histogram
buckets stay cumulative and sum exactly to the rollup (the cumulativity
check CI gates). Sample callbacks take the tier's per-replica lock with a
short timeout so a scrape is consistent against a running pump but can
never deadlock behind a stuck replica; on timeout the family is read
lock-free (a torn-but-live scrape beats a hung one).

Duck-typed on purpose: this module imports nothing from ``repro.serving``
(the serving layer imports obs, never the reverse), so it works over any
object shaped like a ``ServingRuntime`` / ``ReplicaSet``.
"""
from __future__ import annotations

import math
from collections import OrderedDict
from typing import Callable, Dict, Iterable, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry, Sample, format_value

Labels = Tuple[Tuple[str, str], ...]
FamilyFn = Callable[..., List[Sample]]


def latency_hist_samples(
    hist, labels: Tuple[Tuple[str, str], ...] = ()
) -> List[Sample]:
    """Native-histogram samples for a ``serving.telemetry.LatencyHistogram``.

    The log-spaced layout maps 1:1: the underflow bucket's upper edge is
    ``lo``, each log bucket keeps its exact ``upper_edge``, and the
    overflow bucket is ``+Inf`` — so cumulative counts, ``_sum`` and
    ``_count`` reproduce the in-process histogram bit-for-bit and the
    upper-edge quantile rule gives identical p99 answers on both sides."""
    out: List[Sample] = []
    cum = 0
    for b in range(hist.n_buckets + 2):
        cum += int(hist.counts[b])
        edge = hist.upper_edge(b) if b > 0 else hist.lo
        out.append(
            ("_bucket", labels + (("le", format_value(edge)),), float(cum))
        )
    out.append(("_sum", labels, float(hist.sum)))
    out.append(("_count", labels, float(hist.total)))
    return out


def runtime_families(
    runtime, namespace: str = "repro"
) -> List[Tuple[str, str, str, FamilyFn]]:
    """The full metric surface of one runtime as ``(name, type, help,
    fn)`` rows, where ``fn(labels)`` renders the family's samples with a
    label prefix. ``instrument_runtime`` registers them with the empty
    prefix (the PR 9 exposition, unchanged); ``instrument_tier`` registers
    the same rows once and fans each ``fn`` out per replica."""
    ns = namespace
    tel = runtime.telemetry
    fams: List[Tuple[str, str, str, FamilyFn]] = []

    def counter_samples(labels: Labels = ()) -> List[Sample]:
        return [
            ("", labels + (("event", key),), float(tel.counters[key]))
            for key in sorted(tel.counters)
        ]

    fams.append((
        f"{ns}_serving_events_total", "counter",
        "Lifecycle event counters (Telemetry.counters): submitted, "
        "completed, goodput, shed_*, fault_*, routed_*, epoch_swaps, ...",
        counter_samples,
    ))

    def verdict_samples(labels: Labels = ()) -> List[Sample]:
        return [
            (
                "",
                labels + (("strategy", key[len("routed_"):]),),
                float(tel.counters[key]),
            )
            for key in sorted(tel.counters)
            if key.startswith("routed_")
        ]

    fams.append((
        f"{ns}_serving_route_verdicts_total", "counter",
        "Hybrid strategy-router admission verdicts by executor strategy",
        verdict_samples,
    ))

    fams.append((
        f"{ns}_serving_latency_seconds", "histogram",
        "Arrival-to-completion latency of served responses "
        "(log-bucketed; lifetime of the process)",
        lambda labels=(): latency_hist_samples(tel.latency_hist, labels),
    ))

    def stage_samples(labels: Labels = ()) -> List[Sample]:
        out: List[Sample] = []
        for stage in sorted(tel.stage_hists):
            out.extend(
                latency_hist_samples(
                    tel.stage_hists[stage], labels + (("stage", stage),)
                )
            )
        return out

    fams.append((
        f"{ns}_serving_stage_seconds", "histogram",
        "Per-request lifecycle stage durations from the span recorder "
        "(queue_wait | batch_wait | execute | overhead)",
        stage_samples,
    ))

    cache = runtime.cache
    fams.append((
        f"{ns}_serving_compile_cache_hits_total", "counter",
        "Compile-cache lookups served by an already-traced closure",
        lambda labels=(): [("", labels, float(cache.hits))],
    ))
    fams.append((
        f"{ns}_serving_compile_cache_misses_total", "counter",
        "Compile-cache lookups that traced a new closure",
        lambda labels=(): [("", labels, float(cache.misses))],
    ))
    fams.append((
        f"{ns}_serving_compile_cache_traces", "gauge",
        "Compiled closures resident (hard-bounded by the trace budget)",
        lambda labels=(): [("", labels, float(cache.trace_count))],
    ))
    fams.append((
        f"{ns}_serving_trace_budget", "gauge",
        "Declared compile budget: |ladder| x |families| x |tiers|",
        lambda labels=(): [("", labels, float(runtime.trace_budget))],
    ))

    batcher = runtime.batcher
    fams.append((
        f"{ns}_serving_queue_depth", "gauge",
        "Requests waiting in the dynamic batcher (all groups)",
        lambda labels=(): [("", labels, float(batcher.pending_count()))],
    ))

    def occupancy_samples(labels: Labels = ()) -> List[Sample]:
        out: List[Sample] = []
        for (group, tier), n in sorted(
            batcher.occupancy().items(), key=lambda kv: (str(kv[0][0]), kv[0][1])
        ):
            out.append((
                "",
                labels + (
                    ("family", str(group[0])),
                    ("tier", str(tier)),
                    ("group", repr(group)),
                ),
                float(n),
            ))
        return out

    fams.append((
        f"{ns}_serving_group_pending", "gauge",
        "Batcher bucket occupancy per (compatibility group, tier)",
        occupancy_samples,
    ))

    fams.append((
        f"{ns}_serving_in_flight", "gauge",
        "Admitted requests not yet completed/shed (backpressure quantity)",
        lambda labels=(): [("", labels, float(runtime.in_flight))],
    ))

    fams.append((
        f"{ns}_serving_busy_seconds_total", "counter",
        "Dispatch CPU seconds consumed by this runtime — one charge per "
        "microbatch (queries and mutations) on the dispatching thread's "
        "CPU clock: the replica's true busy time on its own core, not "
        "per-request wall batch charges",
        lambda labels=(): [("", labels, float(runtime.busy_seconds))],
    ))

    controller = runtime.controller
    fams.append((
        f"{ns}_serving_degradation_level", "gauge",
        "SLO degradation-ladder level (0 normal .. 3 shedding; 0 when "
        "no ladder is configured)",
        lambda labels=(): [("", labels, float(controller.degradation_level))],
    ))

    def ladder_ema_samples(labels: Labels = ()) -> List[Sample]:
        ladder = controller.ladder
        if ladder is None:
            return []
        out: List[Sample] = []
        for name, v in (
            ("queue", ladder.queue_ema),
            ("latency", ladder.lat_ema),
            ("service", ladder.service_ema),
        ):
            if v is not None and not math.isnan(v):
                out.append(("", labels + (("signal", name),), float(v)))
        return out

    fams.append((
        f"{ns}_serving_slo_ema", "gauge",
        "Degradation-ladder EMAs: queue depth, completion latency (s), "
        "execution-only service time (s)",
        ladder_ema_samples,
    ))

    if hasattr(runtime.executor, "apply_mutations"):  # streaming executor
        index = runtime.executor.index
        executor = runtime.executor
        fams.append((
            f"{ns}_streaming_epoch", "gauge",
            "Published index epoch (queries in one flush share it)",
            lambda labels=(): [("", labels, float(executor.epoch))],
        ))

        def slot_samples(labels: Labels = ()) -> List[Sample]:
            stats = index.pool.stats()
            return [
                ("", labels + (("state", state),), float(stats[state]))
                for state in ("live", "pending", "free")
            ]

        fams.append((
            f"{ns}_streaming_slots", "gauge",
            "Slot-pool occupancy by state (live + pending + free = capacity)",
            slot_samples,
        ))
        fams.append((
            f"{ns}_streaming_capacity", "gauge",
            "Slot-pool capacity (fixed at build time)",
            lambda labels=(): [("", labels, float(index.capacity))],
        ))
        fams.append((
            f"{ns}_streaming_consolidations_total", "counter",
            "Tombstone consolidation passes run",
            lambda labels=(): [("", labels, float(index.consolidations))],
        ))
    return fams


def instrument_runtime(
    runtime,
    registry: Optional[MetricsRegistry] = None,
    namespace: str = "repro",
) -> MetricsRegistry:
    """Register the full serving metric surface for one runtime."""
    reg = registry if registry is not None else MetricsRegistry()
    for name, mtype, help_text, fn in runtime_families(runtime, namespace):
        reg.callback(name, mtype, help_text, fn)
    return reg


def rollup_samples(samples: Iterable[Sample]) -> List[Sample]:
    """Tier rollups: per (suffix, labels-minus-replica) group, the sum of
    all replicas' values re-emitted under ``replica="all"``. Summing works
    for every family here — counters and gauges add, and cumulative
    histogram buckets summed per ``le`` stay cumulative (all replicas
    share identical ``LatencyHistogram`` edges)."""
    groups: "OrderedDict[Tuple[str, Labels], float]" = OrderedDict()
    for suffix, labels, value in samples:
        rest = tuple(kv for kv in labels if kv[0] != "replica")
        key = (suffix, rest)
        groups[key] = groups.get(key, 0.0) + float(value)
    return [
        (suffix, (("replica", "all"),) + rest, value)
        for (suffix, rest), value in groups.items()
    ]


def instrument_tier(
    tier,
    registry: Optional[MetricsRegistry] = None,
    namespace: str = "repro",
    lock_timeout: float = 0.25,
) -> MetricsRegistry:
    """Register the metric surface of a ``ReplicaSet``: same family names
    as ``instrument_runtime``, each sample labeled ``replica="i"``, plus a
    ``replica="all"`` rollup per label set, plus tier-level families."""
    reg = registry if registry is not None else MetricsRegistry()
    per_replica: List[Tuple[int, object, Dict[str, FamilyFn]]] = []
    meta: "OrderedDict[str, Tuple[str, str]]" = OrderedDict()
    for i, rt in enumerate(tier.replicas):
        fns: Dict[str, FamilyFn] = {}
        for name, mtype, help_text, fn in runtime_families(rt, namespace):
            fns[name] = fn
            meta.setdefault(name, (mtype, help_text))
        per_replica.append((i, tier.locks[i], fns))

    def make_family(name: str) -> Callable[[], List[Sample]]:
        def family_samples() -> List[Sample]:
            out: List[Sample] = []
            for i, lock, fns in per_replica:
                fn = fns.get(name)
                if fn is None:
                    continue
                prefix: Labels = (("replica", str(i)),)
                got = lock.acquire(timeout=lock_timeout)
                try:
                    out.extend(fn(prefix))
                except RuntimeError:
                    # Lock-free fallback raced a mutating pump (e.g. the
                    # batcher dict grew mid-iteration) — skip this
                    # replica's family for this scrape rather than hang.
                    pass
                finally:
                    if got:
                        lock.release()
            out.extend(rollup_samples(out))
            return out

        return family_samples

    for name, (mtype, help_text) in meta.items():
        reg.callback(name, mtype, help_text, make_family(name))

    ns = namespace
    reg.callback(
        f"{ns}_tier_replicas", "gauge",
        "Shared-nothing runtime replicas behind this front-end",
        lambda: [("", (), float(tier.n_replicas))],
    )
    reg.callback(
        f"{ns}_tier_submitted_total", "counter",
        "Requests (queries + broadcast mutations) accepted by the tier "
        "router",
        lambda: [("", (), float(tier.submitted))],
    )
    reg.callback(
        f"{ns}_tier_router_info", "gauge",
        "Active replica-router policy (value is always 1)",
        lambda: [("", (("router", tier.router.name),), 1.0)],
    )
    return reg
