"""Registry adapters over the serving runtime's existing state.

``instrument_runtime`` builds ONE ``MetricsRegistry`` whose families read
the live objects the runtime already maintains — telemetry counters, the
log-bucketed ``LatencyHistogram`` (exposed as a *native* Prometheus
histogram: its exact bucket edges as ``le`` labels, ``_sum``/``_count``
from the same fields ``summary()`` reports), per-stage trace histograms,
compile-cache hits/misses, batcher queue depth and per-group occupancy,
the degradation-ladder level, streaming epoch/slot-pool gauges, and
per-strategy router verdicts. Everything is pull-time (``CallbackFamily``):
the scrape reads the same counters the benches read, so ``GET /metrics``
is bit-identical to ``Telemetry.summary()`` by construction, not by
double bookkeeping.

Duck-typed on purpose: this module imports nothing from ``repro.serving``
(the serving layer imports obs, never the reverse), so it works over any
object shaped like a ``ServingRuntime``.
"""
from __future__ import annotations

import math
from typing import Iterable, List, Optional, Tuple

from repro.obs.metrics import MetricsRegistry, Sample, format_value


def latency_hist_samples(
    hist, labels: Tuple[Tuple[str, str], ...] = ()
) -> List[Sample]:
    """Native-histogram samples for a ``serving.telemetry.LatencyHistogram``.

    The log-spaced layout maps 1:1: the underflow bucket's upper edge is
    ``lo``, each log bucket keeps its exact ``upper_edge``, and the
    overflow bucket is ``+Inf`` — so cumulative counts, ``_sum`` and
    ``_count`` reproduce the in-process histogram bit-for-bit and the
    upper-edge quantile rule gives identical p99 answers on both sides."""
    out: List[Sample] = []
    cum = 0
    for b in range(hist.n_buckets + 2):
        cum += int(hist.counts[b])
        edge = hist.upper_edge(b) if b > 0 else hist.lo
        out.append(
            ("_bucket", labels + (("le", format_value(edge)),), float(cum))
        )
    out.append(("_sum", labels, float(hist.sum)))
    out.append(("_count", labels, float(hist.total)))
    return out


def instrument_runtime(
    runtime,
    registry: Optional[MetricsRegistry] = None,
    namespace: str = "repro",
) -> MetricsRegistry:
    """Register the full serving metric surface for one runtime."""
    reg = registry if registry is not None else MetricsRegistry()
    ns = namespace
    tel = runtime.telemetry

    def counter_samples() -> Iterable[Sample]:
        return [
            ("", (("event", key),), float(tel.counters[key]))
            for key in sorted(tel.counters)
        ]

    reg.callback(
        f"{ns}_serving_events_total", "counter",
        "Lifecycle event counters (Telemetry.counters): submitted, "
        "completed, goodput, shed_*, fault_*, routed_*, epoch_swaps, ...",
        counter_samples,
    )

    def verdict_samples() -> Iterable[Sample]:
        return [
            ("", (("strategy", key[len("routed_"):]),), float(tel.counters[key]))
            for key in sorted(tel.counters)
            if key.startswith("routed_")
        ]

    reg.callback(
        f"{ns}_serving_route_verdicts_total", "counter",
        "Hybrid strategy-router admission verdicts by executor strategy",
        verdict_samples,
    )

    reg.callback(
        f"{ns}_serving_latency_seconds", "histogram",
        "Arrival-to-completion latency of served responses "
        "(log-bucketed; lifetime of the process)",
        lambda: latency_hist_samples(tel.latency_hist),
    )

    def stage_samples() -> Iterable[Sample]:
        out: List[Sample] = []
        for stage in sorted(tel.stage_hists):
            out.extend(
                latency_hist_samples(
                    tel.stage_hists[stage], (("stage", stage),)
                )
            )
        return out

    reg.callback(
        f"{ns}_serving_stage_seconds", "histogram",
        "Per-request lifecycle stage durations from the span recorder "
        "(queue_wait | batch_wait | execute | overhead)",
        stage_samples,
    )

    cache = runtime.cache
    reg.callback(
        f"{ns}_serving_compile_cache_hits_total", "counter",
        "Compile-cache lookups served by an already-traced closure",
        lambda: [("", (), float(cache.hits))],
    )
    reg.callback(
        f"{ns}_serving_compile_cache_misses_total", "counter",
        "Compile-cache lookups that traced a new closure",
        lambda: [("", (), float(cache.misses))],
    )
    reg.callback(
        f"{ns}_serving_compile_cache_traces", "gauge",
        "Compiled closures resident (hard-bounded by the trace budget)",
        lambda: [("", (), float(cache.trace_count))],
    )
    reg.callback(
        f"{ns}_serving_trace_budget", "gauge",
        "Declared compile budget: |ladder| x |families| x |tiers|",
        lambda: [("", (), float(runtime.trace_budget))],
    )

    batcher = runtime.batcher
    reg.callback(
        f"{ns}_serving_queue_depth", "gauge",
        "Requests waiting in the dynamic batcher (all groups)",
        lambda: [("", (), float(batcher.pending_count()))],
    )

    def occupancy_samples() -> Iterable[Sample]:
        out: List[Sample] = []
        for (group, tier), n in sorted(
            batcher.occupancy().items(), key=lambda kv: (str(kv[0][0]), kv[0][1])
        ):
            out.append((
                "",
                (
                    ("family", str(group[0])),
                    ("tier", str(tier)),
                    ("group", repr(group)),
                ),
                float(n),
            ))
        return out

    reg.callback(
        f"{ns}_serving_group_pending", "gauge",
        "Batcher bucket occupancy per (compatibility group, tier)",
        occupancy_samples,
    )

    reg.callback(
        f"{ns}_serving_in_flight", "gauge",
        "Admitted requests not yet completed/shed (backpressure quantity)",
        lambda: [("", (), float(runtime.in_flight))],
    )

    controller = runtime.controller
    reg.callback(
        f"{ns}_serving_degradation_level", "gauge",
        "SLO degradation-ladder level (0 normal .. 3 shedding; 0 when "
        "no ladder is configured)",
        lambda: [("", (), float(controller.degradation_level))],
    )

    def ladder_ema_samples() -> Iterable[Sample]:
        ladder = controller.ladder
        if ladder is None:
            return []
        out: List[Sample] = []
        for name, v in (
            ("queue", ladder.queue_ema),
            ("latency", ladder.lat_ema),
            ("service", ladder.service_ema),
        ):
            if v is not None and not math.isnan(v):
                out.append(("", (("signal", name),), float(v)))
        return out

    reg.callback(
        f"{ns}_serving_slo_ema", "gauge",
        "Degradation-ladder EMAs: queue depth, completion latency (s), "
        "execution-only service time (s)",
        ladder_ema_samples,
    )

    if hasattr(runtime.executor, "apply_mutations"):  # streaming executor
        index = runtime.executor.index
        reg.callback(
            f"{ns}_streaming_epoch", "gauge",
            "Published index epoch (queries in one flush share it)",
            lambda: [("", (), float(runtime.executor.epoch))],
        )

        def slot_samples() -> Iterable[Sample]:
            stats = index.pool.stats()
            return [
                ("", (("state", state),), float(stats[state]))
                for state in ("live", "pending", "free")
            ]

        reg.callback(
            f"{ns}_streaming_slots", "gauge",
            "Slot-pool occupancy by state (live + pending + free = capacity)",
            slot_samples,
        )
        reg.callback(
            f"{ns}_streaming_capacity", "gauge",
            "Slot-pool capacity (fixed at build time)",
            lambda: [("", (), float(index.capacity))],
        )
        reg.callback(
            f"{ns}_streaming_consolidations_total", "counter",
            "Tombstone consolidation passes run",
            lambda: [("", (), float(index.consolidations))],
        )
    return reg
