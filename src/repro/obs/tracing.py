"""Per-request span recorder (DESIGN.md §12).

One ``RequestTrace`` rides each in-flight ``Request`` through the serving
runtime, stamping its lifecycle — admission → route verdict → batcher wait
→ flush/pack → executor dispatch → completion/shed — from the *injected*
clock only (this module never reads wall time itself, honoring the
``test_no_wall_clock`` discipline: every timestamp is handed in by the
runtime, which owns the clock).

The stage accumulators tile the request's whole life, escalations and
fault retries included (a request that re-enters the batcher keeps
accumulating into the same trace):

    queue_wait — time spent waiting in the batcher (enqueue → flush),
                 summed across escalation/retry passes;
    batch_wait — flush → executor dispatch start (EDF ordering, shed
                 checks, and earlier microbatches of the same flush);
    execute    — measured dispatch duration(s) charged to the timeline;
    overhead   — everything else (host bookkeeping between stamps),
                 computed as the residual so the stage sum equals the
                 end-to-end latency by construction.

``breakdown()`` is what lands on ``Response.trace``; per-stage histograms
are fed from it by ``Telemetry.on_complete``.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

STAGES = ("queue_wait", "batch_wait", "execute", "overhead")

# Events are bounded per trace: a pathological escalation/retry loop must
# not grow a request's span list without bound (the stage accumulators
# keep counting past the cap; only the event detail stops).
MAX_EVENTS = 64


class RequestTrace:
    """Stage accumulators + a bounded event log for one request."""

    __slots__ = (
        "req_id", "arrival_t", "queue_wait", "batch_wait", "execute",
        "passes", "events", "_flush_t", "_truncated", "replica",
    )

    def __init__(
        self, req_id: int, arrival_t: float, replica: Optional[int] = None
    ):
        self.req_id = int(req_id)
        self.arrival_t = float(arrival_t)
        self.replica = replica  # which tier replica served this request
        self.queue_wait = 0.0
        self.batch_wait = 0.0
        self.execute = 0.0
        self.passes = 0  # dispatches this request participated in
        self.events: List[Tuple[str, float]] = [("admitted", self.arrival_t)]
        self._flush_t: Optional[float] = None
        self._truncated = False

    def mark(self, event: str, t: float) -> None:
        if len(self.events) < MAX_EVENTS:
            self.events.append((event, float(t)))
        else:
            self._truncated = True

    # --- lifecycle stamps (the runtime calls these) -----------------------
    def on_flush(self, enqueue_t: float, flush_t: float) -> None:
        """This request's group was pulled from the batcher: one batcher
        wait ends. Escalated/retried requests hit this once per pass."""
        self.queue_wait += max(float(flush_t) - float(enqueue_t), 0.0)
        self._flush_t = float(flush_t)
        self.mark("flushed", flush_t)

    def on_exec(self, start_t: float, end_t: float) -> None:
        """One executor dispatch covered [start_t, end_t] of the timeline;
        the gap since this pass's flush is batch_wait (EDF ordering plus
        earlier batches of the same flush)."""
        if self._flush_t is not None:
            self.batch_wait += max(float(start_t) - self._flush_t, 0.0)
            self._flush_t = None
        self.execute += max(float(end_t) - float(start_t), 0.0)
        self.passes += 1
        self.mark("executed", end_t)

    def breakdown(self, complete_t: float, outcome: str = "served") -> dict:
        """The ``Response.trace`` payload. ``overhead`` is the residual of
        the accounted stages against end-to-end latency, so the stage sum
        reproduces the latency exactly (clamped at zero against float
        dust)."""
        self.mark(outcome, complete_t)
        total = max(float(complete_t) - self.arrival_t, 0.0)
        accounted = self.queue_wait + self.batch_wait + self.execute
        out = {
            "queue_wait": self.queue_wait,
            "batch_wait": self.batch_wait,
            "execute": self.execute,
            "overhead": max(total - accounted, 0.0),
            "total": total,
            "passes": self.passes,
            "outcome": outcome,
            "events": list(self.events),
        }
        if self.replica is not None:
            out["replica"] = self.replica
        if self._truncated:
            out["events_truncated"] = True
        return out


def stage_sum(trace: dict) -> float:
    return sum(float(trace[s]) for s in STAGES)


def trace_consistent(trace: dict, rel_tol: float = 0.01) -> bool:
    """The acceptance predicate: the stage breakdown tiles the end-to-end
    latency to within ``rel_tol`` (1% by default; an absolute epsilon
    covers ~zero-latency sheds)."""
    total = float(trace["total"])
    return abs(stage_sum(trace) - total) <= max(rel_tol * total, 1e-9)
