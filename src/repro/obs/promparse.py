"""Minimal Prometheus text-format parser — the round-trip verifier.

Parses what ``MetricsRegistry.render_prometheus()`` (or any conformant
exporter) emits and *validates* it while doing so: metric-name charset,
HELP/TYPE placement (at most one each, before any sample of the family),
histogram structure (cumulative non-decreasing ``le`` buckets, a ``+Inf``
edge whose count equals ``_count``, a ``_sum`` sample). The exposition
tests and the CI obs smoke feed scraped ``/metrics`` text through this and
then assert the parsed values are bit-identical to the in-process
``Telemetry`` state — proving the external surface carries the same
numbers as the BENCH artifacts.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

METRIC_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")
_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?"
    r"\s+(?P<value>\S+)"
    r"(?:\s+(?P<ts>-?\d+))?$"
)
_LABEL_RE = re.compile(r'\s*([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"\s*(,|$)')
_HIST_SUFFIXES = ("_bucket", "_sum", "_count")


class ExpositionParseError(ValueError):
    pass


def _parse_value(tok: str) -> float:
    if tok == "+Inf":
        return float("inf")
    if tok == "-Inf":
        return float("-inf")
    if tok == "NaN":
        return float("nan")
    try:
        return float(tok)
    except ValueError as e:
        raise ExpositionParseError(f"bad sample value {tok!r}") from e


def _unescape(v: str) -> str:
    return v.replace(r"\n", "\n").replace(r"\"", '"').replace(r"\\", "\\")


def _parse_labels(body: str) -> Dict[str, str]:
    out: Dict[str, str] = {}
    pos = 0
    while pos < len(body):
        m = _LABEL_RE.match(body, pos)
        if m is None:
            raise ExpositionParseError(f"bad label body {body!r} at {pos}")
        name, value = m.group(1), _unescape(m.group(2))
        if name in out:
            raise ExpositionParseError(f"duplicate label {name!r} in {body!r}")
        out[name] = value
        pos = m.end()
    return out


@dataclasses.dataclass
class ParsedSample:
    name: str  # full sample name (incl. _bucket/_sum/_count suffix)
    labels: Dict[str, str]
    value: float


@dataclasses.dataclass
class ParsedFamily:
    name: str
    mtype: str = "untyped"
    help: Optional[str] = None
    samples: List[ParsedSample] = dataclasses.field(default_factory=list)

    def _match(self, labels: Dict[str, str], sample: ParsedSample) -> bool:
        return all(sample.labels.get(k) == str(v) for k, v in labels.items())

    def value(self, **labels) -> float:
        hits = [
            s for s in self.samples
            if s.name == self.name and self._match(labels, s)
        ]
        if len(hits) != 1:
            raise KeyError(
                f"{self.name}{labels}: {len(hits)} matching samples"
            )
        return hits[0].value

    def label_values(self, label: str) -> List[str]:
        return [s.labels[label] for s in self.samples if label in s.labels]

    # --- histogram views --------------------------------------------------
    def buckets(self, **labels) -> List[Tuple[float, float]]:
        """(upper edge, cumulative count) pairs, ascending by edge."""
        if self.mtype != "histogram":
            raise TypeError(f"{self.name} is {self.mtype}, not histogram")
        out = []
        for s in self.samples:
            if s.name != self.name + "_bucket":
                continue
            rest = {k: v for k, v in s.labels.items() if k != "le"}
            if not self._match(labels, ParsedSample(s.name, rest, s.value)):
                continue
            out.append((_parse_value(s.labels["le"]), s.value))
        return sorted(out, key=lambda p: p[0])

    def hist_count(self, **labels) -> float:
        return self._suffixed("_count", labels)

    def hist_sum(self, **labels) -> float:
        return self._suffixed("_sum", labels)

    def _suffixed(self, suffix: str, labels: Dict[str, str]) -> float:
        hits = [
            s for s in self.samples
            if s.name == self.name + suffix and self._match(labels, s)
        ]
        if len(hits) != 1:
            raise KeyError(f"{self.name}{suffix}{labels}: {len(hits)} samples")
        return hits[0].value

    def quantile(self, p: float, **labels) -> float:
        """Upper-edge quantile over the cumulative buckets — the same
        conservative rule ``LatencyHistogram.quantile`` uses, so the two
        must agree exactly on the same data."""
        buckets = self.buckets(**labels)
        total = self.hist_count(**labels)
        if total == 0:
            return float("nan")
        rank = math.ceil(total * (p / 100.0))
        rank = min(max(rank, 1), total)
        for edge, cum in buckets:
            if cum >= rank:
                return edge
        return float("inf")


def _base_name(sample_name: str, families: Dict[str, ParsedFamily]) -> str:
    for suffix in _HIST_SUFFIXES:
        if sample_name.endswith(suffix):
            base = sample_name[: -len(suffix)]
            fam = families.get(base)
            if fam is not None and fam.mtype == "histogram":
                return base
    return sample_name


def parse_exposition(text: str) -> Dict[str, ParsedFamily]:
    """Parse + validate one exposition payload into families by name."""
    families: Dict[str, ParsedFamily] = {}
    seen_samples_of: set = set()

    def family(name: str) -> ParsedFamily:
        fam = families.get(name)
        if fam is None:
            fam = families[name] = ParsedFamily(name=name)
        return fam

    for lineno, raw in enumerate(text.split("\n"), start=1):
        line = raw.rstrip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) < 3 or parts[1] not in ("HELP", "TYPE"):
                continue  # plain comment
            kind, name = parts[1], parts[2]
            if not METRIC_NAME_RE.match(name):
                raise ExpositionParseError(
                    f"line {lineno}: bad metric name {name!r} in {kind}"
                )
            fam = family(name)
            if name in seen_samples_of:
                raise ExpositionParseError(
                    f"line {lineno}: {kind} for {name} after its samples"
                )
            if kind == "HELP":
                if fam.help is not None:
                    raise ExpositionParseError(
                        f"line {lineno}: duplicate HELP for {name}"
                    )
                fam.help = _unescape(parts[3]) if len(parts) > 3 else ""
            else:
                if fam.mtype != "untyped":
                    raise ExpositionParseError(
                        f"line {lineno}: duplicate TYPE for {name}"
                    )
                if len(parts) < 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"
                ):
                    raise ExpositionParseError(
                        f"line {lineno}: bad TYPE line {line!r}"
                    )
                fam.mtype = parts[3]
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ExpositionParseError(f"line {lineno}: unparseable {line!r}")
        name = m.group("name")
        if not METRIC_NAME_RE.match(name):
            raise ExpositionParseError(f"line {lineno}: bad name {name!r}")
        labels = _parse_labels(m.group("labels")) if m.group("labels") else {}
        base = _base_name(name, families)
        fam = family(base)
        seen_samples_of.add(base)
        fam.samples.append(
            ParsedSample(name=name, labels=labels, value=_parse_value(m.group("value")))
        )

    for fam in families.values():
        if fam.mtype == "histogram":
            _validate_histogram(fam)
    return families


def _validate_histogram(fam: ParsedFamily) -> None:
    """Cumulative non-decreasing buckets, a +Inf edge equal to _count, and
    a _sum sample — per label set."""
    keys = set()
    for s in fam.samples:
        keys.add(tuple(sorted(
            (k, v) for k, v in s.labels.items() if k != "le"
        )))
    for key in keys:
        labels = dict(key)
        buckets = fam.buckets(**labels)
        if not buckets:
            raise ExpositionParseError(f"{fam.name}{labels}: no buckets")
        if not math.isinf(buckets[-1][0]):
            raise ExpositionParseError(f"{fam.name}{labels}: no +Inf bucket")
        counts = [c for _, c in buckets]
        if any(lo > hi for lo, hi in zip(counts, counts[1:])):
            raise ExpositionParseError(
                f"{fam.name}{labels}: buckets not cumulative: {counts}"
            )
        count = fam.hist_count(**labels)
        if counts[-1] != count:
            raise ExpositionParseError(
                f"{fam.name}{labels}: +Inf bucket {counts[-1]} != _count {count}"
            )
        fam.hist_sum(**labels)  # raises if missing
