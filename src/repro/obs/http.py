"""Async HTTP front-end over ``ServingRuntime.submit``/``poll``.

A stdlib ``ThreadingHTTPServer`` (no new dependencies) exposing:

    POST /v1/search   JSON {query, k, family, labels|range[, deadline_ms,
                      timeout_s]} -> submit, wait, return the Response
                      (ids, dists, fill, tier, trace breakdown, epoch, ...)
    GET  /metrics     Prometheus text exposition from the registry
    GET  /healthz     liveness + in-flight/queue snapshot
    GET  /varz        full runtime report (telemetry summary, cache,
                      controller, ladder level, epoch) as JSON

The runtime itself stays single-threaded: every runtime call holds one
lock, and a background *pump* thread advances the clock (virtual clocks
advance by the batcher's ``max_wait`` per tick, so deterministic-clock
runtimes serve over a real socket too) and runs ``step()``. Handler
threads only submit under the lock and then poll-wait, so the batcher
still groups concurrent requests into shared microbatches.

``close()`` is the graceful shutdown: stop admitting, drain the runtime
(every in-flight request completes or sheds — nothing is lost), flush the
structured-log sink, then stop the socket.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

import numpy as np


def _response_payload(resp) -> dict:
    return {
        "req_id": resp.req_id,
        "ids": [int(i) for i in np.asarray(resp.ids).tolist()],
        "dists": [float(d) for d in np.asarray(resp.dists).tolist()],
        "k": resp.k,
        "filled": resp.filled,
        "fill_frac": resp.fill_frac,
        "tier": resp.tier,
        "escalations": resp.escalations,
        "latency_s": resp.latency,
        "deadline_missed": resp.deadline_missed,
        "epoch": resp.epoch,
        "strategy": resp.strategy,
        "shed_reason": resp.shed_reason,
        "degraded": resp.degraded,
        "error": resp.error,
        "trace": resp.trace,
        "batch_id": resp.batch_id,
    }


class ServingFrontend:
    """HTTP surface + pump thread over one ``ServingRuntime``."""

    def __init__(
        self,
        runtime,
        registry=None,
        logger=None,
        host: str = "127.0.0.1",
        port: int = 0,
        pump_interval: float = 0.0005,
        default_timeout_s: float = 10.0,
    ):
        if registry is None:
            from repro.obs.adapters import instrument_runtime

            registry = instrument_runtime(runtime)
        self.runtime = runtime
        self.registry = registry
        self.logger = logger
        if logger is not None:
            # One shared logger: HTTP lifecycle records and the runtime's
            # admit/dispatch/complete records interleave on the runtime's
            # (possibly virtual) clock.
            if logger.clock is None:
                logger.clock = runtime.clock
            if getattr(runtime, "logger", None) is None:
                runtime.logger = logger
        self.host = host
        self._port = int(port)
        self.pump_interval = float(pump_interval)
        self.default_timeout_s = float(default_timeout_s)
        self.lock = threading.RLock()
        self._server: Optional[ThreadingHTTPServer] = None
        self._threads: list = []
        self._stop = threading.Event()
        self._accepting = False
        self.started_requests = 0

    # --- lifecycle --------------------------------------------------------
    @property
    def port(self) -> int:
        return self._server.server_address[1] if self._server else self._port

    @property
    def address(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> str:
        frontend = self

        class Handler(_Handler):
            pass

        Handler.frontend = frontend
        self._server = ThreadingHTTPServer((self.host, self._port), Handler)
        self._server.daemon_threads = True
        self._stop.clear()
        self._accepting = True
        serve = threading.Thread(
            target=self._server.serve_forever,
            kwargs={"poll_interval": 0.05},
            name="obs-http-serve",
            daemon=True,
        )
        pump = threading.Thread(
            target=self._pump, name="obs-http-pump", daemon=True
        )
        self._threads = [serve, pump]
        serve.start()
        pump.start()
        if self.logger is not None:
            self.logger.log("http_start", address=self.address)
        return self.address

    def _pump(self) -> None:
        runtime = self.runtime
        while not self._stop.is_set():
            with self.lock:
                clock = runtime.clock
                if hasattr(clock, "advance"):
                    # Virtual-clock runtimes never see max_wait elapse on
                    # their own; the pump supplies the passage of time.
                    clock.advance(runtime.batcher.max_wait)
                runtime.step()
            self._stop.wait(self.pump_interval)

    def close(self, drain: bool = True, log_path: Optional[str] = None) -> dict:
        """Graceful shutdown: stop admitting, drain in-flight work, flush
        the log sink (optionally to ``log_path``), stop the socket.
        Returns a small shutdown report."""
        self._accepting = False
        self._stop.set()
        for t in self._threads:
            if t is threading.current_thread():
                continue
            t.join(timeout=5.0)
        drained = 0
        with self.lock:
            if drain:
                drained = self.runtime.drain()
            if self.logger is not None:
                self.logger.log(
                    "http_shutdown", drained=drained,
                    in_flight=self.runtime.in_flight,
                )
        flushed = 0
        if self.logger is not None and log_path is not None:
            flushed = self.logger.flush_to_path(log_path)
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        return {
            "drained": drained,
            "in_flight": self.runtime.in_flight,
            "log_records_flushed": flushed,
        }

    # --- request handling (called from handler threads) -------------------
    def handle_search(self, payload: dict) -> tuple:
        from repro.serving.types import AdmissionError

        try:
            query = np.asarray(payload["query"], dtype=np.float32)
            k = int(payload.get("k", 10))
            family = str(payload["family"])
            operand = self._parse_operand(family, payload)
        except (KeyError, TypeError, ValueError) as e:
            return 400, {"error": f"bad request: {e}"}
        timeout_s = float(payload.get("timeout_s", self.default_timeout_s))
        if not self._accepting:
            return 503, {"error": "shutting down"}
        with self.lock:
            deadline = None
            if payload.get("deadline_ms") is not None:
                deadline = self.runtime.clock() + float(payload["deadline_ms"]) / 1e3
            try:
                req_id = self.runtime.submit(
                    query, k, family, operand, deadline=deadline
                )
            except AdmissionError as e:
                return 429, {"error": str(e)}
            except (TypeError, ValueError) as e:
                return 400, {"error": f"bad request: {e}"}
            self.started_requests += 1
        give_up = time.monotonic() + timeout_s
        while time.monotonic() < give_up:
            with self.lock:
                resp = self.runtime.poll(req_id)
            if resp is not None:
                return 200, _response_payload(resp)
            time.sleep(self.pump_interval)
        return 504, {"error": "timed out waiting for completion", "req_id": req_id}

    def _parse_operand(self, family: str, payload: dict):
        from repro.serving.workload import label_words_row

        if family == "label":
            labels = payload.get("labels")
            if labels is None:
                raise ValueError("label family needs a 'labels' list")
            return label_words_row(
                [int(x) for x in labels], self.runtime.n_labels
            )
        if family == "range":
            rng = payload.get("range")
            if rng is None or len(rng) != 3:
                raise ValueError("range family needs 'range': [lo, hi, col]")
            return (float(rng[0]), float(rng[1]), int(rng[2]))
        raise ValueError(f"unknown family {family!r}")

    def handle_metrics(self) -> tuple:
        with self.lock:
            body = self.registry.render_prometheus()
        return 200, body

    def handle_healthz(self) -> tuple:
        with self.lock:
            return 200, {
                "status": "ok" if self._accepting else "draining",
                "in_flight": self.runtime.in_flight,
                "queue_depth": self.runtime.batcher.pending_count(),
            }

    def handle_varz(self) -> tuple:
        with self.lock:
            report = self.runtime.report()
            report["degradation_level"] = self.runtime.controller.degradation_level
            report["epoch"] = getattr(self.runtime.executor, "epoch", None)
            report["started_requests"] = self.started_requests
        return 200, report


class _Handler(BaseHTTPRequestHandler):
    frontend: ServingFrontend  # bound per server in ServingFrontend.start
    protocol_version = "HTTP/1.1"

    # Route stdlib request logging into the structured logger (or drop it)
    # instead of spamming stderr.
    def log_message(self, format: str, *args) -> None:  # noqa: A002
        logger = self.frontend.logger
        if logger is not None:
            logger.log("http_access", detail=format % args)

    def _send_json(self, status: int, payload: dict) -> None:
        body = json.dumps(payload, default=str).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, status: int, text: str, ctype: str) -> None:
        body = text.encode()
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 (stdlib API name)
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            status, body = self.frontend.handle_metrics()
            self._send_text(
                status, body, "text/plain; version=0.0.4; charset=utf-8"
            )
        elif path == "/healthz":
            self._send_json(*self.frontend.handle_healthz())
        elif path == "/varz":
            self._send_json(*self.frontend.handle_varz())
        else:
            self._send_json(404, {"error": f"no route {path!r}"})

    def do_POST(self) -> None:  # noqa: N802 (stdlib API name)
        path = self.path.split("?", 1)[0]
        if path != "/v1/search":
            self._send_json(404, {"error": f"no route {path!r}"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(length) or b"{}")
            if not isinstance(payload, dict):
                raise ValueError("body must be a JSON object")
        except (ValueError, json.JSONDecodeError) as e:
            self._send_json(400, {"error": f"bad JSON body: {e}"})
            return
        self._send_json(*self.frontend.handle_search(payload))
