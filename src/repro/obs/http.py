"""Async HTTP front-end over ``ServingRuntime.submit``/``poll``.

A stdlib ``ThreadingHTTPServer`` (no new dependencies) exposing:

    POST /v1/search   JSON {query, k, family, labels|range[, deadline_ms,
                      timeout_s]} -> submit, wait, return the Response
                      (ids, dists, fill, tier, trace breakdown, epoch,
                      replica, ...)
    POST /v1/upsert   JSON {vector[, label, attrs]} -> streaming insert;
                      broadcast to every replica of a tier
    POST /v1/delete   JSON {slot} -> streaming tombstone; broadcast
    GET  /metrics     Prometheus text exposition from the registry
    GET  /healthz     liveness + in-flight/queue snapshot (never blocks
                      behind a draining replica)
    GET  /varz        full runtime report (telemetry summary, cache,
                      controller, ladder level, epoch) as JSON

The front-end serves either ONE runtime or a ``ReplicaSet`` (duck-typed
on a ``.replicas`` attribute — DESIGN.md §13). Each replica stays
single-threaded behind its own lock with its own background *pump* thread
advancing its clock (virtual clocks advance by the batcher's ``max_wait``
per tick, so deterministic-clock runtimes serve over a real socket too)
and running ``step()``. Handler threads only submit under the routed
replica's lock and then poll-wait, so each replica's batcher still groups
concurrent requests into shared microbatches.

Locking is strictly per replica — there is NO front-end-global lock on
the hot path. ``/healthz`` and ``/metrics`` acquire each replica lock
with a short timeout (falling back to a lock-free peek), so one slow
replica mid-drain can never stall the tier's health or scrape surface
(the single-RLock ``close()`` stall this replaces).

``close()`` is the graceful shutdown: stop admitting, stop the pumps,
drain every replica concurrently (every in-flight request completes or
sheds — nothing is lost), flush the structured-log sink, then stop the
socket.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

import numpy as np


def _response_payload(resp, replica: Optional[int] = None) -> dict:
    return {
        "req_id": resp.req_id,
        "ids": [int(i) for i in np.asarray(resp.ids).tolist()],
        "dists": [float(d) for d in np.asarray(resp.dists).tolist()],
        "k": resp.k,
        "filled": resp.filled,
        "fill_frac": resp.fill_frac,
        "tier": resp.tier,
        "escalations": resp.escalations,
        "latency_s": resp.latency,
        "deadline_missed": resp.deadline_missed,
        "epoch": resp.epoch,
        "strategy": resp.strategy,
        "shed_reason": resp.shed_reason,
        "degraded": resp.degraded,
        "error": resp.error,
        "trace": resp.trace,
        "batch_id": resp.batch_id,
        "replica": replica,
    }


class ServingFrontend:
    """HTTP surface + per-replica pump threads over one runtime or a
    ``ReplicaSet``."""

    def __init__(
        self,
        runtime,
        registry=None,
        logger=None,
        host: str = "127.0.0.1",
        port: int = 0,
        pump_interval: float = 0.0005,
        default_timeout_s: float = 10.0,
    ):
        # A ReplicaSet quacks via .replicas/.locks; a bare runtime gets a
        # one-element tier-shaped view so every code path below is shared.
        self.tier = runtime if hasattr(runtime, "replicas") else None
        self.runtime = runtime
        if self.tier is not None:
            self.runtimes = list(self.tier.replicas)
            self.locks = list(self.tier.locks)
        else:
            self.runtimes = [runtime]
            self.locks = [threading.RLock()]
        # Back-compat: PR 9 callers coordinate with the (single) pump via
        # ``frontend.lock`` — that contract survives as replica 0's lock.
        self.lock = self.locks[0]
        if registry is None:
            if self.tier is not None:
                from repro.obs.adapters import instrument_tier

                registry = instrument_tier(self.tier)
            else:
                from repro.obs.adapters import instrument_runtime

                registry = instrument_runtime(runtime)
        self.registry = registry
        self.logger = logger
        if logger is not None:
            # One shared logger: HTTP lifecycle records and the runtimes'
            # admit/dispatch/complete records interleave on the runtime's
            # (possibly virtual) clock; tier replicas log through bound
            # children stamping their replica id.
            if logger.clock is None:
                logger.clock = self.runtimes[0].clock
            if self.tier is not None:
                self.tier.attach_logger(logger)
            elif getattr(runtime, "logger", None) is None:
                runtime.logger = logger
        self.n_labels = self.runtimes[0].n_labels
        self.host = host
        self._port = int(port)
        self.pump_interval = float(pump_interval)
        self.default_timeout_s = float(default_timeout_s)
        self._server: Optional[ThreadingHTTPServer] = None
        self._threads: list = []
        self._stop = threading.Event()
        self._accepting = False
        self._meta_lock = threading.Lock()
        self.started_requests = 0

    # --- lifecycle --------------------------------------------------------
    @property
    def port(self) -> int:
        return self._server.server_address[1] if self._server else self._port

    @property
    def address(self) -> str:
        return f"http://{self.host}:{self.port}"

    @property
    def n_replicas(self) -> int:
        return len(self.runtimes)

    def start(self) -> str:
        frontend = self

        class Handler(_Handler):
            pass

        Handler.frontend = frontend
        self._server = ThreadingHTTPServer((self.host, self._port), Handler)
        self._server.daemon_threads = True
        self._stop.clear()
        self._accepting = True
        serve = threading.Thread(
            target=self._server.serve_forever,
            kwargs={"poll_interval": 0.05},
            name="obs-http-serve",
            daemon=True,
        )
        self._threads = [serve]
        for i in range(self.n_replicas):
            self._threads.append(threading.Thread(
                target=self._pump, args=(i,),
                name=f"obs-http-pump-{i}", daemon=True,
            ))
        for t in self._threads:
            t.start()
        if self.logger is not None:
            self.logger.log(
                "http_start", address=self.address, replicas=self.n_replicas
            )
        return self.address

    def _pump(self, i: int) -> None:
        runtime = self.runtimes[i]
        lock = self.locks[i]
        while not self._stop.is_set():
            with lock:
                clock = runtime.clock
                if hasattr(clock, "advance"):
                    # Virtual-clock runtimes never see max_wait elapse on
                    # their own; the pump supplies the passage of time.
                    clock.advance(runtime.batcher.max_wait)
                runtime.step()
            self._stop.wait(self.pump_interval)

    def close(self, drain: bool = True, log_path: Optional[str] = None) -> dict:
        """Graceful shutdown: stop admitting, stop the pumps, drain every
        replica concurrently (each under its own lock — ``/healthz`` and
        ``/metrics`` keep answering while a slow replica drains), flush
        the log sink (optionally to ``log_path``), stop the socket.
        Returns a small shutdown report."""
        self._accepting = False
        self._stop.set()
        for t in self._threads:
            if t is threading.current_thread():
                continue
            t.join(timeout=5.0)
        drained = 0
        per_replica = [0] * self.n_replicas
        if drain:
            if self.tier is not None:
                drained = self.tier.drain()
                per_replica = [rt.telemetry.counters["completed"]
                               for rt in self.runtimes]
            else:
                with self.locks[0]:
                    drained = self.runtime.drain()
                per_replica = [drained]
        in_flight = sum(rt.in_flight for rt in self.runtimes)
        if self.logger is not None:
            self.logger.log(
                "http_shutdown", drained=drained, in_flight=in_flight,
            )
        flushed = 0
        if self.logger is not None and log_path is not None:
            flushed = self.logger.flush_to_path(log_path)
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._server = None
        return {
            "drained": drained,
            "in_flight": in_flight,
            "log_records_flushed": flushed,
            "replicas": self.n_replicas,
            "completed_per_replica": per_replica,
        }

    # --- request handling (called from handler threads) -------------------
    def handle_search(self, payload: dict) -> tuple:
        from repro.serving.types import AdmissionError

        try:
            query = np.asarray(payload["query"], dtype=np.float32)
            k = int(payload.get("k", 10))
            family = str(payload["family"])
            operand = self._parse_operand(family, payload)
        except (KeyError, TypeError, ValueError) as e:
            return 400, {"error": f"bad request: {e}"}
        timeout_s = float(payload.get("timeout_s", self.default_timeout_s))
        if not self._accepting:
            return 503, {"error": "shutting down"}
        deadline_s = None
        if payload.get("deadline_ms") is not None:
            deadline_s = float(payload["deadline_ms"]) / 1e3
        try:
            if self.tier is not None:
                replica, req_id = self.tier.submit(
                    query, k, family, operand, deadline_s=deadline_s
                )
            else:
                replica = 0
                with self.locks[0]:
                    deadline = (
                        self.runtime.clock() + deadline_s
                        if deadline_s is not None else None
                    )
                    req_id = self.runtime.submit(
                        query, k, family, operand, deadline=deadline
                    )
        except AdmissionError as e:
            return 429, {"error": str(e)}
        except (TypeError, ValueError) as e:
            return 400, {"error": f"bad request: {e}"}
        with self._meta_lock:
            self.started_requests += 1
        give_up = time.monotonic() + timeout_s
        while time.monotonic() < give_up:
            with self.locks[replica]:
                resp = self.runtimes[replica].poll(req_id)
            if resp is not None:
                return 200, _response_payload(
                    resp,
                    replica=(
                        replica if self.tier is not None
                        else self.runtime.replica_id
                    ),
                )
            time.sleep(self.pump_interval)
        return 504, {
            "error": "timed out waiting for completion",
            "req_id": req_id,
            "replica": replica if self.tier is not None else None,
        }

    def handle_mutation(self, kind: str, payload: dict) -> tuple:
        """Streaming upsert/delete over the wire. On a tier the mutation
        is broadcast to every replica at one enqueue boundary and the
        reply aggregates all replicas' outcomes (slot agreement included);
        a single runtime answers with the plain response payload."""
        from repro.serving.types import AdmissionError

        timeout_s = float(payload.get("timeout_s", self.default_timeout_s))
        if not self._accepting:
            return 503, {"error": "shutting down"}
        try:
            if kind == "upsert":
                vector = np.asarray(payload["vector"], dtype=np.float32)
                label = int(payload.get("label", 0))
                attrs = payload.get("attrs")
                if attrs is not None:
                    attrs = np.asarray(attrs, dtype=np.float32)
                if self.tier is not None:
                    handles = self.tier.submit_upsert(
                        vector, label=label, attrs=attrs
                    )
                else:
                    with self.locks[0]:
                        handles = ((0, self.runtime.submit_upsert(
                            vector, label=label, attrs=attrs
                        )),)
            else:  # delete
                slot = int(payload["slot"])
                if self.tier is not None:
                    handles = self.tier.submit_delete(slot)
                else:
                    with self.locks[0]:
                        handles = ((0, self.runtime.submit_delete(slot)),)
        except AdmissionError as e:
            return 429, {"error": str(e)}
        except (KeyError, TypeError, ValueError) as e:
            # TypeError covers "mutations need a streaming executor".
            return 400, {"error": f"bad request: {e}"}
        with self._meta_lock:
            self.started_requests += 1
        results: dict = {}
        give_up = time.monotonic() + timeout_s
        while time.monotonic() < give_up and len(results) < len(handles):
            for i, rid in handles:
                if (i, rid) in results:
                    continue
                with self.locks[i]:
                    resp = self.runtimes[i].poll(rid)
                if resp is not None:
                    results[(i, rid)] = resp
            if len(results) < len(handles):
                time.sleep(self.pump_interval)
        if len(results) < len(handles):
            return 504, {
                "error": f"timed out waiting for {kind} broadcast",
                "completed": len(results),
                "expected": len(handles),
            }
        per_replica = [
            {
                "replica": i,
                "req_id": rid,
                "slot": int(np.asarray(results[(i, rid)].ids)[0]),
                "ok": bool(results[(i, rid)].filled),
                "epoch": results[(i, rid)].epoch,
                "error": results[(i, rid)].error,
            }
            for i, rid in handles
        ]
        slots = {r["slot"] for r in per_replica}
        body = {
            "family": kind,
            "ok": all(r["ok"] for r in per_replica),
            "slot": per_replica[0]["slot"] if len(slots) == 1 else None,
            "slot_consistent": len(slots) == 1,
            "replicas": per_replica,
        }
        if self.tier is None:
            body["epoch"] = per_replica[0]["epoch"]
        return 200, body

    def _parse_operand(self, family: str, payload: dict):
        from repro.serving.workload import label_words_row

        if family == "label":
            labels = payload.get("labels")
            if labels is None:
                raise ValueError("label family needs a 'labels' list")
            return label_words_row([int(x) for x in labels], self.n_labels)
        if family == "range":
            rng = payload.get("range")
            if rng is None or len(rng) != 3:
                raise ValueError("range family needs 'range': [lo, hi, col]")
            return (float(rng[0]), float(rng[1]), int(rng[2]))
        raise ValueError(f"unknown family {family!r}")

    def handle_metrics(self) -> tuple:
        if self.tier is not None:
            # Tier registries lock per replica inside each family callback
            # (with timeouts) — no front-end lock to hold here.
            return 200, self.registry.render_prometheus()
        got = self.locks[0].acquire(timeout=1.0)
        try:
            return 200, self.registry.render_prometheus()
        finally:
            if got:
                self.locks[0].release()

    def handle_healthz(self) -> tuple:
        """Liveness must answer even while a replica drains: every replica
        lock is tried with a short timeout, and a busy replica is reported
        from a lock-free peek instead of awaited."""
        replicas = []
        for i, rt in enumerate(self.runtimes):
            got = self.locks[i].acquire(timeout=0.05)
            try:
                try:
                    depth = rt.batcher.pending_count()
                except RuntimeError:
                    # Lock-free peek raced the pump mutating the batcher's
                    # group dict; depth is unknowable this instant.
                    depth = -1
                replicas.append({
                    "replica": i,
                    "locked": not got,
                    "in_flight": rt.in_flight,
                    "queue_depth": depth,
                })
            finally:
                if got:
                    self.locks[i].release()
        body = {
            "status": "ok" if self._accepting else "draining",
            "in_flight": sum(r["in_flight"] for r in replicas),
            "queue_depth": sum(max(r["queue_depth"], 0) for r in replicas),
        }
        if self.tier is not None:
            body["replicas"] = replicas
        return 200, body

    def handle_varz(self) -> tuple:
        if self.tier is not None:
            report = self.tier.report()
            report["started_requests"] = self.started_requests
            return 200, report
        with self.locks[0]:
            report = self.runtime.report()
            report["degradation_level"] = self.runtime.controller.degradation_level
            report["epoch"] = getattr(self.runtime.executor, "epoch", None)
            report["started_requests"] = self.started_requests
        return 200, report


class _Handler(BaseHTTPRequestHandler):
    frontend: ServingFrontend  # bound per server in ServingFrontend.start
    protocol_version = "HTTP/1.1"

    # Route stdlib request logging into the structured logger (or drop it)
    # instead of spamming stderr.
    def log_message(self, format: str, *args) -> None:  # noqa: A002
        logger = self.frontend.logger
        if logger is not None:
            logger.log("http_access", detail=format % args)

    def _send_json(self, status: int, payload: dict) -> None:
        body = json.dumps(payload, default=str).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_text(self, status: int, text: str, ctype: str) -> None:
        body = text.encode()
        self.send_response(status)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:  # noqa: N802 (stdlib API name)
        path = self.path.split("?", 1)[0]
        if path == "/metrics":
            status, body = self.frontend.handle_metrics()
            self._send_text(
                status, body, "text/plain; version=0.0.4; charset=utf-8"
            )
        elif path == "/healthz":
            self._send_json(*self.frontend.handle_healthz())
        elif path == "/varz":
            self._send_json(*self.frontend.handle_varz())
        else:
            self._send_json(404, {"error": f"no route {path!r}"})

    def do_POST(self) -> None:  # noqa: N802 (stdlib API name)
        path = self.path.split("?", 1)[0]
        routes = {
            "/v1/search": lambda p: self.frontend.handle_search(p),
            "/v1/upsert": lambda p: self.frontend.handle_mutation("upsert", p),
            "/v1/delete": lambda p: self.frontend.handle_mutation("delete", p),
        }
        handler = routes.get(path)
        if handler is None:
            self._send_json(404, {"error": f"no route {path!r}"})
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(length) or b"{}")
            if not isinstance(payload, dict):
                raise ValueError("body must be a JSON object")
        except (ValueError, json.JSONDecodeError) as e:
            self._send_json(400, {"error": f"bad JSON body: {e}"})
            return
        self._send_json(*handler(payload))
