# Operational observability layer (DESIGN.md §12): metrics registry with
# Prometheus text exposition, per-request span tracing, structured JSON logs
# behind a ring-buffer sink, and the stdlib HTTP front-end over the serving
# runtime. Dependency direction: repro.serving imports repro.obs, never the
# reverse — every adapter here is duck-typed over runtime objects.
from repro.obs.adapters import (
    instrument_runtime,
    instrument_tier,
    latency_hist_samples,
    rollup_samples,
    runtime_families,
)
from repro.obs.logs import JsonLogger, RingBufferSink
from repro.obs.metrics import (
    CallbackFamily,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    format_value,
)
from repro.obs.promparse import (
    ExpositionParseError,
    ParsedFamily,
    parse_exposition,
)
from repro.obs.tracing import (
    STAGES,
    RequestTrace,
    stage_sum,
    trace_consistent,
)

__all__ = [
    "CallbackFamily",
    "Counter",
    "ExpositionParseError",
    "Gauge",
    "Histogram",
    "JsonLogger",
    "MetricsRegistry",
    "ParsedFamily",
    "RequestTrace",
    "RingBufferSink",
    "STAGES",
    "ServingFrontend",
    "format_value",
    "instrument_runtime",
    "instrument_tier",
    "latency_hist_samples",
    "parse_exposition",
    "rollup_samples",
    "runtime_families",
    "stage_sum",
    "trace_consistent",
]


def __getattr__(name: str):
    # The HTTP front-end imports threading/http.server; keep that out of
    # the import path of code that only wants metrics/tracing primitives.
    if name == "ServingFrontend":
        from repro.obs.http import ServingFrontend

        return ServingFrontend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
