"""Metrics primitives + Prometheus text exposition (DESIGN.md §12).

One ``MetricsRegistry`` per server: ``Counter`` / ``Gauge`` / ``Histogram``
families with label sets, plus ``CallbackFamily`` for pull-time adapters
over state the serving runtime already maintains (telemetry counters, the
log-bucketed latency histogram, batcher occupancy, slot-pool gauges —
obs/adapters.py). ``render_prometheus()`` emits the text exposition format
(HELP/TYPE lines, cumulative ``le`` buckets with a ``+Inf`` edge,
``_sum``/``_count``) that ``GET /metrics`` serves and
``obs/promparse.py`` round-trips in tests.

Values render via ``format_value``: integral values as integers and
everything else as ``repr(float)`` — the shortest string that parses back
to the identical float, so a scrape is *bit-identical* to the in-process
counters it came from (the PR 9 acceptance criterion).

This module is dependency-free on purpose (no jax, no repro.serving
imports): the serving layer imports obs, never the reverse.
"""
from __future__ import annotations

import math
import re
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

METRIC_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*\Z")
LABEL_NAME_RE = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*\Z")

# One exposition sample: (name suffix, ((label, value), ...), value).
# Suffix is "" for scalar samples, "_bucket"/"_sum"/"_count" for histograms.
Sample = Tuple[str, Tuple[Tuple[str, str], ...], float]


def format_value(v: float) -> str:
    """Exposition-format a sample value, round-trippably.

    Integral values print as integers (a counter scraped at 17 parses back
    to exactly 17); non-integral floats print via ``repr`` (guaranteed to
    parse back to the identical IEEE double since py3.1)."""
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    if math.isnan(f):
        return "NaN"
    if f.is_integer() and abs(f) < 2**53:
        return str(int(f))
    return repr(f)


def escape_label_value(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r"\"").replace("\n", r"\n")


def escape_help(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n")


def _check_name(name: str) -> str:
    if not METRIC_NAME_RE.match(name):
        raise ValueError(f"invalid metric name: {name!r}")
    return name


def _check_labels(names: Sequence[str]) -> Tuple[str, ...]:
    out = tuple(names)
    for n in out:
        if not LABEL_NAME_RE.match(n) or n.startswith("__"):
            raise ValueError(f"invalid label name: {n!r}")
    if len(set(out)) != len(out):
        raise ValueError(f"duplicate label names: {out}")
    return out


class MetricFamily:
    """Base: one named family, children keyed by label-value tuples."""

    mtype = "untyped"

    def __init__(self, name: str, help: str, label_names: Sequence[str] = ()):
        self.name = _check_name(name)
        self.help = str(help)
        self.label_names = _check_labels(label_names)
        self._children: Dict[Tuple[str, ...], object] = {}

    def _child_factory(self):
        raise NotImplementedError

    def labels(self, **kw) -> object:
        if set(kw) != set(self.label_names):
            raise ValueError(
                f"{self.name}: labels {sorted(kw)} != declared "
                f"{sorted(self.label_names)}"
            )
        key = tuple(str(kw[n]) for n in self.label_names)
        child = self._children.get(key)
        if child is None:
            child = self._child_factory()
            self._children[key] = child
        return child

    def _default_child(self):
        """The label-less singleton child (families declared without
        labels operate through it directly)."""
        if self.label_names:
            raise ValueError(f"{self.name} declares labels; use .labels()")
        return self.labels()

    def _label_pairs(self, key: Tuple[str, ...]) -> Tuple[Tuple[str, str], ...]:
        return tuple(zip(self.label_names, key))

    def samples(self) -> Iterable[Sample]:
        raise NotImplementedError


class _CounterChild:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up (inc by {amount})")
        self.value += float(amount)


class Counter(MetricFamily):
    mtype = "counter"
    _child_factory = _CounterChild

    def inc(self, amount: float = 1.0) -> None:
        self._default_child().inc(amount)

    @property
    def value(self) -> float:
        return self._default_child().value

    def samples(self) -> Iterable[Sample]:
        for key, child in self._children.items():
            yield ("", self._label_pairs(key), child.value)


class _GaugeChild:
    __slots__ = ("value", "fn")

    def __init__(self):
        self.value = 0.0
        self.fn: Optional[Callable[[], float]] = None

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, amount: float = 1.0) -> None:
        self.value += float(amount)

    def dec(self, amount: float = 1.0) -> None:
        self.value -= float(amount)

    def set_function(self, fn: Callable[[], float]) -> None:
        """Pull-time gauge: ``fn`` is evaluated at every collection."""
        self.fn = fn

    def current(self) -> float:
        return float(self.fn()) if self.fn is not None else self.value


class Gauge(MetricFamily):
    mtype = "gauge"
    _child_factory = _GaugeChild

    def set(self, v: float) -> None:
        self._default_child().set(v)

    def inc(self, amount: float = 1.0) -> None:
        self._default_child().inc(amount)

    def dec(self, amount: float = 1.0) -> None:
        self._default_child().dec(amount)

    def set_function(self, fn: Callable[[], float]) -> None:
        self._default_child().set_function(fn)

    @property
    def value(self) -> float:
        return self._default_child().current()

    def samples(self) -> Iterable[Sample]:
        for key, child in self._children.items():
            yield ("", self._label_pairs(key), child.current())


class _HistogramChild:
    __slots__ = ("counts", "sum", "count", "edges")

    def __init__(self, edges: Tuple[float, ...]):
        self.edges = edges  # upper edges, ascending, last is +inf
        self.counts = [0] * len(edges)
        self.sum = 0.0
        self.count = 0

    def observe(self, x: float) -> None:
        x = float(x)
        self.sum += x
        self.count += 1
        # linear scan is fine: exposition histograms here have <= ~100
        # buckets and observe() is not on the per-candidate hot path.
        for i, edge in enumerate(self.edges):
            if x <= edge:
                self.counts[i] += 1
                return


DEFAULT_BUCKETS = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
    0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class Histogram(MetricFamily):
    mtype = "histogram"

    def __init__(
        self,
        name: str,
        help: str,
        label_names: Sequence[str] = (),
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ):
        super().__init__(name, help, label_names)
        edges = tuple(float(b) for b in buckets)
        if list(edges) != sorted(set(edges)):
            raise ValueError(f"bucket edges must be sorted unique: {edges}")
        if not edges or edges[-1] != float("inf"):
            edges = edges + (float("inf"),)
        self._edges = edges

    def _child_factory(self):
        return _HistogramChild(self._edges)

    def observe(self, x: float) -> None:
        self._default_child().observe(x)

    def samples(self) -> Iterable[Sample]:
        for key, child in self._children.items():
            pairs = self._label_pairs(key)
            cum = 0
            for edge, c in zip(child.edges, child.counts):
                cum += c
                yield (
                    "_bucket",
                    pairs + (("le", format_value(edge)),),
                    float(cum),
                )
            yield ("_sum", pairs, child.sum)
            yield ("_count", pairs, float(child.count))


class CallbackFamily(MetricFamily):
    """Pull-time family over external state: ``fn()`` returns the full
    sample list at collection time. This is how the adapters expose the
    runtime's existing counters/histograms without double-bookkeeping —
    the scrape reads the same objects the controller and benches read, so
    the exposition cannot drift from ``Telemetry.summary()``."""

    def __init__(
        self,
        name: str,
        mtype: str,
        help: str,
        fn: Callable[[], Iterable[Sample]],
    ):
        super().__init__(name, help, ())
        if mtype not in ("counter", "gauge", "histogram", "untyped"):
            raise ValueError(f"unknown metric type {mtype!r}")
        self.mtype = mtype
        self._fn = fn

    def samples(self) -> Iterable[Sample]:
        return self._fn()


class MetricsRegistry:
    """One registry per server: families registered once by unique name,
    collected in name order, rendered as the Prometheus text format."""

    def __init__(self):
        self._families: Dict[str, MetricFamily] = {}

    def register(self, family: MetricFamily) -> MetricFamily:
        if family.name in self._families:
            raise ValueError(f"metric {family.name!r} already registered")
        self._families[family.name] = family
        return family

    # --- convenience constructors ----------------------------------------
    def counter(self, name: str, help: str, labels: Sequence[str] = ()) -> Counter:
        return self.register(Counter(name, help, labels))  # type: ignore[return-value]

    def gauge(self, name: str, help: str, labels: Sequence[str] = ()) -> Gauge:
        return self.register(Gauge(name, help, labels))  # type: ignore[return-value]

    def histogram(
        self,
        name: str,
        help: str,
        labels: Sequence[str] = (),
        buckets: Sequence[float] = DEFAULT_BUCKETS,
    ) -> Histogram:
        return self.register(Histogram(name, help, labels, buckets))  # type: ignore[return-value]

    def callback(
        self, name: str, mtype: str, help: str, fn: Callable[[], Iterable[Sample]]
    ) -> CallbackFamily:
        return self.register(CallbackFamily(name, mtype, help, fn))  # type: ignore[return-value]

    # --- collection -------------------------------------------------------
    def collect(self) -> List[MetricFamily]:
        return [self._families[n] for n in sorted(self._families)]

    def render_prometheus(self) -> str:
        """The ``GET /metrics`` payload: HELP/TYPE lines then samples, one
        family after another in name order."""
        lines: List[str] = []
        for fam in self.collect():
            lines.append(f"# HELP {fam.name} {escape_help(fam.help)}")
            lines.append(f"# TYPE {fam.name} {fam.mtype}")
            for suffix, labels, value in fam.samples():
                name = fam.name + suffix
                if labels:
                    body = ",".join(
                        f'{k}="{escape_label_value(str(v))}"' for k, v in labels
                    )
                    lines.append(f"{name}{{{body}}} {format_value(value)}")
                else:
                    lines.append(f"{name} {format_value(value)}")
        return "\n".join(lines) + "\n"
