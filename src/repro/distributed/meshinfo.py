"""Mesh metadata threaded through model builders.

Axis roles:
  * ``pod``   — data parallelism across pods (outermost; optional)
  * ``data``  — data parallel / FSDP parameter+optimizer sharding
  * ``model`` — tensor / expert / sequence(-cache) parallelism

Models never hardcode axis names; they consume a MeshInfo and emit
PartitionSpecs relative to it, so the same model code runs on the 1-device
test mesh, the 16x16 single pod, and the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

Array = jax.Array


@dataclass(frozen=True)
class MeshInfo:
    mesh: Mesh

    @property
    def axis_names(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def has_pod(self) -> bool:
        return "pod" in self.axis_names

    @property
    def dp_axes(self) -> Tuple[str, ...]:
        return ("pod", "data") if self.has_pod else ("data",)

    @property
    def tp_axis(self) -> str:
        return "model"

    @property
    def dp_size(self) -> int:
        size = 1
        for a in self.dp_axes:
            size *= self.mesh.shape[a]
        return size

    @property
    def tp_size(self) -> int:
        return self.mesh.shape["model"]

    @property
    def fsdp_axis(self):
        """Parameter/optimizer sharding axes (ZeRO): spans every DP axis, so
        multi-pod runs shard state across pods too instead of replicating."""
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def axes_if_divisible(self, dim: int, axes):
        """Return ``axes`` when they evenly divide ``dim``, else None.

        Used to drop shardings that cannot apply (e.g. batch=1 decode cannot
        shard over the data axes; an 8-way KV-head dim cannot shard over a
        16-way model axis).
        """
        if axes is None:
            return None

        def flat(a):
            if isinstance(a, str):
                return (a,)
            out = ()
            for x in a:
                out += flat(x)
            return out

        size = 1
        for a in flat(axes):
            size *= self.mesh.shape[a]
        return axes if dim % size == 0 else None

    def constrain(self, x: Array, *spec) -> Array:
        """with_sharding_constraint that silently skips non-divisible dims."""
        fixed = []
        for dim, s in zip(x.shape, spec):
            if s is None:
                fixed.append(None)
                continue
            axes = (s,) if isinstance(s, str) else tuple(s)
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            fixed.append(s if dim % size == 0 else None)
        # Trailing unspecified dims stay unsharded.
        return jax.lax.with_sharding_constraint(x, self.sharding(*fixed))


def single_device_meshinfo() -> MeshInfo:
    """1-chip mesh with the production axis names (for CPU tests)."""
    dev = jax.devices()[0]
    import numpy as np

    mesh = Mesh(np.asarray([dev]).reshape(1, 1), ("data", "model"))
    return MeshInfo(mesh=mesh)
