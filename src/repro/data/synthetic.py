"""Synthetic corpora reproducing the paper's data protocol (§3, 'Data').

The paper uses SIFT1M with k-means (k=10) cluster-ids as labels, optionally
randomized: with probability R% a vector gets a uniformly random label
instead of its cluster id. This module generates cluster-structured vectors
directly (offline container — no downloads), applies the same k-means
labeling + R% randomization, and synthesizes queries with labels generated
"in the same fashion as the base vectors".
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.common.kmeans import kmeans
from repro.core.types import Corpus

Array = jax.Array


def clustered_vectors(
    rng: Array,
    n: int,
    d: int,
    n_clusters: int,
    *,
    spread: float = 0.15,
    anisotropic: bool = False,
) -> tuple[Array, Array]:
    """Gaussian blobs on the unit sphere; returns (vectors (n,d), true (n,))."""
    r_cent, r_assign, r_noise, r_cov = jax.random.split(rng, 4)
    centers = jax.random.normal(r_cent, (n_clusters, d))
    centers = centers / jnp.linalg.norm(centers, axis=-1, keepdims=True)
    assign = jax.random.randint(r_assign, (n,), 0, n_clusters, dtype=jnp.int32)
    noise = jax.random.normal(r_noise, (n, d)) * spread
    if anisotropic:
        # Per-cluster random axis scaling (MNIST-ish uneven class shapes).
        scales = jax.random.uniform(r_cov, (n_clusters, d), minval=0.3, maxval=1.7)
        noise = noise * scales[assign]
    return centers[assign] + noise, assign


def kmeans_labels(
    rng: Array, vectors: Array, k: int, sample: int = 100_000, iters: int = 15
) -> Array:
    """Paper labeling: cluster with k-means, label = cluster id.

    k-means is fit on a subsample for speed, then all vectors are assigned.
    """
    n = vectors.shape[0]
    r_s, r_k = jax.random.split(rng)
    if n > sample:
        idx = jax.random.choice(r_s, n, (sample,), replace=False)
        fit = vectors[idx]
    else:
        fit = vectors
    cent, _ = kmeans(r_k, fit, k, iters)
    from repro.common.distances import squared_l2

    return jnp.argmin(squared_l2(vectors, cent), axis=-1).astype(jnp.int32)


def randomize_labels(
    rng: Array, labels: Array, n_labels: int, pct_random: float
) -> Array:
    """R% randomness (paper §3): with prob R%, replace by a uniform label."""
    if pct_random <= 0:
        return labels
    r_mask, r_lab = jax.random.split(rng)
    coin = jax.random.uniform(r_mask, labels.shape) < (pct_random / 100.0)
    rand = jax.random.randint(r_lab, labels.shape, 0, n_labels, dtype=labels.dtype)
    return jnp.where(coin, rand, labels)


def make_labeled_corpus(
    rng: Array,
    n: int,
    d: int,
    n_labels: int,
    *,
    pct_random: float = 0.0,
    spread: float = 0.15,
    anisotropic: bool = False,
    use_kmeans_labels: bool = True,
) -> Corpus:
    """End-to-end §3 protocol: clustered vectors -> k-means labels -> R%."""
    r_v, r_k, r_r = jax.random.split(rng, 3)
    vecs, true = clustered_vectors(
        r_v, n, d, n_labels, spread=spread, anisotropic=anisotropic
    )
    labels = kmeans_labels(r_k, vecs, n_labels) if use_kmeans_labels else true
    labels = randomize_labels(r_r, labels, n_labels, pct_random)
    return Corpus(vectors=vecs, labels=labels)


def make_queries(
    rng: Array, corpus: Corpus, n_queries: int, *, jitter: float = 0.05
) -> tuple[Array, Array]:
    """Queries drawn near random corpus points; labels inherited (paper:
    'the label of the query vector is generated in the same fashion')."""
    r_pick, r_noise = jax.random.split(rng)
    idx = jax.random.choice(r_pick, corpus.n, (n_queries,), replace=False)
    q = corpus.vectors[idx] + jax.random.normal(
        r_noise, (n_queries, corpus.dim)
    ) * jitter
    return q, corpus.labels[idx]
