"""Deterministic, restart-safe synthetic data pipelines.

Every batch is a pure function of (seed, step): a restarted run that resumes
at step N regenerates exactly the batches it would have seen — no data-state
checkpointing needed. Each model family gets a generator matching the
assigned input shapes; ``shard_batch`` device-puts host batches with the
mesh's data-parallel layout.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.distributed.meshinfo import MeshInfo

Array = jax.Array


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, step]))


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    r = _rng(seed, step)
    # Zipf-ish marginal over the vocab (more realistic logits than uniform).
    z = r.zipf(1.3, size=(batch, seq)).astype(np.int64)
    return {"tokens": jnp.asarray(np.minimum(z, vocab - 1), jnp.int32)}


def dlrm_batch(seed: int, step: int, batch: int, n_dense: int, vocabs) -> dict:
    r = _rng(seed, step)
    sparse = np.stack(
        [r.integers(0, v, size=batch) for v in vocabs], axis=1
    ).astype(np.int32)
    return {
        "dense": jnp.asarray(r.normal(size=(batch, n_dense)), jnp.float32),
        "sparse": jnp.asarray(sparse),
        "label": jnp.asarray(r.integers(0, 2, size=batch), jnp.float32),
    }


def deepfm_batch(seed: int, step: int, batch: int, vocabs) -> dict:
    r = _rng(seed, step)
    sparse = np.stack(
        [r.integers(0, v, size=batch) for v in vocabs], axis=1
    ).astype(np.int32)
    return {
        "sparse": jnp.asarray(sparse),
        "label": jnp.asarray(r.integers(0, 2, size=batch), jnp.float32),
    }


def sasrec_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    r = _rng(seed, step)
    seqs = r.integers(1, vocab, size=(batch, seq + 1)).astype(np.int32)
    return {
        "seq": jnp.asarray(seqs[:, :-1]),
        "pos": jnp.asarray(seqs[:, 1:]),
        "neg": jnp.asarray(r.integers(1, vocab, size=(batch, seq)), jnp.int32),
    }


def two_tower_batch(
    seed: int, step: int, batch: int, user_vocab: int, item_vocab: int, hist: int
) -> dict:
    r = _rng(seed, step)
    h = r.integers(0, item_vocab, size=(batch, hist)).astype(np.int32)
    h[r.random(size=h.shape) < 0.3] = -1  # ragged histories via padding
    return {
        "user_id": jnp.asarray(r.integers(0, user_vocab, size=batch), jnp.int32),
        "hist": jnp.asarray(h),
        "item_id": jnp.asarray(r.integers(0, item_vocab, size=batch), jnp.int32),
    }


def gnn_batch(
    seed: int,
    step: int,
    n_nodes: int,
    n_edges: int,
    n_species: int = 32,
    d_feat: int = 0,
    n_graphs: int = 1,
) -> dict:
    r = _rng(seed, step)
    out = {
        "positions": jnp.asarray(r.normal(size=(n_nodes, 3)), jnp.float32),
        "senders": jnp.asarray(r.integers(0, n_nodes, size=n_edges), jnp.int32),
        "receivers": jnp.asarray(r.integers(0, n_nodes, size=n_edges), jnp.int32),
        "energy": jnp.asarray(r.normal(size=(n_graphs,)), jnp.float32),
        "forces": jnp.asarray(r.normal(size=(n_nodes, 3)) * 0.1, jnp.float32),
    }
    if d_feat:
        out["node_feat"] = jnp.asarray(r.normal(size=(n_nodes, d_feat)), jnp.float32)
    else:
        out["species"] = jnp.asarray(r.integers(0, n_species, size=n_nodes), jnp.int32)
    if n_graphs > 1:
        out["node_graph"] = jnp.asarray(
            np.sort(r.integers(0, n_graphs, size=n_nodes)), jnp.int32
        )
        out["n_graphs"] = n_graphs
    return out


def shard_batch(batch: dict, mi: MeshInfo) -> dict:
    """Device-put a host batch with batch-dim sharding over the dp axes."""
    def put(x):
        spec = mi.axes_if_divisible(x.shape[0], mi.dp_axes) if x.ndim else None
        return jax.device_put(x, NamedSharding(mi.mesh, P(spec)))

    return jax.tree.map(put, batch)
