"""Benchmark entry point — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Figures covered:
Fig. 1 (pipeline under-fill), Fig. 3 (constraint families), Fig. 4
(alter_ratio estimation), Fig. 5 (cluster counts), Fig. 6 (MNIST-style
cross-class), plus kernel micro-benches.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        default="",
        help="comma list: pipeline,constraints,alter_ratio,clusters,mnist,"
        "kernels,beam,fused,serving,streaming,hybrid,slo,autotune,obs,"
        "replicas",
    )
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny shapes + interpret-mode kernels for the suites that "
        "support it (currently: fused, serving, streaming) — the CI mode "
        "exercising the fused pipeline incl. BOTH Pallas kernels (exact "
        "rows and PQ/ADC code rows), the serving runtime's acceptance row "
        "and the streaming churn acceptance row in seconds, without "
        "writing BENCH_*.json artifacts; other suites ignore the flag",
    )
    ap.add_argument(
        "--json-out",
        default="",
        help="also append every suite output line to this file — the "
        "JSON lines are what benchmarks/check_regression.py diffs "
        "against the committed BENCH_*.json smoke references",
    )
    args = ap.parse_args()
    selected = set(filter(None, args.only.split(",")))
    if args.smoke:
        import os

        os.environ["REPRO_BENCH_SMOKE"] = "1"

    from benchmarks import (
        bench_alter_ratio,
        bench_autotune,
        bench_beam,
        bench_clusters,
        bench_constraints,
        bench_fused,
        bench_hybrid,
        bench_kernels,
        bench_mnist_like,
        bench_obs,
        bench_pipeline,
        bench_replicas,
        bench_serving,
        bench_slo,
        bench_streaming,
    )

    suites = {
        "pipeline": bench_pipeline.main,
        "constraints": bench_constraints.main,
        "alter_ratio": bench_alter_ratio.main,
        "clusters": bench_clusters.main,
        "mnist": bench_mnist_like.main,
        "kernels": bench_kernels.main,
        # bench_beam emits one JSON line per (constraint, mode, beam_width)
        # config — machine-readable for BENCH_*.json speedup trajectories.
        "beam": bench_beam.main,
        # bench_fused compares the fused candidate pipeline (ISSUE 2/3)
        # against the unfused path and writes top-level BENCH_PR2.json
        # (exact backend; `--backend pq` standalone writes BENCH_PR3.json).
        # In smoke mode it exercises both interpret kernels regardless.
        "fused": bench_fused.main,
        # bench_serving replays one Poisson mixed workload through the
        # serving runtime vs per-request (batch=1) dispatch and asserts the
        # acceptance row (>=2x QPS, escalation-tier fill, bounded traces);
        # full mode writes top-level BENCH_PR4.json.
        "serving": bench_serving.main,
        # bench_streaming replays a churn stream (inserts/deletes/queries)
        # through the streaming mutable index vs a periodically rebuilt
        # static oracle and asserts the acceptance row (recall gap <= 5
        # pts, ZERO tombstoned ids returned); full mode writes BENCH_PR5.json.
        "streaming": bench_streaming.main,
        # bench_hybrid sweeps constraint selectivity 0.1%-50% and times
        # graph walk vs posting scan vs label overlay vs the strategy
        # router; asserts router within 10% of the best lattice-admissible
        # strategy everywhere, >= 2x over
        # pure graph at <= 1% selectivity at equal recall, bit-exact ids
        # vs the dispatched strategy; full mode writes BENCH_PR6.json.
        "hybrid": bench_hybrid.main,
        # bench_slo replays a burst + fault-schedule workload through the
        # fault-tolerant runtime vs the pre-PR7 no-shedding baseline and
        # asserts the acceptance row (slo goodput > baseline under the
        # burst, zero unmarked late completions, zero lost/hung requests);
        # full mode writes BENCH_PR7.json.
        "slo": bench_slo.main,
        # bench_autotune sweeps the kernel block-shape lattice (PR8): full
        # mode writes the committed tuning table (src/repro/tune/table.json)
        # + BENCH_PR8.json; smoke mode re-times a tiny per-kernel sweep
        # (achieved roofline_fraction, gated vs the committed floor) and
        # re-validates the table's schema/lattice/loader reproducibility.
        "autotune": bench_autotune.main,
        # bench_obs measures the observability layer (PR9): tracing+logging
        # overhead on host wall time vs the untraced runtime, trace
        # completeness (every response's stage breakdown tiles its latency
        # within 1%), and an HTTP replay through ServingFrontend whose
        # scraped /metrics must parse BIT-identical to the in-process
        # Telemetry; full mode writes BENCH_PR9.json.
        "obs": bench_obs.main,
        # bench_replicas boots N shared-nothing streaming replicas behind
        # one HTTP front-end (PR10) and measures goodput/p99/fill scaling
        # vs the 1-replica baseline SOLELY from parsed /metrics scrapes
        # (per-replica virtual execute seconds as the busy denominator);
        # asserts zero lost/hung requests, replica-label cumulativity and
        # one streaming epoch across replicas; full mode (sizes 1/2/4,
        # >= 2.5x at 4 replicas) writes BENCH_PR10.json.
        "replicas": bench_replicas.main,
    }
    print("name,us_per_call,derived")

    json_fh = open(args.json_out, "a") if args.json_out else None

    def out(line: str) -> None:
        print(line, flush=True)
        if json_fh is not None:
            json_fh.write(line + "\n")
            json_fh.flush()

    failed = []
    for name, fn in suites.items():
        if selected and name not in selected:
            continue
        t0 = time.time()
        try:
            fn(out)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            out(f"{name}/ERROR,0,{type(e).__name__}:{str(e)[:120]}")
            failed.append(name)
        print(f"# suite {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if json_fh is not None:
        json_fh.close()
    if failed:
        # Later suites still ran, but the process must fail so CI's smoke
        # step actually gates on the benchmarked code paths.
        print(f"# FAILED suites: {','.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
